"""End-to-end training driver: train a small MoE LM for a few hundred
steps on the synthetic motif dataset, with checkpointing and (optionally)
an injected failure + automatic recovery mid-run.

  PYTHONPATH=src python examples/train_lm.py                # ~10M params
  PYTHONPATH=src python examples/train_lm.py --steps 300 --inject-failure
  PYTHONPATH=src python examples/train_lm.py --d-model 512 --layers 8 \
      --steps 200          # ~100M-param configuration (slow on CPU)
"""
import argparse
import os
import tempfile

import jax
import numpy as np

from repro.configs import get_arch
from repro.training.data import DataConfig, SyntheticLM
from repro.training.fault_tolerance import FailureInjector, run_with_recovery
from repro.training.train_loop import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--experts", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--inject-failure", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    base = get_arch("olmoe-1b-7b")
    cfg = base.replace(
        num_layers=args.layers, d_model=args.d_model,
        num_heads=max(args.d_model // 32, 1),
        num_kv_heads=max(args.d_model // 32, 1),
        d_head=32, d_ff=args.d_model * 2, vocab_size=2048,
        moe=base.moe and base.moe.__class__(
            num_experts=args.experts, experts_per_token=2,
            d_expert=args.d_model // 2))
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")

    tc = TrainConfig(lr=args.lr, microbatches=args.microbatches,
                     grad_compress=args.grad_compress, log_every=10,
                     ckpt_every=25, ckpt_dir=ckpt_dir)
    tr = Trainer(cfg, tc)
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(tr.params))
    print(f"model: {n / 1e6:.1f}M params ({args.layers}L d={args.d_model} "
          f"{args.experts}e top-2) | ckpts -> {ckpt_dir}")

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq,
                                  global_batch=args.batch))
    if args.inject_failure:
        inj = FailureInjector(fail_at=[args.steps // 2])
        rep = run_with_recovery(tr, data, args.steps, injector=inj)
        print(f"\nrecovered from {rep.restarts} failure(s): "
              f"{rep.recovery_log}")
        losses = rep.losses
    else:
        losses = tr.run(data, args.steps)

    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"\nloss: {first:.3f} -> {last:.3f} over {len(losses)} steps "
          f"({'LEARNING' if last < first - 0.2 else 'check config'})")


if __name__ == "__main__":
    main()
