"""Quickstart: the three layers of this repo in ~60 seconds on a laptop.

  1. ANALYSIS  — the paper's methodology: which network topology is the
                 most cost-effective for serving a given MoE model?
  2. MODEL     — a reduced MoE transformer (same family as olmoe-1b-7b):
                 one train step, prefill, and a few decode steps on CPU.
  3. KERNEL    — the Pallas MoE expert kernel vs its jnp oracle
                 (interpret mode on CPU; compiled on TPU).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced_config
from repro.core import H100, Scenario, SearchSpec, make_cluster, solve
from repro.core.tco import cluster_tco
from repro.models import model as M
from repro.sharding.dist import NullDist
from repro.sharding.plans import null_plan

print("=" * 64)
print("1) ANALYSIS — topology cost-effectiveness (DeepSeek-V3, 64 XPUs,")
print("   chatbot scenario: TPOT=40ms, context=512, DBO+SD)")
print("=" * 64)
cfg_paper = get_arch("deepseek-v3")
sc = Scenario(40.0, 512)
for topo in ("scale-up", "scale-out", "torus", "fullmesh"):
    cl = make_cluster(topo, 64, H100)
    sol = solve(cfg_paper, cl, sc, SearchSpec(opts="dbo+sd"))
    cost = cluster_tco(cl).per_xpu(64)
    thpt = sol.throughput / 64
    print(f"  {topo:10s} {thpt:8.0f} tok/s/XPU  cost {cost:7.1f}/mo"
          f"  -> {thpt / cost:6.2f} tok/s per cost unit")

print()
print("=" * 64)
print("2) MODEL — reduced olmoe (64 experts->8): train / prefill / decode")
print("=" * 64)
cfg = reduced_config(get_arch("olmoe-1b-7b"))
plan, dist = null_plan("train"), NullDist()
params, _ = M.init_model(cfg, plan, jax.random.PRNGKey(0))
n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
print(f"  params: {n_params / 1e6:.2f}M  layers={cfg.num_layers} "
      f"experts={cfg.moe.num_experts} top-{cfg.moe.experts_per_token}")

tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                            cfg.vocab_size)
loss = M.train_loss(params, {"tokens": tokens}, cfg, plan, dist, remat=False)
print(f"  train loss (random init): {float(loss):.3f} "
      f"(ln V = {np.log(cfg.vocab_size):.3f})")

dplan = null_plan("decode")
tok, caches = M.prefill(params, {"tokens": tokens}, cfg,
                        null_plan("prefill"), dist)
seq = [int(t) for t in tok[:, 0]]
pos = tokens.shape[1]
from repro.serving import kvcache
caches = kvcache.pad_to_capacity(cfg, caches, pos, 32)
for _ in range(5):
    tok, caches = M.decode_step(params, caches, tok, jnp.int32(pos), cfg,
                                dplan, dist)
    seq.append(int(tok[0, 0]))
    pos += 1
print(f"  greedy continuation (request 0): {seq}")

print()
print("=" * 64)
print("3) KERNEL — Pallas moe_gmm (interpret) vs jnp oracle")
print("=" * 64)
from repro.kernels import ref
from repro.kernels.moe_gmm import moe_gmm_pallas
ks = jax.random.split(jax.random.PRNGKey(2), 4)
e, t, d, f = 2, 128, 64, 256
x = jax.random.normal(ks[0], (e, t, d), jnp.float32) * 0.3
wg = jax.random.normal(ks[1], (e, d, f), jnp.float32) * 0.1
wu = jax.random.normal(ks[2], (e, d, f), jnp.float32) * 0.1
wd = jax.random.normal(ks[3], (e, f, d), jnp.float32) * 0.1
got = moe_gmm_pallas(x, wg, wu, wd, interpret=True)
want = ref.moe_gmm_ref(x, wg, wu, wd)
err = float(jnp.max(jnp.abs(got - want)))
print(f"  [E={e}, T={t}, D={d}, F={f}]  max |pallas - ref| = {err:.2e}")
print("\nquickstart OK")
