"""End-to-end serving driver (the paper's workload kind): batched requests
through the continuous-batching engine, with and without speculative
decoding, on a reduced MoE model.

  PYTHONPATH=src python examples/serve_moe.py [--arch olmoe-1b-7b]
      [--requests 12] [--max-batch 4] [--sd]

Prints per-request completions, slot reuse, and tokens/s; with --sd also
runs the speculative decoder and reports acceptance + the greedy-equality
check (SD must never change outputs).
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced_config
from repro.models import model as M
from repro.serving import kvcache
from repro.serving.engine import Engine
from repro.serving.specdec import SDDecoder
from repro.sharding.dist import NullDist
from repro.sharding.plans import null_plan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-1b-7b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=96)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--sd", action="store_true",
                    help="also run the speculative decoder")
    args = ap.parse_args()

    cfg = reduced_config(get_arch(args.arch))
    params, _ = M.init_model(cfg, null_plan("decode"), jax.random.PRNGKey(0))
    print(f"arch={args.arch} (reduced) layers={cfg.num_layers} "
          f"d_model={cfg.d_model} vocab={cfg.vocab_size}")

    eng = Engine(cfg, params, max_batch=args.max_batch,
                 max_seq=args.max_seq, eos_id=-1)
    prompts = [[(7 * i + j) % (cfg.vocab_size - 1) + 1 for j in range(6)]
               for i in range(args.requests)]
    rids = [eng.submit(p, max_new_tokens=args.new_tokens) for p in prompts]
    print(f"submitted {len(rids)} requests into {args.max_batch} slots "
          f"(continuous batching)")

    t0 = time.time()
    out = eng.run()
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in out.values())
    print(f"completed {len(out)} requests, {total_tokens} tokens "
          f"in {dt:.1f}s ({total_tokens / dt:.1f} tok/s on CPU)")
    for rid in rids[:4]:
        print(f"  req {rid}: prompt={prompts[rid]} -> {out[rid]}")
    if len(rids) > 4:
        print(f"  ... ({len(rids) - 4} more)")

    if args.sd:
        print("\nspeculative decoding (spec_m=4, untrained Medusa heads):")
        prompt = jnp.asarray([prompts[0]], jnp.int32)
        tok, caches = M.prefill(params, {"tokens": prompt}, cfg,
                                null_plan("prefill"), NullDist())
        caches = kvcache.pad_to_capacity(cfg, caches, prompt.shape[1],
                                         args.max_seq)
        dec = SDDecoder(cfg, params, spec_m=4)
        toks, _, stats = dec.generate(caches, tok, prompt.shape[1],
                                      args.new_tokens)
        got = [int(tok[0, 0])] + [int(t) for t in toks[0]]
        want = out[rids[0]][:len(got)]
        print(f"  SD output:     {got}")
        print(f"  greedy output: {want}")
        print(f"  identical: {got == want}  "
              f"mean accepted/iter: {stats['mean_accepted']:.2f} "
              f"({stats['iterations']} iterations)")


if __name__ == "__main__":
    main()
