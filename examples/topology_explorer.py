"""Topology explorer — the paper's methodology as an interactive tool.

Given a model, cluster size, and serving scenario, report every topology's
max throughput under the SLO, its TCO, and throughput-per-cost; optionally
sweep link bandwidth to find the provisioning sweet spot (paper section 4.2)
or render the DBO two-lane schedule (paper Fig 4).

  PYTHONPATH=src python examples/topology_explorer.py \
      --tpot 40 --context 512 --xpus 64 [--arch deepseek-v3] [--gen H100]
      [--bw-sweep] [--show-schedule]
"""
import argparse

from repro.configs import get_arch
from repro.core import (GENERATIONS, Scenario, SearchSpec, make_cluster,
                        solve)
from repro.core.tco import cluster_tco
from repro.core.workload import ServingPoint


def show_schedule(cfg, cluster, batch):
    from repro.core.optimizer import _timers
    from repro.core.overlap import simulate_lanes, to_timed
    from repro.core.workload import decode_iteration
    half = ServingPoint(batch_global=batch // 2, context=512,
                        ep=cluster.n_xpus, n_devices=cluster.n_xpus)
    ops = decode_iteration(cfg, half)[:18]        # first ~2 layers
    t_comp, t_comm = _timers(cluster, half)
    res = simulate_lanes(to_timed(ops, t_comp, t_comm, 0),
                         to_timed(ops, t_comp, t_comm, 1), stagger=3)
    span = res.makespan
    width = 70
    print(f"\nDBO two-lane schedule (first 2 layers, batch {batch}, "
          f"{cluster.topology}):")
    for lane in ("compute", "comm"):
        line = [" "] * width
        for (name, mb, s, e) in res.timeline:
            opl = "compute" if not ("a2a" in name or "_ar" in name) else "comm"
            if opl != lane:
                continue
            i0 = int(s / span * (width - 1))
            i1 = max(int(e / span * (width - 1)), i0 + 1)
            ch = "A" if mb == 0 else "B"
            for i in range(i0, min(i1, width)):
                line[i] = ch
        print(f"  {lane:8s} |{''.join(line)}|")
    print(f"  makespan {res.makespan * 1e3:.2f} ms, exposed comm "
          f"{res.exposed_comm * 1e3:.2f} ms")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v3")
    ap.add_argument("--tpot", type=float, default=40.0)
    ap.add_argument("--context", type=int, default=512)
    ap.add_argument("--xpus", type=int, default=64, choices=(64, 256))
    ap.add_argument("--gen", default="H100", choices=sorted(GENERATIONS))
    ap.add_argument("--opts", default="dbo+sd",
                    choices=("noopt", "dbo", "dbo+sd"))
    ap.add_argument("--c", type=float, default=1.0,
                    help="network-cost adjustment factor")
    ap.add_argument("--bw-sweep", action="store_true")
    ap.add_argument("--show-schedule", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    xpu = GENERATIONS[args.gen]
    sc = Scenario(args.tpot, args.context)
    print(f"model={args.arch}  scenario: TPOT<={args.tpot}ms "
          f"ctx={args.context}  {args.xpus}x {args.gen}  opts={args.opts}")
    print(f"{'topology':>10} {'thpt/XPU':>9} {'batch':>7} {'TPOT ms':>8} "
          f"{'ECT ms':>7} {'cost/XPU':>9} {'thpt/cost':>9}")
    best = None
    for topo in ("scale-up", "scale-out", "torus", "fullmesh"):
        cl = make_cluster(topo, args.xpus, xpu)
        op = solve(cfg, cl, sc, SearchSpec(opts=args.opts)).point
        cost = cluster_tco(cl).per_xpu(args.xpus, args.c)
        if op is None:
            print(f"{topo:>10} {'SLO MISS':>9} {'-':>7} {'-':>8} {'-':>7} "
                  f"{cost:9.1f} {'-':>9}")
            continue
        tpc = op.throughput / args.xpus / cost
        if best is None or tpc > best[1]:
            best = (topo, tpc, op)
        print(f"{topo:>10} {op.throughput / args.xpus:9.0f} {op.batch:7d} "
              f"{op.tpot * 1e3:8.2f} {op.exposed_comm * 1e3:7.2f} "
              f"{cost:9.1f} {tpc:9.2f}")
    if best:
        print(f"\nmost cost-effective: {best[0]} "
              f"({best[1]:.2f} tok/s per cost unit)")

    if args.bw_sweep:
        print(f"\nlink-bandwidth sweep (scale-up, fractions of "
              f"{xpu.scale_up_bw / 1e9:.0f} GB/s):")
        for f in (1 / 9, 1 / 3, 2 / 3, 1.0, 2.0):
            cl = make_cluster("scale-up", args.xpus, xpu,
                              link_bw=xpu.scale_up_bw * f)
            op = solve(cfg, cl, sc, SearchSpec(opts=args.opts)).point
            cost = cluster_tco(cl).per_xpu(args.xpus, args.c)
            tpc = op.throughput / args.xpus / cost if op else 0.0
            print(f"  {f:4.2f}x ({cl.link_bw / 1e9:5.0f} GB/s): "
                  f"thpt/cost {tpc:7.2f}"
                  + ("  <- sweet spot candidate" if op else "  (SLO miss)"))

    if args.show_schedule and best:
        show_schedule(cfg, make_cluster(best[0], args.xpus, xpu),
                      best[2].batch)


if __name__ == "__main__":
    main()
