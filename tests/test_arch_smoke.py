"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + prefill/decode on CPU, asserting shapes and finiteness.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, ASSIGNED_ARCHS, get_arch, reduced_config
from repro.models import model as M
from repro.sharding.dist import NullDist
from repro.sharding.plans import null_plan

B, S = 2, 16


def _batch(cfg, key):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend == "vit_patches":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "audio_frames":
        batch["frames"] = jax.random.normal(
            key, (B, S, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_loss_finite(arch):
    cfg = reduced_config(get_arch(arch))
    plan, dist = null_plan("train"), NullDist()
    key = jax.random.PRNGKey(0)
    params, _ = M.init_model(cfg, plan, key)
    loss = M.train_loss(params, _batch(cfg, key), cfg, plan, dist, remat=False)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: loss={loss}"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_decode(arch):
    cfg = reduced_config(get_arch(arch))
    plan, dist = null_plan("decode"), NullDist()
    pplan = null_plan("prefill")
    key = jax.random.PRNGKey(1)
    params, _ = M.init_model(cfg, pplan, key)
    tok, caches = M.prefill(params, _batch(cfg, key), cfg, pplan, dist)
    assert tok.shape == (B, 1)
    assert (tok >= 0).all() and (tok < cfg.vocab_size).all()
    # caches from prefill have capacity S; decode one token at pos S-1 by
    # rewinding (serving engine pads capacity; smoke just checks mechanics)
    enc_len = S if cfg.is_encoder_decoder else 0
    tok2, caches2 = M.decode_step(params, caches, tok, jnp.int32(S - 1),
                                  cfg, plan, dist, enc_len=enc_len)
    assert tok2.shape == (B, 1)
    assert (tok2 >= 0).all() and (tok2 < cfg.vocab_size).all()
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


def test_assigned_arch_count():
    assert len(ASSIGNED_ARCHS) == 10


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_count_positive(arch):
    cfg = get_arch(arch)
    n = cfg.param_count()
    assert n > 0
    assert cfg.active_param_count() <= n
