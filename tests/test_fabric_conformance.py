"""Fabric-registry conformance battery (parameterized over `FABRICS`).

Every registered fabric — the paper's static four AND the OCS fabric,
plus any future registration — must hold the cross-layer contracts the
`Cluster` facade assumes. The battery enumerates the registry instead of
naming topologies, so registering a new fabric automatically enrolls it:

  1. registry lookup is the validation seam: unknown names raise a
     `ValueError` naming every registered fabric,
  2. scalar == batched timing parity at 1e-9 relative (the engine's
     (A, B) lowering and the scalar timers consume the same
     `comm_spec`),
  3. numpy == jax backend parity at 1e-6 relative (the jitted lowering
     consumes the same menus),
  4. fault derating is monotone in the failure count: bandwidth factor
     non-increasing, extra rounds/dests non-decreasing, survivors
     non-increasing,
  5. every TCO inventory hook is non-negative and every availability
     component class has a positive count,
  6. `describe()` round-trips back into an equal `Cluster`.
"""
from __future__ import annotations

from dataclasses import replace

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import H100, Scenario, make_cluster
from repro.core import optable, optimizer, sweep
from repro.core.availability import component_inventory, faultset_for_counts
from repro.core.fabric import FABRICS, get_fabric
from repro.core.tco import cluster_tco
from repro.core.topology import Cluster, TOPOLOGIES
from repro.core.workload import ServingPoint

ALL_FABRICS = tuple(FABRICS)
N = 64
BATCHES = np.array([1, 4, 64, 512, 4096, 32768])


@pytest.fixture(scope="module")
def dsv3_small():
    return get_arch("deepseek-v3").replace(num_layers=8)


# ------------------------------------------------------------ 1. registry

def test_registry_enumerates_five_fabrics():
    assert ALL_FABRICS == ("scale-up", "scale-out", "torus", "fullmesh",
                           "ocs")
    # TOPOLOGIES = the static (non-reconfigurable) subset, same order
    assert TOPOLOGIES == ALL_FABRICS[:4]
    for name in ALL_FABRICS:
        assert get_fabric(name).name == name


def test_unknown_topology_raises_naming_registered_fabrics():
    # the classic typo: the registered name is "fullmesh"
    with pytest.raises(ValueError, match="fullmesh"):
        make_cluster("full-mesh", N, H100)
    with pytest.raises(ValueError) as ei:
        Cluster(topology="nvl72", n_xpus=N, xpu=H100, link_bw=450e9)
    for name in ALL_FABRICS:
        assert repr(name) in str(ei.value)


# ---------------------------------------------- 2. scalar == batched 1e-9

@pytest.mark.parametrize("topo", ALL_FABRICS)
def test_scalar_batched_parity(dsv3_small, topo):
    cl = make_cluster(topo, N, H100)
    sc = Scenario(40.0, 512)
    for tp in (1, 2, 8):
        ep = N // tp
        table = optable.op_table(dsv3_small, tp, ep, N, "fp8")
        got = sweep.batched_tpot(table, [cl], BATCHES, [sc])[0, 0]
        p0 = ServingPoint(batch_global=1, context=sc.context, tp=tp,
                          ep=ep, n_devices=N)
        want = np.array([
            optimizer.tpot_at(dsv3_small, replace(p0, batch_global=int(b)),
                              cl, dbo=False, sd=None)[0]
            for b in BATCHES])
        np.testing.assert_allclose(got, want, rtol=1e-9)


# ------------------------------------------------- 3. numpy == jax 1e-6

@pytest.mark.parametrize("topo", ALL_FABRICS)
@pytest.mark.parametrize("dbo", [False, True])
def test_backend_parity(dsv3_small, topo, dbo):
    pytest.importorskip("jax")
    table = optable.op_table(dsv3_small, 2, N // 4, N, "fp8", pp=2)
    cl = make_cluster(topo, N, H100)
    scs = [Scenario(25.0, 512), Scenario(60.0, 8192)]
    ref, got = (sweep.GridEval(table, [cl], scs, BATCHES,
                               backend=backend).tpot(dbo=dbo)
                for backend in ("numpy", "jax"))
    np.testing.assert_allclose(got, ref, rtol=1e-6)


# --------------------------------------- 4. fault-derate monotonicity

@pytest.mark.parametrize("topo", ALL_FABRICS)
def test_fault_derate_monotone_in_link_failures(topo):
    cl = make_cluster(topo, N, H100)
    prev_factor, prev_rounds, prev_dests = 1.0, 0.0, 0.0
    prev_surv = N
    for k in range(5):
        fs = faultset_for_counts(cl, {"link_copper": k, "link_aoc": k})
        clf = cl.with_faults(fs)
        factor, rounds, dests = clf._fault_derate()
        assert 0.0 < factor <= prev_factor
        assert rounds >= prev_rounds and dests >= prev_dests
        surv = clf.survivor_xpus()
        assert 0 <= surv <= prev_surv
        prev_factor, prev_rounds, prev_dests = factor, rounds, dests
        prev_surv = surv


@pytest.mark.parametrize("topo", ALL_FABRICS)
def test_survivors_monotone_in_xpu_failures(topo):
    cl = make_cluster(topo, N, H100)
    prev = N
    for k in range(0, N + 8, 8):
        surv = cl.with_faults(
            faultset_for_counts(cl, {"xpu": k})).survivor_xpus()
        assert 0 <= surv <= prev
        prev = surv
    assert prev == 0           # losing every XPU leaves no survivors


# --------------------------------------------- 5. inventories >= 0

@pytest.mark.parametrize("topo", ALL_FABRICS)
def test_inventories_non_negative(topo):
    cl = make_cluster(topo, N, H100)
    assert cl.switch_capacity_total() >= 0.0
    assert cl.ocs_port_count() >= 0
    links = cl.link_inventory()
    assert links.copper_gbps_total >= 0.0
    assert links.aoc_gbps_total >= 0.0
    assert links.ocs_trx_gbps_total >= 0.0
    # something must carry the traffic: switch capacity or link bandwidth
    assert (cl.switch_capacity_total() + links.copper_gbps_total
            + links.aoc_gbps_total + links.ocs_trx_gbps_total) > 0.0
    tco = cluster_tco(cl)
    for part in (tco.monthly_xpu, tco.monthly_switch, tco.monthly_link,
                 tco.monthly_energy_xpu, tco.monthly_energy_net):
        assert part >= 0.0
    assert tco.total() > 0.0
    inv = component_inventory(cl)
    assert any(c.name == "xpu" for c in inv)
    for comp in inv:
        assert comp.count > 0, comp


# --------------------------------------------- 6. describe round-trip

@pytest.mark.parametrize("topo", ALL_FABRICS)
def test_describe_round_trip(topo):
    cl = make_cluster(topo, N, H100)
    d = cl.describe()
    rebuilt = Cluster(topology=d["topology"], n_xpus=d["n"], xpu=H100,
                      link_bw=d["link_bw_GBs"] * 1e9,
                      dims=tuple(d["dims"]) if d["dims"] else None)
    assert rebuilt == cl
