"""Distributed-numerics tests: the shard_map production path must agree
with the single-device (NullDist) path bit-for-bit in structure and within
bf16 tolerance in values.

Each test runs in a SUBPROCESS with XLA_FLAGS forcing 8 host devices —
jax locks the device count on first init, and the main pytest process must
keep seeing 1 device (smoke tests depend on it)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, n_dev: int = 8) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n_dev}").strip()
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-4000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


COMMON = r"""
import json
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_arch, reduced_config
from repro.launch import steps as S
from repro.launch.mesh import make_mesh
from repro.models import model as M
from repro.sharding.dist import NullDist
from repro.sharding.plans import make_plan, null_plan
from repro.configs.base import ShapeCell
from jax.sharding import NamedSharding, PartitionSpec as P

def cfg_for(arch, **kw):
    cfg = reduced_config(get_arch(arch))
    return cfg.replace(**kw) if kw else cfg

def put(tree, specs, mesh):
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs,
        is_leaf=lambda s: isinstance(s, P))
"""


@pytest.mark.parametrize("arch", ["olmoe-1b-7b", "starcoder2-3b",
                                  "jamba-v0.1-52b"])
def test_train_step_matches_single_device(arch):
    res = run_sub(COMMON + f"""
arch = {arch!r}
cfg = cfg_for(arch, num_heads=4, num_kv_heads=2)
B, Sq = 4, 32
shape = ShapeCell("t", Sq, B, "train")
mesh = make_mesh((2, 4), ("data", "model"))

tok = jax.random.randint(jax.random.PRNGKey(1), (B, Sq), 0, cfg.vocab_size)

# single-device reference loss (same init key)
plan0 = null_plan("train")
params0, _ = M.init_model(cfg, plan0, jax.random.PRNGKey(0))
loss0 = M.train_loss(params0, {{"tokens": tok}}, cfg, plan0, NullDist(),
                     remat=False)

# sharded: same params, global batch sharded
plan = make_plan(cfg, shape, ("data", "model"), (2, 4), fsdp=False)
pspecs = S.abstract_model(cfg, plan)[1]
import functools
from repro.sharding.dist import Dist
dist = Dist(dict(data=2, model=4))
def loss_fn(p, batch):
    return M.train_loss(p, batch, cfg, plan, dist, remat=False)
bspecs = {{"tokens": P(("data",), "model")}}
f = jax.jit(jax.shard_map(loss_fn, mesh=mesh,
            in_specs=(pspecs, bspecs), out_specs=P(), check_vma=False))
with mesh:
    params_sh = put(params0, pspecs, mesh)
    tok_sh = jax.device_put(tok, NamedSharding(mesh, P("data", "model")))
    loss1 = f(params_sh, {{"tokens": tok_sh}})
print(json.dumps({{"loss0": float(loss0), "loss1": float(loss1)}}))
""")
    assert res["loss0"] == pytest.approx(res["loss1"], rel=2e-2), res


@pytest.mark.parametrize("arch", ["olmoe-1b-7b", "gemma3-1b"])
def test_decode_step_matches_single_device(arch):
    """Sharded decode logits match single-device within bf16 reduction
    noise; greedy tokens agree except where the reference top-2 margin is
    itself inside that noise (argmax ties are order-sensitive)."""
    res = run_sub(COMMON + f"""
arch = {arch!r}
cfg = cfg_for(arch, num_heads=4, num_kv_heads=2)
B, cap = 8, 32
mesh = make_mesh((2, 4), ("data", "model"))
shape = ShapeCell("d", cap, B, "decode")

from repro.models.layers import common
from repro.models import transformer as tf
def logits_of(params, caches, tokens, pos, plan, dist):
    x = common.embed(params["embed"], tokens, cfg, plan, dist)
    x, nc, _ = tf.apply_stack(params["stack"], x, cfg, plan, dist,
                              mode="decode", caches=caches, pos=pos)
    x = common.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    return common.lm_logits(params["embed"], x, cfg, plan, dist)

plan0 = null_plan("decode")
params0, _ = M.init_model(cfg, plan0, jax.random.PRNGKey(0))
caches0, _ = M.init_cache(cfg, plan0, B, cap)
tok = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab_size)
l0 = logits_of(params0, caches0, tok, jnp.int32(0), plan0, NullDist())

plan = make_plan(cfg, shape, ("data", "model"), (2, 4), fsdp=False)
pspecs = S.abstract_model(cfg, plan)[1]
_, cspecs = S.abstract_cache(cfg, plan, B, cap)
from repro.sharding.dist import Dist
dist = Dist(dict(data=2, model=4))
def step(p, c, t, pos):
    lg = logits_of(p, c, t, pos, plan, dist)
    return dist.all_gather(lg, plan.vocab_axis, dim=-1)
tok_spec = P(plan.batch_axes, None)
f = jax.jit(jax.shard_map(step, mesh=mesh,
            in_specs=(pspecs, cspecs, tok_spec, P()),
            out_specs=P(plan.batch_axes, None, None), check_vma=False))
with mesh:
    params_sh = put(params0, pspecs, mesh)
    caches_sh = put(caches0, cspecs, mesh)
    tok_sh = jax.device_put(tok, NamedSharding(mesh, P(plan.batch_axes, None)))
    l1 = f(params_sh, caches_sh, tok_sh, jnp.int32(0))
l0f = np.asarray(l0[:, 0], np.float32); l1f = np.asarray(l1[:, 0], np.float32)
max_diff = float(np.abs(l0f - l1f).max())
flips_ok = True
for b in range(B):
    a0, a1 = int(l0f[b].argmax()), int(l1f[b].argmax())
    if a0 != a1:
        top2 = np.sort(l0f[b])[-2:]
        flips_ok &= bool(top2[1] - top2[0] < 0.05)   # only near-ties may flip
print(json.dumps({{"max_diff": max_diff, "flips_ok": flips_ok}}))
""")
    assert res["max_diff"] < 0.05, res
    assert res["flips_ok"], res


def test_elastic_checkpoint_across_meshes(tmp_path):
    """Save params trained on a (4,2) mesh layout; restore on (2,2) AND on
    a single device — all three produce the same train-step loss."""
    res = run_sub(COMMON + f"""
import os
from repro.training import checkpoint as ckpt
arch = "olmoe-1b-7b"
cfg = cfg_for(arch, num_heads=4, num_kv_heads=2)
B, Sq = 4, 16
shape = ShapeCell("t", Sq, B, "train")
tok = jax.random.randint(jax.random.PRNGKey(1), (B, Sq), 0, cfg.vocab_size)
ckdir = {str(tmp_path)!r}

# "train" on (4,2): init sharded, save
mesh42 = make_mesh((4, 2), ("data", "model"))
plan42 = make_plan(cfg, shape, ("data", "model"), (4, 2), fsdp=False)
pspecs42 = S.abstract_model(cfg, plan42)[1]
params, _ = M.init_model(cfg, null_plan("train"), jax.random.PRNGKey(0))
with mesh42:
    params_sh = put(params, pspecs42, mesh42)
ckpt.save(params_sh, ckdir, 1, n_shards=4)

# restore on (2,2) with that mesh's shardings
mesh22 = make_mesh((2, 2), ("data", "model"))
plan22 = make_plan(cfg, shape, ("data", "model"), (2, 2), fsdp=False)
pspecs22 = S.abstract_model(cfg, plan22)[1]
shard22 = jax.tree.map(lambda s: NamedSharding(mesh22, s), pspecs22,
                       is_leaf=lambda s: isinstance(s, P))
restored22, at = ckpt.restore(params_sh, ckdir, shardings=shard22)

# restore single-device
restored1, _ = ckpt.restore(params_sh, ckdir)

loss_ref = float(M.train_loss(params, {{"tokens": tok}}, cfg,
                              null_plan("train"), NullDist(), remat=False))
loss1 = float(M.train_loss(jax.tree.map(lambda x: jnp.asarray(np.asarray(x)), restored1),
              {{"tokens": tok}}, cfg, null_plan("train"), NullDist(),
              remat=False))
ok_tree = all(bool((np.asarray(a) == np.asarray(b)).all())
              for a, b in zip(jax.tree.leaves(params),
                              jax.tree.leaves(restored22)))
print(json.dumps({{"loss_ref": loss_ref, "loss1": loss1, "tree22": ok_tree,
                   "step": at}}))
""")
    assert res["tree22"] is True
    assert res["loss_ref"] == pytest.approx(res["loss1"], rel=1e-3)
    assert res["step"] == 1
