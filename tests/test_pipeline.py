"""Pipeline-parallel (tp x pp x ep) mapping-search validation.

Mirrors the guarantee layers of tests/test_sweep.py for the pp axis:

  1. op-list structure: pp-1 `pp_sendrecv` hops at the balanced stage
     boundaries, per-layer shapes pp-invariant, pp=1 byte-identical to
     the seed list;
  2. memory model: the per-stage shard divides by tp*pp while the expert
     shard stays experts/n along ep = n/(tp*pp), unlocking larger batches;
  3. batched-vs-scalar agreement to 1e-9 at pp > 1 on all four Table-3
     topologies (the acceptance bar), plus byte-identical OperatingPoints
     through the fixed-(tp, pp) search;
  4. triple-enumeration edge cases: indivisible tp*pp rejected, pp capped
     by the layer count, expert divisibility along the quotient;
  5. the three prefill serving modes on the axis, including the per-pool
     disaggregated mappings.
"""
from dataclasses import replace

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import (H100, Scenario, SearchSpec, make_cluster,
                        solve)
from repro.core import optable, optimizer, sweep, workload
from repro.core.specdec import SpecDecConfig
from repro.core.workload import ServingPoint

TABLE3_TOPOS = ("scale-up", "scale-out", "torus", "fullmesh")


# ---------------------------------------------------------------------------
# 1. op-list structure
# ---------------------------------------------------------------------------

def test_stage_partition_and_imbalance():
    assert workload.stage_layer_counts(8, 4) == [2, 2, 2, 2]
    assert workload.stage_layer_counts(61, 8) == [8, 8, 8, 8, 8, 7, 7, 7]
    assert workload.stage_imbalance(8, 4) == 1.0
    assert workload.stage_imbalance(61, 8) == pytest.approx(64 / 61)
    with pytest.raises(ValueError, match="exceeds the layer count"):
        workload.stage_layer_counts(4, 8)
    with pytest.raises(ValueError, match="pp must be"):
        workload.stage_layer_counts(8, 0)


def test_decode_iteration_pp_hops():
    """pp-1 hops at the stage boundaries; every other op byte-identical to
    the pp=1 list (per-layer shapes are pp-invariant)."""
    cfg = get_arch("deepseek-v3").replace(num_layers=8)
    p1 = ServingPoint(batch_global=512, context=512, tp=2, ep=32,
                      n_devices=64)
    p4 = ServingPoint(batch_global=512, context=512, tp=2, ep=8,
                      n_devices=64, pp=4)
    ops1 = workload.decode_iteration(cfg, p1)
    ops4 = workload.decode_iteration(cfg, p4)
    hops = [o for o in ops4 if o.kind == "pp_sendrecv"]
    assert len(hops) == 3
    assert [o.name for o in hops] == ["pp_hop0", "pp_hop1", "pp_hop2"]
    # hop payload: the microbatch's [rows, d] hidden state, tp-sliced
    rows = p4.batch_per_device * p4.q_len
    assert hops[0].m_bytes == pytest.approx(rows * cfg.d_model / p4.tp)
    assert all(o.group == 4 for o in hops)
    # boundary placement: hops sit between the stages' layer blocks
    names4 = [o.name for o in ops4]
    assert names4.index("pp_hop0") == names4.index("L2.mla_down") - 1
    # non-hop ops: at the SAME (tp, ep) the per-layer shapes are
    # pp-invariant — pp only inserts the hops (the stage's devices execute
    # the same per-layer shard a pp=1 device at that ep would)
    same_ep = workload.decode_iteration(cfg, replace(p4, pp=1))
    rest = [o for o in ops4 if o.kind != "pp_sendrecv"]
    assert rest == same_ep
    # and against the ep = n/tp mapping only the expert sharding moves
    assert [o.name for o in rest] == [o.name for o in ops1]
    for a, b in zip(rest, ops1):
        assert a.flops == b.flops, a.name
        if a.kind == "compute" and "expert" not in a.name:
            assert a.bytes == b.bytes, a.name


def test_pp1_oplist_byte_identical():
    cfg = get_arch("deepseek-v3")
    p = ServingPoint(batch_global=256, context=512, ep=64, n_devices=64)
    assert workload.decode_iteration(cfg, p) \
        == workload.decode_iteration(cfg, replace(p, pp=1))
    table = optable.op_table(cfg, 1, 64, 64)
    assert (table.stage_scale == 1.0).all()
    assert not (table.kind == optable.KIND_PP).any()


def test_prefill_iteration_keeps_hops():
    cfg = get_arch("deepseek-v3").replace(num_layers=8)
    p = ServingPoint(batch_global=64, context=0, tp=1, ep=16, n_devices=64,
                     pp=4)
    pre = workload.prefill_iteration(cfg, p, 128)
    assert sum(o.kind == "pp_sendrecv" for o in pre) == 3
    assert not any(o.name == "lm_head" for o in pre)


# ---------------------------------------------------------------------------
# 2. memory model
# ---------------------------------------------------------------------------

def test_shard_divides_dense_not_experts():
    cfg = get_arch("deepseek-v3")
    s11 = workload.model_shard_bytes(cfg, 1, 64)
    # pp=1 path byte-identical to the pre-pp signature
    assert s11 == workload.model_shard_bytes(cfg, 1, 64, "fp8", 1)
    # along ep = n/(tp*pp): experts/n invariant, per-layer dense divides
    # by tp*pp, and the boundary stage keeps one UNSPLIT vocab x d matrix
    io = cfg.vocab_size * cfg.d_model
    cfg64 = cfg.replace(num_layers=64)   # uniform: no imbalance factor
    n_moe64 = 64
    experts64 = n_moe64 * cfg.moe.num_experts * 3 * cfg.d_model * \
        cfg.moe.d_expert
    layer64 = cfg64.param_count() - experts64 - 2 * io
    got = workload.model_shard_bytes(cfg64, 2, 8, pp=4)
    assert got == pytest.approx((io + layer64 / 4) / 2 + experts64 / 64)
    # uneven split (61 layers, pp=8) carries the largest-stage factor
    n_moe = sum(1 for s in cfg.layer_specs if s.ffn == "moe")
    experts = n_moe * cfg.moe.num_experts * 3 * cfg.d_model * \
        cfg.moe.d_expert
    layer = cfg.param_count() - experts - 2 * io
    s_pp8 = workload.model_shard_bytes(cfg, 1, 8, pp=8)
    want = io + (layer / 8 + experts / 64) * 64 / 61
    assert s_pp8 == pytest.approx(want)
    # an io-dominated stack cannot dodge the vocab matrix by deep pp
    assert workload.model_shard_bytes(cfg, 1, 2, pp=32) > io * 0.999


def test_pp_unlocks_batches():
    """Smaller dense shard -> more KV headroom -> larger feasible batch."""
    cfg = get_arch("deepseek-v3")
    b1 = workload.max_batch_by_memory(
        cfg, ServingPoint(batch_global=1, context=4096, ep=64,
                          n_devices=64), H100.hbm_cap)
    b2 = workload.max_batch_by_memory(
        cfg, ServingPoint(batch_global=1, context=4096, ep=32,
                          n_devices=64, pp=2), H100.hbm_cap)
    assert b2 > b1


# ---------------------------------------------------------------------------
# 3. batched vs scalar at pp > 1
# ---------------------------------------------------------------------------

def test_batched_tpot_matches_scalar_pp_axis():
    """The 1e-9 batched-vs-scalar property at pp > 1 on every Table-3
    topology: hop placement, stage-imbalance scaling, and the stage-scoped
    A2A quotient must agree between the engine and the scalar timers."""
    cfg = get_arch("deepseek-v3")
    batches = np.array([64, 512, 4096, 20000])
    sc = Scenario(40.0, 4096)
    for topo in TABLE3_TOPOS:
        cl = make_cluster(topo, 64, H100)
        for tp, pp in ((1, 2), (1, 8), (2, 4), (4, 2)):
            ep = 64 // (tp * pp)
            table = optable.op_table(cfg, tp, ep, 64, pp=pp)
            for dbo, sd in ((False, None), (True, SpecDecConfig())):
                got = sweep.batched_tpot(table, [cl], batches, [sc],
                                         dbo=dbo, sd=sd)[0, 0]
                p0 = ServingPoint(batch_global=1, context=sc.context,
                                  tp=tp, ep=ep, n_devices=64, pp=pp)
                want = np.array([
                    optimizer.tpot_at(cfg, replace(p0, batch_global=int(b)),
                                      cl, dbo=dbo, sd=sd)[0]
                    for b in batches])
                np.testing.assert_allclose(got, want, rtol=1e-9,
                                           err_msg=f"{topo} tp{tp} pp{pp}")


def test_fixed_pp_operating_point_byte_identical():
    cfg = get_arch("deepseek-v3")
    sc = Scenario(40.0, 512)
    for topo in ("scale-up", "torus"):
        cl = make_cluster(topo, 64, H100)
        fast = solve(cfg, cl, sc, SearchSpec(tp=2, pp=2)).point
        ref = optimizer.max_throughput_scalar(cl, cfg, sc, tp=2, pp=2)
        assert fast == ref, topo
        assert fast is not None and fast.pp == 2 and fast.ep == 16


def test_dense_pp_is_seed_plus_hops():
    """Dense model, tp=1, pp | L: no collectives change, so the pp
    iteration is EXACTLY the pp=1 iteration plus pp-1 hop times."""
    cfg = get_arch("starcoder2-3b")                  # 30 layers, no MoE
    cl = make_cluster("torus", 64, H100)
    p1 = ServingPoint(batch_global=4096, context=512, n_devices=64, ep=1)
    p2 = replace(p1, pp=2)
    t1 = optimizer.iteration_time(cfg, p1, cl, dbo=False)[0]
    t2 = optimizer.iteration_time(cfg, p2, cl, dbo=False)[0]
    hop = cl.pp_hop_time(p2.batch_per_device * cfg.d_model
                         * workload.BYTES["fp8"], pp=2, tp=1)
    assert t2 == pytest.approx(t1 + hop, rel=1e-12)


# ---------------------------------------------------------------------------
# 4. triple enumeration edge cases
# ---------------------------------------------------------------------------

def test_triples_reject_indivisible_and_cap_pp():
    cl = make_cluster("scale-up", 64, H100)
    olmoe = get_arch("olmoe-1b-7b")                  # 16 layers, 64 experts
    triples = sweep.parallelism_candidates(olmoe, cl, pp="auto")
    assert all(64 % (tp * pp) == 0 for tp, pp, _ in triples)
    assert all(pp <= olmoe.num_layers for _, pp, _ in triples)
    assert all(olmoe.moe.num_experts % ep == 0 for _, _, ep in triples)
    assert all(tp * pp * ep == 64 for tp, pp, ep in triples)
    # pp=32 > 16 layers must be absent even though 32 | 64
    assert not any(pp == 32 for _, pp, _ in triples)
    # a 61-layer stack still pipelines (balanced +-1 stages)
    dsv3 = get_arch("deepseek-v3")
    assert any(pp == 8 for _, pp, _ in
               sweep.parallelism_candidates(dsv3, cl, pp="auto"))
    # fixed pp is honored verbatim
    only2 = sweep.parallelism_candidates(dsv3, cl, pp=2)
    assert only2 and all(pp == 2 for _, pp, _ in only2)


def test_triple_auto_never_worse_than_pair_auto():
    cfg = get_arch("deepseek-v3")
    clusters = [make_cluster(t, 64, H100) for t in TABLE3_TOPOS]
    scenarios = [Scenario(15.0, 512), Scenario(100.0, 4096)]
    pair = sweep.sweep_max_throughput(clusters, cfg, scenarios, tp="auto")
    trip = sweep.sweep_max_throughput(clusters, cfg, scenarios, tp="auto",
                                      pp="auto")
    for ci in range(len(clusters)):
        for si in range(len(scenarios)):
            pt = pair[ci][si].throughput if pair[ci][si] else 0.0
            tt = trip[ci][si].throughput if trip[ci][si] else 0.0
            assert tt >= pt, (TABLE3_TOPOS[ci], scenarios[si].name)
            if trip[ci][si] is not None:
                op = trip[ci][si]
                assert op.tp * op.pp * op.ep == 64


def test_auto_rejects_explicit_ep_with_pp():
    cfg = get_arch("deepseek-v3")
    cl = make_cluster("scale-up", 64, H100)
    with pytest.raises(ValueError, match="auto"):
        sweep.sweep_max_throughput([cl], cfg, [Scenario(40.0, 512)],
                                   pp="auto", ep=64)


# ---------------------------------------------------------------------------
# 5. prefill serving modes on the pp axis
# ---------------------------------------------------------------------------

def test_prefill_modes_accept_pp_auto():
    cfg = get_arch("deepseek-v3").replace(num_layers=8)
    cl = make_cluster("scale-out", 64, H100)
    sc = Scenario(40.0, 4096, prompt_len=2048, ttft_ms=2000.0)
    for mode in ("decode", "chunked", "disagg"):
        fixed = sweep.sweep_prefill([cl], cfg, [sc], mode=mode)[0][0]
        auto = sweep.sweep_prefill([cl], cfg, [sc], mode=mode, tp="auto",
                                   pp="auto")[0][0]
        ft = fixed.throughput if fixed else 0.0
        at = auto.throughput if auto else 0.0
        assert at >= ft, mode
        if auto is not None:
            assert auto.pp >= 1


def test_chunked_batched_matches_scalar_at_pp():
    cfg = get_arch("deepseek-v3").replace(num_layers=8)
    cl = make_cluster("torus", 64, H100)
    sc = Scenario(40.0, 2048 + 512, prompt_len=2048, ttft_ms=2000.0)
    tp, pp = 2, 2
    ep = 64 // (tp * pp)
    table = optable.op_table(cfg, tp, ep, 64, pp=pp)
    ptable = optable.prefill_op_table(cfg, tp, ep, 64, pp=pp)
    batches = np.array([64, 1024, 8192])
    got_tpot, got_ttft = sweep.batched_chunked_tpot_ttft(
        table, ptable, [cl], batches, sc, 512)
    for bi, b in enumerate(batches):
        p = ServingPoint(batch_global=int(b), context=sc.context, tp=tp,
                         ep=ep, n_devices=64, pp=pp)
        want_tpot, want_ttft = optimizer.chunked_prefill_tpot(cfg, p, cl,
                                                              sc, 512)
        np.testing.assert_allclose(got_tpot[0, bi], want_tpot, rtol=1e-9)
        np.testing.assert_allclose(got_ttft[0, bi], want_ttft, rtol=1e-9)


def test_disagg_resolves_per_pool_mappings():
    """The ROADMAP bugfix: pools resolve their own (tp, pp, ep) — the
    record carries both mappings and the search may pick different ones."""
    cfg = get_arch("deepseek-v3")
    cl = make_cluster("scale-out", 64, H100)
    sc = Scenario(40.0, 4096, prompt_len=2048, ttft_ms=500.0)
    op = sweep.sweep_prefill([cl], cfg, [sc], mode="disagg", tp="auto",
                             pp="auto")[0][0]
    assert op is not None
    assert op.mode == "disagg"
    # decode-pool mapping spans the decode pool, prefill's the prefill pool
    assert op.tp * op.pp * op.ep == op.n_decode_xpus
    assert op.tp_prefill >= 1 and op.pp_prefill >= 1
    assert op.n_prefill_xpus % (op.tp_prefill * op.pp_prefill) == 0
    # chunked / decode points leave the prefill-pool fields zeroed
    chk = sweep.sweep_prefill([cl], cfg, [sc], mode="chunked")[0][0]
    if chk is not None:
        assert chk.tp_prefill == 0 and chk.pp_prefill == 0
