"""Sweep-engine validation: the batched path must match the scalar path.

Three layers of guarantee, mirroring how the engine is built:

  1. the op table's closed forms reproduce `workload.decode_iteration`
     at random (batch, q_len, context) points (1e-9 relative),
  2. `sweep.batched_tpot` matches the scalar `optimizer.tpot_at` on a
     seeded random sample of (model, topology, batch, scenario, dbo, sd)
     points (1e-9 relative),
  3. `optimizer.max_throughput` / `best_of_opts` (batched) return
     byte-identical `OperatingPoint`s to the seed scalar implementations
     on the Table-3 cluster configs (all four topologies, N=64 and 256).
"""
from dataclasses import replace

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import H100, Scenario, make_cluster
from repro.core import optable, optimizer, sweep, workload
from repro.core.specdec import SpecDecConfig
from repro.core.workload import ServingPoint

TABLE3_TOPOS = ("scale-up", "scale-out", "torus", "fullmesh")
TABLE3_SIZES = (64, 256)


# ---------------------------------------------------------------------------
# 1. op table vs decode_iteration
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,tp,ep", [
    ("deepseek-v3", 1, 64),       # MLA + MoE + shared expert
    ("olmoe-1b-7b", 1, 16),       # GQA + MoE
    ("starcoder2-3b", 2, 1),      # dense GQA with TP all-reduces
    ("jamba-v0.1-52b", 1, 8),     # mamba/attn hybrid + MoE
])
def test_optable_matches_decode_iteration(arch, tp, ep):
    cfg = get_arch(arch)
    if cfg.moe is None:
        ep = 1
    n = 64
    table = optable.op_table(cfg, tp, ep, n)
    rng = np.random.default_rng(0)
    for _ in range(8):
        bg = int(rng.integers(1, 1 << 16))
        ctx = int(rng.integers(1, 16384))
        q = int(rng.integers(1, 8))
        p = ServingPoint(batch_global=bg, context=ctx, tp=tp, ep=ep,
                         n_devices=n, q_len=q)
        ops = workload.decode_iteration(cfg, p)
        assert tuple(o.name for o in ops) == table.names
        b = np.array([bg])
        for got, want in (
                (table.flops(b, q, ctx)[:, 0], [o.flops for o in ops]),
                (table.op_bytes(b, q, ctx)[:, 0], [o.bytes for o in ops]),
                (table.m_bytes(b, q)[:, 0], [o.m_bytes for o in ops])):
            np.testing.assert_allclose(got, np.array(want), rtol=1e-9,
                                       atol=1e-6)


def test_op_table_cache():
    cfg = get_arch("deepseek-v3")
    assert optable.op_table(cfg, 1, 64, 64) is optable.op_table(cfg, 1, 64, 64)
    assert (optable.op_table(cfg, 1, 64, 64)
            is not optable.op_table(cfg, 1, 32, 64))


# ---------------------------------------------------------------------------
# 2. batched TPOT vs scalar tpot_at (property over a seeded random sample)
# ---------------------------------------------------------------------------

def test_batched_tpot_matches_scalar_sample():
    rng = np.random.default_rng(1234)
    archs = ("deepseek-v3", "olmoe-1b-7b")
    sizes = (8, 64, 256)
    for _ in range(24):
        arch = archs[rng.integers(len(archs))]
        topo = TABLE3_TOPOS[rng.integers(len(TABLE3_TOPOS))]
        n = int(sizes[rng.integers(len(sizes))])
        if topo in ("torus", "fullmesh") and n == 8:
            n = 64                      # 2x2x2 dims exist but stay on-paper
        cfg = get_arch(arch)
        ep = n if cfg.moe is not None else 1
        cl = make_cluster(topo, n, H100,
                          link_bw=float(rng.choice([50e9, 150e9, 450e9])))
        sc = Scenario(float(rng.choice([10.0, 15.0, 40.0, 100.0])),
                      int(rng.choice([512, 4096])))
        dbo = bool(rng.integers(2))
        sd = SpecDecConfig() if rng.integers(2) else None
        batches = np.sort(rng.integers(1, 1 << 15, size=4))
        table = optable.op_table(cfg, 1, ep, n)
        got = sweep.batched_tpot(table, [cl], batches, [sc], dbo=dbo,
                                 sd=sd)[0, 0]
        p0 = ServingPoint(batch_global=1, context=sc.context, tp=1, ep=ep,
                          n_devices=n)
        want = np.array([
            optimizer.tpot_at(cfg, replace(p0, batch_global=int(b)), cl,
                              dbo=dbo, sd=sd)[0]
            for b in batches])
        np.testing.assert_allclose(got, want, rtol=1e-9)


def test_batched_iteration_components_match_scalar():
    cfg = get_arch("deepseek-v3")
    cl = make_cluster("scale-up", 64, H100)
    table = optable.op_table(cfg, 1, 64, 64)
    batches = np.array([64, 1000, 8192])
    t, tc, tm = sweep.batched_iteration_components(table, [cl], batches, 512)
    for i, b in enumerate(batches):
        p = ServingPoint(batch_global=int(b), context=512, ep=64,
                         n_devices=64)
        ts, _, tcs, tms = optimizer.iteration_time(cfg, p, cl, dbo=False)
        np.testing.assert_allclose([t[0, i], tc[0, i], tm[0, i]],
                                   [ts, tcs, tms], rtol=1e-9)


# ---------------------------------------------------------------------------
# 3. byte-identical OperatingPoints on the Table-3 cluster configs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo", TABLE3_TOPOS)
@pytest.mark.parametrize("n", TABLE3_SIZES)
def test_max_throughput_byte_identical_table3(topo, n):
    cfg = get_arch("deepseek-v3")
    cl = make_cluster(topo, n, H100)
    for sc in (Scenario(40.0, 512), Scenario(15.0, 4096)):
        for dbo, sd in ((False, None), (True, SpecDecConfig())):
            fast = optimizer.max_throughput(cl, cfg, sc, dbo=dbo, sd=sd)
            ref = optimizer.max_throughput_scalar(cl, cfg, sc, dbo=dbo,
                                                  sd=sd)
            assert fast == ref, (topo, n, sc.name, dbo, sd)


@pytest.mark.parametrize("opts", ["noopt", "dbo", "dbo+sd"])
def test_best_of_opts_byte_identical(opts):
    cfg = get_arch("deepseek-v3")
    cl = make_cluster("fullmesh", 64, H100)
    sc = Scenario(40.0, 512)
    assert (optimizer.best_of_opts(cl, cfg, sc, opts=opts)
            == optimizer.best_of_opts_scalar(cl, cfg, sc, opts=opts))


def test_best_of_opts_grid_shape_and_consistency():
    """The grid entry point agrees with per-point best_of_opts."""
    cfg = get_arch("deepseek-v3")
    clusters = [make_cluster(t, 64, H100) for t in ("scale-up", "torus")]
    scenarios = [Scenario(40.0, 512), Scenario(100.0, 4096)]
    grid = sweep.best_of_opts_grid(clusters, cfg, scenarios, "dbo")
    assert len(grid) == 2 and all(len(row) == 2 for row in grid)
    for ci, cl in enumerate(clusters):
        for si, sc in enumerate(scenarios):
            assert grid[ci][si] == optimizer.best_of_opts(cl, cfg, sc,
                                                          opts="dbo")


def test_best_of_opts_multi_matches_per_level():
    """The shared-engine multi-level entry point equals per-level grids."""
    cfg = get_arch("deepseek-v3")
    clusters = [make_cluster("scale-up", 64, H100, link_bw=bw)
                for bw in (450e9, 150e9)]
    scenarios = [Scenario(40.0, 512)]
    multi = sweep.best_of_opts_multi(clusters, cfg, scenarios,
                                     ("noopt", "dbo", "dbo+sd"))
    for opts in ("noopt", "dbo", "dbo+sd"):
        assert multi[opts] == sweep.best_of_opts_grid(clusters, cfg,
                                                      scenarios, opts)


def test_mixed_cluster_sizes_rejected():
    cfg = get_arch("deepseek-v3")
    clusters = [make_cluster("scale-up", 64, H100),
                make_cluster("scale-up", 256, H100)]
    with pytest.raises(ValueError, match="uniform device count"):
        sweep.sweep_max_throughput(clusters, cfg, [Scenario(40.0, 512)])
