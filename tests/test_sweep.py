"""Sweep-engine validation: the batched path must match the scalar path.

Three layers of guarantee, mirroring how the engine is built:

  1. the op table's closed forms reproduce `workload.decode_iteration`
     at random (batch, q_len, context) points (1e-9 relative),
  2. `sweep.batched_tpot` matches the scalar `optimizer.tpot_at` on a
     seeded random sample of (model, topology, batch, scenario, dbo, sd)
     points (1e-9 relative),
  3. `optimizer.max_throughput` / `best_of_opts` (batched) return
     byte-identical `OperatingPoint`s to the seed scalar implementations
     on the Table-3 cluster configs (all four topologies, N=64 and 256).
"""
from dataclasses import replace

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import (H100, Scenario, SearchSpec, make_cluster,
                        solve)
from repro.core import optable, optimizer, sweep, workload
from repro.core.specdec import SpecDecConfig
from repro.core.workload import ServingPoint

TABLE3_TOPOS = ("scale-up", "scale-out", "torus", "fullmesh")
TABLE3_SIZES = (64, 256)


# ---------------------------------------------------------------------------
# 1. op table vs decode_iteration
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,tp,ep", [
    ("deepseek-v3", 1, 64),       # MLA + MoE + shared expert
    ("olmoe-1b-7b", 1, 16),       # GQA + MoE
    ("starcoder2-3b", 2, 1),      # dense GQA with TP all-reduces
    ("jamba-v0.1-52b", 1, 8),     # mamba/attn hybrid + MoE
])
def test_optable_matches_decode_iteration(arch, tp, ep):
    cfg = get_arch(arch)
    if cfg.moe is None:
        ep = 1
    n = 64
    table = optable.op_table(cfg, tp, ep, n)
    rng = np.random.default_rng(0)
    for _ in range(8):
        bg = int(rng.integers(1, 1 << 16))
        ctx = int(rng.integers(1, 16384))
        q = int(rng.integers(1, 8))
        p = ServingPoint(batch_global=bg, context=ctx, tp=tp, ep=ep,
                         n_devices=n, q_len=q)
        ops = workload.decode_iteration(cfg, p)
        assert tuple(o.name for o in ops) == table.names
        b = np.array([bg])
        for got, want in (
                (table.flops(b, q, ctx)[:, 0], [o.flops for o in ops]),
                (table.op_bytes(b, q, ctx)[:, 0], [o.bytes for o in ops]),
                (table.m_bytes(b, q)[:, 0], [o.m_bytes for o in ops])):
            np.testing.assert_allclose(got, np.array(want), rtol=1e-9,
                                       atol=1e-6)


def test_op_table_cache():
    cfg = get_arch("deepseek-v3")
    assert optable.op_table(cfg, 1, 64, 64) is optable.op_table(cfg, 1, 64, 64)
    assert (optable.op_table(cfg, 1, 64, 64)
            is not optable.op_table(cfg, 1, 32, 64))


# ---------------------------------------------------------------------------
# 2. batched TPOT vs scalar tpot_at (property over a seeded random sample)
# ---------------------------------------------------------------------------

def test_batched_tpot_matches_scalar_sample():
    rng = np.random.default_rng(1234)
    archs = ("deepseek-v3", "olmoe-1b-7b")
    sizes = (8, 64, 256)
    for _ in range(24):
        arch = archs[rng.integers(len(archs))]
        topo = TABLE3_TOPOS[rng.integers(len(TABLE3_TOPOS))]
        n = int(sizes[rng.integers(len(sizes))])
        if topo in ("torus", "fullmesh") and n == 8:
            n = 64                      # 2x2x2 dims exist but stay on-paper
        cfg = get_arch(arch)
        ep = n if cfg.moe is not None else 1
        cl = make_cluster(topo, n, H100,
                          link_bw=float(rng.choice([50e9, 150e9, 450e9])))
        sc = Scenario(float(rng.choice([10.0, 15.0, 40.0, 100.0])),
                      int(rng.choice([512, 4096])))
        dbo = bool(rng.integers(2))
        sd = SpecDecConfig() if rng.integers(2) else None
        batches = np.sort(rng.integers(1, 1 << 15, size=4))
        table = optable.op_table(cfg, 1, ep, n)
        got = sweep.batched_tpot(table, [cl], batches, [sc], dbo=dbo,
                                 sd=sd)[0, 0]
        p0 = ServingPoint(batch_global=1, context=sc.context, tp=1, ep=ep,
                          n_devices=n)
        want = np.array([
            optimizer.tpot_at(cfg, replace(p0, batch_global=int(b)), cl,
                              dbo=dbo, sd=sd)[0]
            for b in batches])
        np.testing.assert_allclose(got, want, rtol=1e-9)


def test_batched_iteration_components_match_scalar():
    cfg = get_arch("deepseek-v3")
    cl = make_cluster("scale-up", 64, H100)
    table = optable.op_table(cfg, 1, 64, 64)
    batches = np.array([64, 1000, 8192])
    t, tc, tm = sweep.batched_iteration_components(table, [cl], batches, 512)
    for i, b in enumerate(batches):
        p = ServingPoint(batch_global=int(b), context=512, ep=64,
                         n_devices=64)
        ts, _, tcs, tms = optimizer.iteration_time(cfg, p, cl, dbo=False)
        np.testing.assert_allclose([t[0, i], tc[0, i], tm[0, i]],
                                   [ts, tcs, tms], rtol=1e-9)


# ---------------------------------------------------------------------------
# 3. byte-identical OperatingPoints on the Table-3 cluster configs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo", TABLE3_TOPOS)
@pytest.mark.parametrize("n", TABLE3_SIZES)
def test_max_throughput_byte_identical_table3(topo, n):
    cfg = get_arch("deepseek-v3")
    cl = make_cluster(topo, n, H100)
    for sc in (Scenario(40.0, 512), Scenario(15.0, 4096)):
        for dbo, sd in ((False, None), (True, SpecDecConfig())):
            fast = solve(cfg, cl, sc, SearchSpec(dbo=dbo, sd=sd)).point
            ref = optimizer.max_throughput_scalar(cl, cfg, sc, dbo=dbo,
                                                  sd=sd)
            assert fast == ref, (topo, n, sc.name, dbo, sd)


@pytest.mark.parametrize("opts", ["noopt", "dbo", "dbo+sd"])
def test_best_of_opts_byte_identical(opts):
    cfg = get_arch("deepseek-v3")
    cl = make_cluster("fullmesh", 64, H100)
    sc = Scenario(40.0, 512)
    assert (solve(cfg, cl, sc, SearchSpec(opts=opts)).point
            == optimizer.best_of_opts_scalar(cl, cfg, sc, opts=opts))


def test_best_of_opts_grid_shape_and_consistency():
    """The grid entry point agrees with per-point best_of_opts."""
    cfg = get_arch("deepseek-v3")
    clusters = [make_cluster(t, 64, H100) for t in ("scale-up", "torus")]
    scenarios = [Scenario(40.0, 512), Scenario(100.0, 4096)]
    grid = sweep.best_of_opts_grid(clusters, cfg, scenarios, "dbo")
    assert len(grid) == 2 and all(len(row) == 2 for row in grid)
    for ci, cl in enumerate(clusters):
        for si, sc in enumerate(scenarios):
            assert grid[ci][si] == solve(cfg, cl, sc,
                                         SearchSpec(opts="dbo")).point


def test_best_of_opts_multi_matches_per_level():
    """The shared-engine multi-level entry point equals per-level grids."""
    cfg = get_arch("deepseek-v3")
    clusters = [make_cluster("scale-up", 64, H100, link_bw=bw)
                for bw in (450e9, 150e9)]
    scenarios = [Scenario(40.0, 512)]
    multi = sweep.best_of_opts_multi(clusters, cfg, scenarios,
                                     ("noopt", "dbo", "dbo+sd"))
    for opts in ("noopt", "dbo", "dbo+sd"):
        assert multi[opts] == sweep.best_of_opts_grid(clusters, cfg,
                                                      scenarios, opts)


def test_mixed_cluster_sizes_rejected():
    cfg = get_arch("deepseek-v3")
    clusters = [make_cluster("scale-up", 64, H100),
                make_cluster("scale-up", 256, H100)]
    with pytest.raises(ValueError, match="uniform device count"):
        sweep.sweep_max_throughput(clusters, cfg, [Scenario(40.0, 512)])


# ---------------------------------------------------------------------------
# 4. hybrid-parallelism (tp, ep) axis
# ---------------------------------------------------------------------------

def test_parallelism_candidates_structure():
    cl = make_cluster("scale-up", 64, H100)
    dsv3 = get_arch("deepseek-v3")
    cands = sweep.parallelism_candidates(dsv3, cl)
    assert cands[0] == (1, 1, 64)                    # fixed mapping first
    assert cands == sorted(cands)                    # (tp, pp) ascending
    assert all(pp == 1 for _, pp, _ in cands)        # pp=1 is the default
    for tp, pp, ep in cands:
        assert tp * pp * ep == 64
        assert dsv3.moe.num_experts % ep == 0
        assert dsv3.num_heads % tp == 0              # MLA: shard num_heads
    # the pp axis is opt-in: pp="auto" grows the candidate set as triples
    triples = sweep.parallelism_candidates(dsv3, cl, pp="auto")
    assert set(cands) <= set(triples)
    assert any(pp > 1 for _, pp, _ in triples)
    assert all(tp * pp * ep == 64 for tp, pp, ep in triples)
    # GQA model: tp capped by kv heads (olmoe has 16)
    olmoe = get_arch("olmoe-1b-7b")
    assert all(tp <= olmoe.num_kv_heads
               for tp, _, _ in sweep.parallelism_candidates(olmoe, cl))
    # dense model: ep stays 1 on every candidate
    dense = get_arch("starcoder2-3b")
    assert all(ep == 1
               for _, _, ep in sweep.parallelism_candidates(dense, cl))


def test_moe_ops_tp_sharded():
    """tp=1 op list is byte-identical to the seed; tp>1 adds the moe_ar
    and shards expert weights/flops so the per-device expert load is
    invariant along the ep = n/tp family."""
    cfg = get_arch("deepseek-v3")
    p1 = ServingPoint(batch_global=512, context=512, tp=1, ep=64,
                      n_devices=64)
    p2 = ServingPoint(batch_global=512, context=512, tp=2, ep=32,
                      n_devices=64)
    names1 = [o.name for o in workload.decode_iteration(cfg, p1)]
    assert not any(n.endswith("moe_ar") for n in names1)
    ops2 = workload.decode_iteration(cfg, p2)
    assert any(o.name.endswith("moe_ar") for o in ops2)

    def expert(ops):
        return next(o for o in ops if o.name == "L10.expert_ffn")

    e1 = expert(workload.decode_iteration(cfg, p1))
    e2 = expert(ops2)
    assert e2.flops == pytest.approx(e1.flops)       # invariant per device
    # and the weight shard is invariant too: E/(ep*tp) == E/n
    assert workload.model_shard_bytes(cfg, 2, 32) < \
        workload.model_shard_bytes(cfg, 1, 64)       # dense part shrinks


def test_kv_cache_tp_sharding_matches_streaming_model():
    """Per-device KV STORAGE must follow the same TP sharding the
    attention streaming model uses: GQA shards over kv heads, MLA's
    compressed latent is replicated across the domain."""
    gqa = get_arch("olmoe-1b-7b")                    # 16 kv heads
    full = workload.kv_cache_bytes_per_request(gqa, 4096)
    assert workload.kv_cache_bytes_per_request(gqa, 4096, tp=8) == \
        pytest.approx(full / 8)
    # beyond the head count the shard stops shrinking
    assert workload.kv_cache_bytes_per_request(gqa, 4096, tp=64) == \
        pytest.approx(full / 16)
    mla = get_arch("deepseek-v3")
    assert workload.kv_cache_bytes_per_request(mla, 4096, tp=8) == \
        workload.kv_cache_bytes_per_request(mla, 4096)


def test_comm_spec_seed_identity_at_tp1():
    """tp=1 placement must reproduce the seed whole-cluster collectives
    exactly, for every topology and any group argument."""
    m = 64 * 1024 * 1024
    for topo in TABLE3_TOPOS:
        cl = make_cluster(topo, 64, H100)
        assert cl.a2a_time(m) == cl.a2a_time(m, group=64, tp=1)
        assert cl.a2a_time(m) == cl.a2a_time(m, group=32, tp=1)
        assert cl.ar_time(m, group=8) == cl.ar_time(m, group=8, tp=1)


def test_comm_spec_places_tp_neighborhood():
    m = 8 * 1024 * 1024
    # scale-out: a tp<=8 all-reduce rides the NVLink island, far cheaper
    # than the same group over the NIC fabric
    so = make_cluster("scale-out", 64, H100)
    assert so.ar_time(m, group=8, tp=8) < 0.25 * so.ar_time(m, group=8)
    # mesh: the TP sub-mesh sees only its neighborhood's share of the
    # links, so the placed AR is SLOWER than the naive whole-dims menu
    for topo in ("torus", "fullmesh"):
        cl = make_cluster(topo, 64, H100)
        assert cl.ar_time(m, group=4, tp=4) > cl.ar_time(m, group=4)
        # and the quotient A2A of ep = n/tp spans fewer peers
        assert cl.a2a_time(m, group=16, tp=4) != cl.a2a_time(m)


def test_batched_tpot_matches_scalar_tp_axis():
    """The 1e-9 batched-vs-scalar property extended to tp > 1: the new
    moe_ar ops and placed collectives must agree between the engine and
    the scalar timers on every topology."""
    cfg = get_arch("deepseek-v3")
    batches = np.array([64, 512, 4096, 20000])
    sc = Scenario(40.0, 4096)
    for topo in TABLE3_TOPOS:
        cl = make_cluster(topo, 64, H100)
        for tp in (2, 8, 64):
            ep = 64 // tp
            table = optable.op_table(cfg, tp, ep, 64)
            got = sweep.batched_tpot(table, [cl], batches, [sc])[0, 0]
            p0 = ServingPoint(batch_global=1, context=sc.context, tp=tp,
                              ep=ep, n_devices=64)
            want = np.array([
                optimizer.tpot_at(cfg, replace(p0, batch_global=int(b)), cl,
                                  dbo=False, sd=None)[0] for b in batches])
            np.testing.assert_allclose(got, want, rtol=1e-9)


def test_auto_never_worse_and_strictly_better():
    """tp='auto' must dominate the fixed mapping on every Table-3
    topology x scenario, and strictly improve somewhere (the axis's
    reason to exist)."""
    cfg = get_arch("deepseek-v3")
    clusters = [make_cluster(t, 64, H100) for t in TABLE3_TOPOS]
    scenarios = [Scenario(15.0, 512), Scenario(40.0, 512)]
    fixed = sweep.sweep_max_throughput(clusters, cfg, scenarios)
    auto = sweep.sweep_max_throughput(clusters, cfg, scenarios, tp="auto")
    strict = False
    for ci in range(len(clusters)):
        for si in range(len(scenarios)):
            f, a = fixed[ci][si], auto[ci][si]
            ft = f.throughput if f else 0.0
            at = a.throughput if a else 0.0
            assert at >= ft, (TABLE3_TOPOS[ci], scenarios[si].name)
            strict |= at > ft
            if a is not None:
                assert a.tp * a.ep == 64
    assert strict


def test_auto_equals_best_fixed_candidate():
    """The auto merge is exactly the per-candidate argmax with ties to
    the smallest tp."""
    cfg = get_arch("deepseek-v3")
    cl = make_cluster("scale-out", 64, H100)
    sc = Scenario(40.0, 512)
    auto = solve(cfg, cl, sc, SearchSpec(tp="auto")).point
    per_cand = [solve(cfg, cl, sc, SearchSpec(tp=t, pp=q, ep=e)).point
                for t, q, e in sweep.parallelism_candidates(cfg, cl)]
    best = max((p for p in per_cand if p is not None),
               key=lambda p: p.throughput)
    assert auto == best
    assert auto.tp > 1                               # scale-out: TP wins


def test_auto_rejects_explicit_ep():
    cfg = get_arch("deepseek-v3")
    cl = make_cluster("scale-up", 64, H100)
    with pytest.raises(ValueError, match="auto"):
        sweep.sweep_max_throughput([cl], cfg, [Scenario(40.0, 512)],
                                   tp="auto", ep=64)


def test_prefill_modes_accept_auto():
    """All three serving modes search the mapping axis: auto dominates
    the fixed mapping per cell and records the chosen (tp, ep)."""
    cfg = get_arch("deepseek-v3")
    cl = make_cluster("scale-out", 64, H100)
    sc = Scenario(40.0, 4096, prompt_len=2048, ttft_ms=2000.0)
    for mode in ("decode", "chunked", "disagg"):
        fixed = sweep.sweep_prefill([cl], cfg, [sc], mode=mode)[0][0]
        auto = sweep.sweep_prefill([cl], cfg, [sc], mode=mode,
                                   tp="auto")[0][0]
        ft = fixed.throughput if fixed else 0.0
        at = auto.throughput if auto else 0.0
        assert at >= ft, mode
        if auto is not None:
            assert auto.tp >= 1 and auto.mode == (mode if mode != "decode"
                                                  else "decode")
