"""Degraded-fabric serving: FaultSet derating, failure-aware re-search,
remap-vs-degrade policy, availability model, shared injection seam.

Locks the PR-6 acceptance criteria: the zero-fault path is identical to
the healthy model, batched and scalar searches agree to 1e-9 under
injected faults on all four topologies, and bad mesh sizes raise a clear
ValueError instead of an opaque KeyError."""
from __future__ import annotations

import math

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import H100, Scenario, SearchSpec, make_cluster, solve
from repro.core.availability import (COLLECTIVE_TIMEOUT_S, MTBF_MTTR_H,
                                     build_availability,
                                     component_inventory,
                                     faultset_for_counts, straddle_penalty)
from repro.core.optimizer import degrade_policy, max_throughput_scalar
from repro.core.sweep import degraded_max_throughput, degraded_subcluster
from repro.core.tco import availability_adjusted_throughput_per_cost
from repro.core.topology import FaultSet, NODE_XPUS, TOPOLOGIES
from repro.faults import FailureInjector, WorkerFailure, sample_faultset

CFG = get_arch("deepseek-v3")
SC = Scenario(40.0, 512)
M_BYTES = 4 << 20

# one representative non-trivial FaultSet per topology (fabric-derating
# axes only; node-loss axes are exercised separately below)
FAULTS = {
    "torus": FaultSet(mesh_links=(1, 0, 0)),
    "fullmesh": FaultSet(mesh_links=(0, 1, 0)),
    "scale-up": FaultSet(switch_planes=2),
    "scale-out": FaultSet(nics=1),
}


def _clusters(n=64):
    return {t: make_cluster(t, n, H100) for t in TOPOLOGIES}


# ---------------------------------------------------------------- FaultSet

def test_faultset_validation():
    with pytest.raises(ValueError):
        FaultSet(switch_planes=-1)
    with pytest.raises(ValueError):
        FaultSet(mesh_links=(0, -2))
    assert not FaultSet().any
    fs = FaultSet(mesh_links=[1, 0])          # list coerces to tuple
    assert fs.mesh_links == (1, 0) and fs.any
    assert fs.link_at(0) == 1 and fs.link_at(5) == 0


def test_bad_mesh_size_raises_clear_valueerror():
    # satellite: n_xpus outside DIMS_BY_SIZE must not surface a KeyError
    for topo in ("torus", "fullmesh"):
        with pytest.raises(ValueError, match="supported sizes"):
            make_cluster(topo, 128, H100)
    # switched fabrics are sized by formula and accept any n
    assert make_cluster("scale-up", 128, H100).n_xpus == 128


# ------------------------------------------------------ zero-fault identity

def test_zero_fault_path_identical():
    for topo, cl in _clusters().items():
        cl0 = cl.with_faults(FaultSet())
        for kind, tp, pp in (("a2a", 1, 1), ("ar", 4, 1),
                             ("pp_sendrecv", 1, 2)):
            menu, bw, ab = cl.comm_spec(kind, 0 if kind != "pp_sendrecv"
                                        else pp, tp, pp)
            menu0, bw0, ab0 = cl0.comm_spec(kind, 0 if kind != "pp_sendrecv"
                                            else pp, tp, pp)
            assert bw == bw0 and ab == ab0
            assert {k: (c.rounds, c.dests, c.m_coeff)
                    for k, c in menu.items()} == \
                   {k: (c.rounds, c.dests, c.m_coeff)
                    for k, c in menu0.items()}, (topo, kind)


# ------------------------------------------------------------ fault derating

def test_fault_derating_slows_collectives():
    for topo, cl in _clusters().items():
        cl_f = cl.with_faults(FAULTS[topo])
        for name, t0, t1 in (
                ("a2a", cl.a2a_time(M_BYTES), cl_f.a2a_time(M_BYTES)),
                ("ar", cl.ar_time(M_BYTES), cl_f.ar_time(M_BYTES)),
                ("pp", cl.pp_hop_time(M_BYTES), cl_f.pp_hop_time(M_BYTES))):
            assert t1 >= t0, (topo, name)
        if topo != "scale-out":     # NIC loss is a node event, not derate
            assert cl_f.a2a_time(M_BYTES) > cl.a2a_time(M_BYTES), topo


def test_derating_monotone_in_fault_count():
    cl = make_cluster("torus", 64, H100)
    times = [cl.with_faults(FaultSet(mesh_links=(k, 0, 0))).a2a_time(M_BYTES)
             for k in range(4)]
    assert all(b >= a for a, b in zip(times, times[1:])), times
    su = make_cluster("scale-up", 64, H100)
    times = [su.with_faults(FaultSet(switch_planes=k)).ar_time(M_BYTES)
             for k in range(5)]
    assert all(b >= a for a, b in zip(times, times[1:])), times


def test_survivor_accounting():
    for topo, cl in _clusters().items():
        assert cl.with_faults(FaultSet(xpus=3)).survivor_xpus() == 61
    so = make_cluster("scale-out", 64, H100)
    # a dead NIC orphans its whole island node
    assert so.with_faults(FaultSet(nics=1)).survivor_xpus() \
        == 64 - NODE_XPUS
    assert so.with_faults(FaultSet(nics=100)).survivor_xpus() == 0


# -------------------------------------------- batched == scalar under faults

def test_batched_scalar_agree_under_faults():
    """Acceptance criterion: with faults injected, the batched engine and
    the scalar reference agree to 1e-9 on all four topologies."""
    for topo, cl in _clusters().items():
        cl_f = cl.with_faults(FAULTS[topo])
        b = solve(CFG, cl_f, SC, SearchSpec(tp=1, pp=1)).point
        s = max_throughput_scalar(cl_f, CFG, SC, tp=1, pp=1)
        assert (b is None) == (s is None), topo
        if b is None:
            continue
        assert b.batch == s.batch, topo
        np.testing.assert_allclose(b.tpot, s.tpot, rtol=1e-9)
        np.testing.assert_allclose(b.throughput, s.throughput, rtol=1e-9)


# ------------------------------------------------------- degraded re-search

def test_degraded_subcluster_and_search():
    for topo, cl in _clusters().items():
        fs = FaultSet(xpus=2)
        cl_d = degraded_subcluster(cl, fs)
        assert cl_d is not None and cl_d.n_xpus == 62
        pt = degraded_max_throughput(cl, CFG, SC, faults=fs)
        healthy = solve(CFG, cl, SC, SearchSpec(tp="auto")).point
        if pt is not None and healthy is not None:
            assert pt.throughput <= healthy.throughput * (1 + 1e-12), topo


def test_degrade_policy_plan():
    for topo, cl in _clusters().items():
        plan = degrade_policy(cl, CFG, SC, FaultSet(xpus=NODE_XPUS))
        assert plan.action in ("keep", "remap", "down"), topo
        if plan.action == "down":
            assert plan.effective_throughput == 0.0
            continue
        baseline = solve(CFG, cl, SC, SearchSpec(tp="auto")).point
        assert plan.effective_throughput <= baseline.throughput, topo
        # the policy picks the better arm
        keep_thr = plan.keep_point.throughput if plan.keep_point else 0.0
        if plan.action == "keep":
            assert plan.effective_throughput == keep_thr
        else:
            assert plan.effective_throughput >= keep_thr


def test_degrade_policy_horizon_knob():
    """A long remap downtime relative to the horizon disfavors remapping."""
    cl = make_cluster("fullmesh", 64, H100)
    fs = FaultSet(xpus=1)
    cheap = degrade_policy(cl, CFG, SC, fs, remap_downtime_s=0.0)
    dear = degrade_policy(cl, CFG, SC, fs, remap_downtime_s=3600.0,
                          horizon_s=3600.0)
    assert cheap.effective_throughput >= dear.effective_throughput


# ------------------------------------------------------------- availability

def test_straddle_penalty():
    assert straddle_penalty(0.02) == COLLECTIVE_TIMEOUT_S + 0.02
    assert straddle_penalty(0.02, retries=3) == COLLECTIVE_TIMEOUT_S + 0.06
    with pytest.raises(ValueError):
        straddle_penalty(0.02, timeout_s=-1.0)


def test_component_inventory():
    for topo, cl in _clusters().items():
        inv = component_inventory(cl)
        names = [c.name for c in inv]
        assert "xpu" in names and all(c.count > 0 for c in inv)
        assert all(c.mtbf_h > 0 and c.mttr_h > 0 for c in inv)
    so = [c.name for c in component_inventory(_clusters()["scale-out"])]
    assert "nic" in so and "switch" in so
    # per-class MTBF/MTTR overrides replace the documented defaults
    cl = _clusters()["torus"]
    assert MTBF_MTTR_H["xpu"] != (123.0, 4.0)
    xpu = [c for c in component_inventory(cl, {"xpu": (123.0, 4.0)})
           if c.name == "xpu"][0]
    assert (xpu.mtbf_h, xpu.mttr_h) == (123.0, 4.0)
    for mesh in ("torus", "fullmesh"):
        assert "switch" not in [c.name for c in
                                component_inventory(_clusters()[mesh])]


def test_faultset_for_counts_blast_radius():
    cls = _clusters()
    fs = faultset_for_counts(cls["torus"], {"link_copper": 3})
    assert sum(fs.mesh_links) == 3
    fs = faultset_for_counts(cls["scale-up"], {"link_copper": 1,
                                               "switch": 1})
    assert fs.switch_planes == 2
    fs = faultset_for_counts(cls["scale-out"], {"switch": 1})
    assert fs.xpus == 64        # one-level fabric switch: whole cluster
    fs = faultset_for_counts(cls["scale-out"], {"link_copper": 2})
    assert fs.nics == 2         # severed node uplink == dead NIC


def test_availability_model_sanity():
    cl = make_cluster("fullmesh", 64, H100)
    m = build_availability(cl, CFG, SC, max_total_faults=2)
    assert m.healthy_throughput > 0
    assert m.states[0].action == "healthy"
    assert all(s.throughput <= m.healthy_throughput * (1 + 1e-12)
               for s in m.states)
    r = m.report(1.0)
    assert 0.0 < r.availability <= 1.0
    assert 0.0 <= r.tail_mass < 1e-3
    assert all(0.0 <= p <= 1.0 for p in r.state_probs)
    assert abs(sum(r.state_probs) + r.tail_mass - 1.0) < 1e-6
    # healthier fleet -> higher availability
    assert m.report(10.0).availability >= r.availability
    assert r.availability >= m.report(0.1).availability


def test_single_fault_closed_form():
    """Enumerated single-fault probabilities match the analytic binomial
    C(N,1) u (1-u)^(N-1) exactly."""
    cl = make_cluster("torus", 64, H100)
    m = build_availability(cl, CFG, SC, max_total_faults=1)
    r = m.report(1.0)
    for ci, c in enumerate(m.classes):
        u = c.unavailability(1.0)
        want = math.comb(c.count, 1) * u * (1 - u) ** (c.count - 1)
        for cj, other in enumerate(m.classes):
            if cj != ci:
                uo = other.unavailability(1.0)
                want *= (1 - uo) ** other.count
        key = tuple(1 if i == ci else 0 for i in range(len(m.classes)))
        got = [p for s, p in zip(m.states, r.state_probs)
               if s.counts == key]
        assert len(got) == 1
        np.testing.assert_allclose(got[0], want, rtol=1e-12)


def test_availability_adjusted_tpc():
    cl = make_cluster("torus", 64, H100)
    v, rep, model = availability_adjusted_throughput_per_cost(cl, CFG, SC)
    v0, rep0, _ = availability_adjusted_throughput_per_cost(
        cl, None, None, mtbf_scale=0.1, model=model)
    assert 0 < v0 < v
    assert rep0.availability < rep.availability


# ------------------------------------------------------ shared fault seam

def test_seeded_injector_deterministic():
    a = FailureInjector.seeded(200, 0.1, seed=11)
    b = FailureInjector.seeded(200, 0.1, seed=11)
    assert a.fail_at == b.fail_at and a.fail_at
    assert FailureInjector.seeded(200, 0.1, seed=12).fail_at != a.fail_at
    with pytest.raises(ValueError):
        FailureInjector.seeded(10, 1.5)
    with pytest.raises(WorkerFailure):
        a.check(a.fail_at[0])
    a.check(a.fail_at[0])       # fires once


def test_training_seam_reexports():
    # run_with_recovery's injector IS the shared one (behavior unchanged)
    from repro.training import fault_tolerance as ft
    assert ft.FailureInjector is FailureInjector
    assert ft.WorkerFailure is WorkerFailure


def test_sample_faultset_deterministic():
    for topo, cl in _clusters().items():
        a = sample_faultset(cl, exposure_h=5000.0, seed=4)
        b = sample_faultset(cl, exposure_h=5000.0, seed=4)
        assert a == b
    with pytest.raises(ValueError):
        sample_faultset(make_cluster("torus", 64, H100), exposure_h=-1.0)


def test_faults_survive_subclustering():
    cl = make_cluster("torus", 64, H100)
    fs = FaultSet(mesh_links=(1, 0, 0), xpus=1)
    cl_d = degraded_subcluster(cl, fs)
    assert cl_d.faults == fs    # link derate persists on the survivor pool


def test_describe_includes_faults():
    cl = make_cluster("torus", 64, H100).with_faults(FaultSet(xpus=1))
    assert cl.describe()["faults"]["xpus"] == 1
