"""Per-kernel validation: Pallas (interpret=True on CPU) vs the pure-jnp
oracle, swept over shapes and dtypes. The hypothesis property tests live in
test_kernels_props.py behind pytest.importorskip, so a missing `hypothesis`
degrades to a skip instead of killing collection."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_decode import flash_decode_pallas
from repro.kernels.moe_gmm import moe_gmm_pallas


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype) * 0.3


TOLS = {jnp.bfloat16: dict(atol=5e-2, rtol=5e-2),
        jnp.float32: dict(atol=2e-5, rtol=2e-5)}


# ---------------------------------------------------------------------------
# moe_gmm: grouped expert SwiGLU
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("e,t,d,f", [
    (2, 128, 64, 256),       # canonical tile boundary
    (4, 256, 128, 512),      # multiple tiles both axes
    (1, 128, 256, 256),      # single expert
    (3, 384, 64, 768),       # non-power-of-two expert count / tiles
])
def test_moe_gmm_matches_ref(e, t, d, f, dtype):
    ks = jax.random.split(jax.random.PRNGKey(e * 1000 + t), 4)
    x = rand(ks[0], (e, t, d), dtype)
    wg = rand(ks[1], (e, d, f), dtype)
    wu = rand(ks[2], (e, d, f), dtype)
    wd = rand(ks[3], (e, f, d), dtype)
    got = np.asarray(moe_gmm_pallas(x, wg, wu, wd, interpret=True),
                     np.float32)
    want = np.asarray(ref.moe_gmm_ref(x, wg, wu, wd), np.float32)
    if dtype == jnp.float32:
        np.testing.assert_allclose(got, want, **TOLS[dtype])
        return
    # bf16: the kernel accumulates in f32, the oracle in bf16 — they are
    # two equally-valid roundings. Assert the kernel is at least as close
    # to the f32 ground truth as the bf16 oracle is.
    truth = np.asarray(ref.moe_gmm_ref(*(a.astype(jnp.float32)
                                         for a in (x, wg, wu, wd))))
    err_kernel = np.abs(got - truth).max()
    err_oracle = np.abs(want - truth).max()
    assert err_kernel <= err_oracle * 1.5 + 1e-3, (err_kernel, err_oracle)


@pytest.mark.parametrize("block_t,block_f", [(64, 128), (128, 256),
                                             (128, 128), (64, 512)])
def test_moe_gmm_block_shapes(block_t, block_f):
    """Output must be block-shape invariant (pure tiling change)."""
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    e, t, d, f = 2, 128, 64, 512
    x = rand(ks[0], (e, t, d), jnp.float32)
    wg = rand(ks[1], (e, d, f), jnp.float32)
    wu = rand(ks[2], (e, d, f), jnp.float32)
    wd = rand(ks[3], (e, f, d), jnp.float32)
    got = moe_gmm_pallas(x, wg, wu, wd, block_t=block_t, block_f=block_f,
                         interpret=True)
    want = ref.moe_gmm_ref(x, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("e,t,d,f", [
    (2, 100, 64, 300),       # t and f both off the tile boundary
    (1, 7, 32, 130),         # tiny t -> block_t shrinks to t
    (3, 130, 64, 256),       # t just past one tile
])
def test_moe_gmm_unaligned_shapes(e, t, d, f):
    """Arbitrary capacity factors: non-tile-multiple t/f zero-pad instead of
    crashing."""
    ks = jax.random.split(jax.random.PRNGKey(t * 10 + f), 4)
    x = rand(ks[0], (e, t, d), jnp.float32)
    wg = rand(ks[1], (e, d, f), jnp.float32)
    wu = rand(ks[2], (e, d, f), jnp.float32)
    wd = rand(ks[3], (e, f, d), jnp.float32)
    got = moe_gmm_pallas(x, wg, wu, wd, block_t=64, block_f=128,
                         interpret=True)
    want = ref.moe_gmm_ref(x, wg, wu, wd)
    assert got.shape == (e, t, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5,
                               rtol=3e-5)


def test_moe_gmm_expert_independence():
    """Zeroing expert i's tokens must not change expert j's output."""
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    e, t, d, f = 3, 64, 32, 128
    x = rand(ks[0], (e, t, d), jnp.float32)
    wg = rand(ks[1], (e, d, f), jnp.float32)
    wu = rand(ks[2], (e, d, f), jnp.float32)
    wd = rand(ks[3], (e, f, d), jnp.float32)
    base = moe_gmm_pallas(x, wg, wu, wd, block_t=64, block_f=128,
                          interpret=True)
    x2 = x.at[0].set(0.0)
    out = moe_gmm_pallas(x2, wg, wu, wd, block_t=64, block_f=128,
                         interpret=True)
    np.testing.assert_allclose(np.asarray(out[1:]), np.asarray(base[1:]),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(out[0]), 0.0, atol=1e-6)


# ---------------------------------------------------------------------------
# flash_decode: online-softmax decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("b,h,kh,s,hd", [
    (2, 8, 8, 512, 64),      # MHA
    (2, 8, 2, 1024, 64),     # GQA 4:1
    (1, 16, 1, 2048, 128),   # MQA, long S, two S-tiles
    (4, 4, 4, 512, 32),
])
def test_flash_decode_matches_ref(b, h, kh, s, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(b * 100 + s), 3)
    q = rand(ks[0], (b, h, hd), dtype)
    k = rand(ks[1], (b, kh, s, hd), dtype)
    v = rand(ks[2], (b, kh, s, hd), dtype)
    length = jnp.int32(s - 3)
    got = flash_decode_pallas(q, k, v, length, interpret=True)
    want = ref.flash_decode_ref(q, k, v, length)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOLS[dtype])


@pytest.mark.parametrize("block_s", [128, 256, 512, 1024])
def test_flash_decode_block_invariance(block_s):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    b, h, kh, s, hd = 2, 4, 2, 1024, 64
    q = rand(ks[0], (b, h, hd), jnp.float32)
    k = rand(ks[1], (b, kh, s, hd), jnp.float32)
    v = rand(ks[2], (b, kh, s, hd), jnp.float32)
    got = flash_decode_pallas(q, k, v, jnp.int32(700), block_s=block_s,
                              interpret=True)
    want = ref.flash_decode_ref(q, k, v, 700)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5,
                               rtol=3e-5)


def test_flash_decode_softmax_invariances():
    """Scale-shift invariance: adding a constant to all K projections along
    q direction shifts logits uniformly -> output unchanged; and output is
    a convex combination of V rows (within their min/max envelope)."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    b, h, kh, s, hd = 1, 2, 1, 256, 16
    q = rand(ks[0], (b, h, hd), jnp.float32)
    k = rand(ks[1], (b, kh, s, hd), jnp.float32)
    v = rand(ks[2], (b, kh, s, hd), jnp.float32)
    out = flash_decode_pallas(q, k, v, jnp.int32(s), interpret=True)
    vmin = np.asarray(v.min(axis=2))[:, :, None]
    vmax = np.asarray(v.max(axis=2))[:, :, None]
    o = np.asarray(out).reshape(b, kh, -1, hd)
    assert (o >= vmin - 1e-4).all() and (o <= vmax + 1e-4).all()
