"""Prefill-aware analytics validation.

Mirrors the decode sweep's guarantee layers (tests/test_sweep.py):

  1. the prefill op table's closed forms reproduce
     `workload.prefill_iteration` at random (batch, chunk, context) points,
  2. the batched chunked-prefill TPOT/TTFT matches the scalar
     `optimizer.chunked_prefill_tpot` (1e-9 relative) on a seeded sample,
  3. decode-only results stay byte-identical to the PR-1 outputs (the
     committed fig10 JSON is the regression anchor),

plus the serving-mode search invariants, the single-request KV guard, and
the roofline benchmark's clean-skip path on a fresh checkout.
"""
import json
import os
import subprocess
import sys
from dataclasses import replace

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import (H100, Scenario, SearchSpec, make_cluster,
                        solve)
from repro.core import optable, optimizer, sweep, workload
from repro.core.workload import ServingPoint

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def dsv3_small():
    return get_arch("deepseek-v3").replace(num_layers=8)


# ---------------------------------------------------------------------------
# 1. prefill op table vs prefill_iteration
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,tp,ep", [
    ("deepseek-v3", 1, 64),       # MLA + MoE + shared expert
    ("olmoe-1b-7b", 1, 16),       # GQA + MoE
    ("starcoder2-3b", 2, 1),      # dense GQA with TP all-reduces
    ("jamba-v0.1-52b", 1, 8),     # mamba/attn hybrid + MoE
])
def test_prefill_optable_matches_iteration(arch, tp, ep):
    cfg = get_arch(arch)
    if cfg.moe is None:
        ep = 1
    n = 64
    table = optable.prefill_op_table(cfg, tp, ep, n)
    rng = np.random.default_rng(7)
    for _ in range(8):
        bg = int(rng.integers(1, 257))
        chunk = int(rng.integers(1, 4096))
        ctx = int(rng.integers(0, 16384))
        p = ServingPoint(batch_global=bg, context=ctx, tp=tp, ep=ep,
                         n_devices=n)
        ops = workload.prefill_iteration(cfg, p, chunk)
        assert tuple(o.name for o in ops) == table.names
        c = np.array([chunk], float)
        o_arr = np.array([ctx], float)
        for got, want in (
                (table.flops(bg, c, o_arr)[:, 0], [o.flops for o in ops]),
                (table.op_bytes(bg, c, o_arr)[:, 0],
                 [o.bytes for o in ops]),
                (table.m_bytes(bg, c)[:, 0], [o.m_bytes for o in ops])):
            np.testing.assert_allclose(got, np.array(want), rtol=1e-9,
                                       atol=1e-6)


def test_prefill_drops_lm_head_and_keeps_shapes(dsv3_small):
    p = ServingPoint(batch_global=64, context=0, ep=64, n_devices=64)
    dec = workload.decode_iteration(cfg=dsv3_small, p=replace(p, q_len=128))
    pre = workload.prefill_iteration(dsv3_small, p, 128)
    assert [o.name for o in dec if o.name != "lm_head"] \
        == [o.name for o in pre]


def test_prefill_attention_quadratic_in_chunk(dsv3_small):
    """Doubling the chunk must MORE than double the attention-core FLOPs
    (causal intra-chunk term), while GEMM FLOPs scale exactly linearly."""
    p = ServingPoint(batch_global=64, context=0, ep=64, n_devices=64)
    by_name = {}
    for chunk in (512, 1024):
        for o in workload.prefill_iteration(dsv3_small, p, chunk):
            by_name.setdefault(o.name, []).append(o.flops)
    core = by_name["L0.mla_core"]
    assert core[1] > 2 * core[0]
    gemm = by_name["L0.expert_ffn"]
    assert gemm[1] == pytest.approx(2 * gemm[0], rel=1e-12)


def test_chunk_schedule_covers_prompt():
    sizes, offsets = workload.chunk_schedule(1000, 256)
    assert sum(sizes) == 1000
    assert offsets == [0, 256, 512, 768]
    assert sizes[-1] == 232
    with pytest.raises(ValueError):
        workload.chunk_schedule(0, 256)


# ---------------------------------------------------------------------------
# 2. chunked TPOT/TTFT: batched vs scalar (1e-9 relative)
# ---------------------------------------------------------------------------

def test_chunked_tpot_ttft_batched_vs_scalar(dsv3_small):
    rng = np.random.default_rng(42)
    topos = ("scale-up", "scale-out", "torus", "fullmesh")
    n = 64
    table = optable.op_table(dsv3_small, 1, n, n)
    ptable = optable.prefill_op_table(dsv3_small, 1, n, n)
    for _ in range(12):
        topo = topos[rng.integers(len(topos))]
        cl = make_cluster(topo, n, H100,
                          link_bw=float(rng.choice([150e9, 450e9])))
        prompt = int(rng.choice([300, 1024, 4096]))
        chunk = int(rng.choice([128, 512, 1024]))
        sc = Scenario(40.0, prompt + 512, prompt_len=prompt,
                      ttft_ms=float(rng.choice([500.0, 2000.0])))
        batches = np.sort(rng.integers(1, 1 << 14, size=3))
        got_tpot, got_ttft = sweep.batched_chunked_tpot_ttft(
            table, ptable, [cl], batches, sc, chunk)
        for bi, b in enumerate(batches):
            p = ServingPoint(batch_global=int(b), context=sc.context, ep=n,
                             n_devices=n)
            want_tpot, want_ttft = optimizer.chunked_prefill_tpot(
                dsv3_small, p, cl, sc, chunk)
            np.testing.assert_allclose(got_tpot[0, bi], want_tpot,
                                       rtol=1e-9)
            np.testing.assert_allclose(got_ttft[0, bi], want_ttft,
                                       rtol=1e-9)


# ---------------------------------------------------------------------------
# 3. decode-only results byte-identical to PR 1
# ---------------------------------------------------------------------------

def test_scenario_decode_only_unchanged():
    """Prefill fields default inert: same name (JSON keys), same grid key
    semantics, gen_len derived from context = prompt + gen/2."""
    sc = Scenario(40.0, 512)
    assert sc.name == "tpot40ms_ctx512"
    assert sc.mem_context == 512
    pre = Scenario(40.0, 4608, prompt_len=4096, ttft_ms=1500.0)
    assert pre.name == "tpot40ms_ctx4608_p4096_ttft1500ms"
    assert pre.gen_len == 1024
    assert pre.mem_context == 4096 + 4608


def test_decode_only_byte_identical_to_committed_fig10():
    """Recompute two fig10 cells and compare against the committed PR-1
    JSON exactly — the decode path must not move under the prefill
    refactor."""
    path = os.path.join(ROOT, "bench_results", "fig10_scenarios.json")
    with open(path) as f:
        committed = json.load(f)
    cfg = get_arch("deepseek-v3")
    clusters = [make_cluster("scale-up", 64, H100, link_bw=bw)
                for bw in (450e9, 150e9)]
    scenarios = [Scenario(40.0, 512), Scenario(15.0, 4096)]
    ops = sweep.sweep_max_throughput(clusters, cfg, scenarios)
    for ci, bw in enumerate((450, 150)):
        for sc in scenarios:
            want = next(r for r in committed[f"ctx{sc.context}/bw{bw}"]
                        if r["tpot_ms"] == sc.tpot_ms)
            op = ops[ci][scenarios.index(sc)]
            got = ({"thpt_per_xpu": 0.0, "batch": 0} if op is None else
                   {"thpt_per_xpu": op.throughput / 64, "batch": op.batch})
            assert got["thpt_per_xpu"] == want["thpt_per_xpu"]
            assert got["batch"] == want["batch"]


# ---------------------------------------------------------------------------
# 2b. DBO inside the prefill modes (three-lane (max,+) schedule)
# ---------------------------------------------------------------------------

def test_chunked_dbo_batched_vs_scalar_all_topologies(dsv3_small):
    """Chunked-prefill DBO: batched == scalar at 1e-9 on all four Table-3
    topologies at pp > 1 (the acceptance bar) — decode iterations split
    into B/2 microbatches, chunks into causal half-chunks, pp hops on the
    dedicated send/recv lane on both paths."""
    tp, pp = 2, 2
    ep = 64 // (tp * pp)
    table = optable.op_table(dsv3_small, tp, ep, 64, pp=pp)
    ptable = optable.prefill_op_table(dsv3_small, tp, ep, 64, pp=pp)
    sc = Scenario(40.0, 2048 + 512, prompt_len=2048, ttft_ms=2000.0)
    batches = np.array([64, 1024, 8192])
    for topo in ("scale-up", "scale-out", "torus", "fullmesh"):
        cl = make_cluster(topo, 64, H100)
        for chunk in (128, 512, 999):       # odd chunk: uneven causal halves
            got_tpot, got_ttft = sweep.batched_chunked_tpot_ttft(
                table, ptable, [cl], batches, sc, chunk, dbo=True)
            for bi, b in enumerate(batches):
                p = ServingPoint(batch_global=int(b), context=sc.context,
                                 tp=tp, ep=ep, n_devices=64, pp=pp)
                want_tpot, want_ttft = optimizer.chunked_prefill_tpot(
                    dsv3_small, p, cl, sc, chunk, dbo=True)
                np.testing.assert_allclose(got_tpot[0, bi], want_tpot,
                                           rtol=1e-9,
                                           err_msg=f"{topo} c{chunk}")
                np.testing.assert_allclose(got_ttft[0, bi], want_ttft,
                                           rtol=1e-9,
                                           err_msg=f"{topo} c{chunk}")


def test_chunked_dbo_never_worse_than_no_overlap(dsv3_small):
    """DBO TPOT <= no-overlap TPOT on EVERY (cluster, batch, chunk) cell:
    each component is best-of(no-overlap, monotone (max,+) schedule), so
    overlap can only help."""
    sc = Scenario(40.0, 4096 + 512, prompt_len=4096, ttft_ms=0.0)
    table = optable.op_table(dsv3_small, 1, 64, 64)
    ptable = optable.prefill_op_table(dsv3_small, 1, 64, 64)
    batches = np.array([1, 64, 1024, 16384])
    for topo in ("scale-up", "scale-out", "torus", "fullmesh"):
        cl = make_cluster(topo, 64, H100)
        for chunk in (1, 128, 2048):
            t0, f0 = sweep.batched_chunked_tpot_ttft(table, ptable, [cl],
                                                     batches, sc, chunk)
            t1, f1 = sweep.batched_chunked_tpot_ttft(table, ptable, [cl],
                                                     batches, sc, chunk,
                                                     dbo=True)
            assert (t1 <= t0 + 1e-15).all(), (topo, chunk)
            assert (f1 <= f0 + 1e-15).all(), (topo, chunk)


def test_prefill_dbo_gains_on_bandwidth_constrained_fabric(dsv3_small):
    """The motivating trend: on a bandwidth-constrained fabric the chunk's
    A2A hides under the half-chunks' GEMMs, so DBO strictly improves the
    chunked TPOT; the searched operating point is never worse in any
    mode."""
    cl = make_cluster("scale-out", 64, H100)
    sc = Scenario(40.0, 4608, prompt_len=4096, ttft_ms=2000.0)
    table = optable.op_table(dsv3_small, 1, 64, 64)
    ptable = optable.prefill_op_table(dsv3_small, 1, 64, 64)
    batches = np.array([4096])
    t0, _ = sweep.batched_chunked_tpot_ttft(table, ptable, [cl], batches,
                                            sc, 512)
    t1, _ = sweep.batched_chunked_tpot_ttft(table, ptable, [cl], batches,
                                            sc, 512, dbo=True)
    assert t1[0, 0] < t0[0, 0]
    for mode in ("decode", "chunked", "disagg"):
        a = sweep.sweep_prefill([cl], dsv3_small, [sc], mode=mode)[0][0]
        b = sweep.sweep_prefill([cl], dsv3_small, [sc], mode=mode,
                                dbo=True)[0][0]
        assert a is not None and b is not None
        assert b.throughput >= a.throughput - 1e-12, mode
        assert b.used_dbo and not a.used_dbo


def test_decode_dbo_pinned_to_committed_fig11():
    """Decode-path DBO numbers must not move under the three-lane
    generalization: at pp = 1 the sendrecv lane is empty and the schedule
    must reproduce the committed fig11 'dbo' curve byte-identically."""
    path = os.path.join(ROOT, "bench_results", "fig11_sw_opts.json")
    with open(path) as f:
        committed = json.load(f)
    cfg = get_arch("deepseek-v3")
    cl = make_cluster("scale-up", 64, H100, link_bw=150e9)
    for want in committed["dbo/bw150"]:
        if want["thpt_per_xpu"] == 0.0:
            continue
        op = solve(cfg, cl, Scenario(want["tpot_ms"], 512),
                   SearchSpec(opts="dbo")).point
        assert op.throughput / 64 == want["thpt_per_xpu"]
        assert op.used_dbo == want["used_dbo"]


# ---------------------------------------------------------------------------
# disagg KV-handoff alpha (pool-local latency regime)
# ---------------------------------------------------------------------------

def test_disagg_kv_handoff_uses_pool_alpha(dsv3_small):
    """Regression (ISSUE 5 satellite): the KV-handoff alpha must come from
    the PREFILL POOL (`cl_p._ab()`), not the whole cluster — an 8-XPU pool
    sits inside one node and pays intra-node latencies. Pins the corrected
    TTFT against the closed form."""
    from repro.core.alphabeta import CLUSTER, INTRA_NODE

    cl = make_cluster("torus", 64, H100)
    sc = Scenario(40.0, 4608, prompt_len=4096, ttft_ms=2000.0)
    op = sweep.sweep_prefill([cl], dsv3_small, [sc], mode="disagg",
                             split_fracs=(0.125,))[0][0]
    assert op is not None and op.n_prefill_xpus == 8
    cl_p = sweep._subcluster(cl, 8)
    assert cl_p._ab() is INTRA_NODE
    ptable = optable.prefill_op_table(dsv3_small, op.tp_prefill,
                                      op.ep_prefill, 8, pp=op.pp_prefill)
    domains = 8 // op.tp_prefill
    t_p = float(sweep._prefill_chunk_times(ptable, cl_p, domains,
                                           [sc.prompt_len], [0])[0])
    kv = workload.kv_cache_bytes_per_request(dsv3_small, sc.prompt_len)
    want = t_p + INTRA_NODE.alpha0 + kv / (INTRA_NODE.link_utilization
                                           * cl.link_bw)
    wrong = t_p + CLUSTER.alpha0 + kv / (CLUSTER.link_utilization
                                         * cl.link_bw)
    assert op.ttft == pytest.approx(want, rel=1e-12)
    assert op.ttft != pytest.approx(wrong, rel=1e-9)


# ---------------------------------------------------------------------------
# serving-mode search
# ---------------------------------------------------------------------------

def test_sweep_prefill_modes(dsv3_small):
    sc = Scenario(40.0, 4608, prompt_len=4096, ttft_ms=2000.0)
    for topo in ("scale-up", "torus"):
        cl = make_cluster(topo, 64, H100)
        dec = solve(dsv3_small, cl, sc,
                    SearchSpec(mode="decode")).prefill_point
        chk = solve(dsv3_small, cl, sc,
                    SearchSpec(mode="chunked")).prefill_point
        dis = solve(dsv3_small, cl, sc,
                    SearchSpec(mode="disagg")).prefill_point
        # decode mode wraps the seed search byte-identically
        ref = solve(dsv3_small, cl, sc).point
        assert (dec.batch, dec.tpot, dec.throughput) \
            == (ref.batch, ref.tpot, ref.throughput)
        for op in (chk, dis):
            assert op is not None, topo
            assert op.tpot <= sc.tpot_ms * 1e-3 * (1 + 1e-9)
            assert 0.0 < op.ttft <= sc.ttft_ms * 1e-3 * (1 + 1e-9)
            # modeling prefill can only cost throughput
            assert op.throughput <= dec.throughput
        assert chk.chunk >= 1
        assert dis.n_prefill_xpus + dis.n_decode_xpus == cl.n_xpus


def test_sweep_prefill_rejects_bad_input(dsv3_small):
    cl = make_cluster("scale-up", 64, H100)
    with pytest.raises(ValueError, match="prompt_len"):
        sweep.sweep_prefill([cl], dsv3_small, [Scenario(40.0, 512)],
                            mode="chunked")
    with pytest.raises(ValueError, match="unknown prefill mode"):
        sweep.sweep_prefill([cl], dsv3_small,
                            [Scenario(40.0, 512, prompt_len=256)],
                            mode="hybrid")
    # context is the AVERAGE decode KV (prompt + gen/2): a prompt at or
    # past it means gen_len <= 0 and must be rejected, not clamped
    with pytest.raises(ValueError, match="must exceed prompt_len"):
        sweep.sweep_prefill([cl], dsv3_small,
                            [Scenario(40.0, 512, prompt_len=8192)],
                            mode="chunked")


# ---------------------------------------------------------------------------
# single-request KV guard
# ---------------------------------------------------------------------------

def test_memory_guard_rejects_oversized_context(dsv3_small):
    cl = make_cluster("scale-up", 64, H100)
    huge = Scenario(10_000.0, 50_000_000)
    p = ServingPoint(batch_global=1, context=huge.context, ep=64,
                     n_devices=64)
    assert not workload.single_request_fits(dsv3_small, p, cl.xpu.hbm_cap)
    assert solve(dsv3_small, cl, huge).point is None
    assert optimizer.max_throughput_scalar(cl, dsv3_small, huge) is None
    # a prompt that pushes context + prompt_len past HBM is rejected too,
    # in every serving mode
    huge_prompt = Scenario(10_000.0, 30_000_000, prompt_len=25_000_000,
                           ttft_ms=0.0)
    for mode in ("decode", "chunked", "disagg"):
        assert sweep.sweep_prefill([cl], dsv3_small, [huge_prompt],
                                   mode=mode)[0][0] is None


def test_memory_guard_keeps_feasible_scenarios(dsv3_small):
    cl = make_cluster("scale-up", 64, H100)
    p = ServingPoint(batch_global=1, context=4096, ep=64, n_devices=64)
    assert workload.single_request_fits(dsv3_small, p, cl.xpu.hbm_cap)
    assert solve(dsv3_small, cl, Scenario(40.0, 4096)).point is not None


# ---------------------------------------------------------------------------
# roofline benchmark: clean skip on fresh checkouts
# ---------------------------------------------------------------------------

def test_roofline_skips_cleanly_without_dryrun(tmp_path, monkeypatch):
    from benchmarks import common, roofline
    monkeypatch.setattr(common, "OUT_DIR", str(tmp_path))
    monkeypatch.setattr(roofline, "CANDIDATES", [])
    out = roofline.run(verbose=False)
    assert out["status"] == "skipped"
    assert "dry-run" in out["reason"]
    saved = json.load(open(tmp_path / "roofline.json"))
    assert saved["status"] == "skipped"


def test_roofline_runs_as_script(tmp_path):
    """`python benchmarks/roofline.py` from a fresh checkout must exit 0
    (regression: ModuleNotFoundError without PYTHONPATH, bare StopIteration
    without dry-run JSONs)."""
    env = dict(os.environ, BENCH_OUT=str(tmp_path))
    env.pop("PYTHONPATH", None)
    res = subprocess.run(
        [sys.executable, os.path.join(ROOT, "benchmarks", "roofline.py")],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=120)
    assert res.returncode == 0, res.stderr
