"""Hypothesis property tests for jax-vs-NumPy sweep-engine parity.

tests/test_sweep_jax.py pins the contract on hand-picked cells; this
module lets hypothesis draw the cells — topology x (tp, pp, ep) mapping
x dbo x fault set x batch/scenario grid for decode, and chunk schedules
for prefill — and asserts the two backends agree to <= 1e-6 relative on
EVERY grid cell (the documented acceptance bar; observed drift is
~1e-12, pure summation-order residue).

Kept separate from test_sweep_jax.py so a missing `hypothesis` (an
optional [dev] dependency, like tests/test_faults_props.py) skips this
module instead of erroring collection; a missing jax skips both.
"""
import pytest

pytest.importorskip("hypothesis")
pytest.importorskip("jax")

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import get_arch
from repro.core import H100, Scenario, make_cluster
from repro.core import optable, sweep
from repro.core.topology import FaultSet, TOPOLOGIES

CFG = get_arch("deepseek-v3").replace(num_layers=8)
RTOL = 1e-6
N = 64

faultsets = st.one_of(
    st.none(),
    st.builds(FaultSet,
              mesh_links=st.tuples(st.integers(0, 3), st.integers(0, 3),
                                   st.integers(0, 3)),
              switch_planes=st.integers(0, 4),
              nics=st.integers(0, 4)))

scenarios = st.lists(
    st.builds(Scenario,
              st.sampled_from((5.0, 15.0, 40.0, 100.0)),
              st.sampled_from((128, 1024, 8192, 32768))),
    min_size=1, max_size=3)

batch_grids = st.lists(st.integers(1, 65536), min_size=1, max_size=6,
                       unique=True).map(sorted)


@given(topo=st.sampled_from(TOPOLOGIES),
       tp_pp=st.sampled_from(((1, 1), (2, 1), (4, 1), (1, 2), (2, 2),
                              (1, 4), (8, 1))),
       dbo=st.booleans(), fs=faultsets, scs=scenarios, batches=batch_grids)
@settings(max_examples=30, deadline=None)
def test_decode_grid_parity(topo, tp_pp, dbo, fs, scs, batches):
    tp, pp = tp_pp
    ep = max(N // (tp * pp), 1)
    table = optable.op_table(CFG, tp, ep, N, "fp8", pp=pp)
    cl = make_cluster(topo, N, H100)
    if fs is not None:
        cl = cl.with_faults(fs)
    b = np.asarray(batches, np.int64)
    ref = sweep.GridEval(table, [cl], scs, b, backend="numpy")
    got = sweep.GridEval(table, [cl], scs, b, backend="jax")
    np.testing.assert_allclose(got.tpot(dbo=dbo), ref.tpot(dbo=dbo),
                               rtol=RTOL, atol=0.0)
    if dbo:     # the components feeding the (max,+) schedule also agree
        for q in (1,):
            for a, r in zip(got.seq_components(q), ref.seq_components(q)):
                np.testing.assert_allclose(a, r, rtol=RTOL, atol=0.0)


@given(topo=st.sampled_from(TOPOLOGIES),
       tp_pp=st.sampled_from(((1, 1), (2, 1), (2, 2))),
       dbo=st.booleans(),
       bg=st.integers(1, 4096),
       chunks=st.lists(st.tuples(st.integers(1, 8192),
                                 st.integers(0, 16384)),
                       min_size=1, max_size=5))
@settings(max_examples=25, deadline=None)
def test_prefill_chunk_parity(topo, tp_pp, dbo, bg, chunks):
    """Chunk-duration parity on arbitrary (size, kv-offset) schedules —
    the kernel under both the chunked and disagg prefill modes."""
    tp, pp = tp_pp
    ep = max(N // (tp * pp), 1)
    ptable = optable.prefill_op_table(CFG, tp, ep, N, "fp8", pp=pp)
    cl = make_cluster(topo, N, H100)
    sizes = np.array([c[0] for c in chunks], np.int64)
    offsets = np.array([c[1] for c in chunks], np.int64)
    ref = sweep._prefill_chunk_times(ptable, cl, bg, sizes, offsets,
                                     dbo=dbo, backend="numpy")
    got = sweep._prefill_chunk_times(ptable, cl, bg, sizes, offsets,
                                     dbo=dbo, backend="jax")
    np.testing.assert_allclose(got, ref, rtol=RTOL, atol=0.0)
