"""Table 3 ground truth: our A2A cost formulas must reproduce the paper's
coefficients exactly, and the alpha-beta model must behave sanely."""

import numpy as np
import pytest

from repro.core import alphabeta as ab
from repro.core import collectives as coll
from repro.core.hardware import H100
from repro.core.topology import make_cluster


# ---------------------------------------------------------------------------
# paper Table 3 (exact coefficients)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,dims,exp", [
    (64, (4, 4, 4), dict(rounds=1, dests=63, m_coeff=63 / 64)),
    (256, (8, 8, 4), dict(rounds=1, dests=255, m_coeff=255 / 256)),
])
def test_scaleup_p2p(n, dims, exp):
    c = coll.a2a_p2p(n)
    assert (c.rounds, c.dests) == (exp["rounds"], exp["dests"])
    assert c.m_coeff == pytest.approx(exp["m_coeff"])


@pytest.mark.parametrize("n,exp", [
    (64, dict(rounds=6, dests=6, m_coeff=3.0)),
    (256, dict(rounds=8, dests=8, m_coeff=4.0)),
])
def test_scaleup_bruck(n, exp):
    c = coll.a2a_bruck(n)
    assert (c.rounds, c.dests) == (exp["rounds"], exp["dests"])
    assert c.m_coeff == pytest.approx(exp["m_coeff"])


@pytest.mark.parametrize("dims,exp", [
    ((4, 4, 4), dict(rounds=3, dests=27, m_coeff=9 / 4)),
    ((8, 8, 4), dict(rounds=3, dests=51, m_coeff=17 / 4)),
])
def test_fullmesh_dor(dims, exp):
    c = coll.a2a_fullmesh_dor(dims)
    assert (c.rounds, c.dests) == (exp["rounds"], exp["dests"])
    assert c.m_coeff == pytest.approx(exp["m_coeff"])


@pytest.mark.parametrize("dims,exp", [
    ((4, 4, 4), dict(rounds=6, dests=36, m_coeff=3.0)),
    ((8, 8, 4), dict(rounds=12, dests=72, m_coeff=6.0)),
])
def test_torus_halfring(dims, exp):
    c = coll.a2a_torus_halfring(dims)
    assert (c.rounds, c.dests) == (exp["rounds"], exp["dests"])
    assert c.m_coeff == pytest.approx(exp["m_coeff"])


# ---------------------------------------------------------------------------
# ordering properties the paper relies on (Fig 7)
# ---------------------------------------------------------------------------

def test_a2a_topology_ordering_large_messages():
    """scale-up < fullmesh < torus at large message sizes (beta-dominated)."""
    m = 256 * 2**20
    su = make_cluster("scale-up", 64, H100)
    fm = make_cluster("fullmesh", 64, H100)
    to = make_cluster("torus", 64, H100)
    assert su.a2a_time(m) < fm.a2a_time(m) < to.a2a_time(m)


def test_a2a_grows_with_cluster_size():
    for topo in ("scale-up", "torus", "fullmesh"):
        small = make_cluster(topo, 64, H100)
        large = make_cluster(topo, 256, H100)
        m = 16 * 2**20
        assert small.a2a_time(m) < large.a2a_time(m), topo


def test_best_algorithm_switches_with_message_size():
    """Small m -> log-round Bruck wins (alpha-bound); large m -> P2P wins
    (beta-bound). The menu's min() must capture this crossover."""
    n = 256
    ab_model = ab.CLUSTER
    bw = 450e9

    def t(c, m):
        return ab_model.time(rounds=c.rounds, dests=c.dests,
                             m_coeff=c.m_coeff, m_bytes=m, bandwidth=bw)

    p2p, bruck = coll.a2a_p2p(n), coll.a2a_bruck(n)
    assert t(bruck, 1024) < t(p2p, 1024)
    assert t(p2p, 2**30) < t(bruck, 2**30)


# ---------------------------------------------------------------------------
# alpha-beta fitting (the Table 1 procedure on synthetic data)
# ---------------------------------------------------------------------------

def test_fit_alpha_beta_recovers_params():
    rng = np.random.default_rng(0)
    truth = ab.AlphaBeta(alpha0=6e-6, alpha_r=0.8e-6, alpha_d=0.3e-6,
                         link_utilization=0.72)
    bw = 450e9
    rounds = rng.integers(1, 16, 200).astype(float)
    dests = rng.integers(1, 256, 200).astype(float)
    # span alpha-dominated to beta-dominated sizes but keep the unweighted
    # lstsq conditioned enough to identify the alpha terms
    m = np.exp(rng.uniform(np.log(128), np.log(2**22), 200))
    times = np.array([truth.time(rounds=r, dests=d, m_coeff=1.0, m_bytes=mm,
                                 bandwidth=bw)
                      for r, d, mm in zip(rounds, dests, m)])
    times *= 1 + rng.normal(0, 0.02, 200)          # 2% measurement noise
    fit = ab.fit_alpha_beta(rounds, dests, m, bw, times)
    assert fit.alpha0 == pytest.approx(truth.alpha0, rel=0.25)
    assert fit.alpha_r == pytest.approx(truth.alpha_r, rel=0.25)
    assert fit.alpha_d == pytest.approx(truth.alpha_d, rel=0.25)
    assert fit.link_utilization == pytest.approx(truth.link_utilization,
                                                 rel=0.05)
    model = [fit.time(rounds=r, dests=d, m_coeff=1.0, m_bytes=mm,
                      bandwidth=bw)
             for r, d, mm in zip(rounds, dests, m)]
    assert ab.mean_relative_error(model, times) < 0.05


def test_beta_definition():
    """beta = 1/(utilization x peak BW): halving BW doubles the beta term
    (the alpha0 offset subtracts out)."""
    model = ab.INTER_NODE
    m = 2**28
    t1 = model.time(rounds=0, dests=0, m_coeff=1, m_bytes=m, bandwidth=450e9)
    t2 = model.time(rounds=0, dests=0, m_coeff=1, m_bytes=m, bandwidth=225e9)
    assert (t2 - model.alpha0) / (t1 - model.alpha0) == pytest.approx(2.0)
    assert t1 - model.alpha0 == pytest.approx(m / (0.843 * 450e9))
