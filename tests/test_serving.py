"""Serving runtime tests: engine continuous batching, DBO step equivalence,
and the speculative-decoding greedy-equivalence property."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch, reduced_config
from repro.models import model as M
from repro.serving import kvcache
from repro.serving.dbo import dbo_decode_step
from repro.serving.engine import Engine
from repro.serving.specdec import SDDecoder
from repro.sharding.dist import NullDist
from repro.sharding.plans import null_plan

ARCHS_FAST = ["starcoder2-3b", "olmoe-1b-7b"]
ARCHS_STATEFUL = ["rwkv6-1.6b", "jamba-v0.1-52b", "gemma3-1b"]


def make_model(arch, seed=0):
    cfg = reduced_config(get_arch(arch))
    params, _ = M.init_model(cfg, null_plan("decode"), jax.random.PRNGKey(seed))
    return cfg, params


def greedy_reference(cfg, params, prompt, n_tokens, max_seq):
    """Plain sequential greedy decode (the oracle for SD equivalence)."""
    plan, dist = null_plan("decode"), NullDist()
    pplan = null_plan("prefill")
    tok, caches = M.prefill(params, {"tokens": prompt}, cfg, pplan, dist)
    caches = kvcache.pad_to_capacity(cfg, caches, prompt.shape[1], max_seq)
    toks = [tok]
    pos = prompt.shape[1]
    for i in range(n_tokens - 1):
        tok, caches = M.decode_step(params, caches, tok, jnp.int32(pos),
                                    cfg, plan, dist)
        toks.append(tok)
        pos += 1
    return jnp.concatenate(toks, axis=1)


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS_FAST + ["rwkv6-1.6b"])
def test_engine_matches_sequential(arch):
    """Engine output for a single request == plain greedy decode."""
    cfg, params = make_model(arch)
    prompt = [3, 5, 7, 11, 2, 4]
    ref = greedy_reference(cfg, params,
                           jnp.asarray(prompt, jnp.int32)[None], 6, 64)
    eng = Engine(cfg, params, max_batch=2, max_seq=64, eos_id=-1)
    rid = eng.submit(prompt, max_new_tokens=6)
    out = eng.run()
    assert out[rid][:6] == [int(t) for t in ref[0][:6]]


def test_engine_continuous_batching():
    """More requests than slots: all complete, slots are reused."""
    cfg, params = make_model("starcoder2-3b")
    eng = Engine(cfg, params, max_batch=2, max_seq=48, eos_id=-1)
    rids = [eng.submit([1 + i, 2 + i, 3 + i], max_new_tokens=4)
            for i in range(5)]
    out = eng.run()
    assert set(out) == set(rids)
    for r in rids:
        assert len(out[r]) == 5        # 1 prefill token + 4 decode tokens


def test_engine_isolation():
    """Requests decoded together must not affect each other: run the same
    prompt alone and next to a different prompt."""
    cfg, params = make_model("olmoe-1b-7b")
    p1, p2 = [3, 1, 4, 1, 5], [9, 2, 6, 5, 3]
    eng1 = Engine(cfg, params, max_batch=2, max_seq=48, eos_id=-1)
    r1 = eng1.submit(p1, max_new_tokens=5)
    alone = eng1.run()[r1]
    eng2 = Engine(cfg, params, max_batch=2, max_seq=48, eos_id=-1)
    ra = eng2.submit(p1, max_new_tokens=5)
    eng2.submit(p2, max_new_tokens=5)
    both = eng2.run()
    assert both[ra] == alone


# ---------------------------------------------------------------------------
# DBO step
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["olmoe-1b-7b", "jamba-v0.1-52b"])
def test_dbo_step_equivalent_to_plain(arch):
    """The interleaved DBO step must produce the same tokens/caches as two
    independent plain decode steps (it only re-orders independent work)."""
    cfg, params = make_model(arch)
    plan, dist = null_plan("decode"), NullDist()
    B, S = 2, 32
    caches_a, _ = M.init_cache(cfg, plan, B, S)
    caches_b, _ = M.init_cache(cfg, plan, B, S)
    ta = jnp.array([[3], [5]], jnp.int32)
    tb = jnp.array([[7], [9]], jnp.int32)
    pos = jnp.int32(0)

    na, ca, _ = *M.decode_step(params, caches_a, ta, pos, cfg, plan, dist), None
    nb, cb, _ = *M.decode_step(params, caches_b, tb, pos, cfg, plan, dist), None
    da, db, dca, dcb = dbo_decode_step(params, caches_a, caches_b, ta, tb,
                                       pos, cfg, plan, dist)
    assert (da == na).all() and (db == nb).all()
    for x, y in zip(jax.tree.leaves(dca), jax.tree.leaves(ca)):
        assert jnp.allclose(x, y, atol=1e-5), "microbatch A cache diverged"
    for x, y in zip(jax.tree.leaves(dcb), jax.tree.leaves(cb)):
        assert jnp.allclose(x, y, atol=1e-5), "microbatch B cache diverged"


# ---------------------------------------------------------------------------
# speculative decoding: THE invariant — SD == greedy, any draft
# ---------------------------------------------------------------------------

def _sd_vs_greedy(arch, draft_fn, n_tokens=8, seed=0):
    cfg, params = make_model(arch, seed)
    plan, dist = null_plan("decode"), NullDist()
    max_seq = 64
    prompt = jnp.asarray([[3, 5, 7, 11, 2, 4]], jnp.int32)
    ref = greedy_reference(cfg, params, prompt, n_tokens, max_seq)

    tok, caches = M.prefill(params, {"tokens": prompt}, cfg,
                            null_plan("prefill"), dist)
    caches = kvcache.pad_to_capacity(cfg, caches, prompt.shape[1], max_seq)
    dec = SDDecoder(cfg, params, spec_m=4, draft_fn=draft_fn)
    toks, _, stats = dec.generate(caches, tok, prompt.shape[1], n_tokens - 1)
    got = jnp.concatenate([tok, toks], axis=1)
    assert (got[:, :n_tokens] == ref).all(), (
        f"{arch}: SD diverged from greedy: {got} vs {ref}")
    return stats


def bad_draft(params, caches, cur_tok, pos):
    """Adversarial draft: constant garbage -> acceptance must just be 1."""
    return jnp.full((cur_tok.shape[0], 3), 12345 % 500, jnp.int32)


@pytest.mark.parametrize("arch", ARCHS_FAST + ARCHS_STATEFUL)
def test_sd_equals_greedy_bad_draft(arch):
    stats = _sd_vs_greedy(arch, bad_draft)
    assert stats["mean_accepted"] >= 1.0


@pytest.mark.parametrize("arch", ARCHS_FAST + ARCHS_STATEFUL)
def test_sd_equals_greedy_medusa_heads(arch):
    """Untrained Medusa heads (arbitrary draft quality) — output must STILL
    equal greedy; this exercises partial-acceptance rollback paths."""
    _sd_vs_greedy(arch, None)


def test_sd_perfect_draft_accepts_all():
    """Oracle draft (the model's own continuation) -> every iteration
    accepts spec_m tokens."""
    arch = "starcoder2-3b"
    cfg, params = make_model(arch)
    plan, dist = null_plan("decode"), NullDist()
    max_seq = 64
    prompt = jnp.asarray([[3, 5, 7, 11, 2, 4]], jnp.int32)
    n_tokens = 9
    ref = greedy_reference(cfg, params, prompt, n_tokens + 1, max_seq)

    # oracle: look up the reference continuation by position
    def oracle(params_, caches_, cur_tok, pos):
        del params_, caches_
        # cur_tok is ref[pos - prompt_len]; draft the next 3
        i = pos - prompt.shape[1]
        return jax.lax.dynamic_slice(ref, (0, i + 1), (1, 3))

    # oracle needs concrete pos: drive manually
    tok, caches = M.prefill(params, {"tokens": prompt}, cfg,
                            null_plan("prefill"), dist)
    caches = kvcache.pad_to_capacity(cfg, caches, prompt.shape[1], max_seq)
    dec = SDDecoder(cfg, params, spec_m=4)
    pos = prompt.shape[1]
    got = [tok]
    n_acc_all = []
    cur = tok
    while sum(t.shape[1] for t in got) < n_tokens:
        d = oracle(None, None, cur, pos)
        toks, n_acc, caches = dec._step(params, caches, cur, d,
                                        jnp.int32(pos))
        k = int(n_acc[0])
        n_acc_all.append(k)
        got.append(toks[:, :k])
        cur = toks[:, k - 1:k]
        pos += k
    seq = jnp.concatenate(got, axis=1)[:, :n_tokens]
    assert (seq == ref[:, :n_tokens]).all()
    assert all(k == 4 for k in n_acc_all[:-1]), n_acc_all


# ---------------------------------------------------------------------------
# kvcache utilities
# ---------------------------------------------------------------------------

def test_classify_and_pad():
    cfg = reduced_config(get_arch("jamba-v0.1-52b"))
    plan = null_plan("decode")
    caches, _ = M.init_cache(cfg, plan, 2, 16)
    classes = kvcache.classify(cfg, caches)
    vals = set(jax.tree.leaves(classes))
    assert vals == {"positional", "recurrent"}
    padded = kvcache.pad_to_capacity(cfg, caches, 16, 32)
    # attention k/v grew; mamba states untouched
    k_leaves = [x for x in jax.tree.leaves(padded) if x.ndim >= 4]
    assert any(x.shape[-2] == 32 for x in k_leaves)
    assert kvcache.memory_bytes(padded) > kvcache.memory_bytes(caches)
