"""Hypothesis property tests for the Pallas kernels.

Kept separate from test_kernels.py so a missing `hypothesis` (an optional
[dev] dependency) skips this module instead of erroring the whole suite at
collection.
"""
import pytest

pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.flash_decode import flash_decode_pallas
from repro.kernels.moe_gmm import moe_gmm_pallas


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype) * 0.3


@given(e=st.integers(1, 3), nt=st.integers(1, 3), nf=st.integers(1, 3),
       seed=st.integers(0, 2**16))
@settings(max_examples=12, deadline=None)
def test_moe_gmm_property(e, nt, nf, seed):
    """Property: any (expert, tile-count) combination matches the oracle."""
    t, d, f = 64 * nt, 32, 128 * nf
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = rand(ks[0], (e, t, d), jnp.float32)
    wg = rand(ks[1], (e, d, f), jnp.float32)
    wu = rand(ks[2], (e, d, f), jnp.float32)
    wd = rand(ks[3], (e, f, d), jnp.float32)
    got = moe_gmm_pallas(x, wg, wu, wd, block_t=64, block_f=128,
                         interpret=True)
    want = ref.moe_gmm_ref(x, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5,
                               rtol=3e-5)


@given(length_frac=st.floats(0.01, 1.0), seed=st.integers(0, 2**16))
@settings(max_examples=12, deadline=None)
def test_flash_decode_length_property(length_frac, seed):
    """Property: masking via `length` equals physically truncating K/V."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    b, h, kh, s, hd = 1, 4, 2, 512, 32
    q = rand(ks[0], (b, h, hd), jnp.float32)
    k = rand(ks[1], (b, kh, s, hd), jnp.float32)
    v = rand(ks[2], (b, kh, s, hd), jnp.float32)
    length = max(int(s * length_frac), 1)
    got = flash_decode_pallas(q, k, v, jnp.int32(length), interpret=True)
    want = ref.flash_decode_ref(q, k[:, :, :length], v[:, :, :length],
                                length)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5,
                               rtol=3e-5)
