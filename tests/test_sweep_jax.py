"""Backend parity: the jitted sweep engine vs the NumPy reference.

The two-backend contract (docs/sweep_engine.md): the NumPy path is the
reference — held to 1e-9 against the scalar optimizer elsewhere — and the
jax path must agree with it to <= 1e-6 relative on every grid cell, with
identical argmax winners on the committed figures. These tests pin that
contract deterministically:

  1. grid parity across all four Table-3 topologies, dbo on/off,
  2. grid parity across (tp, pp, ep) mappings, including pp > 1 (the
     three-lane schedule's send/recv lane),
  3. end-to-end OperatingPoint equality for the full search entry points
     (sweep_max_throughput, degraded_max_throughput under faults,
     sweep_prefill chunked/disagg) — equality is EXACT, not approximate:
     the jax path re-derives each winner through the scalar optimizer, so
     whenever the argmax agrees the OperatingPoint is byte-identical,
  4. argmax-winner pins against the committed fig10 JSON and the Table-3
     topology comparison under backend="jax",
  5. backend-seam plumbing (set_default_backend, validation, env default).

Randomized cross-products of the same axes live in
tests/test_sweep_jax_props.py (hypothesis, skipped when not installed).
"""
from __future__ import annotations

import json
import os

import numpy as np
import pytest

pytest.importorskip("jax")

from repro.configs import get_arch
from repro.core import H100, Scenario, make_cluster
from repro.core import optable, sweep, sweep_jax
from repro.core.topology import FaultSet, TOPOLOGIES

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RTOL = 1e-6          # the jax-vs-numpy acceptance bar (observed ~1e-12)
BATCHES = np.array([1, 4, 64, 512, 4096, 32768])


@pytest.fixture(scope="module")
def dsv3_small():
    return get_arch("deepseek-v3").replace(num_layers=8)


def _tpots(cfg, tp, pp, topo, *, dbo, faults=None, sd=None):
    """(numpy, jax) TPOT grids for one mapping on one topology."""
    n = 64
    ep = max(n // (tp * pp), 1)
    table = optable.op_table(cfg, tp, ep, n, "fp8", pp=pp)
    cl = make_cluster(topo, n, H100)
    if faults is not None:
        cl = cl.with_faults(faults)
    scs = [Scenario(25.0, 512), Scenario(60.0, 8192)]
    out = []
    for backend in ("numpy", "jax"):
        ev = sweep.GridEval(table, [cl], scs, BATCHES, backend=backend)
        out.append(ev.tpot(dbo=dbo, sd=sd))
    return out


# ---------------------------------------------------------------------------
# 1-2. grid parity: topology x (tp, pp, ep) x dbo x faults
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo", TOPOLOGIES)
@pytest.mark.parametrize("dbo", [False, True])
def test_grid_parity_topologies(dsv3_small, topo, dbo):
    ref, got = _tpots(dsv3_small, 2, 1, topo, dbo=dbo)
    np.testing.assert_allclose(got, ref, rtol=RTOL, atol=0.0)


@pytest.mark.parametrize("tp,pp", [(1, 1), (4, 1), (1, 4), (2, 2)])
def test_grid_parity_mappings(dsv3_small, tp, pp):
    """pp > 1 exercises stage_scale and the dedicated pp send/recv lane
    inside the jitted (max,+) makespan."""
    ref, got = _tpots(dsv3_small, tp, pp, "fullmesh", dbo=True)
    np.testing.assert_allclose(got, ref, rtol=RTOL, atol=0.0)


def test_grid_parity_faulted_fabric(dsv3_small):
    """Link faults derate the comm menus per cluster; the jax lowering
    must pick the derated alphas up from Cluster.comm_spec unchanged."""
    fs = FaultSet(mesh_links=(2, 1, 0))
    ref, got = _tpots(dsv3_small, 2, 1, "torus", dbo=True, faults=fs)
    np.testing.assert_allclose(got, ref, rtol=RTOL, atol=0.0)


def test_comm_lowering_matches_numpy_menus(dsv3_small):
    """The padded (A, Mc, Bt) menu tensors are exactly the per-cluster
    alpha-beta coefficients the NumPy path uses (same Table-3 collective
    algorithms, same association) — parity starts at the lowering."""
    table = optable.op_table(dsv3_small, 2, 32, 64, "fp8")
    clusters = [make_cluster(t, 64, H100) for t in TOPOLOGIES]
    A, Mc, Bt = sweep_jax.lower_comm_menus(table, clusters)
    for oi in range(table.n_ops):
        for ci, cl in enumerate(clusters):
            if table.is_compute[oi]:
                assert np.all(np.isinf(A[oi, ci]))      # inert under min
                continue
            algs = sweep._comm_menu_coeffs(cl, int(table.kind[oi]),
                                           int(table.group[oi]),
                                           table.tp, table.pp)
            k = len(algs)
            want = np.array(algs)                       # (k, 3) triples
            assert np.array_equal(A[oi, ci, :k], want[:, 0])
            assert np.array_equal(Mc[oi, ci, :k], want[:, 1])
            assert np.array_equal(Bt[oi, ci, :k], want[:, 2])
            assert np.all(np.isinf(A[oi, ci, k:]))      # padding is inert


# ---------------------------------------------------------------------------
# 3. end-to-end searches: EXACT OperatingPoint equality
# ---------------------------------------------------------------------------

def test_sweep_max_throughput_exact(dsv3_small):
    clusters = [make_cluster("scale-up", 64, H100),
                make_cluster("torus", 64, H100)]
    scs = [Scenario(25.0, 1024), Scenario(60.0, 4096)]
    ref = sweep.sweep_max_throughput(clusters, dsv3_small, scs, tp=2,
                                     dbo=True, backend="numpy")
    got = sweep.sweep_max_throughput(clusters, dsv3_small, scs, tp=2,
                                     dbo=True, backend="jax")
    assert got == ref


def test_degraded_max_throughput_exact(dsv3_small):
    cl = make_cluster("torus", 64, H100)
    fs = FaultSet(mesh_links=(2, 1, 0), xpus=1)
    sc = Scenario(40.0, 4096)
    ref = sweep.degraded_max_throughput(cl, dsv3_small, sc, faults=fs,
                                        dbo=True, backend="numpy")
    got = sweep.degraded_max_throughput(cl, dsv3_small, sc, faults=fs,
                                        dbo=True, backend="jax")
    assert got == ref and got is not None


@pytest.mark.parametrize("mode", ["chunked", "disagg"])
def test_sweep_prefill_exact(dsv3_small, mode):
    clusters = [make_cluster("scale-up", 64, H100)]
    sc = Scenario(40.0, 4096, prompt_len=2048, ttft_ms=2000.0)
    ref = sweep.sweep_prefill(clusters, dsv3_small, [sc], mode=mode,
                              tp=2, dbo=True, backend="numpy")
    got = sweep.sweep_prefill(clusters, dsv3_small, [sc], mode=mode,
                              tp=2, dbo=True, backend="jax")
    assert got == ref and got[0][0] is not None


def test_prefill_chunk_times_parity(dsv3_small):
    """The prefill chunk-duration kernel (uneven causal halves, dbo)."""
    ptable = optable.prefill_op_table(dsv3_small, 2, 16, 64, pp=2)
    cl = make_cluster("fullmesh", 64, H100)
    sizes = np.array([1, 128, 513, 4096])
    offsets = np.array([0, 0, 512, 8192])
    for dbo in (False, True):
        ref = sweep._prefill_chunk_times(ptable, cl, 256, sizes, offsets,
                                         dbo=dbo, backend="numpy")
        got = sweep._prefill_chunk_times(ptable, cl, 256, sizes, offsets,
                                         dbo=dbo, backend="jax")
        np.testing.assert_allclose(got, ref, rtol=RTOL, atol=0.0)


# ---------------------------------------------------------------------------
# 4. committed-figure argmax pins under backend="jax"
# ---------------------------------------------------------------------------

def test_fig10_winners_pinned_under_jax():
    """Recompute fig10 cells with backend="jax" and require the winners
    (batch AND throughput) to equal the committed PR-1 JSON exactly — the
    jitted argmax must not move the committed figures."""
    with open(os.path.join(ROOT, "bench_results",
                           "fig10_scenarios.json")) as f:
        committed = json.load(f)
    cfg = get_arch("deepseek-v3")
    clusters = [make_cluster("scale-up", 64, H100, link_bw=bw)
                for bw in (450e9, 150e9)]
    scenarios = [Scenario(40.0, 512), Scenario(15.0, 4096),
                 Scenario(100.0, 512)]
    ops = sweep.sweep_max_throughput(clusters, cfg, scenarios,
                                     backend="jax")
    for ci, bw in enumerate((450, 150)):
        for si, sc in enumerate(scenarios):
            want = next(r for r in committed[f"ctx{sc.context}/bw{bw}"]
                        if r["tpot_ms"] == sc.tpot_ms)
            op = ops[ci][si]
            got = ({"thpt_per_xpu": 0.0, "batch": 0} if op is None else
                   {"thpt_per_xpu": op.throughput / 64, "batch": op.batch})
            assert got["thpt_per_xpu"] == want["thpt_per_xpu"], (bw, sc)
            assert got["batch"] == want["batch"], (bw, sc)


def test_table3_topology_winner_pinned_under_jax(dsv3_small):
    """The Table-3 topology comparison (same XPUs, four fabrics) must
    crown the same winner on both backends, with identical points."""
    scs = [Scenario(20.0, 4096)]
    by_backend = {}
    for backend in ("numpy", "jax"):
        pts = {t: sweep.sweep_max_throughput(
                   [make_cluster(t, 64, H100)], dsv3_small, scs, tp=2,
                   backend=backend)[0][0] for t in TOPOLOGIES}
        assert all(p is not None for p in pts.values())
        by_backend[backend] = pts
    assert by_backend["numpy"] == by_backend["jax"]
    win = {b: max(p, key=lambda t: p[t].throughput)
           for b, p in by_backend.items()}
    assert win["numpy"] == win["jax"]


# ---------------------------------------------------------------------------
# 5. backend seam plumbing
# ---------------------------------------------------------------------------

def test_backend_validation_and_default(dsv3_small):
    with pytest.raises(ValueError, match="unknown sweep backend"):
        sweep.set_default_backend("cuda")
    table = optable.op_table(dsv3_small, 1, 64, 64, "fp8")
    with pytest.raises(ValueError, match="unknown sweep backend"):
        sweep.GridEval(table, [make_cluster("scale-up", 64, H100)],
                       [Scenario(40.0, 512)], BATCHES, backend="tpu")
    prev = sweep.set_default_backend("jax")
    try:
        assert prev == "numpy"      # repo default: NumPy is the reference
        ev = sweep.GridEval(table, [make_cluster("scale-up", 64, H100)],
                            [Scenario(40.0, 512)], BATCHES)
        assert ev.backend == "jax"  # backend=None picks up module default
    finally:
        sweep.set_default_backend(prev)


def test_require_jax_importerror_message():
    if sweep_jax.HAVE_JAX:
        sweep_jax.require_jax()     # no-op when jax is importable
    else:                           # pragma: no cover - jax present in CI
        with pytest.raises(ImportError, match="backend"):
            sweep_jax.require_jax()
