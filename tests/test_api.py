"""The `solve()` facade (repro.core.api): the repo's one public search
entry point.

Guarantee layers:

  1. routing equivalence — every `solve()` path returns byte-identical
     results to the legacy `optimizer` wrapper it replaces (decode,
     best-of-opts, prefill modes, degraded, skewed + placement, jax
     backend), because both sides call the same sweep-engine functions;
  2. deprecation enforcement — the legacy wrappers emit
     `ReproDeprecationWarning` (escalated to an error by pyproject's
     filterwarnings, so repo code cannot regress onto them) while still
     returning the same values;
  3. SearchSpec validation — contradictory specs fail loudly at
     construction, not deep inside an engine;
  4. Solution ergonomics — feasible/throughput/tpot/batch/prefill_point
     behave on both feasible and infeasible results, and `tpot_curve`
     reproduces the solved point's TPOT at its own batch.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import (H100, Scenario, SearchSpec, make_cluster, solve,
                        solve_grid)
from repro.core import api, optimizer, sweep
from repro.core.api import ReproDeprecationWarning
from repro.core.specdec import SpecDecConfig
from repro.core.topology import FaultSet

TABLE3_TOPOS = ("scale-up", "scale-out", "torus", "fullmesh")
CFG = get_arch("deepseek-v3")
SC = Scenario(40.0, 512)


@pytest.fixture(scope="module")
def dsv3_small():
    return get_arch("deepseek-v3").replace(num_layers=8)


# ---------------------------------------------------------------------------
# 1. routing equivalence (facade == legacy wrapper, byte-identical)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo", TABLE3_TOPOS)
def test_decode_equals_legacy_max_throughput(topo):
    cl = make_cluster(topo, 64, H100)
    sol = solve(CFG, cl, SC)
    with pytest.warns(ReproDeprecationWarning, match="solve"):
        legacy = optimizer.max_throughput(cl, CFG, SC)
    assert sol.kind == "decode"
    assert sol.point == legacy


def test_decode_variants_equal_legacy():
    cl = make_cluster("torus", 64, H100)
    for dbo, sd in ((True, None), (True, SpecDecConfig())):
        sol = solve(CFG, cl, SC, SearchSpec(dbo=dbo, sd=sd))
        with pytest.warns(ReproDeprecationWarning):
            legacy = optimizer.max_throughput(cl, CFG, SC, dbo=dbo, sd=sd)
        assert sol.point == legacy


@pytest.mark.parametrize("opts", api.OPTS_LEVELS)
def test_opts_equals_legacy_best_of_opts(opts):
    cl = make_cluster("fullmesh", 64, H100)
    sol = solve(CFG, cl, SC, SearchSpec(opts=opts))
    with pytest.warns(ReproDeprecationWarning, match="solve"):
        legacy = optimizer.best_of_opts(cl, CFG, SC, opts=opts)
    assert sol.kind == "decode"
    assert sol.point == legacy


def test_solve_levels_equals_per_level_grids():
    clusters = [make_cluster(t, 64, H100) for t in ("scale-up", "torus")]
    scenarios = [SC, Scenario(100.0, 4096)]
    multi = api.solve_levels(CFG, clusters, scenarios)
    for lvl in api.OPTS_LEVELS:
        grid = solve_grid(CFG, clusters, scenarios, SearchSpec(opts=lvl))
        assert [[s.point for s in row] for row in multi[lvl]] \
            == [[s.point for s in row] for row in grid]
        assert all(s.spec.opts == lvl for row in multi[lvl] for s in row)


@pytest.mark.parametrize("mode", ("chunked", "disagg"))
def test_prefill_equals_legacy(dsv3_small, mode):
    sc = Scenario(40.0, 4608, prompt_len=4096, ttft_ms=2000.0)
    cl = make_cluster("torus", 64, H100)
    sol = solve(dsv3_small, cl, sc, SearchSpec(mode=mode))
    with pytest.warns(ReproDeprecationWarning, match="solve"):
        legacy = optimizer.max_throughput_prefill(cl, dsv3_small, sc,
                                                  mode=mode)
    assert sol.kind == "prefill"
    assert sol.point == legacy
    assert sol.prefill_point is sol.point


def test_prefill_decode_mode_wraps_decode_search(dsv3_small):
    """mode='decode' through the facade is the decode search wrapped into
    a PrefillOperatingPoint exactly like sweep_prefill(mode='decode')."""
    sc = Scenario(40.0, 4608, prompt_len=4096, ttft_ms=2000.0)
    cl = make_cluster("scale-up", 64, H100)
    via_mode = solve(dsv3_small, cl, sc, SearchSpec(mode="decode"))
    assert via_mode.kind == "decode"         # the default-route decode search
    ref = sweep.sweep_prefill([cl], dsv3_small, [sc], mode="decode")[0][0]
    assert via_mode.prefill_point == ref


def test_degraded_equals_degrade_policy(dsv3_small):
    fs = FaultSet(xpus=2)
    cl = make_cluster("torus", 64, H100)
    spec = SearchSpec(faults=fs, tp="auto")
    sol = solve(dsv3_small, cl, SC, spec)
    plan = optimizer.degrade_policy(cl, dsv3_small, SC, fs)
    assert sol.kind == "degraded"
    assert sol.plan == plan
    assert sol.point == plan.point
    assert sol.throughput == plan.effective_throughput


def test_skewed_placement_equals_legacy_sweep():
    sc = Scenario(40.0, 4096, routing="zipf", zipf_s=1.0)
    cl = make_cluster("fullmesh", 64, H100)
    sol = solve(CFG, cl, sc, SearchSpec(dbo=True, placement="auto"))
    ref = sweep.sweep_max_throughput([cl], CFG, [sc], dbo=True,
                                     placement="auto")[0][0]
    assert sol.point == ref


def test_jax_backend_exact_match(dsv3_small):
    cl = make_cluster("torus", 64, H100)
    spec_np = SearchSpec(tp=2, dbo=True, backend="numpy")
    ref = solve(dsv3_small, cl, SC, spec_np)
    got = solve(dsv3_small, cl, SC, spec_np.replace(backend="jax"))
    assert got.point == ref.point


def test_solve_grid_shape_matches_scalar_solve():
    clusters = [make_cluster(t, 64, H100) for t in ("scale-up", "torus")]
    scenarios = [SC, Scenario(15.0, 4096)]
    grid = solve_grid(CFG, clusters, scenarios)
    assert len(grid) == 2 and all(len(row) == 2 for row in grid)
    for ci, cl in enumerate(clusters):
        for si, sc in enumerate(scenarios):
            assert grid[ci][si].point == solve(CFG, cl, sc).point


# ---------------------------------------------------------------------------
# 2. deprecation enforcement
# ---------------------------------------------------------------------------

def test_deprecation_category_is_scoped():
    """The category is OUR subclass: pyproject escalates exactly it, so
    third-party DeprecationWarnings cannot fail the suite."""
    assert issubclass(ReproDeprecationWarning, DeprecationWarning)
    cl = make_cluster("scale-up", 64, H100)
    with pytest.warns(ReproDeprecationWarning):
        optimizer.max_throughput(cl, CFG, SC)
    with pytest.warns(ReproDeprecationWarning):
        optimizer.best_of_opts(cl, CFG, SC, opts="noopt")


def test_deprecated_prefill_wrapper_warns(dsv3_small):
    sc = Scenario(40.0, 4608, prompt_len=4096, ttft_ms=2000.0)
    cl = make_cluster("scale-up", 64, H100)
    with pytest.warns(ReproDeprecationWarning):
        optimizer.max_throughput_prefill(cl, dsv3_small, sc, mode="chunked")


# ---------------------------------------------------------------------------
# 3. SearchSpec validation
# ---------------------------------------------------------------------------

def test_spec_rejects_contradictions():
    with pytest.raises(ValueError, match="unknown mode"):
        SearchSpec(mode="hybrid")
    with pytest.raises(ValueError, match="unknown opts"):
        SearchSpec(opts="everything")
    with pytest.raises(ValueError, match="not.*both|opts"):
        SearchSpec(opts="dbo", dbo=True)
    with pytest.raises(ValueError, match="decode-only"):
        SearchSpec(mode="chunked", opts="dbo")
    with pytest.raises(ValueError, match="decode-only"):
        SearchSpec(mode="disagg", placement="auto")
    with pytest.raises(ValueError, match="decode-only"):
        SearchSpec(faults=FaultSet(xpus=1), mode="chunked")
    with pytest.raises(ValueError, match="do not apply"):
        SearchSpec(faults=FaultSet(xpus=1), ep=64)


def test_spec_is_frozen_hashable_and_replace():
    spec = SearchSpec(opts="dbo+sd")
    with pytest.raises(dataclasses.FrozenInstanceError):
        spec.tp = 2
    assert hash(spec) == hash(SearchSpec(opts="dbo+sd"))
    repl = spec.replace(opts=None, dbo=True)
    assert repl.dbo and repl.opts is None and spec.opts == "dbo+sd"


def test_solve_levels_rejects_variant_specs():
    cl = make_cluster("scale-up", 64, H100)
    with pytest.raises(ValueError, match="variant axis"):
        api.solve_levels(CFG, [cl], [SC], spec=SearchSpec(dbo=True))
    with pytest.raises(ValueError, match="healthy decode"):
        api.solve_levels(CFG, [cl], [SC],
                         spec=SearchSpec(faults=FaultSet(xpus=1),
                                         tp="auto"))


# ---------------------------------------------------------------------------
# 4. Solution ergonomics + tpot_curve
# ---------------------------------------------------------------------------

def test_solution_properties_feasible_and_not(dsv3_small):
    cl = make_cluster("scale-up", 64, H100)
    ok = solve(dsv3_small, cl, SC)
    assert ok.feasible
    assert ok.throughput == ok.point.throughput > 0
    assert ok.tpot == ok.point.tpot and ok.batch == ok.point.batch
    bad = solve(dsv3_small, cl, Scenario(10_000.0, 50_000_000))
    assert not bad.feasible
    assert bad.throughput == 0.0
    assert bad.tpot is None and bad.batch is None
    assert bad.prefill_point is None


def test_tpot_curve_reproduces_solved_point(dsv3_small):
    cl = make_cluster("torus", 64, H100)
    sol = solve(dsv3_small, cl, SC, SearchSpec(opts="dbo+sd"))
    pt = sol.point
    batches = [max(pt.batch // 2, 1), pt.batch, pt.batch * 2]
    curve = api.tpot_curve(dsv3_small, cl, SC, batches, point=pt)
    assert curve.shape == (3,)
    assert curve[1] == pytest.approx(pt.tpot, rel=1e-9)
    assert np.all(np.diff(curve) > 0)          # TPOT grows with batch
