"""Hypothesis property tests for the DBO three-lane scheduler.

Kept separate from test_overlap.py so a missing `hypothesis` (an optional
[dev] dependency) skips this module instead of erroring the whole suite at
collection.
"""
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core.overlap import LANES, TimedOp, simulate_lanes


@given(st.lists(st.tuples(st.sampled_from(LANES),
                          st.floats(0.001, 10.0)), min_size=1, max_size=30))
@settings(max_examples=200, deadline=None)
def test_schedule_invariants(ops):
    """Property: makespan >= max(lane busy times); >= each stream's total;
    <= the fully-serial sum of both streams; within-stream order
    preserved."""
    a = [TimedOp(f"a{i}", l, d, 0) for i, (l, d) in enumerate(ops)]
    b = [TimedOp(f"b{i}", l, d, 1) for i, (l, d) in enumerate(ops)]
    res = simulate_lanes(a, b)
    stream_total = sum(d for _, d in ops)
    assert res.makespan >= res.compute_busy - 1e-9
    assert res.makespan >= res.comm_busy - 1e-9
    assert res.makespan >= res.sendrecv_busy - 1e-9
    assert res.makespan >= stream_total - 1e-9
    assert res.makespan <= 2 * stream_total + 1e-9
    # per-microbatch op order is preserved
    for mb in (0, 1):
        ends = [e for (_, m, s, e) in res.timeline if m == mb]
        starts = [s for (_, m, s, e) in res.timeline if m == mb]
        for i in range(1, len(ends)):
            assert starts[i] >= ends[i - 1] - 1e-9
    # lanes never run two ops at once
    for lane in LANES:
        lane_ops = sorted(
            [(s, e) for (n, m, s, e) in res.timeline
             for op in [next(o for o in (a + b)
                             if o.name == n and o.mb == m)]
             if op.lane == lane])
        for (s1, e1), (s2, e2) in zip(lane_ops, lane_ops[1:]):
            assert s2 >= e1 - 1e-9
