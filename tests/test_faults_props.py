"""Hypothesis property tests for the degraded-fabric model: adding any
fault never speeds the fabric up.

The failure-model analogue of the overlap no-anomaly suite — on every
topology, for arbitrary fault sets:

  * a2a_time / ar_time / pp_hop_time never decrease;
  * stacking MORE faults on an already-faulted fabric never decreases
    them either (monotone along fault chains, not just vs. healthy);
  * the TPOT of the searched operating point never decreases, and the
    searched throughput never increases.

Kept separate from test_faults.py so a missing `hypothesis` (an optional
[dev] dependency) skips this module instead of erroring the whole suite
at collection.
"""
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.configs import get_arch
from repro.core import H100, Scenario, SearchSpec, make_cluster, solve
from repro.core.optimizer import tpot_at
from repro.core.topology import (FaultSet, SCALE_UP_PORTS, TOPOLOGIES)
from repro.core.workload import ServingPoint

CFG = get_arch("deepseek-v3")
SC = Scenario(40.0, 512)
CLUSTERS = {t: make_cluster(t, 64, H100) for t in TOPOLOGIES}

faultsets = st.builds(
    FaultSet,
    mesh_links=st.tuples(st.integers(0, 4), st.integers(0, 4),
                         st.integers(0, 4)),
    switch_planes=st.integers(0, SCALE_UP_PORTS),
    nics=st.integers(0, 8),
    xpus=st.integers(0, 8),
)


def _times(cl, m_bytes, tp, pp):
    return (cl.a2a_time(m_bytes, tp=tp, pp=pp),
            cl.ar_time(m_bytes, tp=tp, pp=pp),
            cl.pp_hop_time(m_bytes, pp=max(pp, 2), tp=tp))


@given(topo=st.sampled_from(TOPOLOGIES), fs=faultsets,
       m_bytes=st.floats(1e3, 1e9), tp=st.sampled_from((1, 2, 4)),
       pp=st.sampled_from((1, 2)))
@settings(max_examples=150, deadline=None)
def test_faults_never_speed_up_collectives(topo, fs, m_bytes, tp, pp):
    cl = CLUSTERS[topo]
    healthy = _times(cl, m_bytes, tp, pp)
    faulted = _times(cl.with_faults(fs), m_bytes, tp, pp)
    for name, t0, t1 in zip(("a2a", "ar", "pp_hop"), healthy, faulted):
        assert t1 >= t0 * (1 - 1e-12), (topo, name, fs)


@given(topo=st.sampled_from(TOPOLOGIES), fs=faultsets,
       extra=st.sampled_from(("link0", "link1", "plane", "nic")),
       m_bytes=st.floats(1e3, 1e8))
@settings(max_examples=100, deadline=None)
def test_fault_chain_monotone(topo, fs, extra, m_bytes):
    """One more fault on an already-degraded fabric never helps."""
    links = list(fs.mesh_links)
    if extra == "link0":
        links[0] += 1
    elif extra == "link1":
        links[1] += 1
    fs2 = FaultSet(
        mesh_links=tuple(links),
        switch_planes=fs.switch_planes + (extra == "plane"),
        nics=fs.nics + (extra == "nic"), xpus=fs.xpus)
    cl = CLUSTERS[topo]
    t1 = _times(cl.with_faults(fs), m_bytes, 1, 1)
    t2 = _times(cl.with_faults(fs2), m_bytes, 1, 1)
    assert all(b >= a * (1 - 1e-12) for a, b in zip(t1, t2)), (topo, fs,
                                                              fs2)


@given(topo=st.sampled_from(TOPOLOGIES),
       links=st.integers(0, 3), planes=st.integers(0, 4))
@settings(max_examples=12, deadline=None)
def test_searched_point_never_improves_under_faults(topo, links, planes):
    """Fabric faults never decrease the searched TPOT (evaluated at the
    healthy winner's batch) nor increase the searched throughput."""
    fs = FaultSet(mesh_links=(links, 0, 0), switch_planes=planes)
    cl = CLUSTERS[topo]
    healthy = solve(CFG, cl, SC, SearchSpec(tp=1, pp=1)).point
    faulted = solve(CFG, cl.with_faults(fs), SC,
                    SearchSpec(tp=1, pp=1)).point
    assert healthy is not None
    if faulted is None:         # SLO now unreachable: degraded, fine
        return
    assert faulted.throughput <= healthy.throughput * (1 + 1e-12)
    p = ServingPoint(batch_global=healthy.batch, context=SC.context,
                     tp=1, ep=cl.n_xpus)
    t_h, *_ = tpot_at(CFG, p, cl, dbo=False, sd=None)
    t_f, *_ = tpot_at(CFG, p, cl.with_faults(fs), dbo=False, sd=None)
    assert t_f >= t_h * (1 - 1e-12)
