"""DBO three-lane scheduler invariants + paper-mechanics checks (Fig 5/6).

The (max,+) schedule runs on three lanes — compute, comm (collectives),
sendrecv (pp hops) — so pipeline hops overlap BOTH compute and
collectives. Checked here: lane semantics, monotonicity in every duration
(no Graham anomalies), the dbo_tpot edge cases, and that the two-lane
behavior is unchanged when the sendrecv lane is empty.

The hypothesis property test lives in test_overlap_props.py behind
pytest.importorskip, so a missing `hypothesis` degrades to a skip instead of
killing collection."""
import numpy as np
import pytest

from repro.core.compute_model import Op
from repro.core.overlap import (LANES, TimedOp, dbo_best, dbo_tpot,
                                simulate_lanes, to_timed)
from repro.core.workload import op_lane


def mk(names_lanes_durs, mb):
    return [TimedOp(n, l, d, mb) for n, l, d in names_lanes_durs]


def test_perfect_overlap():
    """compute(1) | comm(1) alternating across two microbatches overlaps
    fully: makespan == compute_busy + one leading comm... actually with
    two lanes the steady state hides all comm except pipeline edges."""
    ops = [("c0", "compute", 1.0), ("m0", "comm", 1.0),
           ("c1", "compute", 1.0), ("m1", "comm", 1.0)]
    res = simulate_lanes(mk(ops, 0), mk(ops, 1))
    # serial would be 8.0; two-lane must do strictly better
    assert res.makespan < 8.0
    assert res.exposed_comm < 4.0


def test_comm_bound_exposes():
    """When comm is much longer than compute, ECT is positive."""
    ops = [("c", "compute", 1.0), ("m", "comm", 10.0)]
    res = simulate_lanes(mk(ops, 0), mk(ops, 1))
    assert res.exposed_comm > 0
    assert res.makespan >= 20.0          # comm lane serializes 2 x 10


def test_compute_bound_hides_all():
    """Long compute, short comm, repeated layers: ECT ~ 0 plus edges."""
    ops = [(f"c{i}", "compute", 5.0) if i % 2 == 0 else (f"m{i}", "comm", 0.5)
           for i in range(20)]
    res = simulate_lanes(mk(ops, 0), mk(ops, 1))
    assert res.exposed_comm <= 0.5 + 1e-9    # at most the trailing comm op


def test_empty_streams():
    res = simulate_lanes([], [])
    assert res.makespan == 0.0


# ---------------------------------------------------------------------------
# three-lane semantics
# ---------------------------------------------------------------------------

def test_op_lane_tagging():
    """`pp_sendrecv` rides the dedicated lane; collectives share comm."""
    assert op_lane("compute") == "compute"
    assert op_lane("a2a") == "comm"
    assert op_lane("ar") == "comm"
    assert op_lane("pp_sendrecv") == "sendrecv"
    assert LANES == ("compute", "comm", "sendrecv")


def test_pp_hop_overlaps_compute_and_collectives():
    """A pp hop on the sendrecv lane hides under BOTH the other
    microbatch's compute and its collectives: with per-mb chains
    compute(4) -> a2a(4) -> hop(4), the three lanes pipeline and the
    makespan stays well below the 24.0 serial sum — whereas folding the
    hop into the comm lane (the old two-lane model) serializes 4 comm-lane
    ops and cannot beat 16.0."""
    three = [("gemm", "compute", 4.0), ("a2a", "comm", 4.0),
             ("hop", "sendrecv", 4.0)]
    res3 = dbo_best(mk(three, 0), mk(three, 1))
    two = [("gemm", "compute", 4.0), ("a2a", "comm", 4.0),
           ("hop", "comm", 4.0)]
    res2 = dbo_best(mk(two, 0), mk(two, 1))
    assert res3.makespan < res2.makespan
    assert res2.makespan >= 16.0            # comm lane serializes 4 x 4.0
    assert res3.makespan <= 20.0 - 1e-9     # hop rides its own wire
    assert res3.sendrecv_busy == 8.0


def test_sendrecv_lane_serializes_within_itself():
    """Two hops (one per microbatch) still queue on the shared channel."""
    ops = [("hop", "sendrecv", 5.0)]
    res = simulate_lanes(mk(ops, 0), mk(ops, 1))
    assert res.makespan == 10.0
    assert res.sendrecv_busy == 10.0


def test_empty_sendrecv_lane_is_two_lane_schedule():
    """With no sendrecv ops the three-lane schedule IS the two-lane one:
    pinned against hand-computed values of the seed scheduler so the lane
    generalization cannot move decode-path DBO numbers."""
    ops = [("c0", "compute", 1.0), ("m0", "comm", 1.0),
           ("c1", "compute", 1.0), ("m1", "comm", 1.0)]
    res = simulate_lanes(mk(ops, 0), mk(ops, 1), stagger=1)
    # merged order: A fully pipelines with B one op behind; both lanes
    # alternate with no idle gaps after the leading compute
    assert res.makespan == pytest.approx(5.0)
    assert res.compute_busy == pytest.approx(4.0)
    assert res.comm_busy == pytest.approx(4.0)
    assert res.sendrecv_busy == 0.0


# ---------------------------------------------------------------------------
# monotonicity: no Graham anomalies
# ---------------------------------------------------------------------------

def test_makespan_monotone_in_every_duration():
    """Growing ANY single op's duration can never shrink the best-stagger
    makespan — the property that keeps topology comparisons sound (a
    faster network must never look slower)."""
    rng = np.random.default_rng(11)
    for _ in range(20):
        n = int(rng.integers(1, 12))
        lanes = [LANES[i] for i in rng.integers(0, len(LANES), size=n)]
        durs = rng.uniform(0.01, 5.0, size=n)

        def best(d):
            a = [TimedOp(f"o{i}", lanes[i], float(d[i]), 0)
                 for i in range(n)]
            b = [TimedOp(f"o{i}", lanes[i], float(d[i]), 1)
                 for i in range(n)]
            return dbo_best(a, b).makespan

        base = best(durs)
        k = int(rng.integers(0, n))
        bumped = durs.copy()
        bumped[k] += rng.uniform(0.01, 2.0)
        assert best(bumped) >= base - 1e-12, (lanes, durs, k)


# ---------------------------------------------------------------------------
# dbo_tpot edge cases
# ---------------------------------------------------------------------------

def _unit_timers():
    return (lambda o: 1.0), (lambda o: 2.0)


def test_dbo_tpot_empty_op_list():
    t_comp, t_comm = _unit_timers()
    makespan, exposed = dbo_tpot([], t_comp, t_comm)
    assert makespan == 0.0
    assert exposed == 0.0


def test_dbo_tpot_single_op():
    """One op per microbatch: exactly one schedule exists (the stagger
    loop is skipped); the lone lane serializes the two microbatches."""
    t_comp, t_comm = _unit_timers()
    ops = [Op(name="gemm", kind="compute", flops=1.0)]
    makespan, exposed = dbo_tpot(ops, t_comp, t_comm)
    assert makespan == pytest.approx(2.0)
    assert exposed == 0.0
    ops = [Op(name="a2a", kind="a2a", m_bytes=1.0)]
    makespan, exposed = dbo_tpot(ops, t_comp, t_comm)
    assert makespan == pytest.approx(4.0)
    assert exposed == pytest.approx(4.0)


def test_to_timed_routes_pp_hops_to_sendrecv():
    ops = [Op(name="gemm", kind="compute", flops=1.0),
           Op(name="a2a", kind="a2a", m_bytes=1.0),
           Op(name="hop", kind="pp_sendrecv", m_bytes=1.0)]
    timed = to_timed(ops, *_unit_timers(), mb=0)
    assert [t.lane for t in timed] == ["compute", "comm", "sendrecv"]
