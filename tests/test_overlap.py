"""DBO two-lane scheduler invariants + paper-mechanics checks (Fig 5/6)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.overlap import ScheduleResult, TimedOp, simulate_two_lane


def mk(names_lanes_durs, mb):
    return [TimedOp(n, l, d, mb) for n, l, d in names_lanes_durs]


def test_perfect_overlap():
    """compute(1) | comm(1) alternating across two microbatches overlaps
    fully: makespan == compute_busy + one leading comm... actually with
    two lanes the steady state hides all comm except pipeline edges."""
    ops = [("c0", "compute", 1.0), ("m0", "comm", 1.0),
           ("c1", "compute", 1.0), ("m1", "comm", 1.0)]
    res = simulate_two_lane(mk(ops, 0), mk(ops, 1))
    # serial would be 8.0; two-lane must do strictly better
    assert res.makespan < 8.0
    assert res.exposed_comm < 4.0


def test_comm_bound_exposes():
    """When comm is much longer than compute, ECT is positive."""
    ops = [("c", "compute", 1.0), ("m", "comm", 10.0)]
    res = simulate_two_lane(mk(ops, 0), mk(ops, 1))
    assert res.exposed_comm > 0
    assert res.makespan >= 20.0          # comm lane serializes 2 x 10


def test_compute_bound_hides_all():
    """Long compute, short comm, repeated layers: ECT ~ 0 plus edges."""
    ops = [(f"c{i}", "compute", 5.0) if i % 2 == 0 else (f"m{i}", "comm", 0.5)
           for i in range(20)]
    res = simulate_two_lane(mk(ops, 0), mk(ops, 1))
    assert res.exposed_comm <= 0.5 + 1e-9    # at most the trailing comm op


def test_empty_streams():
    res = simulate_two_lane([], [])
    assert res.makespan == 0.0


@given(st.lists(st.tuples(st.sampled_from(["compute", "comm"]),
                          st.floats(0.001, 10.0)), min_size=1, max_size=30))
@settings(max_examples=200, deadline=None)
def test_schedule_invariants(ops):
    """Property: makespan >= max(lane busy times); >= each stream's total;
    <= the fully-serial sum of both streams; within-stream order
    preserved."""
    a = [TimedOp(f"a{i}", l, d, 0) for i, (l, d) in enumerate(ops)]
    b = [TimedOp(f"b{i}", l, d, 1) for i, (l, d) in enumerate(ops)]
    res = simulate_two_lane(a, b)
    stream_total = sum(d for _, d in ops)
    assert res.makespan >= res.compute_busy - 1e-9
    assert res.makespan >= res.comm_busy - 1e-9
    assert res.makespan >= stream_total - 1e-9
    assert res.makespan <= 2 * stream_total + 1e-9
    # per-microbatch op order is preserved
    for mb in (0, 1):
        ends = [e for (_, m, s, e) in res.timeline if m == mb]
        starts = [s for (_, m, s, e) in res.timeline if m == mb]
        for i in range(1, len(ends)):
            assert starts[i] >= ends[i - 1] - 1e-9
    # lanes never run two ops at once
    for lane in ("compute", "comm"):
        lane_ops = sorted(
            [(s, e) for (n, m, s, e) in res.timeline
             for op in [next(o for o in (a + b)
                             if o.name == n and o.mb == m)]
             if op.lane == lane])
        for (s1, e1), (s2, e2) in zip(lane_ops, lane_ops[1:]):
            assert s2 >= e1 - 1e-9
