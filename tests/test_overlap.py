"""DBO two-lane scheduler invariants + paper-mechanics checks (Fig 5/6).

The hypothesis property test lives in test_overlap_props.py behind
pytest.importorskip, so a missing `hypothesis` degrades to a skip instead of
killing collection."""
import pytest

from repro.core.overlap import TimedOp, simulate_two_lane


def mk(names_lanes_durs, mb):
    return [TimedOp(n, l, d, mb) for n, l, d in names_lanes_durs]


def test_perfect_overlap():
    """compute(1) | comm(1) alternating across two microbatches overlaps
    fully: makespan == compute_busy + one leading comm... actually with
    two lanes the steady state hides all comm except pipeline edges."""
    ops = [("c0", "compute", 1.0), ("m0", "comm", 1.0),
           ("c1", "compute", 1.0), ("m1", "comm", 1.0)]
    res = simulate_two_lane(mk(ops, 0), mk(ops, 1))
    # serial would be 8.0; two-lane must do strictly better
    assert res.makespan < 8.0
    assert res.exposed_comm < 4.0


def test_comm_bound_exposes():
    """When comm is much longer than compute, ECT is positive."""
    ops = [("c", "compute", 1.0), ("m", "comm", 10.0)]
    res = simulate_two_lane(mk(ops, 0), mk(ops, 1))
    assert res.exposed_comm > 0
    assert res.makespan >= 20.0          # comm lane serializes 2 x 10


def test_compute_bound_hides_all():
    """Long compute, short comm, repeated layers: ECT ~ 0 plus edges."""
    ops = [(f"c{i}", "compute", 5.0) if i % 2 == 0 else (f"m{i}", "comm", 0.5)
           for i in range(20)]
    res = simulate_two_lane(mk(ops, 0), mk(ops, 1))
    assert res.exposed_comm <= 0.5 + 1e-9    # at most the trailing comm op


def test_empty_streams():
    res = simulate_two_lane([], [])
    assert res.makespan == 0.0
