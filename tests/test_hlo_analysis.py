"""HLO parsers used by the roofline: collective bytes (incl. tuple-result
collectives) and the in-place DUS correction."""
from repro.analysis.hlo import (collective_bytes, dus_overcount_bytes,
                                op_bytes_profile, parse_shapes)

SAMPLE = """
  %all-to-all = (f32[4,2,2048]{2,1,0}, f32[4,2,2048]{2,1,0}, /*index=5*/f32[4,2,2048]{2,1,0}) all-to-all(%a, %b, %c), dimensions={0}
  %x = bf16[16,2048,512]{2,1,0} all-gather(%p), channel_id=3
  %ag.s = bf16[8,16]{1,0} all-gather-start(%q), channel_id=4
  %ag.d = bf16[8,16]{1,0} all-gather-done(%ag.s)
  %ar = f32[100]{0} all-reduce(%z), to_apply=%sum
  %cp = bf16[32]{0} collective-permute(%w), source_target_pairs={{0,1}}
"""


def test_tuple_result_all_to_all_counted():
    r = collective_bytes(SAMPLE)
    assert r["all-to-all_bytes"] == 3 * 4 * 2 * 2048 * 4
    assert r["all-to-all_count"] == 1


def test_start_counted_done_skipped():
    r = collective_bytes(SAMPLE)
    assert r["all-gather_count"] == 2           # plain + -start, not -done
    assert r["all-gather_bytes"] == 16 * 2048 * 512 * 2 + 8 * 16 * 2


def test_ssa_name_not_confused_with_opcode():
    """'%all-to-all = ...' (value NAME) must not trigger a false count for
    a non-collective op."""
    r = collective_bytes("  %all-to-all.5 = f32[8]{0} add(%a, %b)\n")
    assert r["total_bytes"] == 0


def test_all_kinds_present():
    r = collective_bytes(SAMPLE)
    assert r["all-reduce_bytes"] == 400
    assert r["collective-permute_bytes"] == 64


def test_dus_overcount():
    hlo = """
  %u = bf16[1,4]{1,0} parameter(1)
  %t = bf16[100,4]{1,0} parameter(0)
  %d = bf16[100,4]{1,0} dynamic-update-slice(%t, %u, %i, %j)
"""
    # 2 * (target - update) = 2 * (800 - 8)
    assert dus_overcount_bytes(hlo) == 2 * (100 * 4 * 2 - 1 * 4 * 2)


def test_parse_shapes_and_profile():
    sizes = parse_shapes(SAMPLE)
    assert sizes["x"] == 16 * 2048 * 512 * 2
    prof = op_bytes_profile("ENTRY %main {\n" + SAMPLE + "\n}")
    assert prof["_total"] > 0
