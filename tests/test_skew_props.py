"""Hypothesis property tests for the skewed-routing cost model.

Kept separate from test_skew.py so a missing `hypothesis` (an optional
[dev] dependency) skips this module instead of erroring the whole suite at
collection. test_skew.py carries deterministic grid versions of the same
properties for environments without hypothesis.
"""
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.configs import get_arch
from repro.core import H100, Scenario, make_cluster, optimizer, placement
from repro.core.workload import ServingPoint

CFG = get_arch("deepseek-v3")
CLUSTERS = {t: make_cluster(t, 64, H100)
            for t in ("scale-up", "scale-out", "torus", "fullmesh")}


def _tpot(cl, sc, b):
    p = ServingPoint(batch_global=b, context=sc.context, tp=1, ep=64,
                     n_devices=64, dtype="fp8",
                     moe_load=placement.point_factors(CFG, sc, 64))
    return optimizer.tpot_at(CFG, p, cl, dbo=False, sd=None)[0]


@given(topo=st.sampled_from(sorted(CLUSTERS)),
       s=st.floats(0.0, 2.0),
       seed=st.integers(0, 31),
       b=st.integers(1, 1024))
@settings(max_examples=60, deadline=None)
def test_skewed_tpot_dominates_uniform(topo, s, seed, b):
    """Property: skewed TPOT >= uniform TPOT on every topology — load
    factors are >= 1 and every duration/schedule map is monotone."""
    cl = CLUSTERS[topo]
    sc = Scenario(40.0, 4096, routing="zipf", zipf_s=s, routing_seed=seed)
    assert _tpot(cl, sc, b) >= _tpot(cl, Scenario(40.0, 4096), b) - 1e-15


@given(s=st.floats(0.1, 2.0), seed=st.integers(0, 31),
       r=st.integers(1, 16))
@settings(max_examples=60, deadline=None)
def test_load_factors_bounds(s, seed, r):
    """Property: per-layer load factors are >= 1 always, and replication
    never makes the worst layer worse than the unreplicated baseline."""
    sc = Scenario(40.0, 4096, routing="zipf", zipf_s=s, routing_seed=seed)
    base = placement.layer_load_factors(CFG, sc, 64)
    rep = placement.layer_load_factors(CFG, sc, 64, extra_slots=r)
    assert all(f >= 1.0 for f in base)
    assert all(f >= 1.0 for f in rep)
    assert max(rep) <= max(base) + 1e-12
