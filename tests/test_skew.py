"""Skewed-expert routing + replication/placement validation.

Four layers of guarantee, mirroring how the skew axis is built:

  1. `core.placement` unit behavior: Zipf draws are deterministic, load
     factors are >= 1, monotone in s (the per-layer permutation depends
     only on (seed, layer), never s), and replication flattens them;
  2. routing="uniform" (and placement="auto" on uniform scenarios) is
     BYTE-IDENTICAL to the seed — equal OperatingPoints, unchanged
     Scenario names, `op_load_factors` returning None (the structural
     fast path);
  3. batched-vs-scalar parity under skew: NumPy and JAX backends both
     match `optimizer.tpot_at` with `ServingPoint.moe_load` to 1e-9
     relative on all four Table-3 topologies, with and without replicas;
  4. the two theorem-shaped claims fig_skew asserts: skew never improves
     throughput (load factors >= 1 scale durations up, and the (max,+)
     schedule is monotone), and placement="auto" never loses (R=0-first
     strict merge).
"""
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import (H100, Scenario, SearchSpec, make_cluster,
                        solve)
from repro.core import optable, optimizer, placement, sweep, workload
from repro.core.workload import ServingPoint

TOPOS = ("scale-up", "scale-out", "torus", "fullmesh")
CFG = get_arch("deepseek-v3")
N = 64


def _skewed(s, seed=0, tpot=40.0, ctx=4096):
    return Scenario(tpot, ctx, routing="zipf", zipf_s=s, routing_seed=seed)


# ---------------------------------------------------------------------------
# 1. placement unit behavior
# ---------------------------------------------------------------------------

def test_zipf_probs_distribution():
    p = placement.zipf_probs(256, 1.0, seed=0, layer=3)
    assert p.shape == (256,)
    assert abs(p.sum() - 1.0) < 1e-12
    assert (p > 0).all()
    # uniform at s <= 0
    u = placement.zipf_probs(256, 0.0, seed=0, layer=3)
    assert np.allclose(u, 1.0 / 256)
    # deterministic across calls
    assert np.array_equal(p, placement.zipf_probs(256, 1.0, 0, 3))
    # the hot-expert IDENTITY depends only on (seed, layer), not s
    hot_06 = int(placement.zipf_probs(256, 0.6, 0, 3).argmax())
    hot_14 = int(placement.zipf_probs(256, 1.4, 0, 3).argmax())
    assert hot_06 == hot_14 == int(p.argmax())
    # different layers / seeds permute differently
    assert not np.array_equal(p, placement.zipf_probs(256, 1.0, 0, 4))
    assert not np.array_equal(p, placement.zipf_probs(256, 1.0, 7, 3))


def test_layer_load_factors_bounds_and_monotonicity():
    prev = None
    for s in (0.0, 0.3, 0.6, 1.0, 1.4):
        fac = placement.layer_load_factors(CFG, _skewed(s), ep=64)
        assert len(fac) == sum(1 for sp in CFG.layer_specs
                               if sp.ffn == "moe")
        assert all(f >= 1.0 for f in fac)
        if s == 0.0:
            assert all(f == 1.0 for f in fac)
        if prev is not None:
            # same seed => same hot experts => factors monotone in s
            assert all(a <= b + 1e-12 for a, b in zip(prev, fac))
        prev = fac


def test_replication_flattens_load():
    sc = _skewed(1.0)
    base = placement.layer_load_factors(CFG, sc, ep=64)
    for r in (1, 2, 8):
        rep = placement.layer_load_factors(CFG, sc, ep=64, extra_slots=r)
        assert all(b >= 1.0 for b in rep)
        assert max(rep) < max(base)
    # replica slots on every rank can host the full Zipf head: near-flat
    assert max(placement.layer_load_factors(CFG, sc, 64, 8)) < 1.01


def test_replica_counts_and_placement_invariants():
    probs = placement.zipf_probs(256, 1.0, 0, 0)
    counts = placement.replica_counts(probs, ep=64, extra_slots=2)
    assert counts.sum() == 256 + 64 * 2
    assert counts.min() >= 1 and counts.max() <= 64
    loads = placement.place_instances(probs, counts, ep=64, cap=4 + 2)
    assert abs(loads.sum() - 1.0) < 1e-12
    assert loads.max() <= 1.0


def test_point_factors_and_hosting():
    assert placement.point_factors(CFG, Scenario(40.0, 4096), 64) == ()
    fac = placement.point_factors(CFG, _skewed(1.0), 64)
    assert fac == placement.layer_load_factors(CFG, _skewed(1.0), 64)
    assert placement.hosting_factor(CFG, 64, 0) == 1.0
    assert placement.hosting_factor(CFG, 64, 4) == 2.0  # (4+4)/4


# ---------------------------------------------------------------------------
# 2. uniform stays byte-identical
# ---------------------------------------------------------------------------

def test_uniform_scenario_name_and_fast_path():
    sc = Scenario(15.0, 4096)
    assert sc.name == "tpot15ms_ctx4096"          # seed name unchanged
    assert not sc.is_skewed
    assert not Scenario(15.0, 4096, routing="zipf").is_skewed  # s=0
    table = optable.op_table(CFG, 1, 64, N)
    assert sweep.op_load_factors(table, CFG, [sc]) is None
    with pytest.raises(ValueError):
        Scenario(15.0, 4096, routing="hot")
    with pytest.raises(ValueError):
        Scenario(15.0, 4096, zipf_s=-1.0)


@pytest.mark.parametrize("topo", TOPOS)
def test_uniform_sweep_and_auto_placement_byte_identical(topo):
    cl = make_cluster(topo, N, H100)
    sc = Scenario(40.0, 4096)
    ref = solve(CFG, cl, sc, SearchSpec(dbo=True)).point
    assert ref is not None
    assert ref == solve(CFG, cl, sc, SearchSpec(dbo=True,
                                                placement="auto")).point
    assert ref.extra_experts == 0
    got = sweep.sweep_max_throughput([cl], CFG, [sc], dbo=True,
                                     placement="auto")[0][0]
    assert got == ref


def test_moe_load_defaults_are_exact_noops():
    p = ServingPoint(batch_global=128, context=4096, tp=1, ep=64,
                     n_devices=N, dtype="fp8")
    ones = tuple(1.0 for _ in placement.layer_load_factors(
        CFG, _skewed(1.0), 64))
    p1 = ServingPoint(batch_global=128, context=4096, tp=1, ep=64,
                      n_devices=N, dtype="fp8", moe_load=ones)
    cl = make_cluster("torus", N, H100)
    assert optimizer.tpot_at(CFG, p, cl, dbo=True, sd=None) == \
        optimizer.tpot_at(CFG, p1, cl, dbo=True, sd=None)


# ---------------------------------------------------------------------------
# 3. batched vs scalar parity under skew (numpy AND jax, 1e-9)
# ---------------------------------------------------------------------------

def _scalar_tpot(cl, sc, b, extra=0, dbo=True):
    p = ServingPoint(batch_global=b, context=sc.context, tp=1, ep=64,
                     n_devices=N, dtype="fp8",
                     moe_load=placement.point_factors(CFG, sc, 64, extra),
                     moe_extra=extra)
    return optimizer.tpot_at(CFG, p, cl, dbo=dbo, sd=None)[0]


@pytest.mark.parametrize("topo", TOPOS)
@pytest.mark.parametrize("backend", ("numpy", "jax"))
def test_skewed_tpot_parity(topo, backend):
    if backend == "jax":
        pytest.importorskip("jax")
    cl = make_cluster(topo, N, H100)
    scens = [Scenario(40.0, 4096), _skewed(0.6), _skewed(1.0, seed=3)]
    batches = np.array([1, 16, 128, 512], np.int64)
    table = optable.op_table(CFG, 1, 64, N)
    load = sweep.op_load_factors(table, CFG, scens)
    ev = sweep.GridEval(table, [cl], scens, batches, backend=backend,
                        load=load)
    for dbo in (False, True):
        got = ev.tpot(dbo=dbo)
        for si, sc in enumerate(scens):
            for bi, b in enumerate(batches):
                ref = _scalar_tpot(cl, sc, int(b), dbo=dbo)
                assert got[0, si, bi] == pytest.approx(ref, rel=1e-9)


@pytest.mark.parametrize("backend", ("numpy", "jax"))
def test_skewed_tpot_parity_with_replicas(backend):
    if backend == "jax":
        pytest.importorskip("jax")
    cl = make_cluster("fullmesh", N, H100)
    scens = [_skewed(1.0)]
    batches = np.array([8, 256], np.int64)
    table = optable.op_table(CFG, 1, 64, N)
    load = sweep.op_load_factors(table, CFG, scens, extra_slots=2)
    ev = sweep.GridEval(table, [cl], scens, batches, backend=backend,
                        load=load)
    got = ev.tpot(dbo=True)
    for bi, b in enumerate(batches):
        ref = _scalar_tpot(cl, scens[0], int(b), extra=2)
        assert got[0, 0, bi] == pytest.approx(ref, rel=1e-9)


def test_skewed_sweep_winner_matches_scalar_search():
    cl = make_cluster("torus", N, H100)
    sc = _skewed(0.6, tpot=40.0)
    got = sweep.sweep_max_throughput([cl], CFG, [sc], dbo=True)[0][0]
    ref = optimizer.max_throughput_scalar(cl, CFG, sc, dbo=True)
    assert got == ref


def test_skewed_chunked_prefill_parity():
    cl = make_cluster("torus", N, H100)
    sc = Scenario(40.0, 4096, prompt_len=2048, ttft_ms=2000.0,
                  routing="zipf", zipf_s=0.6)
    table = optable.op_table(CFG, 1, 64, N)
    ptable = optable.prefill_op_table(CFG, 1, 64, N)
    batches = np.array([64], np.int64)
    tpot_b, ttft_b = sweep.batched_chunked_tpot_ttft(
        table, ptable, [cl], batches, sc, chunk=512, dbo=True, cfg=CFG)
    p = ServingPoint(batch_global=64, context=sc.context, tp=1, ep=64,
                     n_devices=N, dtype="fp8",
                     moe_load=placement.point_factors(CFG, sc, 64))
    tpot_s, ttft_s, *_ = optimizer.chunked_prefill_components(
        CFG, p, cl, sc, 512, dbo=True)
    assert tpot_b[0, 0] == pytest.approx(tpot_s, rel=1e-9)
    assert ttft_b[0, 0] == pytest.approx(ttft_s, rel=1e-9)


def test_moe_layer_column():
    table = optable.op_table(CFG, 1, 64, N)
    n_moe = sum(1 for sp in CFG.layer_specs if sp.ffn == "moe")
    marked = table.moe_layer[table.moe_layer >= 0]
    assert table.moe_layer.max() == n_moe - 1
    # exactly the dispatch / expert GEMM / gather triple per MoE layer
    assert len(marked) == 3 * n_moe
    names = np.asarray(table.names)
    suffixes = {nm.rsplit(".", 1)[-1] for nm in names[table.moe_layer >= 0]}
    assert suffixes == set(workload.SKEW_SCALED_OPS)
    # dense model: all -1
    dense = optable.op_table(get_arch("starcoder2-3b"), 1, 1, N)
    assert (dense.moe_layer == -1).all()


# ---------------------------------------------------------------------------
# 4. the fig_skew claims, theorem-shaped
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo", TOPOS)
def test_skew_never_improves_tpot(topo):
    """Load factors >= 1 scale per-op durations up; the (max,+) schedule
    and the min-over-staggers are monotone, so skewed TPOT >= uniform
    TPOT at every (batch, dbo) point. Deterministic grid version of the
    hypothesis property in test_skew_props.py."""
    cl = make_cluster(topo, N, H100)
    for s, seed in ((0.3, 0), (0.6, 1), (1.0, 2), (1.4, 3)):
        sc = _skewed(s, seed=seed)
        for b in (1, 32, 512):
            for dbo in (False, True):
                assert _scalar_tpot(cl, sc, b, dbo=dbo) >= \
                    _scalar_tpot(cl, Scenario(40.0, 4096), b, dbo=dbo) \
                    - 1e-15


@pytest.mark.parametrize("topo", TOPOS)
def test_placement_never_loses(topo):
    cl = make_cluster(topo, N, H100)
    scens = [Scenario(40.0, 4096), _skewed(0.6), _skewed(1.0)]
    base = sweep.best_of_opts_grid([cl], CFG, scens, "dbo+sd")
    auto = sweep.best_of_opts_grid([cl], CFG, scens, "dbo+sd",
                                   placement="auto")
    for si in range(len(scens)):
        b, a = base[0][si], auto[0][si]
        thr_b = b.throughput if b else 0.0
        thr_a = a.throughput if a else 0.0
        assert thr_a >= thr_b
        if si == 0:        # uniform cell keeps the byte-identical R=0 arm
            assert a == b


def test_degraded_search_honors_skew():
    """The failure-aware re-search routes through `_sweep_fixed`, so a
    skewed scenario is priced there with no extra plumbing."""
    cl = make_cluster("torus", N, H100)
    u = sweep.degraded_max_throughput(cl, CFG, Scenario(40.0, 4096),
                                      faults=None, tp=1)
    s = sweep.degraded_max_throughput(cl, CFG, _skewed(1.0), faults=None,
                                      tp=1)
    assert s is None or u is None or s.throughput <= u.throughput


def test_extra_slots_charges_hbm():
    bytes0 = workload.model_shard_bytes(CFG, 1, 64, "fp8", 1)
    bytes8 = workload.model_shard_bytes(CFG, 1, 64, "fp8", 1,
                                        extra_experts=8)
    assert bytes8 > bytes0
    n_moe = sum(1 for sp in CFG.layer_specs if sp.ffn == "moe")
    w_expert = 3 * CFG.d_model * CFG.moe.d_expert
    assert bytes8 - bytes0 == pytest.approx(n_moe * 8 * w_expert * 1.0)
