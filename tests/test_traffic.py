"""Cluster-scale traffic simulator (`repro.core.traffic`).

Guarantee layers:

  1. trace generation — spec validation, seeded determinism, rate/shape
     statistics, and the time-warp invariant (scaling the offered rate
     compresses the SAME unit arrival stream, the property the load-sweep
     monotonicity claims stand on);
  2. catalog construction — every entry comes from `api.solve` with its
     `api.tpot_curve` clock, pool sizes ascend, misuse fails loudly;
  3. simulation invariants — zero-arrival and zero-fault edges,
     bit-identical determinism, Little's law on the recorded occupancy
     integral, attainment monotone non-increasing in offered load;
  4. provisioning and faults — autoscaling parks capacity and never loses
     through `best_provisioning`; fault events spike the TTFT tail and
     never add goodput; `fleet_cost` bills the XPU share by active
     fraction while the fabric stays a fixed cost.

Everything runs olmoe-1b-7b on 8 XPUs (the fig_traffic configuration,
shrunk horizons) — small enough that the whole file is seconds, large
enough that traces are thousands of requests.
"""
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import H100, Scenario, SearchSpec, make_cluster, traffic
from repro.core import api
from repro.core.tco import cluster_tco
from repro.core.topology import FaultSet

CFG = get_arch("olmoe-1b-7b")
CL = make_cluster("torus", 8, H100)
# TPOT tight enough that the searched cap binds the SLO + an explicit
# TTFT SLO so queueing delay costs attainment (the cliff precondition)
SC = Scenario(15.0, 512, ttft_ms=500.0)
MIX = ((0.75, 0, 256), (0.25, 384, 512))


@pytest.fixture(scope="module")
def catalog():
    return traffic.build_catalog(CFG, CL, SC, SearchSpec(),
                                 pool_fracs=(0.25, 0.5, 1.0), mix=MIX)


@pytest.fixture(scope="module")
def cap_rps(catalog):
    return catalog.capacity_rps(catalog.full,
                                traffic.TraceSpec(1.0, 1.0,
                                                  length_mix=MIX).mean_gen)


def _trace(cap, load, horizon=60.0, seed=7, **kw):
    return traffic.generate_trace(traffic.TraceSpec(
        horizon_s=horizon, rate_rps=cap * load, length_mix=MIX,
        seed=seed, **kw))


# ---------------------------------------------------------------------------
# 1. trace generation
# ---------------------------------------------------------------------------

def test_trace_spec_validation():
    with pytest.raises(ValueError, match="horizon"):
        traffic.TraceSpec(horizon_s=0.0, rate_rps=1.0)
    with pytest.raises(ValueError, match="rate"):
        traffic.TraceSpec(horizon_s=1.0, rate_rps=-1.0)
    with pytest.raises(ValueError, match="arrival"):
        traffic.TraceSpec(horizon_s=1.0, rate_rps=1.0, arrival="weibull")
    with pytest.raises(ValueError, match="cv2"):
        traffic.TraceSpec(horizon_s=1.0, rate_rps=1.0, cv2=0.0)
    with pytest.raises(ValueError, match="amplitude"):
        traffic.TraceSpec(horizon_s=1.0, rate_rps=1.0,
                          diurnal_amplitude=1.0)
    with pytest.raises(ValueError, match="length_mix"):
        traffic.TraceSpec(horizon_s=1.0, rate_rps=1.0,
                          length_mix=((1.0, 0, 0),))


def test_trace_seeded_and_statistical():
    spec = traffic.TraceSpec(horizon_s=200.0, rate_rps=50.0, length_mix=MIX,
                             seed=3)
    a, b = traffic.generate_trace(spec), traffic.generate_trace(spec)
    np.testing.assert_array_equal(a.t, b.t)
    np.testing.assert_array_equal(a.prompt, b.prompt)
    np.testing.assert_array_equal(a.gen, b.gen)
    other = traffic.generate_trace(traffic.TraceSpec(
        horizon_s=200.0, rate_rps=50.0, length_mix=MIX, seed=4))
    assert not np.array_equal(a.t, other.t)
    # rate and mixture statistics (10k arrivals)
    assert a.n == pytest.approx(200.0 * 50.0, rel=0.05)
    assert np.all(np.diff(a.t) >= 0) and a.t[-1] < spec.horizon_s
    assert float((a.prompt > 0).mean()) == pytest.approx(0.25, abs=0.03)
    assert spec.mean_gen == pytest.approx(0.75 * 256 + 0.25 * 512)


def test_gamma_burstiness():
    mk = lambda arr, cv2: traffic.generate_trace(traffic.TraceSpec(
        horizon_s=400.0, rate_rps=50.0, arrival=arr, cv2=cv2, seed=5))
    ia_p = np.diff(mk("poisson", 1.0).t)
    ia_g = np.diff(mk("gamma", 4.0).t)
    cv2 = lambda x: float(np.var(x) / np.mean(x) ** 2)
    assert cv2(ia_p) == pytest.approx(1.0, rel=0.15)
    assert cv2(ia_g) == pytest.approx(4.0, rel=0.25)


def test_scaled_load_compresses_same_stream():
    """`spec.scaled(L)` time-compresses the SAME unit arrival sequence —
    the shared-prefix times divide exactly by L (the load-sweep
    monotonicity construction)."""
    spec = traffic.TraceSpec(horizon_s=100.0, rate_rps=20.0, arrival="gamma",
                             cv2=4.0, seed=9)
    t1 = traffic.generate_trace(spec)
    t2 = traffic.generate_trace(spec.scaled(2.0))
    assert t2.n >= t1.n
    np.testing.assert_allclose(t2.t[:t1.n], t1.t / 2.0, rtol=1e-12)


def test_diurnal_time_warp():
    spec = traffic.TraceSpec(horizon_s=600.0, rate_rps=30.0,
                             diurnal_amplitude=0.8, diurnal_period_s=300.0,
                             seed=1)
    tr = traffic.generate_trace(spec)
    assert np.all(np.diff(tr.t) >= 0) and tr.t[-1] <= spec.horizon_s
    # peak half-period (sin > 0) holds more arrivals than the trough
    phase = np.mod(tr.t, 300.0)
    peak = int((phase < 150.0).sum())
    assert peak > 1.5 * (tr.n - peak)


# ---------------------------------------------------------------------------
# 2. catalog construction
# ---------------------------------------------------------------------------

def test_catalog_entries_from_solve(catalog):
    sizes = [e.n_xpus for e in catalog.entries]
    assert sizes == sorted(sizes) and sizes[-1] == CL.n_xpus
    assert len(sizes) == 3
    full = catalog.full
    ref = api.solve(CFG, CL, SC).point
    assert full.point == ref
    assert full.cap == ref.batch and full.tpot.shape == (ref.batch,)
    assert full.tpot[-1] == pytest.approx(ref.tpot, rel=1e-9)
    assert np.all(np.diff(full.tpot) > 0)
    assert full.chunk_time > 0.0          # MIX has a prompt class


def test_catalog_misuse_rejected():
    with pytest.raises(ValueError, match="full pool"):
        traffic.build_catalog(CFG, CL, SC, pool_fracs=(0.5,))
    with pytest.raises(ValueError, match="healthy decode"):
        traffic.build_catalog(CFG, CL, SC,
                              SearchSpec(faults=FaultSet(xpus=1),
                                         tp="auto"))
    with pytest.raises(ValueError, match="healthy decode"):
        traffic.build_catalog(
            CFG, CL, Scenario(15.0, 512, prompt_len=384, ttft_ms=500.0),
            SearchSpec(mode="chunked"))


# ---------------------------------------------------------------------------
# 3. simulation invariants
# ---------------------------------------------------------------------------

def test_zero_arrival_edge(catalog):
    tr = traffic.generate_trace(traffic.TraceSpec(horizon_s=30.0,
                                                  rate_rps=0.0))
    res = traffic.simulate_trace(catalog, tr)
    assert res.n_requests == 0 and res.n_iters == 0
    assert res.attainment == 1.0 and res.goodput_tok_s == 0.0
    assert res.elapsed_s == 30.0 and res.active_frac == 1.0


def test_simulation_deterministic(catalog, cap_rps):
    tr = _trace(cap_rps, 0.8, arrival="gamma", cv2=4.0)
    plan = traffic.seeded_fault_plan(CL, n_iters=catalog.est_iterations(tr),
                                     rate_per_iter=1e-3, seed=2,
                                     repair_s=10.0, downtime_s=2.0)
    pol = traffic.AutoscalePolicy(check_interval_s=10.0, min_dwell_s=30.0,
                                  switch_downtime_s=5.0)
    a = traffic.simulate_trace(catalog, tr, autoscale=pol, faults=plan)
    b = traffic.simulate_trace(catalog, tr, autoscale=pol, faults=plan)
    assert a.as_dict() == b.as_dict()


def test_littles_law(catalog, cap_rps):
    tr = _trace(cap_rps, 0.8)
    res = traffic.simulate_trace(catalog, tr)
    assert res.attainment > 0.9
    # L = lambda * W on the recorded occupancy integral (the integral is
    # piecewise-constant over iterations, so a few percent of slack)
    assert res.mean_in_system == pytest.approx(
        res.arrival_rps * res.mean_sojourn_s, rel=0.05)
    # every request was served and all decode tokens accounted for
    assert res.throughput_tok_s * res.elapsed_s \
        == pytest.approx(float(tr.gen.sum()))


def test_attainment_monotone_and_cliff(catalog, cap_rps):
    loads = (0.6, 0.9, 1.1, 1.3)
    res = [traffic.simulate_trace(
        catalog, _trace(cap_rps, ld, arrival="gamma", cv2=4.0))
        for ld in loads]
    att = [r.attainment for r in res]
    assert all(a + 1e-9 >= b for a, b in zip(att, att[1:]))
    assert att[0] > 0.95                      # plateau below capacity
    assert att[-1] < att[0] - 0.05            # cliff past capacity
    # queueing, not serving, is what collapses: p99 TTFT explodes
    assert res[-1].ttft_p99 > 10 * res[0].ttft_p99


# ---------------------------------------------------------------------------
# 4. provisioning, faults, cost
# ---------------------------------------------------------------------------

def test_autoscale_parks_capacity_and_never_loses(catalog, cap_rps):
    dtr = traffic.generate_trace(traffic.TraceSpec(
        horizon_s=600.0, rate_rps=0.4 * cap_rps, diurnal_amplitude=0.6,
        diurnal_period_s=300.0, length_mix=MIX, seed=13))
    pol = traffic.AutoscalePolicy(check_interval_s=30.0, target_util=0.7,
                                  min_dwell_s=120.0, switch_downtime_s=30.0)
    static = traffic.simulate_trace(catalog, dtr)
    auto = traffic.simulate_trace(catalog, dtr, autoscale=pol)
    assert static.active_frac == 1.0 and static.n_switches == 0
    assert auto.n_switches >= 1 and auto.active_frac < 1.0
    assert auto.cost_month < static.cost_month
    name, best = traffic.best_provisioning(catalog, dtr,
                                           policies=[None, pol])
    assert best.goodput_per_cost >= static.goodput_per_cost
    assert name in ("static", "autoscale@0.7")


def test_faults_spike_ttft_never_add_goodput(catalog, cap_rps):
    tr = _trace(cap_rps, 0.8)
    plan = traffic.seeded_fault_plan(CL, n_iters=catalog.est_iterations(tr),
                                     rate_per_iter=1e-3, seed=2,
                                     repair_s=10.0, downtime_s=2.0)
    assert len(plan.faultsets) >= 1
    # every sampled faultset is non-empty (the injector fired for it)
    for fs in plan.faultsets:
        assert any(fs.mesh_links) or fs.switch_planes or fs.nics or fs.xpus
    healthy = traffic.simulate_trace(catalog, tr)
    faulted = traffic.simulate_trace(catalog, tr, faults=plan)
    assert faulted.n_fault_events >= 1
    assert faulted.ttft_p99 >= healthy.ttft_p99
    assert faulted.goodput_tok_s <= healthy.goodput_tok_s


def test_zero_rate_fault_plan_is_identity(catalog, cap_rps):
    tr = _trace(cap_rps, 0.7)
    plan = traffic.seeded_fault_plan(CL, n_iters=catalog.est_iterations(tr),
                                     rate_per_iter=0.0, seed=0)
    assert len(plan.faultsets) == 0
    base = traffic.simulate_trace(catalog, tr)
    with_plan = traffic.simulate_trace(catalog, tr, faults=plan)
    assert with_plan.n_fault_events == 0
    assert with_plan.as_dict() == base.as_dict()


def test_fleet_cost_bills_xpus_by_active_fraction():
    bd = cluster_tco(CL)
    full = traffic.fleet_cost(CL, 1.0)
    assert full == pytest.approx(bd.monthly_xpu + bd.monthly_energy_xpu
                                 + bd.monthly_switch + bd.monthly_link
                                 + bd.monthly_energy_net)
    parked = traffic.fleet_cost(CL, 0.0)
    assert parked == pytest.approx(bd.monthly_switch + bd.monthly_link)
    assert parked < traffic.fleet_cost(CL, 0.5) < full
    # the network-cost factor scales only the fabric share
    assert traffic.fleet_cost(CL, 1.0, c=0.0) \
        == pytest.approx(bd.monthly_xpu + bd.monthly_energy_xpu)
