"""Training runtime: loop convergence mechanics, checkpoint round-trip +
elastic resharding, fault-tolerant resume, gradient compression, data
pipeline determinism + straggler skip."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced_config
from repro.sharding.dist import NullDist
from repro.training import checkpoint as ckpt
from repro.training import compression
from repro.training.data import DataConfig, DeadlineIterator, SyntheticLM
from repro.training.fault_tolerance import (FailureInjector, WorkerFailure,
                                            run_with_recovery)
from repro.training.train_loop import TrainConfig, Trainer


def small_cfg():
    return reduced_config(get_arch("olmoe-1b-7b"))


def small_data(cfg, batch=4, seq=16, seed=0):
    return SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                                  global_batch=batch, seed=seed))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_seekable():
    cfg = small_cfg()
    d = small_data(cfg)
    b7a, b7b = d.batch(7), d.batch(7)
    assert (b7a == b7b).all()
    assert not (d.batch(7) == d.batch(8)).all()


def test_data_rank_sharding():
    cfg = small_cfg()
    d = small_data(cfg, batch=8)
    full_like = [d.batch(3, rank=r, world=4) for r in range(4)]
    assert all(b.shape == (2, 16) for b in full_like)
    # ranks draw different data
    assert not (full_like[0] == full_like[1]).all()


def test_deadline_iterator_skips_stragglers():
    cfg = small_cfg()
    d = small_data(cfg)

    def produce(step):
        return d.batch(step), (10.0 if step == 2 else 0.0)

    it = DeadlineIterator(d, deadline_s=1.0, produce=produce)
    got = [it.batch(s) for s in range(4)]
    assert got[2] is None and it.skipped == [2]
    assert all(g is not None for i, g in enumerate(got) if i != 2)


# ---------------------------------------------------------------------------
# training loop
# ---------------------------------------------------------------------------

def test_loss_decreases():
    cfg = small_cfg()
    tr = Trainer(cfg, TrainConfig(lr=1e-2, log_every=0))
    data = small_data(cfg)
    losses = tr.run(data, 30, log=lambda s: None)
    early = np.mean(losses[:5])
    late = np.mean(losses[-5:])
    assert late < early - 0.5, (early, late)


def test_grad_accumulation_matches_big_batch():
    """mb=2 over batch 4 == mb=1 over the same batch (same update)."""
    cfg = small_cfg()
    data = small_data(cfg)
    tok = data.batch(0)
    tr1 = Trainer(cfg, TrainConfig(lr=1e-3, microbatches=1, seed=7))
    tr2 = Trainer(cfg, TrainConfig(lr=1e-3, microbatches=2, seed=7))
    l1 = tr1.train_step(tok)
    l2 = tr2.train_step(tok)
    assert l1 == pytest.approx(l2, rel=1e-2)
    for a, b in zip(jax.tree.leaves(tr1.params), jax.tree.leaves(tr2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-3)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "nest": {"b": jnp.ones((5,), jnp.bfloat16)},
            "t": (jnp.zeros((2, 2)), jnp.full((1,), 3, jnp.int32))}
    ckpt.save(tree, str(tmp_path), 5)
    out, step = ckpt.restore(tree, str(tmp_path))
    assert step == 5
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_checkpoint_sharded_files_elastic(tmp_path):
    """Save split into 4 shard files; restore reassembles identically —
    the mesh shape is config, not checkpoint format."""
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    d = ckpt.save(tree, str(tmp_path), 1, n_shards=4)
    files = [f for f in os.listdir(d) if f.startswith("w.shard")]
    assert len(files) == 4
    out, _ = ckpt.restore(tree, str(tmp_path))
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))


def test_checkpoint_atomic_and_prune(tmp_path):
    tree = {"x": jnp.ones(3)}
    for s in (1, 2, 3, 4):
        ckpt.save(tree, str(tmp_path), s)
    ckpt.prune_old(str(tmp_path), keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 4
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]
    # a stale .tmp directory must not confuse latest_step
    os.makedirs(os.path.join(tmp_path, "step_000099.tmp"))
    assert ckpt.latest_step(str(tmp_path)) == 4


def test_trainer_resume_exact(tmp_path):
    """Train 6 steps with ckpt@2; a fresh trainer restored at step 4 and
    run to 6 must produce bit-identical params to the uninterrupted run."""
    cfg = small_cfg()
    data = small_data(cfg)
    tc = TrainConfig(lr=1e-3, ckpt_every=2, ckpt_dir=str(tmp_path),
                     log_every=0, seed=3)
    tr = Trainer(cfg, tc)
    tr.run(data, 6, log=lambda s: None)

    tr2 = Trainer(cfg, tc)
    at = tr2.restore(4)
    assert at == 4
    tr2.run(data, 6, log=lambda s: None)
    for a, b in zip(jax.tree.leaves(tr.params), jax.tree.leaves(tr2.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_recovery_from_injected_failures(tmp_path):
    cfg = small_cfg()
    data = small_data(cfg)
    tc = TrainConfig(lr=1e-3, ckpt_every=2, ckpt_dir=str(tmp_path),
                     log_every=0)
    tr = Trainer(cfg, tc)
    inj = FailureInjector(fail_at=[3, 7])
    rep = run_with_recovery(tr, data, 10, injector=inj)
    assert rep.restarts == 2
    assert rep.completed_steps == 10
    assert len(rep.recovery_log) == 2
    assert inj.fired == [3, 7]


def test_recovery_bounded(tmp_path):
    cfg = small_cfg()
    data = small_data(cfg)
    tc = TrainConfig(lr=1e-3, ckpt_every=100, ckpt_dir=str(tmp_path),
                     log_every=0)
    tr = Trainer(cfg, tc)

    class AlwaysFail(FailureInjector):
        def check(self, step):
            raise WorkerFailure("permafail")

    with pytest.raises(RuntimeError, match="restarts"):
        run_with_recovery(tr, data, 5, injector=AlwaysFail(),
                          max_restarts=3)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (128, 64)), jnp.float32)
    q, s = compression.quantize_int8(x)
    back = compression.dequantize_int8(q, s)
    assert float(jnp.max(jnp.abs(back - x))) <= float(s) / 2 + 1e-6


def test_error_feedback_accumulates():
    """With error feedback, the MEAN of repeated compressed reductions of a
    constant gradient converges to the true value (bias -> residual)."""
    dist = NullDist()
    g = jnp.asarray([[1.37e-3, -4.2e-4], [9.9e-5, 2.2e-3]], jnp.float32)
    err = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for _ in range(64):
        out, err = compression.compressed_psum(g, None, dist, err)
        total = total + out
    np.testing.assert_allclose(np.asarray(total / 64), np.asarray(g),
                               rtol=0.02, atol=1e-6)


def test_compressed_training_still_learns():
    cfg = small_cfg()
    tr = Trainer(cfg, TrainConfig(lr=1e-2, grad_compress=True, log_every=0))
    data = small_data(cfg)
    losses = tr.run(data, 25, log=lambda s: None)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3
