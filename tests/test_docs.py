"""Docs reference integrity: tools/check_docs.py must pass, and must be
able to fail (a deliberately stale reference is caught)."""
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
CHECKER = ROOT / "tools" / "check_docs.py"


def _run():
    return subprocess.run([sys.executable, str(CHECKER)],
                          capture_output=True, text=True, cwd=ROOT)


def test_docs_references_resolve():
    r = _run()
    assert r.returncode == 0, f"stale docs references:\n{r.stderr}"


def test_checker_catches_stale_reference():
    doc = ROOT / "docs" / "architecture.md"
    orig = doc.read_text()
    try:
        doc.write_text(orig + "\n`core/no_such_module.py` and "
                              "`repro.core.sweep.no_such_symbol`\n")
        r = _run()
        assert r.returncode == 1
        assert "no_such_module" in r.stderr
        assert "no_such_symbol" in r.stderr
    finally:
        doc.write_text(orig)


def test_required_docs_exist():
    for name in ("architecture.md", "figures.md", "sweep_engine.md",
                 "failure_model.md"):
        assert (ROOT / "docs" / name).is_file(), name
    assert (ROOT / "README.md").is_file()


def test_figures_catalog_covers_every_benchmark():
    """Every benchmarks/fig_*.py (and table/validation/roofline modules)
    has an entry in docs/figures.md."""
    text = (ROOT / "docs" / "figures.md").read_text()
    for mod in sorted((ROOT / "benchmarks").glob("*.py")):
        if mod.stem in ("run", "common", "check_timing", "__init__"):
            continue
        assert f"`{mod.stem}`" in text, f"docs/figures.md misses {mod.stem}"
