"""TCO model, workload op-lists, and optimizer trend checks against the
paper's first-order rules of thumb (paper section 4.1, Table 4)."""
import dataclasses

import pytest

from repro.configs import get_arch
from repro.core import (H100, BLACKWELL, Scenario, SearchSpec,
                        make_cluster, solve)
from repro.core import tco, workload
from repro.core.specdec import SpecDecConfig, sd_tpot
from repro.core.workload import ServingPoint


@pytest.fixture(scope="module")
def dsv3():
    return get_arch("deepseek-v3")


# a reduced DeepSeek-V3-like config keeps optimizer tests fast
@pytest.fixture(scope="module")
def dsv3_small(dsv3):
    return dsv3.replace(num_layers=8)


# ---------------------------------------------------------------------------
# TCO
# ---------------------------------------------------------------------------

def test_switchless_has_zero_switch_cost():
    for topo in ("torus", "fullmesh"):
        t = tco.cluster_tco(make_cluster(topo, 64, H100))
        assert t.monthly_switch == 0.0


def test_scaleup_network_share():
    """Scale-up network should be a noticeable share of TCO (the premise of
    the whole paper) but not dominate the XPU cost."""
    t = tco.cluster_tco(make_cluster("scale-up", 64, H100))
    share = t.monthly_network / t.total(1.0)
    assert 0.10 < share < 0.45, share


def test_two_level_fat_tree_cost_jump():
    """Past 64 XPUs the scale-up network needs a two-level fat-tree; the
    per-XPU network cost must jump (paper section 4.3.2)."""
    t64 = tco.cluster_tco(make_cluster("scale-up", 64, H100))
    t256 = tco.cluster_tco(make_cluster("scale-up", 256, H100))
    assert t256.monthly_network / 256 > 1.5 * t64.monthly_network / 64


def test_adjustment_factor():
    cl = make_cluster("scale-up", 64, H100)
    t = tco.cluster_tco(cl)
    assert t.total(0.0) < t.total(0.5) < t.total(1.0) < t.total(2.0)
    assert t.total(0.0) == pytest.approx(t.monthly_xpu + t.monthly_energy_xpu)


def test_lower_bandwidth_costs_less():
    hi = tco.cluster_tco(make_cluster("scale-up", 64, H100, link_bw=450e9))
    lo = tco.cluster_tco(make_cluster("scale-up", 64, H100, link_bw=150e9))
    assert lo.monthly_network < hi.monthly_network


# ---------------------------------------------------------------------------
# workload (Table 4 relationships)
# ---------------------------------------------------------------------------

def test_kv_cache_scales_with_context(dsv3):
    k1 = workload.kv_cache_bytes_per_request(dsv3, 512)
    k2 = workload.kv_cache_bytes_per_request(dsv3, 4096)
    assert k2 == pytest.approx(8 * k1, rel=1e-6)


def test_mla_kv_much_smaller_than_gqa(dsv3):
    """MLA at ctx 8192 ~ 1 GB/request claim check (paper section 4.1.2:
    '~1 GB per request' at context 8192 with fp16-ish cache)."""
    kv = workload.kv_cache_bytes_per_request(dsv3, 8192)
    assert 0.03e9 < kv < 1.2e9


def test_max_batch_shrinks_with_context(dsv3):
    p = ServingPoint(batch_global=1, context=512, ep=64, n_devices=64)
    b_short = workload.max_batch_by_memory(dsv3, p, H100.hbm_cap)
    p_long = dataclasses.replace(p, context=4096)
    b_long = workload.max_batch_by_memory(dsv3, p_long, H100.hbm_cap)
    assert b_long < b_short
    assert b_short > 0


def test_a2a_message_grows_with_batch(dsv3):
    p1 = ServingPoint(batch_global=1024, context=512, ep=64, n_devices=64)
    p2 = dataclasses.replace(p1, batch_global=2048)
    m1 = [o for o in workload.decode_iteration(dsv3, p1)
          if o.kind == "a2a"][0].m_bytes
    m2 = [o for o in workload.decode_iteration(dsv3, p2)
          if o.kind == "a2a"][0].m_bytes
    assert m2 == pytest.approx(2 * m1)


def test_moe_arch_emits_a2a_dense_does_not():
    dense = get_arch("deepseek-67b")
    p = ServingPoint(batch_global=512, context=512, ep=64, n_devices=64)
    kinds = {o.kind for o in workload.decode_iteration(dense,
             dataclasses.replace(p, ep=1, tp=8, n_devices=64))}
    assert "a2a" not in kinds
    moe_kinds = {o.kind for o in workload.decode_iteration(
        get_arch("olmoe-1b-7b"), p)}
    assert "a2a" in moe_kinds


# ---------------------------------------------------------------------------
# optimizer trends (paper section 4.1)
# ---------------------------------------------------------------------------

def test_throughput_increases_with_tpot_budget(dsv3_small):
    cl = make_cluster("scale-up", 64, H100)
    thr = []
    for t in (15.0, 40.0, 100.0):
        op = solve(dsv3_small, cl, Scenario(t, 512)).point
        assert op is not None
        thr.append(op.throughput)
    assert thr[0] < thr[1] <= thr[2]


def test_long_context_reduces_throughput(dsv3_small):
    cl = make_cluster("scale-up", 64, H100)
    short = solve(dsv3_small, cl, Scenario(40, 512)).point
    long_ = solve(dsv3_small, cl, Scenario(40, 4096)).point
    assert long_.throughput < short.throughput


def test_dbo_helps_at_relaxed_slo(dsv3_small):
    """DBO must close (most of) the 450 vs 150 GB/s gap at TPOT=100ms
    (paper Fig 11a)."""
    sc = Scenario(100, 512)
    hi = make_cluster("scale-up", 64, H100, link_bw=450e9)
    lo = make_cluster("scale-up", 64, H100, link_bw=150e9)
    no_lo = solve(dsv3_small, lo, sc, SearchSpec(opts="noopt")).point
    dbo_lo = solve(dsv3_small, lo, sc, SearchSpec(opts="dbo")).point
    dbo_hi = solve(dsv3_small, hi, sc, SearchSpec(opts="dbo")).point
    assert dbo_lo.throughput >= no_lo.throughput
    # gap after DBO must be small relative to the hi-BW throughput
    assert dbo_lo.throughput > 0.8 * dbo_hi.throughput


def test_sd_required_for_tight_slo(dsv3):
    """TPOT=15ms with full DeepSeek-V3: SD extends the reachable SLO
    (paper: 'SD is necessary to meet the SLO of TPOT=15ms')."""
    cl = make_cluster("torus", 64, H100)
    sc = Scenario(15, 512)
    no = solve(dsv3, cl, sc, SearchSpec(opts="dbo")).point
    sd = solve(dsv3, cl, sc, SearchSpec(opts="dbo+sd")).point
    assert sd is not None
    if no is not None:
        assert sd.throughput >= no.throughput


def test_sd_tpot_formula():
    sd = SpecDecConfig(spec_m=4, spec_p=0.8)
    assert sd_tpot(0.010, 0.014, sd) == pytest.approx(0.024 / 3.2)


def test_blackwell_faster_than_hopper(dsv3_small):
    sc = Scenario(40, 512)
    h = solve(dsv3_small, make_cluster("scale-up", 64, H100), sc).point
    b = solve(dsv3_small, make_cluster("scale-up", 64, BLACKWELL),
              sc).point
    assert b.throughput > h.throughput


def test_exposed_comm_nonnegative(dsv3_small):
    cl = make_cluster("torus", 64, H100)
    op = solve(dsv3_small, cl, Scenario(40, 512), SearchSpec(dbo=True)).point
    assert op.exposed_comm >= 0.0
