"""TPU v5e through the operating-point search (closes the ROADMAP note:
'TPU v5e in core/hardware.py is still unswept').

v5e is the JAX half's execution target: a 3D-torus-native part with 16 GB
HBM. DeepSeek-V3's dense shard exceeds that at low tensor-parallel degree,
so at pp=1 every tp < 8 mapping is pruned and the model can only be served
behind wide (and all-reduce-heavy) TP. The pipeline-parallel axis flips
that: pp divides the dense shard by tp*pp while keeping the per-device
expert shard at experts/n, so the low-tp mappings become feasible and the
triple search must return a SERVED operating point — not just report the
pruning — and never do worse than the (tp, ep) search. A small MoE
(olmoe-1b-7b) must keep producing feasible points on the Table-3
topologies.
"""
import pytest

from repro.configs import get_arch
from repro.core import TPU_V5E, Scenario, make_cluster
from repro.core import sweep


@pytest.mark.parametrize("topo", ["torus", "scale-up"])
def test_v5e_sweeps_small_moe(topo):
    cfg = get_arch("olmoe-1b-7b")
    cl = make_cluster(topo, 64, TPU_V5E)
    ops = sweep.sweep_max_throughput([cl], cfg, [Scenario(40.0, 512)])
    op = ops[0][0]
    assert op is not None, f"v5e {topo} found no operating point"
    assert op.throughput > 0 and op.batch >= 1
    assert op.tpot <= 40.0 * 1e-3

    auto = sweep.sweep_max_throughput([cl], cfg, [Scenario(40.0, 512)],
                                      tp="auto")[0][0]
    assert auto is not None and auto.throughput >= op.throughput


def test_v5e_candidates_respect_16gb_hbm():
    """DeepSeek-V3's dense shard alone exceeds v5e's HBM at low tp; the
    candidate enumerator must prune those mappings instead of sweeping
    them — and the pp axis must flip exactly those mappings to feasible
    (dense / (tp*pp) shrinks, experts / n does not grow)."""
    dsv3 = get_arch("deepseek-v3")
    cl = make_cluster("torus", 64, TPU_V5E)
    cands = sweep.parallelism_candidates(dsv3, cl)
    assert (1, 1, 64) not in cands
    assert all(tp >= 8 for tp, _, _ in cands)        # dense/tp must fit
    triples = sweep.parallelism_candidates(dsv3, cl, pp="auto")
    assert any(tp < 8 and pp > 1 for tp, pp, _ in triples)
    assert set(cands) <= set(triples)
    olmoe = get_arch("olmoe-1b-7b")
    assert (1, 1, 64) in sweep.parallelism_candidates(olmoe, cl)


@pytest.mark.parametrize("topo", ["torus", "scale-up"])
def test_v5e_serves_dsv3_via_triple_search(topo):
    """The acceptance bar: DeepSeek-V3 on 64 v5e chips returns a SERVED
    operating point at some (tp, pp, ep) triple, meeting the SLO, and the
    triple search never loses to the (tp, ep)-only search."""
    dsv3 = get_arch("deepseek-v3")
    cl = make_cluster(topo, 64, TPU_V5E)
    sc = Scenario(100.0, 512)
    pair = sweep.sweep_max_throughput([cl], dsv3, [sc], tp="auto")[0][0]
    trip = sweep.sweep_max_throughput([cl], dsv3, [sc], tp="auto",
                                      pp="auto")[0][0]
    assert trip is not None, f"v5e {topo}: no served (tp, pp, ep) point"
    assert trip.tpot <= sc.tpot_ms * 1e-3
    assert trip.batch >= 1 and trip.throughput > 0
    assert trip.tp * trip.pp * trip.ep == 64
    assert trip.throughput >= (pair.throughput if pair else 0.0)


def test_mixed_xpu_auto_keeps_per_cluster_candidates():
    """In a mixed-XPU sweep the candidate set is the per-cluster UNION:
    a mapping v5e's HBM prunes must still reach the H100 cluster, so
    auto never returns less than the H100's own fixed tp=1 sweep."""
    from repro.core import H100

    dsv3 = get_arch("deepseek-v3")
    pair = [make_cluster("torus", 64, TPU_V5E),
            make_cluster("torus", 64, H100)]
    sc = Scenario(40.0, 512)
    auto = sweep.sweep_max_throughput(pair, dsv3, [sc], tp="auto")
    fixed_h100 = sweep.sweep_max_throughput([pair[1]], dsv3, [sc])[0][0]
    assert auto[1][0] is not None
    assert auto[1][0].throughput >= fixed_h100.throughput
    assert auto[0][0] is None or auto[0][0].tp > 1   # v5e can't run tp=1
