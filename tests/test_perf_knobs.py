"""Numerics of the §Perf optimization knobs: each must preserve model
outputs within quantization/bf16 tolerance vs the paper-faithful baseline.
Subprocess-based (needs 8 forced host devices)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, n_dev: int = 8) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n_dev}").strip()
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-4000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


COMMON = r"""
import json
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_arch, reduced_config
from repro.launch import steps as S
from repro.launch.mesh import make_mesh
from repro.models import model as M
from repro.sharding.dist import Dist, NullDist
from repro.sharding.plans import make_plan, null_plan
from repro.configs.base import ShapeCell
from jax.sharding import NamedSharding, PartitionSpec as P

def put(tree, specs, mesh):
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs,
        is_leaf=lambda s: isinstance(s, P))

def sharded_loss(cfg, params0, tok, mesh_shape, plan):
    mesh = make_mesh(mesh_shape, ("data", "model"))
    pspecs = S.abstract_model(cfg, plan)[1]
    dist = Dist(dict(zip(("data", "model"), mesh_shape)))
    def f(p, batch):
        return M.train_loss(p, batch, cfg, plan, dist, remat=False)
    bspecs = {"tokens": P(("data",), "model")}
    g = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=(pspecs, bspecs),
                out_specs=P(), check_vma=False))
    with mesh:
        params_sh = put(params0, pspecs, mesh)
        tok_sh = jax.device_put(tok, NamedSharding(mesh, P("data", "model")))
        return float(g(params_sh, {"tokens": tok_sh}))
"""


def test_ring_attention_matches_megatron():
    """ring_attn prefill/train loss == Megatron-SP loss (same math,
    different collective schedule)."""
    res = run_sub(COMMON + """
cfg = reduced_config(get_arch("deepseek-67b")).replace(num_heads=8,
                                                       num_kv_heads=2)
B, Sq = 4, 32
shape = ShapeCell("t", Sq, B, "train")
tok = jax.random.randint(jax.random.PRNGKey(1), (B, Sq), 0, cfg.vocab_size)
params0, _ = M.init_model(cfg, null_plan("train"), jax.random.PRNGKey(0))

plan_m = make_plan(cfg, shape, ("data", "model"), (2, 4), fsdp=False)
plan_r = make_plan(cfg, shape, ("data", "model"), (2, 4), fsdp=False,
                   ring_attn=True)
assert plan_m.attn_mode == "head_tp"
l_m = sharded_loss(cfg, params0, tok, (2, 4), plan_m)
l_r = sharded_loss(cfg, params0, tok, (2, 4), plan_r)
print(json.dumps({"megatron": l_m, "ring": l_r}))
""")
    assert res["ring"] == pytest.approx(res["megatron"], rel=2e-2), res


def test_ag_fp8_close_to_baseline():
    """fp8 wire-format FFN gather: loss within fp8-quantization tolerance."""
    res = run_sub(COMMON + """
cfg = reduced_config(get_arch("starcoder2-3b"))
B, Sq = 4, 32
shape = ShapeCell("t", Sq, B, "train")
tok = jax.random.randint(jax.random.PRNGKey(1), (B, Sq), 0, cfg.vocab_size)
params0, _ = M.init_model(cfg, null_plan("train"), jax.random.PRNGKey(0))
plan_b = make_plan(cfg, shape, ("data", "model"), (2, 4), fsdp=False)
plan_q = make_plan(cfg, shape, ("data", "model"), (2, 4), fsdp=False,
                   ag_fp8=True)
l_b = sharded_loss(cfg, params0, tok, (2, 4), plan_b)
l_q = sharded_loss(cfg, params0, tok, (2, 4), plan_q)
print(json.dumps({"base": l_b, "fp8": l_q}))
""")
    assert res["fp8"] == pytest.approx(res["base"], rel=5e-2), res


def test_ffn_2d_decode_matches_baseline():
    """ffn_2d decode: same greedy logits as the baseline plan (pure
    resharding, no numerics change beyond reduction order)."""
    res = run_sub(COMMON + """
cfg = reduced_config(get_arch("deepseek-67b")).replace(
    num_heads=4, num_kv_heads=2, d_ff=128)
B, cap = 8, 32
shape = ShapeCell("d", cap, B, "decode")
params0, _ = M.init_model(cfg, null_plan("decode"), jax.random.PRNGKey(0))
tok = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab_size)

outs = {}
for name, kw in (("base", {}), ("ffn2d", {"ffn_2d": True})):
    mesh = make_mesh((2, 4), ("data", "model"))
    plan = make_plan(cfg, shape, ("data", "model"), (2, 4), fsdp=False, **kw)
    if name == "ffn2d":
        assert plan.ffn_2d, "ffn_2d not activated (divisibility?)"
    pspecs = S.abstract_model(cfg, plan)[1]
    caches0, _ = M.init_cache(cfg, null_plan("decode"), B, cap)
    _, cspecs = S.abstract_cache(cfg, plan, B, cap)
    dist = Dist(dict(data=2, model=4))
    def step(p, c, t, pos):
        return M.decode_step(p, c, t, pos, cfg, plan, dist)[0]
    tok_spec = P(plan.batch_axes, None)
    f = jax.jit(jax.shard_map(step, mesh=mesh,
                in_specs=(pspecs, cspecs, tok_spec, P()),
                out_specs=tok_spec, check_vma=False))
    with mesh:
        params_sh = put(params0, pspecs, mesh)
        caches_sh = put(caches0, cspecs, mesh)
        tok_sh = jax.device_put(tok, NamedSharding(mesh, tok_spec))
        outs[name] = np.asarray(f(params_sh, caches_sh, tok_sh,
                                  jnp.int32(0))).tolist()
match = sum(int(a == b) for a, b in zip(outs["base"], outs["ffn2d"]))
print(json.dumps({"match": match, "n": len(outs["base"]), **outs}))
""")
    assert res["match"] >= res["n"] - 1, res       # bf16 argmax near-ties


def test_a2a_fp8_close_to_baseline():
    """fp8 dispatch A2A: MoE train loss within quantization tolerance."""
    res = run_sub(COMMON + """
cfg = reduced_config(get_arch("olmoe-1b-7b")).replace(num_heads=4,
                                                      num_kv_heads=2)
B, Sq = 4, 32
shape = ShapeCell("t", Sq, B, "train")
tok = jax.random.randint(jax.random.PRNGKey(1), (B, Sq), 0, cfg.vocab_size)
params0, _ = M.init_model(cfg, null_plan("train"), jax.random.PRNGKey(0))
plan_b = make_plan(cfg, shape, ("data", "model"), (2, 4), fsdp=False)
plan_q = make_plan(cfg, shape, ("data", "model"), (2, 4), fsdp=False,
                   a2a_fp8=True)
l_b = sharded_loss(cfg, params0, tok, (2, 4), plan_b)
l_q = sharded_loss(cfg, params0, tok, (2, 4), plan_q)
print(json.dumps({"base": l_b, "fp8": l_q}))
""")
    assert res["fp8"] == pytest.approx(res["base"], rel=5e-2), res
