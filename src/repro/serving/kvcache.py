"""KV-cache management for the serving engine.

Caches come from ``models.model.init_cache`` as a pytree
``{"periods": tuple(stacked-per-period), "rem": tuple}``. This module owns
the structural knowledge of where the *sequence* dimension lives in each
leaf and which leaves are *recurrent* (order-dependent state that must be
rolled back if speculative tokens are rejected) versus *positional*
(indexed by absolute position; stale speculative writes are masked by
``max_pos`` and later overwritten, so rollback is free).

Leaf classes (leaf key -> class):
  k, v (full attention)   positional  (seq dim: 2 after the batch dim)
  k, v (sliding window)   recurrent   (ring buffer: slot aliasing breaks
                                       the masking argument)
  c_kv, k_rope (MLA)      positional  (seq dim: 1)
  conv, ssm (mamba)       recurrent
  wkv, shift (rwkv)       recurrent
  cross k, v              positional  (read-only after prefill)
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig

# leaf-name -> (class, seq_dim_after_batch) for non-window attention
_POSITIONAL_SEQ_DIM = {"k": 2, "v": 2, "c_kv": 1, "k_rope": 1}
_RECURRENT_KEYS = {"conv", "ssm", "wkv", "shift"}


def _layer_spec_for_path(cfg: ModelConfig, path) -> LayerSpec:
    """Map a cache-tree path to the LayerSpec that produced it.

    Paths look like ("periods", i, <stack keys...>) or ("rem", i, ...);
    index i is the position within cfg.period.
    """
    idx = path[1].idx if hasattr(path[1], "idx") else path[1]
    return cfg.period[idx % len(cfg.period)]


def _leaf_info(cfg: ModelConfig, path) -> Tuple[str, int]:
    """(class, seq_dim) for one cache leaf. class: 'positional'|'recurrent'.
    seq_dim is the GLOBAL-array dim holding absolute positions (-1: none).
    Dims are counted on the unstacked [B, ...] layer cache; the 'periods'
    branch carries one extra leading stack dim handled by callers."""
    spec = _layer_spec_for_path(cfg, path)
    names = [p.key for p in path if hasattr(p, "key")]
    leaf = names[-1]
    group = names[-2]                      # mixer | ffn | cross
    if leaf in _RECURRENT_KEYS:
        return "recurrent", -1
    if group == "cross":
        return "positional", 2             # enc cache: fixed capacity
    if spec.mixer == "attn_local" and cfg.sliding_window and leaf in ("k", "v"):
        return "recurrent", -1             # ring buffer
    return "positional", _POSITIONAL_SEQ_DIM[leaf]


def classify(cfg: ModelConfig, caches) -> Any:
    """Pytree (same structure as caches) of 'positional'|'recurrent'."""
    return jax.tree_util.tree_map_with_path(
        lambda path, _: _leaf_info(cfg, path)[0], caches)


def pad_to_capacity(cfg: ModelConfig, caches, from_seq: int, to_seq: int):
    """Grow every positional leaf's sequence dim from_seq -> to_seq with
    zeros (prefill produced capacity from_seq; the engine runs at to_seq)."""
    assert to_seq >= from_seq

    def pad(path, x):
        cls, dim = _leaf_info(cfg, path)
        stacked = (path[0].key if hasattr(path[0], "key") else path[0]) == "periods"
        if cls == "recurrent" or dim < 0:
            return x
        d = dim + (1 if stacked else 0)
        if x.shape[d] != from_seq:          # e.g. cross cache (enc capacity)
            return x
        widths = [(0, 0)] * x.ndim
        widths[d] = (0, to_seq - from_seq)
        return jnp.pad(x, widths)

    return jax.tree_util.tree_map_with_path(pad, caches)


def insert_slot(caches, sub, slot: int, *, stacked_batch_dim: Dict = None):
    """Scatter a single-request cache `sub` (batch dim size 1) into batch
    index `slot` of the engine's caches. Batch dim: 0 for 'rem' leaves,
    1 for 'periods' leaves (stacked over periods)."""
    def ins(path, full, one):
        stacked = (path[0].key if hasattr(path[0], "key") else path[0]) == "periods"
        b_dim = 1 if stacked else 0
        idx = [slice(None)] * full.ndim
        idx[b_dim] = slot
        one_squeezed = jnp.squeeze(one, axis=b_dim)
        return full.at[tuple(idx)].set(one_squeezed)

    return jax.tree_util.tree_map_with_path(
        lambda path, f, o: ins(path, f, o), caches, sub)


def batch_dim_tree(caches) -> Any:
    """Pytree of ints: which array dim is the batch dim per leaf (1 for
    period-stacked leaves, 0 for remainder leaves). Used as vmap axes."""
    return jax.tree_util.tree_map_with_path(
        lambda path, _: 1 if (path[0].key if hasattr(path[0], "key")
                              else path[0]) == "periods" else 0,
        caches)


def memory_bytes(caches) -> int:
    return int(sum(x.size * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(caches)))


def select_history(cfg: ModelConfig, final_caches, history, accept_idx):
    """Combine speculative-decode cache state: positional leaves keep the
    FINAL state (stale writes are masked/overwritten); recurrent leaves are
    restored from `history` (stacked per verify step, leading dim T) at
    step `accept_idx` (the last step whose input token was accepted)."""
    def pick(path, final, hist):
        cls, _ = _leaf_info(cfg, path)
        if cls == "positional":
            return final
        return jax.lax.dynamic_index_in_dim(hist, accept_idx, axis=0,
                                            keepdims=False)

    return jax.tree_util.tree_map_with_path(pick, final_caches, history)
