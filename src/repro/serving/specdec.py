"""Speculative decoding runtime (paper sections 2.3, 3.3).

Medusa-style multi-head drafting: `spec_m - 1` extra linear heads on the
final hidden state propose candidate continuations; verification feeds the
current token plus the draft through the target model step-by-step inside
one jitted scan, accepts the longest prefix where the model's own greedy
prediction agrees with the draft, and rolls the cache back to the
acceptance point:

  * positional cache leaves (attention K/V at absolute positions) need no
    rollback — writes beyond the accepted position are masked by max_pos
    and overwritten later (serving/kvcache.py);
  * recurrent leaves (mamba/rwkv/sliding-window states) keep a per-step
    history inside the scan and restore the state at the acceptance point.

The key correctness property (tested): the emitted sequence is IDENTICAL
to plain greedy decoding, for any draft quality — SD only changes how many
tokens one iteration yields (spec_p), never what they are.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.layers import common
from repro.serving import kvcache
from repro.sharding.dist import Dist, NullDist
from repro.sharding.plans import ShardingPlan, null_plan


# ---------------------------------------------------------------------------
# draft heads (Medusa-style)
# ---------------------------------------------------------------------------

def init_draft_heads(cfg: ModelConfig, key, n_heads: int):
    """n_heads linear heads d_model -> vocab predicting tokens at +2..+n+1."""
    ks = jax.random.split(key, n_heads)
    return [jax.random.normal(k, (cfg.d_model, cfg.vocab_size),
                              jnp.dtype(cfg.dtype)) * cfg.d_model ** -0.5
            for k in ks]


def draft_from_hidden(heads, hidden) -> jnp.ndarray:
    """hidden: [B, 1, D] -> draft tokens [B, n_heads]."""
    toks = [jnp.argmax(hidden[:, 0] @ w, axis=-1).astype(jnp.int32)
            for w in heads]
    return jnp.stack(toks, axis=1)


# ---------------------------------------------------------------------------
# verification + top-level SD loop
# ---------------------------------------------------------------------------

class SDDecoder:
    """Greedy decoding accelerated by self-drafted speculation.

    Draft source options:
      heads   Medusa linear heads (untrained here; mechanics + interface)
      oracle  the model itself supplies the draft (acceptance = 100%) —
              used by tests to bound the mechanics
      fixed   caller-provided draft fn(batch_hidden) -> [B, spec_m-1]
    """

    def __init__(self, cfg: ModelConfig, params, *, spec_m: int = 4,
                 plan: Optional[ShardingPlan] = None,
                 dist: Optional[Dist] = None,
                 draft_fn: Optional[Callable] = None, seed: int = 0):
        assert spec_m >= 2
        self.cfg = cfg
        self.params = params
        self.plan = plan or null_plan("decode")
        self.dist = dist or NullDist()
        self.spec_m = spec_m
        self.heads = init_draft_heads(cfg, jax.random.PRNGKey(seed),
                                      spec_m - 1)
        self.draft_fn = draft_fn
        self._step = jax.jit(self._make_step())

    def _decode_hidden(self, params, caches, tokens, pos):
        """decode_step that also returns the final hidden state."""
        cfg, plan, dist = self.cfg, self.plan, self.dist
        x = common.embed(params["embed"], tokens, cfg, plan, dist)
        from repro.models import transformer as tf
        x, nc, _ = tf.apply_stack(params["stack"], x, cfg, plan, dist,
                                  mode="decode", caches=caches, pos=pos)
        x = common.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
        logits = common.lm_logits(params["embed"], x, cfg, plan, dist)
        tok = common.greedy_sample(logits, cfg, plan, dist)
        return tok, nc, x

    def _make_step(self):
        cfg = self.cfg
        spec_m = self.spec_m

        def step(params, caches, cur_tok, draft, pos):
            feed = jnp.concatenate([cur_tok, draft], axis=1)
            rec_mask = kvcache.classify(cfg, caches)
            bdims = kvcache.batch_dim_tree(caches)

            def body(c, inp):
                tok, off = inp
                nt, nc, _ = self._decode_hidden(params, c, tok[:, None],
                                                pos + off)
                hist = jax.tree.map(
                    lambda x, cls: (x if cls == "recurrent"
                                    else jnp.zeros((0,), x.dtype)),
                    nc, rec_mask)
                return nc, (nt[:, 0], hist)

            final_caches, (preds, hists) = jax.lax.scan(
                body, caches, (jnp.swapaxes(feed, 0, 1), jnp.arange(spec_m)))
            preds = jnp.swapaxes(preds, 0, 1)                 # [B, spec_m]

            agree = (draft == preds[:, :-1])
            n_agree = jnp.sum(jnp.cumprod(agree.astype(jnp.int32), axis=1),
                              axis=1)
            n_accept = n_agree + 1

            idx = jnp.arange(spec_m)[None, :]
            own = jnp.take_along_axis(preds, n_agree[:, None], axis=1)
            draft_pad = jnp.concatenate(
                [draft, jnp.zeros_like(draft[:, :1])], axis=1)
            tokens = jnp.where(idx < n_agree[:, None], draft_pad, own)

            def pick(final, hist, bdim):
                if hist.size == 0:         # positional sentinel [T, 0]
                    return final
                # hist: [T, ...cache dims...]; batch lives at bdim+1.
                # vmap over batch, select the accepted step along T.
                return jax.vmap(
                    lambda h, i: jax.lax.dynamic_index_in_dim(
                        h, i, axis=0, keepdims=False),
                    in_axes=(bdim + 1, 0), out_axes=bdim)(hist, n_agree)

            new_caches = jax.tree.map(pick, final_caches, hists, bdims)
            return tokens, n_accept, new_caches

        return step

    def draft(self, caches, cur_tok, pos) -> jnp.ndarray:
        """Produce [B, spec_m-1] draft tokens."""
        if self.draft_fn is not None:
            return self.draft_fn(self.params, caches, cur_tok, pos)
        # heads path needs the last hidden state; approximate with the
        # embedding of the current token (untrained heads anyway)
        h = common.embed(self.params["embed"], cur_tok, self.cfg, self.plan,
                         self.dist)
        return draft_from_hidden(self.heads, h)

    def generate(self, caches, first_tok, start_pos: int, n_tokens: int):
        """Greedy-equivalent generation of ~n_tokens (may emit a few more,
        then truncates). Returns (tokens [B, n_tokens], caches, stats)."""
        out: List[jnp.ndarray] = []
        cur = first_tok
        pos = start_pos
        accepted_hist = []
        while sum(int(t.shape[1]) for t in out) < n_tokens:
            d = self.draft(caches, cur, pos)
            toks, n_acc, caches = self._step(self.params, caches, cur, d,
                                             jnp.int32(pos))
            # engine semantics need uniform progress: commit the MIN accept
            # across the batch (production engines track per-slot positions;
            # see serving.engine)
            k = int(jnp.min(n_acc))
            out.append(toks[:, :k])
            accepted_hist.append(k)
            cur = toks[:, k - 1:k]
            pos += k
        tokens = jnp.concatenate(out, axis=1)[:, :n_tokens]
        stats = {"iterations": len(accepted_hist),
                 "mean_accepted": sum(accepted_hist) / len(accepted_hist)}
        return tokens, caches, stats
