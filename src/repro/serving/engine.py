"""Continuous-batching serving engine.

A fixed pool of `max_batch` slots over a fixed-capacity cache. Requests are
admitted into free slots (prefill at the request's length, cache padded to
capacity and scattered into the slot); every decode wave advances ALL live
slots one token with per-slot positions (vmapped decode step). Slots free
as requests hit EOS or their token budget, making room for waiting
requests — the standard continuous-batching loop.

Static shapes throughout: the decode wave compiles once; prefill compiles
once per distinct prompt length (production systems bucket lengths; the
engine exposes `prefill_buckets` for that).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.serving import kvcache
from repro.sharding.dist import Dist, NullDist
from repro.sharding.plans import ShardingPlan, null_plan


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    """Single-host engine (NullDist); the sharded production path reuses the
    same model functions under shard_map (launch.steps / launch.serve)."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 max_seq: int = 256, eos_id: int = 0,
                 plan: Optional[ShardingPlan] = None,
                 dist: Optional[Dist] = None):
        self.cfg = cfg
        self.params = params
        self.plan = plan or null_plan("decode")
        self.dist = dist or NullDist()
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos_id = eos_id

        enc = max_seq if cfg.is_encoder_decoder else 0
        self.caches, _ = M.init_cache(cfg, self.plan, max_batch, max_seq, enc)
        self.pos = jnp.zeros((max_batch,), jnp.int32)
        self.last_tok = jnp.zeros((max_batch, 1), jnp.int32)
        self.live = [False] * max_batch
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.queue: deque[Request] = deque()
        self.finished: Dict[int, Request] = {}
        self._rid = 0
        self._decode_wave = self._build_decode_wave()
        self._prefill_cache: Dict[int, Any] = {}

    # ------------------------------------------------------------------
    # request lifecycle
    # ------------------------------------------------------------------

    def submit(self, prompt: List[int], max_new_tokens: int = 32) -> int:
        rid = self._rid
        self._rid += 1
        self.queue.append(Request(rid, list(prompt), max_new_tokens))
        return rid

    def _admit(self):
        while self.queue and not all(self.live):
            slot = self.live.index(False)
            req = self.queue.popleft()
            tok0, sub = self._prefill_one(req.prompt)
            self.caches = kvcache.insert_slot(self.caches, sub, slot)
            self.pos = self.pos.at[slot].set(len(req.prompt))
            self.last_tok = self.last_tok.at[slot].set(tok0[0])
            req.generated = [int(tok0[0, 0])]
            self.slots[slot] = req
            self.live[slot] = True
            if req.generated[-1] == self.eos_id:
                self._retire(slot)

    def _retire(self, slot: int):
        req = self.slots[slot]
        if req.generated and req.generated[-1] == self.eos_id:
            req.generated = req.generated[:-1]
        req.done = True
        self.finished[req.rid] = req
        self.slots[slot] = None
        self.live[slot] = False

    # ------------------------------------------------------------------
    # prefill
    # ------------------------------------------------------------------

    def _prefill_one(self, prompt: List[int]):
        """Prefill a single request; returns (first generated token [1,1],
        capacity-padded cache with batch dim 1)."""
        L = len(prompt)
        assert 0 < L < self.max_seq, (L, self.max_seq)
        fn = self._prefill_cache.get(L)
        if fn is None:
            pplan = dataclasses.replace(self.plan, kind="prefill")

            def fn(params, tokens, frames=None):
                batch = {"tokens": tokens}
                if self.cfg.frontend == "audio_frames":
                    batch["frames"] = frames
                tok, caches = M.prefill(params, batch, self.cfg, pplan,
                                        self.dist)
                return tok, caches

            fn = jax.jit(fn)
            self._prefill_cache[L] = fn
        tokens = jnp.asarray(prompt, jnp.int32)[None, :]
        frames = None
        if self.cfg.frontend == "audio_frames":
            frames = jnp.zeros((1, L, self.cfg.d_model),
                               jnp.dtype(self.cfg.dtype))
        tok, sub = fn(self.params, tokens, frames) \
            if frames is not None else fn(self.params, tokens)
        sub = kvcache.pad_to_capacity(self.cfg, sub, L, self.max_seq)
        if self.cfg.is_encoder_decoder:
            # cross cache capacity == enc len L -> pad to engine capacity
            pass
        return tok, sub

    # ------------------------------------------------------------------
    # decode wave (per-slot positions via vmap)
    # ------------------------------------------------------------------

    def _build_decode_wave(self):
        cfg, plan, dist = self.cfg, self.plan, self.dist
        enc_len = self.max_seq if cfg.is_encoder_decoder else 0
        bdims = kvcache.batch_dim_tree(self.caches)

        def one(caches, tok, pos):
            # re-add the batch dim vmap stripped (per-leaf position)
            c1 = jax.tree.map(lambda x, d: jnp.expand_dims(x, d),
                              caches, bdims)
            t1 = tok.reshape(1, 1)
            nt, nc = M.decode_step(self.params, c1, t1, pos, cfg, plan,
                                   dist, enc_len=enc_len)
            return nt[0, 0], jax.tree.map(lambda x, d: jnp.squeeze(x, d),
                                          nc, bdims)

        def wave(caches, toks, pos):
            return jax.vmap(one, in_axes=(bdims, 0, 0),
                            out_axes=(0, bdims))(caches, toks[:, 0], pos)

        return jax.jit(wave, donate_argnums=(0,))

    def step(self) -> int:
        """One engine iteration: admit waiting requests, advance all live
        slots one token. Returns number of live slots stepped."""
        self._admit()
        n_live = sum(self.live)
        if n_live == 0:
            return 0
        toks, self.caches = self._decode_wave(self.caches, self.last_tok,
                                              self.pos)
        self.last_tok = toks[:, None]
        self.pos = self.pos + 1
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            t = int(toks[slot])
            req.generated.append(t)
            ntok = len(req.generated) - 1       # first came from prefill
            if (t == self.eos_id or ntok >= req.max_new_tokens
                    or int(self.pos[slot]) >= self.max_seq - 1):
                self._retire(slot)
        return n_live

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        """Drive until every submitted request completes."""
        for _ in range(max_steps):
            if not self.queue and not any(self.live):
                break
            self.step()
        return {rid: r.generated for rid, r in self.finished.items()}
