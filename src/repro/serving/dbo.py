"""Dual-batch overlap serve step (paper section 2.3, Fig 4).

The batch splits into two microbatches; the stack applies layer i to
microbatch A, then layer i to microbatch B, alternating. A's MoE all-to-all
is data-independent of B's attention/FFN compute (and vice versa), so XLA's
latency-hiding scheduler can overlap the collective of one microbatch with
the compute of the other — the structural analogue of DeepSeek's dual-stream
DBO, expressed in one SPMD program.

``core/overlap.py`` quantifies the expected gain analytically; this module
is the runnable counterpart whose lowered HLO exhibits the interleaving
(benchmarks/dryrun_dbo.py counts independent collective/compute pairs).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models import transformer as tf
from repro.models.layers import common
from repro.sharding.dist import Dist
from repro.sharding.plans import ShardingPlan


def _interleaved_stack(params, xa, xb, cfg: ModelConfig, plan, dist, *,
                       caches_a, caches_b, pos):
    """Apply the decoder stack to two microbatches, layer-interleaved."""
    period = cfg.period
    n_per = cfg.n_periods
    n_rem = cfg.n_remainder

    def one_period(xa, xb, pparams, pca, pcb):
        nca, ncb = [], []
        for i, spec in enumerate(period):
            p_i = pparams[i]
            xa, ca, _ = tf.apply_layer(spec, p_i, xa, cfg, plan, dist,
                                       mode="decode", cache=pca[i], pos=pos)
            xb, cb, _ = tf.apply_layer(spec, p_i, xb, cfg, plan, dist,
                                       mode="decode", cache=pcb[i], pos=pos)
            nca.append(ca)
            ncb.append(cb)
        return xa, xb, tuple(nca), tuple(ncb)

    new_pa = new_pb = None
    if n_per > 0:
        def body(carry, xs):
            xa, xb = carry
            pparams, pca, pcb = xs
            xa, xb, nca, ncb = one_period(xa, xb, pparams, pca, pcb)
            return (xa, xb), (nca, ncb)

        (xa, xb), (new_pa, new_pb) = jax.lax.scan(
            body, (xa, xb),
            (params["stack"]["periods"], caches_a["periods"],
             caches_b["periods"]))

    new_ra, new_rb = [], []
    for i in range(n_rem):
        p_i = params["stack"]["rem"][i]
        xa, ca, _ = tf.apply_layer(period[i], p_i, xa, cfg, plan, dist,
                                   mode="decode", cache=caches_a["rem"][i],
                                   pos=pos)
        xb, cb, _ = tf.apply_layer(period[i], p_i, xb, cfg, plan, dist,
                                   mode="decode", cache=caches_b["rem"][i],
                                   pos=pos)
        new_ra.append(ca)
        new_rb.append(cb)

    ca = {"periods": new_pa if new_pa is not None else (),
          "rem": tuple(new_ra)}
    cb = {"periods": new_pb if new_pb is not None else (),
          "rem": tuple(new_rb)}
    return xa, xb, ca, cb


def dbo_decode_step(params, caches_a, caches_b, tok_a, tok_b, pos,
                    cfg: ModelConfig, plan: ShardingPlan, dist: Dist):
    """One DBO decode step over two microbatches.

    tok_a/tok_b: [B/2, 1]; caches_*: per-microbatch cache trees.
    Returns (next_a, next_b, caches_a, caches_b).
    """
    xa = common.embed(params["embed"], tok_a, cfg, plan, dist)
    xb = common.embed(params["embed"], tok_b, cfg, plan, dist)
    xa, xb, ca, cb = _interleaved_stack(params, xa, xb, cfg, plan, dist,
                                        caches_a=caches_a, caches_b=caches_b,
                                        pos=pos)
    out = []
    for x in (xa, xb):
        x = common.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
        logits = common.lm_logits(params["embed"], x, cfg, plan, dist)
        out.append(common.greedy_sample(logits, cfg, plan, dist))
    return out[0], out[1], ca, cb
