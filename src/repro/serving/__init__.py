"""Serving runtime: continuous-batching engine, KV-cache management,
dual-batch-overlap step, speculative decoding."""
from repro.serving.engine import Engine, Request
from repro.serving.specdec import SDDecoder
