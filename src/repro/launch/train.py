"""Production training launcher: mesh + sharded step + data + checkpoints.

On real hardware this runs one process per host and jax.distributed wires
the fleet; on this container use forced host devices to exercise the full
sharded path end-to-end:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.train \
      --arch olmoe-1b-7b --reduced --mesh 2x4 --steps 5 --ckpt-dir /tmp/ck

`--mesh 16x16` (+ `--multi-pod` for 2x16x16) is the production shape.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch, reduced_config
from repro.configs.base import ShapeCell
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_mesh
from repro.sharding.plans import make_plan
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, SyntheticLM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-1b-7b")
    ap.add_argument("--reduced", action="store_true",
                    help="family-preserving reduced config (CPU-friendly)")
    ap.add_argument("--mesh", default="2x4",
                    help="AxB -> (data, model) or AxBxC -> (pod, data, model)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    shape_t = tuple(int(x) for x in args.mesh.split("x"))
    axes = ("pod", "data", "model")[-len(shape_t):]
    mesh = make_mesh(shape_t, axes)
    print(f"mesh {dict(zip(axes, shape_t))} on {mesh.devices.size} devices")

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    cell = ShapeCell("train", args.seq, args.batch, "train")
    plan = make_plan(cfg, cell, axes, shape_t)
    step, structs, shardings = steps_mod.build_train_step(
        cfg, cell, plan, mesh, remat=False, lr=args.lr)
    pshapes, oshapes, _ = structs
    psh, osh, bsh = shardings

    # sharded init: jit the real initializer with sharded outputs
    from repro.models import model as M
    init = jax.jit(lambda k: M.init_model(cfg, plan, k)[0],
                   out_shardings=psh)
    from repro.training import optim
    with mesh:
        params = init(jax.random.PRNGKey(0))
        opt_state = jax.jit(optim.init_state, out_shardings=osh)(params)
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"{args.arch}{' (reduced)' if args.reduced else ''}: "
          f"{n / 1e6:.1f}M params")

    start = 0
    if args.resume and args.ckpt_dir and ckpt.latest_step(args.ckpt_dir):
        state = {"params": params, "opt": opt_state}
        state, start = ckpt.restore(state, args.ckpt_dir,
                                    shardings={"params": psh, "opt": osh})
        params, opt_state = state["params"], state["opt"]
        print(f"resumed from step {start}")

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq, global_batch=args.batch))
    t0 = time.time()
    with mesh:
        for i in range(start, start + args.steps):
            tokens = jax.device_put(data.batch(i), bsh["tokens"])
            params, opt_state, loss = step(params, opt_state,
                                           {"tokens": tokens})
            print(f"step {i}: loss {float(loss):.4f}")
    dt = time.time() - t0
    tok_s = args.steps * args.batch * args.seq / dt
    print(f"{args.steps} steps in {dt:.1f}s ({tok_s:.0f} tok/s)")
    if args.ckpt_dir:
        d = ckpt.save({"params": params, "opt": opt_state}, args.ckpt_dir,
                      start + args.steps, n_shards=shape_t[-1])
        print(f"checkpoint -> {d}")


if __name__ == "__main__":
    main()
