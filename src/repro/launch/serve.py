"""Production serving launcher: sharded prefill + decode loop on a mesh.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve \
      --arch olmoe-1b-7b --reduced --mesh 2x4 --batch 8 --new-tokens 16

Exercises the same shard_map step the dry-run compiles: batch sharded over
(pod,)data, TP/EP over model, KV sequence-sharded, perf knobs optional
(--ffn-2d / --a2a-fp8). Single-host continuous batching lives in
repro.serving.engine; this launcher is the fleet-shaped batched path.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch, reduced_config
from repro.configs.base import ShapeCell
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_mesh
from repro.sharding.plans import make_plan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-1b-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="2x4")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--ffn-2d", action="store_true")
    ap.add_argument("--a2a-fp8", action="store_true")
    args = ap.parse_args()

    shape_t = tuple(int(x) for x in args.mesh.split("x"))
    axes = ("pod", "data", "model")[-len(shape_t):]
    mesh = make_mesh(shape_t, axes)
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    print(f"mesh {dict(zip(axes, shape_t))}; arch {args.arch}"
          f"{' (reduced)' if args.reduced else ''}")

    plan_kw = dict(ffn_2d=args.ffn_2d, a2a_fp8=args.a2a_fp8)
    pre_cell = ShapeCell("p", args.prompt_len, args.batch, "prefill")
    dec_cell = ShapeCell("d", args.max_seq, args.batch, "decode")
    pre_plan = make_plan(cfg, pre_cell, axes, shape_t, **{
        k: v for k, v in plan_kw.items() if k != "ffn_2d"})
    dec_plan = make_plan(cfg, dec_cell, axes, shape_t, **plan_kw)

    prefill, pstructs, pshard = steps_mod.build_prefill(cfg, pre_cell,
                                                        pre_plan, mesh)
    decode, dstructs, dshard = steps_mod.build_decode_step(cfg, dec_cell,
                                                           dec_plan, mesh)

    from repro.models import model as M
    init = jax.jit(lambda k: M.init_model(cfg, pre_plan, k)[0],
                   out_shardings=pshard[0])
    rng = np.random.default_rng(0)
    tokens = rng.integers(1, cfg.vocab_size, (args.batch, args.prompt_len),
                          dtype=np.int32)
    with mesh:
        params = init(jax.random.PRNGKey(0))
        tok_sh = jax.device_put(tokens, pshard[1]["tokens"])
        t0 = time.time()
        next_tok, caches = prefill(params, {"tokens": tok_sh})
        next_tok.block_until_ready()
        t_prefill = time.time() - t0

        # prefill cache capacity == prompt_len; decode runs against the
        # decode-cell capacity — re-home the cache (pad along seq dims)
        from repro.serving import kvcache
        caches = kvcache.pad_to_capacity(cfg, caches, args.prompt_len,
                                         args.max_seq)
        caches = jax.device_put(caches, dshard[1])
        next_tok = jax.device_put(next_tok, dshard[2])

        out = [np.asarray(next_tok)]
        t0 = time.time()
        for i in range(args.new_tokens - 1):
            pos = jnp.int32(args.prompt_len + i)
            next_tok, caches = decode(params, caches, next_tok, pos)
            out.append(np.asarray(next_tok))
        dt = time.time() - t0

    seqs = np.concatenate(out, axis=1)
    thpt = args.batch * (args.new_tokens - 1) / dt
    print(f"prefill {args.batch}x{args.prompt_len} in {t_prefill:.2f}s; "
          f"decode {args.new_tokens - 1} steps in {dt:.2f}s "
          f"({thpt:.1f} tok/s on CPU)")
    for b in range(min(args.batch, 3)):
        print(f"  seq {b}: {seqs[b].tolist()}")
    print(f"plan: ffn_2d={dec_plan.ffn_2d} a2a_fp8={dec_plan.a2a_fp8} "
          f"attn={dec_plan.attn_mode} ep={dec_plan.ep_axis}")


if __name__ == "__main__":
    main()
