import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

# Multi-pod dry-run: AOT lower + compile every (arch x shape) cell on the
# production mesh, record memory/cost analysis + collective bytes for the
# roofline (EXPERIMENTS.md section Dry-run / section Roofline).
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch starcoder2-3b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
#
# NOTE: the XLA_FLAGS line above must run before ANY other import (jax locks
# the device count on first init), hence the unusual import order.

import argparse
import json
import time
import traceback

import jax

from repro.configs import ASSIGNED_ARCHS, SHAPES, cell_applicable, get_arch
from repro.launch.mesh import make_production_mesh
from repro.launch import steps as steps_mod


def _with_shardings(structs, shardings):
    return jax.tree.map(
        lambda st, sh: jax.ShapeDtypeStruct(st.shape, st.dtype, sharding=sh),
        structs, shardings)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             collect_hlo: bool = False, fsdp: bool = True,
             plan_overrides=None, unroll: bool = False,
             unstack: bool = False, plan_kw=None):
    """Lower + compile one cell. Returns a result dict.

    unroll=True unrolls the layer scan so cost_analysis() counts every
    layer (XLA counts a scan body once) — used for exact roofline numbers;
    the default scanned form is what production would run.

    unstack=True additionally gives every layer its OWN parameter/cache
    arrays (period = the full layer list). Without this the stacked cache
    is one array and XLA's fusion cost accounting charges each per-layer
    slice/update fusion for the FULL stacked operand — TB-scale phantom
    bytes on decode cells. unroll+unstack is the exact-accounting mode."""
    cfg = get_arch(arch)
    if unstack:
        cfg = cfg.replace(period=cfg.layer_specs)
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    step, structs, shardings, plan = steps_mod.build_cell(
        cfg, shape, mesh, fsdp=fsdp, unroll=unroll, plan_kw=plan_kw)
    if plan_overrides:
        import dataclasses
        plan = dataclasses.replace(plan, **plan_overrides)
    args = _with_shardings(structs, shardings)
    with mesh:
        lowered = step.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    res = {
        "arch": arch, "shape": shape_name, "status": "ok",
        "unroll": unroll, "unstack": unstack,
        "mesh": list(mesh.devices.shape), "axes": list(mesh.axis_names),
        "n_devices": int(mesh.devices.size),
        "plan": {"attn_mode": plan.attn_mode, "ep_axis": plan.ep_axis,
                 "batch_axes": plan.batch_axes, "seq_axis": plan.seq_axis,
                 "kv_axis": plan.kv_axis, "fsdp_axis": plan.fsdp_axis,
                 "ffn_2d": plan.ffn_2d},
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops": float(cost.get("flops", 0.0)) if cost else 0.0,
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)) if cost else 0.0,
    }
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                res[f"mem_{k}"] = int(v)
    # collective bytes + in-place DUS correction from the compiled
    # (post-SPMD-partitioning) HLO
    from repro.analysis.hlo import collective_bytes, dus_overcount_bytes
    try:
        hlo = compiled.as_text()
        res["collectives"] = collective_bytes(hlo)
        res["dus_overcount_bytes"] = dus_overcount_bytes(hlo)
        res["bytes_accessed_inplace"] = max(
            res["bytes_accessed"] - res["dus_overcount_bytes"], 0.0)
        if collect_hlo:
            res["hlo_len"] = len(hlo)
    except Exception as e:  # pragma: no cover
        res["collectives_error"] = str(e)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll the layer scan for exact cost accounting")
    ap.add_argument("--unstack", action="store_true",
                    help="per-layer cache/param arrays (exact accounting)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ASSIGNED_ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    results = []
    for arch, shape in cells:
        print(f"=== {arch} x {shape} (multi_pod={args.multi_pod}) ===",
              flush=True)
        try:
            res = run_cell(arch, shape, multi_pod=args.multi_pod,
                           unroll=args.unroll, unstack=args.unstack)
        except Exception as e:
            res = {"arch": arch, "shape": shape, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
        print(json.dumps({k: v for k, v in res.items() if k != "trace"},
                         default=str), flush=True)
        results.append(res)
        if args.out:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1, default=str)

    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_skip = sum(1 for r in results if r["status"] == "skipped")
    n_err = len(results) - n_ok - n_skip
    print(f"DONE ok={n_ok} skipped={n_skip} errors={n_err}")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
