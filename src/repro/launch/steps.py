"""Step builders: wrap the manual-SPMD model functions in shard_map and jit,
and build the ShapeDtypeStruct input specs for dry-run lowering.

Everything here works off GLOBAL shapes + PartitionSpec trees; actual arrays
never materialize during a dry run (jax.eval_shape + AOT lower/compile).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import model as M
from repro.models.layers.common import fsdp_spec
from repro.sharding.dist import Dist, NullDist
from repro.sharding.plans import ShardingPlan, make_plan
from repro.training import optim


def dist_for(mesh) -> Dist:
    if mesh is None:
        return NullDist()
    return Dist(dict(zip(mesh.axis_names, mesh.devices.shape)))


def _is_p(x):
    return isinstance(x, P)


# ---------------------------------------------------------------------------
# abstract init (global shapes, no allocation)
# ---------------------------------------------------------------------------

def abstract_model(cfg: ModelConfig, plan: ShardingPlan):
    """(param ShapeDtypeStructs, PartitionSpec tree) without allocating."""
    captured = {}

    def f(key):
        p, s = M.init_model(cfg, plan, key)
        captured["specs"] = s
        return p

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, captured["specs"]


def abstract_cache(cfg: ModelConfig, plan: ShardingPlan, batch: int,
                   seq: int, enc_seq: int = 0):
    captured = {}

    def f():
        c, s = M.init_cache(cfg, plan, batch, seq, enc_seq)
        captured["specs"] = s
        return c

    shapes = jax.eval_shape(f)
    return shapes, captured["specs"]


def apply_fsdp_specs(shapes, specs, plan: ShardingPlan):
    """Extend param specs with FSDP sharding where dims divide (training)."""
    if plan.fsdp_axis is None:
        return specs
    return jax.tree.map(
        lambda sh, sp: fsdp_spec(sh.shape, sp, plan), shapes, specs,
        is_leaf=lambda x: _is_p(x))


# ---------------------------------------------------------------------------
# batch input specs
# ---------------------------------------------------------------------------

def batch_struct(cfg: ModelConfig, shape: ShapeCell, plan: ShardingPlan):
    """(ShapeDtypeStruct dict, PartitionSpec dict) for one step's data batch."""
    B, S = shape.global_batch, shape.seq_len
    bax = plan.batch_axes
    structs: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    if shape.kind in ("train", "prefill"):
        structs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        specs["tokens"] = P(bax, plan.seq_axis)
        if cfg.frontend == "vit_patches":
            structs["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frontend_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
            specs["patches"] = P(bax, None, None)
        if cfg.frontend == "audio_frames":
            structs["frames"] = jax.ShapeDtypeStruct(
                (B, S, cfg.d_model), jnp.dtype(cfg.dtype))
            specs["frames"] = P(bax, plan.seq_axis, None)
    else:  # decode
        structs["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        specs["tokens"] = P(bax, None)
    return structs, specs


# ---------------------------------------------------------------------------
# gradient reduction
# ---------------------------------------------------------------------------

def reduce_grads(grads, specs, plan: ShardingPlan, dist: Dist):
    """psum each grad over every mesh axis its param is replicated over."""
    mesh_axes = plan.mesh_axes

    def axes_in(spec):
        used = set()
        for e in spec:
            if e is None:
                continue
            for a in (e if isinstance(e, tuple) else (e,)):
                used.add(a)
        return used

    def red(g, spec):
        for ax in mesh_axes:
            if ax not in axes_in(spec):
                g = dist.psum(g, ax)
        return g

    return jax.tree.map(red, grads, specs, is_leaf=lambda x: _is_p(x))


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, shape: ShapeCell, plan: ShardingPlan,
                     mesh=None, *, remat: bool = True, lr: float = 3e-4,
                     unroll: bool = False):
    """Returns (step_fn, in_structs, in_shardings, donate) where
    step(params, opt_state, batch) -> (params, opt_state, loss)."""
    dist = dist_for(mesh)
    pshapes, pspecs = abstract_model(cfg, plan)
    bstructs, bspecs = batch_struct(cfg, shape, plan)
    ospecs = optim.state_specs(pspecs)

    def step(params, opt_state, batch):
        def loss_fn(p):
            return M.train_loss(p, batch, cfg, plan, dist, remat=remat,
                                param_specs=pspecs, unroll=unroll)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads = reduce_grads(grads, pspecs, plan, dist)
        params_new, opt_new = optim.update(params, grads, opt_state, lr=lr)
        return params_new, opt_new, loss

    if mesh is not None:
        step = jax.shard_map(step, mesh=mesh,
                             in_specs=(pspecs, ospecs, bspecs),
                             out_specs=(pspecs, ospecs, P()),
                             check_vma=False)
    step = jax.jit(step, donate_argnums=(0, 1))

    oshapes = jax.eval_shape(optim.init_state, pshapes)
    structs = (pshapes, oshapes, bstructs)
    shardings = None
    if mesh is not None:
        shardings = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                 (pspecs, ospecs, bspecs),
                                 is_leaf=_is_p)
    return step, structs, shardings


def build_prefill(cfg: ModelConfig, shape: ShapeCell, plan: ShardingPlan,
                  mesh=None, *, unroll: bool = False):
    """step(params, batch) -> (next_token, caches)."""
    dist = dist_for(mesh)
    pshapes, pspecs = abstract_model(cfg, plan)
    bstructs, bspecs = batch_struct(cfg, shape, plan)
    enc_seq = shape.seq_len if cfg.is_encoder_decoder else 0
    _, cspecs = abstract_cache(cfg, plan, shape.global_batch, shape.seq_len,
                               enc_seq)

    def step(params, batch):
        return M.prefill(params, batch, cfg, plan, dist, unroll=unroll)

    out_specs = (P(plan.batch_axes, None), cspecs)
    if mesh is not None:
        step = jax.shard_map(step, mesh=mesh, in_specs=(pspecs, bspecs),
                             out_specs=out_specs, check_vma=False)
    step = jax.jit(step)
    structs = (pshapes, bstructs)
    shardings = None
    if mesh is not None:
        shardings = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                 (pspecs, bspecs), is_leaf=_is_p)
    return step, structs, shardings


def build_decode_step(cfg: ModelConfig, shape: ShapeCell, plan: ShardingPlan,
                      mesh=None, *, unroll: bool = False):
    """step(params, caches, tokens, pos) -> (next_token, caches).
    Cache capacity = shape.seq_len; the new token lands at pos."""
    dist = dist_for(mesh)
    pshapes, pspecs = abstract_model(cfg, plan)
    enc_seq = shape.seq_len if cfg.is_encoder_decoder else 0
    cshapes, cspecs = abstract_cache(cfg, plan, shape.global_batch,
                                     shape.seq_len, enc_seq)
    enc_len = enc_seq

    def step(params, caches, tokens, pos):
        return M.decode_step(params, caches, tokens, pos, cfg, plan, dist,
                             enc_len=enc_len, unroll=unroll)

    tok_spec = P(plan.batch_axes, None)
    if mesh is not None:
        step = jax.shard_map(
            step, mesh=mesh,
            in_specs=(pspecs, cspecs, tok_spec, P()),
            out_specs=(tok_spec, cspecs), check_vma=False)
    step = jax.jit(step, donate_argnums=(1,))
    structs = (pshapes, cshapes,
               jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
               jax.ShapeDtypeStruct((), jnp.int32))
    shardings = None
    if mesh is not None:
        shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            (pspecs, cspecs, tok_spec, P()), is_leaf=_is_p)
    return step, structs, shardings


def build_cell(cfg: ModelConfig, shape: ShapeCell, mesh,
               *, fsdp: bool = True, unroll: bool = False, plan_kw=None):
    """One dry-run cell: returns (step, structs, shardings, plan)."""
    axes = mesh.axis_names
    sizes = mesh.devices.shape
    plan = make_plan(cfg, shape, tuple(axes), tuple(sizes), fsdp=fsdp,
                     **(plan_kw or {}))
    if shape.kind == "train":
        step, structs, sh = build_train_step(cfg, shape, plan, mesh,
                                             unroll=unroll)
    elif shape.kind == "prefill":
        step, structs, sh = build_prefill(cfg, shape, plan, mesh,
                                          unroll=unroll)
    else:
        step, structs, sh = build_decode_step(cfg, shape, plan, mesh,
                                              unroll=unroll)
    return step, structs, sh, plan
