"""Shared fault-injection machinery (serving sweeps + training drills).

One seam drives both sides of the repo's failure story:

  * the training recovery loop (`training/fault_tolerance.py`) raises
    `WorkerFailure` through a `FailureInjector` at deterministic step
    indices and restores from checkpoint;
  * the serving-side fault sweeps (tests/test_faults*.py, the degraded
    searches behind `benchmarks/fig_failures.py`) draw seeded random
    `FaultSet`s from the same per-component inventory the availability
    model prices, via `sample_faultset`.

Everything here is deterministic given its seed — injected failures must
reproduce exactly across reruns (a recovery drill that fails flakily is
useless as a regression test), so the injector takes explicit step
indices or a seed, never wall-clock or global RNG state.

Layer: shared seam between the serving stack (`core.topology.FaultSet`,
`core.availability`) and the training loop; everything here is seeded and
deterministic, matching the repo-wide reproducibility contract (committed
figure JSONs regenerate byte-identically).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.topology import Cluster, FaultSet


class WorkerFailure(RuntimeError):
    """A worker (or its host / link) died during a step."""


@dataclass
class FailureInjector:
    """Raise WorkerFailure at the configured step indices (once each)."""
    fail_at: List[int] = field(default_factory=list)
    fired: List[int] = field(default_factory=list)

    def check(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.append(step)
            raise WorkerFailure(f"injected failure at step {step}")

    @classmethod
    def seeded(cls, n_steps: int, rate: float,
               seed: int = 0) -> "FailureInjector":
        """Deterministic Bernoulli(rate)-per-step failure plan over
        `n_steps` — the seeded construction both the training drills and
        the serving sweeps share."""
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {rate}")
        rng = np.random.default_rng(seed)
        hits = np.nonzero(rng.random(n_steps) < rate)[0]
        return cls(fail_at=[int(s) for s in hits])


def sample_faultset(cluster: Cluster, *, exposure_h: float,
                    seed: int = 0,
                    mtbf_mttr: Optional[Dict[str, Tuple[float, float]]]
                    = None) -> FaultSet:
    """Draw one seeded random `FaultSet` for `cluster`: each component
    class fails Poisson(count x exposure_h / MTBF) times over the exposure
    window, mapped onto the serving model's fault axes by the same
    blast-radius rules the availability enumeration uses
    (`availability.faultset_for_counts`). Deterministic per seed."""
    from repro.core.availability import (component_inventory,
                                         faultset_for_counts)
    if exposure_h < 0:
        raise ValueError(f"exposure_h must be >= 0, got {exposure_h}")
    rng = np.random.default_rng(seed)
    counts = {c.name: int(rng.poisson(c.count * exposure_h / c.mtbf_h))
              for c in component_inventory(cluster, mtbf_mttr)}
    return faultset_for_counts(cluster, counts)
