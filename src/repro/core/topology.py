"""Cluster topologies: bandwidth provisioning, switch/link inventory (for
TCO), and best-algorithm collective times (paper sections 2.2, 3.2.2, 3.4).

Four families (paper Fig. 2): scale-up / scale-out (non-blocking fat-tree),
3D torus, 3D full-mesh. Torus/full-mesh dims: 4x4x4 (64) and 8x8x4 (256).

Degraded fabrics: a `FaultSet` attached to a `Cluster` derates every
collective placed through `comm_spec` — the topologies fail very
differently (a mesh degrades gracefully via detours; a switched fabric
concentrates failures into few high-blast-radius planes), and the derating
formulas per topology live in `Cluster._fault_derate` (documented in
docs/failure_model.md). A cluster with `faults=None` is byte-identical to
the pre-fault model on every path.

Expert-load skew never enters this layer: a skewed A2A is priced by
scaling the per-op PAYLOAD handed to the alpha-beta menus (`m_bytes` x
hot-rank load factor, `sweep.op_load_factors`) — the beta term grows with
the hottest rank's ingress while the alpha terms (rounds, destinations)
are topology properties and stay fixed, matching a symmetric collective
that synchronizes on its slowest member. `comm_spec` and the menus below
are skew-agnostic.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.core.alphabeta import AlphaBeta, CLUSTER, INTRA_NODE
from repro.core import collectives as coll
from repro.core.hardware import XPUSpec

TOPOLOGIES = ("scale-up", "scale-out", "torus", "fullmesh")

DIMS_BY_SIZE = {8: (2, 2, 2), 64: (4, 4, 4), 256: (8, 8, 4), 512: (8, 8, 8)}

# XPUs per NVLink-class island inside a scale-out cluster (DGX-style node);
# a TP domain that fits the island rides its scale-up switch, not the NIC
NODE_XPUS = 8


def _tp_subdims(dims: Tuple[int, ...],
                tp: int) -> Optional[Tuple[int, ...]]:
    """Greedy contiguous sub-mesh of `tp` devices inside `dims`: fill the
    first dimension first (matching how DIMS_BY_SIZE orders the long axes).
    Returns per-dim extents of the TP neighborhood, or None when `tp` has
    no contiguous factorization (then placement falls back to the
    whole-cluster menus)."""
    sub = []
    rem = tp
    for d in dims:
        t = math.gcd(rem, d)
        sub.append(t)
        rem //= t
    if rem != 1:
        return None
    return tuple(sub)


def _strip_ones(dims: Tuple[int, ...]) -> Tuple[int, ...]:
    return tuple(d for d in dims if d > 1) or (1,)

SWITCH_RADIX = 64
SCALE_UP_PORTS = 16          # per XPU
SCALE_OUT_PORTS = 1
XPUS_PER_RACK = 64


@dataclass(frozen=True)
class LinkInventory:
    copper_gbps_total: float = 0.0     # aggregate copper bandwidth (GB/s)
    aoc_gbps_total: float = 0.0        # aggregate AOC bandwidth (GB/s)


# bandwidth floor of a fully-failed fabric: keeps collective times finite
# (astronomical, so any feasibility check rejects them) instead of inf/NaN
_DEAD_FABRIC_FRAC = 1e-9


@dataclass(frozen=True)
class FaultSet:
    """Failed components of one cluster — counts per class, not identities
    (the model is symmetric across same-class components, and collectives
    synchronize on the slowest rank, so the worst-case placement prices
    every placement).

    mesh_links     failed torus / full-mesh links per dimension (entries
                   beyond the cluster's dims, or on switched fabrics, are
                   ignored); a broken torus ring forces detour rounds, a
                   lost full-mesh direct link forces a 2-hop relay over the
                   (d-1) surviving links of its line
    switch_planes  failed scale-up switch-plane rails (of the
                   SCALE_UP_PORTS parallel planes each XPU stripes across)
    nics           failed scale-out NICs — each takes its whole NODE_XPUS
                   island node out of the serving pool
    xpus           failed XPUs (any topology)

    The zero FaultSet derates nothing; `Cluster(faults=None)` skips the
    derating code path entirely (byte-identity of the healthy model).
    """
    mesh_links: Tuple[int, ...] = ()
    switch_planes: int = 0
    nics: int = 0
    xpus: int = 0

    def __post_init__(self):
        if (any(f < 0 for f in self.mesh_links) or self.switch_planes < 0
                or self.nics < 0 or self.xpus < 0):
            raise ValueError(f"fault counts must be >= 0: {self}")
        object.__setattr__(self, "mesh_links", tuple(self.mesh_links))

    @property
    def any(self) -> bool:
        return bool(sum(self.mesh_links) or self.switch_planes
                    or self.nics or self.xpus)

    def link_at(self, i: int) -> int:
        """Failed links in mesh dim `i` (0 beyond the recorded dims)."""
        return self.mesh_links[i] if i < len(self.mesh_links) else 0


@dataclass(frozen=True)
class Cluster:
    topology: str
    n_xpus: int
    xpu: XPUSpec
    link_bw: float                      # per-XPU aggregate network BW (B/s)
    dims: Optional[Tuple[int, ...]] = None
    faults: Optional[FaultSet] = None   # None = healthy (byte-identical)

    def __post_init__(self):
        if self.topology in ("torus", "fullmesh") and self.dims is None:
            if self.n_xpus not in DIMS_BY_SIZE:
                raise ValueError(
                    f"no predefined {self.topology} dims for "
                    f"n_xpus={self.n_xpus}; supported sizes: "
                    f"{sorted(DIMS_BY_SIZE)} — pass dims=(a, b, c) "
                    "explicitly for other sizes")
            object.__setattr__(self, "dims", DIMS_BY_SIZE[self.n_xpus])

    # ------------- degraded fabric -------------
    def with_faults(self, faults: Optional[FaultSet]) -> "Cluster":
        """This cluster with `faults` attached (None clears them)."""
        return Cluster(topology=self.topology, n_xpus=self.n_xpus,
                       xpu=self.xpu, link_bw=self.link_bw, dims=self.dims,
                       faults=faults)

    def survivor_xpus(self) -> int:
        """Devices still serving under `self.faults`: failed XPUs are out
        everywhere; on scale-out each failed NIC additionally takes its
        whole NODE_XPUS island node out (the node's only path into the
        fabric)."""
        if self.faults is None:
            return self.n_xpus
        lost = self.faults.xpus
        if self.topology == "scale-out":
            lost += self.faults.nics * NODE_XPUS
        return max(self.n_xpus - lost, 0)

    def mesh_link_counts(self) -> Tuple[int, ...]:
        """Physical link count per dimension of a torus / full-mesh
        (0 for inactive dims and switched fabrics). Torus dim of extent d:
        n/d rings x d links (degenerate d=2 'ring': one link per pair);
        full-mesh dim: n/d lines x d(d-1)/2 direct links."""
        if self.topology not in ("torus", "fullmesh") or not self.dims:
            return ()
        out = []
        for d in self.dims:
            if d <= 1:
                out.append(0)
            elif self.topology == "torus":
                out.append(self.n_xpus if d > 2 else self.n_xpus // 2)
            else:
                out.append((self.n_xpus // d) * d * (d - 1) // 2)
        return tuple(out)

    def _fault_derate(self) -> Tuple[float, float, float]:
        """(bandwidth factor, extra rounds, extra dests) the attached
        FaultSet imposes on every collective placed through `comm_spec`
        (docs/failure_model.md derives the formulas):

        scale-up   a failed switch plane removes one of the SCALE_UP_PORTS
                   parallel rails every XPU stripes across: bandwidth
                   scales by surviving planes / planes, no extra latency
                   (the rails are independent).
        scale-out  NIC failures are node-count events (survivor_xpus), not
                   fabric derates — the surviving nodes' non-blocking tree
                   is unaffected.
        torus      the first failed link of a dimension breaks a ring into
                   a line: wrapped traffic detours the long way, folding
                   over the surviving links (x1/2 efficiency), and ring
                   phases pay ~d/2 detour rounds; further failures remove
                   capacity linearly.
        full-mesh  a lost direct link forces its pair onto a 2-hop relay
                   across the (d-1) surviving links of the line — the
                   rerouted traffic consumes 2x capacity (factor
                   (L - 2f)/L per dim) and adds one store-and-forward
                   relay round per affected dimension.

        The factor applies to the whole fabric (collectives synchronize on
        the slowest rank, so one degraded ring/plane gates every phase);
        it is monotonically non-increasing — and rounds non-decreasing —
        in every fault count, the invariant the degradation-monotonicity
        property tests pin.
        """
        f = self.faults
        if f is None or not f.any:
            return 1.0, 0.0, 0.0
        if self.topology == "scale-up":
            frac = max(SCALE_UP_PORTS - f.switch_planes, 0) / SCALE_UP_PORTS
            return max(frac, _DEAD_FABRIC_FRAC), 0.0, 0.0
        if self.topology == "scale-out":
            return 1.0, 0.0, 0.0
        links = self.mesh_link_counts()
        active = [i for i, d in enumerate(self.dims) if d > 1]
        if not active:
            return 1.0, 0.0, 0.0
        fracs = []
        extra_r = extra_d = 0.0
        for i in active:
            li = links[i]
            fi = min(f.link_at(i), li)
            if fi == 0:
                fracs.append(1.0)
                continue
            if self.topology == "torus":
                fracs.append(0.5 * (li - fi) / li)
                extra_r += math.ceil(self.dims[i] / 2)
                extra_d += math.ceil(self.dims[i] / 2)
            else:
                fracs.append(max(li - 2 * fi, 0) / li)
                extra_r += 1.0
                extra_d += 2.0
        frac = sum(fracs) / len(fracs)
        return max(frac, _DEAD_FABRIC_FRAC), extra_r, extra_d

    # ------------- collectives -------------
    def _ab(self) -> AlphaBeta:
        return CLUSTER if self.n_xpus > 8 else INTRA_NODE

    def comm_spec(self, kind: str, group: int = 0, tp: int = 1,
                  pp: int = 1):
        """(algorithm menu, bandwidth, AlphaBeta) of one collective PLACED
        under the hybrid (tp, pp, ep) mapping, derated by the attached
        `FaultSet` (identity when `faults` is None — the healthy placement
        below is untouched). Both the scalar timers and the batched
        engine's (A, B) lowering consume this one spec, so degraded
        batched and scalar times agree exactly as healthy ones do."""
        menu, bw, ab = self._comm_spec_healthy(kind, group, tp, pp)
        if self.faults is None or not self.faults.any:
            return menu, bw, ab
        factor, extra_r, extra_d = self._fault_derate()
        if factor == 1.0 and extra_r == 0.0 and extra_d == 0.0:
            return menu, bw, ab
        menu = {name: coll.CollCost(rounds=c.rounds + extra_r,
                                    dests=c.dests + extra_d,
                                    m_coeff=c.m_coeff, name=c.name)
                for name, c in menu.items()}
        return menu, bw * factor, ab

    def _comm_spec_healthy(self, kind: str, group: int = 0, tp: int = 1,
                           pp: int = 1):
        """The healthy-fabric collective placement — the topology-aware
        half of the parallelism search.

        kind 'ar' with group == tp is the TP all-reduce: it runs over the
        scale-up / mesh NEIGHBORHOOD (a tp-sized sub-mesh of torus /
        full-mesh dims, the intra-node island of a scale-out cluster), so
        it sees only the link bandwidth that points into that neighborhood
        — the placement is the same contiguous block on every pipeline
        stage, so it is pp-independent.
        kind 'a2a' with group == ep < n is the expert dispatch/gather over
        the REMAINDER of the STAGE: the quotient of the stage's n/pp-device
        block by the TP neighborhood (stride-tp peers on meshes, with torus
        hops dilated by the stride).
        kind 'pp_sendrecv' is the per-token hidden-state hop between
        corresponding devices of adjacent stages: a neighbor hop riding
        ONE mesh link on torus / full-mesh, a NIC hop on multi-island
        scale-out (scale-up switching only when the whole cluster fits
        one island), a switch hop at full provision on scale-up.

        tp <= 1, pp <= 1, group in (0, n): the seed whole-cluster
        placement, byte-identical to the pre-hybrid model.
        """
        n_grp = group or self.n_xpus
        ab = self._ab()
        if kind == "pp_sendrecv":
            hop = {"sendrecv": coll.pp_sendrecv()}
            if self.topology == "scale-up":
                return hop, self.link_bw, ab
            if self.topology == "scale-out":
                if self.n_xpus <= NODE_XPUS:
                    # whole cluster inside one NVLink island: every
                    # boundary rides the scale-up switch
                    return hop, self.xpu.scale_up_bw, INTRA_NODE
                # multi-island cluster: island-crossing stage boundaries
                # exist at every pp (stages >= island: all of them; stages
                # < island: the island-edge ones), and one menu prices all
                # pp-1 hops — charge the NIC, the conservative bound
                return hop, self.link_bw, CLUSTER
            # mesh: the hop crosses the single link that leaves the stage
            # block, one of the 2*ndim (torus) / sum(d-1) (full-mesh)
            # links the per-XPU aggregate provision is spread across
            active = [d for d in (self.dims or (self.n_xpus,)) if d > 1]
            n_links = (2 * len(active) if self.topology == "torus"
                       else sum(d - 1 for d in active))
            return hop, self.link_bw / max(n_links, 1), ab
        if kind == "a2a":
            if tp * max(pp, 1) <= 1 or n_grp >= self.n_xpus:
                return (coll.a2a_menu(self.topology, self.n_xpus, self.dims),
                        self.link_bw, ab)
            if self.topology in ("scale-up", "scale-out"):
                # any ep subset of the switched fabric at full provision
                return coll.a2a_menu(self.topology, n_grp, None), \
                    self.link_bw, ab
            stage = (_tp_subdims(self.dims, self.n_xpus // pp)
                     if pp > 1 else self.dims)
            sub = _tp_subdims(stage, tp) if stage is not None else None
            if sub is None:
                return (coll.a2a_menu(self.topology, self.n_xpus, self.dims),
                        self.link_bw, ab)
            qdims = tuple(d // t for d, t in zip(stage, sub))
            menu = coll.a2a_menu(self.topology, n_grp, _strip_ones(qdims))
            active = [i for i, d in enumerate(self.dims) if d > 1]
            if self.topology == "fullmesh":
                # stride-t peers in a full-mesh line are directly linked:
                # (q-1) of the (d-1) links per dim stay usable
                frac = (sum(qdims[i] - 1 for i in active)
                        / sum(self.dims[i] - 1 for i in active))
            else:
                # torus: a stride-t ring hop crosses t physical links
                frac = (sum(1.0 / sub[i] for i in active if qdims[i] > 1)
                        / len(active))
            return menu, self.link_bw * max(frac, 1e-9), ab
        # all-reduce
        if tp > 1 and n_grp == tp and n_grp < self.n_xpus:
            if self.topology == "scale-out" and tp <= NODE_XPUS:
                # TP inside the NVLink-class island: scale-up switching at
                # the XPU's scale-up provision, intra-node latencies
                return (coll.ar_menu("scale-up", n_grp, None),
                        self.xpu.scale_up_bw, INTRA_NODE)
            if self.topology in ("torus", "fullmesh"):
                sub = _tp_subdims(self.dims, tp)
                if sub is not None:
                    sdims = _strip_ones(sub)
                    menu = coll.ar_menu(self.topology, n_grp, sdims)
                    active = [i for i, d in enumerate(self.dims) if d > 1]
                    if self.topology == "fullmesh":
                        frac = (sum(s - 1 for s in sub)
                                / sum(self.dims[i] - 1 for i in active))
                    else:
                        frac = (len([s for s in sub if s > 1])
                                / len(active))
                    return menu, self.link_bw * max(frac, 1e-9), ab
        menu = coll.ar_menu(self.topology, n_grp, self.dims)
        return menu, self.link_bw, ab

    def _best_time(self, kind: str, m_bytes: float, group: int, tp: int,
                   pp: int) -> float:
        """min over the placed menu's algorithms — the one timing formula
        behind a2a_time / ar_time / pp_hop_time."""
        menu, bw, ab = self.comm_spec(kind, group, tp, pp)
        return min(ab.time(rounds=c.rounds, dests=c.dests, m_coeff=c.m_coeff,
                           m_bytes=m_bytes, bandwidth=bw)
                   for c in menu.values())

    def a2a_time(self, m_bytes: float, group: Optional[int] = None,
                 tp: int = 1, pp: int = 1) -> float:
        """Best all-to-all algorithm for this topology; m = per-XPU payload.
        `group`/`tp`/`pp` place the collective under the hybrid mapping
        (see `comm_spec`); the defaults are the seed whole-cluster
        semantics."""
        return self._best_time("a2a", m_bytes, group or 0, tp, pp)

    def ar_time(self, m_bytes: float, group: Optional[int] = None,
                tp: int = 1, pp: int = 1) -> float:
        return self._best_time("ar", m_bytes, group or 0, tp, pp)

    def pp_hop_time(self, m_bytes: float, pp: int = 2, tp: int = 1) -> float:
        """One inter-stage hidden-state hop (see `comm_spec` kind
        'pp_sendrecv'); m = per-XPU payload of the microbatch slice."""
        return self._best_time("pp_sendrecv", m_bytes, pp, tp, pp)

    # ------------- inventory (for TCO) -------------
    def switch_capacity_total(self) -> float:
        """Total switch capacity in B/s (radix x port bandwidth x count),
        non-blocking fat-tree sized for per-XPU `link_bw`.

        Scale-out additionally carries its INTRA-NODE scale-up domain
        (8-XPU NVLink-class switching at the XPU's scale-up provision) —
        that is what a DGX-style server actually ships with, and omitting
        it would make scale-out spuriously cheap (paper section 3.4)."""
        if self.topology in ("torus", "fullmesh"):
            return 0.0
        intra = 0.0
        if self.topology == "scale-out":
            intra = self.n_xpus * self.xpu.scale_up_bw
        ports_per_xpu = SCALE_UP_PORTS if self.topology == "scale-up" else SCALE_OUT_PORTS
        port_bw = self.link_bw / ports_per_xpu
        endpoints = self.n_xpus * ports_per_xpu
        if endpoints <= SWITCH_RADIX * ports_per_xpu and self.n_xpus <= SWITCH_RADIX:
            # one-level: each XPU port rail goes to its own switch plane
            n_switches = ports_per_xpu
            return intra + n_switches * SWITCH_RADIX * port_bw
        # two-level folded clos: leaf (half down/half up) + spine
        down = SWITCH_RADIX // 2
        n_leaf = math.ceil(endpoints / down)
        n_spine = math.ceil(n_leaf * down / SWITCH_RADIX)
        return intra + (n_leaf + n_spine) * SWITCH_RADIX * port_bw

    def link_inventory(self) -> LinkInventory:
        """Aggregate link bandwidth by cable type. Intra-rack copper,
        inter-rack AOC (64 XPUs/rack, paper section 3.4)."""
        gb = 1e9
        n_racks = math.ceil(self.n_xpus / XPUS_PER_RACK)
        if self.topology in ("scale-up", "scale-out"):
            # XPU->leaf links: intra-rack copper. Leaf->spine (two-level): AOC.
            xpu_links_bw = self.n_xpus * self.link_bw
            intra = (self.n_xpus * self.xpu.scale_up_bw
                     if self.topology == "scale-out" else 0.0)
            if self.n_xpus <= SWITCH_RADIX:
                return LinkInventory(
                    copper_gbps_total=(xpu_links_bw + intra) / gb)
            up_bw = xpu_links_bw                     # non-blocking
            return LinkInventory(
                copper_gbps_total=(xpu_links_bw + intra) / gb,
                aoc_gbps_total=up_bw / gb)
        # switchless: every XPU's aggregate BW spread across its links;
        # links within a rack are copper, cross-rack AOC.
        total_bw = self.n_xpus * self.link_bw      # counts each link twice/2
        if n_racks == 1:
            return LinkInventory(copper_gbps_total=total_bw / gb)
        # fraction of links that leave the rack (rough: last dim crosses)
        if self.topology == "torus":
            cross_frac = 1.0 / 3.0
        else:
            d = self.dims
            links = sum(x - 1 for x in d)
            cross_frac = (d[-1] - 1) / links
        return LinkInventory(
            copper_gbps_total=total_bw * (1 - cross_frac) / gb,
            aoc_gbps_total=total_bw * cross_frac / gb)

    def describe(self) -> Dict:
        out = {"topology": self.topology, "n": self.n_xpus,
               "link_bw_GBs": self.link_bw / 1e9, "dims": self.dims}
        if self.faults is not None and self.faults.any:
            out["faults"] = {"mesh_links": list(self.faults.mesh_links),
                             "switch_planes": self.faults.switch_planes,
                             "nics": self.faults.nics,
                             "xpus": self.faults.xpus}
        return out


def make_cluster(topology: str, n_xpus: int, xpu: XPUSpec,
                 link_bw: Optional[float] = None) -> Cluster:
    """link_bw defaults to the XPU's provisioned bandwidth: scale-out uses
    the NIC bandwidth, all others the scale-up provision (paper section 3.2:
    'fix the total per-XPU network bandwidth')."""
    if link_bw is None:
        link_bw = xpu.scale_out_bw if topology == "scale-out" else xpu.scale_up_bw
    return Cluster(topology=topology, n_xpus=n_xpus, xpu=xpu, link_bw=link_bw)
