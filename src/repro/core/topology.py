"""Cluster facade over the pluggable fabric registry (`core/fabric.py`):
bandwidth provisioning, switch/link inventory (for TCO), and
best-algorithm collective times (paper sections 2.2, 3.2.2, 3.4).

Five registered fabrics: the paper's four static families (Fig. 2) —
scale-up / scale-out (non-blocking fat-tree), 3D torus, 3D full-mesh
(torus/full-mesh dims: 4x4x4 at 64 and 8x8x4 at 256) — plus the
reconfigurable optical circuit-switched fabric (docs/fabrics.md).
`TOPOLOGIES` enumerates the static four (what the paper's figures
sweep); `repro.core.fabric.FABRICS` is the full registry and the single
source of truth for names, menus, derates, and inventories. `Cluster`
owns only the fabric-AGNOSTIC machinery: the alpha-beta regime choice
(`_ab`), the FaultSet derate wrapper around `comm_spec`, the
best-of-menu timers, and `describe`.

Degraded fabrics: a `FaultSet` attached to a `Cluster` derates every
collective placed through `comm_spec` — the topologies fail very
differently (a mesh degrades gracefully via detours; a switched fabric
concentrates failures into few high-blast-radius planes), and the
derating formulas live in each fabric's `fault_derate` (documented in
docs/failure_model.md). A cluster with `faults=None` is byte-identical
to the pre-fault model on every path.

Expert-load skew never enters this layer: a skewed A2A is priced by
scaling the per-op PAYLOAD handed to the alpha-beta menus (`m_bytes` x
hot-rank load factor, `sweep.op_load_factors`) — the beta term grows with
the hottest rank's ingress while the alpha terms (rounds, destinations)
are topology properties and stay fixed, matching a symmetric collective
that synchronizes on its slowest member. `comm_spec` and the menus
are skew-agnostic.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.core.alphabeta import AlphaBeta, CLUSTER, INTRA_NODE
from repro.core import collectives as coll
from repro.core.fabric import (DIMS_BY_SIZE, FABRICS, FaultSet, Fabric,
                               LinkInventory, NODE_XPUS, SCALE_OUT_PORTS,
                               SCALE_UP_PORTS, SWITCH_RADIX, XPUS_PER_RACK,
                               _DEAD_FABRIC_FRAC, _strip_ones, _tp_subdims,
                               get_fabric)
from repro.core.hardware import XPUSpec

__all__ = [
    "TOPOLOGIES", "DIMS_BY_SIZE", "NODE_XPUS", "SWITCH_RADIX",
    "SCALE_UP_PORTS", "SCALE_OUT_PORTS", "XPUS_PER_RACK",
    "Cluster", "Fabric", "FaultSet", "LinkInventory", "get_fabric",
    "make_cluster",
]

# the paper's four STATIC fabrics, in registry order — what fig10/14/17
# sweep; the reconfigurable OCS fabric is registered beside them and
# enumerated via `fabric.FABRICS` where a figure wants all five
TOPOLOGIES = tuple(name for name, f in FABRICS.items()
                   if not f.reconfigurable)


@dataclass(frozen=True)
class Cluster:
    topology: str
    n_xpus: int
    xpu: XPUSpec
    link_bw: float                      # per-XPU aggregate network BW (B/s)
    dims: Optional[Tuple[int, ...]] = None
    faults: Optional[FaultSet] = None   # None = healthy (byte-identical)

    def __post_init__(self):
        # registry lookup IS the validation: a typo ("full-mesh") raises
        # here naming the registered fabrics instead of silently pricing
        # as a phantom fabric through the generic menus
        fab = get_fabric(self.topology)
        if fab.needs_dims and self.dims is None:
            if self.n_xpus not in DIMS_BY_SIZE:
                raise ValueError(
                    f"no predefined {self.topology} dims for "
                    f"n_xpus={self.n_xpus}; supported sizes: "
                    f"{sorted(DIMS_BY_SIZE)} — pass dims=(a, b, c) "
                    "explicitly for other sizes")
            object.__setattr__(self, "dims", DIMS_BY_SIZE[self.n_xpus])

    @property
    def fabric(self) -> Fabric:
        """The registered `Fabric` every topology-dependent hook
        delegates to."""
        return get_fabric(self.topology)

    # ------------- degraded fabric -------------
    def with_faults(self, faults: Optional[FaultSet]) -> "Cluster":
        """This cluster with `faults` attached (None clears them)."""
        return Cluster(topology=self.topology, n_xpus=self.n_xpus,
                       xpu=self.xpu, link_bw=self.link_bw, dims=self.dims,
                       faults=faults)

    def survivor_xpus(self) -> int:
        """Devices still serving under `self.faults` (fabric-specific:
        e.g. on scale-out each failed NIC takes its whole island node
        out)."""
        return self.fabric.survivor_xpus(self)

    def mesh_link_counts(self) -> Tuple[int, ...]:
        """Physical link count per dimension of a torus / full-mesh
        (empty for non-mesh fabrics)."""
        return self.fabric.mesh_link_counts(self)

    def _fault_derate(self) -> Tuple[float, float, float]:
        """(bandwidth factor, extra rounds, extra dests) the attached
        FaultSet imposes — the fabric's formula
        (docs/failure_model.md)."""
        return self.fabric.fault_derate(self)

    # ------------- collectives -------------
    def _ab(self) -> AlphaBeta:
        return CLUSTER if self.n_xpus > 8 else INTRA_NODE

    def comm_spec(self, kind: str, group: int = 0, tp: int = 1,
                  pp: int = 1):
        """(algorithm menu, bandwidth, AlphaBeta) of one collective PLACED
        under the hybrid (tp, pp, ep) mapping, derated by the attached
        `FaultSet` (identity when `faults` is None — the healthy placement
        is untouched). Both the scalar timers and the batched
        engine's (A, B) lowering consume this one spec, so degraded
        batched and scalar times agree exactly as healthy ones do."""
        menu, bw, ab = self._comm_spec_healthy(kind, group, tp, pp)
        if self.faults is None or not self.faults.any:
            return menu, bw, ab
        factor, extra_r, extra_d = self._fault_derate()
        if factor == 1.0 and extra_r == 0.0 and extra_d == 0.0:
            return menu, bw, ab
        menu = {name: coll.CollCost(rounds=c.rounds + extra_r,
                                    dests=c.dests + extra_d,
                                    m_coeff=c.m_coeff, name=c.name)
                for name, c in menu.items()}
        return menu, bw * factor, ab

    def _comm_spec_healthy(self, kind: str, group: int = 0, tp: int = 1,
                           pp: int = 1):
        """The healthy-fabric collective placement — the topology-aware
        half of the parallelism search, owned by the fabric
        (`Fabric.comm_spec_healthy`).

        kind 'ar' with group == tp is the TP all-reduce: it runs over the
        scale-up / mesh NEIGHBORHOOD (a tp-sized sub-mesh of torus /
        full-mesh dims, the intra-node island of a scale-out cluster, a
        dedicated circuit ring on the OCS fabric), so it sees only the
        link bandwidth that points into that neighborhood — the placement
        is the same contiguous block on every pipeline stage, so it is
        pp-independent.
        kind 'a2a' with group == ep < n is the expert dispatch/gather over
        the REMAINDER of the STAGE: the quotient of the stage's n/pp-device
        block by the TP neighborhood (stride-tp peers on meshes, with torus
        hops dilated by the stride).
        kind 'pp_sendrecv' is the per-token hidden-state hop between
        corresponding devices of adjacent stages: a neighbor hop riding
        ONE mesh link on torus / full-mesh, a NIC hop on multi-island
        scale-out (scale-up switching only when the whole cluster fits
        one island), a switch hop at full provision on scale-up.

        tp <= 1, pp <= 1, group in (0, n): the seed whole-cluster
        placement, byte-identical to the pre-hybrid model.
        """
        return self.fabric.comm_spec_healthy(self, kind, group, tp, pp)

    def _best_time(self, kind: str, m_bytes: float, group: int, tp: int,
                   pp: int) -> float:
        """min over the placed menu's algorithms — the one timing formula
        behind a2a_time / ar_time / pp_hop_time."""
        menu, bw, ab = self.comm_spec(kind, group, tp, pp)
        return min(ab.time(rounds=c.rounds, dests=c.dests, m_coeff=c.m_coeff,
                           m_bytes=m_bytes, bandwidth=bw)
                   for c in menu.values())

    def a2a_time(self, m_bytes: float, group: Optional[int] = None,
                 tp: int = 1, pp: int = 1) -> float:
        """Best all-to-all algorithm for this topology; m = per-XPU payload.
        `group`/`tp`/`pp` place the collective under the hybrid mapping
        (see `comm_spec`); the defaults are the seed whole-cluster
        semantics."""
        return self._best_time("a2a", m_bytes, group or 0, tp, pp)

    def ar_time(self, m_bytes: float, group: Optional[int] = None,
                tp: int = 1, pp: int = 1) -> float:
        return self._best_time("ar", m_bytes, group or 0, tp, pp)

    def pp_hop_time(self, m_bytes: float, pp: int = 2, tp: int = 1) -> float:
        """One inter-stage hidden-state hop (see `comm_spec` kind
        'pp_sendrecv'); m = per-XPU payload of the microbatch slice."""
        return self._best_time("pp_sendrecv", m_bytes, pp, tp, pp)

    # ------------- inventory (for TCO) -------------
    def switch_capacity_total(self) -> float:
        """Total packet-switch capacity in B/s (radix x port bandwidth x
        count), non-blocking fat-tree sized for per-XPU `link_bw`;
        switchless and circuit-switched fabrics carry none.

        Scale-out additionally carries its INTRA-NODE scale-up domain
        (8-XPU NVLink-class switching at the XPU's scale-up provision) —
        that is what a DGX-style server actually ships with, and omitting
        it would make scale-out spuriously cheap (paper section 3.4)."""
        return self.fabric.switch_capacity_total(self)

    def link_inventory(self) -> LinkInventory:
        """Aggregate link bandwidth by cable type. Intra-rack copper,
        inter-rack AOC (64 XPUs/rack, paper section 3.4); OCS fiber is
        tracked separately (transceiver-terminated)."""
        return self.fabric.link_inventory(self)

    def ocs_port_count(self) -> int:
        """Circuit-switch ports the cluster terminates (0 off the OCS
        fabric); priced per port by `core.tco`."""
        return self.fabric.ocs_port_count(self)

    def describe(self) -> Dict:
        out = {"topology": self.topology, "n": self.n_xpus,
               "link_bw_GBs": self.link_bw / 1e9, "dims": self.dims}
        if self.faults is not None and self.faults.any:
            out["faults"] = {"mesh_links": list(self.faults.mesh_links),
                             "switch_planes": self.faults.switch_planes,
                             "nics": self.faults.nics,
                             "xpus": self.faults.xpus}
        return out


def make_cluster(topology: str, n_xpus: int, xpu: XPUSpec,
                 link_bw: Optional[float] = None, *,
                 link_bw_mult: Optional[float] = None) -> Cluster:
    """link_bw defaults to the fabric's provision
    (`Fabric.default_link_bw`): the NIC bandwidth on NIC-provisioned
    fabrics, the scale-up provision elsewhere (paper section 3.2: 'fix
    the total per-XPU network bandwidth'). `link_bw_mult` scales whatever
    the previous rules produced — the bandwidth-derating sweeps
    (fig12/fig17-style) say 'x of provision' without restating the
    provision."""
    if link_bw is None:
        link_bw = get_fabric(topology).default_link_bw(xpu)
    if link_bw_mult is not None:
        link_bw = link_bw * link_bw_mult
    return Cluster(topology=topology, n_xpus=n_xpus, xpu=xpu, link_bw=link_bw)
