"""Collective algorithm cost coefficients per topology (paper Tables 2-3).

Each algorithm maps (cluster size / topology dims, message size m) to the
(rounds, dests, m_coeff) triple consumed by the alpha-beta model. `m` is the
TOTAL payload each XPU contributes (paper convention: ScaleUp-P2P carries
(N-1)/N * m past the NIC). Which algorithms a topology gets to choose from
(the paper-Table-2 menus) is owned by the fabric registry
(`core/fabric.py`); this module holds only the per-algorithm cost
primitives.

Table 3 ground truth (asserted in tests/test_collectives.py):
  ScaleUp-P2P     N=64: 1ar +  63ad + (63/64) m·b     N=256: 1ar + 255ad + (255/256) m·b
  ScaleUp-Bruck   N=64: 6ar +   6ad + 3 m·b           N=256: 8ar +   8ad + 4 m·b
  FullMesh-DoR    N=64: 3ar +  27ad + (9/4) m·b       N=256: 3ar +  51ad + (17/4) m·b
  Torus-HalfRing  N=64: 6ar +  36ad + 3 m·b           N=256: 12ar +  72ad + 6 m·b

beta uses each topology's PER-XPU aggregate bandwidth; the coefficients
already encode how much of that aggregate a given algorithm can actually
drive (e.g. full-mesh DoR is bottlenecked by its thinnest dimension).

Layer: pure coefficient tables between `core.alphabeta` (below) and
`core.topology` (above); no timing is computed here, so scalar/batched
parity is inherited, not asserted.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class CollCost:
    rounds: float
    dests: float
    m_coeff: float
    name: str = ""


# ---------------------------------------------------------------------------
# all-to-all
# ---------------------------------------------------------------------------

def a2a_p2p(n: int) -> CollCost:
    """Direct pairwise exchange (NCCL-style)."""
    return CollCost(rounds=1, dests=n - 1, m_coeff=(n - 1) / n, name="p2p")


def a2a_bruck(n: int) -> CollCost:
    """Bruck's log-round A2A: log2(N) rounds each moving m/2."""
    k = math.ceil(math.log2(n))
    return CollCost(rounds=k, dests=k, m_coeff=k / 2, name="bruck")


def a2a_fullmesh_dor(dims: Tuple[int, ...]) -> CollCost:
    """Dimension-order routing on nD full-mesh with cut-through: per-dim
    phases pipeline; the thinnest dimension bottlenecks the beta term."""
    links = sum(d - 1 for d in dims)
    return CollCost(rounds=len(dims), dests=3 * links,
                    m_coeff=links / min(dims), name="fullmesh-dor")


def a2a_fullmesh_oneshot(dims: Tuple[int, ...]) -> CollCost:
    """One-shot: direct per-destination sends over the mesh links (torus-P2P
    adapted): same bandwidth bottleneck as DoR, P2P-style serialization."""
    n = math.prod(dims)
    links = sum(d - 1 for d in dims)
    return CollCost(rounds=1, dests=n - 1, m_coeff=links / min(dims),
                    name="fullmesh-oneshot")


def a2a_torus_halfring(dims: Tuple[int, ...]) -> CollCost:
    """HalfRing on a 3D torus (Qin et al. [48] adapted): bidirectional ring
    phases per dimension; rounds scale with the largest dimension."""
    r = len(dims) * max(dims) // 2
    return CollCost(rounds=r, dests=2 * len(dims) * r, m_coeff=r / 2,
                    name="torus-halfring")


def a2a_torus_p2p(dims: Tuple[int, ...]) -> CollCost:
    """Direct sends with DOR routing on the torus; average hop dilation
    inflates the beta term (each dim contributes ~d/4 average hops on a
    bidirectional ring, and traffic shares 2 links per dim)."""
    n = math.prod(dims)
    # average hops per dim ~ d/4; effective bandwidth fraction ~ 6/(sum hops*..)
    avg_hops = sum(d / 4 for d in dims)
    return CollCost(rounds=1, dests=n - 1,
                    m_coeff=((n - 1) / n) * avg_hops, name="torus-p2p")


# ---------------------------------------------------------------------------
# point-to-point (pipeline-parallel stage boundary)
# ---------------------------------------------------------------------------

def pp_sendrecv() -> CollCost:
    """One send/recv between corresponding devices of adjacent pipeline
    stages: a single round to a single destination moving the full payload.
    The topology decides the bandwidth the hop rides (one mesh link, the
    NIC, or the scale-up switch — see `Cluster.comm_spec`)."""
    return CollCost(rounds=1, dests=1, m_coeff=1.0, name="sendrecv")


# ---------------------------------------------------------------------------
# all-reduce (coefficient of m is the classic 2(N-1)/N for BW-optimal algos;
# topology-specific effective-bandwidth derating folds into m_coeff)
# ---------------------------------------------------------------------------

def ar_ring(n: int, bw_derate: float = 1.0) -> CollCost:
    return CollCost(rounds=2 * (n - 1), dests=2 * (n - 1),
                    m_coeff=2 * (n - 1) / n * bw_derate, name="ring")


def ar_recursive_doubling(n: int, bw_derate: float = 1.0) -> CollCost:
    k = math.ceil(math.log2(n))
    return CollCost(rounds=k, dests=k, m_coeff=k * bw_derate,
                    name="recursive-doubling")


def ar_rabenseifner(n: int, bw_derate: float = 1.0) -> CollCost:
    """Reduce-scatter + all-gather (recursive halving/doubling)."""
    k = math.ceil(math.log2(n))
    return CollCost(rounds=2 * k, dests=2 * k,
                    m_coeff=2 * (n - 1) / n * bw_derate, name="rabenseifner")


def ar_swing_torus(dims: Tuple[int, ...]) -> CollCost:
    """Swing [12] on torus: near-BW-optimal using all 2*ndim links/XPU."""
    n = math.prod(dims)
    k = math.ceil(math.log2(n))
    return CollCost(rounds=2 * k, dests=2 * k, m_coeff=2 * (n - 1) / n,
                    name="swing")


# The per-topology algorithm MENUS (paper Table 2) live with the fabric
# classes in core/fabric.py (`Fabric.a2a_menu` / `Fabric.ar_menu`) — this
# module stays a registry-free layer of pure cost primitives.
