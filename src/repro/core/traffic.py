"""Cluster-scale continuous-batching traffic simulator.

The operating-point search answers "best steady-state point per scenario";
production serves bursty, diurnal, mixed-length traffic from millions of
users. This module replays seeded arrival traces against a cluster running
SOLVED operating points (obtained exclusively through `repro.core.api`) and
reports goodput under SLO attainment — the production-facing counterpart of
the capacity figures.

Model (docs/traffic_sim.md has the full derivation):

  * Traces: Poisson or Gamma-burst interarrivals (`TraceSpec.cv2` is the
    interarrival CV^2), optional diurnal rate modulation via a time-warp of
    the unit-rate arrival stream (so scaling `rate_rps` compresses the SAME
    request sequence — offered-load sweeps are monotone by construction),
    and a (weight, prompt_len, gen_len) mixture per request. All seeded.
  * Serving: iteration-clocked continuous batching. Requests join at
    iteration boundaries up to the operating point's batch; each iteration
    takes `api.tpot_curve`'s TPOT at the CURRENT batch (the same GridEval
    arithmetic the search used). A request with an m-chunk prompt occupies
    its slot for m prefill iterations before its first token; iterations
    carrying chunks stretch by ceil(k/domains) * mean-chunk-time
    (Sarathi-style piggybacking, priced by the scalar chunk components).
  * Autoscaling: a threshold policy switches between pool sizes of an
    operating-point catalog as observed load shifts. An elective switch
    does NOT stall serving — the old pool keeps serving while the new
    one re-shards, so the new operating point takes effect one PR-6
    remap downtime LATER and both pools bill during the overlap (that
    lag-plus-double-billing IS the switch cost). Parked pool capacity is
    released back to the shared fleet, so the XPU capex + energy share
    of the monthly cost bills by active fraction while the fabric stays
    a fixed cost of the topology.
  * Faults: `repro.faults.FailureInjector` fires at seeded iteration
    indices; each event prices its `FaultSet` through the remap-vs-degrade
    policy (`api.solve` with `spec.faults`) and becomes a QUEUEING event —
    keep-arm derating, or drain + remap downtime + degraded serving until
    repair + re-shard back — instead of PR 6's amortized availability
    factor. TTFT spikes fall out of the queue, not an approximation.

Vectorization follows `core/sweep.py`'s idiom: the per-iteration Python
loop does O(1) bookkeeping (dict-of-counts for completions), admissions
land as array slices, and every per-request metric (TTFT, TPOT, SLO
attainment, Little's-law occupancy) is derived post-hoc from the recorded
iteration end-times with NumPy — so a million-request trace costs an
array program plus one short loop over iterations, not per-request Python.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import api, optimizer, placement, sweep, workload
from repro.core.optimizer import OperatingPoint, Scenario
from repro.core.specdec import SpecDecConfig
from repro.core.tco import cluster_tco
from repro.core.topology import Cluster, FaultSet
from repro.core.workload import ServingPoint
from repro.faults import FailureInjector, sample_faultset


# ---------------------------------------------------------------------------
# arrival traces
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TraceSpec:
    """Seeded arrival-trace recipe.

    `arrival` 'poisson' draws Exp(1) unit interarrivals; 'gamma' draws
    Gamma(1/cv2, cv2) (mean 1, CV^2 = `cv2` > 1 = bursty). The unit-rate
    stream is scaled by `rate_rps` and, when `diurnal_amplitude` > 0,
    time-warped through the inverse cumulative rate of
    rate(t) = rate_rps * (1 + A sin(2 pi t / P)) — the classic inversion
    construction, so the SAME seed yields the SAME request sequence at
    every rate (load sweeps are monotone by construction).

    `length_mix` is a tuple of (weight, prompt_len, gen_len) classes; each
    request draws its class from the normalized weights.
    """
    horizon_s: float
    rate_rps: float
    arrival: str = "poisson"
    cv2: float = 1.0
    diurnal_amplitude: float = 0.0
    diurnal_period_s: float = 3600.0
    length_mix: Tuple[Tuple[float, int, int], ...] = ((1.0, 0, 1024),)
    seed: int = 0
    name: str = ""

    def __post_init__(self):
        if self.horizon_s <= 0:
            raise ValueError(f"horizon_s must be > 0, got {self.horizon_s}")
        if self.rate_rps < 0:
            raise ValueError(f"rate_rps must be >= 0, got {self.rate_rps}")
        if self.arrival not in ("poisson", "gamma"):
            raise ValueError(f"unknown arrival {self.arrival!r}")
        if self.cv2 <= 0:
            raise ValueError(f"cv2 must be > 0, got {self.cv2}")
        if not 0.0 <= self.diurnal_amplitude < 1.0:
            raise ValueError("diurnal_amplitude must be in [0, 1), got "
                             f"{self.diurnal_amplitude}")
        if not self.length_mix or any(
                w <= 0 or p < 0 or g < 1 for w, p, g in self.length_mix):
            raise ValueError("length_mix needs (weight > 0, prompt >= 0, "
                             f"gen >= 1) classes, got {self.length_mix}")

    @property
    def mean_gen(self) -> float:
        w = sum(w for w, _, _ in self.length_mix)
        return sum(wi * g for wi, _, g in self.length_mix) / w

    def scaled(self, load: float) -> "TraceSpec":
        """The same trace recipe at `load` x the offered rate."""
        return replace(self, rate_rps=self.rate_rps * load)


@dataclass
class Trace:
    """Materialized arrival trace: sorted times + per-request lengths."""
    spec: TraceSpec
    t: np.ndarray        # arrival seconds, sorted, within [0, horizon_s)
    prompt: np.ndarray   # prompt tokens per request (int64)
    gen: np.ndarray      # decode tokens per request (int64, >= 1)

    @property
    def n(self) -> int:
        return int(self.t.size)


def _unit_arrivals(spec: TraceSpec, budget: float,
                   rng: np.random.Generator) -> np.ndarray:
    """Cumulative unit-rate arrival stream covering [0, budget]."""
    draws: List[np.ndarray] = []
    total = 0.0
    block = max(int(budget) + 16, 64)
    while total <= budget:
        if spec.arrival == "poisson":
            ia = rng.exponential(1.0, size=block)
        else:
            ia = rng.gamma(1.0 / spec.cv2, spec.cv2, size=block)
        draws.append(ia)
        total += float(ia.sum())
    s = np.cumsum(np.concatenate(draws))
    return s[s <= budget]


def generate_trace(spec: TraceSpec) -> Trace:
    """Materialize a `TraceSpec` deterministically (one RNG per spec)."""
    rng = np.random.default_rng(spec.seed)
    r, h = spec.rate_rps, spec.horizon_s
    if r == 0.0:
        empty = np.zeros(0)
        zero = np.zeros(0, np.int64)
        return Trace(spec, empty, zero, zero)
    a, period = spec.diurnal_amplitude, spec.diurnal_period_s
    if a == 0.0:
        s = _unit_arrivals(spec, r * h, rng)
        t = s / r
    else:
        # cumulative rate Lambda(t) = r*(t + A*P/(2pi)*(1 - cos(2pi t/P)));
        # invert on a fine grid (monotone, A < 1 keeps rate(t) > 0)
        grid = np.linspace(0.0, h, max(int(64 * h / period), 4096))
        lam = r * (grid + a * period / (2 * np.pi)
                   * (1.0 - np.cos(2 * np.pi * grid / period)))
        s = _unit_arrivals(spec, float(lam[-1]), rng)
        t = np.interp(s, lam, grid)
    w = np.array([wi for wi, _, _ in spec.length_mix], float)
    cls = rng.choice(len(spec.length_mix), size=t.size, p=w / w.sum())
    prompts = np.array([p for _, p, _ in spec.length_mix], np.int64)[cls]
    gens = np.array([g for _, _, g in spec.length_mix], np.int64)[cls]
    return Trace(spec, t, prompts, gens)


# ---------------------------------------------------------------------------
# operating-point catalog (pool sizes x solved points)
# ---------------------------------------------------------------------------

@dataclass
class PoolPoint:
    """One catalog entry: a pool of the base cluster with its solved
    operating point and the curves the simulator clocks against."""
    cluster: Cluster
    point: OperatingPoint
    tpot: np.ndarray           # TPOT seconds at batch b = index + 1
    chunk_time: float          # mixture-mean prefill-chunk time (0 = none)
    domains: int               # DP-attention domains (chunks per iteration)

    @property
    def n_xpus(self) -> int:
        return self.cluster.n_xpus

    @property
    def cap(self) -> int:
        return self.point.batch


class Catalog:
    """Operating points per pool size for one (cfg, cluster, scenario,
    spec) — the autoscaler's menu. Entries ascend in pool size; the last
    (full-pool) entry is the static-provisioning arm. Every point comes
    from `api.solve`; every curve from `api.tpot_curve`."""

    def __init__(self, cfg: ModelConfig, cluster: Cluster,
                 scenario: Scenario, spec: api.SearchSpec,
                 entries: List[PoolPoint], chunk: int):
        self.cfg = cfg
        self.cluster = cluster
        self.scenario = scenario
        self.spec = spec
        self.entries = entries
        self.chunk = chunk
        self._degraded: Dict[Tuple[int, FaultSet], Tuple] = {}

    @property
    def full(self) -> PoolPoint:
        return self.entries[-1]

    def capacity_rps(self, entry: PoolPoint, mean_gen: float) -> float:
        return entry.point.throughput / max(mean_gen, 1.0)

    def est_iterations(self, trace: Trace) -> int:
        """Generous iteration-count bound for sizing a FailureInjector."""
        t_it = float(self.full.tpot[-1])
        return int(2 * trace.spec.horizon_s / t_it) + 4096

    def degraded_state(self, entry_idx: int, faults: FaultSet):
        """(plan, keep_curve, remap_curve) for a fault on one pool, cached.

        Curves are `api.tpot_curve` on the survivor sub-cluster for the
        plan's keep/remap points (None where that arm is infeasible). The
        policy search runs with tp='auto' (re-sharding is the point of the
        remap arm), same software variant as the pool's solved point.
        """
        key = (entry_idx, faults)
        if key in self._degraded:
            return self._degraded[key]
        entry = self.entries[entry_idx]
        pt = entry.point
        spec_f = api.SearchSpec(
            tp="auto", dbo=pt.used_dbo,
            sd=SpecDecConfig() if pt.used_sd else None,
            dtype=self.spec.dtype, faults=faults)
        sol = api.solve(self.cfg, entry.cluster, self.scenario, spec_f)
        plan = sol.plan
        cl_d = sweep.degraded_subcluster(entry.cluster, faults)

        def curve(p):
            if p is None or cl_d is None:
                return None
            return api.tpot_curve(self.cfg, cl_d, self.scenario,
                                  np.arange(1, p.batch + 1), point=p,
                                  dtype=self.spec.dtype)
        state = (plan, curve(plan.keep_point), curve(plan.remap_point))
        self._degraded[key] = state
        return state


def _chunk_pricing(cfg: ModelConfig, cluster: Cluster, scenario: Scenario,
                   point: OperatingPoint, mix, chunk: int,
                   dtype: str) -> Tuple[float, Dict[int, int]]:
    """(mixture-mean chunk time, prompt_len -> n_chunks) for one pool.

    Chunks run one per DP domain per carrying iteration
    (`optimizer.chunked_prefill_components`); the simulator charges each
    carrying iteration the MEAN chunk time of the arrival mix, weighted by
    how many chunks each prompt class contributes."""
    n = cluster.n_xpus
    domains = max(n // point.tp, 1)
    n_chunks: Dict[int, int] = {}
    t_sum = w_sum = 0.0
    for w, p_len, _ in mix:
        if p_len < 1:
            continue
        sizes, offsets = workload.chunk_schedule(p_len, chunk)
        n_chunks[p_len] = len(sizes)
        p_ch = ServingPoint(
            batch_global=domains, context=0, tp=point.tp,
            ep=max(point.ep, 1), n_devices=n, dtype=dtype, pp=point.pp,
            moe_load=placement.point_factors(cfg, scenario,
                                             max(point.ep, 1),
                                             point.extra_experts),
            moe_extra=point.extra_experts)
        times = [optimizer.prefill_chunk_components(
            cfg, replace(p_ch, context=off), cluster, s,
            dbo=point.used_dbo)[0] for s, off in zip(sizes, offsets)]
        t_sum += w * sum(times)
        w_sum += w * len(times)
    return (t_sum / w_sum if w_sum else 0.0), n_chunks


def build_catalog(cfg: ModelConfig, cluster: Cluster, scenario: Scenario,
                  spec: api.SearchSpec = api.SearchSpec(), *,
                  pool_fracs: Sequence[float] = (1.0,),
                  mix: Sequence[Tuple[float, int, int]] = ((1.0, 0, 1024),),
                  chunk: int = 512) -> Catalog:
    """Solve one operating point per pool size (carved by the
    disagg-pool conventions, `sweep._subcluster`) through `api.solve`,
    with TPOT curves from `api.tpot_curve` and chunk pricing for the
    arrival mix. Infeasible pools are dropped; the full pool must solve."""
    if spec.faults is not None or spec.mode != "decode":
        raise ValueError("catalogs are healthy decode-path searches; "
                         "faults enter per-event via FaultPlan")
    n = cluster.n_xpus
    sizes = sorted({max(int(round(n * f)), 1) for f in pool_fracs})
    if sizes[-1] != n:
        raise ValueError(f"pool_fracs must include 1.0 (full pool), got "
                         f"{pool_fracs}")
    entries: List[PoolPoint] = []
    for n_sub in sizes:
        pool = (cluster if n_sub == n
                else sweep._subcluster(cluster, n_sub))
        sol = api.solve(cfg, pool, scenario, spec)
        if sol.point is None:
            continue
        pt = sol.point
        curve = api.tpot_curve(cfg, pool, scenario,
                               np.arange(1, pt.batch + 1), point=pt,
                               dtype=spec.dtype, backend=spec.backend)
        chunk_time, _ = _chunk_pricing(cfg, pool, scenario, pt, mix, chunk,
                                       spec.dtype)
        entries.append(PoolPoint(cluster=pool, point=pt,
                                 tpot=np.asarray(curve),
                                 chunk_time=chunk_time,
                                 domains=max(n_sub // pt.tp, 1)))
    if not entries or entries[-1].n_xpus != n:
        raise ValueError("the full pool has no feasible operating point "
                         "for this scenario")
    return Catalog(cfg, cluster, scenario, spec, entries, chunk)


# ---------------------------------------------------------------------------
# policies and fault plans
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AutoscalePolicy:
    """Threshold autoscaler over the catalog's pool sizes.

    Every `check_interval_s` of sim time it estimates demand from the
    arrivals of the last interval PLUS the un-admitted backlog (cleared
    within one interval) and picks the smallest pool whose request
    capacity covers demand / `target_util`. A decided switch takes
    effect `switch_downtime_s` later (PR 6's remap downtime — the new
    pool re-shards while the old one keeps serving) and bills BOTH pool
    sizes during the overlap. Hysteresis is asymmetric, as in
    production autoscalers: scale-UP is decided at any check,
    scale-DOWN only after `min_dwell_s` since the last switch —
    reacting slowly to troughs costs energy, reacting slowly to ramps
    costs SLOs."""
    check_interval_s: float = 60.0
    target_util: float = 0.75
    min_dwell_s: float = 300.0
    switch_downtime_s: float = optimizer.REMAP_DOWNTIME_S


@dataclass
class FaultPlan:
    """Seeded fault events for one simulation: the injector fires at
    iteration indices; firing k consumes `faultsets[k]` (cycling), prices
    it through the remap-vs-degrade policy, and serves degraded until
    `repair_s` later. `downtime_s` is charged per re-shard (enter AND
    exit of a remap plan)."""
    injector: FailureInjector
    faultsets: Tuple[FaultSet, ...]
    repair_s: float = 1800.0
    downtime_s: float = optimizer.REMAP_DOWNTIME_S


def seeded_fault_plan(cluster: Cluster, *, n_iters: int,
                      rate_per_iter: float, seed: int = 0,
                      exposure_h: float = 24.0,
                      repair_s: float = 1800.0,
                      downtime_s: float = optimizer.REMAP_DOWNTIME_S
                      ) -> FaultPlan:
    """Deterministic fault plan: Bernoulli(rate)-per-iteration firing
    times (`FailureInjector.seeded`) with one non-empty seeded `FaultSet`
    per firing (`repro.faults.sample_faultset`)."""
    inj = FailureInjector.seeded(n_iters, rate_per_iter, seed)
    fss: List[FaultSet] = []
    k = 0
    for _ in inj.fail_at:
        fs = FaultSet(xpus=1)   # fallback if sampling never fires
        for _ in range(1024):
            cand = sample_faultset(cluster, exposure_h=exposure_h,
                                   seed=seed * 7919 + k)
            k += 1
            # sample_faultset pads mesh_links with zeros, so compare
            # component counts, not dataclass equality with FaultSet()
            if (any(cand.mesh_links) or cand.switch_planes
                    or cand.nics or cand.xpus):
                fs = cand
                break
        fss.append(fs)
    return FaultPlan(injector=inj, faultsets=tuple(fss),
                     repair_s=repair_s, downtime_s=downtime_s)


# ---------------------------------------------------------------------------
# simulation result
# ---------------------------------------------------------------------------

@dataclass
class TrafficResult:
    """Per-trace serving outcome. Times in seconds, rates cluster-wide."""
    n_requests: int
    n_iters: int
    elapsed_s: float
    attainment: float          # fraction of requests meeting BOTH SLOs
    goodput_tok_s: float       # decode tokens of SLO-meeting requests / s
    throughput_tok_s: float    # all served decode tokens / s
    ttft_p50: float
    ttft_p99: float
    tpot_p50: float
    tpot_p99: float
    n_ttft_miss: int
    n_tpot_miss: int
    active_frac: float         # time-weighted active-XPU fraction
    cost_month: float          # $ / month, XPU share billed by active_frac
    goodput_per_cost: float    # goodput_tok_s / cost_month
    n_switches: int
    n_fault_events: int
    mean_batch: float
    mean_in_system: float      # time-average requests in system (L)
    mean_sojourn_s: float      # mean arrival -> completion (W)
    arrival_rps: float         # completed-request rate (lambda)

    def as_dict(self) -> Dict[str, float]:
        out = {}
        for k, v in self.__dict__.items():
            out[k] = float(f"{v:.9g}") if isinstance(v, float) else v
        return out


def fleet_cost(cluster: Cluster, active_frac: float = 1.0,
               c: float = 1.0) -> float:
    """Monthly fleet cost with the XPU capex + energy share billed by the
    time-weighted active fraction — XPUs the autoscaler parks go back to
    the shared fleet and bill elsewhere, but the fabric is a fixed cost
    of the topology (you cannot scale away a fat-tree you already
    bought). `c` is the paper's network-cost adjustment factor."""
    bd = cluster_tco(cluster)
    return ((bd.monthly_xpu + bd.monthly_energy_xpu) * active_frac
            + c * (bd.monthly_switch + bd.monthly_link
                   + bd.monthly_energy_net * active_frac))


# ---------------------------------------------------------------------------
# the simulator
# ---------------------------------------------------------------------------

def _percentiles(x: np.ndarray) -> Tuple[float, float]:
    if x.size == 0:
        return 0.0, 0.0
    return (float(np.percentile(x, 50)), float(np.percentile(x, 99)))


def simulate_trace(catalog: Catalog, trace: Trace, *,
                   autoscale: Optional[AutoscalePolicy] = None,
                   faults: Optional[FaultPlan] = None,
                   start_entry: Optional[int] = None,
                   cost_c: float = 1.0,
                   max_iters: int = 4_000_000) -> TrafficResult:
    """Replay `trace` against `catalog`'s operating points.

    Static provisioning (autoscale=None) serves the whole trace on one
    entry (default: the full pool). With a policy, the simulator switches
    pools on observed demand; with a `FaultPlan`, injector firings become
    queueing events (see the module docstring). Deterministic: same
    inputs -> bit-identical result.
    """
    n = trace.n
    arr_t, gen = trace.t, trace.gen
    # chunk count per request (prompt prefill iterations)
    m_arr = np.zeros(n, np.int64)
    if n and trace.prompt.max() > 0:
        for p_len in np.unique(trace.prompt):
            if p_len > 0:
                m = len(workload.chunk_schedule(int(p_len),
                                                catalog.chunk)[0])
                m_arr[trace.prompt == p_len] = m

    entries = catalog.entries
    e_idx = len(entries) - 1 if start_entry is None else start_entry
    entry = entries[e_idx]
    cur_curve, cur_cap = entry.tpot, entry.cap
    cur_domains, cur_chunk_t = entry.domains, entry.chunk_time
    n_active = entry.n_xpus
    n_base = catalog.cluster.n_xpus
    mean_gen = trace.spec.mean_gen

    # per-request records (join iteration; -1 = never admitted)
    join_iter = np.full(n, -1, np.int64)
    t_end: List[float] = []            # end time of each iteration
    # future-iteration count arrays (batch completions / prefill-slot
    # releases land as np.add.at slices at admission time)
    cap_events = catalog.est_iterations(trace) + int(gen.max(initial=1)) \
        + int(m_arr.max(initial=0)) + 16
    finishing = np.zeros(cap_events, np.int64)
    pre_end = np.zeros(cap_events, np.int64)

    t = 0.0
    it = 0
    b = 0            # requests in the batch
    n_pre = 0        # of which still prefilling
    ptr = 0          # next arrival to admit
    done = 0         # completed requests

    # integrals for active-fraction / Little's law / mean batch
    active_int = 0.0
    system_int = 0.0
    batch_int = 0.0

    def advance(dt: float) -> None:
        nonlocal t, active_int, system_int, batch_int
        arrived = int(np.searchsorted(arr_t, t, side="right"))
        system_int += (arrived - done) * dt
        active_int += n_active * dt
        batch_int += b * dt
        t += dt

    # ---- autoscale / fault state ----
    policy = autoscale
    next_check = policy.check_interval_s if policy else math.inf
    last_switch = -math.inf
    n_switches = 0
    # (apply_at_t, target_entry): decided switch re-sharding in the
    # background while the current pool keeps serving
    pending_switch: Optional[Tuple[float, int]] = None

    fault_state: Optional[Tuple] = None     # (plan, keep_c, remap_c, kind)
    fault_restore = math.inf
    fault_drain = False
    degraded_serving = False
    n_fault_events = 0
    # run-local firing bookkeeping: `FailureInjector.check` mutates its
    # `fired` list, which would make a shared FaultPlan one-shot across
    # simulations — membership here keeps simulate_trace side-effect-free
    fail_set = (frozenset(faults.injector.fail_at) if faults is not None
                else frozenset())
    fired_local: set = set()

    healthy = (cur_curve, cur_cap, cur_domains, cur_chunk_t)

    def set_clock(curve, cap, domains, chunk_t):
        nonlocal cur_curve, cur_cap, cur_domains, cur_chunk_t
        cur_curve, cur_cap = curve, cap
        cur_domains, cur_chunk_t = domains, chunk_t

    def enter_degraded(plan, keep_c, remap_c):
        """Post-drain (or no-drain) switch onto the fault plan's serving
        arm; returns True if any serving curve exists."""
        nonlocal degraded_serving
        pt = plan.point
        curve = keep_c if plan.action == "keep" else remap_c
        if pt is None or curve is None:
            return False
        surv = sweep.degraded_subcluster(entries[e_idx].cluster,
                                         plan_faults[0])
        set_clock(curve, pt.batch, max(surv.n_xpus // pt.tp, 1),
                  entries[e_idx].chunk_time)
        degraded_serving = True
        return True

    plan_faults: List[FaultSet] = [FaultSet()]

    while ptr < n or b > 0:
        if it >= max_iters:
            raise RuntimeError(f"simulation exceeded {max_iters} "
                               "iterations; check offered load")
        # ---- fault injection (iteration boundary) ----
        if faults is not None and fault_state is None:
            if it in fail_set and it not in fired_local:
                fired_local.add(it)
                n_fault_events += 1
                fs = faults.faultsets[(n_fault_events - 1)
                                      % len(faults.faultsets)]
                plan_faults[0] = fs
                plan, keep_c, remap_c = catalog.degraded_state(e_idx, fs)
                fault_restore = t + faults.repair_s
                if plan.action == "down" or plan.point is None:
                    # nothing survives: stall until repair
                    advance(faults.repair_s)
                    fault_restore = math.inf
                elif plan.action == "keep":
                    fault_state = (plan, keep_c, remap_c, "keep")
                    enter_degraded(plan, keep_c, remap_c)
                else:  # remap: drain on the keep arm, then re-shard
                    fault_state = (plan, keep_c, remap_c, "remap")
                    if keep_c is not None and plan.keep_point is not None:
                        surv = sweep.degraded_subcluster(
                            entries[e_idx].cluster, fs)
                        set_clock(keep_c, plan.keep_point.batch,
                                  max(surv.n_xpus
                                      // plan.keep_point.tp, 1),
                                  entries[e_idx].chunk_time)
                        fault_drain = True
                    else:
                        # keep arm infeasible: requests stall through the
                        # re-shard downtime, then serve the remap arm
                        advance(faults.downtime_s)
                        if not enter_degraded(plan, keep_c, remap_c):
                            advance(max(fault_restore - t, 0.0))
                            fault_state, fault_restore = None, math.inf
        # ---- fault repair ----
        if fault_state is not None and t >= fault_restore and not fault_drain:
            plan = fault_state[0]
            if fault_state[3] == "remap" and degraded_serving:
                advance(faults.downtime_s)   # re-shard back
            set_clock(*healthy)
            fault_state, fault_restore = None, math.inf
            degraded_serving = False

        # ---- elective switch warmed up -> swap serving curves ----
        if pending_switch is not None:
            if fault_state is not None:
                # the fleet is busy surviving a fault: abandon the
                # elective re-shard (deterministically) and re-decide
                # after repair
                pending_switch = None
                n_active = entries[e_idx].n_xpus
            elif t >= pending_switch[0]:
                e_idx = pending_switch[1]
                entry = entries[e_idx]
                set_clock(entry.tpot, entry.cap, entry.domains,
                          entry.chunk_time)
                healthy = (cur_curve, cur_cap, cur_domains, cur_chunk_t)
                n_active = entry.n_xpus
                pending_switch = None
                last_switch = t
                n_switches += 1

        draining = fault_drain

        # ---- drain completion -> execute pending switch ----
        if b == 0 and fault_drain:
            fault_drain = False
            plan, keep_c, remap_c, _ = fault_state
            if t >= fault_restore:    # repaired before the drain finished
                set_clock(*healthy)
                fault_state, fault_restore = None, math.inf
            else:
                advance(faults.downtime_s)
                if not enter_degraded(plan, keep_c, remap_c):
                    advance(max(fault_restore - t, 0.0))
                    set_clock(*healthy)
                    fault_state, fault_restore = None, math.inf
            continue

        # ---- idle fast-forward ----
        if b == 0 and not draining:
            if ptr >= n:
                break
            if arr_t[ptr] > t:
                nxt = arr_t[ptr]
                if policy:
                    nxt = min(nxt, next_check)
                    if pending_switch is not None:
                        nxt = min(nxt, pending_switch[0])
                advance(max(nxt - t, 0.0))
                if pending_switch is not None and t >= pending_switch[0]:
                    continue    # apply the warmed-up switch first
        # ---- admissions ----
        if not draining and ptr < n and b < cur_cap:
            limit = int(np.searchsorted(arr_t, t, side="right"))
            k = min(limit - ptr, cur_cap - b)
            if k > 0:
                sl = slice(ptr, ptr + k)
                join_iter[sl] = it
                fin = it + m_arr[sl] + gen[sl] - 1
                if int(fin.max()) >= finishing.size:
                    grow = int(fin.max()) + cap_events
                    finishing = np.concatenate(
                        [finishing, np.zeros(grow - finishing.size,
                                             np.int64)])
                    pre_end = np.concatenate(
                        [pre_end, np.zeros(grow - pre_end.size, np.int64)])
                np.add.at(finishing, fin, 1)
                pre = m_arr[sl]
                if pre.max(initial=0) > 0:
                    np.add.at(pre_end, it + pre[pre > 0], 1)
                    n_pre += int((pre > 0).sum())
                b += k
                ptr += k

        # ---- one decode iteration ----
        if b > 0:
            n_pre -= int(pre_end[it])
            if b <= cur_cap:
                dt = float(cur_curve[b - 1])
            else:
                # over-capacity (degraded cap below in-flight batch):
                # serve in cap-sized waves
                dt = float(cur_curve[cur_cap - 1]) * (b / cur_cap)
            if n_pre > 0:
                dt += math.ceil(n_pre / cur_domains) * cur_chunk_t
            advance(dt)
            t_end.append(t)
            fin = int(finishing[it])
            b -= fin
            done += fin
            it += 1

        # ---- autoscale control loop ----
        if policy and t >= next_check and fault_state is None:
            w0 = t - policy.check_interval_s
            arrived = int(np.searchsorted(arr_t, t, side="right"))
            seen = arrived - int(np.searchsorted(arr_t, w0, side="right"))
            backlog = arrived - ptr       # waiting, not yet admitted
            demand = (seen + backlog) / policy.check_interval_s
            want = len(entries) - 1
            for i, e in enumerate(entries):
                if demand <= (policy.target_util
                              * catalog.capacity_rps(e, mean_gen)):
                    want = i
                    break
            if pending_switch is None and (
                    want > e_idx or (want < e_idx and t - last_switch
                                     >= policy.min_dwell_s)):
                pending_switch = (t + policy.switch_downtime_s, want)
                # both pools powered while the target re-shards
                n_active = max(entries[e_idx].n_xpus,
                               entries[want].n_xpus)
            next_check = t + policy.check_interval_s

    elapsed = max(t, trace.spec.horizon_s)
    # the pool stays provisioned through the idle tail after the last
    # completion (static = full price for the whole horizon)
    active_int += n_active * max(elapsed - t, 0.0)
    t_end_a = np.asarray(t_end)
    n_iters = len(t_end)

    served = join_iter >= 0
    if n == 0 or not served.any():
        ttft = tpot_req = np.zeros(0)
        meets = np.zeros(0, bool)
        goodput = thr = 0.0
        sojourn = 0.0
    else:
        ji = join_iter[served]
        first = t_end_a[ji + m_arr[served]]
        last = t_end_a[ji + m_arr[served] + gen[served] - 1]
        ttft = first - arr_t[served]
        g = gen[served]
        tpot_req = np.where(g > 1, (last - first) / np.maximum(g - 1, 1),
                            0.0)
        sc = catalog.scenario
        ttft_slo = sc.ttft_ms * 1e-3 if sc.ttft_ms > 0 else math.inf
        tpot_slo = sc.tpot_ms * 1e-3
        ok_ttft = ttft <= ttft_slo * (1 + 1e-9)
        ok_tpot = tpot_req <= tpot_slo * (1 + 1e-9)
        meets = ok_ttft & ok_tpot
        goodput = float(g[meets].sum()) / elapsed
        thr = float(g.sum()) / elapsed
        sojourn = float(np.mean(last - arr_t[served]))

    active_frac = active_int / (n_base * elapsed) if elapsed else 1.0
    cost = fleet_cost(catalog.cluster, active_frac, cost_c)
    p50_t, p99_t = _percentiles(ttft)
    p50_p, p99_p = _percentiles(tpot_req)
    n_served = int(served.sum())
    return TrafficResult(
        n_requests=n,
        n_iters=n_iters,
        elapsed_s=elapsed,
        attainment=float(meets.mean()) if n_served else 1.0,
        goodput_tok_s=goodput,
        throughput_tok_s=thr,
        ttft_p50=p50_t, ttft_p99=p99_t,
        tpot_p50=p50_p, tpot_p99=p99_p,
        n_ttft_miss=int((~ok_ttft).sum()) if n_served else 0,
        n_tpot_miss=int((~ok_tpot).sum()) if n_served else 0,
        active_frac=active_frac,
        cost_month=cost,
        goodput_per_cost=goodput / cost if cost else 0.0,
        n_switches=n_switches,
        n_fault_events=n_fault_events,
        mean_batch=batch_int / elapsed if elapsed else 0.0,
        mean_in_system=system_int / elapsed if elapsed else 0.0,
        mean_sojourn_s=sojourn,
        arrival_rps=n_served / elapsed if elapsed else 0.0,
    )


def best_provisioning(catalog: Catalog, trace: Trace, *,
                      policies: Sequence[Optional[AutoscalePolicy]],
                      faults: Optional[FaultPlan] = None,
                      cost_c: float = 1.0
                      ) -> Tuple[str, TrafficResult]:
    """Run `trace` under each provisioning arm (None = static full pool)
    and keep the best goodput-per-cost. Because the static arm is always
    in the menu, the winner never loses to static provisioning — the
    same never-loses construction as the placement search."""
    best_name, best = None, None
    for pol in policies:
        res = simulate_trace(catalog, trace, autoscale=pol, faults=faults,
                             cost_c=cost_c)
        name = "static" if pol is None else (
            f"autoscale@{pol.target_util:g}")
        if best is None or res.goodput_per_cost > best.goodput_per_cost:
            best_name, best = name, res
    return best_name, best
