"""XPU generation specs (paper section 3.2 setup + Table 5 scaling).

The paper bases its model on NVIDIA Hopper and projects Blackwell/Rubin with
the Table 5 multipliers. We add TPU v5e — the execution target of the JAX
half of this repo — parameterizing the same methodology (DESIGN.md section 3).

Layer: leaf data (no dependencies inside core); every engine reads the
same spec objects, so there is nothing parity-sensitive here.
"""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class XPUSpec:
    name: str
    flops_fp8: float            # FLOP/s dense
    flops_bf16: float
    hbm_bw: float               # B/s
    hbm_cap: float              # bytes
    scale_up_bw: float          # B/s unidirectional per XPU (the "1x" provision)
    scale_out_bw: float         # B/s per XPU
    tdp_w: float
    cost_usd: float             # CapEx per XPU (catalog-ish; normalized in reports)


H100 = XPUSpec(
    name="H100",
    flops_fp8=1979e12,
    flops_bf16=989e12,
    hbm_bw=3.35e12,
    hbm_cap=80e9,
    scale_up_bw=450e9,
    scale_out_bw=50e9,
    tdp_w=700.0,
    cost_usd=30_000.0,
)

# Table 5 relative scaling vs Hopper (H100 = 1x)
BLACKWELL = XPUSpec(
    name="Blackwell",
    flops_fp8=1979e12 * 2.56,
    flops_bf16=989e12 * 2.56,
    hbm_bw=3.35e12 * 2.39,
    hbm_cap=80e9 * 2.33,
    scale_up_bw=900e9,          # 2.00x
    scale_out_bw=100e9,
    tdp_w=1000.0,
    cost_usd=40_000.0,
)

RUBIN = XPUSpec(
    name="Rubin",
    flops_fp8=1979e12 * 4.49,
    flops_bf16=989e12 * 4.49,
    hbm_bw=3.35e12 * 6.57,
    hbm_cap=80e9 * 3.60,
    scale_up_bw=1800e9,         # 4.00x
    scale_out_bw=200e9,
    tdp_w=1800.0,
    cost_usd=55_000.0,
)

TPU_V5E = XPUSpec(
    name="TPUv5e",
    flops_fp8=394e12,           # int8
    flops_bf16=197e12,
    hbm_bw=819e9,
    hbm_cap=16e9,
    scale_up_bw=200e9,          # 4 ICI links x ~50 GB/s (native 3D torus)
    scale_out_bw=25e9,
    tdp_w=220.0,
    cost_usd=5_000.0,
)

GENERATIONS = {g.name: g for g in (H100, BLACKWELL, RUBIN, TPU_V5E)}


def with_link_bw(spec: XPUSpec, scale_up_bw: float) -> XPUSpec:
    """Hypothetical link-bandwidth provision (the paper's BW sweeps)."""
    return replace(spec, scale_up_bw=scale_up_bw)
