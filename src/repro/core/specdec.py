"""Speculative decoding TPOT model (paper section 3.3).

Multi-head (Medusa-style) SD:

  TPOT = (t_draft + t_verify) / (spec_m * spec_p)

t_draft  = one normal decode iteration (the target model step that also
           produces the draft heads' proposals).
t_verify = one iteration where attention q_len = spec_m and every other op
           sees batch * spec_m rows.

Defaults (spec_m, spec_p) = (4, 0.8) per the paper.

Layer: a combinator over iteration times — the scalar path feeds it
`optimizer.iteration_time`, the batched engines feed it
`GridEval.best_iteration(q)`; the 1e-9 parity contract covers the
combined TPOT because both sides evaluate this same formula.
"""
from __future__ import annotations

from dataclasses import dataclass

SPEC_M_DEFAULT = 4
SPEC_P_DEFAULT = 0.8


@dataclass(frozen=True)
class SpecDecConfig:
    spec_m: int = SPEC_M_DEFAULT
    spec_p: float = SPEC_P_DEFAULT

    @property
    def tokens_per_iteration(self) -> float:
        return self.spec_m * self.spec_p


def sd_tpot(t_draft: float, t_verify: float,
            sd: SpecDecConfig = SpecDecConfig()) -> float:
    return (t_draft + t_verify) / sd.tokens_per_iteration
