"""Availability-adjusted serving throughput under component failures.

The paper's cost-effectiveness ranking (fig14/fig17) is evaluated on a
healthy cluster, but the four fabrics fail very differently: a mesh has
thousands of individually-failable cables and degrades gracefully via
detours, while a switched fabric concentrates failures into a few
high-blast-radius switch planes. This module prices that difference:

  1. `component_inventory` derives per-cluster component counts from the
     same inventory the TCO model charges (links by cable class via
     `Cluster.link_inventory` / `mesh_link_counts`, switch ASICs via the
     `switch_capacity_total` sizing, NICs, XPUs) and attaches per-class
     MTBF/MTTR defaults (documented in docs/failure_model.md).
  2. `build_availability` maps every fault state up to `max_total_faults`
     onto a `FaultSet`, prices it through the failure-aware re-search with
     the remap-vs-degrade policy (`optimizer.degrade_policy`), and caches
     the per-state throughputs.
  3. `AvailabilityModel.report(mtbf_scale)` computes the stationary
     probability of each state — closed-form binomial for the single-fault
     states, the same pmf vectorized (NumPy outer products over the state
     grid, the `core/sweep.py` idiom) for the multi-fault enumeration —
     and returns the expected steady-state throughput. Unenumerated
     deeper states are lumped into the tail at zero throughput (a
     conservative under-estimate), and per-event transition losses (the
     in-flight-collective retry/timeout penalty plus any re-shard
     downtime) are charged against the failure arrival rates.

Separating (2) from (3) makes MTBF sweeps cheap: the expensive degraded
searches run once per cluster, then `report` re-weights them per failure
rate — how `benchmarks/fig_failures.py` finds the crossover MTBF.

Layer: probability weighting above the degraded sweep
(`sweep.degraded_max_throughput`); the underlying searches keep the
sweep layer's scalar/batched parity, and the stationary weighting is
plain float arithmetic on top.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.topology import Cluster, FaultSet

HOURS_TO_S = 3600.0

# ---------------------------------------------------------------------------
# in-flight collective retry/timeout model
# ---------------------------------------------------------------------------

# NCCL-style watchdog: a collective whose peer died hangs until the
# timeout fires before the runtime tears the group down and retries.
COLLECTIVE_TIMEOUT_S = 0.5


def straddle_penalty(t_iter_degraded: float, *,
                     timeout_s: float = COLLECTIVE_TIMEOUT_S,
                     retries: int = 1) -> float:
    """Seconds lost by an iteration whose in-flight collective straddles a
    failure: the op hangs to the watchdog timeout, then the iteration
    replays on the (already derated) surviving fabric `retries` times at
    worst. The pre-failure partial iteration is discarded, so the replay
    is charged in full."""
    if timeout_s < 0 or retries < 0:
        raise ValueError("timeout_s and retries must be >= 0")
    return timeout_s + retries * t_iter_degraded


# ---------------------------------------------------------------------------
# component inventory
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ComponentClass:
    """One failable component class: `count` identical units, each with
    the given MTBF/MTTR (hours). Stationary per-unit unavailability is the
    classic MTTR / (MTBF + MTTR) of an alternating renewal process."""
    name: str                  # "xpu" | "link_copper" | "link_aoc"
    count: int                 # | "switch" | "nic"
    mtbf_h: float
    mttr_h: float

    def unavailability(self, mtbf_scale: float = 1.0) -> float:
        return self.mttr_h / (self.mtbf_h * mtbf_scale + self.mttr_h)

    def event_rate_per_s(self, mtbf_scale: float = 1.0) -> float:
        """Fleet-wide failure arrivals of this class, events/second."""
        return self.count / (self.mtbf_h * mtbf_scale * HOURS_TO_S)


# Per-class MTBF/MTTR defaults (hours). Sources in docs/failure_model.md:
# XPU ~5e4 h matches the 15-20 %/yr accelerator annual failure rates of
# published large-fleet training post-mortems; optical transceivers/AOCs
# fail an order of magnitude more often than passive copper DACs; switch
# ASICs sit between; repair times are cable-swap vs. board-swap scale.
MTBF_MTTR_H: Dict[str, Tuple[float, float]] = {
    "xpu": (5.0e4, 24.0),
    "link_copper": (5.0e6, 2.0),
    "link_aoc": (7.5e5, 2.0),
    "switch": (2.0e5, 8.0),
    "nic": (1.0e6, 4.0),
}


def component_inventory(cluster: Cluster,
                        mtbf_mttr: Optional[Dict[str, Tuple[float, float]]]
                        = None) -> List[ComponentClass]:
    """Failable components of one cluster, counts derived from the same
    inventory the TCO model prices. The XPU row is fabric-agnostic; the
    network rows come from the fabric's `net_component_classes` hook
    (core/fabric.py): mesh links split copper/AOC by the `link_inventory`
    bandwidth fractions over the exact physical link count; switched
    fabrics carry XPU-to-leaf cables (copper), leaf-spine cables (AOC,
    two-level only), and switch ASICs; scale-out carries one NIC per XPU
    whose loss orphans the whole NODE_XPUS node; the OCS fabric carries
    transceiver-terminated fibers and MEMS switches."""
    mm = dict(MTBF_MTTR_H)
    if mtbf_mttr:
        mm.update(mtbf_mttr)

    def cls(name: str, count: int) -> ComponentClass:
        mtbf, mttr = mm[name]
        return ComponentClass(name=name, count=count, mtbf_h=mtbf,
                              mttr_h=mttr)

    out = [cls("xpu", cluster.n_xpus)]
    out.extend(cluster.fabric.net_component_classes(cluster, cls))
    return [c for c in out if c.count > 0]


# ---------------------------------------------------------------------------
# fault-state -> FaultSet mapping
# ---------------------------------------------------------------------------

def faultset_for_counts(cluster: Cluster,
                        counts: Dict[str, int]) -> FaultSet:
    """Map per-class failure counts onto the `FaultSet` the serving model
    consumes, encoding each topology's blast radius — the fabric's
    `faultset_for_counts` hook (core/fabric.py):

    meshes      link failures spread over dims (`_spread_mesh_links`,
                longest dims first — the adversarial placement);
    scale-up    a severed XPU-to-leaf cable idles one of that XPU's rails,
                and collectives synchronize on the slowest rank, so it
                derates like a plane; switch/AOC failures likewise;
    scale-out   a severed XPU cable is NIC-equivalent (the node's only
                path); a fabric-switch failure disconnects its whole
                down-port span of XPUs (`switch_blast_xpus`); leaf-spine
                AOC loss is absorbed by the non-blocking tree (a known
                under-estimate, noted in docs/failure_model.md);
    ocs         fiber / MEMS failures idle port planes, the scale-up rail
                model over OCS_PORTS.
    """
    return cluster.fabric.faultset_for_counts(cluster, counts)


# ---------------------------------------------------------------------------
# stationary expectation
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FaultStateEval:
    counts: Tuple[int, ...]        # per component class, classes order
    faults: FaultSet
    throughput: float              # effective tokens/s under the policy
    action: str                    # degrade_policy action for this state


@dataclass(frozen=True)
class AvailabilityReport:
    expected_throughput: float     # tokens/s, stationary expectation
    healthy_throughput: float
    availability: float            # expected / healthy (0 when down)
    tail_mass: float               # P(unenumerated deeper states) -> thr 0
    transition_loss: float         # tokens/s charged to failure events
    mtbf_scale: float
    state_probs: Tuple[float, ...]


@dataclass(frozen=True)
class AvailabilityModel:
    """Per-(cluster, model, scenario) cache of fault states and their
    degraded throughputs; `report` re-weights them per failure rate."""
    cluster: Cluster
    classes: Tuple[ComponentClass, ...]
    states: Tuple[FaultStateEval, ...]
    healthy_throughput: float
    healthy_tpot: float            # seconds (straddle replay cost scale)
    remap_downtime_s: float

    def _probs(self, mtbf_scale: float) -> np.ndarray:
        """Stationary P(state) for every enumerated state, vectorized:
        per-class truncated-binomial tables combine by outer product over
        the state grid. Single-fault states reduce to the closed form
        C(N,1) u (1-u)^(N-1) exactly."""
        grid = np.array([s.counts for s in self.states], np.int64)
        probs = np.ones(len(self.states))
        for ci, c in enumerate(self.classes):
            u = c.unavailability(mtbf_scale)
            kmax = int(grid[:, ci].max()) if len(grid) else 0
            table = np.array([math.comb(c.count, k) * u ** k
                              * (1 - u) ** (c.count - k)
                              for k in range(kmax + 1)])
            probs *= table[grid[:, ci]]
        return probs

    def report(self, mtbf_scale: float = 1.0) -> AvailabilityReport:
        probs = self._probs(mtbf_scale)
        expected = float(probs @ np.array([s.throughput
                                           for s in self.states]))
        tail = max(1.0 - float(probs.sum()), 0.0)
        # per-event transient: the straddling collective hangs to the
        # timeout and the iteration replays; a remap decision additionally
        # pays the re-shard downtime. Charged at the healthy rate —
        # that is what the event interrupts.
        loss = 0.0
        single = {s.counts: s for s in self.states if sum(s.counts) == 1}
        for ci, c in enumerate(self.classes):
            key = tuple(1 if i == ci else 0
                        for i in range(len(self.classes)))
            st = single.get(key)
            if st is None:
                continue
            penalty = straddle_penalty(self.healthy_tpot)
            if st.action == "remap":
                penalty += self.remap_downtime_s
            loss += (c.event_rate_per_s(mtbf_scale) * penalty
                     * self.healthy_throughput)
        expected = max(expected - loss, 0.0)
        avail = (expected / self.healthy_throughput
                 if self.healthy_throughput else 0.0)
        return AvailabilityReport(
            expected_throughput=expected,
            healthy_throughput=self.healthy_throughput,
            availability=avail, tail_mass=tail, transition_loss=loss,
            mtbf_scale=mtbf_scale,
            state_probs=tuple(float(p) for p in probs))


def _enumerate_counts(classes: Sequence[ComponentClass],
                      max_total: int) -> List[Tuple[int, ...]]:
    """All per-class fault-count vectors with sum <= max_total (and k_c
    <= count_c), the zero state first."""
    caps = [min(c.count, max_total) for c in classes]
    grids = np.meshgrid(*[np.arange(cap + 1) for cap in caps],
                        indexing="ij")
    grid = np.stack([g.ravel() for g in grids], axis=-1)
    grid = grid[grid.sum(axis=1) <= max_total]
    return sorted(map(tuple, grid.tolist()), key=lambda t: (sum(t), t))


def build_availability(cluster: Cluster, cfg: ModelConfig, scenario, *,
                       max_total_faults: int = 2,
                       tp="auto", pp=1, dtype: str = "fp8",
                       dbo: bool = False, sd=None,
                       remap_downtime_s: Optional[float] = None,
                       horizon_s: Optional[float] = None,
                       mtbf_mttr: Optional[Dict[str, Tuple[float, float]]]
                       = None) -> AvailabilityModel:
    """Enumerate and price every fault state of `cluster` up to
    `max_total_faults` simultaneous failures.

    Each state maps to a `FaultSet` (`faultset_for_counts`), runs the
    failure-aware re-search under the remap-vs-degrade policy
    (`optimizer.degrade_policy`, baseline = the healthy operating point),
    and records the policy's effective throughput. States sharing a
    FaultSet share one search. The healthy (zero-fault) state prices
    through the ordinary search, byte-identical to the paper's model."""
    from repro.core import optimizer, sweep

    rd = optimizer.REMAP_DOWNTIME_S if remap_downtime_s is None \
        else remap_downtime_s
    hz = optimizer.DEGRADED_HORIZON_S if horizon_s is None else horizon_s
    classes = tuple(component_inventory(cluster, mtbf_mttr))
    baseline = sweep.sweep_max_throughput([cluster], cfg, [scenario], tp=tp,
                                          pp=pp, dtype=dtype, dbo=dbo,
                                          sd=sd)[0][0]
    healthy_thr = baseline.throughput if baseline else 0.0
    healthy_tpot = baseline.tpot if baseline else 0.0

    states: List[FaultStateEval] = []
    by_faultset: Dict[FaultSet, Tuple[float, str]] = {}
    for counts_vec in _enumerate_counts(classes, max_total_faults):
        counts = {c.name: k for c, k in zip(classes, counts_vec)}
        if sum(counts_vec) == 0:
            states.append(FaultStateEval(counts_vec, FaultSet(),
                                         healthy_thr, "healthy"))
            continue
        fs = faultset_for_counts(cluster, counts)
        if fs not in by_faultset:
            plan = optimizer.degrade_policy(
                cluster, cfg, scenario, fs, baseline=baseline,
                remap_downtime_s=rd, horizon_s=hz, tp=tp, pp=pp,
                dtype=dtype, dbo=dbo, sd=sd)
            by_faultset[fs] = (plan.effective_throughput, plan.action)
        thr, action = by_faultset[fs]
        states.append(FaultStateEval(counts_vec, fs, thr, action))
    return AvailabilityModel(cluster=cluster, classes=classes,
                             states=tuple(states),
                             healthy_throughput=healthy_thr,
                             healthy_tpot=healthy_tpot,
                             remap_downtime_s=rd)
