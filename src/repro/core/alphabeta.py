"""Extended alpha-beta (Hockney) communication model — paper section 3.2.2.

  T = alpha0 + R * alpha_r + D * alpha_d + coeff * m * beta
  beta = 1 / (link_utilization * peak_bandwidth)

alpha0   one-time launch latency per collective
alpha_r  per-communication-round latency (captures A2A growth with XPU count)
alpha_d  per-destination serialization cost
R, D, coeff come from the collective algorithm (core.collectives, Table 3).

Fitted values (paper Table 1, NCCL on DGX H100) are the defaults; the fitting
code itself (fit_alpha_beta) is exercised on synthetic data in
benchmarks/table1_alphabeta.py to validate the methodology.

Layer: leaf of the comm stack — consumed by `core.collectives` (which
supplies R, D, coeff) and `core.topology.Cluster.comm_spec`; depends on
nothing above it. Pure float arithmetic, identical on every path (scalar,
batched, jax), so it has no separate parity contract of its own.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class AlphaBeta:
    alpha0: float           # seconds
    alpha_r: float
    alpha_d: float
    link_utilization: float

    def time(self, *, rounds: float, dests: float, m_coeff: float,
             m_bytes: float, bandwidth: float) -> float:
        beta = 1.0 / (self.link_utilization * bandwidth)
        return (self.alpha0 + rounds * self.alpha_r + dests * self.alpha_d
                + m_coeff * m_bytes * beta)


# paper Table 1
INTRA_NODE = AlphaBeta(alpha0=5.874e-6, alpha_r=0.809e-6, alpha_d=0.323e-6,
                       link_utilization=0.717)
INTER_NODE = AlphaBeta(alpha0=26.508e-6, alpha_r=1.358e-6, alpha_d=0.340e-6,
                       link_utilization=0.843)

# scale-up domains beyond one node behave like the inter-node fit; the paper
# uses the inter-node parameters for cluster-scale collectives.
CLUSTER = INTER_NODE


def fit_alpha_beta(rounds, dests, m_bytes, bandwidth, times):
    """Least-squares fit of (alpha0, alpha_r, alpha_d, utilization) from
    measured collective times — the paper's Table 1 procedure.

    All args are 1-D arrays over measurements. Returns AlphaBeta.
    """
    rounds = np.asarray(rounds, float)
    dests = np.asarray(dests, float)
    m = np.asarray(m_bytes, float)
    times = np.asarray(times, float)
    # linear model: t = a0 + ar*R + ad*D + (1/(u*bw)) * m   (coeff folded in m)
    A = np.stack([np.ones_like(rounds), rounds, dests, m / bandwidth], axis=1)
    x, *_ = np.linalg.lstsq(A, times, rcond=None)
    a0, ar, ad, inv_u = x
    util = 1.0 / max(inv_u, 1e-9)
    return AlphaBeta(alpha0=max(a0, 0.0), alpha_r=max(ar, 0.0),
                     alpha_d=max(ad, 0.0),
                     link_utilization=float(np.clip(util, 0.05, 1.0)))


def mean_relative_error(model_times, actual_times) -> float:
    model_times = np.asarray(model_times, float)
    actual_times = np.asarray(actual_times, float)
    return float(np.mean(np.abs(actual_times - model_times) / actual_times))
