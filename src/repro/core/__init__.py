"""The paper's primary contribution: a cross-layer cost-effectiveness
methodology for MoE LLM serving networks.

  alphabeta    extended Hockney communication model (paper Table 1)
  collectives  AR/A2A algorithm cost formulas (Tables 2-3)
  fabric       pluggable Fabric registry: per-topology collective menus,
               fault derating, survivor accounting, TCO inventory hooks
               (scale-up / scale-out / torus / full-mesh + the
               reconfigurable optical circuit-switched fabric)
  topology     the Cluster facade delegating to the registered fabrics
  hardware     XPU generations (H100, Blackwell, Rubin, TPU v5e; Table 5)
  compute_model roofline-with-efficiency per-layer compute times
  workload     MoE decode/prefill iterations -> ordered op lists (per-device)
  placement    expert-routing skew (Zipf load factors) + replication/
               placement search spending HBM headroom on hot experts
  overlap      DBO three-lane (max,+) scheduler (compute / collectives /
               pp send-recv) -> exposed communication time
  specdec      speculative decoding TPOT model
  tco          CapEx/OpEx cluster cost model (+ adjustment factor c)
  optable      decode/prefill op lists lowered to coefficient arrays
  sweep        batched operating-point search (vectorized alpha-beta + DBO,
               chunked / disaggregated prefill serving modes, hybrid
               (tp, pp, ep) parallelism-mapping search)
  optimizer    max-throughput-under-SLO sweep (+ remap-vs-degrade policy)
  api          THE public search surface: SearchSpec + solve()/solve_grid()
               routing decode / prefill / degraded searches (the legacy
               optimizer wrappers are deprecated shims onto it)
  traffic      cluster-scale continuous-batching traffic simulator (seeded
               arrival traces, queueing, autoscaling, fault events) on top
               of solved operating points
  pareto       performance-vs-cost sweep + Pareto frontier (Fig 17)
  future       Blackwell/Rubin saturating-bandwidth projection (Fig 18/19)
  availability component MTBF/MTTR -> stationary expected throughput
               under the per-topology fault derating (FaultSet)
"""
from repro.core.alphabeta import AlphaBeta, INTRA_NODE, INTER_NODE, CLUSTER
from repro.core.api import (ReproDeprecationWarning, SearchSpec, Solution,
                            solve, solve_grid, solve_levels, tpot_curve)
from repro.core.availability import (AvailabilityModel, ComponentClass,
                                     build_availability)
from repro.core.fabric import FABRICS, Fabric, get_fabric, register_fabric
from repro.core.hardware import (H100, BLACKWELL, RUBIN, TPU_V5E, GENERATIONS,
                                 XPUSpec)
from repro.core.optimizer import (Scenario, SCENARIOS, best_of_opts,
                                  best_of_opts_scalar, max_throughput,
                                  max_throughput_prefill,
                                  max_throughput_scalar, degrade_policy,
                                  DegradedPlan, PrefillOperatingPoint)
from repro.core.specdec import SpecDecConfig
from repro.core.sweep import degraded_max_throughput, parallelism_candidates
from repro.core.topology import (Cluster, FaultSet, make_cluster,
                                 TOPOLOGIES)
from repro.core.traffic import (AutoscalePolicy, Catalog, FaultPlan,
                                TraceSpec, TrafficResult,
                                best_provisioning, build_catalog,
                                fleet_cost, generate_trace,
                                seeded_fault_plan, simulate_trace)
from repro.core.tco import (availability_adjusted_throughput_per_cost,
                            cluster_tco, throughput_per_cost)
from repro.core.workload import ServingPoint
