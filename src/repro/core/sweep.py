"""Batched sweep engine: the operating-point search as array programs.

The optimizer's search space is a batch-grid x {dbo, sd} x scenario x
topology cross-product; the seed implementation walked it one scalar Python
evaluation at a time, rebuilding the decode op list at every point. This
module evaluates the whole grid with NumPy broadcasts over a precomputed
`optable.OpTable`:

  compute times   roofline closed forms over (batch, q_len, context), with
                  the thin-GEMM efficiency switch applied elementwise
  alpha-beta comm each cluster's collective-algorithm menu lowered to
                  (A, B) pairs so t = min_alg(A + B * m) broadcasts over the
                  payload grid
  DBO             the three-lane fixed-order schedule (compute / comm /
                  pp send-recv) is a (max,+) recurrence in the op order
                  (see overlap.simulate_lanes), so it vectorizes exactly
                  over the grid: same max/add operations, batched over
                  trailing axes — for decode iterations, prefill chunks,
                  and the disaggregated whole-prompt pass alike

`batched_tpot` matches the scalar `optimizer.tpot_at` to float rounding
(~1e-15 relative; asserted at 1e-9 in tests/test_sweep.py). Selection
(feasibility + argmax) runs on the batched values; the single winning point
is then re-evaluated through the exact scalar path so the returned
`OperatingPoint` is byte-identical to the seed implementation.

Backends: every entry point takes `backend="numpy" | "jax"` (default
"numpy", overridable via the `REPRO_SWEEP_BACKEND` env var or
`set_default_backend`). "numpy" is THE reference — 1e-9-vs-scalar, and the
path every committed figure regenerates through, byte-identical. "jax"
delegates the two heavy primitives (no-overlap duration sums and the DBO
makespan) to `core/sweep_jax.py`'s jitted kernels — one `lax.scan` device
program per grid under `enable_x64`, <= 1e-6 relative vs the reference
(~1e-12 in practice) and >= 10x faster on 10^6-point product grids
(BENCH_sweep_timing.json). Selection and the scalar re-derivation of each
argmax winner are shared NumPy code, so both backends return bit-identical
`OperatingPoint`s whenever their argmax agrees; see docs/sweep_engine.md
for the contract.

Hybrid parallelism (tp="auto" / pp="auto"): the search grows a joint
(tp, pp, ep = n/(tp*pp)) mapping axis. `parallelism_candidates` enumerates
the valid mappings (head/expert divisibility, device- and layer-count
constraints on (tp, pp), weight-shard feasibility with the per-stage shard
divided by tp*pp), each candidate runs the same batched engine against its
own op table with the collectives PLACED by the topology
(`Cluster.comm_spec`: AR(tp) over the scale-up / mesh neighborhood, expert
A2A over the stage's quotient, pp hops on the stage-boundary link), and
each (cluster, scenario) cell keeps the highest-throughput mapping — ties
to the smallest (tp, pp) lexicographically, so fixed-mapping (tp=1, pp=1)
results are byte-identical to the seed.

Expert-load skew (`Scenario(routing="zipf", ...)`, see `core.placement`):
tables stay UNIFORM — skew enters as per-op constant multipliers
(`op_load_factors`: lf scales the row-linear flops/bytes/payload of the
expert GEMM and A2As per scenario, cf scales the expert weight stream
under replication) applied inside `GridEval._durations`, so no new table
cache keys and no new probe points. `load=None` (every scenario uniform,
no replicas) skips the factor path entirely — structural byte-identity,
not a numerical coincidence. placement="auto" wraps the fixed-mapping
search in a replica-count loop (`_placement_candidates`) merged R=0-first
through the same strict-> `_merge_best`, so the placement search can
never lose to no-placement and uniform scenarios keep the R=0 arm.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import optable, placement, workload
from repro.core.compute_model import (EFF_MEMORY, GEMM_SMALL_TOKENS,
                                      T_LAUNCH)
from repro.core.optable import OpTable
from repro.core.overlap import LANES, MAX_STAGGER
from repro.core.specdec import SpecDecConfig
from repro.core.topology import Cluster
from repro.core.workload import ServingPoint


# ---------------------------------------------------------------------------
# backend seam
# ---------------------------------------------------------------------------

BACKENDS = ("numpy", "jax")
_DEFAULT_BACKEND = os.environ.get("REPRO_SWEEP_BACKEND", "numpy")


def set_default_backend(backend: str) -> str:
    """Set the process-wide default backend ("numpy" | "jax"); returns the
    previous default. Explicit `backend=` arguments always win over this."""
    global _DEFAULT_BACKEND
    if backend not in BACKENDS:
        raise ValueError(f"unknown sweep backend {backend!r}; "
                         f"expected one of {BACKENDS}")
    prev, _DEFAULT_BACKEND = _DEFAULT_BACKEND, backend
    return prev


def _resolve_backend(backend: Optional[str]) -> str:
    b = backend if backend is not None else _DEFAULT_BACKEND
    if b not in BACKENDS:
        raise ValueError(f"unknown sweep backend {b!r}; "
                         f"expected one of {BACKENDS}")
    if b == "jax":
        from repro.core import sweep_jax
        sweep_jax.require_jax()
    return b


# ---------------------------------------------------------------------------
# per-cluster alpha-beta lowering
# ---------------------------------------------------------------------------

_KIND_NAMES = {optable.KIND_A2A: "a2a", optable.KIND_AR: "ar",
               optable.KIND_PP: "pp_sendrecv"}


def _comm_menu_coeffs(cluster: Cluster, kind: int, group: int,
                      tp: int = 1, pp: int = 1) -> List[Tuple[float, float]]:
    """Lower one collective menu to (A, B) pairs: t(m) = min_alg(A + B*m).

    A carries the alpha terms exactly as `AlphaBeta.time` associates them;
    B*m keeps the scalar's (m_coeff * m) * beta association elementwise, so
    the batched time equals the scalar time to the rounding of the shared
    subexpressions. The menu, bandwidth, and alpha set come from the
    cluster's `comm_spec` placement under the (tp, pp, ep) mapping —
    identical to the seed whole-cluster lowering at tp=1, pp=1.
    """
    menu, bw, ab = cluster.comm_spec(_KIND_NAMES[kind], group, tp, pp)
    beta = 1.0 / (ab.link_utilization * bw)
    return [(ab.alpha0 + c.rounds * ab.alpha_r + c.dests * ab.alpha_d,
             c.m_coeff, beta) for c in menu.values()]


def _comm_times(table: OpTable, cluster: Cluster,
                m: np.ndarray) -> np.ndarray:
    """Comm time per op, shape of `m` (n_ops, ...); 0 for compute ops."""
    out = np.zeros_like(m)
    for kind in (optable.KIND_A2A, optable.KIND_AR, optable.KIND_PP):
        for group in np.unique(table.group[table.kind == kind]):
            sel = (table.kind == kind) & (table.group == group)
            if not sel.any():
                continue
            algs = _comm_menu_coeffs(cluster, kind, int(group), table.tp,
                                     table.pp)
            best = None
            for a, m_coeff, beta in algs:
                t = a + (m_coeff * m[sel]) * beta
                best = t if best is None else np.minimum(best, t)
            out[sel] = best
    return out


# ---------------------------------------------------------------------------
# vectorized (max,+) lane schedule
# ---------------------------------------------------------------------------

def _lane_makespan(lanes: np.ndarray, dur_a: np.ndarray,
                   dur_b: np.ndarray) -> np.ndarray:
    """Best-stagger makespan of the fixed-order three-lane schedule, exact
    vectorization of `overlap.dbo_best` with arbitrary trailing grid axes.

    `lanes` is the (n_ops,) int lane column (overlap.LANES indices);
    `dur_a` / `dur_b` are the two microbatches' per-op duration tensors,
    (n_ops, ...). They may differ — DBO'd prefill chunks split causally
    into unequal half-chunks — but must share the op structure (same lane
    per index), which every caller guarantees by construction.
    """
    n = dur_a.shape[0]
    tail = dur_a.shape[1:]
    dur = (dur_a, dur_b)
    best = None
    for s in range(0, min(MAX_STAGGER, max(n - 1, 0)) + 1):
        order = sorted(((k, mb) for mb in (0, 1) for k in range(n)),
                       key=lambda km: (km[0] + (s if km[1] else 0),
                                       km[1]))
        ready = [np.zeros(tail), np.zeros(tail)]
        free = [np.zeros(tail) for _ in LANES]
        for k, mb in order:
            lane = int(lanes[k])
            end = np.maximum(ready[mb], free[lane]) + dur[mb][k]
            ready[mb] = end
            free[lane] = end
        mk = np.maximum(ready[0], ready[1])
        best = mk if best is None else np.minimum(best, mk)
    return best if best is not None else np.zeros(tail)


# ---------------------------------------------------------------------------
# expert-skew load factors
# ---------------------------------------------------------------------------

def op_load_factors(table, cfg: ModelConfig, scenarios: Sequence,
                    extra_slots: int = 0
                    ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
    """Per-op skew multipliers for one grid, or None on the uniform path.

    Returns (lf, cf): lf (n_ops, n_scenarios) multiplies the row-linear
    flops / bytes / payload coefficients of the skew-scaled MoE ops
    (`workload.SKEW_SCALED_OPS`, located via the table's `moe_layer`
    column) with the scenario's per-MoE-layer hot-rank load factor
    (`placement.layer_load_factors`); cf (n_ops,) multiplies bytes_const
    — the expert weight stream — with the replica hosting factor
    (`placement.hosting_factor`). Both are exactly 1 everywhere else.
    None (every scenario uniform, no replicas, or no sharded experts)
    selects `GridEval`'s untouched seed arithmetic — byte-identity is
    structural, not numerical. Works on decode and prefill tables alike.
    """
    skewed = [bool(getattr(sc, "is_skewed", False)) for sc in scenarios]
    if cfg.moe is None or (not any(skewed) and not extra_slots):
        return None
    ml = np.asarray(table.moe_layer)
    sel = ml >= 0
    lf = np.ones((table.n_ops, len(scenarios)))
    if sel.any():
        for si, sc in enumerate(scenarios):
            if not skewed[si]:
                continue
            fac = np.asarray(placement.layer_load_factors(
                cfg, sc, table.ep, extra_slots))
            lf[sel, si] = fac[ml[sel]]
    cf = np.ones(table.n_ops)
    if extra_slots:
        host = np.array([nm.rsplit(".", 1)[-1] == "expert_ffn"
                         for nm in table.names])
        cf[host] = placement.hosting_factor(cfg, table.ep, extra_slots)
    if not extra_slots and np.all(lf == 1.0):
        return None            # e.g. ep=1: skew cannot create imbalance
    return lf, cf


# ---------------------------------------------------------------------------
# grid evaluation context
# ---------------------------------------------------------------------------

class GridEval:
    """Shared evaluation state for one (table, clusters, scenarios, batches)
    grid. Duration tensors and DBO makespans are cached per (q_len, half)
    so the dbo / dbo+sd / sd variants of one sweep reuse each other's work.

    backend="jax" swaps the two heavy primitives (`seq_components`,
    `dbo_makespan`) for `sweep_jax.JaxGridEngine`'s jitted kernels;
    everything downstream of those arrays (best_iteration, tpot,
    selection) is shared NumPy code. backend=None takes the module
    default (see `set_default_backend`).

    `load` carries the expert-skew multipliers from `op_load_factors`
    (None on the uniform path, which then runs the seed arithmetic
    unchanged — byte-identity is structural).

    All result arrays have shape (n_clusters, n_scenarios, n_batches).
    """

    def __init__(self, table: OpTable, clusters: Sequence[Cluster],
                 scenarios: Sequence, batches: np.ndarray,
                 backend: Optional[str] = None,
                 load: Optional[Tuple[np.ndarray, np.ndarray]] = None):
        self.table = table
        self.clusters = list(clusters)
        self.scenarios = list(scenarios)
        self.batches = np.asarray(batches, np.int64)
        self.half = np.maximum(self.batches // 2, 1)
        self.backend = _resolve_backend(backend)
        self.load = load
        self._engine = None
        self._dur: Dict = {}
        self._mk: Dict = {}
        self._seq: Dict = {}

    def _jax_engine(self):
        if self._engine is None:
            from repro.core import sweep_jax
            self._engine = sweep_jax.JaxGridEngine(
                self.table, self.clusters, self.scenarios, self.batches,
                self.half, load=self.load)
        return self._engine

    # ------------- durations -------------
    def _durations(self, q: int, half: bool):
        """(comp, comm) duration tensors, (n_ops, n_cl, n_sc, n_b); entries
        are zero off their own lane, exactly like the scalar timers."""
        key = (q, half)
        if key in self._dur:
            return self._dur[key]
        t = self.table
        b_arr = self.half if half else self.batches
        rows = t.rows(b_arr, q)                        # (n_b,)
        ctx = np.array([sc.context for sc in self.scenarios],
                       float)[:, None]                 # (n_sc, 1)
        is_comp = t.is_compute[:, None, None, None]

        # compute roofline (cluster axis only matters if XPUs differ)
        flops_base = t.flop_row[:, None] * rows
        flops_ctx = t.flop_row_ctx[:, None] * rows
        byts_ctx = t.bytes_ctx[:, None] * t.batch_per_device(b_arr)
        if self.load is None:
            byts_base = t.bytes_const[:, None] + t.bytes_row[:, None] * rows
            flops_sc = flops_base[:, None, :] + flops_ctx[:, None, :] * ctx
            byts_sc = byts_base[:, None, :] + byts_ctx[:, None, :] * ctx
        else:
            # expert-skew path: lf (n_ops, n_sc) scales the row-linear
            # terms per scenario (exact — affected ops have zero ctx
            # coefficients), cf (n_ops,) scales the expert weight stream
            lf3 = self.load[0][:, :, None]
            flops_sc = (flops_base[:, None, :] * lf3
                        + flops_ctx[:, None, :] * ctx)
            byts_sc = ((t.bytes_const * self.load[1])[:, None, None]
                       + (t.bytes_row[:, None] * rows)[:, None, :] * lf3
                       + byts_ctx[:, None, :] * ctx)

        fp8 = t.dtype == "fp8"
        eff = np.where(rows < GEMM_SMALL_TOKENS,
                       t.eff_small[:, None], t.eff[:, None])[:, None, :]
        comp_by_xpu: Dict[int, np.ndarray] = {}
        comp = np.zeros((t.n_ops, len(self.clusters)) + flops_sc.shape[1:])
        for ci, cl in enumerate(self.clusters):
            xk = id(cl.xpu)
            if xk not in comp_by_xpu:
                peak = cl.xpu.flops_fp8 if fp8 else cl.xpu.flops_bf16
                t_c = flops_sc / (peak * eff)
                t_m = byts_sc / (cl.xpu.hbm_bw * EFF_MEMORY)
                comp_by_xpu[xk] = np.maximum(t_c, t_m) + T_LAUNCH
            comp[:, ci] = comp_by_xpu[xk]
        comp = np.where(is_comp, comp, 0.0)

        m = t.m_bytes(b_arr, q)                        # (n_ops, n_b)
        comm = np.zeros_like(comp)
        if self.load is None:
            for ci, cl in enumerate(self.clusters):
                comm[:, ci] = _comm_times(t, cl, m)[:, None, :]
        else:
            # hot-rank A2A payload: the collective finishes when its
            # hottest rank does, so the beta term scales by lf per
            # scenario (alpha unchanged — _comm_times broadcasts over
            # the trailing (n_sc, n_b) axes)
            m_sc = m[:, None, :] * self.load[0][:, :, None]
            for ci, cl in enumerate(self.clusters):
                comm[:, ci] = _comm_times(t, cl, m_sc)
        comm = np.where(is_comp, 0.0, comm)

        # pipeline bottleneck: the largest stage's layer ops repeat
        # stage_imbalance times per round (all-ones at pp=1 and pp | L, so
        # the multiply is an exact identity on the seed path)
        scale = t.stage_scale[:, None, None, None]
        self._dur[key] = (comp * scale, comm * scale)
        return self._dur[key]

    # ------------- no-overlap iteration -------------
    def seq_components(self, q: int, half: bool = False):
        """(t_iter, t_compute, t_comm), each (n_cl, n_sc, n_b) — the
        dbo=False path of optimizer.iteration_time."""
        key = (q, half)
        if key not in self._seq:
            if self.backend == "jax":
                tc, tm = self._jax_engine().seq_components(q, half)
            else:
                comp, comm = self._durations(q, half)
                tc = comp.sum(axis=0)
                tm = comm.sum(axis=0)
            self._seq[key] = (tc + tm, tc, tm)
        return self._seq[key]

    # ------------- DBO three-lane schedule -------------
    def dbo_makespan(self, q: int) -> np.ndarray:
        """Best-stagger three-lane makespan at HALF batch, (n_cl,n_sc,n_b).

        Exact vectorization of overlap.dbo_tpot: with a fixed per-lane
        order, every start time is max(end of the microbatch's previous op,
        end of the lane's previous op) — a (max,+) recurrence evaluated here
        in merged order with the batch grid as trailing axes. The lane
        column (`OpTable.lane`) routes collectives to the comm lane and
        `pp_sendrecv` hops to the dedicated send/recv lane, so pipeline
        hops overlap BOTH compute and collectives; at pp = 1 the third
        lane is empty and the schedule is the original two-lane one.
        """
        if q in self._mk:
            return self._mk[q]
        if self.backend == "jax":
            self._mk[q] = self._jax_engine().dbo_makespan(q)
            return self._mk[q]
        comp, comm = self._durations(q, half=True)
        dur = comp + comm                      # disjoint supports
        self._mk[q] = _lane_makespan(self.table.lane, dur, dur)
        return self._mk[q]

    # ------------- TPOT -------------
    def best_iteration(self, q: int, dbo: bool) -> np.ndarray:
        """min(no-overlap, DBO) per grid point — optimizer's best_iter."""
        t_seq, _, _ = self.seq_components(q)
        if not dbo:
            return t_seq
        mk = self.dbo_makespan(q)
        return np.where(self.batches >= 2, np.minimum(t_seq, mk), t_seq)

    def tpot(self, *, dbo: bool = False,
             sd: Optional[SpecDecConfig] = None) -> np.ndarray:
        """TPOT seconds over the grid — batched optimizer.tpot_at."""
        t1 = self.best_iteration(1, dbo)
        if sd is None:
            return t1
        tv = self.best_iteration(sd.spec_m, dbo)
        return (t1 + tv) / sd.tokens_per_iteration


def batched_tpot(op_table: OpTable, clusters: Sequence[Cluster],
                 batches: np.ndarray, scenarios: Sequence, *,
                 dbo: bool = False,
                 sd: Optional[SpecDecConfig] = None,
                 backend: Optional[str] = None) -> np.ndarray:
    """TPOT for every (cluster, scenario, batch) grid point in one shot.

    Returns shape (n_clusters, n_scenarios, n_batches); matches the scalar
    `optimizer.tpot_at` within float-rounding (tested at 1e-9 relative on
    the numpy backend, 1e-6 on jax).
    All clusters must share the op table's device count.
    """
    return GridEval(op_table, clusters, scenarios, batches,
                    backend=backend).tpot(dbo=dbo, sd=sd)


def batched_iteration_components(op_table: OpTable,
                                 clusters: Sequence[Cluster],
                                 batches: np.ndarray, context: int,
                                 q_len: int = 1):
    """No-overlap (t_iter, t_compute, t_comm), each (n_cl, n_b) — the
    batched optimizer.iteration_time(dbo=False) for one context."""
    from repro.core.optimizer import Scenario

    ev = GridEval(op_table, clusters, [Scenario(0.0, context)], batches)
    t, tc, tm = ev.seq_components(q_len)
    return t[:, 0, :], tc[:, 0, :], tm[:, 0, :]


# ---------------------------------------------------------------------------
# grid search: max throughput under SLO, batched over clusters x scenarios
# ---------------------------------------------------------------------------

def parallelism_candidates(cfg: ModelConfig, cluster: Cluster, *,
                           dtype: str = "fp8",
                           pp: Union[int, str] = 1,
                           strict_experts: bool = True
                           ) -> List[Tuple[int, int, int]]:
    """All valid (tp, pp, ep) hybrid mappings of `cfg` on `cluster`,
    (tp, pp) lexicographically ascending (so exact throughput ties resolve
    to the fixed mapping, then to the shallower pipeline).

    A tp is valid when it divides the device count AND the attention heads
    shard evenly (num_kv_heads for GQA, num_heads for MLA; head-free
    mixers only need the device-count divisibility). pp (all valid stage
    counts when pp="auto", the requested degree otherwise) is capped by
    the layer count — every stage owns at least one layer — and tp*pp must
    divide the device count. ep = n/(tp*pp) must divide the expert count
    (MoE) and the resulting per-stage weight shard (dense / (tp*pp),
    experts / (ep*tp*pp), largest stage of the balanced partition — see
    `workload.model_shard_bytes`) must leave room on the device
    (per-scenario KV feasibility is checked by the batch grids, exactly as
    for the fixed mapping). strict_experts=False drops the expert-count
    divisibility requirement (experts pad to the EP group, `workload` uses
    max(E//ep, 1)) — the convention the disaggregated prefill pools
    inherited from the fixed-mapping search."""
    n = cluster.n_xpus
    if cfg.attn_kind == "mla":
        heads = cfg.num_heads
    elif cfg.has_attention:
        heads = cfg.num_kv_heads
    else:
        heads = 0
    pp_opts = (range(1, min(n, cfg.num_layers) + 1) if pp == "auto"
               else (int(pp),))
    out: List[Tuple[int, int, int]] = []
    for tp in range(1, n + 1):
        if n % tp:
            continue
        if heads and (tp > heads or heads % tp):
            continue
        for q in pp_opts:
            if q < 1 or q > cfg.num_layers or n % (tp * q):
                continue
            if cfg.moe is not None:
                ep = n // (tp * q)
                if strict_experts and cfg.moe.num_experts % ep:
                    continue
            else:
                ep = 1
            shard = workload.model_shard_bytes(cfg, tp, ep, dtype, q)
            if shard >= cluster.xpu.hbm_cap * (1 - workload.KV_RESERVE_FRAC):
                continue
            out.append((tp, q, ep))
    return out


def _resolve_parallelism(cfg: ModelConfig, n: int, tp: int, pp: int,
                         ep: Optional[int]) -> int:
    """Resolved EP degree of one FIXED mapping: ep defaults to n/(tp*pp)
    for MoE models (the hybrid family; n at the paper's tp=1, pp=1), 1 for
    dense."""
    if cfg.moe is not None:
        return ep or max(n // (tp * pp), 1)
    return 1


def _merge_best(grids: Sequence[List[List]]) -> List[List]:
    """Elementwise argmax-throughput across per-mapping [cluster][scenario]
    grids; exact ties keep the EARLIEST grid (candidates are ordered tp
    ascending, so the fixed mapping wins draws)."""
    out = []
    for ci in range(len(grids[0])):
        row = []
        for si in range(len(grids[0][ci])):
            best = None
            for g in grids:
                cand = g[ci][si]
                if cand is None:
                    continue
                if best is None or cand.throughput > best.throughput:
                    best = cand
            row.append(best)
        out.append(row)
    return out


def _auto_candidates(clusters: Sequence[Cluster], cfg: ModelConfig,
                     dtype: str, tp: Union[int, str] = "auto",
                     pp: Union[int, str] = 1
                     ) -> List[Tuple[int, int, int]]:
    """Union of each cluster's valid mappings (clusters share a device
    count but may differ in XPU, so a mapping one cluster's HBM prunes can
    still be another's best — the per-cluster batch grids reject it where
    the shard genuinely does not fit). A fixed value on either axis
    restricts the enumeration to it."""
    cands = sorted({c for cl in clusters
                    for c in parallelism_candidates(cfg, cl, dtype=dtype,
                                                    pp=pp)})
    if tp != "auto":
        cands = [c for c in cands if c[0] == tp]
    if not cands:
        raise ValueError(
            f"no feasible (tp, pp, ep) mapping for {cfg.name!r} on "
            f"{clusters[0].n_xpus} XPUs under (tp={tp!r}, pp={pp!r}) — "
            "model shard exceeds HBM at every searched degree")
    return cands


def _prepare_grid(clusters, cfg, scenarios, tp, pp, ep_r, dtype,
                  extra_slots=0):
    """Per-(cluster, scenario) seed batch grids + their sorted union.
    extra_slots > 0 charges the replica weights against HBM (shrinking
    the grids) via `ServingPoint.moe_extra`."""
    from repro.core.optimizer import _batch_grid
    n = clusters[0].n_xpus
    grids = {}
    union = set()
    for ci, cl in enumerate(clusters):
        for si, sc in enumerate(scenarios):
            # reject scenarios where ONE request's prompt + decode context
            # cannot be held at all (empty grid, not a degenerate batch-0
            # point); batch sizing keeps the seed convention of KV at the
            # average context
            mem_ctx = getattr(sc, "mem_context", sc.context)
            p0 = ServingPoint(batch_global=1, context=sc.context, tp=tp,
                              ep=ep_r, n_devices=n, dtype=dtype, pp=pp,
                              moe_extra=extra_slots)
            p_mem = ServingPoint(batch_global=1, context=mem_ctx, tp=tp,
                                 ep=ep_r, n_devices=n, dtype=dtype, pp=pp,
                                 moe_extra=extra_slots)
            if not workload.single_request_fits(cfg, p_mem, cl.xpu.hbm_cap):
                grids[ci, si] = []
                continue
            b_max = workload.max_batch_by_memory(cfg, p0, cl.xpu.hbm_cap)
            grids[ci, si] = _batch_grid(b_max, max(n // tp, 1))
            union.update(grids[ci, si])
    batches = np.array(sorted(union), np.int64)
    return grids, batches


def _select_and_finalize(ev: GridEval, grids, cfg, *, dbo, sd, tp, pp,
                         ep_r, dtype, extra_slots=0):
    """Feasibility + argmax on the batched TPOTs, then re-evaluate the
    winner through the exact scalar path (byte-identical OperatingPoint).
    extra_slots tags the replica-count arm of the placement search so the
    scalar re-derivation (and knife-edge fallback) prices the same skew."""
    from repro.core import optimizer

    tpot = ev.tpot(dbo=dbo, sd=sd)
    index = {int(b): i for i, b in enumerate(ev.batches)}
    n = ev.clusters[0].n_xpus
    out: List[List[Optional[optimizer.OperatingPoint]]] = []
    for ci, cl in enumerate(ev.clusters):
        row = []
        for si, sc in enumerate(ev.scenarios):
            budget = sc.tpot_ms * 1e-3
            best_b, best_thr = None, 0.0
            knife_edge = False
            for b in grids[ci, si]:
                t = float(tpot[ci, si, index[b]])
                if t > budget:
                    # batched and scalar TPOT agree within 1e-9 relative
                    # (the bound tests/test_sweep.py asserts); a rejection
                    # inside that band could flip under scalar rounding, so
                    # the whole cell defers to the exact search
                    knife_edge = knife_edge or t <= budget * (1 + 1e-9)
                    continue
                thr = b / t
                if best_b is None or thr > best_thr:
                    best_b, best_thr = b, thr
            if knife_edge:
                row.append(optimizer.max_throughput_scalar(
                    cl, cfg, ev.scenarios[si], dbo=dbo, sd=sd, tp=tp, pp=pp,
                    ep=ep_r, dtype=dtype, extra_slots=extra_slots))
                continue
            if best_b is None:
                row.append(None)
                continue
            p = ServingPoint(batch_global=best_b, context=sc.context, tp=tp,
                             ep=ep_r, n_devices=n, dtype=dtype, pp=pp,
                             moe_load=placement.point_factors(
                                 cfg, sc, ep_r, extra_slots),
                             moe_extra=extra_slots)
            tpot_s, ect, tc, tm = optimizer.tpot_at(cfg, p, cl, dbo=dbo,
                                                    sd=sd)
            if tpot_s > budget:
                # the batched value sat exactly on the SLO boundary and the
                # scalar rounding disagrees — defer to the exact search
                row.append(optimizer.max_throughput_scalar(
                    cl, cfg, sc, dbo=dbo, sd=sd, tp=tp, pp=pp, ep=ep_r,
                    dtype=dtype, extra_slots=extra_slots))
                continue
            row.append(optimizer.OperatingPoint(
                batch=best_b, tpot=tpot_s, throughput=best_b / tpot_s,
                used_dbo=dbo, used_sd=sd is not None, exposed_comm=ect,
                t_compute=tc, t_comm=tm, tp=tp, ep=ep_r, pp=pp,
                extra_experts=extra_slots))
        out.append(row)
    return out


def _sweep_fixed(clusters, cfg, scenarios, *, dbo, sd, tp, pp, ep_r,
                 dtype, backend=None, extra_slots=0):
    """One FIXED-mapping batched search (the pre-hybrid sweep body).
    Skewed scenarios are priced automatically (`op_load_factors` is
    always consulted), so every caller — degraded re-search included —
    honors the routing axis without its own plumbing."""
    n = clusters[0].n_xpus
    grids, batches = _prepare_grid(clusters, cfg, scenarios, tp, pp, ep_r,
                                   dtype, extra_slots=extra_slots)
    if batches.size == 0:
        return [[None] * len(scenarios) for _ in clusters]
    table = optable.op_table(cfg, tp, ep_r, n, dtype, pp=pp)
    load = op_load_factors(table, cfg, scenarios, extra_slots)
    ev = GridEval(table, clusters, scenarios, batches, backend=backend,
                  load=load)
    return _select_and_finalize(ev, grids, cfg, dbo=dbo, sd=sd, tp=tp, pp=pp,
                                ep_r=ep_r, dtype=dtype,
                                extra_slots=extra_slots)


def _check_placement(placement_mode) -> None:
    if placement_mode not in (None, "auto"):
        raise ValueError(f"unknown placement {placement_mode!r}; "
                         "expected None or 'auto'")


def _placement_candidates(clusters, cfg, scenarios, tp, pp, ep_r,
                          dtype) -> List[int]:
    """Replica-slot candidates R of the placement search: 0 plus powers of
    two, pruned to counts whose weight shard + replicas still fit at least
    one cluster's HBM (the per-arm batch grids do the exact per-cluster
    rejection) and capped at E - E/ep (every expert everywhere). [0] when
    there is nothing to search: dense model, unsharded experts, or no
    skewed scenario."""
    if (cfg.moe is None or ep_r <= 1
            or not any(getattr(sc, "is_skewed", False) for sc in scenarios)):
        return [0]
    cap = cfg.moe.num_experts - max(cfg.moe.num_experts // ep_r, 1)
    out = [0]
    r = 1
    while r <= cap:
        if any(workload.model_shard_bytes(cfg, tp, ep_r, dtype, pp, r)
               < cl.xpu.hbm_cap * (1 - workload.KV_RESERVE_FRAC)
               for cl in clusters):
            out.append(r)
        r *= 2
    return out


def _sweep_mapping(clusters, cfg, scenarios, *, dbo, sd, tp, pp, ep_r,
                   dtype, backend=None, placement_mode=None, extra_slots=0):
    """`_sweep_fixed`, optionally wrapped in the replication/placement
    search: placement_mode="auto" runs one fixed-mapping search per
    replica count and merges the arms R=0-FIRST through `_merge_best`'s
    strict argmax — so auto placement can never lose to no-placement, and
    uniform scenarios (whose extra replicas only add weight traffic) keep
    the byte-identical R=0 result."""
    _check_placement(placement_mode)
    if placement_mode == "auto":
        if extra_slots:
            raise ValueError("pass either placement='auto' or a fixed "
                             "extra_slots, not both")
        rs = _placement_candidates(clusters, cfg, scenarios, tp, pp, ep_r,
                                   dtype)
    else:
        rs = [extra_slots]
    if len(rs) == 1:
        return _sweep_fixed(clusters, cfg, scenarios, dbo=dbo, sd=sd, tp=tp,
                            pp=pp, ep_r=ep_r, dtype=dtype, backend=backend,
                            extra_slots=rs[0])
    return _merge_best([
        _sweep_fixed(clusters, cfg, scenarios, dbo=dbo, sd=sd, tp=tp, pp=pp,
                     ep_r=ep_r, dtype=dtype, backend=backend, extra_slots=r)
        for r in rs])


def sweep_max_throughput(clusters: Sequence[Cluster], cfg: ModelConfig,
                         scenarios: Sequence, *, dbo: bool = False,
                         sd: Optional[SpecDecConfig] = None,
                         tp: Union[int, str] = 1,
                         pp: Union[int, str] = 1,
                         ep: Optional[int] = None, dtype: str = "fp8",
                         backend: Optional[str] = None,
                         placement: Optional[str] = None
                         ) -> List[List[Optional["OperatingPoint"]]]:
    """Batched optimizer.max_throughput over clusters x scenarios.

    Clusters must share a device count (they may differ in topology, link
    bandwidth, and alpha sets). Returns [cluster][scenario] OperatingPoints
    (None where the SLO is unreachable), byte-identical to the scalar path.

    tp="auto" / pp="auto" sweep the joint (tp, pp, ep = n/(tp*pp)) axes
    (either one alone holds the other fixed): every mapping from
    `parallelism_candidates` runs the same batched search (its own op
    table, batch grids, and topology-placed collectives) and each
    (cluster, scenario) cell keeps the highest-throughput mapping, ties to
    the smallest (tp, pp). The chosen mapping is recorded on the point's
    `tp` / `pp` / `ep` fields.

    placement="auto" additionally searches expert replica counts for
    skewed scenarios (`_placement_candidates`; chosen count on the
    point's `extra_experts`) — a no-op, byte-identical to placement=None,
    when every scenario routes uniformly.
    """
    n = clusters[0].n_xpus
    if any(cl.n_xpus != n for cl in clusters):
        raise ValueError("sweep_max_throughput requires a uniform device "
                         "count; group clusters by n_xpus")
    _check_placement(placement)
    if tp == "auto" or pp == "auto":
        if ep is not None:
            raise ValueError("auto mapping search resolves ep = n/(tp*pp) "
                             "per candidate; pass ep=None")
        return _merge_best([
            _sweep_mapping(clusters, cfg, scenarios, dbo=dbo, sd=sd, tp=t,
                           pp=q, ep_r=e, dtype=dtype, backend=backend,
                           placement_mode=placement)
            for t, q, e in _auto_candidates(clusters, cfg, dtype, tp, pp)])
    ep_r = _resolve_parallelism(cfg, n, tp, pp, ep)
    return _sweep_mapping(clusters, cfg, scenarios, dbo=dbo, sd=sd, tp=tp,
                          pp=pp, ep_r=ep_r, dtype=dtype, backend=backend,
                          placement_mode=placement)


def _variants_for(opts: str) -> List[Tuple[bool, Optional[SpecDecConfig]]]:
    """The (dbo, sd) candidates of one opts level, in seed's tie-break
    order (best_of_opts keeps the FIRST candidate on equal throughput)."""
    variants: List[Tuple[bool, Optional[SpecDecConfig]]] = [(False, None)]
    if opts in ("dbo", "dbo+sd"):
        variants.append((True, None))
    if opts == "dbo+sd":
        sd = SpecDecConfig()
        variants += [(True, sd), (False, sd)]
    return variants


def best_of_opts_multi(clusters: Sequence[Cluster], cfg: ModelConfig,
                       scenarios: Sequence,
                       opts_levels: Sequence[str] = ("noopt", "dbo",
                                                     "dbo+sd"), *,
                       tp: Union[int, str] = 1, pp: Union[int, str] = 1,
                       ep: Optional[int] = None,
                       dtype: str = "fp8",
                       backend: Optional[str] = None,
                       placement: Optional[str] = None,
                       extra_slots: int = 0
                       ) -> Dict[str, List[List[Optional["OperatingPoint"]]]]:
    """Batched optimizer.best_of_opts for SEVERAL opts levels at once.

    One GridEval and one result per (dbo, sd) variant are shared across the
    levels ('dbo+sd' already evaluates everything 'noopt' and 'dbo' need),
    so e.g. fig11's three curves cost one engine pass, not three.
    tp="auto" / pp="auto" additionally sweep the (tp, pp, ep = n/(tp*pp))
    mapping axes per level (one engine pass per candidate mapping), and
    placement="auto" the expert replica counts (one engine pass per
    count, merged R=0-first so it never loses to placement=None).
    """
    n = clusters[0].n_xpus
    if any(cl.n_xpus != n for cl in clusters):
        raise ValueError("best_of_opts_multi requires a uniform device "
                         "count")
    _check_placement(placement)
    if tp == "auto" or pp == "auto":
        if ep is not None:
            raise ValueError("auto mapping search resolves ep = n/(tp*pp) "
                             "per candidate; pass ep=None")
        per_cand = [best_of_opts_multi(clusters, cfg, scenarios, opts_levels,
                                       tp=t, pp=q, ep=e, dtype=dtype,
                                       backend=backend, placement=placement,
                                       extra_slots=extra_slots)
                    for t, q, e in _auto_candidates(clusters, cfg, dtype,
                                                    tp, pp)]
        return {opts: _merge_best([pc[opts] for pc in per_cand])
                for opts in opts_levels}
    ep_r = _resolve_parallelism(cfg, n, tp, pp, ep)
    if placement == "auto":
        if extra_slots:
            raise ValueError("pass either placement='auto' or a fixed "
                             "extra_slots, not both")
        rs = _placement_candidates(clusters, cfg, scenarios, tp, pp, ep_r,
                                   dtype)
        if len(rs) > 1:
            per_r = [best_of_opts_multi(clusters, cfg, scenarios,
                                        opts_levels, tp=tp, pp=pp, ep=ep,
                                        dtype=dtype, backend=backend,
                                        extra_slots=r)
                     for r in rs]
            return {opts: _merge_best([pr[opts] for pr in per_r])
                    for opts in opts_levels}
    grids, batches = _prepare_grid(clusters, cfg, scenarios, tp, pp, ep_r,
                                   dtype, extra_slots=extra_slots)
    if batches.size == 0:
        empty = [[None] * len(scenarios) for _ in clusters]
        return {opts: [list(row) for row in empty] for opts in opts_levels}
    table = optable.op_table(cfg, tp, ep_r, n, dtype, pp=pp)
    load = op_load_factors(table, cfg, scenarios, extra_slots)
    ev = GridEval(table, clusters, scenarios, batches, backend=backend,
                  load=load)

    by_variant: Dict[Tuple, List[List[Optional["OperatingPoint"]]]] = {}
    out = {}
    for opts in opts_levels:
        per_variant = []
        for d, s in _variants_for(opts):
            key = (d, s)
            if key not in by_variant:
                by_variant[key] = _select_and_finalize(
                    ev, grids, cfg, dbo=d, sd=s, tp=tp, pp=pp, ep_r=ep_r,
                    dtype=dtype, extra_slots=extra_slots)
            per_variant.append(by_variant[key])
        level = []
        for ci in range(len(clusters)):
            row = []
            for si in range(len(scenarios)):
                best = None
                for cand in (v[ci][si] for v in per_variant):
                    if cand is None:
                        continue
                    if best is None or cand.throughput > best.throughput:
                        best = cand
                row.append(best)
            level.append(row)
        out[opts] = level
    return out


def best_of_opts_grid(clusters: Sequence[Cluster], cfg: ModelConfig,
                      scenarios: Sequence, opts: str = "dbo+sd", *,
                      tp: Union[int, str] = 1, pp: Union[int, str] = 1,
                      ep: Optional[int] = None,
                      dtype: str = "fp8",
                      backend: Optional[str] = None,
                      placement: Optional[str] = None
                      ) -> List[List[Optional["OperatingPoint"]]]:
    """Batched optimizer.best_of_opts over clusters x scenarios."""
    return best_of_opts_multi(clusters, cfg, scenarios, [opts], tp=tp,
                              pp=pp, ep=ep, dtype=dtype,
                              backend=backend, placement=placement)[opts]


# ---------------------------------------------------------------------------
# prefill-aware operating-point search
# ---------------------------------------------------------------------------

# chunk sizes tried by the chunked-prefill search (clipped to the prompt)
CHUNK_GRID = (128, 256, 512, 1024, 2048)
# prefill-pool fractions tried by the disaggregated-prefill search
SPLIT_FRACS = (0.125, 0.25, 0.375, 0.5, 0.625, 0.75)


def _prefill_load(ptable: "optable.PrefillOpTable", cfg: ModelConfig,
                  scenario) -> Optional[Tuple[np.ndarray, np.ndarray]]:
    """Single-scenario (lf, cf) column vectors for a prefill table, or
    None for the uniform fast path — `op_load_factors` specialised to the
    per-schedule prefill evaluators (no scenario axis, no replication:
    prefill chunks run on the unreplicated shard)."""
    out = op_load_factors(ptable, cfg, [scenario], 0)
    if out is None:
        return None
    lf, cf = out
    return lf[:, 0], cf


def _skew_sig(scenario) -> Optional[Tuple[float, int]]:
    """Cache-key component distinguishing skewed scenarios that share a
    prompt length (None for uniform, keeping seed keys unchanged)."""
    if not getattr(scenario, "is_skewed", False):
        return None
    return (float(scenario.zipf_s), int(scenario.routing_seed))


def _prefill_chunk_durations(ptable: "optable.PrefillOpTable",
                             cluster: Cluster, batch_global: int,
                             sizes: np.ndarray, offsets: np.ndarray,
                             load: Optional[Tuple[np.ndarray,
                                                  np.ndarray]] = None
                             ) -> Tuple[np.ndarray, np.ndarray]:
    """(comp, comm) per-op per-chunk duration rows of one chunk schedule,
    each (n_ops, n_chunks) with zeros off their own lane — the prefill
    counterpart of `GridEval._durations` (stage scale applied), built from
    the table's chunk-polynomial closed forms. `load` (from
    `_prefill_load`) prices expert skew; None is the untouched seed
    arithmetic."""
    s = np.asarray(sizes, float)
    o = np.asarray(offsets, float)
    rows = ptable.rows(batch_global, s)                    # (n_chunks,)
    if load is None:
        flops = ptable.flops(batch_global, s, o)           # (n_ops, n_chunks)
        byts = ptable.op_bytes(batch_global, s, o)
        m = ptable.m_bytes(batch_global, s)
    else:
        lfv, cfv = load
        # exact for the skew-scaled ops: their ctx / chunk coefficients
        # are zero (expert flops and A2A payload are row-linear), so
        # scaling the closed-form total equals scaling the row term
        flops = ptable.flops(batch_global, s, o) * lfv[:, None]
        byts = (ptable.bytes_const[:, None] * cfv[:, None]
                + (ptable.bytes_row[:, None] * rows) * lfv[:, None]
                + ptable.bytes_ctx[:, None]
                * (ptable.batch_per_device(batch_global) * o))
        m = ptable.m_bytes(batch_global, s) * lfv[:, None]

    fp8 = ptable.dtype == "fp8"
    peak = cluster.xpu.flops_fp8 if fp8 else cluster.xpu.flops_bf16
    eff = np.where(rows < GEMM_SMALL_TOKENS,
                   ptable.eff_small[:, None], ptable.eff[:, None])
    t_c = flops / (peak * eff)
    t_m = byts / (cluster.xpu.hbm_bw * EFF_MEMORY)
    comp = np.maximum(t_c, t_m) + T_LAUNCH
    is_comp = ptable.is_compute[:, None]
    scale = ptable.stage_scale[:, None]
    comp = np.where(is_comp, comp, 0.0) * scale
    comm = np.where(is_comp, 0.0, _comm_times(ptable, cluster, m)) * scale
    return comp, comm


def _prefill_chunk_times(ptable: "optable.PrefillOpTable", cluster: Cluster,
                         batch_global: int, sizes: Sequence[int],
                         offsets: Sequence[int], *,
                         dbo: bool = False,
                         backend: Optional[str] = None,
                         load: Optional[Tuple[np.ndarray,
                                              np.ndarray]] = None
                         ) -> np.ndarray:
    """Prefill-iteration time per chunk of one schedule, shape (n_chunks,)
    — the batched `optimizer.prefill_chunk_components` time. dbo=False is
    the no-overlap sum (`optimizer.prefill_iteration_time`); dbo=True takes
    best-of(no-overlap, three-lane DBO) per chunk, where each chunk splits
    CAUSALLY into a leading ceil- and trailing floor-half microbatch
    (`optimizer.prefill_iteration_dbo`); 1-token chunks stay no-overlap.
    Skewed schedules (`load` from `_prefill_load`) always run on the
    NumPy reference path — per-schedule prefill rows are too small to
    amortise a second jit variant, and uniform scenarios (load=None, the
    byte-identity path) keep the jitted kernel."""
    if load is None and _resolve_backend(backend) == "jax":
        from repro.core import sweep_jax
        return sweep_jax.prefill_chunk_times(ptable, cluster, batch_global,
                                             sizes, offsets, dbo=dbo)
    comp, comm = _prefill_chunk_durations(ptable, cluster, batch_global,
                                          sizes, offsets, load)
    seq = comp.sum(axis=0) + comm.sum(axis=0)
    if not dbo:
        return seq
    s_arr = np.asarray(sizes, np.int64)
    o_arr = np.asarray(offsets, np.int64)
    h2 = s_arr // 2
    h1 = s_arr - h2
    comp_a, comm_a = _prefill_chunk_durations(ptable, cluster, batch_global,
                                              h1, o_arr, load)
    comp_b, comm_b = _prefill_chunk_durations(ptable, cluster, batch_global,
                                              h2, o_arr + h1, load)
    mk = _lane_makespan(ptable.lane, comp_a + comm_a, comp_b + comm_b)
    return np.where(s_arr >= 2, np.minimum(seq, mk), seq)


def _chunked_formulas(t_dec, s_pre, m: int, batches, gen_len: int,
                      domains: int):
    """(tpot, ttft, b_eff) of the load-weighted chunked-prefill model —
    the ONE place the batched search evaluates it (see
    `optimizer.chunked_prefill_tpot` for the derivation and the scalar
    reference the 1e-9 equivalence test locks this against). Broadcasts
    over any (t_dec, batches) shapes."""
    b_eff = np.minimum(np.asarray(batches, float), domains * gen_len / m)
    phi = b_eff * m / (gen_len * domains)
    tpot = t_dec + phi * (s_pre / m)
    ttft = m * t_dec + s_pre
    return tpot, ttft, b_eff


def batched_chunked_tpot_ttft(op_table: OpTable,
                              ptable: "optable.PrefillOpTable",
                              clusters: Sequence[Cluster],
                              batches: np.ndarray, scenario,
                              chunk: int, *, dbo: bool = False,
                              backend: Optional[str] = None,
                              cfg: Optional[ModelConfig] = None
                              ) -> Tuple[np.ndarray, np.ndarray]:
    """(TPOT, TTFT) of the chunked-prefill model over a (cluster, batch)
    grid, each (n_clusters, n_batches) — the batched
    `optimizer.chunked_prefill_tpot` (matches it to 1e-9 relative, with
    and without the three-lane DBO schedule). Pass `cfg` to price a
    skewed scenario (without it the routing axis is ignored, the seed
    behavior)."""
    load = (op_load_factors(op_table, cfg, [scenario])
            if cfg is not None else None)
    ev = GridEval(op_table, clusters, [scenario], batches, backend=backend,
                  load=load)
    t_dec = ev.best_iteration(1, dbo)[:, 0, :]             # (n_cl, n_b)
    sizes, offsets = workload.chunk_schedule(scenario.prompt_len, chunk)
    # chunk-carrying DP lanes across all pipeline stages: n/(tp*pp) per
    # stage times pp microbatches in flight = n/tp, pp-invariant
    domains = max(op_table.n // op_table.tp, 1)
    p_load = (_prefill_load(ptable, cfg, scenario) if cfg is not None
              else None)
    s_pre = np.stack([_prefill_chunk_times(ptable, cl, domains, sizes,
                                           offsets, dbo=dbo,
                                           backend=backend,
                                           load=p_load).sum()
                      for cl in clusters])                 # (n_cl,)
    tpot, ttft, _ = _chunked_formulas(t_dec, s_pre[:, None], len(sizes),
                                      batches[None, :], scenario.gen_len,
                                      domains)
    return tpot, ttft


def _as_decode_point(op) -> Optional["optimizer.PrefillOperatingPoint"]:
    from repro.core import optimizer
    if op is None:
        return None
    return optimizer.PrefillOperatingPoint(
        mode="decode", batch=op.batch, tpot=op.tpot, ttft=0.0,
        throughput=op.throughput, tp=op.tp, ep=op.ep, pp=op.pp,
        used_dbo=op.used_dbo, exposed_comm=op.exposed_comm,
        t_compute=op.t_compute, t_comm=op.t_comm)


def _chunk_candidates(prompt_len: int, chunk_grid: Sequence[int]) -> List[int]:
    return sorted({min(int(c), prompt_len) for c in chunk_grid if c >= 1})


def _sweep_chunked(clusters, cfg, scenarios, tp, pp, ep_r, dtype,
                   chunk_grid, dbo=False, backend=None):
    """Joint (batch, chunk) search of the chunked-prefill mode.

    For each (cluster, scenario): TPOT/TTFT over the batch grid x chunk
    candidates via the closed-form tables (see
    `optimizer.chunked_prefill_components` for the load-weighted iteration
    model). Throughput is B_eff / TPOT with B_eff = min(B, domains *
    gen_len / n_chunks) — past that batch the prefill lanes cannot refill
    the decode batch and slots idle. dbo=True times decode iterations and
    prefill chunks with the three-lane (max,+) schedule wherever it beats
    no-overlap (chunk A2A/AR hides under the half-chunks' big GEMMs).
    The winner is re-derived through the scalar path; knife-edge cells
    (batched feasibility within float rounding of the SLO) may return a
    point within 1e-9 of the budget.
    """
    from repro.core import optimizer

    n = clusters[0].n_xpus
    table = optable.op_table(cfg, tp, ep_r, n, dtype, pp=pp)
    ptable = optable.prefill_op_table(cfg, tp, ep_r, n, dtype, pp=pp)
    grids, batches = _prepare_grid(clusters, cfg, scenarios, tp, pp, ep_r,
                                   dtype)
    if batches.size == 0:
        return [[None] * len(scenarios) for _ in clusters]
    load = op_load_factors(table, cfg, scenarios)
    ev = GridEval(table, clusters, scenarios, batches, backend=backend,
                  load=load)
    t_dec_all = ev.best_iteration(1, dbo)                  # (n_cl, n_sc, n_b)
    index = {int(b): i for i, b in enumerate(batches)}
    domains = max(n // tp, 1)
    pre_cache: Dict[Tuple, float] = {}

    def s_pre_of(ci, sc, c):
        """Summed per-chunk prefill time, cached per (cluster, prompt,
        chunk, skew signature) — scenarios sharing a prompt length (e.g.
        a TTFT sweep) reuse one DBO makespan evaluation."""
        key = (ci, sc.prompt_len, c, _skew_sig(sc))
        if key not in pre_cache:
            sizes, offsets = workload.chunk_schedule(sc.prompt_len, c)
            pre_cache[key] = float(_prefill_chunk_times(
                ptable, clusters[ci], domains, sizes, offsets,
                dbo=dbo, backend=backend,
                load=_prefill_load(ptable, cfg, sc)).sum())
        return pre_cache[key]

    out: List[List[Optional[optimizer.PrefillOperatingPoint]]] = []
    for ci, cl in enumerate(clusters):
        row = []
        for si, sc in enumerate(scenarios):
            budget = sc.tpot_ms * 1e-3
            ttft_budget = sc.ttft_ms * 1e-3 if sc.ttft_ms else float("inf")
            best = None                     # (thr, b, chunk, b_eff)
            for c in _chunk_candidates(sc.prompt_len, chunk_grid):
                m = len(workload.chunk_schedule(sc.prompt_len, c)[0])
                s_pre = s_pre_of(ci, sc, c)
                for b in grids[ci, si]:
                    t_dec = float(t_dec_all[ci, si, index[b]])
                    tpot, ttft, b_eff = (
                        float(v) for v in _chunked_formulas(
                            t_dec, s_pre, m, float(b), sc.gen_len, domains))
                    if tpot > budget or ttft > ttft_budget:
                        continue
                    thr = b_eff / tpot
                    if best is None or thr > best[0]:
                        best = (thr, b, c, b_eff)
            if best is None:
                row.append(None)
                continue
            _, b, c, b_eff = best
            p = ServingPoint(batch_global=b, context=sc.context, tp=tp,
                             ep=ep_r, n_devices=n, dtype=dtype, pp=pp,
                             moe_load=placement.point_factors(cfg, sc, ep_r))
            tpot_s, ttft_s, ect, tc, tm = optimizer.chunked_prefill_components(
                cfg, p, cl, sc, c, dbo=dbo)
            row.append(optimizer.PrefillOperatingPoint(
                mode="chunked", batch=b, tpot=tpot_s, ttft=ttft_s,
                throughput=b_eff / tpot_s, chunk=c, tp=tp, ep=ep_r, pp=pp,
                used_dbo=dbo, exposed_comm=ect, t_compute=tc, t_comm=tm))
        out.append(row)
    return out


def _subcluster(cl: Cluster, n_sub: int) -> Cluster:
    """A pool carved out of `cl`: same XPU, per-XPU link bandwidth and
    topology family, `n_sub` devices. Mesh fabrics re-factorize to the
    most-cubic dims via the fabric's `pool_dims` hook (dims-free fabrics
    return None)."""
    return Cluster(topology=cl.topology, n_xpus=n_sub, xpu=cl.xpu,
                   link_bw=cl.link_bw, dims=cl.fabric.pool_dims(n_sub))


def _split_candidates(n: int, tp: int, fracs: Sequence[float]) -> List[int]:
    """Prefill-pool sizes to try: tp-aligned, both pools >= tp devices."""
    cands = set()
    for f in fracs:
        n_p = max(int(round(n * f / tp)), 1) * tp
        if tp <= n_p <= n - tp:
            cands.add(n_p)
    return sorted(cands)


def _disagg_pool_candidates(clusters, cfg, n_pool, tp, pp, dtype):
    """(tp, pp, ep) mappings for an n_pool-device pool: enumerated (and
    HBM-pruned) over the pool's sub-clusters when an axis is "auto"; the
    single requested mapping otherwise — unpruned, matching the seed,
    whose per-scenario prompt-KV guard does the rejecting."""
    if tp == "auto" or pp == "auto":
        pools = [_subcluster(cl, n_pool) for cl in clusters]
        cands = sorted({c for cl in pools
                        for c in parallelism_candidates(
                            cfg, cl, dtype=dtype, pp=pp,
                            strict_experts=False)})
        return [c for c in cands if tp == "auto" or c[0] == tp]
    if n_pool % (tp * pp):
        return []
    ep = max(n_pool // (tp * pp), 1) if cfg.moe is not None else 1
    return [(tp, pp, ep)]


def _sweep_disagg(clusters, cfg, scenarios, tp, pp, dtype, split_fracs,
                  dbo=False, backend=None):
    """Disaggregated-prefill search: sweep the prefill/decode split ratio,
    each pool resolving its OWN (tp, pp, ep) mapping.

    The decode pool runs the ordinary decode-only search on its sub-cluster
    (EP spans the pool; tp="auto"/pp="auto" search the mapping axes within
    the pool); the prefill pool independently enumerates ITS candidate
    mappings — the pools need not share one (the prefill pass is
    latency-bound and wants large tp, decode is throughput-bound and wants
    small tp). The prefill pool runs whole-prompt prefill, one prompt per
    DP domain per pipeline slot. TTFT = prefill pass + KV-cache handoff to
    the decode pool (alpha-beta at the PREFILL POOL's latency regime —
    `cl_p._ab()`, so an intra-node-sized pool pays intra-node alphas —
    over one XPU's link at the cluster's bandwidth); throughput is the
    balanced pipeline rate min(decode tokens/s, prefill request rate *
    gen_len). dbo=True applies the three-lane (max,+) schedule to BOTH
    pools: the decode search overlaps its iterations, the whole-prompt
    pass splits into two causal half-prompt microbatches.
    """
    from repro.core import optimizer

    n = clusters[0].n_xpus
    out: List[List[Optional[optimizer.PrefillOperatingPoint]]] = \
        [[None] * len(scenarios) for _ in clusters]
    # whole-prompt pass times, keyed (pool mapping, cluster, prompt):
    # scenarios sharing a prompt length (a TTFT sweep) reuse one pass —
    # and, under dbo, one (max,+) half-prompt makespan evaluation
    pass_cache: Dict[Tuple, float] = {}
    auto = tp == "auto" or pp == "auto"
    align = 1 if auto else tp * pp
    for n_p in _split_candidates(n, align, split_fracs):
        n_d = n - n_p
        pre_cands = _disagg_pool_candidates(clusters, cfg, n_p, tp, pp,
                                            dtype)
        if not pre_cands:
            continue            # dead split: skip the decode sweep too
        # clusters share n_xpus, so their decode pools share n_d: one
        # vectorized decode search covers ALL clusters x scenarios per split.
        # Pool mappings use the seed's padded-expert convention (ep need
        # not divide the expert count — pool sizes like 48 have no such
        # divisor), so the decode pool enumerates its own candidates
        # rather than going through the strict whole-cluster auto search.
        dec_pools = [_subcluster(cl, n_d) for cl in clusters]
        if auto:
            dec_cands = _disagg_pool_candidates(clusters, cfg, n_d, tp, pp,
                                                dtype)
            if not dec_cands:
                continue
            dec_grid = _merge_best([
                _sweep_fixed(dec_pools, cfg, scenarios, dbo=dbo, sd=None,
                             tp=t, pp=q, ep_r=e, dtype=dtype,
                             backend=backend)
                for t, q, e in dec_cands])
        else:
            dec_grid = sweep_max_throughput(dec_pools, cfg, scenarios,
                                            tp=tp, pp=pp, dtype=dtype,
                                            dbo=dbo, backend=backend)
        for tp_p, pp_p, ep_p in pre_cands:
            domains_p = max(n_p // tp_p, 1)   # prompts in flight (all stages)
            ptable = optable.prefill_op_table(cfg, tp_p, ep_p, n_p, dtype,
                                              pp=pp_p)
            for ci, cl in enumerate(clusters):
                cl_p = _subcluster(cl, n_p)
                ab = cl_p._ab()
                for si, sc in enumerate(scenarios):
                    dec = dec_grid[ci][si]
                    if dec is None:
                        continue
                    L = sc.prompt_len
                    p_pre = ServingPoint(batch_global=domains_p, context=L,
                                         tp=tp_p, ep=ep_p, n_devices=n_p,
                                         dtype=dtype, pp=pp_p)
                    # every domain must hold its in-flight prompts' KV
                    # beside the shard (one prompt per domain per stage;
                    # at pp=1 this is exactly the seed single-request fit)
                    if workload.max_batch_by_memory(
                            cfg, p_pre, cl.xpu.hbm_cap) < domains_p:
                        continue
                    ck = (n_p, tp_p, pp_p, ep_p, ci, L, _skew_sig(sc))
                    if ck not in pass_cache:
                        # the whole-prompt pass is a single-chunk scalar
                        # evaluation — no grid to amortize a jit over —
                        # so it always runs on the reference path; disagg
                        # winners stay byte-identical under backend="jax"
                        # (the decode-pool grid above is the heavy part)
                        pass_cache[ck] = float(_prefill_chunk_times(
                            ptable, cl_p, domains_p, [L], [0], dbo=dbo,
                            backend="numpy",
                            load=_prefill_load(ptable, cfg, sc))[0])
                    t_p = pass_cache[ck]
                    # latency term via the fabric hook: base alpha0
                    # everywhere, plus the circuit re-match on the OCS
                    # fabric (the KV handoff is its one phase switch)
                    t_xfer = (cl_p.fabric.kv_handoff_alpha(cl_p)
                              + workload.kv_cache_bytes_per_request(cfg, L)
                              / (ab.link_utilization * cl.link_bw))
                    ttft = t_p + t_xfer
                    if sc.ttft_ms and ttft > sc.ttft_ms * 1e-3:
                        continue
                    lam_p = domains_p / t_p              # prompts / s
                    thr = min(dec.throughput, lam_p * sc.gen_len)
                    prev = out[ci][si]
                    if prev is None or thr > prev.throughput:
                        out[ci][si] = optimizer.PrefillOperatingPoint(
                            mode="disagg", batch=dec.batch, tpot=dec.tpot,
                            ttft=ttft, throughput=thr, chunk=L,
                            n_prefill_xpus=n_p, n_decode_xpus=n_d,
                            tp=dec.tp, ep=dec.ep, pp=dec.pp,
                            tp_prefill=tp_p, pp_prefill=pp_p,
                            ep_prefill=ep_p, used_dbo=dec.used_dbo,
                            exposed_comm=dec.exposed_comm,
                            t_compute=dec.t_compute, t_comm=dec.t_comm)
    return out


# ---------------------------------------------------------------------------
# failure-aware re-search (degraded-fabric serving)
# ---------------------------------------------------------------------------

def degraded_subcluster(cl: Cluster, faults) -> Optional[Cluster]:
    """`cl` shrunk to the fault set's survivor pool with the link/plane
    derates attached, or None when no XPU survives.

    XPU-count faults carve a survivor sub-cluster exactly like the
    disaggregated-prefill pools (`_subcluster` conventions: same XPU,
    per-XPU link bandwidth and topology family; meshes re-factorize to
    the most-cubic dims via the fabric's `pool_dims` hook). Link /
    switch-plane faults stay attached to the survivor fabric — the broken
    cables are still broken after the pool shrinks."""
    cl_f = cl.with_faults(faults)
    n_surv = cl_f.survivor_xpus()
    if n_surv < 1:
        return None
    if n_surv == cl.n_xpus:
        return cl_f
    return Cluster(topology=cl.topology, n_xpus=n_surv, xpu=cl.xpu,
                   link_bw=cl.link_bw, dims=cl.fabric.pool_dims(n_surv),
                   faults=faults)


def degraded_candidates(cfg: ModelConfig, cluster: Cluster, *,
                        dtype: str = "fp8",
                        tp: Union[int, str] = "auto",
                        pp: Union[int, str] = 1
                        ) -> List[Tuple[int, int, int]]:
    """(tp, pp, ep) mappings valid on a (possibly odd-sized) survivor
    cluster. Survivor counts like 63 or 56 rarely divide the expert count,
    so the enumeration uses the padded-expert convention the disaggregated
    pools established (strict_experts=False: experts pad to the EP
    group)."""
    cands = parallelism_candidates(cfg, cluster, dtype=dtype, pp=pp,
                                   strict_experts=False)
    if tp != "auto":
        cands = [c for c in cands if c[0] == tp]
    return cands


def degraded_max_throughput(cluster: Cluster, cfg: ModelConfig, scenario, *,
                            faults=None,
                            tp: Union[int, str] = "auto",
                            pp: Union[int, str] = 1,
                            dtype: str = "fp8", dbo: bool = False,
                            sd: Optional[SpecDecConfig] = None,
                            mapping: Optional[Tuple[int, int, int]] = None,
                            backend: Optional[str] = None):
    """Best operating point of `cluster` under `faults` (which may already
    be attached to the cluster): the failure-aware re-search.

    The cluster shrinks to the survivor sub-cluster (failed XPUs, and on
    scale-out whole NIC-less nodes, leave the pool; link and switch-plane
    faults derate the surviving fabric via `Cluster.comm_spec`) and the
    (tp, pp, ep) mapping search re-runs there with padded experts.

    mapping=(tp, pp, ep) restricts the search to ONE mapping — the
    "keep the pre-fault sharding, serve a smaller batch" arm of the
    remap-vs-degrade policy (`optimizer.degrade_policy`); ep is
    re-derived as survivors/(tp*pp), since EP is device-count-defined.
    Returns None when the SLO is unreachable (or the mapping infeasible)
    on the survivor cluster."""
    cl_d = degraded_subcluster(cluster, faults if faults is not None
                               else cluster.faults)
    if cl_d is None:
        return None
    n = cl_d.n_xpus
    if mapping is not None:
        t, q, _ = mapping
        if t * q > n or n % (t * q) or q > cfg.num_layers:
            return None
        cands = [(t, q, max(n // (t * q), 1) if cfg.moe is not None else 1)]
    else:
        cands = degraded_candidates(cfg, cl_d, dtype=dtype, tp=tp, pp=pp)
    grids = [_sweep_fixed([cl_d], cfg, [scenario], dbo=dbo, sd=sd, tp=t,
                          pp=q, ep_r=e, dtype=dtype, backend=backend)
             for t, q, e in cands]
    if not grids:
        return None
    return _merge_best(grids)[0][0]


def sweep_prefill(clusters: Sequence[Cluster], cfg: ModelConfig,
                  scenarios: Sequence, mode: str = "chunked", *,
                  tp: Union[int, str] = 1, pp: Union[int, str] = 1,
                  ep: Optional[int] = None,
                  dtype: str = "fp8",
                  dbo: bool = False,
                  chunk_grid: Sequence[int] = CHUNK_GRID,
                  split_fracs: Sequence[float] = SPLIT_FRACS,
                  backend: Optional[str] = None
                  ) -> List[List[Optional["PrefillOperatingPoint"]]]:
    """Prefill-aware operating-point search over clusters x scenarios.

    mode:
      'decode'   the seed's decode-only search (prefill free) wrapped as
                 PrefillOperatingPoints — the comparison baseline;
      'chunked'  prefill chunks interleaved into decode iterations (joint
                 batch x chunk-size search under TPOT and TTFT SLOs);
      'disagg'   cluster split into prefill/decode pools (split ratio
                 swept; throughput capped by the balanced pipeline rate).

    dbo=True times every mode with the three-lane (max,+) DBO schedule
    wherever it beats no-overlap: decode iterations split into two B/2
    microbatches, prefill chunks and the disagg whole-prompt pass into two
    causal half-chunks — A2A/AR hide under the other microbatch's GEMMs,
    pp hops under both lanes. dbo=False (the default) is the no-overlap
    timing, byte-identical to the pre-DBO search.

    All three modes accept tp="auto" / pp="auto": the (tp, pp, ep =
    n/(tp*pp)) mapping axes are searched per (cluster, scenario) cell
    alongside the mode's own grid (batch x chunk for chunked, split ratio
    for disagg), ties to the smallest (tp, pp). Disagg searches the
    mapping PER POOL — the prefill and decode pools need not agree.
    Prefill modes require `scenario.prompt_len >= 1`. Clusters must share
    a device count, as in `sweep_max_throughput`.
    """
    n = clusters[0].n_xpus
    if any(cl.n_xpus != n for cl in clusters):
        raise ValueError("sweep_prefill requires a uniform device count; "
                         "group clusters by n_xpus")
    if mode == "decode":
        grid = sweep_max_throughput(clusters, cfg, scenarios, tp=tp, pp=pp,
                                    ep=ep, dtype=dtype, dbo=dbo,
                                    backend=backend)
        return [[_as_decode_point(op) for op in row] for row in grid]
    if mode not in ("chunked", "disagg"):
        raise ValueError(f"unknown prefill mode {mode!r}; expected "
                         "'decode' | 'chunked' | 'disagg'")
    for sc in scenarios:
        if getattr(sc, "prompt_len", 0) < 1:
            raise ValueError(
                f"scenario {getattr(sc, 'name', sc)!r} has no prompt_len; "
                "prefill modes need Scenario(..., prompt_len=..., ttft_ms=...)")
        if sc.prompt_len >= sc.context:
            raise ValueError(
                f"scenario {sc.name!r}: context ({sc.context}) must exceed "
                f"prompt_len ({sc.prompt_len}) — context is the AVERAGE "
                "decode KV length, prompt_len + gen_len / 2")
    if mode == "disagg":
        if ep is not None:
            raise ValueError("disagg mode resolves EP per pool; pass "
                             "ep=None")
        return _sweep_disagg(clusters, cfg, scenarios, tp, pp, dtype,
                             split_fracs, dbo=dbo, backend=backend)
    if tp == "auto" or pp == "auto":
        if ep is not None:
            raise ValueError("auto mapping search resolves ep = n/(tp*pp) "
                             "per candidate; pass ep=None")
        return _merge_best([
            _sweep_chunked(clusters, cfg, scenarios, t, q, e, dtype,
                           chunk_grid, dbo=dbo, backend=backend)
            for t, q, e in _auto_candidates(clusters, cfg, dtype, tp, pp)])
    ep_r = _resolve_parallelism(cfg, n, tp, pp, ep)
    return _sweep_chunked(clusters, cfg, scenarios, tp, pp, ep_r, dtype,
                          chunk_grid, dbo=dbo, backend=backend)
