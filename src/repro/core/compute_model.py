"""Per-layer compute-time estimation (paper section 3.2.3).

The paper profiles kernels on a real H100 (Vidur-style). Without GPU access
(DESIGN.md section 7), we use a roofline-with-efficiency model:

  t = max(flops / (peak * eff_c(op)),  bytes / (hbm_bw * eff_m)) + t_launch

with per-op-class compute efficiencies and a small fixed launch cost. The
efficiency constants are calibrated so DeepSeek-V3 decode TPOT/throughput
lands in the envelope of the public SGLang 96xH100 report the paper itself
validates against (benchmarks/validation.py cross-checks this).

Layer: leaf constants + the roofline formula, shared verbatim by the
scalar timers (`core.workload` op lists), the batched NumPy engine
(`sweep.GridEval._durations`), and the jax kernels (`sweep_jax`) — the
1e-9 scalar/batched parity contract holds because all three apply THESE
constants with the same associations.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable

from repro.core.hardware import XPUSpec

# calibrated efficiencies (fraction of peak). The paper profiles real H100
# kernels; these constants are calibrated against the public SGLang
# DeepSeek-V3 96xH100 decode trace (benchmarks/validation.py): decode-batch
# GEMMs run well below peak, KV/weight streaming below STREAM bandwidth.
EFF_COMPUTE = {
    "gemm": 0.55,          # large matmuls on tensor cores / MXU
    "gemm_small": 0.27,    # thin matmuls (decode projections at small batch)
    "attn": 0.42,          # attention core math
    "other": 0.25,
}
EFF_MEMORY = 0.58          # achievable fraction of HBM bandwidth
T_LAUNCH = 2.0e-6          # CUDA-graph/fused-step per-kernel overhead
GEMM_SMALL_TOKENS = 128    # below this many rows a GEMM is 'thin'


@dataclass(frozen=True)
class Op:
    """One compute or communication operation of an iteration."""
    name: str
    kind: str               # compute | a2a | ar
    flops: float = 0.0
    bytes: float = 0.0
    op_class: str = "gemm"
    m_bytes: float = 0.0    # payload for comm ops
    group: int = 0          # AR group size


def compute_time(op: Op, xpu: XPUSpec, *, rows: float = 1e9,
                 fp8: bool = False) -> float:
    peak = xpu.flops_fp8 if fp8 else xpu.flops_bf16
    cls = op.op_class
    if cls == "gemm" and rows < GEMM_SMALL_TOKENS:
        cls = "gemm_small"
    eff = EFF_COMPUTE.get(cls, EFF_COMPUTE["other"])
    t_c = op.flops / (peak * eff) if op.flops else 0.0
    t_m = op.bytes / (xpu.hbm_bw * EFF_MEMORY) if op.bytes else 0.0
    return max(t_c, t_m) + T_LAUNCH


def total_compute_time(ops: Iterable[Op], xpu: XPUSpec, *, rows: float,
                       fp8: bool = False) -> float:
    return sum(compute_time(o, xpu, rows=rows, fp8=fp8)
               for o in ops if o.kind == "compute")
