"""Expert-load skew and replication/placement search.

Layer: pure workload-side math, below `core.workload` (which consumes the
per-layer factors via `ServingPoint.moe_load` / `ServingPoint.moe_extra`)
and `core.sweep` (which turns them into per-op coefficient multipliers).
Nothing here touches topologies, tables, or timing.

Parity contract: every function is deterministic given
(num_experts, zipf_s, routing_seed, ep, extra_slots) — NumPy's
`default_rng` is stable across platforms, so the same Scenario produces
bit-identical load factors everywhere. Scalar (`optimizer.tpot_at`) and
batched (`sweep.GridEval`) paths both read these factors, which is what
keeps them within 1e-9 of each other under skew.

Model
-----
A `Scenario(routing="zipf", zipf_s=s, routing_seed=k)` draws, per MoE
layer, an expert-popularity vector p with p_(rank r) proportional to
r**(-s), assigned to expert ids by a seeded per-layer permutation
(`np.random.default_rng([seed, layer])`). The serving cost model then
charges the MAX per-rank expert load instead of the mean:

  load_factor(layer) = ep * max_r (sum of p_i over experts hosted on r)

which multiplies the row-linear terms of the expert grouped GEMM and the
A2A dispatch/gather payload (a symmetric collective finishes when its
hottest rank does). load_factor >= 1 always, with equality iff the load
is perfectly balanced; uniform routing gives exactly 1 and takes the
byte-identical fast path (no factors materialised at all).

Replication/placement search
----------------------------
`extra_slots=R` gives every rank R expert slots beyond its E/ep shard,
spending HBM headroom (`workload.model_shard_bytes(..., extra_experts=R)`
charges the weights; `max_batch_by_memory` shrinks the batch grid
accordingly). Replicas are allocated greedily — each of the ep*R slots
goes to the expert with the highest per-instance load p_i / c_i — and
instances are placed LPT (heaviest first into the least-loaded rank with
a free slot and no copy of that expert), flattening the per-rank and
per-link A2A load. `sweep` merges the R candidates with R=0 first, so
`placement="auto"` can never lose to no-placement and uniform scenarios
keep the byte-identical R=0 arm.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Tuple

import numpy as np

__all__ = [
    "zipf_probs",
    "replica_counts",
    "place_instances",
    "layer_load_factors",
    "point_factors",
    "hosting_factor",
]


def zipf_probs(num_experts: int, s: float, seed: int, layer: int) -> np.ndarray:
    """Per-layer expert popularity: Zipf(s) over popularity rank, assigned
    to expert ids by a seeded per-layer permutation.

    The permutation depends only on (seed, layer) — NOT on s — so for a
    fixed scenario seed the same experts stay hot as s grows, and load
    factors are monotone in s.
    """
    if s <= 0.0:
        return np.full(num_experts, 1.0 / num_experts)
    ranks = np.arange(1, num_experts + 1, dtype=np.float64)
    w = ranks ** (-float(s))
    p = w / w.sum()
    perm = np.random.default_rng([int(seed), int(layer)]).permutation(num_experts)
    out = np.empty_like(p)
    out[perm] = p  # expert id perm[r] has popularity rank r+1
    return out


def replica_counts(probs: np.ndarray, ep: int, extra_slots: int) -> np.ndarray:
    """Greedy replica allocation: grant each of the ep*extra_slots spare
    slots to the expert with the highest per-instance load p_i / c_i.

    Returns instance counts (one per expert, >= 1, <= ep — a second copy
    on the same rank is useless). Deterministic: argmax breaks ties at
    the lowest expert id.
    """
    counts = np.ones(len(probs), dtype=np.int64)
    for _ in range(int(ep) * int(extra_slots)):
        per = probs / counts
        per[counts >= ep] = -1.0
        i = int(per.argmax())
        if per[i] < 0:
            break  # every expert already has one instance per rank
        counts[i] += 1
    return counts


def place_instances(probs: np.ndarray, counts: np.ndarray, ep: int,
                    cap: int) -> np.ndarray:
    """LPT placement of expert instances into ep rank bins of `cap` slots.

    Instances (load p_i / c_i each) are sorted heaviest-first and each is
    placed on the least-loaded rank that has a free slot and no copy of
    that expert yet. Returns the per-rank load shares (sums to 1).
    Deterministic: ties break at the lower expert id / lower rank id.
    """
    loads = np.zeros(ep, dtype=np.float64)
    free = np.full(ep, int(cap), dtype=np.int64)
    hosted = [set() for _ in range(ep)]
    inst = []
    for e, c in enumerate(counts):
        inst.extend([(probs[e] / c, e)] * int(c))
    inst.sort(key=lambda t: (-t[0], t[1]))
    for load, e in inst:
        placed = False
        for r in np.argsort(loads, kind="stable"):
            if free[r] > 0 and e not in hosted[r]:
                loads[r] += load
                free[r] -= 1
                hosted[r].add(e)
                placed = True
                break
        if not placed:  # cap exhausted (cannot happen when cap*ep >= instances)
            r = int(np.argmin(loads))
            loads[r] += load
    return loads


@lru_cache(maxsize=8192)
def _layer_factor(num_experts: int, ep: int, s: float, seed: int,
                  layer: int, extra_slots: int) -> float:
    """Hot-rank load factor (ep * max per-rank load share) for one MoE layer."""
    if ep <= 1:
        return 1.0
    probs = zipf_probs(num_experts, s, seed, layer)
    if extra_slots <= 0:
        # Naive placement: experts live on ranks in id order (contiguous
        # blocks). The per-layer permutation makes this equivalent to a
        # random assignment — the un-searched baseline.
        chunks = np.array_split(probs, ep)
        worst = max(float(c.sum()) for c in chunks)
    else:
        counts = replica_counts(probs, ep, extra_slots)
        cap = max(num_experts // ep, 1) + int(extra_slots)
        worst = float(place_instances(probs, counts, ep, cap).max())
    return max(ep * worst, 1.0)


def _n_moe_layers(cfg) -> int:
    return sum(1 for spec in cfg.layer_specs if spec.ffn == "moe")


@lru_cache(maxsize=4096)
def _factors_tuple(num_experts: int, n_moe: int, ep: int, s: float,
                   seed: int, extra_slots: int) -> Tuple[float, ...]:
    return tuple(_layer_factor(num_experts, ep, s, seed, li, extra_slots)
                 for li in range(n_moe))


def layer_load_factors(cfg, scenario, ep: int,
                       extra_slots: int = 0) -> Tuple[float, ...]:
    """Per-MoE-layer hot-rank load factors for a scenario (all >= 1).

    Layer index here is the MoE ordinal (0-based among MoE layers in
    execution order) — the same counter `workload.decode_iteration` and
    `optable.moe_layer` use, so factors line up across scalar and
    batched paths.
    """
    if cfg.moe is None:
        return ()
    skewed = getattr(scenario, "is_skewed", False)
    s = float(scenario.zipf_s) if skewed else 0.0
    seed = int(getattr(scenario, "routing_seed", 0))
    return _factors_tuple(cfg.moe.num_experts, _n_moe_layers(cfg),
                          int(ep), s, seed, int(extra_slots))


def point_factors(cfg, scenario, ep: int,
                  extra_slots: int = 0) -> Tuple[float, ...]:
    """`ServingPoint.moe_load` value for a scenario: per-MoE-layer load
    factors when the scenario is skewed, or () (the byte-identical
    uniform default) otherwise."""
    if cfg.moe is None or not getattr(scenario, "is_skewed", False):
        return ()
    return layer_load_factors(cfg, scenario, ep, extra_slots)


def hosting_factor(cfg, ep: int, extra_slots: int) -> float:
    """Weight-hosting multiplier for the expert grouped GEMM's streamed
    bytes: (E/ep + extra) / (E/ep). 1.0 without replication."""
    if cfg.moe is None or extra_slots <= 0:
        return 1.0
    experts_local = max(cfg.moe.num_experts // max(ep, 1), 1)
    return (experts_local + extra_slots) / experts_local
