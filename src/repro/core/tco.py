"""Cluster TCO model (paper section 3.4).

Monthly TCO = amortized CapEx (3-year lifetime) + monthly OpEx.

CapEx:
  - XPU: catalog price each.
  - Switch: linear in capacity = radix x per-port bandwidth (R^2=0.93 fit in
    the paper); switchless topologies carry zero switch cost. The OCS
    fabric instead pays per MEMS port (bandwidth-independent).
  - Link: fixed cost per unit bandwidth per cable type; AOC = 6.7x copper;
    OCS transceiver-terminated fiber priced between the two.

OpEx: TDP x electricity price x PUE (plus switch/link port power).

An adjustment factor c scales the network share:
  monthly_tco = monthly_xpu + c * monthly_network.

Costs are reported normalized to a reference unit (paper: 'normalized to a
reference unit cost rather than absolute dollar figures').

Layer: cost side only — consumes `core.topology` inventories, never
timing; throughput/$ figures pair its output with sweep results
downstream (benchmarks), so it carries no scalar/batched parity
obligations.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.topology import Cluster

HOURS_PER_MONTH = 730.0
AMORTIZE_MONTHS = 36.0

# cost constants (catalog-derived; normalized in all reports).
# SWITCH: linear capacity fit (radix x port BW); anchors: 64x400Gbps IB/Eth
# switch (3.2 TB/s) at ~$38k and NVLink-class scale-up switching at a
# premium -> ~$18 per GB/s of capacity on the blended fit.
# COPPER: 400G DAC ~ $300 for 50 GB/s -> ~$6 per GB/s; AOC = 6.7x (paper).
SWITCH_USD_PER_GBPS = 18.0         # linear capacity model (radix x port BW)
COPPER_USD_PER_GBPS = 6.0          # per GB/s of link bandwidth
AOC_MULT = 6.7                     # paper: AOCs priced at 6.7x copper
ELECTRICITY_USD_PER_KWH = 0.083    # US industrial average
PUE = 1.3                          # paper cites LBNL AI-cluster PUE
SWITCH_W_PER_GBPS = 0.025          # switch power scales with capacity
NIC_W_PER_XPU = 25.0

# OCS fabric pricing (docs/fabrics.md): a MEMS circuit-switch port costs
# the same whatever bandwidth the light carries — the OCS thesis — so it
# is priced PER PORT, not per GB/s; the per-GB/s cost sits in the
# transceivers that terminate each fiber, between copper DACs and the
# full AOC premium (the MEMS path replaces the electrical switch tiers,
# so `switch_capacity_total` is 0 and these two lines are the whole
# network bill). Port power is the MEMS mirror drive + monitoring, a few
# W per port — far below a packet switch ASIC's per-port burn.
OCS_PORT_USD = 300.0               # per MEMS port (bandwidth-independent)
OCS_TRX_USD_PER_GBPS = 10.0        # optical transceiver, per GB/s
OCS_W_PER_PORT = 1.5


@dataclass(frozen=True)
class TCOBreakdown:
    monthly_xpu: float
    monthly_switch: float
    monthly_link: float
    monthly_energy_xpu: float
    monthly_energy_net: float

    @property
    def monthly_network(self) -> float:
        return self.monthly_switch + self.monthly_link + self.monthly_energy_net

    def total(self, c: float = 1.0) -> float:
        return self.monthly_xpu + self.monthly_energy_xpu \
            + c * self.monthly_network

    def per_xpu(self, n: int, c: float = 1.0) -> float:
        return self.total(c) / n


def cluster_tco(cluster: Cluster) -> TCOBreakdown:
    n = cluster.n_xpus
    xpu = cluster.xpu

    capex_xpu = n * xpu.cost_usd
    capex_switch = (cluster.switch_capacity_total() / 1e9) * SWITCH_USD_PER_GBPS \
        + cluster.ocs_port_count() * OCS_PORT_USD
    links = cluster.link_inventory()
    capex_link = (links.copper_gbps_total * COPPER_USD_PER_GBPS
                  + links.aoc_gbps_total * COPPER_USD_PER_GBPS * AOC_MULT
                  + links.ocs_trx_gbps_total * OCS_TRX_USD_PER_GBPS)

    kwh_price = ELECTRICITY_USD_PER_KWH * PUE * HOURS_PER_MONTH / 1000.0
    energy_xpu = n * xpu.tdp_w * kwh_price
    net_w = (cluster.switch_capacity_total() / 1e9) * SWITCH_W_PER_GBPS \
        + n * NIC_W_PER_XPU + cluster.ocs_port_count() * OCS_W_PER_PORT
    energy_net = net_w * kwh_price

    return TCOBreakdown(
        monthly_xpu=capex_xpu / AMORTIZE_MONTHS,
        monthly_switch=capex_switch / AMORTIZE_MONTHS,
        monthly_link=capex_link / AMORTIZE_MONTHS,
        monthly_energy_xpu=energy_xpu,
        monthly_energy_net=energy_net,
    )


def throughput_per_cost(throughput_tok_s: float, cluster: Cluster,
                        c: float = 1.0) -> float:
    """tokens/s per normalized monthly cost unit."""
    tco = cluster_tco(cluster).total(c)
    return throughput_tok_s / max(tco, 1e-9)


def availability_adjusted_throughput_per_cost(cluster: Cluster, cfg,
                                              scenario, *,
                                              mtbf_scale: float = 1.0,
                                              max_total_faults: int = 2,
                                              c: float = 1.0,
                                              model=None):
    """fig14's throughput/$ metric with the numerator replaced by the
    expected steady-state throughput under the stationary failure
    distribution (`core/availability.py`): the cluster still pays full TCO
    while serving degraded. Pass a prebuilt `AvailabilityModel` via
    `model` to amortize the degraded searches across an MTBF sweep.

    Returns (tokens/s per cost unit, AvailabilityReport, AvailabilityModel).
    """
    from repro.core import availability as av
    if model is None:
        model = av.build_availability(cluster, cfg, scenario,
                                      max_total_faults=max_total_faults)
    report = model.report(mtbf_scale)
    return (throughput_per_cost(report.expected_throughput, cluster, c),
            report, model)
