"""JAX-jitted sweep backend: the product-grid engine behind
`sweep.GridEval(backend="jax")`.

The NumPy engine broadcasts the whole batch-grid x scenario x cluster
search as array programs, but it materializes (n_ops, n_clusters,
n_scenarios, n_batches) temporaries and walks the comm menus and the
(max,+) lane recurrence in Python — at the 10^6-10^7-point product grids
of Fig 18-style studies (link-bw x cluster-size x XPU-generation x
scenario) that is both out of memory and out of time. This module lowers
one `optable.OpTable` + cluster list into a pytree of stacked arrays
(`optable.OpTable.coeff_pytree` columns + per-cluster collective (alpha,
m_coeff, beta) menus + XPU roofline peaks) and evaluates the grid as ONE
jitted device program:

  compute + comm  a `lax.scan` over the op axis accumulates the roofline
                  and best-algorithm collective times without ever
                  materializing the (n_ops, grid) tensor — peak memory is
                  a handful of (n_clusters, n_scenarios, n_batches) blocks
  DBO             the three-lane (max,+) recurrence of
                  `sweep._lane_makespan` as a `lax.scan` over the merged
                  (op, microbatch) order, `vmap`-ed over the static
                  stagger candidates
  prefill         the chunk-polynomial duration rows and the causal
                  half-chunk DBO makespan of `sweep._prefill_chunk_times`
  skew            expert-load factors (`sweep.op_load_factors`) ride in as
                  two extra per-op leaves (lf, cf) consumed by dedicated
                  `*_skew` kernel variants whose comm accumulator carries a
                  scenario axis; uniform grids (load=None) keep the
                  scenario-free factored kernels untouched — the >= 10x
                  product-grid speedup and the byte-identity path never
                  see the skew code

Numerics contract (docs/sweep_engine.md): every kernel runs under
`jax.experimental.enable_x64` (float64, same associations as the NumPy
path wherever practical), and the NumPy engine remains the 1e-9-vs-scalar
REFERENCE — this backend is held to <= 1e-6 relative against it
(tests/test_sweep_jax.py; in practice the agreement is ~1e-12). All public
functions take and return NumPy arrays; JAX never leaks to callers.

JAX is an install-time dependency of the repo, but this module still
degrades gracefully: `HAVE_JAX` is False when import fails and
`sweep`'s backend resolution raises a clear error instead of crashing at
first use.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Dict, Sequence, Tuple

import numpy as np

from repro.core import optable
from repro.core.compute_model import (EFF_MEMORY, GEMM_SMALL_TOKENS,
                                      T_LAUNCH)
from repro.core.overlap import LANES, MAX_STAGGER

try:  # pragma: no cover - exercised implicitly by every jax test
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental import enable_x64
    HAVE_JAX = True
except Exception:  # pragma: no cover
    jax = jnp = lax = enable_x64 = None
    HAVE_JAX = False


def require_jax() -> None:
    if not HAVE_JAX:
        raise RuntimeError(
            "sweep backend 'jax' requested but jax failed to import; "
            "install jax or use backend='numpy'")


# keys of the per-op leaves every kernel scans over (leading axis n_ops)
_PER_OP_KEYS = ("kind", "stage_scale", "eff", "eff_small", "flop_row",
                "flop_row_ctx", "flop_row_chunk", "bytes_const",
                "bytes_row", "bytes_ctx", "m_row", "A", "Mc", "Bt")
# the skew kernels additionally scan the expert-load leaves
_PER_OP_KEYS_SKEW = _PER_OP_KEYS + ("lf", "cf")


# ---------------------------------------------------------------------------
# lowering: table + clusters -> pytree of stacked arrays
# ---------------------------------------------------------------------------

def lower_comm_menus(table, clusters) -> Tuple[np.ndarray, np.ndarray,
                                               np.ndarray]:
    """Per-op collective menus as stacked arrays (n_ops, n_cl, n_alg):
    t_comm(op, cl) = min_alg(A + (Mc * m_bytes) * Bt) — exactly the
    association `sweep._comm_times` evaluates, so the jitted times match
    the NumPy ones to float rounding. Missing algorithm slots (menus have
    different sizes) and compute ops pad with A=+inf, which can never win
    the min and is masked off by the op-kind switch downstream."""
    from repro.core.sweep import _comm_menu_coeffs

    kind = np.asarray(table.kind)
    group = np.asarray(table.group)
    pairs = sorted({(int(k), int(g)) for k, g in zip(kind, group)
                    if int(k) != optable.KIND_COMPUTE})
    menus = {(ci, kg): _comm_menu_coeffs(cl, kg[0], kg[1], table.tp,
                                         table.pp)
             for ci, cl in enumerate(clusters) for kg in pairs}
    n_alg = max((len(m) for m in menus.values()), default=1)
    n_cl = len(clusters)
    A = np.full((table.n_ops, n_cl, n_alg), np.inf)
    Mc = np.zeros((table.n_ops, n_cl, n_alg))
    Bt = np.zeros((table.n_ops, n_cl, n_alg))
    for kg in pairs:
        sel = (kind == kg[0]) & (group == kg[1])
        for ci in range(n_cl):
            for j, (a, mc, bt) in enumerate(menus[ci, kg]):
                A[sel, ci, j] = a
                Mc[sel, ci, j] = mc
                Bt[sel, ci, j] = bt
    return A, Mc, Bt


def lower_grid(table, clusters) -> Dict[str, np.ndarray]:
    """One (op table, cluster list) lowered to the flat pytree the jitted
    kernels consume: the table's `coeff_pytree` columns, the stacked comm
    menus, and the per-cluster XPU roofline constants. All leaves are
    NumPy float64/int arrays — they cross into jax at call time, under the
    caller's `enable_x64` scope."""
    lw = table.coeff_pytree()
    lw["A"], lw["Mc"], lw["Bt"] = lower_comm_menus(table, clusters)
    # roofline constants per UNIQUE XPU + a cluster -> xpu gather index:
    # a link-bw x topology product grid shares a handful of XPU specs
    # across hundreds of clusters, and the roofline only depends on the
    # spec — the same dedup `GridEval._durations` does with comp_by_xpu
    fp8 = table.dtype == "fp8"
    xpu_of: Dict[int, int] = {}
    peak, hbm, idx = [], [], []
    for cl in clusters:
        key = id(cl.xpu)
        if key not in xpu_of:
            xpu_of[key] = len(peak)
            peak.append(cl.xpu.flops_fp8 if fp8 else cl.xpu.flops_bf16)
            hbm.append(cl.xpu.hbm_bw)
        idx.append(xpu_of[key])
    lw["peak"] = np.array(peak, np.float64)
    lw["hbm"] = np.array(hbm, np.float64)
    lw["xpu_idx"] = np.array(idx, np.int32)
    return lw


@lru_cache(maxsize=None)
def _stagger_orders(n_ops: int) -> Tuple[np.ndarray, np.ndarray]:
    """The merged (op, microbatch) execution orders of every static
    stagger candidate, as gather-index arrays (n_staggers, 2 * n_ops) —
    the same orders `sweep._lane_makespan` walks in Python."""
    s_max = min(MAX_STAGGER, max(n_ops - 1, 0))
    ks = np.empty((s_max + 1, 2 * n_ops), np.int32)
    mbs = np.empty_like(ks)
    for s in range(s_max + 1):
        order = sorted(((k, mb) for mb in (0, 1) for k in range(n_ops)),
                       key=lambda km: (km[0] + (s if km[1] else 0), km[1]))
        ks[s] = [k for k, _ in order]
        mbs[s] = [mb for _, mb in order]
    return ks, mbs


# ---------------------------------------------------------------------------
# jitted kernels (decode grid: rows x scenarios outer product)
# ---------------------------------------------------------------------------

def _op_factors(op, peak, hbm, rows, bpd, ctx, knee):
    """(comp, comm) of ONE op in FACTORED form — the jnp twin of
    `GridEval._durations`' per-op row: roofline with the thin-GEMM
    efficiency knee, best-algorithm alpha-beta comm time, pipeline
    `stage_scale` on both. The roofline only depends on the cluster
    through its XPU spec and the comm time is scenario-free, so the
    factors stay small — comp is (n_xpu, n_sc, n_b), comm is (n_cl, n_b)
    — and the expansion to the full (n_cl, n_sc, n_b) grid happens ONCE
    on the summed results (or per-op in `_dur_kernel`), not per op. That
    factorization is what makes the seq path >= 10x the NumPy engine: the
    hot loop touches n_xpu + n_cl rows, not n_cl * n_sc."""
    f = op["flop_row"] * rows[None, :] \
        + (op["flop_row_ctx"] * rows)[None, :] * ctx[:, None]
    by = (op["bytes_const"] + op["bytes_row"] * rows)[None, :] \
        + (op["bytes_ctx"] * bpd)[None, :] * ctx[:, None]
    eff = jnp.where(knee, op["eff_small"], op["eff"])          # (n_b,)
    t_c = f[None] / (peak[:, None, None] * eff[None, None, :])
    t_m = by[None] / (hbm[:, None, None] * EFF_MEMORY)
    comp = (jnp.maximum(t_c, t_m) + T_LAUNCH) * op["stage_scale"]
    m = op["m_row"] * rows                                     # (n_b,)
    alg = op["A"][:, :, None] \
        + (op["Mc"][:, :, None] * m[None, None, :]) * op["Bt"][:, :, None]
    comm = alg.min(axis=1) * op["stage_scale"]                 # (n_cl, n_b)
    return comp, comm, op["kind"] == optable.KIND_COMPUTE


def _jit(fn):
    return jax.jit(fn) if HAVE_JAX else fn


@_jit
def _seq_kernel(lw, rows, bpd, ctx):
    """(t_compute, t_comm) sums over the op axis, each (n_cl, n_sc, n_b).
    A `lax.scan` accumulation over the factored per-op forms: nothing of
    shape (n_ops, grid) — or even (n_cl, n_sc, n_b) — exists inside the
    loop, so grids of 10^6+ cells evaluate in-cache."""
    peak, hbm = lw["peak"], lw["hbm"]
    knee = rows < GEMM_SMALL_TOKENS
    per_op = {k: lw[k] for k in _PER_OP_KEYS}

    def step(carry, op):
        comp, comm, is_comp = _op_factors(op, peak, hbm, rows, bpd, ctx,
                                          knee)
        tc, tm = carry
        return (tc + jnp.where(is_comp, comp, 0.0),
                tm + jnp.where(is_comp, 0.0, comm)), None

    z_c = jnp.zeros((peak.shape[0], ctx.shape[0], rows.shape[0]),
                    rows.dtype)
    z_m = jnp.zeros((lw["A"].shape[1], rows.shape[0]), rows.dtype)
    (tc, tm), _ = lax.scan(step, (z_c, z_m), per_op)
    tc_full = tc[lw["xpu_idx"]]                    # (n_cl, n_sc, n_b)
    return tc_full, jnp.broadcast_to(tm[:, None, :], tc_full.shape)


def _op_factors_skew(op, peak, hbm, rows, bpd, ctx, knee):
    """(comp, comm) of ONE op under expert skew — `_op_factors` with the
    per-scenario load factor lf on the row-linear flops / bytes / payload
    terms and the hosting factor cf on the weight-stream bytes_const (the
    same associations as `GridEval._durations`' skew branch, so numpy and
    jax agree to float rounding). The payload now depends on the
    scenario, so comm is (n_cl, n_sc, n_b) — the scenario-free
    factorization is lost, which is why uniform grids keep the plain
    kernels."""
    lf = op["lf"]                                              # (n_sc,)
    f = (op["flop_row"] * rows)[None, :] * lf[:, None] \
        + (op["flop_row_ctx"] * rows)[None, :] * ctx[:, None]
    by = op["bytes_const"] * op["cf"] \
        + (op["bytes_row"] * rows)[None, :] * lf[:, None] \
        + (op["bytes_ctx"] * bpd)[None, :] * ctx[:, None]
    eff = jnp.where(knee, op["eff_small"], op["eff"])          # (n_b,)
    t_c = f[None] / (peak[:, None, None] * eff[None, None, :])
    t_m = by[None] / (hbm[:, None, None] * EFF_MEMORY)
    comp = (jnp.maximum(t_c, t_m) + T_LAUNCH) * op["stage_scale"]
    m = (op["m_row"] * rows)[None, :] * lf[:, None]            # (n_sc, n_b)
    alg = op["A"][:, :, None, None] \
        + (op["Mc"][:, :, None, None] * m[None, None]) \
        * op["Bt"][:, :, None, None]
    comm = alg.min(axis=1) * op["stage_scale"]         # (n_cl, n_sc, n_b)
    return comp, comm, op["kind"] == optable.KIND_COMPUTE


@_jit
def _seq_kernel_skew(lw, rows, bpd, ctx):
    """`_seq_kernel` for skewed grids: same scan, scenario-carrying comm
    accumulator (n_cl, n_sc, n_b)."""
    peak, hbm = lw["peak"], lw["hbm"]
    knee = rows < GEMM_SMALL_TOKENS
    per_op = {k: lw[k] for k in _PER_OP_KEYS_SKEW}

    def step(carry, op):
        comp, comm, is_comp = _op_factors_skew(op, peak, hbm, rows, bpd,
                                               ctx, knee)
        tc, tm = carry
        return (tc + jnp.where(is_comp, comp, 0.0),
                tm + jnp.where(is_comp, 0.0, comm)), None

    z_c = jnp.zeros((peak.shape[0], ctx.shape[0], rows.shape[0]),
                    rows.dtype)
    z_m = jnp.zeros((lw["A"].shape[1], ctx.shape[0], rows.shape[0]),
                    rows.dtype)
    (tc, tm), _ = lax.scan(step, (z_c, z_m), per_op)
    return tc[lw["xpu_idx"]], tm


@_jit
def _dur_kernel_skew(lw, rows, bpd, ctx):
    """`_dur_kernel` for skewed grids (per-op durations for the DBO
    makespan, full (n_ops, n_cl, n_sc, n_b))."""
    peak, hbm = lw["peak"], lw["hbm"]
    knee = rows < GEMM_SMALL_TOKENS
    per_op = {k: lw[k] for k in _PER_OP_KEYS_SKEW}

    def step(carry, op):
        comp, comm, is_comp = _op_factors_skew(op, peak, hbm, rows, bpd,
                                               ctx, knee)
        d = jnp.where(is_comp, comp[lw["xpu_idx"]], comm)
        return carry, d

    _, dur = lax.scan(step, 0, per_op)
    return dur


@_jit
def _dur_kernel(lw, rows, bpd, ctx):
    """Per-op duration tensor (n_ops, n_cl, n_sc, n_b) — the DBO makespan
    needs the individual rows (each op is gathered once per merged-order
    position), so this one does materialize the full grid per op; DBO
    callers chunk the cluster axis accordingly."""
    peak, hbm = lw["peak"], lw["hbm"]
    knee = rows < GEMM_SMALL_TOKENS
    per_op = {k: lw[k] for k in _PER_OP_KEYS}

    def step(carry, op):
        comp, comm, is_comp = _op_factors(op, peak, hbm, rows, bpd, ctx,
                                          knee)
        d = jnp.where(is_comp, comp[lw["xpu_idx"]], comm[:, None, :])
        return carry, d

    _, dur = lax.scan(step, 0, per_op)
    return dur


@_jit
def _makespan_kernel(lane, dur_a, dur_b, ks, mbs):
    """Best-stagger makespan of the fixed-order three-lane schedule —
    `sweep._lane_makespan` as a (max,+) `lax.scan` over the merged order,
    `vmap`-ed over the stagger candidates (ks/mbs: (n_staggers, 2*n_ops)
    gather indices from `_stagger_orders`). dur_a/dur_b are the two
    microbatches' (n_ops, *tail) duration tensors (equal for decode DBO,
    causal halves for prefill chunks)."""
    dur = jnp.stack([dur_a, dur_b])                 # (2, n_ops, *tail)
    tail = dur_a.shape[1:]

    def one_stagger(order):
        ks_s, mbs_s = order

        def step(carry, x):
            ready, free = carry
            k, mb = x
            end = jnp.maximum(jnp.where(mb == 0, ready[0], ready[1]),
                              free[lane[k]]) + dur[mb, k]
            ready = lax.dynamic_update_index_in_dim(ready, end, mb, 0)
            free = lax.dynamic_update_index_in_dim(free, end, lane[k], 0)
            return (ready, free), None

        init = (jnp.zeros((2,) + tail, dur.dtype),
                jnp.zeros((len(LANES),) + tail, dur.dtype))
        (ready, _), _ = lax.scan(step, init, (ks_s, mbs_s))
        return jnp.maximum(ready[0], ready[1])

    return jax.vmap(one_stagger)((ks, mbs)).min(axis=0)


# ---------------------------------------------------------------------------
# jitted kernels (prefill chunks: sizes/offsets aligned vectors)
# ---------------------------------------------------------------------------

@_jit
def _prefill_dur_kernel(lw, rows, bpd, chunk, ctx):
    """Per-op per-chunk durations (n_ops, n_chunks) of one chunk schedule
    on one cluster — the jnp twin of `sweep._prefill_chunk_durations`
    (comp and comm merged into one tensor; their supports are disjoint).
    `chunk`/`ctx` are ALIGNED vectors (one entry per chunk of the
    schedule), not an outer product, and the flop polynomial carries the
    quadratic-in-chunk `flop_row_chunk` attention term."""
    peak, hbm = lw["peak"][0], lw["hbm"][0]
    knee = rows < GEMM_SMALL_TOKENS
    per_op = {k: lw[k] for k in _PER_OP_KEYS}

    def step(carry, op):
        f = op["flop_row"] * rows + op["flop_row_ctx"] * (rows * ctx) \
            + op["flop_row_chunk"] * (rows * chunk)
        by = op["bytes_const"] + op["bytes_row"] * rows \
            + op["bytes_ctx"] * (bpd * ctx)
        eff = jnp.where(knee, op["eff_small"], op["eff"])
        comp = jnp.maximum(f / (peak * eff), by / (hbm * EFF_MEMORY)) \
            + T_LAUNCH
        m = op["m_row"] * rows
        alg = op["A"][0][:, None] \
            + (op["Mc"][0][:, None] * m[None, :]) * op["Bt"][0][:, None]
        is_comp = op["kind"] == optable.KIND_COMPUTE
        d = jnp.where(is_comp, comp, alg.min(axis=0)) * op["stage_scale"]
        return carry, d

    _, dur = lax.scan(step, 0, per_op)
    return dur


def prefill_chunk_times(ptable, cluster, batch_global: int,
                        sizes: Sequence[int], offsets: Sequence[int], *,
                        dbo: bool = False) -> np.ndarray:
    """Jitted `sweep._prefill_chunk_times`: per-chunk prefill iteration
    times, (n_chunks,). dbo=True takes best-of(no-overlap, three-lane DBO
    over the causal ceil/floor half-chunk split) per chunk."""
    require_jax()
    lw = lower_grid(ptable, [cluster])
    s_arr = np.asarray(sizes, np.float64)
    o_arr = np.asarray(offsets, np.float64)
    bpd = float(batch_global) * ptable.tp / ptable.n

    def dur(sz, off):
        return _prefill_dur_kernel(lw, bpd * sz, bpd, sz, off)

    with enable_x64():
        seq = np.asarray(dur(s_arr, o_arr).sum(axis=0))
        if not dbo:
            return seq
        h2 = np.floor(s_arr / 2)
        h1 = s_arr - h2
        mk = _makespan_kernel(np.asarray(ptable.lane, np.int32),
                              dur(h1, o_arr), dur(h2, o_arr + h1),
                              *_stagger_orders(ptable.n_ops))
        return np.where(s_arr >= 2, np.minimum(seq, np.asarray(mk)), seq)


# ---------------------------------------------------------------------------
# decode-grid engine (the jax twin of GridEval's heavy primitives)
# ---------------------------------------------------------------------------

class JaxGridEngine:
    """Jitted evaluator for one (table, clusters, scenarios, batches) grid.

    `sweep.GridEval(backend="jax")` delegates its two heavy primitives —
    the no-overlap duration sums and the DBO makespan — here; selection,
    SD combination, and the scalar winner re-derivation stay in
    `GridEval`, identical across backends. Methods return NumPy arrays of
    shape (n_clusters, n_scenarios, n_batches)."""

    def __init__(self, table, clusters, scenarios,
                 batches: np.ndarray, half: np.ndarray, load=None):
        require_jax()
        self.table = table
        self.lw = lower_grid(table, clusters)
        self.skew = load is not None
        if self.skew:
            # expert-load leaves (sweep.op_load_factors) ride the same
            # pytree; the plain kernels never select them
            self.lw["lf"] = np.asarray(load[0], np.float64)
            self.lw["cf"] = np.asarray(load[1], np.float64)
        self.ctx = np.array([sc.context for sc in scenarios], np.float64)
        self.batches = np.asarray(batches, np.float64)
        self.half = np.asarray(half, np.float64)

    def _rows(self, q: int, half: bool):
        b = self.half if half else self.batches
        bpd = b * self.table.tp / self.table.n
        return bpd * q, bpd

    def seq_components(self, q: int, half: bool = False):
        rows, bpd = self._rows(q, half)
        kernel = _seq_kernel_skew if self.skew else _seq_kernel
        with enable_x64():
            tc, tm = kernel(self.lw, rows, bpd, self.ctx)
        return np.asarray(tc), np.asarray(tm)

    def dbo_makespan(self, q: int) -> np.ndarray:
        rows, bpd = self._rows(q, half=True)
        kernel = _dur_kernel_skew if self.skew else _dur_kernel
        with enable_x64():
            dur = kernel(self.lw, rows, bpd, self.ctx)
            mk = _makespan_kernel(np.asarray(self.table.lane, np.int32),
                                  dur, dur,
                                  *_stagger_orders(self.table.n_ops))
        return np.asarray(mk)
