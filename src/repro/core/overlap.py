"""Dual-batch overlap (DBO) modeling on a THREE-lane fixed-order schedule
(paper sections 2.3, 3.3; MixServe/MixNet-style overlap-aware scheduling).

The paper models DBO'd TPOT as

  TPOT_dbo = compute(B/2) * 2 + exposed_comm

where exposed_comm comes from a fixed-order multi-lane schedule. The lanes
are the hardware resources an op occupies exclusively:

  compute    the XPU's SIMD/tensor cores (GEMMs, attention, router)
  comm       the collective fabric (expert A2A, TP all-reduce)
  sendrecv   the point-to-point pipeline channel (`pp_sendrecv` hops)

Each op of each microbatch is scheduled as soon as (a) its predecessor
within its own microbatch is done and (b) its lane is free. Communication
time not hidden under compute is the exposed communication time (ECT).

The dedicated send/recv lane is what models 1F1B-style decode pipelining:
a pp hidden-state hop occupies neither the compute units nor the
collective fabric, so it overlaps BOTH the other microbatch's GEMMs and
its collectives — folding it into the comm lane (the old two-lane model)
would serialize hops behind A2As that ride different wires. At pp = 1 the
sendrecv lane is empty and the schedule degenerates to the original
two-lane model exactly.

`simulate_lanes` is the scheduler; `dbo_best` picks the best static
stagger; `dbo_tpot` applies both to a decode op list. The same machinery
times DBO'd prefill chunks (`optimizer.prefill_iteration_dbo` splits a
chunk into two causal half-chunk microbatches) and is vectorized exactly
over sweep grids by `sweep.GridEval.dbo_makespan`.

Layer: schedule math over per-op duration lists from `core.workload` +
`core.compute_model`; `dbo_best` is the scalar REFERENCE the batched
(max,+) vectorizations (`sweep._lane_makespan`, `sweep_jax`) are held to
at 1e-9 / 1e-6 respectively.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Sequence, Tuple

from repro.core.compute_model import Op
from repro.core.workload import op_lane

# scheduler lanes, in (max,+) recurrence order; index = the integer lane
# code used by the vectorized engine (`optable.OpTable.lane`)
LANES = ("compute", "comm", "sendrecv")


@dataclass(frozen=True)
class TimedOp:
    name: str
    lane: str          # "compute" | "comm" | "sendrecv"
    duration: float
    mb: int            # microbatch id (0 or 1)


@dataclass
class ScheduleResult:
    makespan: float
    compute_busy: float
    comm_busy: float
    exposed_comm: float            # makespan - compute_busy (comm not hidden)
    timeline: List[Tuple[str, int, float, float]]   # (name, mb, start, end)
    sendrecv_busy: float = 0.0


def simulate_lanes(ops_a: Sequence[TimedOp],
                   ops_b: Sequence[TimedOp],
                   stagger: int = 0) -> ScheduleResult:
    """Fixed-order schedule of two microbatches on the `LANES` resources —
    the structure real DBO implementations pin statically: microbatch B
    runs `stagger` ops behind microbatch A, so A's collective phase lines
    up with B's compute phase (DeepSeek's DBO staggers by the attention
    block; `dbo_best` picks the best static stagger).

    Within a microbatch, ops execute strictly in order (the dependency
    chain of a transformer stack); each lane serves one op at a time in the
    merged (op-index [+ stagger for B], microbatch) order; an op starts as
    soon as its predecessor is done AND its lane is free.

    A fixed per-lane order makes every start time a (max, +) expression of
    the durations, so the makespan is MONOTONE in each duration — a greedy
    earliest-start scheduler is not (Graham anomalies let a slower network
    beat a faster one, which would corrupt every topology comparison).
    The argument generalizes to any lane count: an op's start is
    max(end of mb predecessor, end of lane predecessor), and both
    predecessors come earlier in the merged order.
    """
    streams = [list(ops_a), list(ops_b)]
    # per-lane FIFO queues in merged (k [+stagger], mb) order
    order = sorted(
        [(k, mb) for mb in (0, 1) for k in range(len(streams[mb]))],
        key=lambda km: (km[0] + (stagger if km[1] == 1 else 0), km[1]))
    queues: Dict[str, List[Tuple[int, int]]] = {lane: [] for lane in LANES}
    for k, mb in order:
        queues[streams[mb][k].lane].append((mb, k))

    ready_at = [0.0, 0.0]            # time the mb's previous op finished
    done_idx = [0, 0]                # next op index to finish per mb
    lane_free = {lane: 0.0 for lane in LANES}
    head = {lane: 0 for lane in LANES}
    timeline: List[Tuple[str, int, float, float]] = []
    busy = {lane: 0.0 for lane in LANES}

    def head_ready(lane):
        """Head op of `lane` is dependency-ready iff it is the mb's next op."""
        if head[lane] >= len(queues[lane]):
            return None
        mb, k = queues[lane][head[lane]]
        if k != done_idx[mb]:
            return None
        return mb, k

    n_total = len(streams[0]) + len(streams[1])
    while len(timeline) < n_total:
        best = None
        for lane in LANES:
            hr = head_ready(lane)
            if hr is None:
                continue
            mb, k = hr
            start = max(ready_at[mb], lane_free[lane])
            if best is None or start < best[0]:
                best = (start, lane, mb, k)
        assert best is not None, "deadlock: cyclic lane order"
        start, lane, mb, k = best
        op = streams[mb][k]
        end = start + op.duration
        lane_free[lane] = end
        ready_at[mb] = end
        done_idx[mb] += 1
        head[lane] += 1
        busy[lane] += op.duration
        timeline.append((op.name, mb, start, end))

    makespan = max(ready_at)
    return ScheduleResult(
        makespan=makespan,
        compute_busy=busy["compute"],
        comm_busy=busy["comm"],
        exposed_comm=max(makespan - busy["compute"], 0.0),
        timeline=timeline,
        sendrecv_busy=busy["sendrecv"],
    )


# ---------------------------------------------------------------------------
# glue: op list -> timed ops -> TPOT
# ---------------------------------------------------------------------------

def to_timed(ops: Sequence[Op], compute_time: Callable[[Op], float],
             comm_time: Callable[[Op], float], mb: int) -> List[TimedOp]:
    out = []
    for o in ops:
        if o.kind == "compute":
            out.append(TimedOp(o.name, "compute", compute_time(o), mb))
        else:
            out.append(TimedOp(o.name, op_lane(o.kind), comm_time(o), mb))
    return out


def sequential_tpot(ops: Sequence[Op], compute_time, comm_time) -> float:
    """No-overlap baseline: straight sum over the op list."""
    return sum((compute_time(o) if o.kind == "compute" else comm_time(o))
               for o in ops)


MAX_STAGGER = 9        # ~ops per MoE layer; staggers 0..MAX_STAGGER tried


def dbo_best(ops_a: Sequence[TimedOp],
             ops_b: Sequence[TimedOp]) -> ScheduleResult:
    """Best static stagger of microbatch B over the fixed-order schedules
    (min over fixed-order schedules: each is monotone, so the min is too).
    The microbatches may differ — DBO'd prefill chunks split causally into
    a leading ceil- and a trailing floor-half, which are not the same ops.

    A <= 1-op leading microbatch admits exactly one merged order, so the
    stagger loop would re-simulate the identical schedule MAX_STAGGER
    times; it is simulated once instead.
    """
    if len(ops_a) <= 1:
        return simulate_lanes(ops_a, ops_b, stagger=0)
    best = None
    for s in range(0, min(MAX_STAGGER, len(ops_a) - 1) + 1):
        res = simulate_lanes(ops_a, ops_b, stagger=s)
        if best is None or res.makespan < best.makespan:
            best = res
    assert best is not None, (
        f"dbo_best: no stagger schedule evaluated for microbatches of "
        f"{len(ops_a)}/{len(ops_b)} ops")
    return best


def dbo_tpot(ops_half: Sequence[Op], compute_time, comm_time) -> Tuple[float, float]:
    """(TPOT with DBO, exposed_comm). `ops_half` is the op list at B/2 —
    the caller re-derives it at half batch (compute does NOT halve at small
    batch; that is the point of paper Fig. 6)."""
    a = to_timed(ops_half, compute_time, comm_time, 0)
    b = to_timed(ops_half, compute_time, comm_time, 1)
    res = dbo_best(a, b)
    return res.makespan, res.exposed_comm
