"""Dual-batch overlap (DBO) modeling (paper sections 2.3, 3.3).

The paper models DBO'd TPOT as

  TPOT_dbo = compute(B/2) * 2 + exposed_comm

where exposed_comm comes from a greedy two-lane schedule: one compute lane,
one communication lane; each op of each microbatch is scheduled as soon as
(a) its predecessor within its own microbatch is done and (b) its lane is
free. The communication time not hidden under compute is the exposed
communication time (ECT).

`simulate_two_lane` is the scheduler; `dbo_tpot` applies it to an op list.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Sequence, Tuple

from repro.core.compute_model import Op


@dataclass(frozen=True)
class TimedOp:
    name: str
    lane: str          # "compute" | "comm"
    duration: float
    mb: int            # microbatch id (0 or 1)


@dataclass
class ScheduleResult:
    makespan: float
    compute_busy: float
    comm_busy: float
    exposed_comm: float            # makespan - compute_busy (comm not hidden)
    timeline: List[Tuple[str, int, float, float]]   # (name, mb, start, end)


def simulate_two_lane(ops_a: Sequence[TimedOp],
                      ops_b: Sequence[TimedOp],
                      stagger: int = 0) -> ScheduleResult:
    """Fixed-order schedule of two microbatches on {compute, comm} lanes —
    the structure real DBO implementations pin statically: microbatch B
    runs `stagger` ops behind microbatch A, so A's collective phase lines
    up with B's compute phase (DeepSeek's DBO staggers by the attention
    block; dbo_tpot picks the best static stagger).

    Within a microbatch, ops execute strictly in order (the dependency
    chain of a transformer stack); each lane serves one op at a time in the
    merged (op-index [+ stagger for B], microbatch) order; an op starts as
    soon as its predecessor is done AND its lane is free.

    A fixed per-lane order makes every start time a (max, +) expression of
    the durations, so the makespan is MONOTONE in each duration — a greedy
    earliest-start scheduler is not (Graham anomalies let a slower network
    beat a faster one, which would corrupt every topology comparison).
    """
    streams = [list(ops_a), list(ops_b)]
    # per-lane FIFO queues in merged (k [+stagger], mb) order
    order = sorted(
        [(k, mb) for mb in (0, 1) for k in range(len(streams[mb]))],
        key=lambda km: (km[0] + (stagger if km[1] == 1 else 0), km[1]))
    queues: Dict[str, List[Tuple[int, int]]] = {"compute": [], "comm": []}
    for k, mb in order:
        queues[streams[mb][k].lane].append((mb, k))

    ready_at = [0.0, 0.0]            # time the mb's previous op finished
    done_idx = [0, 0]                # next op index to finish per mb
    lane_free = {"compute": 0.0, "comm": 0.0}
    head = {"compute": 0, "comm": 0}
    timeline: List[Tuple[str, int, float, float]] = []
    busy = {"compute": 0.0, "comm": 0.0}

    def head_ready(lane):
        """Head op of `lane` is dependency-ready iff it is the mb's next op."""
        if head[lane] >= len(queues[lane]):
            return None
        mb, k = queues[lane][head[lane]]
        if k != done_idx[mb]:
            return None
        return mb, k

    n_total = len(streams[0]) + len(streams[1])
    while len(timeline) < n_total:
        best = None
        for lane in ("compute", "comm"):
            hr = head_ready(lane)
            if hr is None:
                continue
            mb, k = hr
            start = max(ready_at[mb], lane_free[lane])
            if best is None or start < best[0]:
                best = (start, lane, mb, k)
        assert best is not None, "deadlock: cyclic lane order"
        start, lane, mb, k = best
        op = streams[mb][k]
        end = start + op.duration
        lane_free[lane] = end
        ready_at[mb] = end
        done_idx[mb] += 1
        head[lane] += 1
        busy[lane] += op.duration
        timeline.append((op.name, mb, start, end))

    makespan = max(ready_at)
    return ScheduleResult(
        makespan=makespan,
        compute_busy=busy["compute"],
        comm_busy=busy["comm"],
        exposed_comm=max(makespan - busy["compute"], 0.0),
        timeline=timeline,
    )


# ---------------------------------------------------------------------------
# glue: op list -> timed ops -> TPOT
# ---------------------------------------------------------------------------

def to_timed(ops: Sequence[Op], compute_time: Callable[[Op], float],
             comm_time: Callable[[Op], float], mb: int) -> List[TimedOp]:
    out = []
    for o in ops:
        if o.kind == "compute":
            out.append(TimedOp(o.name, "compute", compute_time(o), mb))
        else:
            out.append(TimedOp(o.name, "comm", comm_time(o), mb))
    return out


def sequential_tpot(ops: Sequence[Op], compute_time, comm_time) -> float:
    """No-overlap baseline: straight sum over the op list."""
    return sum((compute_time(o) if o.kind == "compute" else comm_time(o))
               for o in ops)


MAX_STAGGER = 9        # ~ops per MoE layer; staggers 0..MAX_STAGGER tried


def dbo_tpot(ops_half: Sequence[Op], compute_time, comm_time) -> Tuple[float, float]:
    """(TPOT with DBO, exposed_comm). `ops_half` is the op list at B/2 —
    the caller re-derives it at half batch (compute does NOT halve at small
    batch; that is the point of paper Fig. 6). The best static stagger of
    microbatch B is selected (min over fixed-order schedules: monotone)."""
    a = to_timed(ops_half, compute_time, comm_time, 0)
    b = to_timed(ops_half, compute_time, comm_time, 1)
    best = None
    for s in range(0, min(MAX_STAGGER, max(len(a) - 1, 0)) + 1):
        res = simulate_two_lane(a, b, stagger=s)
        if best is None or res.makespan < best.makespan:
            best = res
    return best.makespan, best.exposed_comm
