"""Pluggable fabric registry: per-topology classes owning collective
placement, fault derating, survivor accounting, and TCO inventory.

`Cluster` (core/topology.py) is a thin facade: every topology-dependent
decision — the `comm_spec` placement menus (kinds 'ar' / 'a2a' /
'pp_sendrecv'), the `FaultSet` derating formulas, survivor accounting,
the switch/link inventory the TCO model prices, and the availability
model's component classes / blast-radius mapping — delegates to the
`Fabric` registered under `Cluster.topology`. Adding a topology is a
subclass plus one `register_fabric` call, no core edits (recipe in
docs/architecture.md; the fabric-by-fabric model in docs/fabrics.md).

The four static fabrics' formulas moved here VERBATIM from the former
string-matched branches of topology.py / collectives.py /
availability.py — identical float association order — so every committed
figure JSON regenerates byte-identical through the registry (the CI
gate), and the registry-parameterized conformance battery
(tests/test_fabric_conformance.py) holds each fabric to scalar==batched
1e-9 parity.

The fifth fabric, `OCSFabric`, is the ROADMAP's runtime-reconfigurable
optical circuit-switched topology (MixNet/MFABRIC, arXiv 2501.03905):
every XPU terminates OCS_PORTS fiber ports on MEMS circuit switches, and
the circuit graph is re-matched per SERVING PHASE — not per collective:
a ~25 us MEMS re-match inside each of a decode iteration's dozens of
A2As would dwarf the collectives themselves, so within a phase the
circuits are held static and only algorithms that keep the SAME partner
graph every round are on the menu (ring all-reduce yes, recursive
doubling no — its partners change per round, each change a re-match).

  decode pools    OCS_TP_BW_FRAC of the port budget holds dedicated
                  single-hop circuits around the TP neighborhood (the
                  'low-alpha neighborhood': intra-node-class alphas at
                  that fraction of provision); the remainder forms a
                  static expander over which the expert A2A runs in
                  `_circuit_hops` store-and-forward rounds.
  prefill pools   a disaggregated prefill pool is its own sub-cluster,
                  so its whole-prompt pass sees the full port budget re-
                  matched into fat circuits (full `link_bw` to its own
                  comm_spec).
  disagg handoff  the prefill->decode KV transfer rides a dedicated
                  circuit set up at the phase switch: `kv_handoff_alpha`
                  charges OCS_RECONF_S on top of the base alpha0 (static
                  fabrics return alpha0 unchanged — byte-identity).

TCO: the OCS trades the electrical switch tiers for bandwidth-
INDEPENDENT per-port MEMS cost (the OCS thesis) plus per-GB/s optical
transceivers — `link_inventory().ocs_trx_gbps_total` and
`ocs_port_count` are new inventory hooks priced in core/tco.py; static
fabrics report 0 from both, and x + 0.0 == x keeps their TCO
byte-identical.

Layer: between `core.collectives` (pure cost primitives, below) and
`core.topology` (the Cluster facade, above); tco / availability / sweep
reach fabrics only through `Cluster`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import TYPE_CHECKING, Callable, Dict, List, Optional, Tuple

from repro.core import collectives as coll
from repro.core.alphabeta import AlphaBeta, CLUSTER, INTRA_NODE

if TYPE_CHECKING:
    from repro.core.availability import ComponentClass
    from repro.core.hardware import XPUSpec
    from repro.core.topology import Cluster

DIMS_BY_SIZE = {8: (2, 2, 2), 64: (4, 4, 4), 256: (8, 8, 4), 512: (8, 8, 8)}

# XPUs per NVLink-class island inside a scale-out cluster (DGX-style node);
# a TP domain that fits the island rides its scale-up switch, not the NIC
NODE_XPUS = 8

SWITCH_RADIX = 64
SCALE_UP_PORTS = 16          # per XPU
SCALE_OUT_PORTS = 1
XPUS_PER_RACK = 64

# OCS fabric model constants (cost constants live with the other cost
# constants in core/tco.py; these shape timing and inventory COUNTS)
OCS_PORTS = 8                # fiber ports per XPU on the circuit switches
OCS_RADIX = 128              # duplex ports per MEMS circuit switch
OCS_RECONF_S = 25e-6         # MEMS re-match latency, charged per phase switch
OCS_TP_BW_FRAC = 0.5         # port fraction held as dedicated TP circuits

# bandwidth floor of a fully-failed fabric: keeps collective times finite
# (astronomical, so any feasibility check rejects them) instead of inf/NaN
_DEAD_FABRIC_FRAC = 1e-9


def _tp_subdims(dims: Tuple[int, ...],
                tp: int) -> Optional[Tuple[int, ...]]:
    """Greedy contiguous sub-mesh of `tp` devices inside `dims`: fill the
    first dimension first (matching how DIMS_BY_SIZE orders the long axes).
    Returns per-dim extents of the TP neighborhood, or None when `tp` has
    no contiguous factorization (then placement falls back to the
    whole-cluster menus)."""
    sub = []
    rem = tp
    for d in dims:
        t = math.gcd(rem, d)
        sub.append(t)
        rem //= t
    if rem != 1:
        return None
    return tuple(sub)


def _strip_ones(dims: Tuple[int, ...]) -> Tuple[int, ...]:
    return tuple(d for d in dims if d > 1) or (1,)


def most_cubic_dims(n: int) -> Tuple[int, ...]:
    """Most-cubic 3D factorization of a pool size (sub-pools of mesh
    clusters need explicit dims; DIMS_BY_SIZE only covers the paper's
    whole-cluster sizes)."""
    best = (n, 1, 1)
    for a in range(1, n + 1):
        if n % a:
            continue
        for b in range(a, n // a + 1):
            if (n // a) % b:
                continue
            c = n // (a * b)
            if c < b:
                break
            if max((c, b, a)) < max(best):
                best = (c, b, a)
    return best


def _circuit_hops(n: int, ports: int) -> int:
    """Store-and-forward hops to span `n` endpoints over a static
    degree-`ports` expander circuit graph: the smallest h whose h-hop
    neighborhood reaches the group. Integer arithmetic — a float
    ceil(log(n)/log(ports)) is platform-shaped exactly at the power-of-
    ports boundaries the paper's cluster sizes sit on."""
    h = 1
    reach = ports + 1
    while reach < n:
        reach *= ports
        h += 1
    return h


@dataclass(frozen=True)
class LinkInventory:
    copper_gbps_total: float = 0.0     # aggregate copper bandwidth (GB/s)
    aoc_gbps_total: float = 0.0        # aggregate AOC bandwidth (GB/s)
    ocs_trx_gbps_total: float = 0.0    # transceiver-terminated OCS fiber


@dataclass(frozen=True)
class FaultSet:
    """Failed components of one cluster — counts per class, not identities
    (the model is symmetric across same-class components, and collectives
    synchronize on the slowest rank, so the worst-case placement prices
    every placement).

    mesh_links     failed torus / full-mesh links per dimension (entries
                   beyond the cluster's dims, or on switched fabrics, are
                   ignored); a broken torus ring forces detour rounds, a
                   lost full-mesh direct link forces a 2-hop relay over the
                   (d-1) surviving links of its line
    switch_planes  failed scale-up switch-plane rails (of the
                   SCALE_UP_PORTS parallel planes each XPU stripes
                   across); on the OCS fabric the same counter carries
                   failed fiber/MEMS port planes (of OCS_PORTS)
    nics           failed scale-out NICs — each takes its whole NODE_XPUS
                   island node out of the serving pool
    xpus           failed XPUs (any topology)

    The zero FaultSet derates nothing; `Cluster(faults=None)` skips the
    derating code path entirely (byte-identity of the healthy model).
    """
    mesh_links: Tuple[int, ...] = ()
    switch_planes: int = 0
    nics: int = 0
    xpus: int = 0

    def __post_init__(self):
        if (any(f < 0 for f in self.mesh_links) or self.switch_planes < 0
                or self.nics < 0 or self.xpus < 0):
            raise ValueError(f"fault counts must be >= 0: {self}")
        object.__setattr__(self, "mesh_links", tuple(self.mesh_links))

    @property
    def any(self) -> bool:
        return bool(sum(self.mesh_links) or self.switch_planes
                    or self.nics or self.xpus)

    def link_at(self, i: int) -> int:
        """Failed links in mesh dim `i` (0 beyond the recorded dims)."""
        return self.mesh_links[i] if i < len(self.mesh_links) else 0


def _spread_mesh_links(cluster: "Cluster", k: int) -> Tuple[int, ...]:
    """Distribute k failed links over the mesh's active dims, longest dims
    first, round-robin — the adversarial placement (breaking a NEW
    dimension costs a fresh detour/relay penalty, and longer dims pay more
    detour rounds), so the stationary model prices the worst case."""
    dims = cluster.dims or ()
    counts = [0] * len(dims)
    order = sorted((i for i, d in enumerate(dims) if d > 1),
                   key=lambda i: -dims[i])
    if not order:
        return tuple(counts)
    caps = cluster.mesh_link_counts()
    for j in range(k):
        i = order[j % len(order)]
        if counts[i] < caps[i]:
            counts[i] += 1
    return tuple(counts)


# shared collective menus (paper Table 2): both switched electrical
# fabrics run the same NCCL-class algorithm set over the non-blocking tree
def _switched_a2a_menu(n: int) -> Dict[str, coll.CollCost]:
    return {"p2p": coll.a2a_p2p(n), "bruck": coll.a2a_bruck(n)}


def _switched_ar_menu(n: int) -> Dict[str, coll.CollCost]:
    return {"ring": coll.ar_ring(n),
            "recdouble": coll.ar_recursive_doubling(n),
            "rabenseifner": coll.ar_rabenseifner(n)}


# ---------------------------------------------------------------------------
# the Fabric interface
# ---------------------------------------------------------------------------

class Fabric:
    """One network topology's pluggable behavior bundle. Subclass,
    override the hooks whose defaults don't fit, and `register_fabric` an
    instance — `Cluster` picks it up by name and the conformance battery
    (tests/test_fabric_conformance.py) covers it automatically.

    Defaults are the no-op / zero behaviors: no dims requirement, no
    switches, no fault derating beyond lost XPUs, empty link inventory
    hooks must be provided. Hooks take the `Cluster` explicitly — fabric
    instances are stateless singletons shared by every cluster of their
    topology."""

    name: str = "?"
    # True: dims required (defaulted from DIMS_BY_SIZE), pools re-factorize
    needs_dims: bool = False
    # True: link_bw defaults to the NIC provision, not the scale-up one
    nic_provisioned: bool = False
    # True: circuit-switched — the link graph re-matches per serving phase
    # (excluded from the static TOPOLOGIES tuple the paper figures sweep)
    reconfigurable: bool = False

    # ---- provisioning / shape ----
    def default_link_bw(self, xpu: "XPUSpec") -> float:
        """Per-XPU aggregate bandwidth when `make_cluster` gets no
        link_bw (paper section 3.2: 'fix the total per-XPU network
        bandwidth')."""
        return xpu.scale_out_bw if self.nic_provisioned else xpu.scale_up_bw

    def pool_dims(self, n: int) -> Optional[Tuple[int, ...]]:
        """dims for an n-device pool carved out of a cluster of this
        fabric (disagg pools, fault survivors); None when the fabric is
        dims-free."""
        return most_cubic_dims(n) if self.needs_dims else None

    # ---- collective placement (the comm_spec seam) ----
    def a2a_menu(self, n: int,
                 dims: Optional[Tuple[int, ...]]) -> Dict[str, coll.CollCost]:
        raise NotImplementedError

    def ar_menu(self, n: int,
                dims: Optional[Tuple[int, ...]]) -> Dict[str, coll.CollCost]:
        raise NotImplementedError

    def comm_spec_healthy(self, cl: "Cluster", kind: str, group: int,
                          tp: int, pp: int):
        """(menu, bandwidth, AlphaBeta) of one collective placed under the
        healthy (tp, pp, ep) mapping — `Cluster.comm_spec` wraps it with
        the fabric-agnostic FaultSet derating."""
        raise NotImplementedError

    def kv_handoff_alpha(self, cl: "Cluster") -> float:
        """Latency term of the disagg prefill->decode KV handoff
        (`sweep._sweep_disagg`): the pool's base alpha0, plus whatever a
        fabric charges to stand the transfer path up — the OCS re-match
        is the one phase-switch cost in the static-circuit model."""
        return cl._ab().alpha0

    # ---- degraded fabric ----
    def survivor_xpus(self, cl: "Cluster") -> int:
        if cl.faults is None:
            return cl.n_xpus
        return max(cl.n_xpus - cl.faults.xpus, 0)

    def mesh_link_counts(self, cl: "Cluster") -> Tuple[int, ...]:
        """Physical link count per dimension (empty off the meshes)."""
        return ()

    def fault_derate(self, cl: "Cluster") -> Tuple[float, float, float]:
        """(bandwidth factor, extra rounds, extra dests) the attached
        FaultSet imposes on every collective placed through `comm_spec`
        (docs/failure_model.md derives the per-fabric formulas). Factor
        monotonically non-increasing — and rounds/dests non-decreasing —
        in every fault count: the invariant the conformance battery and
        the degradation-monotonicity property tests pin."""
        return 1.0, 0.0, 0.0

    # ---- inventory (priced by core/tco.py) ----
    def switch_capacity_total(self, cl: "Cluster") -> float:
        """Total packet-switch capacity in B/s (radix x port bandwidth x
        count); 0.0 for switchless and circuit-switched fabrics."""
        return 0.0

    def link_inventory(self, cl: "Cluster") -> LinkInventory:
        raise NotImplementedError

    def ocs_port_count(self, cl: "Cluster") -> int:
        """Circuit-switch (MEMS) ports the cluster terminates — priced
        per port, independent of bandwidth (the OCS thesis); 0 off the
        OCS fabric."""
        return 0

    # ---- availability (component classes + blast radius) ----
    def switch_count(self, cl: "Cluster") -> int:
        """Switch ASIC count behind `switch_capacity_total`'s sizing (0
        for the switchless meshes)."""
        return 0

    def net_component_classes(self, cl: "Cluster",
                              make: Callable[[str, int], "ComponentClass"]
                              ) -> List["ComponentClass"]:
        """Failable NETWORK component classes (the XPU row is fabric-
        agnostic and added by `availability.component_inventory`)."""
        raise NotImplementedError

    def faultset_for_counts(self, cl: "Cluster",
                            counts: Dict[str, int]) -> FaultSet:
        """Per-class failure counts -> the `FaultSet` the serving model
        consumes, encoding this fabric's blast radius."""
        raise NotImplementedError


FABRICS: Dict[str, Fabric] = {}


def register_fabric(fabric: Fabric) -> Fabric:
    """Register `fabric` under its name (insertion order is the order
    TOPOLOGIES and the figures enumerate)."""
    FABRICS[fabric.name] = fabric
    return fabric


def get_fabric(name: str) -> Fabric:
    try:
        return FABRICS[name]
    except KeyError:
        raise ValueError(
            f"unknown topology {name!r}; registered fabrics: "
            + ", ".join(repr(n) for n in FABRICS)) from None


# ---------------------------------------------------------------------------
# switched electrical fabrics (non-blocking fat-tree)
# ---------------------------------------------------------------------------

class _SwitchedFabric(Fabric):
    """Shared machinery of the two fat-tree fabrics: NCCL-class menus,
    clos switch sizing, copper/AOC cable inventory."""

    ports_per_xpu: int = 1

    def a2a_menu(self, n, dims):
        return _switched_a2a_menu(n)

    def ar_menu(self, n, dims):
        return _switched_ar_menu(n)

    def _intra_switch_bw(self, cl: "Cluster") -> float:
        """Intra-node scale-up switching the fabric carries on top of the
        cluster fabric (0.0 unless the nodes ship their own islands)."""
        return 0.0

    def switch_capacity_total(self, cl):
        intra = self._intra_switch_bw(cl)
        ports_per_xpu = self.ports_per_xpu
        port_bw = cl.link_bw / ports_per_xpu
        endpoints = cl.n_xpus * ports_per_xpu
        if endpoints <= SWITCH_RADIX * ports_per_xpu \
                and cl.n_xpus <= SWITCH_RADIX:
            # one-level: each XPU port rail goes to its own switch plane
            n_switches = ports_per_xpu
            return intra + n_switches * SWITCH_RADIX * port_bw
        # two-level folded clos: leaf (half down/half up) + spine
        down = SWITCH_RADIX // 2
        n_leaf = math.ceil(endpoints / down)
        n_spine = math.ceil(n_leaf * down / SWITCH_RADIX)
        return intra + (n_leaf + n_spine) * SWITCH_RADIX * port_bw

    def link_inventory(self, cl):
        # XPU->leaf links: intra-rack copper. Leaf->spine (two-level): AOC.
        gb = 1e9
        xpu_links_bw = cl.n_xpus * cl.link_bw
        intra = self._intra_switch_bw(cl)
        if cl.n_xpus <= SWITCH_RADIX:
            return LinkInventory(
                copper_gbps_total=(xpu_links_bw + intra) / gb)
        up_bw = xpu_links_bw                     # non-blocking
        return LinkInventory(
            copper_gbps_total=(xpu_links_bw + intra) / gb,
            aoc_gbps_total=up_bw / gb)

    def switch_count(self, cl):
        ports = self.ports_per_xpu
        endpoints = cl.n_xpus * ports
        if endpoints <= SWITCH_RADIX * ports and cl.n_xpus <= SWITCH_RADIX:
            return ports
        down = SWITCH_RADIX // 2
        n_leaf = math.ceil(endpoints / down)
        n_spine = math.ceil(n_leaf * down / SWITCH_RADIX)
        return n_leaf + n_spine

    def net_component_classes(self, cl, make):
        out = [make("link_copper", cl.n_xpus * self.ports_per_xpu)]
        if cl.n_xpus > SWITCH_RADIX:
            # two-level clos: leaf->spine AOC runs, one per endpoint port
            out.append(make("link_aoc", cl.n_xpus * self.ports_per_xpu))
        out.append(make("switch", self.switch_count(cl)))
        return out


class ScaleUpFabric(_SwitchedFabric):
    """NVLink-class scale-up domain: every XPU stripes SCALE_UP_PORTS
    rails across parallel switch planes at full provision."""

    name = "scale-up"
    ports_per_xpu = SCALE_UP_PORTS

    def comm_spec_healthy(self, cl, kind, group, tp, pp):
        n_grp = group or cl.n_xpus
        ab = cl._ab()
        if kind == "pp_sendrecv":
            # a switch hop at full provision
            return {"sendrecv": coll.pp_sendrecv()}, cl.link_bw, ab
        if kind == "a2a":
            if tp * max(pp, 1) <= 1 or n_grp >= cl.n_xpus:
                return self.a2a_menu(cl.n_xpus, cl.dims), cl.link_bw, ab
            # any ep subset of the switched fabric at full provision
            return self.a2a_menu(n_grp, None), cl.link_bw, ab
        menu = self.ar_menu(n_grp, cl.dims)
        return menu, cl.link_bw, ab

    def fault_derate(self, cl):
        # a failed switch plane removes one of the SCALE_UP_PORTS parallel
        # rails every XPU stripes across: bandwidth scales by surviving
        # planes / planes, no extra latency (the rails are independent)
        f = cl.faults
        if f is None or not f.any:
            return 1.0, 0.0, 0.0
        frac = max(SCALE_UP_PORTS - f.switch_planes, 0) / SCALE_UP_PORTS
        return max(frac, _DEAD_FABRIC_FRAC), 0.0, 0.0

    def faultset_for_counts(self, cl, counts):
        # a severed XPU-to-leaf cable idles one of that XPU's rails, and
        # collectives synchronize on the slowest rank, so it derates like
        # a plane; switch/AOC failures likewise
        k_link = counts.get("link_copper", 0) + counts.get("link_aoc", 0)
        planes = min(counts.get("switch", 0) + k_link, SCALE_UP_PORTS)
        return FaultSet(switch_planes=planes,
                        xpus=min(counts.get("xpu", 0), cl.n_xpus))


class ScaleOutFabric(_SwitchedFabric):
    """NIC-provisioned fat-tree over DGX-style nodes, each node carrying
    its own NODE_XPUS-wide NVLink island (the intra-node scale-up domain
    the TCO must not omit — paper section 3.4)."""

    name = "scale-out"
    ports_per_xpu = SCALE_OUT_PORTS
    nic_provisioned = True

    def _intra_switch_bw(self, cl):
        return cl.n_xpus * cl.xpu.scale_up_bw

    def comm_spec_healthy(self, cl, kind, group, tp, pp):
        n_grp = group or cl.n_xpus
        ab = cl._ab()
        if kind == "pp_sendrecv":
            hop = {"sendrecv": coll.pp_sendrecv()}
            if cl.n_xpus <= NODE_XPUS:
                # whole cluster inside one NVLink island: every
                # boundary rides the scale-up switch
                return hop, cl.xpu.scale_up_bw, INTRA_NODE
            # multi-island cluster: island-crossing stage boundaries
            # exist at every pp (stages >= island: all of them; stages
            # < island: the island-edge ones), and one menu prices all
            # pp-1 hops — charge the NIC, the conservative bound
            return hop, cl.link_bw, CLUSTER
        if kind == "a2a":
            if tp * max(pp, 1) <= 1 or n_grp >= cl.n_xpus:
                return self.a2a_menu(cl.n_xpus, cl.dims), cl.link_bw, ab
            # any ep subset of the switched fabric at full provision
            return self.a2a_menu(n_grp, None), cl.link_bw, ab
        if tp > 1 and n_grp == tp and n_grp < cl.n_xpus \
                and tp <= NODE_XPUS:
            # TP inside the NVLink-class island: scale-up switching at
            # the XPU's scale-up provision, intra-node latencies
            return _switched_ar_menu(n_grp), cl.xpu.scale_up_bw, INTRA_NODE
        menu = self.ar_menu(n_grp, cl.dims)
        return menu, cl.link_bw, ab

    def survivor_xpus(self, cl):
        # each failed NIC additionally takes its whole NODE_XPUS island
        # node out (the node's only path into the fabric)
        if cl.faults is None:
            return cl.n_xpus
        lost = cl.faults.xpus + cl.faults.nics * NODE_XPUS
        return max(cl.n_xpus - lost, 0)

    def fault_derate(self, cl):
        # NIC failures are node-count events (survivor_xpus), not fabric
        # derates — the surviving nodes' non-blocking tree is unaffected
        return 1.0, 0.0, 0.0

    def net_component_classes(self, cl, make):
        return super().net_component_classes(cl, make) \
            + [make("nic", cl.n_xpus)]

    def faultset_for_counts(self, cl, counts):
        # a severed XPU cable is NIC-equivalent (the node's only path); a
        # fabric-switch failure disconnects its whole down-port span of
        # XPUs (`switch_blast_xpus`); leaf-spine AOC loss is absorbed by
        # the non-blocking tree (a known under-estimate, noted in
        # docs/failure_model.md)
        xpus = counts.get("xpu", 0)
        nics = counts.get("nic", 0) + counts.get("link_copper", 0)
        xpus += counts.get("switch", 0) * switch_blast_xpus(cl)
        return FaultSet(nics=nics, xpus=min(xpus, cl.n_xpus))


def switch_blast_xpus(cluster: "Cluster") -> int:
    """XPUs a single scale-out switch failure disconnects: at one level the
    lone fabric switch serves every endpoint (the whole cluster goes dark
    — the blast-radius concentration the mesh topologies do not have);
    at two levels a leaf takes its SWITCH_RADIX/2 down-ports' XPUs."""
    if cluster.n_xpus <= SWITCH_RADIX:
        return cluster.n_xpus
    return min(SWITCH_RADIX // 2, cluster.n_xpus)


# ---------------------------------------------------------------------------
# switchless mesh fabrics (3D torus / 3D full-mesh)
# ---------------------------------------------------------------------------

class _MeshFabric(Fabric):
    """Shared machinery of the switchless meshes: dims handling, link
    census, copper/AOC split, fault spreading; each concrete mesh supplies
    its per-dimension link count, derate, and quotient-bandwidth rules."""

    needs_dims = True

    def _links_per_dim(self, cl: "Cluster", d: int) -> int:
        raise NotImplementedError

    def mesh_link_counts(self, cl):
        if not cl.dims:
            return ()
        out = []
        for d in cl.dims:
            if d <= 1:
                out.append(0)
            else:
                out.append(self._links_per_dim(cl, d))
        return tuple(out)

    def _dim_derate(self, cl: "Cluster", i: int, li: int,
                    fi: int) -> Tuple[float, float, float]:
        """(bandwidth fraction, extra rounds, extra dests) of ONE active
        dimension with fi of its li links down."""
        raise NotImplementedError

    def fault_derate(self, cl):
        f = cl.faults
        if f is None or not f.any:
            return 1.0, 0.0, 0.0
        links = self.mesh_link_counts(cl)
        active = [i for i, d in enumerate(cl.dims) if d > 1]
        if not active:
            return 1.0, 0.0, 0.0
        fracs = []
        extra_r = extra_d = 0.0
        for i in active:
            li = links[i]
            fi = min(f.link_at(i), li)
            if fi == 0:
                fracs.append(1.0)
                continue
            fr, dr, dd = self._dim_derate(cl, i, li, fi)
            fracs.append(fr)
            extra_r += dr
            extra_d += dd
        frac = sum(fracs) / len(fracs)
        return max(frac, _DEAD_FABRIC_FRAC), extra_r, extra_d

    def _pp_n_links(self, active: List[int]) -> int:
        """Links the per-XPU aggregate provision is spread across (the
        pp hop rides exactly one of them)."""
        raise NotImplementedError

    def _a2a_quotient_frac(self, cl: "Cluster", sub: Tuple[int, ...],
                           qdims: Tuple[int, ...],
                           active: List[int]) -> float:
        """Bandwidth fraction the stride-tp quotient group keeps."""
        raise NotImplementedError

    def _ar_sub_frac(self, cl: "Cluster", sub: Tuple[int, ...],
                     active: List[int]) -> float:
        """Bandwidth fraction pointing into the TP sub-mesh."""
        raise NotImplementedError

    def comm_spec_healthy(self, cl, kind, group, tp, pp):
        n_grp = group or cl.n_xpus
        ab = cl._ab()
        if kind == "pp_sendrecv":
            hop = {"sendrecv": coll.pp_sendrecv()}
            # mesh: the hop crosses the single link that leaves the stage
            # block, one of the 2*ndim (torus) / sum(d-1) (full-mesh)
            # links the per-XPU aggregate provision is spread across
            active = [d for d in (cl.dims or (cl.n_xpus,)) if d > 1]
            n_links = self._pp_n_links(active)
            return hop, cl.link_bw / max(n_links, 1), ab
        if kind == "a2a":
            if tp * max(pp, 1) <= 1 or n_grp >= cl.n_xpus:
                return self.a2a_menu(cl.n_xpus, cl.dims), cl.link_bw, ab
            stage = (_tp_subdims(cl.dims, cl.n_xpus // pp)
                     if pp > 1 else cl.dims)
            sub = _tp_subdims(stage, tp) if stage is not None else None
            if sub is None:
                return self.a2a_menu(cl.n_xpus, cl.dims), cl.link_bw, ab
            qdims = tuple(d // t for d, t in zip(stage, sub))
            menu = self.a2a_menu(n_grp, _strip_ones(qdims))
            active = [i for i, d in enumerate(cl.dims) if d > 1]
            frac = self._a2a_quotient_frac(cl, sub, qdims, active)
            return menu, cl.link_bw * max(frac, 1e-9), ab
        # all-reduce
        if tp > 1 and n_grp == tp and n_grp < cl.n_xpus:
            sub = _tp_subdims(cl.dims, tp)
            if sub is not None:
                sdims = _strip_ones(sub)
                menu = self.ar_menu(n_grp, sdims)
                active = [i for i, d in enumerate(cl.dims) if d > 1]
                frac = self._ar_sub_frac(cl, sub, active)
                return menu, cl.link_bw * max(frac, 1e-9), ab
        menu = self.ar_menu(n_grp, cl.dims)
        return menu, cl.link_bw, ab

    def _cross_frac(self, cl: "Cluster") -> float:
        """Fraction of links that leave the rack (rough: last dim
        crosses)."""
        raise NotImplementedError

    def link_inventory(self, cl):
        # switchless: every XPU's aggregate BW spread across its links;
        # links within a rack are copper, cross-rack AOC.
        gb = 1e9
        n_racks = math.ceil(cl.n_xpus / XPUS_PER_RACK)
        total_bw = cl.n_xpus * cl.link_bw      # counts each link twice/2
        if n_racks == 1:
            return LinkInventory(copper_gbps_total=total_bw / gb)
        cross_frac = self._cross_frac(cl)
        return LinkInventory(
            copper_gbps_total=total_bw * (1 - cross_frac) / gb,
            aoc_gbps_total=total_bw * cross_frac / gb)

    def net_component_classes(self, cl, make):
        # mesh links split copper/AOC by the `link_inventory` bandwidth
        # fractions over the exact physical link count
        inv = cl.link_inventory()
        total_links = sum(cl.mesh_link_counts())
        total_bw = inv.copper_gbps_total + inv.aoc_gbps_total
        aoc_frac = inv.aoc_gbps_total / total_bw if total_bw else 0.0
        n_aoc = int(round(total_links * aoc_frac))
        return [make("link_copper", total_links - n_aoc),
                make("link_aoc", n_aoc)]

    def faultset_for_counts(self, cl, counts):
        # link failures spread over dims (`_spread_mesh_links`)
        k_link = counts.get("link_copper", 0) + counts.get("link_aoc", 0)
        mesh = _spread_mesh_links(cl, k_link)
        return FaultSet(mesh_links=mesh,
                        xpus=min(counts.get("xpu", 0), cl.n_xpus))


class TorusFabric(_MeshFabric):
    """3D torus: ring dims, HalfRing / DOR-P2P A2A, Swing all-reduce."""

    name = "torus"

    def a2a_menu(self, n, dims):
        return {"halfring": coll.a2a_torus_halfring(dims),
                "p2p": coll.a2a_torus_p2p(dims)}

    def ar_menu(self, n, dims):
        return {"ring": coll.ar_ring(n), "swing": coll.ar_swing_torus(dims)}

    def _links_per_dim(self, cl, d):
        # dim of extent d: n/d rings x d links (degenerate d=2 'ring':
        # one link per pair)
        return cl.n_xpus if d > 2 else cl.n_xpus // 2

    def _dim_derate(self, cl, i, li, fi):
        # the first failed link of a dimension breaks a ring into a line:
        # wrapped traffic detours the long way, folding over the
        # surviving links (x1/2 efficiency), and ring phases pay ~d/2
        # detour rounds; further failures remove capacity linearly
        return (0.5 * (li - fi) / li,
                math.ceil(cl.dims[i] / 2),
                math.ceil(cl.dims[i] / 2))

    def _pp_n_links(self, active):
        return 2 * len(active)

    def _a2a_quotient_frac(self, cl, sub, qdims, active):
        # torus: a stride-t ring hop crosses t physical links
        return (sum(1.0 / sub[i] for i in active if qdims[i] > 1)
                / len(active))

    def _ar_sub_frac(self, cl, sub, active):
        return len([s for s in sub if s > 1]) / len(active)

    def _cross_frac(self, cl):
        return 1.0 / 3.0


class FullMeshFabric(_MeshFabric):
    """3D full-mesh: fully-connected lines per dim, DoR / one-shot A2A."""

    name = "fullmesh"

    def a2a_menu(self, n, dims):
        return {"dor": coll.a2a_fullmesh_dor(dims),
                "oneshot": coll.a2a_fullmesh_oneshot(dims)}

    def ar_menu(self, n, dims):
        # rings embed across mesh links; near-optimal aggregate bandwidth
        return {"ring": coll.ar_ring(n), "p2p": coll.ar_rabenseifner(n)}

    def _links_per_dim(self, cl, d):
        # dim of extent d: n/d lines x d(d-1)/2 direct links
        return (cl.n_xpus // d) * d * (d - 1) // 2

    def _dim_derate(self, cl, i, li, fi):
        # a lost direct link forces its pair onto a 2-hop relay across
        # the (d-1) surviving links of the line — the rerouted traffic
        # consumes 2x capacity (factor (L - 2f)/L per dim) and adds one
        # store-and-forward relay round per affected dimension
        return max(li - 2 * fi, 0) / li, 1.0, 2.0

    def _pp_n_links(self, active):
        return sum(d - 1 for d in active)

    def _a2a_quotient_frac(self, cl, sub, qdims, active):
        # stride-t peers in a full-mesh line are directly linked:
        # (q-1) of the (d-1) links per dim stay usable
        return (sum(qdims[i] - 1 for i in active)
                / sum(cl.dims[i] - 1 for i in active))

    def _ar_sub_frac(self, cl, sub, active):
        return (sum(s - 1 for s in sub)
                / sum(cl.dims[i] - 1 for i in active))

    def _cross_frac(self, cl):
        d = cl.dims
        links = sum(x - 1 for x in d)
        return (d[-1] - 1) / links


# ---------------------------------------------------------------------------
# optical circuit-switched fabric (the fifth topology)
# ---------------------------------------------------------------------------

class OCSFabric(Fabric):
    """Runtime-reconfigurable optical circuit switching: OCS_PORTS fiber
    ports per XPU into MEMS switches, circuits re-matched per serving
    phase and held static within one (see the module docstring and
    docs/fabrics.md). Within a phase only fixed-partner-graph algorithms
    exist: the expert A2A store-and-forwards over a static expander, the
    TP all-reduce rings over dedicated single-hop circuits."""

    name = "ocs"
    reconfigurable = True

    def a2a_menu(self, n, dims):
        # DOR-style store-and-forward over the held expander circuits:
        # every payload byte crosses `h` fibers, so the beta term dilates
        # by the hop count; alpha pays per-hop rounds and P2P-style
        # per-destination serialization
        h = _circuit_hops(n, OCS_PORTS)
        return {"expander": coll.CollCost(rounds=h, dests=n - 1,
                                          m_coeff=h * (n - 1) / n,
                                          name="ocs-expander")}

    def ar_menu(self, n, dims):
        # ring keeps the same left/right partners every round — the one
        # classic all-reduce that never asks for a circuit re-match
        # (recursive doubling / rabenseifner re-pair each round: each
        # re-pairing would be a MEMS re-match mid-collective)
        return {"ring": coll.ar_ring(n)}

    def comm_spec_healthy(self, cl, kind, group, tp, pp):
        n_grp = group or cl.n_xpus
        ab = cl._ab()
        if kind == "pp_sendrecv":
            # adjacent stages hold a dedicated circuit pair (one fiber
            # each way) for the hidden-state hop
            hop = {"sendrecv": coll.pp_sendrecv()}
            return hop, cl.link_bw * (2.0 / OCS_PORTS), ab
        if kind == "a2a":
            if tp * max(pp, 1) <= 1 or n_grp >= cl.n_xpus:
                # whole-cluster phase: every port joins the expander
                return self.a2a_menu(cl.n_xpus, cl.dims), cl.link_bw, ab
            # expert A2A on the ports the TP circuits don't hold
            bw = cl.link_bw * (1.0 - OCS_TP_BW_FRAC) if tp > 1 \
                else cl.link_bw
            return self.a2a_menu(n_grp, None), bw, ab
        # all-reduce
        if tp > 1 and n_grp == tp and n_grp < cl.n_xpus:
            # the low-alpha neighborhood: dedicated single-hop ring
            # circuits around the TP group — intra-node-class latency at
            # the TP fraction of the port budget
            return (self.ar_menu(n_grp, None),
                    cl.link_bw * OCS_TP_BW_FRAC, INTRA_NODE)
        return self.ar_menu(n_grp, cl.dims), cl.link_bw, ab

    def kv_handoff_alpha(self, cl):
        # the dedicated prefill->decode circuit is set up AT the phase
        # switch: one MEMS re-match on top of the base handoff latency
        return cl._ab().alpha0 + OCS_RECONF_S

    def fault_derate(self, cl):
        # a failed fiber / MEMS port idles one of the OCS_PORTS port
        # planes of its XPU, and collectives synchronize on the slowest
        # rank — the scale-up plane model over the OCS port count. A re-
        # match can route AROUND the dead port (no detour rounds), unlike
        # a torus ring break.
        f = cl.faults
        if f is None or not f.any:
            return 1.0, 0.0, 0.0
        frac = max(OCS_PORTS - f.switch_planes, 0) / OCS_PORTS
        return max(frac, _DEAD_FABRIC_FRAC), 0.0, 0.0

    def link_inventory(self, cl):
        # every port's bandwidth is transceiver-terminated fiber — priced
        # per GB/s in core/tco.py (between copper and AOC); the MEMS
        # ports themselves are the bandwidth-independent ocs_port_count
        gb = 1e9
        return LinkInventory(ocs_trx_gbps_total=cl.n_xpus * cl.link_bw / gb)

    def ocs_port_count(self, cl):
        return cl.n_xpus * OCS_PORTS

    def switch_count(self, cl):
        # MEMS switch count: every XPU port terminates on a duplex
        # OCS_RADIX-port circuit switch
        return math.ceil(cl.n_xpus * OCS_PORTS / OCS_RADIX)

    def net_component_classes(self, cl, make):
        # fibers are transceiver-terminated optics -> the AOC failure
        # class; MEMS switches reuse the switch class
        return [make("link_aoc", cl.n_xpus * OCS_PORTS),
                make("switch", self.switch_count(cl))]

    def faultset_for_counts(self, cl, counts):
        # any fiber or MEMS failure idles port planes (`fault_derate`);
        # there is no high-blast-radius packet switch to lose
        k_link = counts.get("link_copper", 0) + counts.get("link_aoc", 0)
        planes = min(counts.get("switch", 0) + k_link, OCS_PORTS)
        return FaultSet(switch_planes=planes,
                        xpus=min(counts.get("xpu", 0), cl.n_xpus))


# registration order IS the canonical enumeration order (TOPOLOGIES, the
# figure sweeps): the four static fabrics first, reconfigurable last
register_fabric(ScaleUpFabric())
register_fabric(ScaleOutFabric())
register_fabric(TorusFabric())
register_fabric(FullMeshFabric())
register_fabric(OCSFabric())
