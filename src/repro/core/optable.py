"""Structured op tables: the workload op lists lowered to coefficient
arrays — the IR between `workload` (shape formulas) and the sweep engines.

Layer: `workload.decode_iteration` / `workload.prefill_iteration` produce
per-op dataclasses; this module lowers each list ONCE per mapping into an
`OpTable` / `PrefillOpTable` of closed-form coefficients; `sweep` (NumPy
reference) and `sweep_jax` (jitted) evaluate those tables over whole
batch x {dbo, sd} x scenario x topology grids. Rebuilding the op list
(hundreds of dataclass instances) per grid point was the hot path of every
figure benchmark — with the tables the grid is a handful of broadcasts.

Tables are LRU-cached per (model, tp, ep, n_devices, dtype, kv_dtype, pp)
— the full hybrid-parallelism key, so the (tp, pp, ep) mapping search
reuses one lowering per candidate mapping. The tp > 1 op lists gain the
`moe_ar` all-reduce and the TP-sharded expert terms (see
`workload.moe_ops`); both stay inside the linear basis below, so the
probes need no new points. Each table also carries a `lane` column (int
codes into `overlap.LANES`) routing every op to its scheduler lane —
compute, collective fabric, or the dedicated pp send/recv channel — for
the vectorized three-lane (max,+) DBO schedule (`sweep._lane_makespan`) —
and a `moe_layer` column (the per-op MoE-layer ordinal from
`workload.moe_layer_ordinals`, -1 for ops expert-load skew does not
touch). Tables are always built at UNIFORM routing; skewed scenarios are
applied by the sweep as per-op constant multipliers indexed through
`moe_layer` (`sweep.op_load_factors`), so skew changes neither the cache
key nor the probe points.

Parity contract: the closed forms must match the probed workload to 1e-9
relative (`_validate` raises otherwise), which is what lets the batched
engines claim 1e-9 agreement with the scalar `optimizer` path.

Every op emitted by `workload.decode_iteration` is exactly linear in the
basis {1, rows, rows*ctx, b*ctx} where b = batch_per_device and
rows = b * q_len:

  flops   = flop_row * rows + flop_row_ctx * rows * ctx     (attn core)
  bytes   = bytes_const + bytes_row * rows + bytes_ctx * b * ctx  (KV stream)
  m_bytes = m_row * rows                                    (comm payloads)

Rather than duplicating the formulas in `workload.py` (and silently
diverging from them), the coefficients are recovered by probing
`decode_iteration` at points chosen so the linear solve is trivial
(b in {0, tp}, ctx in {0, 1}), then validated against an independent probe
at a generic (b, q, ctx) point — if a future workload change breaks the
linearity assumption, `build_op_table` raises instead of mis-sweeping.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Dict, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import workload
from repro.core.compute_model import EFF_COMPUTE
from repro.core.workload import ServingPoint

# integer codes for Op.kind
KIND_COMPUTE, KIND_A2A, KIND_AR, KIND_PP = 0, 1, 2, 3
KIND_CODES = {"compute": KIND_COMPUTE, "a2a": KIND_A2A, "ar": KIND_AR,
              "pp_sendrecv": KIND_PP}

def _lane_codes(ops) -> np.ndarray:
    """int8 lane column: index into `overlap.LANES` ("compute", "comm",
    "sendrecv" — collectives share the comm lane, pp hops get the
    dedicated send/recv lane of the three-lane (max,+) DBO schedule),
    derived from `workload.op_lane` (the scalar scheduler's tagging), so
    the vectorized schedule cannot diverge."""
    from repro.core.overlap import LANES
    return np.array([LANES.index(workload.op_lane(o.kind))
                     for o in ops], np.int8)


@dataclass(frozen=True)
class OpTable:
    """Decode-iteration op list as coefficient arrays (one row per op).

    Fixed per (model config, tp, ep, n_devices, dtype, kv_dtype); evaluated
    at any (batch, q_len, context) via the closed forms in the docstrings
    below. All arrays have shape (n_ops,).
    """
    cfg_name: str
    tp: int
    ep: int
    n: int
    dtype: str
    kv_dtype: str
    pp: int

    names: Tuple[str, ...]
    kind: np.ndarray           # int8, KIND_* codes
    lane: np.ndarray           # int8, LANE_* codes (three-lane DBO schedule)
    group: np.ndarray          # AR group / pp-hop stage count (0 otherwise)
    stage_scale: np.ndarray    # per-op pipeline bottleneck factor (1.0 at pp|L)
    eff: np.ndarray            # compute efficiency at rows >= GEMM_SMALL_TOKENS
    eff_small: np.ndarray      # compute efficiency below the thin-GEMM cutoff

    flop_row: np.ndarray       # FLOPs per row
    flop_row_ctx: np.ndarray   # FLOPs per row per context token (attn core)
    bytes_const: np.ndarray    # weight bytes streamed regardless of batch
    bytes_row: np.ndarray      # activation bytes per row
    bytes_ctx: np.ndarray      # KV bytes per request per context token
    m_row: np.ndarray          # comm payload bytes per row
    moe_layer: np.ndarray      # int32 MoE-layer ordinal of skew-scaled ops
                               # (workload.moe_layer_ordinals; -1 otherwise)

    @property
    def n_ops(self) -> int:
        return len(self.names)

    @property
    def is_compute(self) -> np.ndarray:
        return self.kind == KIND_COMPUTE

    def coeff_pytree(self) -> Dict[str, np.ndarray]:
        """The coefficient columns as a flat pytree of stacked arrays —
        the interchange format of the jitted sweep backend
        (`repro.core.sweep_jax`): every leaf is an (n_ops,) array, so the
        whole table flows through `jax.jit`/`vmap` as one structure with
        no per-op Python objects left. Float columns are emitted as
        float64 (the x64 contract of the jax backend)."""
        return {
            "kind": np.asarray(self.kind, np.int32),
            "lane": np.asarray(self.lane, np.int32),
            "group": np.asarray(self.group, np.int64),
            "stage_scale": np.asarray(self.stage_scale, np.float64),
            "eff": np.asarray(self.eff, np.float64),
            "eff_small": np.asarray(self.eff_small, np.float64),
            "flop_row": np.asarray(self.flop_row, np.float64),
            "flop_row_ctx": np.asarray(self.flop_row_ctx, np.float64),
            "flop_row_chunk": np.zeros(self.n_ops, np.float64),
            "bytes_const": np.asarray(self.bytes_const, np.float64),
            "bytes_row": np.asarray(self.bytes_row, np.float64),
            "bytes_ctx": np.asarray(self.bytes_ctx, np.float64),
            "m_row": np.asarray(self.m_row, np.float64),
            "moe_layer": np.asarray(self.moe_layer, np.int32),
        }

    # ------------- closed-form evaluation -------------
    def batch_per_device(self, batches: np.ndarray) -> np.ndarray:
        return np.asarray(batches, float) * self.tp / self.n

    def rows(self, batches: np.ndarray, q_len: int) -> np.ndarray:
        return self.batch_per_device(batches) * q_len

    def flops(self, batches: np.ndarray, q_len: int, ctx: int) -> np.ndarray:
        """(n_ops, *batches.shape) FLOPs per op."""
        rows = self.rows(batches, q_len)
        return (self.flop_row[:, None] * rows
                + self.flop_row_ctx[:, None] * (rows * ctx))

    def op_bytes(self, batches: np.ndarray, q_len: int, ctx: int) -> np.ndarray:
        rows = self.rows(batches, q_len)
        b = self.batch_per_device(batches)
        return (self.bytes_const[:, None] + self.bytes_row[:, None] * rows
                + self.bytes_ctx[:, None] * (b * ctx))

    def m_bytes(self, batches: np.ndarray, q_len: int) -> np.ndarray:
        return self.m_row[:, None] * self.rows(batches, q_len)


def _stage_scale(names, n_layers: int, pp: int) -> np.ndarray:
    """Per-op pipeline bottleneck multiplier: per-layer ops
    (`workload.is_per_layer_op`) repeat on the largest stage
    `stage_imbalance` times per round; the lm head and the pp hops ride
    the round once. All ones at pp=1 and whenever pp divides the layer
    count."""
    imb = workload.stage_imbalance(n_layers, pp)
    return np.array([imb if workload.is_per_layer_op(nm) else 1.0
                     for nm in names])


def _probe(cfg: ModelConfig, *, batch_global: int, context: int, q_len: int,
           tp: int, ep: int, n: int, dtype: str, kv_dtype: str, pp: int = 1):
    p = ServingPoint(batch_global=batch_global, context=context, tp=tp,
                     ep=ep, n_devices=n, dtype=dtype, kv_dtype=kv_dtype,
                     q_len=q_len, pp=pp)
    ops = workload.decode_iteration(cfg, p)
    return (tuple(o.name for o in ops),
            np.array([o.flops for o in ops]),
            np.array([o.bytes for o in ops]),
            np.array([o.m_bytes for o in ops]),
            ops)


def build_op_table(cfg: ModelConfig, *, tp: int = 1, ep: int = 1,
                   n_devices: int = 0, dtype: str = "fp8",
                   kv_dtype: str = "bf16", pp: int = 1) -> OpTable:
    """Lower one decode iteration to an OpTable via linear probes.

    Probe points: b=0 isolates constant (weight) bytes; b=tp (i.e.
    batch_global=n, which makes batch_per_device exactly tp) isolates the
    per-row terms; ctx 0 vs 1 isolates the context terms. pp > 1 adds the
    pp-1 `pp_sendrecv` hop rows (payload linear in rows, so the same
    probes recover them) and the `stage_scale` bottleneck column.
    """
    n = n_devices or (ep * tp * pp)
    kw = dict(tp=tp, ep=ep, n=n, dtype=dtype, kv_dtype=kv_dtype, pp=pp)
    names0, f0, by0, m0, ops = _probe(cfg, batch_global=0, context=0,
                                      q_len=1, **kw)
    names1, f1, by1, m1, _ = _probe(cfg, batch_global=n, context=0,
                                    q_len=1, **kw)
    names2, f2, by2, m2, _ = _probe(cfg, batch_global=n, context=1,
                                    q_len=1, **kw)
    if not (names0 == names1 == names2):
        raise ValueError("op-list structure varies with batch/context; "
                         "cannot lower to a table")

    b1 = float(tp)                       # batch_per_device at the b-probes
    flop_row = f1 / b1
    flop_row_ctx = (f2 - f1) / b1
    bytes_const = by0
    bytes_row = (by1 - by0) / b1
    bytes_ctx = (by2 - by1) / b1
    m_row = m1 / b1

    eff = np.array([EFF_COMPUTE.get(o.op_class, EFF_COMPUTE["other"])
                    for o in ops])
    eff_small = np.array([
        EFF_COMPUTE["gemm_small"] if o.op_class == "gemm"
        else EFF_COMPUTE.get(o.op_class, EFF_COMPUTE["other"])
        for o in ops])

    table = OpTable(
        cfg_name=cfg.name, tp=tp, ep=ep, n=n, dtype=dtype, kv_dtype=kv_dtype,
        pp=pp, names=names0,
        kind=np.array([KIND_CODES[o.kind] for o in ops], np.int8),
        lane=_lane_codes(ops),
        group=np.array([o.group for o in ops], np.int64),
        stage_scale=_stage_scale(names0, cfg.num_layers, pp),
        eff=eff, eff_small=eff_small,
        flop_row=flop_row, flop_row_ctx=flop_row_ctx,
        bytes_const=bytes_const, bytes_row=bytes_row, bytes_ctx=bytes_ctx,
        m_row=m_row,
        moe_layer=np.array(workload.moe_layer_ordinals(names0), np.int32))
    _validate(cfg, table, **kw)
    return table


def _validate(cfg: ModelConfig, table: OpTable, *, tp, ep, n, dtype,
              kv_dtype, pp=1, rtol: float = 1e-9):
    """Cross-check the closed forms against a generic probe point. Guards
    against future nonlinearity creeping into `workload.decode_iteration`."""
    bg, ctx, q = 3 * n, 37, 2
    _, f, by, m, _ = _probe(cfg, batch_global=bg, context=ctx, q_len=q,
                            tp=tp, ep=ep, n=n, dtype=dtype,
                            kv_dtype=kv_dtype, pp=pp)
    batches = np.array([bg], float)
    got_f = table.flops(batches, q, ctx)[:, 0]
    got_by = table.op_bytes(batches, q, ctx)[:, 0]
    got_m = table.m_bytes(batches, q)[:, 0]
    for got, want, what in ((got_f, f, "flops"), (got_by, by, "bytes"),
                            (got_m, m, "m_bytes")):
        err = np.abs(got - want) / np.maximum(np.abs(want), 1.0)
        if err.max() > rtol:
            i = int(err.argmax())
            raise ValueError(
                f"op table diverges from decode_iteration on {what} for op "
                f"{table.names[i]!r}: {got[i]!r} vs {want[i]!r} — workload "
                "formulas are no longer linear in the sweep basis")


# Cache bound of the two table caches. 64 was enough for one figure's
# (tp, pp, ep) candidate set, but mapping x model x fault product grids
# (degraded re-search enumerates mappings per survivor count) cycle through
# hundreds of distinct keys and thrashed it — every eviction re-runs the
# probe + validate lowering. Tables are a few KB each, so a generous bound
# is effectively free; `cache_stats()` surfaces the hit/miss counters (the
# harness records them in BENCH_sweep_timing.json).
TABLE_CACHE_MAXSIZE = 1024


@lru_cache(maxsize=TABLE_CACHE_MAXSIZE)
def op_table(cfg: ModelConfig, tp: int, ep: int, n_devices: int,
             dtype: str = "fp8", kv_dtype: str = "bf16",
             pp: int = 1) -> OpTable:
    """LRU-cached table builder — the sweep engine's entry point, keyed on
    the full (model, tp, pp, ep, n, dtype) mapping. ModelConfig is a frozen
    dataclass, so it hashes by value and config edits miss the cache as
    they should."""
    return build_op_table(cfg, tp=tp, ep=ep, n_devices=n_devices,
                          dtype=dtype, kv_dtype=kv_dtype, pp=pp)


# ---------------------------------------------------------------------------
# prefill tables
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PrefillOpTable:
    """`workload.prefill_iteration` lowered to polynomial coefficients.

    With b = batch_per_device, rows = b * chunk, and ctx = tokens already
    cached when the chunk starts, every prefill op is exactly a polynomial
    over the basis

      flops   = flop_row * rows + flop_row_ctx * rows*ctx
                + flop_row_chunk * rows*chunk          (causal intra-chunk)
      bytes   = bytes_const + bytes_row * rows + bytes_ctx * b*ctx
      m_bytes = m_row * rows

    (the rows*chunk flop term is the quadratic-in-chunk attention core; the
    chunk's own KV streaming lands in bytes_row since it is linear in rows).
    As with the decode table, coefficients are recovered by probing
    `prefill_iteration` rather than re-deriving formulas, and validated at
    an independent (batch, chunk, context) point so nonlinearity creeping
    into the workload raises instead of mis-sweeping.
    """
    cfg_name: str
    tp: int
    ep: int
    n: int
    dtype: str
    kv_dtype: str
    pp: int

    names: Tuple[str, ...]
    kind: np.ndarray
    lane: np.ndarray
    group: np.ndarray
    stage_scale: np.ndarray
    eff: np.ndarray
    eff_small: np.ndarray

    flop_row: np.ndarray
    flop_row_ctx: np.ndarray
    flop_row_chunk: np.ndarray
    bytes_const: np.ndarray
    bytes_row: np.ndarray
    bytes_ctx: np.ndarray
    m_row: np.ndarray
    moe_layer: np.ndarray      # int32 MoE-layer ordinal of skew-scaled ops

    @property
    def n_ops(self) -> int:
        return len(self.names)

    @property
    def is_compute(self) -> np.ndarray:
        return self.kind == KIND_COMPUTE

    def coeff_pytree(self) -> Dict[str, np.ndarray]:
        """Coefficient columns as a pytree of stacked (n_ops,) arrays —
        same leaves as `OpTable.coeff_pytree` (shared jitted kernels), the
        prefill table just carries a nonzero `flop_row_chunk` column (the
        quadratic-in-chunk causal attention core)."""
        return {
            "kind": np.asarray(self.kind, np.int32),
            "lane": np.asarray(self.lane, np.int32),
            "group": np.asarray(self.group, np.int64),
            "stage_scale": np.asarray(self.stage_scale, np.float64),
            "eff": np.asarray(self.eff, np.float64),
            "eff_small": np.asarray(self.eff_small, np.float64),
            "flop_row": np.asarray(self.flop_row, np.float64),
            "flop_row_ctx": np.asarray(self.flop_row_ctx, np.float64),
            "flop_row_chunk": np.asarray(self.flop_row_chunk, np.float64),
            "bytes_const": np.asarray(self.bytes_const, np.float64),
            "bytes_row": np.asarray(self.bytes_row, np.float64),
            "bytes_ctx": np.asarray(self.bytes_ctx, np.float64),
            "m_row": np.asarray(self.m_row, np.float64),
            "moe_layer": np.asarray(self.moe_layer, np.int32),
        }

    # ------------- closed-form evaluation -------------
    # `chunk` and `ctx` broadcast together (e.g. the per-chunk sizes and
    # offsets of one chunked-prefill schedule); `batch_global` is scalar.
    def batch_per_device(self, batch_global: float) -> float:
        return batch_global * self.tp / self.n

    def rows(self, batch_global: float, chunk: np.ndarray) -> np.ndarray:
        return self.batch_per_device(batch_global) * np.asarray(chunk, float)

    def flops(self, batch_global: float, chunk: np.ndarray,
              ctx: np.ndarray) -> np.ndarray:
        """(n_ops, *chunk.shape) FLOPs per op."""
        rows = self.rows(batch_global, chunk)
        ctx = np.asarray(ctx, float)
        return (self.flop_row[:, None] * rows
                + self.flop_row_ctx[:, None] * (rows * ctx)
                + self.flop_row_chunk[:, None] * (rows * np.asarray(chunk,
                                                                    float)))

    def op_bytes(self, batch_global: float, chunk: np.ndarray,
                 ctx: np.ndarray) -> np.ndarray:
        rows = self.rows(batch_global, chunk)
        b = self.batch_per_device(batch_global)
        ctx = np.asarray(ctx, float)
        return (self.bytes_const[:, None] + self.bytes_row[:, None] * rows
                + self.bytes_ctx[:, None] * (b * ctx))

    def m_bytes(self, batch_global: float, chunk: np.ndarray) -> np.ndarray:
        return self.m_row[:, None] * self.rows(batch_global, chunk)


def _probe_prefill(cfg: ModelConfig, *, batch_global: int, context: int,
                   chunk: int, tp: int, ep: int, n: int, dtype: str,
                   kv_dtype: str, pp: int = 1):
    p = ServingPoint(batch_global=batch_global, context=context, tp=tp,
                     ep=ep, n_devices=n, dtype=dtype, kv_dtype=kv_dtype,
                     pp=pp)
    ops = workload.prefill_iteration(cfg, p, chunk)
    return (tuple(o.name for o in ops),
            np.array([o.flops for o in ops]),
            np.array([o.bytes for o in ops]),
            np.array([o.m_bytes for o in ops]),
            ops)


def build_prefill_op_table(cfg: ModelConfig, *, tp: int = 1, ep: int = 1,
                           n_devices: int = 0, dtype: str = "fp8",
                           kv_dtype: str = "bf16",
                           pp: int = 1) -> PrefillOpTable:
    """Lower one prefill iteration to a PrefillOpTable via polynomial probes.

    Probe points: b=0 isolates constant (weight) bytes; at b=tp, chunk 1 vs
    2 (ctx=0) separates the rows and rows*chunk flop terms; ctx 0 vs 1 at
    chunk=1 isolates the context terms.
    """
    n = n_devices or (ep * tp * pp)
    kw = dict(tp=tp, ep=ep, n=n, dtype=dtype, kv_dtype=kv_dtype, pp=pp)
    names0, f0, by0, m0, ops = _probe_prefill(cfg, batch_global=0, context=0,
                                              chunk=1, **kw)
    names1, f1, by1, m1, _ = _probe_prefill(cfg, batch_global=n, context=0,
                                            chunk=1, **kw)
    names2, f2, by2, m2, _ = _probe_prefill(cfg, batch_global=n, context=0,
                                            chunk=2, **kw)
    names3, f3, by3, m3, _ = _probe_prefill(cfg, batch_global=n, context=1,
                                            chunk=1, **kw)
    if not (names0 == names1 == names2 == names3):
        raise ValueError("prefill op-list structure varies with "
                         "batch/chunk/context; cannot lower to a table")

    b1 = float(tp)                       # batch_per_device at the b-probes
    # flops: f1 = b1*(fr + fc); f2 = b1*(2*fr + 4*fc); f3 adds b1*fctx
    flop_row_chunk = (f2 - 2 * f1) / (2 * b1)
    flop_row = f1 / b1 - flop_row_chunk
    flop_row_ctx = (f3 - f1) / b1
    bytes_const = by0
    bytes_row = (by1 - by0) / b1
    bytes_ctx = (by3 - by1) / b1
    m_row = m1 / b1

    eff = np.array([EFF_COMPUTE.get(o.op_class, EFF_COMPUTE["other"])
                    for o in ops])
    eff_small = np.array([
        EFF_COMPUTE["gemm_small"] if o.op_class == "gemm"
        else EFF_COMPUTE.get(o.op_class, EFF_COMPUTE["other"])
        for o in ops])

    table = PrefillOpTable(
        cfg_name=cfg.name, tp=tp, ep=ep, n=n, dtype=dtype, kv_dtype=kv_dtype,
        pp=pp, names=names0,
        kind=np.array([KIND_CODES[o.kind] for o in ops], np.int8),
        lane=_lane_codes(ops),
        group=np.array([o.group for o in ops], np.int64),
        stage_scale=_stage_scale(names0, cfg.num_layers, pp),
        eff=eff, eff_small=eff_small,
        flop_row=flop_row, flop_row_ctx=flop_row_ctx,
        flop_row_chunk=flop_row_chunk,
        bytes_const=bytes_const, bytes_row=bytes_row, bytes_ctx=bytes_ctx,
        m_row=m_row,
        moe_layer=np.array(workload.moe_layer_ordinals(names0), np.int32))
    _validate_prefill(cfg, table, **kw)
    return table


def _validate_prefill(cfg: ModelConfig, table: PrefillOpTable, *, tp, ep, n,
                      dtype, kv_dtype, pp=1, rtol: float = 1e-9):
    """Cross-check the closed forms against a generic probe point (the
    chunk=7 probe would expose e.g. a cubic-in-chunk term the chunk={1,2}
    fit could not see)."""
    bg, chunk, ctx = 3 * n, 7, 37
    _, f, by, m, _ = _probe_prefill(cfg, batch_global=bg, context=ctx,
                                    chunk=chunk, tp=tp, ep=ep, n=n,
                                    dtype=dtype, kv_dtype=kv_dtype, pp=pp)
    c_arr = np.array([chunk], float)
    o_arr = np.array([ctx], float)
    got_f = table.flops(bg, c_arr, o_arr)[:, 0]
    got_by = table.op_bytes(bg, c_arr, o_arr)[:, 0]
    got_m = table.m_bytes(bg, c_arr)[:, 0]
    for got, want, what in ((got_f, f, "flops"), (got_by, by, "bytes"),
                            (got_m, m, "m_bytes")):
        err = np.abs(got - want) / np.maximum(np.abs(want), 1.0)
        if err.max() > rtol:
            i = int(err.argmax())
            raise ValueError(
                f"prefill op table diverges from prefill_iteration on "
                f"{what} for op {table.names[i]!r}: {got[i]!r} vs "
                f"{want[i]!r} — workload formulas are no longer polynomial "
                "in the prefill sweep basis")


@lru_cache(maxsize=TABLE_CACHE_MAXSIZE)
def prefill_op_table(cfg: ModelConfig, tp: int, ep: int, n_devices: int,
                     dtype: str = "fp8", kv_dtype: str = "bf16",
                     pp: int = 1) -> PrefillOpTable:
    """LRU-cached prefill table builder — the prefill sweep's entry point."""
    return build_prefill_op_table(cfg, tp=tp, ep=ep, n_devices=n_devices,
                                  dtype=dtype, kv_dtype=kv_dtype, pp=pp)


def cache_stats() -> Dict[str, Dict[str, int]]:
    """Hit/miss counters of the two table caches (cumulative since import,
    or since the last `clear_caches()`). The benchmark harness writes these
    into BENCH_sweep_timing.json so a cache-thrashing regression (misses ~
    evaluations instead of ~ distinct mappings) is visible in the committed
    record."""
    out = {}
    for name, fn in (("op_table", op_table),
                     ("prefill_op_table", prefill_op_table)):
        info = fn.cache_info()
        out[name] = {"hits": info.hits, "misses": info.misses,
                     "maxsize": info.maxsize, "currsize": info.currsize}
    return out


def clear_caches() -> None:
    """Reset both table caches (and their counters) — for benchmarks that
    want a cold-start measurement."""
    op_table.cache_clear()
    prefill_op_table.cache_clear()
