"""MoE decode iteration -> ordered list of compute + communication ops
(paper sections 2.1, 3.2.3).

One decode iteration of an MoE transformer under TP x EP is a repeating
per-layer pattern:

  [attn: qkv-proj, attn-core, o-proj, AR(tp)]
  [moe : router, A2A dispatch, expert FFN, A2A gather, (+shared expert)]

The per-device tensor shapes follow the Vidur observation the paper leans
on: every device in a parallelism domain executes the same-shaped shard, so
we derive shapes analytically from (batch, context, config, TP, EP) and feed
them to the roofline-with-efficiency compute model.

Expert-load skew (`core.placement`): uniform routing is the default and the
byte-identical fast path. A skewed scenario threads per-MoE-layer hot-rank
load factors through `ServingPoint.moe_load` (and replica slots through
`ServingPoint.moe_extra`); `moe_ops` then charges the MAX per-rank expert
load — grouped-GEMM row terms and A2A payload scale by the factor, the
expert weight stream by the hosted-expert count. Ops affected are exactly
`SKEW_SCALED_OPS`; `moe_layer_ordinals` maps op names to the per-layer
factor index and is the single source of truth shared with
`optable.OpTable.moe_layer`.

All sizes below are PER DEVICE unless suffixed `_global`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core.compute_model import Op

BYTES = {"bf16": 2, "fp8": 1, "fp16": 2, "f32": 4}

# scheduler lane of each communication kind (see `repro.core.overlap`):
# collectives (expert A2A, TP all-reduce) contend for the fabric on the
# "comm" lane; `pp_sendrecv` hops ride the dedicated point-to-point
# "sendrecv" lane, so pipeline hops overlap BOTH compute and collectives
# under the (max,+) DBO schedule (1F1B-style decode pipelining)
COMM_LANES = {"a2a": "comm", "ar": "comm", "pp_sendrecv": "sendrecv"}


def op_lane(kind: str) -> str:
    """Scheduler lane of an `Op.kind` — the single source of truth shared
    by the scalar scheduler (`overlap.to_timed`) and the vectorized lane
    column (`optable.OpTable.lane`)."""
    return "compute" if kind == "compute" else COMM_LANES[kind]


@dataclass(frozen=True)
class ServingPoint:
    """One operating point of the serving cluster.

    Parallelism is the hybrid (tp, pp, ep) mapping: the cluster splits
    into `pp` pipeline stages of n/pp devices, each stage an
    (n/(tp*pp)) x tp grid over its share of the layer stack. Attention
    runs data-parallel over the stage's n/(tp*pp) TP domains, TP-sharded
    inside each. MoE experts are EP over the `ep` expert groups of the
    stage (one group per TP domain when ep = n/(tp*pp)) and TP-sharded
    over the tp devices inside a group. With pp > 1 the batch circulates
    as pp microbatches (one per stage), so the per-device row count
    stays batch_global * tp / n and TPOT is the latency sum over all
    stages plus the pp-1 inter-stage hidden-state hops (see
    `decode_iteration`). The paper's fixed mapping is (tp=1, pp=1,
    ep=n) — and all (tp=1, pp=1) op lists are byte-identical to it.
    `n_devices` defaults to ep*tp*pp.
    """
    batch_global: int            # requests in flight per iteration (decode)
    context: int                 # average context length (KV length)
    tp: int = 1                  # tensor parallel degree
    ep: int = 1                  # expert parallel degree
    n_devices: int = 0           # 0 -> ep * tp * pp
    dtype: str = "fp8"           # weights/activations wire format
    kv_dtype: str = "bf16"
    q_len: int = 1               # >1 during SD verification
    pp: int = 1                  # pipeline-parallel degree (layer stages)
    # expert-load skew (core.placement): per-MoE-layer hot-rank load
    # factors (execution order; () = uniform, the byte-identical default)
    # and replica expert slots hosted per rank beyond the E/ep shard
    moe_load: Tuple[float, ...] = ()
    moe_extra: int = 0

    @property
    def n(self) -> int:
        return self.n_devices or (self.ep * self.tp * self.pp)

    @property
    def batch_per_device(self) -> float:
        # requests each device is responsible for (DP-attention domains);
        # pp-invariant: the stage's microbatch B/pp spreads over the
        # stage's n/(tp*pp) domains, so rows per device stay B*tp/n
        return self.batch_global * self.tp / self.n


def _wb(p: ServingPoint) -> int:
    return BYTES[p.dtype]


# ---------------------------------------------------------------------------
# per-layer op builders
# ---------------------------------------------------------------------------

def attention_ops(cfg: ModelConfig, p: ServingPoint) -> List[Op]:
    """Self-attention sublayer of ONE layer (decode, MLA or GQA)."""
    d = cfg.d_model
    b = p.batch_per_device            # rows through the projections
    q = p.q_len
    rows = b * q
    wb = _wb(p)
    kvb = BYTES[p.kv_dtype]
    ops: List[Op] = []

    if cfg.attn_kind == "mla":
        r, qr, rp = cfg.mla_kv_lora_rank, cfg.mla_q_lora_rank, cfg.mla_rope_head_dim
        nh, hd = cfg.num_heads, cfg.head_dim
        # down projections + up projections (weights sharded over tp where applicable)
        w_down = d * (r + rp) + d * qr
        w_up = (qr * nh * (hd + rp) + r * nh * 2 * hd + nh * hd * d) / p.tp
        for name, w in (("mla_down", w_down), ("mla_up", w_up)):
            ops.append(Op(name=name, kind="compute",
                          flops=2 * rows * w, bytes=w * wb + rows * d * wb,
                          op_class="gemm"))
        # attention core against compressed KV cache [b, ctx, r+rp]
        kv_bytes = b * p.context * (r + rp) * kvb
        core_flops = 2 * b * q * (nh / p.tp) * p.context * (r + rp) * 2
        ops.append(Op(name="mla_core", kind="compute", flops=core_flops,
                      bytes=kv_bytes, op_class="attn"))
    else:
        nh, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        w_qkv = d * (nh + 2 * kh) * hd / p.tp
        w_o = nh * hd * d / p.tp
        ops.append(Op(name="qkv_proj", kind="compute",
                      flops=2 * rows * w_qkv,
                      bytes=w_qkv * wb + rows * d * wb, op_class="gemm"))
        kv_bytes = b * p.context * 2 * (kh / min(p.tp, kh)) * hd * kvb
        core_flops = 2 * b * q * (nh / p.tp) * p.context * hd * 2
        ops.append(Op(name="attn_core", kind="compute", flops=core_flops,
                      bytes=kv_bytes, op_class="attn"))
        ops.append(Op(name="o_proj", kind="compute", flops=2 * rows * w_o,
                      bytes=w_o * wb + rows * d * wb, op_class="gemm"))

    if p.tp > 1:
        # TP all-reduce of the attention output [rows, d]
        ops.append(Op(name="attn_ar", kind="ar",
                      m_bytes=rows * d * wb, group=p.tp))
    return ops


def moe_ops(cfg: ModelConfig, p: ServingPoint, load: float = 1.0,
            extra: int = 0) -> List[Op]:
    """MoE FFN sublayer of ONE layer: router + A2A dispatch + experts + A2A.

    With tp > 1 the experts are TP-sharded inside each expert group: the
    dispatch/gather A2As carry each token's 1/tp feature shard, the expert
    GEMMs run column/row-parallel over d_expert (weights and flops / tp),
    and the sublayer ends with one `moe_ar` all-reduce of the combined
    [rows, d] output over the tp shards (the row-parallel partial sums,
    shared-expert included). At tp=1 every term reduces to the paper's
    fixed mapping exactly.

    `load` is the layer's hot-rank load factor (`core.placement`, >= 1):
    under skewed routing a symmetric A2A/grouped-GEMM finishes when its
    hottest rank does, so the token-proportional terms of `a2a_dispatch`,
    `expert_ffn` and `a2a_gather` scale by `load` instead of the mean.
    `extra` replica expert slots per rank widen the expert weight stream
    (and the HBM shard — see `model_shard_bytes`). The defaults
    (load=1.0, extra=0) are bit-exact no-ops: multiplying by 1.0 and
    adding 0 leave every float unchanged, preserving the uniform path's
    byte-identity.
    """
    assert cfg.moe is not None
    m = cfg.moe
    d = cfg.d_model
    b = p.batch_per_device
    rows = b * p.q_len
    wb = _wb(p)
    ops: List[Op] = []

    # router (tiny; replicated per domain device)
    ops.append(Op(name="router", kind="compute",
                  flops=2 * rows * d * m.num_experts,
                  bytes=d * m.num_experts * wb + rows * d * wb,
                  op_class="other"))

    # dispatch A2A: each token is sent to top-k expert owners.
    # m = per-device payload = rows * topk * d / tp (paper's A2A message
    # convention; the domain's tp devices split the token features); the
    # hottest rank ingests `load` x the mean and the collective waits on it
    a2a_bytes = rows * m.experts_per_token * d * wb / p.tp * load
    if p.ep > 1:
        ops.append(Op(name="a2a_dispatch", kind="a2a", m_bytes=a2a_bytes,
                      group=p.ep))

    # expert FFN: each expert group hosts E/ep experts (+ `extra` replica
    # slots) and its hottest rank receives rows * topk * load tokens; each
    # of the group's tp devices holds a 1/tp shard of the expert weights
    # and activations.
    tokens_in = rows * m.experts_per_token
    experts_local = max(m.num_experts // p.ep, 1)
    w_expert = 3 * d * m.d_expert            # SwiGLU gate/up/down
    ops.append(Op(name="expert_ffn", kind="compute",
                  flops=2 * tokens_in * load * w_expert / p.tp,
                  bytes=((experts_local + extra) * w_expert * wb
                         + 2 * tokens_in * load * d * wb) / p.tp,
                  op_class="gemm"))

    if m.num_shared_experts:
        w_sh = m.num_shared_experts * 3 * d * m.d_shared_expert / p.tp
        ops.append(Op(name="shared_expert", kind="compute",
                      flops=2 * rows * w_sh, bytes=w_sh * wb + rows * d * wb,
                      op_class="gemm"))

    if p.ep > 1:
        ops.append(Op(name="a2a_gather", kind="a2a", m_bytes=a2a_bytes,
                      group=p.ep))

    if p.tp > 1:
        # TP all-reduce of the combined MoE output [rows, d]: the
        # row-parallel down-proj partial sums (routed + shared experts)
        ops.append(Op(name="moe_ar", kind="ar", m_bytes=rows * d * wb,
                      group=p.tp))
    return ops


def dense_ffn_ops(cfg: ModelConfig, p: ServingPoint) -> List[Op]:
    d = cfg.d_model
    rows = p.batch_per_device * p.q_len
    wb = _wb(p)
    w = 3 * d * cfg.d_ff / p.tp
    ops = [Op(name="dense_ffn", kind="compute", flops=2 * rows * w,
              bytes=w * wb + 2 * rows * d * wb, op_class="gemm")]
    if p.tp > 1:
        ops.append(Op(name="ffn_ar", kind="ar", m_bytes=rows * d * wb,
                      group=p.tp))
    return ops


# ---------------------------------------------------------------------------
# pipeline-parallel stage partition
# ---------------------------------------------------------------------------

def stage_layer_counts(n_layers: int, pp: int) -> List[int]:
    """Balanced contiguous stage partition of the layer stack: stage sizes
    differ by at most one layer (the leading n_layers % pp stages take the
    extra). Raises when pp exceeds the layer count — a stage must own at
    least one layer."""
    if pp < 1:
        raise ValueError(f"pp must be >= 1, got {pp}")
    if pp > n_layers:
        raise ValueError(f"pp ({pp}) exceeds the layer count ({n_layers}); "
                         "every stage needs at least one layer")
    base, rem = divmod(n_layers, pp)
    return [base + (1 if s < rem else 0) for s in range(pp)]


def is_per_layer_op(name: str) -> bool:
    """True for ops that live on a pipeline stage's layer block — the
    'L{li}.'-prefixed names `decode_iteration` emits (the only dotted
    ones). The lm head and `pp_hop*` sends ride the round once and are
    NOT per-layer. Single source of truth for the stage-bottleneck
    scaling in `optable._stage_scale` and `optimizer._scaled_timers`."""
    return "." in name


# ops whose token-proportional terms scale with the hot-rank expert load
# factor under skewed routing (see `moe_ops` and `core.placement`); the
# router, shared expert and TP all-reduces see every token regardless of
# which expert it routes to, so they stay at the mean
SKEW_SCALED_OPS = ("a2a_dispatch", "expert_ffn", "a2a_gather")


def moe_layer_ordinals(names) -> List[int]:
    """Per-op MoE-layer ordinal for skew scaling: -1 for ops unaffected by
    expert-load skew, else the op's 0-based index among MoE layers in
    execution order — the same counter `decode_iteration` advances, so
    `ServingPoint.moe_load[ordinal]` is the factor the scalar path applied.
    Single source of truth for `optable.OpTable.moe_layer`."""
    out: List[int] = []
    seen: dict = {}
    for nm in names:
        if "." in nm and nm.rsplit(".", 1)[-1] in SKEW_SCALED_OPS:
            layer = nm.split(".", 1)[0]
            if layer not in seen:
                seen[layer] = len(seen)
            out.append(seen[layer])
        else:
            out.append(-1)
    return out


def stage_imbalance(n_layers: int, pp: int) -> float:
    """Pipeline bottleneck factor of the balanced partition: the steady-
    state round period is pp * t_largest_stage, so per-layer op times
    scale by ceil(L/pp) * pp / L (exactly 1.0 when pp divides the layer
    count — there the latency-sum op list is the exact pipeline model)."""
    if pp <= 1:
        return 1.0
    return math.ceil(n_layers / pp) * pp / n_layers


# ---------------------------------------------------------------------------
# whole-iteration builders
# ---------------------------------------------------------------------------

def decode_iteration(cfg: ModelConfig, p: ServingPoint) -> List[Op]:
    """Op list for ONE decode iteration (all layers + lm head).

    Layers are emitted in execution order so the DBO scheduler can respect
    dependencies; `Op.name` carries a layer index prefix.

    With pp > 1 the stack splits into `p.pp` contiguous stages
    (`stage_layer_counts`); a `pp_sendrecv` hop op is emitted at each of
    the pp-1 stage boundaries, carrying the microbatch's hidden state
    [rows, d] split over the tp shards (each device forwards its 1/tp
    feature slice to its counterpart on the next stage). Per-layer shapes
    are pp-invariant — a stage device executes the same per-layer shard a
    pp=1 device would — so the summed op list is the token's pipeline
    latency; the bottleneck factor of an uneven partition is applied by
    the timers via `stage_imbalance`, not baked into the shapes.
    """
    boundaries = set()
    if p.pp > 1:
        acc = 0
        for c in stage_layer_counts(cfg.num_layers, p.pp)[:-1]:
            acc += c
            boundaries.add(acc)
    hop_bytes = p.batch_per_device * p.q_len * cfg.d_model * _wb(p) / p.tp
    stage = 0
    moe_i = 0
    ops: List[Op] = []
    for li, spec in enumerate(cfg.layer_specs):
        if li in boundaries:
            ops.append(Op(name=f"pp_hop{stage}", kind="pp_sendrecv",
                          m_bytes=hop_bytes, group=p.pp))
            stage += 1
        prefix = f"L{li}."
        layer_ops: List[Op] = []
        if spec.mixer in ("attn", "attn_local"):
            layer_ops += attention_ops(cfg, p)
        elif spec.mixer in ("mamba", "rwkv"):
            # linear-time mixer: projections dominate; model as one gemm
            d = cfg.d_model
            rows = p.batch_per_device * p.q_len
            wb = _wb(p)
            w = 6 * d * d / p.tp
            layer_ops.append(Op(name="ssm_mixer", kind="compute",
                               flops=2 * rows * w,
                               bytes=w * wb + rows * d * wb, op_class="gemm"))
            if p.tp > 1:
                layer_ops.append(Op(name="mixer_ar", kind="ar",
                                   m_bytes=rows * d * wb, group=p.tp))
        if spec.ffn == "moe":
            lf = p.moe_load[moe_i] if p.moe_load else 1.0
            layer_ops += moe_ops(cfg, p, load=lf, extra=p.moe_extra)
            moe_i += 1
        elif spec.ffn == "dense":
            layer_ops += dense_ffn_ops(cfg, p)
        ops += [Op(name=prefix + o.name, kind=o.kind, flops=o.flops,
                   bytes=o.bytes, op_class=o.op_class, m_bytes=o.m_bytes,
                   group=o.group) for o in layer_ops]
    if p.moe_load and len(p.moe_load) != moe_i:
        raise ValueError(f"moe_load has {len(p.moe_load)} factors but the "
                         f"model has {moe_i} MoE layers")

    # LM head (vocab projection, TP-sharded)
    d, v = cfg.d_model, cfg.vocab_size
    rows = p.batch_per_device * p.q_len
    wb = _wb(p)
    w = d * v / p.tp
    ops.append(Op(name="lm_head", kind="compute", flops=2 * rows * w,
                  bytes=w * wb + rows * d * wb, op_class="gemm"))
    return ops


def prefill_iteration(cfg: ModelConfig, p: ServingPoint,
                      chunk: int) -> List[Op]:
    """Op list for ONE prefill iteration: `chunk` new prompt tokens per
    request, appended after `p.context` tokens already in the KV cache
    (the chunk's offset into the prompt; 0 for the first chunk).

    Derived from `decode_iteration` at q_len=chunk — GEMM, router, expert
    and communication shapes are IDENTICAL (rows = batch_per_device * chunk
    tokens flow through every projection and A2A) — with two
    prefill-specific corrections:

      * the attention core gains the causal intra-chunk term: query i of
        the chunk attends to `context + i + 1` keys, so on top of the
        decode core's `chunk * context` (query, key) pairs it scores
        chunk*(chunk+1)/2 in-chunk pairs (quadratic in `chunk`), and
        streams the chunk's own KV once more (`chunk` extra key positions);
      * the LM head is dropped: logits are only needed once per request
        when its last chunk completes, and that single-row projection is
        charged to the request's first decode iteration.

    The corrections are derived by differencing `decode_iteration` at
    context and context+1 (its per-context-token slopes), not by
    duplicating the attention formulas — the same no-silent-divergence
    policy `optable.build_op_table` uses.
    """
    if chunk < 1:
        raise ValueError(f"prefill chunk must be >= 1 token, got {chunk}")
    pq = replace(p, q_len=chunk)
    ops0 = decode_iteration(cfg, pq)
    ops1 = decode_iteration(cfg, replace(pq, context=p.context + 1))
    out: List[Op] = []
    for o, o1 in zip(ops0, ops1):
        if o.name.rsplit(".", 1)[-1] == "lm_head":
            continue
        d_flops = o1.flops - o.flops       # per extra context token
        d_bytes = o1.bytes - o.bytes
        if d_flops or d_bytes:
            o = replace(o,
                        flops=o.flops + d_flops * (chunk + 1) / 2.0,
                        bytes=o.bytes + d_bytes * chunk)
        out.append(o)
    return out


def chunk_schedule(prompt_len: int, chunk: int) -> Tuple[List[int], List[int]]:
    """(sizes, offsets) of the chunked-prefill schedule covering a prompt:
    full `chunk`-token chunks plus a final partial one; `offsets[j]` is the
    KV length already cached when chunk j starts."""
    if prompt_len < 1:
        raise ValueError(f"prompt_len must be >= 1, got {prompt_len}")
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    sizes, offsets = [], []
    off = 0
    while off < prompt_len:
        s = min(chunk, prompt_len - off)
        sizes.append(s)
        offsets.append(off)
        off += s
    return sizes, offsets


def kv_cache_bytes_per_request(cfg: ModelConfig, context: int,
                               kv_dtype: str = "bf16", tp: int = 1) -> float:
    """KV-cache footprint of one request at `context` tokens (all layers),
    PER DEVICE of a tp-way TP domain: GQA KV shards over the kv heads
    (mirroring the `attention_ops` streaming model), MLA's compressed
    latent is replicated across the domain. tp=1 (the default) is the
    whole-request footprint — what the disagg KV handoff moves."""
    kvb = BYTES[kv_dtype]
    total = 0.0
    for spec in cfg.layer_specs:
        if spec.mixer in ("attn", "attn_local"):
            if cfg.attn_kind == "mla":
                total += context * (cfg.mla_kv_lora_rank
                                    + cfg.mla_rope_head_dim) * kvb
            else:
                w = cfg.sliding_window if (spec.mixer == "attn_local"
                                           and cfg.sliding_window) else context
                kh = cfg.num_kv_heads / min(tp, cfg.num_kv_heads)
                total += min(w, context) * 2 * kh * cfg.head_dim * kvb
        elif spec.mixer == "mamba":
            mc = cfg.mamba
            di = mc.expand * cfg.d_model
            total += di * (mc.d_state * 4 + mc.d_conv * kvb)
        elif spec.mixer == "rwkv":
            hd = cfg.rwkv.head_dim
            total += (cfg.d_model // hd) * hd * hd * 4
    return total


def model_shard_bytes(cfg: ModelConfig, tp: int, ep: int,
                      dtype: str = "fp8", pp: int = 1,
                      extra_experts: int = 0) -> float:
    """Per-device weight bytes: per-layer dense params / (tp*pp), expert
    params / (ep*tp*pp) (experts are TP-sharded inside each expert group,
    see `moe_ops` — at the paper mapping (tp=1, pp=1, ep=n) this is expert
    params / n exactly, and with ep = n/(tp*pp) it STAYS expert params / n
    at every pp: pipeline stages shrink only the dense shard).

    The pp split is checked against the WORST stage of the balanced
    partition: per-layer params carry the ceil(L/pp)*pp/L bottleneck
    factor (`stage_imbalance`), and the embedding / LM-head matrices —
    which pipeline stages do NOT split — are charged in full (one
    vocab x d matrix, TP-sharded) to the boundary stage, so an uneven
    split or a fat vocabulary cannot sneak a stage past the HBM capacity
    the uniform average would claim. pp=1 is the seed formula exactly.

    `extra_experts` replica slots per rank (the placement search,
    `core.placement`) each host one full TP-sharded expert on EVERY rank
    — they do not divide by ep — and under pp they belong to the stage's
    own MoE layers, so they carry the same imb/pp bottleneck factor as
    the base expert shard. extra_experts=0 adds nothing (bit-exact)."""
    wb = BYTES[dtype]
    total_params = cfg.param_count()
    imb = stage_imbalance(cfg.num_layers, pp)
    io_params = cfg.vocab_size * cfg.d_model  # per boundary stage (pp > 1)
    if cfg.moe is None:
        if pp == 1:
            return total_params * wb / tp
        layer_params = total_params - io_params * (1 if cfg.tie_embeddings
                                                  else 2)
        return (io_params + layer_params * imb / pp) * wb / tp
    m = cfg.moe
    n_moe = sum(1 for s in cfg.layer_specs if s.ffn == "moe")
    expert_params = n_moe * m.num_experts * 3 * cfg.d_model * m.d_expert
    dense_params = total_params - expert_params
    if pp == 1:
        total = (dense_params / tp + expert_params / (ep * tp)) * wb
    else:
        layer_dense = dense_params - io_params * (1 if cfg.tie_embeddings
                                                  else 2)
        total = ((io_params + layer_dense * imb / pp) / tp
                 + expert_params * imb / (ep * tp * pp)) * wb
    if extra_experts:
        w_expert = 3 * cfg.d_model * m.d_expert
        scale = imb / pp if pp > 1 else 1.0
        total += n_moe * extra_experts * w_expert * scale * wb / tp
    return total


# HBM fraction reserved for activations/fragmentation — the single memory
# headroom constant shared by the batch sizer and the (tp, ep) candidate
# enumerator (sweep.parallelism_candidates)
KV_RESERVE_FRAC = 0.10


def single_request_fits(cfg: ModelConfig, p: ServingPoint, hbm_cap: float,
                        reserve_frac: float = KV_RESERVE_FRAC) -> bool:
    """True iff ONE request's KV cache at `p.context` fits beside the model
    shard — exactly `max_batch_by_memory(...) >= 1`, named so the
    operating-point searches can REJECT scenarios whose per-request KV
    cannot be held at all instead of quietly sweeping an empty grid."""
    return max_batch_by_memory(cfg, p, hbm_cap, reserve_frac) >= 1


def max_batch_by_memory(cfg: ModelConfig, p: ServingPoint, hbm_cap: float,
                        reserve_frac: float = KV_RESERVE_FRAC) -> int:
    """Largest global batch whose KV cache fits beside the model shard
    (paper Table 4 last row). Batch is spread over the n/(tp*pp)
    DP-attention domains per stage; the per-device KV footprint follows
    the TP sharding of `kv_cache_bytes_per_request` (GQA shards over kv
    heads, MLA latent is replicated) and, under pp, each stage stores
    only its own layers' KV (1/pp of a request) for the pp microbatches
    it serves — per-device KV totals B*tp/n * kv_request either way, but
    the request count each device can admit divides by tp*pp."""
    shard = model_shard_bytes(cfg, p.tp, p.ep, p.dtype, p.pp, p.moe_extra)
    free = hbm_cap * (1 - reserve_frac) - shard
    if free <= 0:
        return 0
    per_req = kv_cache_bytes_per_request(cfg, p.context, p.kv_dtype, p.tp)
    if p.pp > 1:
        # largest stage holds ceil(L/pp)/L of a request's KV — the same
        # bottleneck factor the shard check applies, so uneven splits
        # cannot overcommit the fat stage's KV either
        per_req *= stage_imbalance(cfg.num_layers, p.pp) / p.pp
    per_dev = max(int(free / max(per_req, 1.0)), 0)
    return per_dev * p.n // (p.tp * p.pp)
