"""Max-throughput-under-SLO sweep (paper sections 3.2.1, 4.1).

For a (cluster, model, scenario) triple, sweep batch size (and the software
optimizations DBO / SD) under the memory-capacity constraint, model TPOT as
compute + communication (with DBO's two-lane overlap when enabled), and
return the configuration with the highest throughput whose TPOT meets the
SLO. "Cluster builders provision for peak load": max capacity per cost is
the paper's cost-effectiveness metric.

The supported search entry point is `repro.core.api.solve` (the batched
engine lives in `repro.core.sweep`: the whole batch grid evaluates as
array programs, the argmax winner re-derived through the scalar path
below). This module keeps:

  max_throughput / best_of_opts / max_throughput_prefill   DEPRECATED
      shims onto `api.solve` (they emit `ReproDeprecationWarning`).
  max_throughput_scalar / best_of_opts_scalar   the seed one-point-at-a-time
      reference, kept as ground truth for tests and boundary fallbacks.
  degrade_policy   the remap-vs-degrade decision `api.solve` routes to
      when a `FaultSet` is on the spec.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import List, Optional, Sequence, Union

from repro.configs.base import ModelConfig
from repro.core import compute_model as cm
from repro.core import overlap, workload
from repro.core.compute_model import Op
from repro.core.specdec import SpecDecConfig
from repro.core.topology import Cluster
from repro.core.workload import ServingPoint


@dataclass(frozen=True)
class Scenario:
    """TPOT SLO x average context length (paper section 3.1), optionally
    extended with a prefill spec: `prompt_len` (tokens to prefill per
    request) and `ttft_ms` (time-to-first-token SLO; 0 = unconstrained).
    `prompt_len == 0` keeps the seed's decode-only semantics.

    The routing axis models expert-load skew: `routing="zipf"` with
    `zipf_s > 0` draws a per-MoE-layer Zipf(s) expert-popularity vector
    from `routing_seed` (`core.placement`), and the cost model charges the
    MAX per-rank expert load instead of the mean. The default
    (`routing="uniform"`, which `zipf_s=0` also reduces to) is
    byte-identical to the pre-skew stack — `name` and every sweep result
    are unchanged."""
    tpot_ms: float
    context: int
    prompt_len: int = 0
    ttft_ms: float = 0.0
    routing: str = "uniform"
    zipf_s: float = 0.0
    routing_seed: int = 0

    def __post_init__(self):
        if self.routing not in ("uniform", "zipf"):
            raise ValueError(f"unknown routing {self.routing!r}; "
                             "expected 'uniform' or 'zipf'")
        if self.zipf_s < 0:
            raise ValueError(f"zipf_s must be >= 0, got {self.zipf_s}")

    @property
    def is_skewed(self) -> bool:
        """True when the scenario departs from uniform expert load —
        s = 0 is the uniform distribution, so it keeps the fast path."""
        return self.routing == "zipf" and self.zipf_s > 0

    @property
    def name(self) -> str:
        base = f"tpot{int(self.tpot_ms)}ms_ctx{self.context}"
        if self.prompt_len:
            base += f"_p{self.prompt_len}_ttft{int(self.ttft_ms)}ms"
        if self.is_skewed:
            base += f"_zipf{self.zipf_s:g}"
            if self.routing_seed:
                base += f"_seed{self.routing_seed}"
        return base

    @property
    def gen_len(self) -> int:
        """Decode tokens per request implied by `context` being the AVERAGE
        KV length during decode: context = prompt_len + gen_len / 2."""
        return max(2 * (self.context - self.prompt_len), 1)

    @property
    def mem_context(self) -> int:
        """Context of the single-request KV REJECTION guard: a scenario is
        serveable only if one request's prompt plus its decode context can
        be held at all. Batch sizing itself stays at the seed convention
        (KV at the AVERAGE `context`); the in-flight prompt KV of chunked
        prefill (at most one request per DP domain) is second-order
        against the hundreds of decode slots per device and is not
        reserved per slot."""
        return self.context + self.prompt_len


# the paper's evaluation grid
SCENARIOS = [Scenario(t, c) for c in (512, 4096) for t in (15.0, 40.0, 100.0)]


@dataclass(frozen=True)
class OperatingPoint:
    batch: int
    tpot: float                    # seconds
    throughput: float              # tokens / s (cluster-wide)
    used_dbo: bool
    used_sd: bool
    exposed_comm: float            # seconds (under the schedule actually used)
    t_compute: float
    t_comm: float
    tp: int = 1                    # the (tp, pp, ep) mapping of the point
    ep: int = 0                    # resolved EP degree (1 for dense models)
    pp: int = 1                    # pipeline-parallel degree (layer stages)
    extra_experts: int = 0         # replica expert slots per rank (placement)

    @property
    def throughput_per_xpu(self):  # filled by caller via cluster.n_xpus
        raise AttributeError("use result.throughput / cluster.n_xpus")


@dataclass(frozen=True)
class PrefillOperatingPoint:
    """Operating point of a prefill-aware serving mode.

    mode 'decode' is the seed's prefill-free search (ttft = 0.0 means "not
    modeled"); 'chunked' interleaves prefill chunks into decode iterations;
    'disagg' splits the cluster into prefill/decode pools. `throughput` is
    decode tokens/s cluster-wide, capped by the prefill/decode pipeline
    balance, so modes are directly comparable."""
    mode: str                  # "decode" | "chunked" | "disagg"
    batch: int                 # decode requests in flight
    tpot: float                # seconds (chunked: mixed-iteration average)
    ttft: float                # seconds (0.0 in decode mode)
    throughput: float          # decode tokens/s, cluster-wide
    chunk: int = 0             # chunked: chunk size; disagg: prompt tokens/pass
    n_prefill_xpus: int = 0    # disagg: prefill-pool device count
    n_decode_xpus: int = 0     # disagg: decode-pool device count
    tp: int = 1                # the (tp, pp, ep) mapping (disagg: the
    ep: int = 0                # DECODE pool's; each pool resolves its own)
    pp: int = 1
    tp_prefill: int = 0        # disagg: the prefill pool's own mapping
    pp_prefill: int = 0        # (0 outside disagg mode)
    ep_prefill: int = 0
    used_dbo: bool = False     # searched with the (max,+) DBO schedule
    exposed_comm: float = 0.0  # TPOT-side comm not hidden under compute (s)
    t_compute: float = 0.0     # TPOT-side busy times under the schedule
    t_comm: float = 0.0        # actually used (chunked: load-weighted)


# ---------------------------------------------------------------------------
# single-iteration time
# ---------------------------------------------------------------------------

def _timers(cluster: Cluster, p: ServingPoint):
    fp8 = p.dtype == "fp8"
    rows = p.batch_per_device * p.q_len

    def t_comp(op: Op) -> float:
        return cm.compute_time(op, cluster.xpu, rows=rows, fp8=fp8)

    def t_comm(op: Op) -> float:
        if op.kind == "a2a":
            return cluster.a2a_time(op.m_bytes, group=op.group or None,
                                    tp=p.tp, pp=p.pp)
        if op.kind == "pp_sendrecv":
            return cluster.pp_hop_time(op.m_bytes, pp=p.pp, tp=p.tp)
        return cluster.ar_time(op.m_bytes, group=op.group or None, tp=p.tp,
                               pp=p.pp)

    return t_comp, t_comm


def _scaled_timers(cfg: ModelConfig, cluster: Cluster, p: ServingPoint):
    """`_timers` with the pipeline bottleneck factor applied: per-layer
    ops (`workload.is_per_layer_op`) repeat `workload.stage_imbalance`
    times per steady-state round on the largest stage; the lm head and pp
    hops ride the round once. Identity at pp=1 — the timers are returned
    unwrapped, keeping the seed path byte-identical."""
    t_comp, t_comm = _timers(cluster, p)
    if p.pp <= 1:
        return t_comp, t_comm
    imb = workload.stage_imbalance(cfg.num_layers, p.pp)

    def scaled(f):
        def g(op: Op) -> float:
            return f(op) * (imb if workload.is_per_layer_op(op.name)
                            else 1.0)
        return g

    return scaled(t_comp), scaled(t_comm)


def iteration_time(cfg: ModelConfig, p: ServingPoint, cluster: Cluster,
                   *, dbo: bool) -> tuple[float, float, float, float]:
    """One decode iteration -> (t_iter, exposed_comm, t_compute, t_comm).

    dbo=True: the batch splits into two microbatches of B/2; TPOT is the
    three-lane fixed-order (max,+) schedule's makespan (paper section 3.3;
    pp hops ride the dedicated send/recv lane — see `repro.core.overlap`).
    """
    if not dbo:
        ops = workload.decode_iteration(cfg, p)
        t_comp, t_comm = _scaled_timers(cfg, cluster, p)
        tc = sum(t_comp(o) for o in ops if o.kind == "compute")
        tm = sum(t_comm(o) for o in ops if o.kind != "compute")
        return tc + tm, tm, tc, tm

    half = replace(p, batch_global=max(p.batch_global // 2, 1))
    ops_half = workload.decode_iteration(cfg, half)
    t_comp, t_comm = _scaled_timers(cfg, cluster, half)
    makespan, exposed = overlap.dbo_tpot(ops_half, t_comp, t_comm)
    tc = 2 * sum(t_comp(o) for o in ops_half if o.kind == "compute")
    tm = 2 * sum(t_comm(o) for o in ops_half if o.kind != "compute")
    return makespan, exposed, tc, tm


def _best_decode_iter(cfg: ModelConfig, p: ServingPoint, cluster: Cluster,
                      dbo: bool) -> tuple[float, float, float, float]:
    """best-of(no-overlap, DBO) decode iteration — "DBO on" means the
    schedule is USED only where it helps (paper Fig. 11a); DBO needs two
    microbatches, so batch 1 stays no-overlap."""
    res = iteration_time(cfg, p, cluster, dbo=False)
    if dbo and p.batch_global >= 2:
        res_dbo = iteration_time(cfg, p, cluster, dbo=True)
        if res_dbo[0] < res[0]:
            return res_dbo
    return res


def prefill_iteration_time(cfg: ModelConfig, p: ServingPoint,
                           cluster: Cluster,
                           chunk: int) -> tuple[float, float, float]:
    """One prefill iteration (`chunk` tokens after `p.context` cached) ->
    (t_iter, t_compute, t_comm), no-overlap. The thin-GEMM efficiency
    cutoff sees rows = batch_per_device * chunk, mirroring the decode
    timers at q_len = chunk."""
    ops = workload.prefill_iteration(cfg, p, chunk)
    t_comp, t_comm = _scaled_timers(cfg, cluster, replace(p, q_len=chunk))
    tc = sum(t_comp(o) for o in ops if o.kind == "compute")
    tm = sum(t_comm(o) for o in ops if o.kind != "compute")
    return tc + tm, tc, tm


def prefill_iteration_dbo(cfg: ModelConfig, p: ServingPoint,
                          cluster: Cluster,
                          chunk: int) -> overlap.ScheduleResult:
    """DBO'd prefill chunk: the chunk splits CAUSALLY into a leading
    ceil(chunk/2)-token and a trailing floor(chunk/2)-token microbatch
    (the trailing one starts `h1` tokens deeper into the KV cache), and
    the two run the three-lane (max,+) schedule — the leading half's
    A2A/AR hides under the trailing half's big GEMMs, pp hops under both.

    The causal split is EXACT: the two halves' attention-core flops sum to
    the full chunk's (h1*(h1+1)/2 + h2*(h2+1)/2 + h1*h2 = s*(s+1)/2), so
    DBO re-schedules the same work rather than dropping any.
    """
    if chunk < 2:
        raise ValueError(f"DBO needs two microbatches; chunk={chunk} < 2")
    h2 = chunk // 2
    h1 = chunk - h2
    ops_a = workload.prefill_iteration(cfg, p, h1)
    ops_b = workload.prefill_iteration(
        cfg, replace(p, context=p.context + h1), h2)
    ca, ma = _scaled_timers(cfg, cluster, replace(p, q_len=h1))
    cb, mb = _scaled_timers(cfg, cluster, replace(p, q_len=h2))
    return overlap.dbo_best(overlap.to_timed(ops_a, ca, ma, 0),
                            overlap.to_timed(ops_b, cb, mb, 1))


def prefill_chunk_components(cfg: ModelConfig, p: ServingPoint,
                             cluster: Cluster, chunk: int, *,
                             dbo: bool = False
                             ) -> tuple[float, float, float, float]:
    """(t_iter, exposed_comm, t_compute, t_comm) of one prefill chunk under
    the schedule actually used: best-of(no-overlap, three-lane DBO) when
    `dbo`, mirroring `_best_decode_iter`. Single-token chunks cannot split
    into two microbatches and stay no-overlap."""
    t, tc, tm = prefill_iteration_time(cfg, p, cluster, chunk)
    if dbo and chunk >= 2:
        res = prefill_iteration_dbo(cfg, p, cluster, chunk)
        if res.makespan < t:
            return (res.makespan, res.exposed_comm, res.compute_busy,
                    res.comm_busy + res.sendrecv_busy)
    return t, tm, tc, tm


def chunked_prefill_components(cfg: ModelConfig, p: ServingPoint,
                               cluster: Cluster, scenario: Scenario,
                               chunk: int, *, dbo: bool = False
                               ) -> tuple[float, float, float, float, float]:
    """(TPOT, TTFT, exposed_comm, t_compute, t_comm) of the chunked-prefill
    model at decode batch B = `p.batch_global` (Sarathi-style: chunks
    piggyback on decode iterations, one chunk per DP-attention domain per
    carrying iteration).

    Each decode slot turns over every `gen_len` iterations and its
    replacement prompt needs `n_chunks` chunk-iterations on one of the
    `domains` DP lanes, so the fraction of iterations that carry a chunk is

        phi = B_eff * n_chunks / (gen_len * domains)        (phi <= 1;
        B_eff = min(B, domains * gen_len / n_chunks) is the
        pipeline-balanced decode batch — beyond it prefill cannot refill
        the batch and slots idle)

    TPOT is the load-weighted average iteration, t_dec + phi * mean_j
    t_chunk_j; TTFT is the sum over the prompt's chunk schedule of the
    iterations it rides, sum_j (t_dec + t_chunk_j) — those iterations DO
    carry its chunks back to back. `dbo` times BOTH parts with the
    three-lane (max,+) schedule where it helps: the decode iteration
    splits into two B/2 microbatches, each chunk into two causal
    half-chunks (`prefill_iteration_dbo` — the chunk's A2A/AR hides under
    the other half's big GEMMs). exposed/compute/comm components carry the
    same load weighting as TPOT.
    """
    t_dec, e_dec, tc_dec, tm_dec = _best_decode_iter(cfg, p, cluster, dbo)
    sizes, offsets = workload.chunk_schedule(scenario.prompt_len, chunk)
    p_ch = replace(p, batch_global=max(p.n // p.tp, 1))  # one chunk / domain
    parts = [prefill_chunk_components(cfg, replace(p_ch, context=off),
                                      cluster, s, dbo=dbo)
             for s, off in zip(sizes, offsets)]
    m = len(parts)
    domains = max(p.n // p.tp, 1)
    g = scenario.gen_len
    b_eff = min(float(p.batch_global), domains * g / m)
    phi = b_eff * m / (g * domains)
    s_pre = sum(t for t, _, _, _ in parts)
    tpot = t_dec + phi * (s_pre / m)
    ttft = m * t_dec + s_pre
    exposed = e_dec + phi * (sum(e for _, e, _, _ in parts) / m)
    tc = tc_dec + phi * (sum(c for _, _, c, _ in parts) / m)
    tm = tm_dec + phi * (sum(t for _, _, _, t in parts) / m)
    return tpot, ttft, exposed, tc, tm


def chunked_prefill_tpot(cfg: ModelConfig, p: ServingPoint, cluster: Cluster,
                         scenario: Scenario, chunk: int, *,
                         dbo: bool = False) -> tuple[float, float]:
    """(TPOT, TTFT) of the chunked-prefill model — see
    `chunked_prefill_components` for the derivation; this is the scalar
    reference the batched `sweep.batched_chunked_tpot_ttft` is locked
    against at 1e-9 relative (with and without DBO)."""
    return chunked_prefill_components(cfg, p, cluster, scenario, chunk,
                                      dbo=dbo)[:2]


def tpot_at(cfg: ModelConfig, p: ServingPoint, cluster: Cluster, *,
            dbo: bool, sd: Optional[SpecDecConfig]) -> tuple[float, float, float, float]:
    """(TPOT, exposed_comm, t_compute, t_comm) for one operating point.

    DBO on means "best of DBO and no-overlap" (paper Fig. 11a). SD wraps
    draft + verify iterations.
    """
    def best_iter(q_len: int):
        return _best_decode_iter(cfg, replace(p, q_len=q_len), cluster, dbo)

    if sd is None:
        return best_iter(1)

    t_draft, e1, c1, m1 = best_iter(1)
    t_verify, e2, c2, m2 = best_iter(sd.spec_m)
    denom = sd.tokens_per_iteration
    return ((t_draft + t_verify) / denom, (e1 + e2) / denom,
            (c1 + c2) / denom, (m1 + m2) / denom)


# ---------------------------------------------------------------------------
# the sweep
# ---------------------------------------------------------------------------

def _batch_grid(b_max: int, ep: int) -> List[int]:
    """Geometric grid from ep to b_max (finer near the top end)."""
    if b_max < 1:
        return []
    grid = set()
    b = max(ep, 1)
    while b <= b_max:
        grid.add(b)
        b *= 2
    # refine: 3/4 points between octaves near the top two octaves
    for base in sorted(grid)[-3:]:
        for frac in (1.25, 1.5, 1.75):
            v = int(base * frac)
            if v <= b_max:
                grid.add(v)
    grid.add(b_max)
    return sorted(grid)


def max_throughput(cluster: Cluster, cfg: ModelConfig, scenario: Scenario,
                   *, dbo: bool = False, sd: Optional[SpecDecConfig] = None,
                   tp: Union[int, str] = 1, pp: Union[int, str] = 1,
                   ep: Optional[int] = None,
                   dtype: str = "fp8",
                   backend: Optional[str] = None,
                   placement: Optional[str] = None
                   ) -> Optional[OperatingPoint]:
    """DEPRECATED shim for `repro.core.api.solve` (emits
    `ReproDeprecationWarning`; byte-identical result).

    Best operating point under the TPOT SLO, or None if the SLO is
    unreachable at every feasible batch size. tp="auto" / pp="auto" search
    the joint (tp, pp, ep = n/(tp*pp)) mapping axes; placement="auto"
    additionally searches expert replication for skewed scenarios. See
    `api.SearchSpec` for the field semantics and `api.solve_grid` for the
    amortized clusters x scenarios form.
    """
    from repro.core import api
    api.warn_deprecated("optimizer.max_throughput", "repro.core.api.solve")
    return api.solve(cfg, cluster, scenario,
                     api.SearchSpec(tp=tp, pp=pp, ep=ep, dbo=dbo, sd=sd,
                                    dtype=dtype, backend=backend,
                                    placement=placement)).point


def max_throughput_scalar(cluster: Cluster, cfg: ModelConfig,
                          scenario: Scenario, *, dbo: bool = False,
                          sd: Optional[SpecDecConfig] = None, tp: int = 1,
                          pp: int = 1, ep: Optional[int] = None,
                          dtype: str = "fp8",
                          extra_slots: int = 0) -> Optional[OperatingPoint]:
    """Reference scalar sweep (the seed implementation, one `tpot_at` call
    per grid point). Kept as the ground truth the batched engine is tested
    against, and as the fallback when a batched TPOT lands exactly on the
    SLO boundary.

    Skewed scenarios thread their per-layer hot-rank load factors
    (`placement.point_factors`) into every ServingPoint; `extra_slots`
    fixes the replica count of one placement-search arm (the batched
    search's knife-edge fallback passes the arm it is finalizing)."""
    from repro.core import placement
    n = cluster.n_xpus
    if cfg.moe is not None:
        ep = ep or max(n // (tp * pp), 1)
    else:
        ep = 1
        extra_slots = 0
    tpot_budget = scenario.tpot_ms * 1e-3

    p0 = ServingPoint(batch_global=1, context=scenario.context, tp=tp, ep=ep,
                      n_devices=n, dtype=dtype, pp=pp,
                      moe_load=placement.point_factors(cfg, scenario, ep,
                                                       extra_slots),
                      moe_extra=extra_slots)
    # reject scenarios where ONE request's prompt + decode context cannot
    # be held at all (degenerate empty grids otherwise); batch sizing
    # keeps the seed convention of KV at the average context
    p_mem = replace(p0, context=getattr(scenario, "mem_context",
                                        scenario.context))
    if not workload.single_request_fits(cfg, p_mem, cluster.xpu.hbm_cap):
        return None
    b_max = workload.max_batch_by_memory(cfg, p0, cluster.xpu.hbm_cap)
    best: Optional[OperatingPoint] = None
    for b in _batch_grid(b_max, max(n // tp, 1)):
        p = replace(p0, batch_global=b)
        tpot, ect, tc, tm = tpot_at(cfg, p, cluster, dbo=dbo, sd=sd)
        if tpot > tpot_budget:
            continue
        thr = b / tpot
        if best is None or thr > best.throughput:
            best = OperatingPoint(batch=b, tpot=tpot, throughput=thr,
                                  used_dbo=dbo, used_sd=sd is not None,
                                  exposed_comm=ect, t_compute=tc, t_comm=tm,
                                  tp=tp, ep=ep, pp=pp,
                                  extra_experts=extra_slots)
    return best


def best_of_opts(cluster: Cluster, cfg: ModelConfig, scenario: Scenario,
                 opts: str = "dbo+sd", **kw) -> Optional[OperatingPoint]:
    """DEPRECATED shim for `repro.core.api.solve` with `opts` set (emits
    `ReproDeprecationWarning`; byte-identical result).

    opts: 'noopt' | 'dbo' | 'dbo+sd'. DBO/SD results fall back to the
    unoptimized point when that is faster (paper's 'best of' curves)."""
    from repro.core import api
    api.warn_deprecated("optimizer.best_of_opts", "repro.core.api.solve")
    return api.solve(cfg, cluster, scenario,
                     api.SearchSpec(opts=opts, **kw)).point


def max_throughput_prefill(cluster: Cluster, cfg: ModelConfig,
                           scenario: Scenario, mode: str = "chunked",
                           **kw) -> Optional[PrefillOperatingPoint]:
    """DEPRECATED shim for `repro.core.api.solve` with `mode` set (emits
    `ReproDeprecationWarning`; byte-identical result).

    Prefill-aware best operating point under BOTH the TPOT and TTFT SLOs.
    mode: 'decode' (seed behavior, prefill unmodeled) | 'chunked' (prefill
    chunks interleaved into decode iterations) | 'disagg' (cluster split
    into prefill/decode pools, split ratio swept)."""
    from repro.core import api
    api.warn_deprecated("optimizer.max_throughput_prefill",
                        "repro.core.api.solve")
    for seq in ("chunk_grid", "split_fracs"):
        if seq in kw:
            kw[seq] = tuple(kw[seq])
    return api.solve(cfg, cluster, scenario,
                     api.SearchSpec(mode=mode, **kw)).prefill_point


# ---------------------------------------------------------------------------
# degraded-fabric serving policy (remap vs. degrade)
# ---------------------------------------------------------------------------

# Default re-shard downtime: re-sharding to a new (tp, pp, ep) mapping
# reloads every device's weight shard and drains in-flight requests.
# Pulling ~10-20 GB/device over a shared frontend at tens of GB/s plus
# drain/warmup lands in the tens-of-seconds-to-minutes band reported for
# production reconfigurations; 120 s is the conservative default, and the
# policy exposes it as a knob (docs/failure_model.md).
REMAP_DOWNTIME_S = 120.0
# Horizon the remap downtime amortizes over: the expected time the cluster
# serves in the new degraded state before the failed component repairs
# (~ MTTR of the cheap components; availability.py carries per-class MTTRs).
DEGRADED_HORIZON_S = 4 * 3600.0


@dataclass(frozen=True)
class DegradedPlan:
    """Outcome of the remap-vs-degrade decision for one fault state.

    action 'keep'  — serve the pre-fault (tp, pp, ep) mapping on the
                     survivor cluster at a smaller batch (no downtime);
           'remap' — pay `remap_downtime_s` of zero service to re-shard
                     into the best degraded mapping;
           'down'  — no feasible operating point survives the faults.
    `effective_throughput` is tokens/s averaged over `horizon_s`
    (downtime amortized in), the quantity the policy maximizes."""
    action: str
    point: Optional[OperatingPoint]
    keep_point: Optional[OperatingPoint]
    remap_point: Optional[OperatingPoint]
    remap_downtime_s: float
    horizon_s: float
    effective_throughput: float


def degrade_policy(cluster: Cluster, cfg: ModelConfig, scenario: Scenario,
                   faults, *, baseline: Optional[OperatingPoint] = None,
                   remap_downtime_s: float = REMAP_DOWNTIME_S,
                   horizon_s: float = DEGRADED_HORIZON_S,
                   tp: Union[int, str] = "auto", pp: Union[int, str] = 1,
                   dtype: str = "fp8", dbo: bool = False,
                   sd: Optional[SpecDecConfig] = None) -> DegradedPlan:
    """Graceful-degradation decision on a fault: keep the current mapping
    and serve a smaller batch under the same SLO, or pay a re-shard
    downtime for the better degraded operating point.

    `baseline` is the pre-fault operating point whose mapping the 'keep'
    arm preserves (computed fresh via the healthy search when omitted).
    The 'remap' arm re-runs the full (tp, pp, ep) search on the survivor
    cluster (`sweep.degraded_max_throughput`) and is charged
    `remap_downtime_s` of lost service amortized over `horizon_s` —
    the repair-time-scale the degraded state persists for."""
    from repro.core import sweep

    if baseline is None:
        baseline = sweep.sweep_max_throughput([cluster], cfg, [scenario],
                                              dbo=dbo, sd=sd, tp=tp, pp=pp,
                                              dtype=dtype)[0][0]
    keep_pt = None
    if baseline is not None:
        keep_pt = sweep.degraded_max_throughput(
            cluster, cfg, scenario, faults=faults, dtype=dtype, dbo=dbo,
            sd=sd, mapping=(baseline.tp, baseline.pp, baseline.ep))
    remap_pt = sweep.degraded_max_throughput(
        cluster, cfg, scenario, faults=faults, tp=tp, pp=pp, dtype=dtype,
        dbo=dbo, sd=sd)
    keep_thr = keep_pt.throughput if keep_pt is not None else 0.0
    remap_eff = 0.0
    if remap_pt is not None:
        remap_eff = remap_pt.throughput * max(
            1.0 - remap_downtime_s / max(horizon_s, 1e-9), 0.0)
    if keep_pt is None and remap_pt is None:
        return DegradedPlan("down", None, None, None, remap_downtime_s,
                            horizon_s, 0.0)
    # ties keep the no-downtime arm — remapping is never free
    if keep_thr >= remap_eff:
        return DegradedPlan("keep", keep_pt, keep_pt, remap_pt,
                            remap_downtime_s, horizon_s, keep_thr)
    return DegradedPlan("remap", remap_pt, keep_pt, remap_pt,
                        remap_downtime_s, horizon_s, remap_eff)


def best_of_opts_scalar(cluster: Cluster, cfg: ModelConfig,
                        scenario: Scenario, opts: str = "dbo+sd",
                        **kw) -> Optional[OperatingPoint]:
    """Reference scalar counterpart of `best_of_opts` (seed semantics)."""
    candidates = [max_throughput_scalar(cluster, cfg, scenario, dbo=False,
                                        sd=None, **kw)]
    if opts in ("dbo", "dbo+sd"):
        candidates.append(
            max_throughput_scalar(cluster, cfg, scenario, dbo=True, sd=None,
                                  **kw))
    if opts == "dbo+sd":
        sd = SpecDecConfig()
        candidates.append(
            max_throughput_scalar(cluster, cfg, scenario, dbo=True, sd=sd,
                                  **kw))
        candidates.append(
            max_throughput_scalar(cluster, cfg, scenario, dbo=False, sd=sd,
                                  **kw))
    candidates = [c for c in candidates if c is not None]
    if not candidates:
        return None
    return max(candidates, key=lambda c: c.throughput)
