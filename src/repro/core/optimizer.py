"""Max-throughput-under-SLO sweep (paper sections 3.2.1, 4.1).

For a (cluster, model, scenario) triple, sweep batch size (and the software
optimizations DBO / SD) under the memory-capacity constraint, model TPOT as
compute + communication (with DBO's two-lane overlap when enabled), and
return the configuration with the highest throughput whose TPOT meets the
SLO. "Cluster builders provision for peak load": max capacity per cost is
the paper's cost-effectiveness metric.

Two execution paths share this module's public API:

  max_throughput / best_of_opts          batched (repro.core.sweep): the
      whole batch grid evaluates as array programs, the argmax winner is
      re-derived through the scalar path below.
  max_throughput_scalar / best_of_opts_scalar   the seed one-point-at-a-time
      reference, kept as ground truth for tests and boundary fallbacks.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import List, Optional, Sequence

from repro.configs.base import ModelConfig
from repro.core import compute_model as cm
from repro.core import overlap, workload
from repro.core.compute_model import Op
from repro.core.specdec import SpecDecConfig
from repro.core.topology import Cluster
from repro.core.workload import ServingPoint


@dataclass(frozen=True)
class Scenario:
    """TPOT SLO x average context length (paper section 3.1)."""
    tpot_ms: float
    context: int

    @property
    def name(self) -> str:
        return f"tpot{int(self.tpot_ms)}ms_ctx{self.context}"


# the paper's evaluation grid
SCENARIOS = [Scenario(t, c) for c in (512, 4096) for t in (15.0, 40.0, 100.0)]


@dataclass(frozen=True)
class OperatingPoint:
    batch: int
    tpot: float                    # seconds
    throughput: float              # tokens / s (cluster-wide)
    used_dbo: bool
    used_sd: bool
    exposed_comm: float            # seconds (under the schedule actually used)
    t_compute: float
    t_comm: float

    @property
    def throughput_per_xpu(self):  # filled by caller via cluster.n_xpus
        raise AttributeError("use result.throughput / cluster.n_xpus")


# ---------------------------------------------------------------------------
# single-iteration time
# ---------------------------------------------------------------------------

def _timers(cluster: Cluster, p: ServingPoint):
    fp8 = p.dtype == "fp8"
    rows = p.batch_per_device * p.q_len

    def t_comp(op: Op) -> float:
        return cm.compute_time(op, cluster.xpu, rows=rows, fp8=fp8)

    def t_comm(op: Op) -> float:
        if op.kind == "a2a":
            return cluster.a2a_time(op.m_bytes)
        return cluster.ar_time(op.m_bytes, group=op.group or None)

    return t_comp, t_comm


def iteration_time(cfg: ModelConfig, p: ServingPoint, cluster: Cluster,
                   *, dbo: bool) -> tuple[float, float, float, float]:
    """One decode iteration -> (t_iter, exposed_comm, t_compute, t_comm).

    dbo=True: the batch splits into two microbatches of B/2; TPOT is the
    two-lane greedy schedule's makespan (paper section 3.3).
    """
    if not dbo:
        ops = workload.decode_iteration(cfg, p)
        t_comp, t_comm = _timers(cluster, p)
        tc = sum(t_comp(o) for o in ops if o.kind == "compute")
        tm = sum(t_comm(o) for o in ops if o.kind != "compute")
        return tc + tm, tm, tc, tm

    half = replace(p, batch_global=max(p.batch_global // 2, 1))
    ops_half = workload.decode_iteration(cfg, half)
    t_comp, t_comm = _timers(cluster, half)
    makespan, exposed = overlap.dbo_tpot(ops_half, t_comp, t_comm)
    tc = 2 * sum(t_comp(o) for o in ops_half if o.kind == "compute")
    tm = 2 * sum(t_comm(o) for o in ops_half if o.kind != "compute")
    return makespan, exposed, tc, tm


def tpot_at(cfg: ModelConfig, p: ServingPoint, cluster: Cluster, *,
            dbo: bool, sd: Optional[SpecDecConfig]) -> tuple[float, float, float, float]:
    """(TPOT, exposed_comm, t_compute, t_comm) for one operating point.

    DBO on means "best of DBO and no-overlap" (paper Fig. 11a). SD wraps
    draft + verify iterations.
    """
    def best_iter(q_len: int):
        pq = replace(p, q_len=q_len)
        res = iteration_time(cfg, pq, cluster, dbo=False)
        if dbo and p.batch_global >= 2:
            res_dbo = iteration_time(cfg, pq, cluster, dbo=True)
            if res_dbo[0] < res[0]:
                return res_dbo
        return res

    if sd is None:
        return best_iter(1)

    t_draft, e1, c1, m1 = best_iter(1)
    t_verify, e2, c2, m2 = best_iter(sd.spec_m)
    denom = sd.tokens_per_iteration
    return ((t_draft + t_verify) / denom, (e1 + e2) / denom,
            (c1 + c2) / denom, (m1 + m2) / denom)


# ---------------------------------------------------------------------------
# the sweep
# ---------------------------------------------------------------------------

def _batch_grid(b_max: int, ep: int) -> List[int]:
    """Geometric grid from ep to b_max (finer near the top end)."""
    if b_max < 1:
        return []
    grid = set()
    b = max(ep, 1)
    while b <= b_max:
        grid.add(b)
        b *= 2
    # refine: 3/4 points between octaves near the top two octaves
    for base in sorted(grid)[-3:]:
        for frac in (1.25, 1.5, 1.75):
            v = int(base * frac)
            if v <= b_max:
                grid.add(v)
    grid.add(b_max)
    return sorted(grid)


def max_throughput(cluster: Cluster, cfg: ModelConfig, scenario: Scenario,
                   *, dbo: bool = False, sd: Optional[SpecDecConfig] = None,
                   tp: int = 1, ep: Optional[int] = None,
                   dtype: str = "fp8") -> Optional[OperatingPoint]:
    """Best operating point under the TPOT SLO, or None if the SLO is
    unreachable at every feasible batch size.

    Evaluates the batch grid through the vectorized sweep engine
    (`repro.core.sweep`); the winning point is re-derived through the exact
    scalar path below, so the result is byte-identical to
    `max_throughput_scalar`. Pass lists of clusters/scenarios to
    `sweep.sweep_max_throughput` directly to amortize one grid evaluation
    across a whole figure.
    """
    from repro.core import sweep
    return sweep.sweep_max_throughput([cluster], cfg, [scenario], dbo=dbo,
                                      sd=sd, tp=tp, ep=ep,
                                      dtype=dtype)[0][0]


def max_throughput_scalar(cluster: Cluster, cfg: ModelConfig,
                          scenario: Scenario, *, dbo: bool = False,
                          sd: Optional[SpecDecConfig] = None, tp: int = 1,
                          ep: Optional[int] = None,
                          dtype: str = "fp8") -> Optional[OperatingPoint]:
    """Reference scalar sweep (the seed implementation, one `tpot_at` call
    per grid point). Kept as the ground truth the batched engine is tested
    against, and as the fallback when a batched TPOT lands exactly on the
    SLO boundary."""
    n = cluster.n_xpus
    if cfg.moe is not None:
        ep = ep or n
    else:
        ep = 1
    tpot_budget = scenario.tpot_ms * 1e-3

    p0 = ServingPoint(batch_global=1, context=scenario.context, tp=tp, ep=ep,
                      n_devices=n, dtype=dtype)
    b_max = workload.max_batch_by_memory(cfg, p0, cluster.xpu.hbm_cap)
    best: Optional[OperatingPoint] = None
    for b in _batch_grid(b_max, max(n // tp, 1)):
        p = replace(p0, batch_global=b)
        tpot, ect, tc, tm = tpot_at(cfg, p, cluster, dbo=dbo, sd=sd)
        if tpot > tpot_budget:
            continue
        thr = b / tpot
        if best is None or thr > best.throughput:
            best = OperatingPoint(batch=b, tpot=tpot, throughput=thr,
                                  used_dbo=dbo, used_sd=sd is not None,
                                  exposed_comm=ect, t_compute=tc, t_comm=tm)
    return best


def best_of_opts(cluster: Cluster, cfg: ModelConfig, scenario: Scenario,
                 opts: str = "dbo+sd", **kw) -> Optional[OperatingPoint]:
    """opts: 'noopt' | 'dbo' | 'dbo+sd'. DBO/SD results fall back to the
    unoptimized point when that is faster (paper's 'best of' curves).

    Runs on the batched sweep engine; `sweep.best_of_opts_grid` is the
    many-clusters/many-scenarios entry point the benchmarks use."""
    from repro.core import sweep
    return sweep.best_of_opts_grid([cluster], cfg, [scenario], opts,
                                   **kw)[0][0]


def best_of_opts_scalar(cluster: Cluster, cfg: ModelConfig,
                        scenario: Scenario, opts: str = "dbo+sd",
                        **kw) -> Optional[OperatingPoint]:
    """Reference scalar counterpart of `best_of_opts` (seed semantics)."""
    candidates = [max_throughput_scalar(cluster, cfg, scenario, dbo=False,
                                        sd=None, **kw)]
    if opts in ("dbo", "dbo+sd"):
        candidates.append(
            max_throughput_scalar(cluster, cfg, scenario, dbo=True, sd=None,
                                  **kw))
    if opts == "dbo+sd":
        sd = SpecDecConfig()
        candidates.append(
            max_throughput_scalar(cluster, cfg, scenario, dbo=True, sd=sd,
                                  **kw))
        candidates.append(
            max_throughput_scalar(cluster, cfg, scenario, dbo=False, sd=sd,
                                  **kw))
    candidates = [c for c in candidates if c is not None]
    if not candidates:
        return None
    return max(candidates, key=lambda c: c.throughput)
