"""Unified serving-search facade: one `solve()` for every search mode.

The operating-point search grew four entry points with overlapping kwarg
sprawls — `optimizer.max_throughput` (decode), `optimizer.best_of_opts`
(decode + software-optimization levels), `optimizer.max_throughput_prefill`
(chunked / disaggregated prefill), and `optimizer.degrade_policy` /
`sweep.degraded_max_throughput` (failure-aware re-search). Downstream
consumers (benchmarks, the traffic simulator, examples) should not need to
know which engine function answers which question, so this module is the
supported surface:

  SearchSpec   frozen value object naming the WHOLE search configuration
               (mapping axes, placement, backend, software opts, serving
               mode, fault state);
  solve()      one (cfg, cluster, scenario, spec) -> Solution call that
               routes to the decode / prefill / degraded search;
  solve_grid() the batched clusters x scenarios form (one engine pass,
               the shape every figure uses);
  solve_levels() the multi-opts-level form (shares one GridEval across
               levels, e.g. fig11's three curves for one engine pass);
  tpot_curve() TPOT over an arbitrary batch grid for a SOLVED point's
               configuration — the seam the traffic simulator clocks
               decode iterations through without touching engine
               internals.

Routing never re-implements a search: every path delegates to the same
`repro.core.sweep` engine calls the legacy wrappers used, so results are
byte-identical to the pre-facade stack. The legacy `optimizer` wrappers
remain as thin shims that emit `ReproDeprecationWarning` (an in-repo
`DeprecationWarning` subclass pytest escalates to an error, so repo code
cannot regress onto them).
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field, fields
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import optable, optimizer, sweep
from repro.core.optimizer import (DegradedPlan, OperatingPoint,
                                  PrefillOperatingPoint, Scenario)
from repro.core.specdec import SpecDecConfig
from repro.core.topology import Cluster, FaultSet


class ReproDeprecationWarning(DeprecationWarning):
    """Deprecation category for this repo's legacy entry points.

    A dedicated subclass lets pytest escalate exactly OUR deprecations to
    errors (`filterwarnings` in pyproject.toml) without tripping over
    third-party `DeprecationWarning`s from numpy/jax."""


def warn_deprecated(old: str, new: str) -> None:
    warnings.warn(f"{old} is deprecated; use {new}",
                  ReproDeprecationWarning, stacklevel=3)


PREFILL_MODES = ("decode", "chunked", "disagg")
OPTS_LEVELS = ("noopt", "dbo", "dbo+sd")


@dataclass(frozen=True)
class SearchSpec:
    """Everything that configures an operating-point search, in one frozen
    (hashable, cache-key-able) value object.

    Mapping axes: `tp` / `pp` take an int or "auto" (joint (tp, pp,
    ep = n/(tp*pp)) search); `ep` pins the expert-parallel degree (None =
    derived). `placement="auto"` searches expert replication for skewed
    scenarios. `backend` picks the sweep engine ("numpy" / "jax" / None =
    module default).

    Software opts: either fix the variant with `dbo` / `sd`, or set
    `opts` to a best-of level ("noopt" | "dbo" | "dbo+sd") — the two are
    mutually exclusive, `opts` searches over variants.

    Serving mode: `mode` "decode" (prefill unmodeled, the seed search)
    | "chunked" | "disagg"; prefill modes accept `chunk_grid` /
    `split_fracs` overrides (None = engine defaults).

    Fault state: a `FaultSet` in `faults` routes to the failure-aware
    remap-vs-degrade policy (`optimizer.degrade_policy`) — the Solution
    then carries a `DegradedPlan`. Note the policy's conventional mapping
    default is tp="auto" (re-shard searches the mapping); pass it
    explicitly, the spec default stays tp=1 like every other path.
    """
    tp: Union[int, str] = 1
    pp: Union[int, str] = 1
    ep: Optional[int] = None
    placement: Optional[str] = None
    backend: Optional[str] = None
    faults: Optional[FaultSet] = None
    dbo: bool = False
    sd: Optional[SpecDecConfig] = None
    opts: Optional[str] = None
    mode: str = "decode"
    dtype: str = "fp8"
    chunk_grid: Optional[Tuple[int, ...]] = None
    split_fracs: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        if self.mode not in PREFILL_MODES:
            raise ValueError(f"unknown mode {self.mode!r}; expected one of "
                             f"{PREFILL_MODES}")
        if self.opts is not None:
            if self.opts not in OPTS_LEVELS:
                raise ValueError(f"unknown opts {self.opts!r}; expected one "
                                 f"of {OPTS_LEVELS}")
            if self.dbo or self.sd is not None:
                raise ValueError("opts searches the (dbo, sd) variants; "
                                 "pass either opts or fixed dbo/sd, not "
                                 "both")
        if self.mode != "decode":
            if self.opts is not None:
                raise ValueError("prefill modes fix the variant via dbo; "
                                 "opts is decode-only")
            if self.sd is not None:
                raise ValueError("speculative decoding is not modeled in "
                                 "prefill modes")
            if self.placement is not None:
                raise ValueError("placement search is decode-only")
        if self.faults is not None:
            if self.mode != "decode":
                raise ValueError("the degraded search is decode-only")
            if self.opts is not None or self.placement is not None \
                    or self.ep is not None:
                raise ValueError("the degraded search resolves ep on the "
                                 "survivor cluster and fixes the variant "
                                 "via dbo/sd; opts/placement/ep do not "
                                 "apply")

    def replace(self, **kw) -> "SearchSpec":
        """`dataclasses.replace` spelled as a method (the spec is the unit
        callers tweak: `spec.replace(faults=fs)`)."""
        cur = {f.name: getattr(self, f.name) for f in fields(self)}
        cur.update(kw)
        return SearchSpec(**cur)


@dataclass(frozen=True)
class Solution:
    """Unified result of `solve()`.

    kind 'decode'   -> `point` is an `OperatingPoint` (or None: SLO
                       unreachable);
         'prefill'  -> `point` is a `PrefillOperatingPoint` (or None);
         'degraded' -> `plan` is the `DegradedPlan`; `point` is the plan's
                       chosen operating point (None when action='down').
    """
    kind: str
    point: Optional[Union[OperatingPoint, PrefillOperatingPoint]]
    plan: Optional[DegradedPlan] = None
    spec: SearchSpec = field(default_factory=SearchSpec, compare=False)

    @property
    def feasible(self) -> bool:
        return self.point is not None

    @property
    def throughput(self) -> float:
        """Tokens/s cluster-wide; 0.0 when infeasible. The degraded kind
        reports the plan's downtime-amortized effective throughput."""
        if self.kind == "degraded":
            return self.plan.effective_throughput if self.plan else 0.0
        return self.point.throughput if self.point else 0.0

    @property
    def tpot(self) -> Optional[float]:
        return self.point.tpot if self.point else None

    @property
    def batch(self) -> Optional[int]:
        return self.point.batch if self.point else None

    @property
    def prefill_point(self) -> Optional[PrefillOperatingPoint]:
        """The point as a `PrefillOperatingPoint`, wrapping decode-mode
        results the way `sweep.sweep_prefill(mode='decode')` does — the
        shape prefill-comparison consumers want."""
        if self.point is None or isinstance(self.point,
                                            PrefillOperatingPoint):
            return self.point
        return sweep._as_decode_point(self.point)


def _prefill_kw(spec: SearchSpec) -> Dict:
    kw: Dict = {}
    if spec.chunk_grid is not None:
        kw["chunk_grid"] = spec.chunk_grid
    if spec.split_fracs is not None:
        kw["split_fracs"] = spec.split_fracs
    return kw


def _solve_degraded(cfg: ModelConfig, cluster: Cluster, scenario: Scenario,
                    spec: SearchSpec) -> Solution:
    plan = optimizer.degrade_policy(cluster, cfg, scenario, spec.faults,
                                    tp=spec.tp, pp=spec.pp, dtype=spec.dtype,
                                    dbo=spec.dbo, sd=spec.sd)
    return Solution(kind="degraded", point=plan.point, plan=plan, spec=spec)


def solve_grid(cfg: ModelConfig, clusters: Sequence[Cluster],
               scenarios: Sequence[Scenario],
               spec: SearchSpec = SearchSpec()) -> List[List[Solution]]:
    """Batched `solve` over clusters x scenarios (one engine pass for the
    decode/prefill paths; the degraded path prices each cell's policy).
    Returns [cluster][scenario] Solutions."""
    if spec.faults is not None:
        return [[_solve_degraded(cfg, cl, sc, spec) for sc in scenarios]
                for cl in clusters]
    if spec.mode != "decode":
        grid = sweep.sweep_prefill(clusters, cfg, scenarios, mode=spec.mode,
                                   tp=spec.tp, pp=spec.pp, ep=spec.ep,
                                   dtype=spec.dtype, dbo=spec.dbo,
                                   backend=spec.backend, **_prefill_kw(spec))
        return [[Solution(kind="prefill", point=p, spec=spec) for p in row]
                for row in grid]
    if spec.opts is not None:
        grid = sweep.best_of_opts_grid(clusters, cfg, scenarios, spec.opts,
                                       tp=spec.tp, pp=spec.pp, ep=spec.ep,
                                       dtype=spec.dtype, backend=spec.backend,
                                       placement=spec.placement)
    else:
        grid = sweep.sweep_max_throughput(clusters, cfg, scenarios,
                                          dbo=spec.dbo, sd=spec.sd,
                                          tp=spec.tp, pp=spec.pp, ep=spec.ep,
                                          dtype=spec.dtype,
                                          backend=spec.backend,
                                          placement=spec.placement)
    return [[Solution(kind="decode", point=p, spec=spec) for p in row]
            for row in grid]


def solve(cfg: ModelConfig, cluster: Cluster, scenario: Scenario,
          spec: SearchSpec = SearchSpec()) -> Solution:
    """THE entry point: best operating point of `cluster` for `scenario`
    under the search configuration in `spec`.

    Routing (all delegate to `repro.core.sweep`, byte-identical to the
    legacy wrappers):
      spec.faults set        -> remap-vs-degrade policy (kind 'degraded')
      spec.mode != 'decode'  -> prefill-aware search    (kind 'prefill')
      spec.opts set          -> best-of-(dbo, sd) search (kind 'decode')
      otherwise              -> fixed-variant decode search (kind 'decode')

    Batch several clusters/scenarios through `solve_grid` to amortize one
    grid evaluation across a whole figure.
    """
    return solve_grid(cfg, [cluster], [scenario], spec)[0][0]


def solve_levels(cfg: ModelConfig, clusters: Sequence[Cluster],
                 scenarios: Sequence[Scenario],
                 levels: Sequence[str] = OPTS_LEVELS,
                 spec: SearchSpec = SearchSpec()
                 ) -> Dict[str, List[List[Solution]]]:
    """`solve_grid` for SEVERAL best-of levels at once, sharing one
    GridEval across them ('dbo+sd' already evaluates everything 'noopt'
    and 'dbo' need — fig11's three curves cost one engine pass). `spec`
    must leave `opts`/`dbo`/`sd` at their defaults (the levels ARE the
    variant axis) and stay on the healthy decode path."""
    if spec.opts is not None or spec.dbo or spec.sd is not None:
        raise ValueError("solve_levels sweeps the variant axis itself; "
                         "leave spec.opts/dbo/sd at defaults")
    if spec.faults is not None or spec.mode != "decode":
        raise ValueError("solve_levels is a healthy decode-path search")
    multi = sweep.best_of_opts_multi(clusters, cfg, scenarios, list(levels),
                                     tp=spec.tp, pp=spec.pp, ep=spec.ep,
                                     dtype=spec.dtype, backend=spec.backend,
                                     placement=spec.placement)
    return {lvl: [[Solution(kind="decode", point=p,
                            spec=spec.replace(opts=lvl))
                   for p in row] for row in multi[lvl]]
            for lvl in levels}


def tpot_curve(cfg: ModelConfig, cluster: Cluster, scenario: Scenario,
               batches: Sequence[int], *, point: OperatingPoint,
               dtype: str = "fp8",
               backend: Optional[str] = None) -> np.ndarray:
    """TPOT seconds at each batch size for a SOLVED point's configuration
    (its (tp, pp, ep) mapping, placement, and software variant) on
    `cluster` — the decode-iteration clock of `repro.core.traffic`.

    Runs the same GridEval the search used, so `curve[batch == point.batch]
    == point.tpot` exactly (modulo the knife-edge scalar fallback, which
    only re-derives the winning cell)."""
    b = np.asarray(list(batches), np.int64)
    table = optable.op_table(cfg, point.tp, max(point.ep, 1),
                             cluster.n_xpus, dtype, pp=point.pp)
    load = sweep.op_load_factors(table, cfg, [scenario],
                                 point.extra_experts)
    ev = sweep.GridEval(table, [cluster], [scenario], b, backend=backend,
                        load=load)
    sd = SpecDecConfig() if point.used_sd else None
    return ev.tpot(dbo=point.used_dbo, sd=sd)[0, 0]
