"""Performance-vs-cost sweep + Pareto frontier (paper section 4.4, Fig 17).

Each point = (monthly cost per XPU, throughput per XPU) for one
(topology, link bandwidth, cluster size) under a scenario with all software
optimizations. The slope origin->point is throughput per cost; the Pareto
frontier is the upper-left hull.

Layer: presentation-side aggregation over sweep results + `core.tco`;
no timing math of its own, so parity is inherited from the sweep.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

from repro.configs.base import ModelConfig
from repro.core import optimizer, tco
from repro.core.hardware import XPUSpec
from repro.core.optimizer import Scenario
from repro.core.topology import TOPOLOGIES, make_cluster

# the paper's bandwidth sweep grid, as fractions of the 1x provision
BW_FRACTIONS = (1 / 9, 1 / 3, 2 / 3, 1.0, 2.0)


@dataclass(frozen=True)
class ParetoPoint:
    topology: str
    n_xpus: int
    link_bw: float
    cost_per_xpu: float            # monthly, normalized units
    throughput_per_xpu: float      # tokens/s
    throughput_per_cost: float
    batch: int
    tpot_ms: float

    def dominates(self, other: "ParetoPoint") -> bool:
        return (self.cost_per_xpu <= other.cost_per_xpu
                and self.throughput_per_xpu >= other.throughput_per_xpu
                and (self.cost_per_xpu < other.cost_per_xpu
                     or self.throughput_per_xpu > other.throughput_per_xpu))


def sweep_networks(cfg: ModelConfig, scenario: Scenario, xpu: XPUSpec,
                   *, sizes: Sequence[int] = (64, 256),
                   topologies: Sequence[str] = TOPOLOGIES,
                   bw_fracs: Sequence[float] = BW_FRACTIONS,
                   opts: str = "dbo+sd", c: float = 1.0) -> List[ParetoPoint]:
    """All (topology, link bandwidth) points of one scenario, evaluated as
    one batched grid per cluster size (the sweep engine requires a uniform
    device count per grid). Point order matches the seed's nested loops.
    `topologies` defaults to the registry's static four; pass
    `tuple(repro.core.fabric.FABRICS)` to rank the OCS fabric too."""
    from repro.core import api

    ops_by_size = {}
    for n in sizes:
        keys, clusters = [], []
        for topo in topologies:
            for f in bw_fracs:
                # each topology sweeps fractions of its own provision
                # (`Fabric.default_link_bw`; scale-out: NIC-class fabric
                # on top of the intra-node scale-up domain it always
                # carries — see core.fabric)
                keys.append((topo, f))
                clusters.append(make_cluster(topo, n, xpu,
                                             link_bw_mult=f))
        grid = api.solve_grid(cfg, clusters, [scenario],
                              api.SearchSpec(opts=opts))
        ops_by_size[n] = {k: (cl, row[0].point)
                          for k, cl, row in zip(keys, clusters, grid)}

    points: List[ParetoPoint] = []
    for topo in topologies:
        for n in sizes:
            for f in bw_fracs:
                cl, op = ops_by_size[n][(topo, f)]
                if op is None:
                    continue
                cost = tco.cluster_tco(cl).per_xpu(n, c)
                points.append(ParetoPoint(
                    topology=topo, n_xpus=n, link_bw=cl.link_bw,
                    cost_per_xpu=cost,
                    throughput_per_xpu=op.throughput / n,
                    throughput_per_cost=op.throughput / n / cost,
                    batch=op.batch, tpot_ms=op.tpot * 1e3))
    return points


def pareto_frontier(points: Sequence[ParetoPoint]) -> List[ParetoPoint]:
    """Upper-left hull: no other point has both lower cost and higher
    throughput."""
    frontier = [p for p in points
                if not any(q.dominates(p) for q in points)]
    return sorted(frontier, key=lambda p: p.cost_per_xpu)
