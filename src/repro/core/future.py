"""Forward-looking projection to Blackwell / Rubin (paper section 4.5).

Since future cost data is unavailable, the paper uses the bandwidth required
to reach throughput saturation as a proxy for cost-effectiveness: if the
saturating bandwidth of switchless topologies stays at/below the generation's
provision, their advantage persists.

The compute-time projection applies per-kernel roofline speedups (Table 5
FLOPs and memory-bandwidth scaling) — our compute model is already a
roofline, so switching the XPUSpec does exactly that.

`alpha_scale` models the paper's alpha-reduction study (Fig 19): scaling
alpha_r and alpha_d toward zero (lower software/protocol overhead).

Layer: top-of-stack study driver over `core.hardware` specs and the sweep
engines; it only swaps inputs (XPUSpec, alphas), so results inherit the
sweep layer's scalar/batched parity unchanged.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from repro.configs.base import ModelConfig
from repro.core import optimizer
from repro.core.hardware import XPUSpec, BLACKWELL, RUBIN
from repro.core.optimizer import Scenario
from repro.core.topology import (Cluster, TOPOLOGIES, get_fabric,
                                 make_cluster)


@dataclass(frozen=True)
class BWCurvePoint:
    topology: str
    link_bw: float
    throughput_per_xpu: float
    batch: int


def throughput_vs_bandwidth(cfg: ModelConfig, scenario: Scenario,
                            xpu: XPUSpec, topology: str, n: int,
                            bw_grid: Sequence[float], *,
                            opts: str = "dbo+sd",
                            alpha_scale: float = 1.0) -> List[BWCurvePoint]:
    """Throughput-per-XPU as link bandwidth sweeps (paper Fig 18/19).

    The whole bandwidth grid evaluates as one batched sweep; the alpha-scaled
    cluster subclass composes transparently because the sweep engine reads
    alphas through `cluster._ab()`."""
    from repro.core import api

    clusters = []
    for bw in bw_grid:
        cl = make_cluster(topology, n, xpu, link_bw=bw)
        if alpha_scale != 1.0:
            cl = scaled_alpha_cluster(cl, alpha_scale)
        clusters.append(cl)
    grid = api.solve_grid(cfg, clusters, [scenario],
                          api.SearchSpec(opts=opts))
    pts = []
    for bw, row in zip(bw_grid, grid):
        op = row[0].point
        if op is None:
            continue
        pts.append(BWCurvePoint(topology=topology, link_bw=bw,
                                throughput_per_xpu=op.throughput / n,
                                batch=op.batch))
    return pts


def scaled_alpha_cluster(cluster: Cluster, alpha_scale: float) -> Cluster:
    """Cluster whose collectives use alpha_r/alpha_d scaled by
    `alpha_scale` (0.0 = the paper's theoretical bound in Fig 19)."""

    class _Scaled(Cluster):
        def _ab(self):
            ab = super()._ab()
            return dataclasses.replace(
                ab, alpha_r=ab.alpha_r * alpha_scale,
                alpha_d=ab.alpha_d * alpha_scale)

    return _Scaled(topology=cluster.topology, n_xpus=cluster.n_xpus,
                   xpu=cluster.xpu, link_bw=cluster.link_bw,
                   dims=cluster.dims)


def saturating_bandwidth(curve: Sequence[BWCurvePoint],
                         frac: float = 0.97) -> Optional[float]:
    """Smallest bandwidth whose throughput reaches `frac` of the curve's
    ceiling — the paper's saturation-point proxy."""
    if not curve:
        return None
    ceiling = max(p.throughput_per_xpu for p in curve)
    for p in sorted(curve, key=lambda p: p.link_bw):
        if p.throughput_per_xpu >= frac * ceiling:
            return p.link_bw
    return None


GENERATION_PROVISION = {"Blackwell": 900e9, "Rubin": 1800e9}


def generation_report(cfg: ModelConfig, scenario: Scenario, gen_name: str,
                      n: int = 256, *, alpha_scale: float = 1.0) -> Dict:
    """Per-topology saturating bandwidth vs the generation's provision."""
    xpu = {"Blackwell": BLACKWELL, "Rubin": RUBIN}[gen_name]
    provision = GENERATION_PROVISION[gen_name]
    grid = [provision * f for f in (1 / 8, 1 / 4, 1 / 2, 1.0, 2.0)]
    out = {"generation": gen_name, "provision": provision,
           "scenario": scenario.name, "topologies": {}}
    # the grid sweeps fractions of the generation's SCALE-UP provision, so
    # only the scale-up-provisioned static fabrics are comparable here
    # (scale-out's own axis is the NIC; registry-derived, not hardcoded)
    for topo in (t for t in TOPOLOGIES if not get_fabric(t).nic_provisioned):
        curve = throughput_vs_bandwidth(cfg, scenario, xpu, topo, n, grid,
                                        alpha_scale=alpha_scale)
        out["topologies"][topo] = {
            "curve": [(p.link_bw, p.throughput_per_xpu) for p in curve],
            "saturating_bw": saturating_bandwidth(curve),
        }
    return out
