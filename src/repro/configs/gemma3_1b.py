"""gemma3-1b [dense] — 5:1 local:global attention, 128k context.

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144  [hf:google/gemma-3-1b-pt]

Pattern: 5 sliding-window (1024) layers then 1 global layer. 26 layers = 4
full periods + 2 remainder local layers. Sub-quadratic in the 5:1 sense:
long_500k runs with seq-sharded KV on the 4 global layers.
"""
from repro.configs.base import LayerSpec, ModelConfig

_PERIOD = tuple(
    LayerSpec(mixer="attn_local", ffn="dense") for _ in range(5)
) + (LayerSpec(mixer="attn", ffn="dense"),)

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    d_ff=6912,
    vocab_size=262144,
    d_head=256,
    period=_PERIOD,
    sliding_window=1024,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
