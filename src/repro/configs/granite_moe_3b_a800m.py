"""granite-moe-3b-a800m [moe] — 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base family]

32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155, MoE 40e top-8
(d_ff=512 is the per-expert hidden dim). 40 experts pad to 48 under EP=16.
"""
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    period=(LayerSpec(mixer="attn", ffn="moe"),),
    moe=MoEConfig(num_experts=40, experts_per_token=8, d_expert=512),
    rope_theta=10_000.0,
    tie_embeddings=True,
)
