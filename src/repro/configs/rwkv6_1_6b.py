"""rwkv6-1.6b [ssm] — Finch, data-dependent decay, attention-free.
[arXiv:2404.05892]

24L d_model=2048 (attn-free) d_ff=7168 vocab=65536
"""
from repro.configs.base import LayerSpec, ModelConfig, RwkvConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,            # 2048 / head_dim 64 WKV heads
    num_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    attn_kind="none",
    period=(LayerSpec(mixer="rwkv", ffn="dense"),),
    rwkv=RwkvConfig(head_dim=64),
)
