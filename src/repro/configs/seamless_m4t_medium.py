"""seamless-m4t-medium [audio] — encoder-decoder, multimodal.
[arXiv:2308.11596]

12L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206

The audio frontend is a STUB: ``input_specs()`` supplies precomputed frame
embeddings [B, enc_len, d_model]. 12 encoder layers + 12 decoder layers with
cross-attention against the encoder output.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    period=(LayerSpec(mixer="attn", ffn="dense"),),
    encoder_layers=12,
    frontend="audio_frames",
)
