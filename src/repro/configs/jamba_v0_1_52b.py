"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536  [arXiv:2403.19887; hf]

Jamba block structure: period of 8 layers with one attention layer (position
4 of the block, per the released model) and MoE replacing the dense MLP on
every other layer (positions 1,3,5,7).
"""
from repro.configs.base import LayerSpec, MambaConfig, ModelConfig, MoEConfig


def _spec(i: int) -> LayerSpec:
    mixer = "attn" if i == 4 else "mamba"
    ffn = "moe" if i % 2 == 1 else "dense"
    return LayerSpec(mixer=mixer, ffn=ffn)


CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    period=tuple(_spec(i) for i in range(8)),
    moe=MoEConfig(num_experts=16, experts_per_token=2, d_expert=14336),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    rope_theta=10_000.0,   # jamba attn layers use no RoPE in release; we keep RoPE for generality
)
