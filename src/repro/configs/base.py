"""Config system: architecture + parallelism + shape cells.

Every assigned architecture is a ``ModelConfig`` built out of a periodic
``LayerSpec`` pattern (mixer kind x ffn kind), so heterogeneous stacks
(jamba's 1:7 mamba:attn interleave, gemma3's 5:1 local:global) compile as a
``lax.scan`` over periods with an unrolled remainder.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer pattern
# ---------------------------------------------------------------------------

MIXERS = ("attn", "attn_local", "mamba", "rwkv", "none")
FFNS = ("dense", "moe", "none")


@dataclass(frozen=True)
class LayerSpec:
    """One decoder layer = a sequence mixer + a token-wise FFN."""

    mixer: str = "attn"           # attn | attn_local | mamba | rwkv | none
    ffn: str = "dense"            # dense | moe | none

    def __post_init__(self):
        assert self.mixer in MIXERS, self.mixer
        assert self.ffn in FFNS, self.ffn


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int            # top-k
    d_expert: int                     # per-expert hidden dim
    num_shared_experts: int = 0
    d_shared_expert: int = 0
    capacity_factor: float = 1.5      # GShard-style static capacity
    router_aux_loss_coef: float = 0.01
    gated: bool = True                # SwiGLU experts

    def padded_num_experts(self, ep: int) -> int:
        """Experts padded up to a multiple of the EP group size."""
        return int(math.ceil(self.num_experts / ep) * ep)


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2                   # d_inner = expand * d_model
    dt_rank: int = 0                  # 0 -> ceil(d_model/16)


@dataclass(frozen=True)
class RwkvConfig:
    head_dim: int = 64


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    d_head: int = 0                   # 0 -> d_model // num_heads
    # Layer pattern: repeated `period` of LayerSpecs; remainder unrolled.
    period: Tuple[LayerSpec, ...] = (LayerSpec(),)

    attn_kind: str = "gqa"            # gqa | mla | none
    rope_theta: float = 10_000.0
    sliding_window: int = 0           # for attn_local mixers
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RwkvConfig] = None

    # MLA (deepseek-v3 style latent attention)
    mla_kv_lora_rank: int = 0
    mla_q_lora_rank: int = 0
    mla_rope_head_dim: int = 0

    # encoder-decoder (seamless-m4t): encoder reuses the decoder LayerSpec
    # machinery with non-causal attention and no cache.
    encoder_layers: int = 0

    # modality frontend stub: input_specs() supplies precomputed embeddings.
    frontend: str = ""                # "" | "vit_patches" | "audio_frames"
    n_frontend_tokens: int = 0        # patches per image / audio frames

    dtype: str = "bfloat16"

    # ---------------- derived ----------------
    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // max(self.num_heads, 1))

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def layer_specs(self) -> Tuple[LayerSpec, ...]:
        reps = self.num_layers // len(self.period)
        rem = self.num_layers % len(self.period)
        return tuple(self.period) * reps + tuple(self.period[:rem])

    @property
    def n_periods(self) -> int:
        return self.num_layers // len(self.period)

    @property
    def n_remainder(self) -> int:
        return self.num_layers % len(self.period)

    @property
    def has_attention(self) -> bool:
        return any(s.mixer in ("attn", "attn_local") for s in self.layer_specs)

    @property
    def full_attention_only(self) -> bool:
        """True when every mixer is dense full attention (no recurrence /
        window) -> long_500k is architecturally inapplicable."""
        mixers = {s.mixer for s in self.layer_specs if s.mixer != "none"}
        return mixers == {"attn"}

    def param_count(self) -> int:
        """Approximate parameter count (embedding + per-layer)."""
        d, hd = self.d_model, self.head_dim
        n = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for spec in self.layer_specs:
            if spec.mixer == "attn" or spec.mixer == "attn_local":
                if self.attn_kind == "mla":
                    r, qr, rp = self.mla_kv_lora_rank, self.mla_q_lora_rank, self.mla_rope_head_dim
                    n += d * (r + rp) + r * self.num_heads * (hd + hd)
                    n += (d * qr + qr * self.num_heads * (hd + rp)) if qr else d * self.num_heads * (hd + rp)
                    n += self.num_heads * hd * d
                else:
                    n += d * self.num_heads * hd            # q
                    n += 2 * d * self.num_kv_heads * hd     # k, v
                    n += self.num_heads * hd * d            # o
            elif spec.mixer == "mamba":
                mc = self.mamba or MambaConfig()
                di = mc.expand * d
                dtr = mc.dt_rank or -(-d // 16)
                n += d * 2 * di                              # in_proj
                n += di * mc.d_conv                          # conv
                n += di * (dtr + 2 * mc.d_state) + dtr * di  # x_proj, dt_proj
                n += di * mc.d_state + di                    # A, D
                n += di * d                                  # out_proj
            elif spec.mixer == "rwkv":
                n += 4 * d * d + d * d                       # r,k,v,g,o  (+ decay small)
            if spec.ffn == "dense":
                n += 3 * d * self.d_ff                       # SwiGLU
            elif spec.ffn == "moe":
                m = self.moe
                n += d * m.num_experts                       # router
                n += m.num_experts * 3 * d * m.d_expert
                if m.num_shared_experts:
                    n += m.num_shared_experts * 3 * d * m.d_shared_expert
            n += 2 * d                                       # norms
        if self.encoder_layers:
            # encoder layers: self-attn + dense ffn; decoder adds cross-attn
            n += self.encoder_layers * (4 * d * self.num_heads * hd + 3 * d * self.d_ff)
            n += self.num_layers * (2 * d * self.num_kv_heads * hd + 2 * d * self.num_heads * hd)
        return int(n)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        total = self.param_count()
        n_moe_layers = sum(1 for s in self.layer_specs if s.ffn == "moe")
        all_experts = n_moe_layers * m.num_experts * 3 * self.d_model * m.d_expert
        active = n_moe_layers * m.experts_per_token * 3 * self.d_model * m.d_expert
        return int(total - all_experts + active)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Shape cells
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, shape: ShapeCell) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic attention; skip pure full-attention archs
    (documented in DESIGN.md section 6)."""
    if shape.name == "long_500k" and cfg.full_attention_only:
        return False, "pure full-attention arch: 512k KV/step is architecturally inapplicable"
    return True, ""
