"""deepseek-v3 — the paper's representative serving workload (671B).

61L d_model=7168, MLA (kv_lora 512, q_lora 1536, rope head 64), 128H hd=128,
MoE: 256 routed experts top-8 + 1 shared, d_expert=2048; first 3 layers dense
d_ff=18432. vocab=129280.  [arXiv:2412.19437]

Used by the analysis stack (core/workload.py) and available as a JAX config;
not part of the assigned 40-cell dry-run grid.
"""
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig

# period of 1 MoE layer; the 3 leading dense layers are approximated as MoE
# for stack uniformity in the JAX build (the analysis stack models them
# exactly; see core/workload.py).
CONFIG = ModelConfig(
    name="deepseek-v3",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=18432,
    vocab_size=129280,
    d_head=128,
    attn_kind="mla",
    mla_kv_lora_rank=512,
    mla_q_lora_rank=1536,
    mla_rope_head_dim=64,
    period=(LayerSpec(mixer="attn", ffn="moe"),),
    moe=MoEConfig(
        num_experts=256,
        experts_per_token=8,
        d_expert=2048,
        num_shared_experts=1,
        d_shared_expert=2048,
    ),
    rope_theta=10_000.0,
)
