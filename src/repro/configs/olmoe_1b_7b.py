"""olmoe-1b-7b [moe] — 64 experts top-8.  [arXiv:2409.02060]

16L d_model=2048 16H (GQA kv=16) d_ff=1024 vocab=50304, MoE 64e top-8
(d_ff=1024 is the per-expert hidden dim).
"""
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    period=(LayerSpec(mixer="attn", ffn="moe"),),
    moe=MoEConfig(num_experts=64, experts_per_token=8, d_expert=1024),
    rope_theta=10_000.0,
)
