"""internvl2-76b [vlm] — InternViT frontend (stub) + InternLM2-like backbone.

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256  [arXiv:2404.16821]

The ViT frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed patch embeddings [B, n_patches, d_model]; the text tokens fill the
remainder of the sequence.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    period=(LayerSpec(mixer="attn", ffn="dense"),),
    frontend="vit_patches",
    n_frontend_tokens=256,
    rope_theta=1_000_000.0,
)
