"""Architecture registry: ``--arch <id>`` resolves through ARCHS."""
from repro.configs.base import (
    LayerSpec,
    MambaConfig,
    ModelConfig,
    MoEConfig,
    RwkvConfig,
    ShapeCell,
    SHAPES,
    cell_applicable,
)

from repro.configs import (  # noqa: E402
    jamba_v0_1_52b,
    internvl2_76b,
    starcoder2_3b,
    minitron_8b,
    gemma3_1b,
    deepseek_67b,
    granite_moe_3b_a800m,
    olmoe_1b_7b,
    rwkv6_1_6b,
    seamless_m4t_medium,
    deepseek_v3,
)

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (
        jamba_v0_1_52b,
        internvl2_76b,
        starcoder2_3b,
        minitron_8b,
        gemma3_1b,
        deepseek_67b,
        granite_moe_3b_a800m,
        olmoe_1b_7b,
        rwkv6_1_6b,
        seamless_m4t_medium,
        deepseek_v3,
    )
}

ASSIGNED_ARCHS = [n for n in ARCHS if n != "deepseek-v3"]


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def reduced_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Family-preserving smoke-test reduction: few layers, thin width, few
    experts, tiny vocab. Keeps the layer-pattern structure (>= one period)."""
    import dataclasses

    period = cfg.period
    n_layers = max(len(period), 2)
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(
            moe, num_experts=min(moe.num_experts, 8), d_expert=64,
            d_shared_expert=64 if moe.num_shared_experts else 0)
    kw = dict(
        num_layers=n_layers,
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        d_head=16,
        d_ff=128,
        vocab_size=512,
        moe=moe,
        encoder_layers=2 if cfg.encoder_layers else 0,
        n_frontend_tokens=8 if cfg.frontend else 0,
        sliding_window=8 if cfg.sliding_window else 0,
    )
    if cfg.attn_kind == "mla":
        kw.update(mla_kv_lora_rank=32, mla_q_lora_rank=32, mla_rope_head_dim=8)
    kw.update(overrides)
    return cfg.replace(**kw)
