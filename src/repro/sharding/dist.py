"""Distribution context: one abstraction for single-device and shard_map SPMD.

The whole model runs inside ONE ``jax.shard_map`` region (manual SPMD): every
collective is explicit, so the lowered HLO contains exactly the all-to-all /
all-gather / reduce-scatter / all-reduce traffic the paper reasons about, and
the roofline's collective-bytes term is faithful.

``Dist`` wraps the ``jax.lax`` collectives with the mesh axis names; the
``NullDist`` implements them as identities so the identical model code runs
on a single CPU device for smoke tests / the serving example.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax

AxisName = Union[str, Tuple[str, ...]]


class Dist:
    """Collective ops bound to mesh axis names inside a shard_map region."""

    def __init__(self, axis_sizes: dict[str, int]):
        self._sizes = dict(axis_sizes)

    # ------------- topology -------------
    def size(self, axis: Optional[AxisName]) -> int:
        if axis is None:
            return 1
        if isinstance(axis, tuple):
            n = 1
            for a in axis:
                n *= self._sizes.get(a, 1)
            return n
        return self._sizes.get(axis, 1)

    def index(self, axis: Optional[AxisName]):
        if axis is None or self.size(axis) == 1:
            return jnp.int32(0)
        return lax.axis_index(axis)

    # ------------- collectives -------------
    def psum(self, x, axis: Optional[AxisName]):
        if axis is None or self.size(axis) == 1:
            return x
        return lax.psum(x, axis)

    def pmax(self, x, axis: Optional[AxisName]):
        if axis is None or self.size(axis) == 1:
            return x
        return lax.pmax(x, axis)

    def all_gather(self, x, axis: Optional[AxisName], dim: int = 0):
        """Tiled all-gather along array dim `dim` over mesh axis `axis`."""
        if axis is None or self.size(axis) == 1:
            return x
        return lax.all_gather(x, axis, axis=dim, tiled=True)

    def reduce_scatter(self, x, axis: Optional[AxisName], dim: int = 0):
        """Tiled psum_scatter along array dim `dim` over mesh axis `axis`."""
        if axis is None or self.size(axis) == 1:
            return x
        return lax.psum_scatter(x, axis, scatter_dimension=dim, tiled=True)

    def all_to_all(self, x, axis: Optional[AxisName], split_dim: int, concat_dim: int):
        if axis is None or self.size(axis) == 1:
            return x
        return lax.all_to_all(x, axis, split_axis=split_dim,
                              concat_axis=concat_dim, tiled=True)

    def ppermute(self, x, axis: Optional[AxisName], perm: Sequence[Tuple[int, int]]):
        if axis is None or self.size(axis) == 1:
            return x
        return lax.ppermute(x, axis, perm)

    def roll(self, x, axis: Optional[AxisName], shift: int = 1):
        """Ring shift: rank r -> rank (r+shift) % n."""
        n = self.size(axis)
        if axis is None or n == 1:
            return x
        perm = [(i, (i + shift) % n) for i in range(n)]
        return lax.ppermute(x, axis, perm)


class NullDist(Dist):
    """Single-device stand-in: every collective is the identity."""

    def __init__(self):
        super().__init__({})

    def size(self, axis):
        return 1

    def index(self, axis):
        return jnp.int32(0)


def argmax_across(dist: Dist, values, indices, axis: Optional[AxisName]):
    """Global argmax over a sharded dimension: values/indices are the local
    winners; returns the global winning index (ties -> lowest index)."""
    if axis is None or dist.size(axis) == 1:
        return indices
    vmax = dist.pmax(values, axis)
    # lowest global index among ties
    cand = jnp.where(values >= vmax, indices, jnp.iinfo(jnp.int32).max)
    return -dist.pmax(-cand, axis)
