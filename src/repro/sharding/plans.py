"""Sharding plans: how each (arch x shape x mesh) cell maps onto the mesh.

Mesh axes:
  pod    — cross-pod data parallelism only (gradient all-reduce traffic;
           the paper's principle: keep A2A inside the high-bandwidth domain)
  data   — batch DP; FSDP shard axis in training; the decode A2A (EP) axis
  model  — the "scale-up domain": TP / sequence-parallel activations /
           train+prefill EP axis / decode KV-sequence sharding

Attention modes:
  head_tp    — q heads sharded over `model` (requires heads % tp == 0 and
               16 % kv_heads == 0 so each rank needs exactly one KV head),
               K/V weights replicated (small), Megatron-SP AG/RS schedule.
  replicated — attention weights replicated (only small archs), tokens stay
               sequence-sharded, K/V all-gathered for the core.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple, Union

from repro.configs.base import ModelConfig, ShapeCell

AxesEntry = Union[str, Tuple[str, ...], None]

VOCAB_PAD = 256


def pad_to(n: int, mult: int) -> int:
    return (n + mult - 1) // mult * mult


@dataclass(frozen=True)
class ShardingPlan:
    mesh_axes: Tuple[str, ...]                 # ("data","model") | ("pod","data","model")
    mesh_shape: Tuple[int, ...]
    batch_axes: Optional[Tuple[str, ...]]      # batch sharding (None = replicated)
    seq_axis: Optional[str]                    # activation seq sharding (train/prefill)
    tp_axis: Optional[str]                     # tensor parallel axis
    ep_axis: Optional[str]                     # MoE all-to-all axis
    kv_axis: Optional[str]                     # decode KV-cache sequence sharding
    attn_mode: str                             # head_tp | replicated
    fsdp_axis: Optional[str]                   # training-only param sharding
    vocab_axis: Optional[AxesEntry]
    kind: str                                  # train | prefill | decode
    # decode-only: dense-FFN weights sharded over (data x model) with the
    # (cheap) decode tokens all-gathered over data — 16x less weight
    # streaming per device per step (EXPERIMENTS.md §Perf iteration 2)
    ffn_2d: bool = False
    # train/prefill: ring attention instead of Megatron-SP all-gather —
    # KV chunks rotate via collective_permute (EXPERIMENTS.md §Perf it. 3)
    ring_attn: bool = False
    # fp8(e4m3) wire format for the FFN sequence all-gather (§Perf it. 4)
    ag_fp8: bool = False
    # fp8 MoE dispatch A2A (bf16 combine) — DeepSeek-V3's production wire
    # format for the paper's central traffic (§Perf iteration 5)
    a2a_fp8: bool = False

    @property
    def ffn_axes(self):
        """Mesh axes the dense-FFN hidden dim is sharded over."""
        if self.ffn_2d:
            return ("data", "model")
        return self.tp_axis

    def axis_size(self, axis) -> int:
        if axis is None:
            return 1
        if isinstance(axis, tuple):
            n = 1
            for a in axis:
                n *= self.axis_size(a)
            return n
        return self.mesh_shape[self.mesh_axes.index(axis)]

    @property
    def tp(self) -> int:
        return self.axis_size(self.tp_axis)

    @property
    def ep(self) -> int:
        return self.axis_size(self.ep_axis)

    @property
    def dp(self) -> int:
        return self.axis_size(self.batch_axes) if self.batch_axes else 1


def head_tp_ok(cfg: ModelConfig, tp: int) -> bool:
    """Head-TP requires q heads divisible by tp and each rank's q-head group
    to map onto exactly one KV head (see DESIGN.md section 4)."""
    if not cfg.has_attention or cfg.attn_kind == "mla":
        return False
    if cfg.num_heads % tp != 0:
        return False
    h_loc = cfg.num_heads // tp
    g = cfg.num_heads // cfg.num_kv_heads      # q heads per kv head
    return g % h_loc == 0 or h_loc % g == 0 and cfg.num_kv_heads % tp == 0


def make_plan(cfg: ModelConfig, shape: ShapeCell,
              mesh_axes: Tuple[str, ...], mesh_shape: Tuple[int, ...],
              *, fsdp: bool = True, ffn_2d: bool = False,
              ring_attn: bool = False, ag_fp8: bool = False,
              a2a_fp8: bool = False) -> ShardingPlan:
    axes = dict(zip(mesh_axes, mesh_shape))
    tp = axes["model"]
    dp_axes = tuple(a for a in mesh_axes if a in ("pod", "data"))
    dp = 1
    for a in dp_axes:
        dp *= axes[a]

    attn_mode = "head_tp" if head_tp_ok(cfg, tp) else "replicated"

    if shape.kind in ("train", "prefill"):
        batch_axes = dp_axes if shape.global_batch % dp == 0 else None
        return ShardingPlan(
            mesh_axes=mesh_axes, mesh_shape=mesh_shape,
            batch_axes=batch_axes,
            seq_axis="model",
            tp_axis="model",
            ep_axis="model" if cfg.moe else None,
            kv_axis="model",          # prefill writes a seq-sharded cache
            attn_mode=attn_mode,
            fsdp_axis="data" if (fsdp and shape.kind == "train") else None,
            vocab_axis="model",
            kind=shape.kind,
            ring_attn=ring_attn,
            ag_fp8=ag_fp8,
            a2a_fp8=a2a_fp8,
        )

    # decode: batch over DP axes; KV sequence over model; EP A2A over data.
    batch_axes = dp_axes if shape.global_batch % dp == 0 else None
    ep_axis = None
    if cfg.moe:
        # faithful A2A path when tokens are batch-sharded; degenerate
        # replicated-token fallback (B=1 long-context) routes over model.
        ep_axis = "data" if (batch_axes and "data" in batch_axes) else "model"
    # ffn_2d requires tokens batch-sharded over data and d_ff/vocab
    # divisible by the full (data x model) product
    use_2d = (ffn_2d and batch_axes and "data" in batch_axes
              and cfg.d_ff % (axes.get("data", 1) * tp) == 0)
    return ShardingPlan(
        mesh_axes=mesh_axes, mesh_shape=mesh_shape,
        batch_axes=batch_axes,
        seq_axis=None,
        tp_axis="model",
        ep_axis=ep_axis,
        kv_axis="model",
        attn_mode=attn_mode,
        fsdp_axis=None,
        vocab_axis="model",
        kind="decode",
        ffn_2d=bool(use_2d),
        a2a_fp8=a2a_fp8,
    )


def null_plan(kind: str = "train") -> ShardingPlan:
    """Single-device plan (smoke tests, CPU serving example)."""
    return ShardingPlan(
        mesh_axes=(), mesh_shape=(), batch_axes=None, seq_axis=None,
        tp_axis=None, ep_axis=None, kv_axis=None, attn_mode="replicated",
        fsdp_axis=None, vocab_axis=None, kind=kind)
