"""Sharded checkpointing with elastic resharding.

Save layout (one directory per step):

  ckpt_dir/step_000042/
    manifest.json                 {step, keys, shards-per-key, shapes, dtypes}
    <key>.shard00.npy ...         leaf split into K shard files along its
                                  largest dim (K = save-mesh axis size), so
                                  per-host files stay bounded at scale

Restore is *elastic*: shard files are reassembled to the global array and
re-laid-out for whatever mesh/sharding the restoring job uses — the mesh
shape is config, not checkpoint format. Tested: save under a (4, 2) layout,
restore under (2, 2) and single-device.

Atomicity: writes go to `<dir>.tmp` then os.rename (POSIX-atomic), so a
failure mid-save never corrupts the latest checkpoint. `latest_step` scans
completed directories only.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

# dtypes numpy can't serialize natively -> (wire view dtype, logical dtype)
_EXOTIC = {
    "bfloat16": (np.uint16, ml_dtypes.bfloat16),
    "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn),
    "float8_e5m2": (np.uint8, ml_dtypes.float8_e5m2),
}


def _save_arr(path: str, arr: np.ndarray):
    if arr.dtype.name in _EXOTIC:
        arr = arr.view(_EXOTIC[arr.dtype.name][0])
    np.save(path, arr)


def _load_arr(path: str, dtype_name: str) -> np.ndarray:
    arr = np.load(path)
    if dtype_name in _EXOTIC:
        arr = arr.view(_EXOTIC[dtype_name][1])
    return arr


def _flat(tree) -> Dict[str, Any]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        out[key] = leaf
    return out


def save(tree, ckpt_dir: str, step: int, *, n_shards: int = 1) -> str:
    """Write `tree` (params/opt state pytree of arrays) for `step`."""
    final = os.path.join(ckpt_dir, f"step_{step:06d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    manifest = {"step": step, "keys": {}}
    for key, leaf in _flat(tree).items():
        arr = np.asarray(leaf)
        fname = key.replace("/", ".")
        axis = int(np.argmax(arr.shape)) if arr.ndim else 0
        k = n_shards if (arr.ndim and arr.shape[axis] % n_shards == 0) else 1
        manifest["keys"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "shards": k, "axis": axis,
        }
        if k == 1:
            _save_arr(os.path.join(tmp, f"{fname}.shard00.npy"), arr)
        else:
            for i, piece in enumerate(np.split(arr, k, axis=axis)):
                _save_arr(os.path.join(tmp, f"{fname}.shard{i:02d}.npy"),
                          piece)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))]
    return max(steps) if steps else None


def restore(like_tree, ckpt_dir: str, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, int]:
    """Restore into the structure of `like_tree` (a pytree of arrays or
    ShapeDtypeStructs). `shardings`: optional matching pytree of
    jax.sharding.Sharding for elastic re-layout onto the restoring mesh."""
    if step is None:
        step = latest_step(ckpt_dir)
        assert step is not None, f"no checkpoints under {ckpt_dir}"
    d = os.path.join(ckpt_dir, f"step_{step:06d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    flat_like = _flat(like_tree)
    flat_shard = _flat(shardings) if shardings is not None else {}
    loaded = {}
    for key, meta in manifest["keys"].items():
        assert key in flat_like, f"checkpoint key {key!r} not in target tree"
        pieces = [_load_arr(os.path.join(d,
                                         f"{meta['file']}.shard{i:02d}.npy"),
                            meta["dtype"])
                  for i in range(meta["shards"])]
        arr = pieces[0] if len(pieces) == 1 else np.concatenate(
            pieces, axis=meta["axis"])
        want = flat_like[key]
        assert tuple(arr.shape) == tuple(want.shape), (key, arr.shape,
                                                       want.shape)
        arr = arr.astype(want.dtype)
        if key in flat_shard and flat_shard[key] is not None:
            loaded[key] = jax.device_put(arr, flat_shard[key])
        else:
            loaded[key] = jax.numpy.asarray(arr)

    # rebuild the pytree in like_tree's structure
    treedef = jax.tree_util.tree_structure(like_tree)
    keys_in_order = list(_flat(like_tree).keys())
    missing = [k for k in keys_in_order if k not in loaded]
    assert not missing, f"checkpoint missing keys: {missing[:5]}"
    return treedef.unflatten([loaded[k] for k in keys_in_order]), step


def prune_old(ckpt_dir: str, keep: int = 3):
    """Remove all but the newest `keep` checkpoints."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:06d}"),
                      ignore_errors=True)
