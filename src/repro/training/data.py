"""Deterministic synthetic LM data pipeline.

Production properties the trainer relies on:
  * deterministic & seekable — batch(step) is a pure function of
    (seed, step), so resume-after-failure re-produces the exact stream
    without replaying it;
  * host-shardable — each data-parallel rank draws only its slice;
  * straggler mitigation — `DeadlineIterator` drops batches whose
    producer missed a deadline (skipped steps are logged, training
    continues on the next batch — the standard large-fleet policy of
    trading samples for synchrony).

The token stream is a mixture of repeated n-gram motifs over the vocab so
the LM loss decreases measurably within a few hundred steps (used by
examples/train_lm.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Iterator, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_motifs: int = 64            # distinct repeated patterns
    motif_len: int = 16


class SyntheticLM:
    """batch(step) -> tokens [global_batch, seq_len] int32 (deterministic)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self._motifs = rng.integers(
            0, cfg.vocab_size, (cfg.n_motifs, cfg.motif_len), dtype=np.int32)

    def batch(self, step: int, *, rank: int = 0, world: int = 1) -> np.ndarray:
        cfg = self.cfg
        assert cfg.global_batch % world == 0
        b_loc = cfg.global_batch // world
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, rank]))
        n_tiles = -(-cfg.seq_len // cfg.motif_len)
        ids = rng.integers(0, cfg.n_motifs, (b_loc, n_tiles))
        toks = self._motifs[ids].reshape(b_loc, -1)[:, :cfg.seq_len]
        # light noise keeps the task from being trivially memorized
        noise = rng.random((b_loc, cfg.seq_len)) < 0.02
        toks = np.where(noise,
                        rng.integers(0, cfg.vocab_size, toks.shape), toks)
        return toks.astype(np.int32)

    def __iter__(self) -> Iterator[np.ndarray]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class DeadlineIterator:
    """Wrap a (step -> batch) source with a per-batch deadline; a miss skips
    the batch (straggler mitigation). `clock`/`produce_time` are injectable
    for tests."""

    def __init__(self, source: SyntheticLM, deadline_s: float,
                 produce: Optional[Callable[[int], Tuple[np.ndarray, float]]] = None):
        self.source = source
        self.deadline_s = deadline_s
        self._produce = produce
        self.skipped = []

    def batch(self, step: int, **kw) -> Optional[np.ndarray]:
        if self._produce is not None:
            data, elapsed = self._produce(step)
        else:
            t0 = time.monotonic()
            data = self.source.batch(step, **kw)
            elapsed = time.monotonic() - t0
        if elapsed > self.deadline_s:
            self.skipped.append(step)
            return None
        return data
