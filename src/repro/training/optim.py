"""Minimal sharded AdamW (f32 moments over possibly-bf16 params).

Moments carry the same sharding specs as their parameters, so the optimizer
update is fully local on every rank; gradient reduction happens before
(see launch.steps.reduce_grads).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


def init_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def state_specs(param_specs):
    return AdamWState(step=P(), m=param_specs,
                      v=jax.tree.map(lambda s: s, param_specs,
                                     is_leaf=lambda s: isinstance(s, P)))


def update(params, grads, state: AdamWState, *, lr=3e-4, b1=0.9, b2=0.95,
           eps=1e-8, weight_decay=0.1):
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    m_new = jax.tree.map(
        lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
        state.m, grads)
    v_new = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state.v, grads)

    def upd(p, m, v):
        delta = (m / bc1) / (jnp.sqrt(v / bc2) + eps) \
            + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    params_new = jax.tree.map(upd, params, m_new, v_new)
    return params_new, AdamWState(step=step, m=m_new, v=v_new)
