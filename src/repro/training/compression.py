"""Gradient compression for bandwidth-scarce mesh axes.

The paper's cost analysis says cross-pod links are exactly where bandwidth
is expensive; int8 quantized all-reduce with error feedback cuts that
traffic 4x (bf16 -> int8 wire format, psum in int32 to avoid overflow up to
2^23 summands).

Error feedback (Seide et al. / EF-SGD): each rank keeps a residual of what
quantization dropped and adds it back before the next quantize — unbiased
in the long run, standard convergence behaviour.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.dist import Dist


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum(g: jnp.ndarray, axis, dist: Dist,
                    err: Optional[jnp.ndarray] = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """int8 all-reduce of `g` over mesh axis `axis` with error feedback.

    Returns (summed gradient f32, new error-feedback residual).
    The wire carries int8 payload (4x less than f32; 2x less than bf16) —
    the psum itself runs in int32 for exact integer accumulation.
    """
    gf = g.astype(jnp.float32)
    if err is not None:
        gf = gf + err
    q, scale = quantize_int8(gf)
    new_err = gf - dequantize_int8(q, scale)
    # scales differ per rank: psum the dequantized *integer* payload per-rank
    # scale. Exact formulation: sum_r q_r * s_r. We psum (q, q*0+s) pairs:
    # int32 sum of q weighted by its own scale needs the scale alongside;
    # cheapest faithful form: psum(q * s) would be f32 again — instead use a
    # SHARED scale: pmax of per-rank scales, requantize, then int32-psum.
    s_shared = dist.pmax(scale, axis)
    q_shared = jnp.clip(jnp.round(gf / s_shared), -127, 127)
    new_err = gf - q_shared * s_shared
    total = dist.psum(q_shared.astype(jnp.int32), axis)
    return total.astype(jnp.float32) * s_shared, new_err.astype(g.dtype)


def init_error_state(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
