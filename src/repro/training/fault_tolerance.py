"""Failure handling: checkpoint-backed recovery loop + failure injection.

Large-fleet training treats worker failure as routine: detect at a step
boundary, restore the last atomic checkpoint, resume the (deterministic,
seekable) data stream at the restored step. This module provides:

  * WorkerFailure / FailureInjector — re-exported from the shared
    `repro.faults` seam (the serving-side fault sweeps draw from the
    same machinery; see also `repro.faults.sample_faultset`);
  * run_with_recovery — the driver loop: catches failures mid-run,
    restores, and continues until the target step, bounded by
    `max_restarts` (a crash-looping job must page a human, not spin).

Straggler policy lives in training/data.py (DeadlineIterator): a slow
batch producer is skipped, not waited for. Hardware-level straggler
mitigation on a real fleet adds per-step all-reduce deadlines; the decision
logic is the same and is exercised here through the injector.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from repro.faults import FailureInjector, WorkerFailure
from repro.training.data import SyntheticLM
from repro.training.train_loop import Trainer

__all__ = ["WorkerFailure", "FailureInjector", "RecoveryReport",
           "run_with_recovery"]


@dataclass
class RecoveryReport:
    restarts: int
    completed_steps: int
    losses: List[float]
    recovery_log: List[str]


def run_with_recovery(trainer: Trainer, data: SyntheticLM, n_steps: int, *,
                      injector: Optional[FailureInjector] = None,
                      max_restarts: int = 5) -> RecoveryReport:
    """Drive training to `n_steps`, recovering from WorkerFailure by
    restoring the latest checkpoint. Requires trainer.tc.ckpt_every > 0."""
    assert trainer.tc.ckpt_every > 0 and trainer.tc.ckpt_dir, \
        "recovery needs periodic checkpoints"
    restarts = 0
    log: List[str] = []
    # initial checkpoint so step-0 failures are recoverable
    trainer.save()
    while trainer.step_idx < n_steps:
        try:
            tokens = data.batch(trainer.step_idx)
            if injector is not None:
                injector.check(trainer.step_idx)
            trainer.train_step(tokens)
        except WorkerFailure as e:
            restarts += 1
            if restarts > max_restarts:
                raise RuntimeError(
                    f"exceeded {max_restarts} restarts; aborting") from e
            at = trainer.restore()
            log.append(f"{e} -> restored step {at} (restart {restarts})")
    return RecoveryReport(restarts=restarts, completed_steps=trainer.step_idx,
                          losses=trainer.losses, recovery_log=log)
