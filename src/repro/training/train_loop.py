"""Training driver: init -> (accumulate microbatches -> update) -> log /
checkpoint -> resume. Works single-device (NullDist) and under shard_map on
a mesh (launch.steps builds the production-mesh step; this loop is the
driver around either).

Fault-tolerance contract (training/fault_tolerance.py drives it):
  * checkpoints are atomic (checkpoint.py) and carried with the data step
    counter, so a restart resumes the exact stream position;
  * the step function is pure (params, opt, batch) -> (params, opt, loss):
    a failed step leaves no partial state.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.sharding.dist import Dist, NullDist
from repro.sharding.plans import ShardingPlan, null_plan
from repro.training import checkpoint as ckpt
from repro.training import compression, optim
from repro.training.data import SyntheticLM


@dataclass
class TrainConfig:
    lr: float = 3e-4
    microbatches: int = 1          # gradient accumulation factor
    remat: bool = False
    grad_compress: bool = False    # int8 + error feedback on reduction axes
    log_every: int = 10
    ckpt_every: int = 0            # 0 = off
    ckpt_dir: str = ""
    ckpt_keep: int = 3
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, tc: TrainConfig, *,
                 plan: Optional[ShardingPlan] = None,
                 dist: Optional[Dist] = None):
        self.cfg = cfg
        self.tc = tc
        self.plan = plan or null_plan("train")
        self.dist = dist or NullDist()
        key = jax.random.PRNGKey(tc.seed)
        self.params, self.pspecs = M.init_model(cfg, self.plan, key)
        self.opt_state = optim.init_state(self.params)
        self.err_state = (compression.init_error_state(self.params)
                          if tc.grad_compress else None)
        self.step_idx = 0
        self.losses: List[float] = []
        self._step = jax.jit(self._build_step(), donate_argnums=(0, 1, 3))

    # ------------------------------------------------------------------

    def _build_step(self):
        cfg, tc, plan, dist = self.cfg, self.tc, self.plan, self.dist

        def loss_fn(p, batch):
            return M.train_loss(p, batch, cfg, plan, dist, remat=tc.remat)

        def reduce(g, err):
            """Reduce grads over replicated axes; int8-compress the psum on
            the slowest axis (pod > data) when enabled."""
            axes = [a for a in plan.mesh_axes if a in ("pod", "data")]
            if not axes:
                return g, err
            if not tc.grad_compress:
                for a in axes:
                    g = jax.tree.map(lambda x: dist.psum(x, a), g)
                return g, err
            slow = axes[0]
            fast = axes[1:]
            for a in fast:
                g = jax.tree.map(lambda x: dist.psum(x, a), g)
            pairs = jax.tree.map(
                lambda x, e: compression.compressed_psum(x, slow, dist, e),
                g, err)
            g = jax.tree.map(lambda pr: pr[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
            err = jax.tree.map(lambda pr: pr[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
            return g, err

        def step(params, opt_state, batch, err_state):
            """batch tokens: [mb, B/mb, S] — scan accumulates microbatch
            grads (the microbatch A2A/AR of step i overlaps step i+1's
            compute under XLA's scheduler)."""
            def one(acc, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (jax.tree.map(jnp.add, acc[0], g), acc[1] + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(one, (zeros, 0.0), batch)
            n = batch["tokens"].shape[0]
            grads = jax.tree.map(lambda g: g / n, gsum)
            grads, err_state = reduce(grads, err_state)
            params, opt_state = optim.update(params, grads, opt_state,
                                             lr=tc.lr)
            return params, opt_state, lsum / n, err_state

        return step

    # ------------------------------------------------------------------

    def _shape_batch(self, tokens: np.ndarray) -> Dict[str, jnp.ndarray]:
        mb = self.tc.microbatches
        B, S = tokens.shape
        assert B % mb == 0, (B, mb)
        batch = {"tokens": jnp.asarray(tokens).reshape(mb, B // mb, S)}
        if self.cfg.frontend == "vit_patches":
            batch["patches"] = jnp.zeros(
                (mb, B // mb, self.cfg.n_frontend_tokens, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        if self.cfg.frontend == "audio_frames":
            batch["frames"] = jnp.zeros(
                (mb, B // mb, S, self.cfg.d_model), jnp.dtype(self.cfg.dtype))
        return batch

    def train_step(self, tokens: np.ndarray) -> float:
        batch = self._shape_batch(tokens)
        self.params, self.opt_state, loss, self.err_state = self._step(
            self.params, self.opt_state, batch, self.err_state)
        self.step_idx += 1
        loss = float(loss)
        self.losses.append(loss)
        if self.tc.ckpt_every and self.step_idx % self.tc.ckpt_every == 0:
            self.save()
        return loss

    # ------------------------------------------------------------------
    # checkpoint / resume
    # ------------------------------------------------------------------

    def _state_tree(self):
        return {"params": self.params, "opt": self.opt_state}

    def save(self):
        assert self.tc.ckpt_dir, "ckpt_dir not configured"
        ckpt.save(self._state_tree(), self.tc.ckpt_dir, self.step_idx)
        ckpt.prune_old(self.tc.ckpt_dir, self.tc.ckpt_keep)

    def restore(self, step: Optional[int] = None) -> int:
        state, at = ckpt.restore(self._state_tree(), self.tc.ckpt_dir, step)
        self.params, self.opt_state = state["params"], state["opt"]
        self.step_idx = at
        return at

    def run(self, data: SyntheticLM, n_steps: int, *,
            log: Callable[[str], None] = print) -> List[float]:
        t0 = time.time()
        while self.step_idx < n_steps:
            tokens = data.batch(self.step_idx)
            loss = self.train_step(tokens)
            if self.tc.log_every and self.step_idx % self.tc.log_every == 0:
                dt = time.time() - t0
                log(f"step {self.step_idx:5d} loss {loss:.4f} "
                    f"({dt / max(self.step_idx, 1):.2f}s/step)")
        return self.losses
