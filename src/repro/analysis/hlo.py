"""Parse collective traffic out of lowered/compiled HLO text.

The roofline's collective term needs bytes moved by all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute; cost_analysis() does not
report it, so we sum operand sizes of every collective op in the module.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# opcode position: " all-gather(" / " all-to-all-start(" — NOT the SSA value
# name (%all-to-all = ...), hence the required leading whitespace
_COLL_OP_RE = re.compile(
    r"\s(" + "|".join(_COLLECTIVES) + r")(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _nbytes(dtype: str, dims: str) -> int:
    n = _DTYPE_BYTES.get(dtype, 4)
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


# Any op definition: %name = dtype[dims]{layout} opcode(...operands...)
_DEF_RE = re.compile(
    r"%([\w.\-]+)\s*=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\]")
_OPCODE_RE = re.compile(
    r"%[\w.\-]+\s*=\s*(?:\()?[a-z0-9]+\[[0-9,]*\][^\s]*\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def parse_shapes(hlo_text: str) -> Dict[str, int]:
    """name -> result nbytes for every op definition in the module."""
    sizes: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.search(line)
        if m:
            name, dtype, dims = m.groups()
            sizes[name] = _nbytes(dtype, dims)
    return sizes


def op_bytes_profile(hlo_text: str, top: int = 15) -> Dict[str, float]:
    """Aggregate (result + operand) bytes per opcode — the dry-run
    'profiler' for the perf loop. Fusions count their result + operands
    (what crosses HBM), matching HloCostAnalysis' fusion treatment."""
    sizes = parse_shapes(hlo_text)
    agg: Dict[str, float] = defaultdict(float)
    for line in hlo_text.splitlines():
        mo = _OPCODE_RE.search(line)
        md = _DEF_RE.search(line)
        if not mo or not md:
            continue
        opcode = mo.group(1)
        name = md.group(1)
        total = sizes.get(name, 0)
        args = line.split("(", 1)[1] if "(" in line else ""
        for om in _OPERAND_RE.finditer(args.split("metadata=")[0]):
            total += sizes.get(om.group(1), 0)
        agg[opcode] += total
    out = dict(sorted(agg.items(), key=lambda kv: -kv[1])[:top])
    out["_total"] = sum(agg.values())
    return out


def dus_overcount_bytes(hlo_text: str) -> float:
    """XLA's HloCostAnalysis charges a dynamic-update-slice for reading AND
    writing the FULL target buffer; the compiled program updates in place
    (only the slice moves). Returns the bytes to subtract from
    `bytes accessed` to get in-place-accurate traffic:

        sum over DUS of 2*(target_size - update_size)

    Without this, a decode step that writes one token into a multi-GB KV
    cache is charged the whole cache per layer — a >20x distortion of the
    memory roofline term.
    """
    sizes = parse_shapes(hlo_text)
    over = 0.0
    for line in hlo_text.splitlines():
        if "dynamic-update-slice(" not in line:
            continue
        md = _DEF_RE.search(line)
        if not md:
            continue
        target = _nbytes(md.group(2), md.group(3))
        args = line.split("dynamic-update-slice(", 1)[1]
        operands = _OPERAND_RE.findall(args.split("metadata=")[0])
        if len(operands) < 2:
            continue
        update = sizes.get(operands[1], 0)
        over += 2.0 * max(target - update, 0)
    return over


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes per collective kind (per device, since post-
    partitioning HLO shapes are per-device local shapes). Tuple results
    (e.g. a 16-way all-to-all returns 16 shards) sum every element.
    *-done ops are skipped so async pairs aren't double counted."""
    out: Dict[str, float] = defaultdict(float)
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        if "-done(" in line or "=" not in line:
            continue
        m = _COLL_OP_RE.search(line)
        if not m or m.start() < line.find("="):
            continue
        kind = m.group(1)
        # every dtype[dims] between the '=' and the opcode is a result
        # (tuple) element; operands live after the opcode's '(' and are
        # excluded by slicing the line at the opcode.
        lhs = line[line.find("=") + 1: m.start()]
        nb = sum(_nbytes(d, dims) for d, dims in _SHAPE_RE.findall(lhs))
        if nb == 0:
            continue
        out[kind] += nb
        counts[kind] += 1
    res = {f"{k}_bytes": v for k, v in out.items()}
    res.update({f"{k}_count": counts[k] for k in counts})
    res["total_bytes"] = sum(out.values())
    return dict(res)
