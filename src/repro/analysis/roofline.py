"""Three-term roofline analysis from compiled dry-run artifacts.

Per (arch x shape x mesh) cell:

  compute term    = per-device HLO FLOPs / peak FLOP/s
  memory term     = per-device HLO bytes / HBM bandwidth
  collective term = per-device collective bytes / ICI link bandwidth

(cost_analysis() reports the PER-DEVICE partitioned program, so no division
by chip count; verified empirically in benchmarks/roofline.py docstring.)

Target hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.

MODEL_FLOPS references:
  train   6 * N * tokens          (fwd+bwd, dense counting)
  decode  2 * N_active * tokens   (one token per sequence)
  prefill 2 * N_active * tokens
The HLO/MODEL ratio flags remat recompute and redundant work; quadratic
attention FLOPs legitimately push it above 1 at long context.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from repro.configs import SHAPES, get_arch

PEAK_FLOPS = 197e12           # bf16 / chip
HBM_BW = 819e9                # B/s / chip
LINK_BW = 50e9                # B/s / ICI link


@dataclass(frozen=True)
class Roofline:
    arch: str
    shape: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_per_dev: float
    hlo_flops_per_dev: float

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline-optimistic step time: terms overlap perfectly, so the
        max dominates."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return (self.model_flops_per_dev / self.hlo_flops_per_dev
                if self.hlo_flops_per_dev else 0.0)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the step spent on the USEFUL compute roofline:
        (model flops / peak) / step_time — the MFU the compiled program
        would achieve if every term ran at its hardware limit."""
        t_use = self.model_flops_per_dev / PEAK_FLOPS
        return t_use / self.step_time_s if self.step_time_s else 0.0


def model_flops_per_device(arch: str, shape_name: str, n_devices: int
                           ) -> float:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * cfg.active_param_count() * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * cfg.active_param_count() * tokens
    else:  # decode: one token per sequence
        total = 2.0 * cfg.active_param_count() * shape.global_batch
    return total / n_devices


def from_dryrun(res: Dict) -> Optional[Roofline]:
    """Build a Roofline from one dryrun.run_cell result dict.

    Uses the in-place-corrected byte count when present (XLA charges
    dynamic-update-slice for the whole target buffer; the compiled program
    updates KV caches in place — see analysis.hlo.dus_overcount_bytes)."""
    if res.get("status") != "ok":
        return None
    coll = res.get("collectives", {}).get("total_bytes", 0.0)
    nbytes = res.get("bytes_accessed_inplace", res["bytes_accessed"])
    return Roofline(
        arch=res["arch"], shape=res["shape"],
        compute_s=res["flops"] / PEAK_FLOPS,
        memory_s=nbytes / HBM_BW,
        collective_s=coll / LINK_BW,
        model_flops_per_dev=model_flops_per_device(
            res["arch"], res["shape"], res["n_devices"]),
        hlo_flops_per_dev=res["flops"],
    )


def what_would_help(r: Roofline) -> str:
    """One-sentence suggestion for the dominant term (EXPERIMENTS.md)."""
    b = r.bottleneck
    if b == "collective":
        return ("reduce collective volume: shrink FSDP all-gather via "
                "better param placement, fuse AG/RS pairs, or move traffic "
                "to a wider mesh axis")
    if b == "memory":
        return ("cut HBM traffic: larger fused blocks (Pallas), fewer "
                "remat recomputes, bf16->fp8 weights, better KV layout")
    return ("raise MXU utilization: bigger per-device tiles (less "
            "sharding on the contracted dim), fewer small ops, avoid "
            "padding waste")
