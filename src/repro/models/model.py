"""Model API: init / train loss / prefill / decode + spec builders.

Every step function is written in manual-SPMD style against a ``Dist``; the
launch layer wraps them in shard_map (real mesh) or calls them directly
(NullDist, single device).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import transformer as tf
from repro.models.layers import attention as attn_mod
from repro.models.layers import common
from repro.sharding.dist import Dist, NullDist
from repro.sharding.plans import ShardingPlan


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_model(cfg: ModelConfig, plan: ShardingPlan, key):
    k_embed, k_stack, k_enc, k_norm = jax.random.split(key, 4)
    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    params["embed"], specs["embed"] = common.init_embedding(cfg, plan, k_embed)
    params["stack"], specs["stack"] = tf.init_stack(
        cfg, plan, k_stack, cross=cfg.is_encoder_decoder)
    params["final_norm"], specs["final_norm"] = common.init_rms_norm(
        cfg.d_model, jnp.float32)
    if cfg.is_encoder_decoder:
        from repro.configs.base import LayerSpec
        enc_period = (LayerSpec(mixer="attn", ffn="dense"),)
        params["encoder"], specs["encoder"] = tf.init_stack(
            cfg, plan, k_enc, cross=False, n_layers=cfg.encoder_layers,
            period=enc_period)
        params["enc_norm"], specs["enc_norm"] = common.init_rms_norm(
            cfg.d_model, jnp.float32)
    # FSDP over non-stack leaves (stack leaves handled in init_layer)
    for k in ("embed", "final_norm", "enc_norm"):
        if k in params:
            specs[k] = jax.tree.map(
                lambda p, s: common.fsdp_spec(p.shape, s, plan),
                params[k], specs[k])
    return params, specs


# ---------------------------------------------------------------------------
# shared forward pieces
# ---------------------------------------------------------------------------

def _embed_inputs(params, batch, cfg, plan, dist):
    """Returns x [B, S_loc, D] from tokens (+ frontend stub embeddings)."""
    x = common.embed(params["embed"], batch["tokens"], cfg, plan, dist)
    if cfg.frontend == "vit_patches" and "patches" in batch:
        # overwrite the first n_frontend_tokens global positions with the
        # precomputed patch embeddings (replicated [B, Pf, D] input).
        patches = batch["patches"]
        B, s_loc, d = x.shape
        pf = patches.shape[1]
        r = dist.index(plan.seq_axis)
        start = r * s_loc
        padded = jnp.pad(patches, ((0, 0), (0, s_loc), (0, 0)))
        window = jax.lax.dynamic_slice(
            padded, (0, jnp.minimum(start, pf), 0), (B, s_loc, d))
        gpos = start + jnp.arange(s_loc)
        x = jnp.where((gpos < pf)[None, :, None], window.astype(x.dtype), x)
    return x


def _encode(params, frames, cfg, plan, dist, param_specs=None):
    """Audio/encoder stub path: frames [B, Se_loc, D] are already embedded."""
    from repro.configs.base import LayerSpec
    enc_period = (LayerSpec(mixer="attn", ffn="dense"),)
    x, _, _ = tf.apply_stack(
        params["encoder"], frames.astype(jnp.dtype(cfg.dtype)), cfg, plan,
        dist, mode="train", period=enc_period, n_layers=cfg.encoder_layers,
        param_specs=(param_specs or {}).get("encoder"))
    return common.rms_norm(x, params["enc_norm"]["scale"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# train forward (loss)
# ---------------------------------------------------------------------------

def train_loss(params, batch, cfg: ModelConfig, plan: ShardingPlan,
               dist: Dist, *, remat: bool = True, param_specs=None,
               unroll: bool = False):
    """batch: tokens [B, S_loc] (+ patches/frames). Global-mean LM loss."""
    if param_specs is not None and plan.fsdp_axis is not None:
        params = dict(params)
        for k in ("embed", "final_norm", "enc_norm"):
            if k in params:
                params[k] = common.fsdp_gather(params[k], param_specs[k],
                                               plan, dist)
    x = _embed_inputs(params, batch, cfg, plan, dist)
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(params, batch["frames"], cfg, plan, dist,
                          param_specs=param_specs)
    x, _, aux = tf.apply_stack(params["stack"], x, cfg, plan, dist,
                               mode="train", collect_aux=True, remat=remat,
                               enc_out=enc_out, unroll=unroll,
                               param_specs=(param_specs or {}).get("stack"))
    x = common.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = common.lm_logits(params["embed"], x, cfg, plan, dist)

    tokens = batch["tokens"]
    B, s_loc = tokens.shape
    seq_ax = plan.seq_axis
    n_seq = dist.size(seq_ax)
    # labels = next token; the first token of the next seq shard arrives by
    # ring shift (rank n-1 receives garbage — masked as the final position).
    nxt = dist.roll(tokens[:, :1], seq_ax, shift=-1) if n_seq > 1 \
        else jnp.zeros_like(tokens[:, :1])
    labels = jnp.concatenate([tokens[:, 1:], nxt], axis=1)
    r = dist.index(seq_ax)
    gpos = r * s_loc + jnp.arange(s_loc)
    S = s_loc * n_seq
    w = (gpos < S - 1).astype(jnp.float32)[None, :]

    v_loc = logits.shape[-1]
    rv = dist.index(plan.vocab_axis)
    # max-subtraction is numerics only; its gradient path cancels exactly
    # (stop_gradient on the INPUT: pmax has no JVP rule, so it must see a
    # symbolic-zero tangent)
    m = dist.pmax(jax.lax.stop_gradient(jnp.max(logits, axis=-1)),
                  plan.vocab_axis)
    sumexp = dist.psum(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1),
                       plan.vocab_axis)
    local = labels - rv * v_loc
    ok = (local >= 0) & (local < v_loc)
    safe = jnp.clip(local, 0, v_loc - 1)
    picked = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    label_logit = dist.psum(jnp.where(ok, picked, 0.0), plan.vocab_axis)
    token_loss = (jnp.log(sumexp) + m - label_logit) * w

    # global mean over every token (batch axes x sequence axis)
    reduce_axes = tuple(a for a in ((plan.batch_axes or ()) + ((seq_ax,) if seq_ax else ())) if a)
    loss_sum = jnp.sum(token_loss)
    cnt = jnp.sum(jnp.broadcast_to(w, token_loss.shape))
    for ax in reduce_axes:
        loss_sum = dist.psum(loss_sum, ax)
        cnt = dist.psum(cnt, ax)
    loss = loss_sum / jnp.maximum(cnt, 1.0)
    if cfg.moe is not None:
        aux_mean = aux / max(cfg.num_layers, 1)
        for ax in reduce_axes:
            aux_mean = dist.psum(aux_mean, ax) / dist.size(ax)
        loss = loss + cfg.moe.router_aux_loss_coef * aux_mean
    return loss


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------

def prefill(params, batch, cfg: ModelConfig, plan: ShardingPlan, dist: Dist,
            *, unroll: bool = False):
    """Returns (next_token [B, 1], caches). Fills the KV/state caches."""
    x = _embed_inputs(params, batch, cfg, plan, dist)
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(params, batch["frames"], cfg, plan, dist)
    x, caches, _ = tf.apply_stack(params["stack"], x, cfg, plan, dist,
                                  mode="prefill", enc_out=enc_out,
                                  unroll=unroll)
    x = common.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    # next token comes from the LAST global position: last seq shard owns it
    seq_ax = plan.seq_axis
    n_seq = dist.size(seq_ax)
    last = x[:, -1:]
    if n_seq > 1:
        # broadcast the last rank's final hidden to every rank
        r = dist.index(seq_ax)
        contrib = jnp.where(r == n_seq - 1, last, jnp.zeros_like(last))
        last = dist.psum(contrib, seq_ax)
    logits = common.lm_logits(params["embed"], last, cfg, plan, dist)
    token = common.greedy_sample(logits, cfg, plan, dist)
    return token, caches


def decode_step(params, caches, tokens, pos, cfg: ModelConfig,
                plan: ShardingPlan, dist: Dist, *, enc_len: int = 0,
                unroll: bool = False):
    """One serving step: tokens [B, 1] -> (next token [B, 1], new caches).
    pos: scalar int32 position of `tokens` in the sequence."""
    x = common.embed(params["embed"], tokens, cfg, plan, dist)
    x, caches, _ = tf.apply_stack(params["stack"], x, cfg, plan, dist,
                                  mode="decode", caches=caches, pos=pos,
                                  enc_len=enc_len, unroll=unroll)
    x = common.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = common.lm_logits(params["embed"], x, cfg, plan, dist)
    token = common.greedy_sample(logits, cfg, plan, dist)
    return token, caches


# ---------------------------------------------------------------------------
# cache construction + specs
# ---------------------------------------------------------------------------

def _layer_cache(spec, cfg, plan: ShardingPlan, batch: int, seq: int,
                 enc_seq: int, *, cross: bool):
    """(zeros-pytree, pspec-pytree) for one layer's decode cache (GLOBAL
    shapes)."""
    dt = jnp.dtype(cfg.dtype)
    bax = plan.batch_axes
    kv_ax = plan.kv_axis
    tp = plan.tp_axis
    c, s = {}, {}
    if spec.mixer in ("attn", "attn_local"):
        if cfg.attn_kind == "mla":
            r, rp = cfg.mla_kv_lora_rank, cfg.mla_rope_head_dim
            c["mixer"] = {"c_kv": jnp.zeros((batch, seq, r), dt),
                          "k_rope": jnp.zeros((batch, seq, rp), dt)}
            s["mixer"] = {"c_kv": P(bax, None, None),
                          "k_rope": P(bax, None, None)}
        elif spec.mixer == "attn_local" and cfg.sliding_window:
            w = min(cfg.sliding_window, seq)
            kv, hd = cfg.num_kv_heads, cfg.head_dim
            c["mixer"] = {"k": jnp.zeros((batch, kv, w, hd), dt),
                          "v": jnp.zeros((batch, kv, w, hd), dt)}
            s["mixer"] = {"k": P(bax, None, None, None),
                          "v": P(bax, None, None, None)}
        else:
            kv, hd = cfg.num_kv_heads, cfg.head_dim
            c["mixer"] = {"k": jnp.zeros((batch, kv, seq, hd), dt),
                          "v": jnp.zeros((batch, kv, seq, hd), dt)}
            s["mixer"] = {"k": P(bax, None, kv_ax, None),
                          "v": P(bax, None, kv_ax, None)}
    elif spec.mixer == "mamba":
        mc = cfg.mamba
        di = mc.expand * cfg.d_model
        c["mixer"] = {"conv": jnp.zeros((batch, mc.d_conv - 1, di), dt),
                      "ssm": jnp.zeros((batch, di, mc.d_state), jnp.float32)}
        s["mixer"] = {"conv": P(bax, None, tp), "ssm": P(bax, tp, None)}
    elif spec.mixer == "rwkv":
        hd = cfg.rwkv.head_dim
        nh = cfg.d_model // hd
        c["mixer"] = {"wkv": jnp.zeros((batch, nh, hd, hd), jnp.float32),
                      "shift": jnp.zeros((batch, cfg.d_model), dt)}
        s["mixer"] = {"wkv": P(bax, tp, None, None), "shift": P(bax, None)}
        c["ffn"] = {"shift": jnp.zeros((batch, cfg.d_model), dt)}
        s["ffn"] = {"shift": P(bax, None)}
    if cross:
        kv, hd = cfg.num_kv_heads, cfg.head_dim
        c["cross"] = {"k": jnp.zeros((batch, kv, enc_seq, hd), dt),
                      "v": jnp.zeros((batch, kv, enc_seq, hd), dt)}
        s["cross"] = {"k": P(bax, None, kv_ax, None),
                      "v": P(bax, None, kv_ax, None)}
    return c, s


def init_cache(cfg: ModelConfig, plan: ShardingPlan, batch: int, seq: int,
               enc_seq: int = 0):
    """Zero-filled decode caches (GLOBAL shapes) + PartitionSpec tree."""
    period = cfg.period
    n_per = cfg.n_periods
    n_rem = cfg.n_remainder
    cross = cfg.is_encoder_decoder
    per_caches, per_specs = [], []
    for i, lspec in enumerate(period):
        c, s = _layer_cache(lspec, cfg, plan, batch, seq, enc_seq, cross=cross)
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_per,) + x.shape), c)
        per_caches.append(stacked)
        per_specs.append(jax.tree.map(
            lambda p: P(*((None,) + tuple(p))), s,
            is_leaf=lambda p: isinstance(p, P)))
    rem_c, rem_s = [], []
    for i in range(n_rem):
        c, s = _layer_cache(period[i], cfg, plan, batch, seq, enc_seq,
                            cross=cross)
        rem_c.append(c)
        rem_s.append(s)
    caches = {"periods": tuple(per_caches), "rem": tuple(rem_c)}
    specs = {"periods": tuple(per_specs), "rem": tuple(rem_s)}
    if n_per == 0:
        caches["periods"], specs["periods"] = (), ()
    return caches, specs
