"""Shared layer primitives: norms, RoPE, Megatron-SP dense FFN,
vocab-parallel embedding / cross-entropy.

All functions take the triple (plan, dist) and run identically under a real
shard_map (local shards) or NullDist (full arrays). Weight layout convention:
matmul weights are stored [in, out]; sharded dims noted per init fn.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding.dist import Dist
from repro.sharding.plans import ShardingPlan, pad_to, VOCAB_PAD


def dtype_of(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def init_rms_norm(d: int, dtype) -> Tuple[dict, dict]:
    return {"scale": jnp.zeros((d,), dtype)}, {"scale": P(None)}


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: [..., S] (broadcastable int32)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs   # [..., S, hd/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Megatron-SP dense FFN (SwiGLU)
#   train/prefill: tokens seq-sharded -> all-gather(seq) .. reduce-scatter(seq)
#   decode:        tokens replicated over tp -> partial matmul .. psum
# ---------------------------------------------------------------------------

def init_dense_ffn(cfg, plan: ShardingPlan, key, d_ff: Optional[int] = None):
    """Global shapes; shard_map in_specs slice the d_ff dim over tp.
    Gate/up stored separately so column-slicing stays head^Wdim-aligned."""
    d, dff = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    dt = dtype_of(cfg)
    params = {
        "w_gate": jax.random.normal(k1, (d, dff), dt) * (d ** -0.5),
        "w_up": jax.random.normal(k2, (d, dff), dt) * (d ** -0.5),
        "w_out": jax.random.normal(k3, (dff, d), dt) * (dff ** -0.5),
    }
    ax = plan.ffn_axes
    specs = {
        "w_gate": P(None, ax),
        "w_up": P(None, ax),
        "w_out": P(ax, None),
    }
    return params, specs


def fp8_all_gather(x, axis, dist: Dist, dim: int):
    """All-gather with an fp8(e4m3) wire format + per-row f32 scales
    (EXPERIMENTS.md Perf iteration 4). Halves collective bytes vs bf16 —
    and pins the wire width against XLA hoisting a widening convert ahead
    of the collective (observed: f32-width gathers on the CPU lowering).
    The dequantized result returns in x.dtype."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 448.0, 1.0)     # e4m3 max normal
    q = (xf / scale).astype(jnp.float8_e4m3fn)
    # gather the raw bytes: XLA promotes f8 collectives to f16 (observed)
    # and hoists widening converts ahead of collectives — a uint8 bitcast
    # pins the 1-byte wire format on every backend
    qb = jax.lax.bitcast_convert_type(q, jnp.uint8)
    qg = dist.all_gather(qb, axis, dim=dim)
    sg = dist.all_gather(scale, axis, dim=dim)
    qg = jax.lax.bitcast_convert_type(
        jax.lax.optimization_barrier(qg), jnp.float8_e4m3fn)
    return (qg.astype(jnp.float32) * sg).astype(x.dtype)


def dense_ffn(params, x, plan: ShardingPlan, dist: Dist):
    """x: [B, S_loc, D] (seq-sharded) or [B, T, D] (replicated over tp).

    Decode ffn_2d path (§Perf iteration 2): weights column-sharded over
    (data x model); the handful of decode tokens all-gathers over `data`
    (cheap: B*D bytes), every device computes with a 16x thinner weight
    shard, and the partial outputs reduce-scatter back to the batch shard.
    Trades ~B*D*2 collective bytes per layer for a (dp-1)/dp cut in FFN
    weight streaming — decode is weight-bound, so this wins whenever
    B*D << ffn_params/dp."""
    seq_sharded = plan.seq_axis is not None and dist.size(plan.seq_axis) > 1
    if seq_sharded:
        if plan.ag_fp8:
            x = fp8_all_gather(x, plan.seq_axis, dist, dim=1)
        else:
            x = dist.all_gather(x, plan.seq_axis, dim=1)
    ffn_2d = plan.ffn_2d and dist.size("data") > 1
    if ffn_2d:
        x = dist.all_gather(x, "data", dim=0)
    gate = jax.nn.silu((x @ params["w_gate"]).astype(jnp.float32)).astype(x.dtype)
    h = gate * (x @ params["w_up"])
    y = h @ params["w_out"]
    if seq_sharded:
        return dist.reduce_scatter(y, plan.seq_axis, dim=1)
    if ffn_2d:
        y = dist.reduce_scatter(y, "data", dim=0)
        return dist.psum(y, plan.tp_axis)
    return dist.psum(y, plan.tp_axis)


# ---------------------------------------------------------------------------
# vocab-parallel embedding + cross-entropy (Megatron-style)
# ---------------------------------------------------------------------------

def padded_vocab(cfg) -> int:
    return pad_to(cfg.vocab_size, VOCAB_PAD)


def init_embedding(cfg, plan: ShardingPlan, key):
    """Global shapes (padded vocab); sliced over the vocab axis by in_specs."""
    v = padded_vocab(cfg)
    dt = dtype_of(cfg)
    k1, k2 = jax.random.split(key)
    params = {"table": jax.random.normal(k1, (v, cfg.d_model), dt) * 0.02}
    specs = {"table": P(plan.vocab_axis, None)}
    if not cfg.tie_embeddings:
        params["head"] = jax.random.normal(k2, (cfg.d_model, v), dt) * 0.02
        specs["head"] = P(None, plan.vocab_axis)
    return params, specs


def embed(params, tokens, cfg, plan: ShardingPlan, dist: Dist):
    """tokens: [B, S_loc] int32 -> [B, S_loc, D]. Vocab-sharded table:
    each rank embeds the ids it owns, psum over the vocab axis."""
    table = params["table"]
    v_loc = table.shape[0]
    r = dist.index(plan.vocab_axis)
    local = tokens - r * v_loc
    in_range = (local >= 0) & (local < v_loc)
    safe = jnp.clip(local, 0, v_loc - 1)
    out = jnp.take(table, safe, axis=0)
    out = jnp.where(in_range[..., None], out, jnp.zeros_like(out))
    return dist.psum(out, plan.vocab_axis)


def lm_logits(params, x, cfg, plan: ShardingPlan, dist: Dist):
    """x: [B, T, D] -> logits [B, T, V_loc] (vocab-sharded, padded ids
    masked)."""
    w = params["table"].T if cfg.tie_embeddings else params["head"]
    logits = (x @ w).astype(jnp.float32)
    v_loc = w.shape[-1]
    r = dist.index(plan.vocab_axis)
    ids = r * v_loc + jnp.arange(v_loc)
    return jnp.where(ids < cfg.vocab_size, logits, -jnp.inf)


def vocab_parallel_xent(logits, labels, cfg, plan: ShardingPlan, dist: Dist):
    """Cross entropy without materializing full-vocab logits on any rank.

    logits: [B, T, V_loc] fp32 (vocab-sharded); labels: [B, T] global ids.
    Returns mean loss (scalar, replicated)."""
    v_loc = logits.shape[-1]
    r = dist.index(plan.vocab_axis)
    m = dist.pmax(jax.lax.stop_gradient(jnp.max(logits, axis=-1)),
                  plan.vocab_axis)                                   # [B, T]
    sumexp = dist.psum(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1),
                       plan.vocab_axis)                              # [B, T]
    local = labels - r * v_loc
    in_range = (local >= 0) & (local < v_loc)
    safe = jnp.clip(local, 0, v_loc - 1)
    picked = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    picked = jnp.where(in_range, picked, 0.0)
    label_logit = dist.psum(picked, plan.vocab_axis)                 # [B, T]
    loss = jnp.log(sumexp) + m - label_logit
    return jnp.mean(loss)


def greedy_sample(logits, cfg, plan: ShardingPlan, dist: Dist):
    """Global argmax over the sharded vocab: [B, T, V_loc] -> [B, T] int32."""
    v_loc = logits.shape[-1]
    r = dist.index(plan.vocab_axis)
    local_idx = jnp.argmax(logits, axis=-1)
    local_val = jnp.max(logits, axis=-1)
    vmax = dist.pmax(local_val, plan.vocab_axis)
    global_idx = r * v_loc + local_idx
    cand = jnp.where(local_val >= vmax, global_idx, jnp.iinfo(jnp.int32).max)
    return (-dist.pmax(-cand.astype(jnp.int32), plan.vocab_axis)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# FSDP helpers
# ---------------------------------------------------------------------------

def fsdp_spec(shape, base_spec: P, plan: ShardingPlan) -> P:
    """Extend a param spec with FSDP sharding over plan.fsdp_axis on the
    first dimension that is divisible and not already sharded."""
    if plan.fsdp_axis is None:
        return base_spec
    n = plan.axis_size(plan.fsdp_axis)
    entries = list(base_spec) + [None] * (len(shape) - len(base_spec))
    for i, (dim, e) in enumerate(zip(shape, entries)):
        if e is None and dim % n == 0 and dim >= n:
            entries[i] = plan.fsdp_axis
            return P(*entries)
    return base_spec


def fsdp_gather(params, specs, plan: ShardingPlan, dist: Dist):
    """All-gather FSDP-sharded leaves back to TP-only sharding for use in a
    layer body. Autodiff of the tiled all-gather produces the matching
    reduce-scatter on the gradient."""
    if plan.fsdp_axis is None or dist.size(plan.fsdp_axis) == 1:
        return params

    def gather(p, spec):
        if spec is None:
            return p
        entries = list(spec)
        for dim, e in enumerate(entries):
            if e == plan.fsdp_axis:
                return dist.all_gather(p, plan.fsdp_axis, dim=dim)
            if isinstance(e, tuple) and plan.fsdp_axis in e:
                return dist.all_gather(p, plan.fsdp_axis, dim=dim)
        return p

    return jax.tree.map(gather, params, specs,
                        is_leaf=lambda x: x is None or isinstance(x, P))
