"""Mamba-1 block (jamba's sequence mixer) with Megatron-SP distribution.

The selective-scan channels are independent, so d_inner is tensor-parallel
over `model`: AG(x over seq) -> column-sharded in_proj -> depthwise causal
conv -> chunked selective scan over the FULL sequence locally (no cross-rank
recurrence) -> row-sharded out_proj -> RS(seq).

Decode keeps per-rank states (conv ring [B, d_conv-1, di_loc], ssm state
[B, di_loc, ds]) so the prefill cache layout matches decode exactly.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers.common import dtype_of
from repro.sharding.dist import Dist
from repro.sharding.plans import ShardingPlan


def _dims(cfg):
    mc = cfg.mamba
    di = mc.expand * cfg.d_model
    dtr = mc.dt_rank or -(-cfg.d_model // 16)
    return di, dtr, mc.d_state, mc.d_conv


def init_mamba(cfg, plan: ShardingPlan, key):
    d = cfg.d_model
    di, dtr, ds, dc = _dims(cfg)
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 8)
    sc = d ** -0.5
    params = {
        "w_x": jax.random.normal(ks[0], (d, di), dt) * sc,
        "w_z": jax.random.normal(ks[1], (d, di), dt) * sc,
        "conv_w": jax.random.normal(ks[2], (dc, di), dt) * 0.2,
        "conv_b": jnp.zeros((di,), dt),
        "w_bc": jax.random.normal(ks[3], (di, 2 * ds), dt) * (di ** -0.5),
        "w_dt_in": jax.random.normal(ks[4], (di, dtr), dt) * (di ** -0.5),
        "w_dt": jax.random.normal(ks[5], (dtr, di), dt) * (dtr ** -0.5),
        "dt_bias": jnp.full((di,), -4.6, dt),          # softplus^-1(0.01)
        "log_a": jnp.log(jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, 1))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "w_out": jax.random.normal(ks[6], (di, d), dt) * (di ** -0.5),
    }
    tp = plan.tp_axis
    specs = {
        "w_x": P(None, tp), "w_z": P(None, tp),
        "conv_w": P(None, tp), "conv_b": P(tp),
        "w_bc": P(tp, None),
        "w_dt_in": P(tp, None), "w_dt": P(None, tp), "dt_bias": P(tp),
        "log_a": P(tp, None), "d_skip": P(tp),
        "w_out": P(tp, None),
    }
    return params, specs


def _ssm_scan(u, dt_, b, c, log_a, d_skip, h0, chunk: int = 128):
    """Selective scan. u/dt_: [B, S, di]; b/c: [B, S, ds]; h0: [B, di, ds].
    Returns (y [B, S, di] f32, h_final)."""
    B, S, di = u.shape
    ds = b.shape[-1]
    a = -jnp.exp(log_a)                                        # [di, ds]
    da = jnp.exp(dt_[..., None] * a)                           # [B,S,di,ds]
    dbu = (dt_ * u)[..., None] * b[:, :, None, :]              # [B,S,di,ds]

    ck = min(chunk, S)
    pad = (-S) % ck
    if pad:
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        dbu = jnp.pad(dbu, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    n = (S + pad) // ck
    da = da.reshape(B, n, ck, di, ds).transpose(1, 0, 2, 3, 4)
    dbu = dbu.reshape(B, n, ck, di, ds).transpose(1, 0, 2, 3, 4)
    cc = c.reshape(B, n, ck, ds).transpose(1, 0, 2, 3)

    def chunk_body(h, inp):
        da_c, dbu_c, c_c = inp                                 # [B, ck, di, ds]

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, b1 * a2 + b2

        a_cum, b_cum = jax.lax.associative_scan(combine, (da_c, dbu_c), axis=1)
        h_seq = a_cum * h[:, None] + b_cum                     # [B, ck, di, ds]
        y_c = jnp.einsum("bsdn,bsn->bsd", h_seq, c_c)          # [B, ck, di]
        return h_seq[:, -1], y_c

    h_fin, y = jax.lax.scan(chunk_body, h0, (da, dbu, cc))
    y = y.transpose(1, 0, 2, 3).reshape(B, n * ck, di)[:, :S]
    return y + u * d_skip, h_fin


def mamba_fwd(params, x, cfg, plan: ShardingPlan, dist: Dist, *,
              make_cache: bool = False):
    """x: [B, S_loc, D] seq-sharded. Returns (y [B, S_loc, D], cache|None)."""
    di, dtr, ds, dc = _dims(cfg)
    seq_ax = plan.seq_axis
    B, s_loc, d = x.shape
    xg = dist.all_gather(x, seq_ax, dim=1)                    # [B, S, D]
    S = xg.shape[1]

    u = xg @ params["w_x"]                                     # [B, S, di_loc]
    z = xg @ params["w_z"]
    # depthwise causal conv over S
    conv_w = params["conv_w"]                                  # [dc, di_loc]
    u_pad = jnp.pad(u, ((0, 0), (dc - 1, 0), (0, 0)))
    conv = sum(u_pad[:, i:i + S] * conv_w[i] for i in range(dc)) + params["conv_b"]
    uc = jax.nn.silu(conv.astype(jnp.float32)).astype(u.dtype)

    bc = uc @ params["w_bc"]
    b, c = jnp.split(bc.astype(jnp.float32), 2, axis=-1)       # [B, S, ds]
    dt_ = jax.nn.softplus(
        ((uc @ params["w_dt_in"]) @ params["w_dt"]).astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32))

    h0 = jnp.zeros((B, u.shape[-1], ds), jnp.float32)
    y, h_fin = _ssm_scan(uc.astype(jnp.float32), dt_, b, c,
                         params["log_a"], params["d_skip"], h0)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ params["w_out"]
    out = dist.reduce_scatter(out, seq_ax, dim=1)

    cache = None
    if make_cache:
        conv_tail = jnp.pad(u, ((0, 0), (dc - 1, 0), (0, 0)))[:, -(dc - 1):] \
            if dc > 1 else jnp.zeros((B, 0, u.shape[-1]), u.dtype)
        cache = {"conv": conv_tail, "ssm": h_fin.astype(jnp.float32)}
    return out, cache


def mamba_decode(params, x, cache, cfg, plan: ShardingPlan, dist: Dist):
    """x: [B, 1, D] replicated over tp; cache: conv [B, dc-1, di_loc],
    ssm [B, di_loc, ds]."""
    di, dtr, ds, dc = _dims(cfg)
    xt = x[:, 0]
    u = xt @ params["w_x"]                                     # [B, di_loc]
    z = xt @ params["w_z"]

    conv_in = jnp.concatenate([cache["conv"], u[:, None]], axis=1)  # [B, dc, di]
    conv = jnp.einsum("bcd,cd->bd", conv_in, params["conv_w"]) + params["conv_b"]
    uc = jax.nn.silu(conv.astype(jnp.float32)).astype(u.dtype)

    bc = uc @ params["w_bc"]
    b, c = jnp.split(bc.astype(jnp.float32), 2, axis=-1)       # [B, ds]
    dt_ = jax.nn.softplus(
        ((uc @ params["w_dt_in"]) @ params["w_dt"]).astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32))               # [B, di]

    a = -jnp.exp(params["log_a"])
    da = jnp.exp(dt_[..., None] * a)                           # [B, di, ds]
    h = cache["ssm"] * da + (dt_ * uc.astype(jnp.float32))[..., None] * b[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, c) + uc.astype(jnp.float32) * params["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ params["w_out"]
    out = dist.psum(out, plan.tp_axis)
    new_cache = {"conv": conv_in[:, 1:], "ssm": h}
    return out[:, None], new_cache


def mamba_cache_spec(cfg, plan: ShardingPlan, batch: int):
    """ShapeDtypeStructs + PartitionSpecs for the decode cache."""
    di, dtr, ds, dc = _dims(cfg)
    tp = plan.tp_axis
    bax = plan.batch_axes
    shapes = {
        "conv": jax.ShapeDtypeStruct((batch, dc - 1, di), jnp.dtype(cfg.dtype)),
        "ssm": jax.ShapeDtypeStruct((batch, di, ds), jnp.float32),
    }
    specs = {"conv": P(bax, None, tp), "ssm": P(bax, tp, None)}
    return shapes, specs
