"""Multi-head Latent Attention (DeepSeek-V2/V3) — the paper's workload.

The KV cache stores only the compressed latent (kv_lora_rank + rope_head_dim
per token, e.g. 576 for V3 vs 2*128*128 for vanilla MHA), which is why the
paper's Fig. 10 KV-capacity analysis uses MLA. Naive (non-absorbed) decode
decompresses K/V from the latent each step; the absorbed variant is a
hillclimb note in EXPERIMENTS.md.

Replicated-weight distribution only (deepseek-v3 is the analysis workload,
not a dry-run grid arch); the latent cache is small enough to replicate over
`model` while batch shards over `data`.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers.common import apply_rope, dtype_of
from repro.models.layers.attention import flash_attn, NEG_INF
from repro.sharding.dist import Dist
from repro.sharding.plans import ShardingPlan


def init_mla(cfg, plan: ShardingPlan, key):
    d, H, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    r, qr, rp = cfg.mla_kv_lora_rank, cfg.mla_q_lora_rank, cfg.mla_rope_head_dim
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 8)
    sc = d ** -0.5
    params = {
        "w_dq": jax.random.normal(ks[0], (d, qr), dt) * sc,
        "w_uq": jax.random.normal(ks[1], (qr, H * (hd + rp)), dt) * (qr ** -0.5),
        "w_dkv": jax.random.normal(ks[2], (d, r), dt) * sc,
        "w_kr": jax.random.normal(ks[3], (d, rp), dt) * sc,
        "w_uk": jax.random.normal(ks[4], (r, H * hd), dt) * (r ** -0.5),
        "w_uv": jax.random.normal(ks[5], (r, H * hd), dt) * (r ** -0.5),
        "w_o": jax.random.normal(ks[6], (H * hd, d), dt) * ((H * hd) ** -0.5),
        "q_norm": jnp.zeros((qr,), dt),
        "kv_norm": jnp.zeros((r,), dt),
    }
    specs = {k: P(*([None] * v.ndim)) for k, v in params.items()}
    return params, specs


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def _qkv(params, x, cfg, positions):
    """x: [B, S, D] -> q_n [B,S,H,hd], q_r [B,S,H,rp], c_kv [B,S,r],
    k_r [B,S,rp] (roped)."""
    H, hd, rp = cfg.num_heads, cfg.head_dim, cfg.mla_rope_head_dim
    B, S, _ = x.shape
    cq = _rms(x @ params["w_dq"], params["q_norm"])
    q = (cq @ params["w_uq"]).reshape(B, S, H, hd + rp)
    q_n, q_r = q[..., :hd], q[..., hd:]
    q_r = apply_rope(q_r, positions, cfg.rope_theta)
    c_kv = _rms(x @ params["w_dkv"], params["kv_norm"])
    k_r = apply_rope((x @ params["w_kr"])[:, :, None, :], positions,
                     cfg.rope_theta)[:, :, 0]
    return q_n, q_r, c_kv, k_r


def _decompress(params, c_kv, cfg):
    H, hd = cfg.num_heads, cfg.head_dim
    B, S, _ = c_kv.shape
    k = (c_kv @ params["w_uk"]).reshape(B, S, H, hd)
    v = (c_kv @ params["w_uv"]).reshape(B, S, H, hd)
    return k, v


def mla_fwd(params, x, cfg, plan: ShardingPlan, dist: Dist, *,
            causal: bool = True, make_cache: bool = False):
    """x: [B, S_loc, D]. Latent-cache MLA; weights replicated."""
    seq_ax = plan.seq_axis
    B, s_loc, _ = x.shape
    r_seq = dist.index(seq_ax)
    pos = r_seq * s_loc + jnp.arange(s_loc)
    q_n, q_r, c_kv, k_r = _qkv(params, x, cfg, pos)

    c_kv_g = dist.all_gather(c_kv, seq_ax, dim=1)
    k_r_g = dist.all_gather(k_r, seq_ax, dim=1)
    k, v = _decompress(params, c_kv_g, cfg)
    # fold the shared rope key into the per-head attention by augmenting dims
    q_aug = jnp.concatenate([q_n, q_r], axis=-1)
    k_aug = jnp.concatenate(
        [k, jnp.broadcast_to(k_r_g[:, :, None], k.shape[:3] + (k_r_g.shape[-1],))],
        axis=-1)
    o = flash_attn(q_aug, k_aug,
                   jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, q_r.shape[-1]))),
                   causal=causal, q_offset=r_seq * s_loc)
    o = o[..., :cfg.head_dim]
    y = o.reshape(B, s_loc, -1) @ params["w_o"]
    cache = {"c_kv": c_kv, "k_rope": k_r} if make_cache else None
    return y, cache


def mla_decode(params, x, cache, pos, cfg, plan: ShardingPlan, dist: Dist):
    """x: [B, 1, D]; cache: c_kv [B, S, r], k_rope [B, S, rp] (replicated
    over model, batch over data)."""
    H, hd = cfg.num_heads, cfg.head_dim
    B = x.shape[0]
    q_n, q_r, c_new, kr_new = _qkv(params, x, cfg,
                                   jnp.full((1,), pos))
    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_new, (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], kr_new, (0, pos, 0))
    k, v = _decompress(params, c_kv, cfg)                    # [B, S, H, hd]
    S = k.shape[1]
    scale = 1.0 / math.sqrt(hd + q_r.shape[-1])
    s = (jnp.einsum("bhd,bshd->bhs", q_n[:, 0].astype(jnp.float32),
                    k.astype(jnp.float32))
         + jnp.einsum("bhr,bsr->bhs", q_r[:, 0].astype(jnp.float32),
                      k_rope.astype(jnp.float32))) * scale
    valid = jnp.arange(S) <= pos
    s = jnp.where(valid[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32))
    y = o.reshape(B, -1).astype(x.dtype) @ params["w_o"]
    return y[:, None], {"c_kv": c_kv, "k_rope": k_rope}
