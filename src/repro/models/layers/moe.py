"""Expert-parallel Mixture-of-Experts layer (static capacity, scatter-based).

This is the paper's central communication pattern: tokens are dispatched to
the devices hosting their routed experts with an explicit
``jax.lax.all_to_all`` (the A2A the paper's alpha-beta model prices),
computed by the grouped expert matmul (Pallas kernel on TPU), and gathered
back with the mirror all-to-all.

Token layout: x [B, T_loc, D] — the local token slice on each rank of the EP
axis (train/prefill: seq-sharded tokens; decode: batch-sharded tokens).
Experts are padded up to a multiple of the EP group (e.g. granite 40 -> 48);
padded experts receive -inf router logits and are never routed to.

Dispatch uses scatter-add into the [E, C, D] expert buffers (and a gather on
the way back) instead of the GShard one-hot einsum: O(T*k*D) work and no
[T, E, C] tensor, matching how production systems build A2A payloads.

EP trace (per rank, E = padded experts, L = E / ep local experts, C = capacity):
  router     [T_loc, E]
  scatter    -> x_e [E, C, D]
  all_to_all (split expert dim, concat capacity dim)  -> [L, ep*C, D]
  expert FFN (grouped matmul kernel)                  -> [L, ep*C, D]
  all_to_all back                                     -> [E, C, D]
  gather+weighted-sum                                 -> y [T_loc, D]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers.common import dtype_of
from repro.sharding.dist import Dist
from repro.sharding.plans import ShardingPlan

from repro.kernels import ops as kops


def fp8_dispatch_a2a(x_e, ep_ax, dist: Dist):
    """fp8(e4m3) wire format for the dispatch all-to-all (DeepSeek-V3's
    production scheme: fp8 dispatch, bf16 combine). Per-slot scales ride
    along; the uint8 bitcast pins the 1-byte wire width against XLA's
    convert hoisting / f8-collective promotion (§Perf iteration 5)."""
    xf = x_e.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 448.0, 1.0)
    q = (xf / scale).astype(jnp.float8_e4m3fn)
    qb = jax.lax.bitcast_convert_type(q, jnp.uint8)
    qg = dist.all_to_all(qb, ep_ax, split_dim=0, concat_dim=1)
    sg = dist.all_to_all(scale, ep_ax, split_dim=0, concat_dim=1)
    qg = jax.lax.bitcast_convert_type(
        jax.lax.optimization_barrier(qg), jnp.float8_e4m3fn)
    return (qg.astype(jnp.float32) * sg).astype(x_e.dtype)


def capacity(t_loc: int, topk: int, n_exp: int, cf: float) -> int:
    c = int(-(-t_loc * topk * cf // n_exp))
    return max(c, 1)


def init_moe(cfg, plan: ShardingPlan, key):
    m = cfg.moe
    ep = plan.ep
    e_pad = m.padded_num_experts(max(ep, 1))
    d, de = cfg.d_model, m.d_expert
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 7)
    params = {
        "router": jax.random.normal(ks[0], (d, e_pad), jnp.float32) * (d ** -0.5),
        "w_gate": jax.random.normal(ks[1], (e_pad, d, de), dt) * (d ** -0.5),
        "w_up": jax.random.normal(ks[2], (e_pad, d, de), dt) * (d ** -0.5),
        "w_down": jax.random.normal(ks[3], (e_pad, de, d), dt) * (de ** -0.5),
    }
    specs = {
        "router": P(None, None),
        "w_gate": P(plan.ep_axis, None, None),
        "w_up": P(plan.ep_axis, None, None),
        "w_down": P(plan.ep_axis, None, None),
    }
    if m.num_shared_experts:
        dsh = m.d_shared_expert * m.num_shared_experts
        params["w_shared_gate"] = jax.random.normal(ks[4], (d, dsh), dt) * (d ** -0.5)
        params["w_shared_up"] = jax.random.normal(ks[5], (d, dsh), dt) * (d ** -0.5)
        params["w_shared_down"] = jax.random.normal(ks[6], (dsh, d), dt) * (dsh ** -0.5)
        specs["w_shared_gate"] = P(None, plan.tp_axis)
        specs["w_shared_up"] = P(None, plan.tp_axis)
        specs["w_shared_down"] = P(plan.tp_axis, None)
    return params, specs


def route(logits, topk: int, n_real: int):
    """logits [T, E] fp32 (E includes padding). Returns (gates [T,k],
    idx [T,k], probs [T,E]) with padded experts masked out."""
    e = logits.shape[-1]
    mask = jnp.arange(e) < n_real
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, topk)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    return gates, idx, probs


def slot_assignment(idx, e_pad: int, cap: int):
    """Queue position of each (token, k) routing decision in its expert's
    capacity buffer, token-major priority. idx: [T, k] ->
    (slot [T, k] int32, keep [T, k] bool)."""
    t, k = idx.shape
    onehot = jax.nn.one_hot(idx.reshape(t * k), e_pad, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot                    # [T*k, E]
    slot = jnp.take_along_axis(pos, idx.reshape(t * k, 1), axis=1)[:, 0]
    slot = slot.reshape(t, k)
    keep = slot < cap
    return slot.astype(jnp.int32), keep


def aux_load_balance_loss(probs, idx, n_real: int):
    """Switch-transformer load-balance loss over the real experts."""
    e = probs.shape[-1]
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32).sum(1)     # [T, E]
    frac_tokens = jnp.mean(onehot, axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    return n_real * jnp.sum(frac_tokens * frac_probs)


def moe_ffn(params, x, cfg, plan: ShardingPlan, dist: Dist,
            *, collect_aux: bool = False):
    """x: [B, T_loc, D] local token slice on each EP rank.
    Returns (y, aux_loss)."""
    m = cfg.moe
    B, t, d = x.shape
    xt = x.reshape(B * t, d)
    n_tok = B * t
    ep_ax = plan.ep_axis
    ep = dist.size(ep_ax)
    e_pad = params["router"].shape[-1]
    cap = capacity(n_tok, m.experts_per_token, e_pad, m.capacity_factor)

    logits = xt.astype(jnp.float32) @ params["router"]
    gates, idx, probs = route(logits, m.experts_per_token, m.num_experts)
    slot, keep = slot_assignment(idx, e_pad, cap)

    # scatter tokens into [E*C, D] expert buffers
    flat_idx = (idx * cap + jnp.clip(slot, 0, cap - 1)).reshape(-1)  # [T*k]
    contrib = (xt[:, None, :] * keep[..., None].astype(xt.dtype))
    x_e = jnp.zeros((e_pad * cap, d), xt.dtype).at[flat_idx].add(
        contrib.reshape(-1, d))
    x_e = x_e.reshape(e_pad, cap, d)

    if ep > 1:
        if plan.a2a_fp8:
            x_e = fp8_dispatch_a2a(x_e, ep_ax, dist)
        else:
            x_e = dist.all_to_all(x_e, ep_ax, split_dim=0, concat_dim=1)
        # -> [E_loc, ep*C, D]: rows for MY experts from every EP rank
    h = kops.moe_gmm(x_e, params["w_gate"], params["w_up"], params["w_down"])
    if ep > 1:
        h = dist.all_to_all(h, ep_ax, split_dim=1, concat_dim=0)    # [E, C, D]

    # gather back and combine with gates
    h_flat = h.reshape(e_pad * cap, d)
    picked = jnp.take(h_flat, flat_idx, axis=0).reshape(n_tok, -1, d)
    w = (gates * keep.astype(gates.dtype)).astype(h.dtype)
    y = jnp.einsum("tk,tkd->td", w, picked).reshape(B, t, d)

    if m.num_shared_experts:
        xs = x
        seq_sharded = plan.seq_axis is not None and dist.size(plan.seq_axis) > 1
        if seq_sharded:
            xs = dist.all_gather(xs, plan.seq_axis, dim=1)
        g = jax.nn.silu((xs @ params["w_shared_gate"]).astype(jnp.float32)).astype(xs.dtype)
        sh = (g * (xs @ params["w_shared_up"])) @ params["w_shared_down"]
        if seq_sharded:
            sh = dist.reduce_scatter(sh, plan.seq_axis, dim=1)
        else:
            sh = dist.psum(sh, plan.tp_axis)
        y = y + sh

    aux = aux_load_balance_loss(probs, idx, m.num_experts) if collect_aux else jnp.float32(0)
    return y, aux
