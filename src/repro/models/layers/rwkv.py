"""RWKV6 ("Finch") block: data-dependent-decay WKV recurrence + channel mix.

The WKV heads are independent, so the time-mix is head-TP over `model`
(Megatron-SP: AG(x over seq) -> local full-seq recurrence on the head shard
-> row-sharded output -> RS(seq)). Channel-mix is a standard TP FFN.

Recurrence (per head, state s: [hd, hd]):
  out_t = r_t . (s_{t-1} + (u * k_t) v_t^T)
  s_t   = diag(w_t) s_{t-1} + k_t v_t^T
with w_t = exp(-exp(decay_t)) data-dependent via a small LoRA.

Simplifications vs the release (noted in DESIGN.md): the 5-way token-shift
mixing LoRA is collapsed to a single learned interpolation per stream, and
output gating uses SiLU. The communication/compute structure — which is what
this systems paper prices — is unchanged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers.common import dtype_of
from repro.sharding.dist import Dist
from repro.sharding.plans import ShardingPlan


def _dims(cfg):
    hd = cfg.rwkv.head_dim
    n_heads = cfg.d_model // hd
    return n_heads, hd


def init_rwkv_tm(cfg, plan: ShardingPlan, key):
    """Time-mix params. Head dim sharded over tp via column blocks."""
    d = cfg.d_model
    nh, hd = _dims(cfg)
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 8)
    sc = d ** -0.5
    lora = max(32, d // 64)
    params = {
        "mix": jnp.full((4, d), 0.5, dt),                  # r,k,v,w shift mixes
        "w_r": jax.random.normal(ks[0], (d, d), dt) * sc,
        "w_k": jax.random.normal(ks[1], (d, d), dt) * sc,
        "w_v": jax.random.normal(ks[2], (d, d), dt) * sc,
        "w_g": jax.random.normal(ks[3], (d, d), dt) * sc,
        "decay_lora_a": jax.random.normal(ks[4], (d, lora), dt) * sc,
        "decay_lora_b": jax.random.normal(ks[5], (lora, d), dt) * (lora ** -0.5),
        "decay_base": jnp.full((d,), -4.0, jnp.float32),
        "bonus": jnp.zeros((d,), jnp.float32),             # u term, per channel
        "w_o": jax.random.normal(ks[6], (d, d), dt) * sc,
    }
    tp = plan.tp_axis
    specs = {
        "mix": P(None, None),
        "w_r": P(None, tp), "w_k": P(None, tp), "w_v": P(None, tp),
        "w_g": P(None, tp),
        "decay_lora_a": P(None, None), "decay_lora_b": P(None, tp),
        "decay_base": P(tp), "bonus": P(tp),
        "w_o": P(tp, None),
    }
    return params, specs


def init_rwkv_cm(cfg, plan: ShardingPlan, key):
    """Channel-mix params (relu^2 FFN, TP over d_ff)."""
    d, dff = cfg.d_model, cfg.d_ff
    dt = dtype_of(cfg)
    k1, k2 = jax.random.split(key)
    params = {
        "mix": jnp.full((d,), 0.5, dt),
        "w_in": jax.random.normal(k1, (d, dff), dt) * (d ** -0.5),
        "w_out": jax.random.normal(k2, (dff, d), dt) * (dff ** -0.5),
    }
    specs = {"mix": P(None), "w_in": P(None, plan.tp_axis),
             "w_out": P(plan.tp_axis, None)}
    return params, specs


def _wkv_scan(r, k, v, w, u, s0, chunk: int = 64):
    """WKV recurrence. r,k,v: [B, S, nh, hd]; w: [B, S, nh, hd] decay in (0,1);
    u: [nh, hd]; s0: [B, nh, hd, hd]. Returns (out [B,S,nh,hd] f32, s_fin)."""
    B, S, nh, hd = r.shape
    ck = min(chunk, S)
    pad = (-S) % ck
    if pad:
        z = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = jnp.pad(r, z), jnp.pad(k, z), jnp.pad(v, z)
        w = jnp.pad(w, z, constant_values=1.0)
    n = (S + pad) // ck

    def reshape(x):
        return x.reshape(B, n, ck, nh, hd).transpose(1, 0, 2, 3, 4)

    rc, kc, vc, wc = map(reshape, (r, k, v, w))

    def chunk_body(s, inp):
        r_c, k_c, v_c, w_c = inp                             # [B, ck, nh, hd]

        def step(s_, t):
            r_t, k_t, v_t, w_t = (r_c[:, t], k_c[:, t], v_c[:, t], w_c[:, t])
            kv = k_t[..., :, None] * v_t[..., None, :]       # [B,nh,hd,hd]
            out_t = jnp.einsum("bhk,bhkd->bhd", r_t, s_ + u[..., None] * kv)
            s_next = w_t[..., None] * s_ + kv
            return s_next, out_t

        s_fin, out_c = jax.lax.scan(step, s, jnp.arange(ck))
        return s_fin, out_c.transpose(1, 0, 2, 3)            # [B, ck, nh, hd]

    s_fin, out = jax.lax.scan(chunk_body, s0, (rc, kc, vc, wc))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, n * ck, nh, hd)[:, :S]
    return out, s_fin


def _tm_inputs(params, xg, x_prev, nh_loc, hd):
    """Compute r,k,v,g,w streams from token-shifted input.
    xg: [B, S, D]; x_prev: [B, S, D] (previous token)."""
    mix = params["mix"].astype(jnp.float32)
    xf = xg.astype(jnp.float32)
    pf = x_prev.astype(jnp.float32)

    def mixed(i):
        return (xf * mix[i] + pf * (1 - mix[i])).astype(xg.dtype)

    r = mixed(0) @ params["w_r"]
    k = mixed(1) @ params["w_k"]
    v = mixed(2) @ params["w_v"]
    g = mixed(2) @ params["w_g"]
    decay = (mixed(3) @ params["decay_lora_a"]) @ params["decay_lora_b"]
    w = jnp.exp(-jnp.exp(decay.astype(jnp.float32)
                         + params["decay_base"]))            # (0,1)
    B, S = xg.shape[0], xg.shape[1]

    def heads(x):
        return x.reshape(B, S, nh_loc, hd)

    return (heads(r).astype(jnp.float32), heads(k).astype(jnp.float32),
            heads(v).astype(jnp.float32), g, heads(w))


def rwkv_tm_fwd(params, x, cfg, plan: ShardingPlan, dist: Dist, *,
                make_cache: bool = False):
    """Time-mix. x: [B, S_loc, D] seq-sharded."""
    nh, hd = _dims(cfg)
    seq_ax = plan.seq_axis
    B = x.shape[0]
    xg = dist.all_gather(x, seq_ax, dim=1)                   # [B, S, D]
    S = xg.shape[1]
    x_prev = jnp.pad(xg, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    nh_loc = params["w_r"].shape[-1] // hd

    r, k, v, g, w = _tm_inputs(params, xg, x_prev, nh_loc, hd)
    u = params["bonus"].astype(jnp.float32).reshape(nh_loc, hd)
    s0 = jnp.zeros((B, nh_loc, hd, hd), jnp.float32)
    out, s_fin = _wkv_scan(r, k, v, w, u, s0)
    out = (out.reshape(B, S, -1) * jax.nn.silu(g.astype(jnp.float32))).astype(x.dtype)
    y = out @ params["w_o"]
    y = dist.reduce_scatter(y, seq_ax, dim=1)

    cache = None
    if make_cache:
        cache = {"wkv": s_fin, "shift": xg[:, -1]}
    return y, cache


def rwkv_tm_decode(params, x, cache, cfg, plan: ShardingPlan, dist: Dist):
    """x: [B, 1, D] replicated over tp; cache: wkv [B, nh_loc, hd, hd],
    shift [B, D]."""
    nh, hd = _dims(cfg)
    B = x.shape[0]
    xt = x[:, 0]
    nh_loc = params["w_r"].shape[-1] // hd
    r, k, v, g, w = _tm_inputs(params, xt[:, None], cache["shift"][:, None],
                               nh_loc, hd)
    r, k, v, w = r[:, 0], k[:, 0], v[:, 0], w[:, 0]          # [B, nh_loc, hd]
    u = params["bonus"].astype(jnp.float32).reshape(nh_loc, hd)
    s = cache["wkv"]
    kv = k[..., :, None] * v[..., None, :]
    out = jnp.einsum("bhk,bhkd->bhd", r, s + u[..., None] * kv)
    s_new = w[..., None] * s + kv
    out = (out.reshape(B, -1) * jax.nn.silu(g[:, 0].astype(jnp.float32))).astype(x.dtype)
    y = out @ params["w_o"]
    y = dist.psum(y, plan.tp_axis)
    return y[:, None], {"wkv": s_new, "shift": xt}


def rwkv_cm_fwd(params, x, plan: ShardingPlan, dist: Dist, *,
                make_cache: bool = False):
    """Channel-mix. x: [B, S_loc, D] seq-sharded (or decode [B, 1, D])."""
    seq_ax = plan.seq_axis
    seq_sharded = seq_ax is not None and dist.size(seq_ax) > 1
    xg = dist.all_gather(x, seq_ax, dim=1) if seq_sharded else x
    x_prev = jnp.pad(xg, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    mix = params["mix"].astype(jnp.float32)
    mixed = (xg.astype(jnp.float32) * mix
             + x_prev.astype(jnp.float32) * (1 - mix)).astype(x.dtype)
    h = jnp.square(jax.nn.relu((mixed @ params["w_in"]).astype(jnp.float32)))
    y = h.astype(x.dtype) @ params["w_out"]
    if seq_sharded:
        y = dist.reduce_scatter(y, seq_ax, dim=1)
    else:
        y = dist.psum(y, plan.tp_axis)
    cache = {"shift": xg[:, -1]} if make_cache else None
    return y, cache


def rwkv_cm_decode(params, x, cache, plan: ShardingPlan, dist: Dist):
    """x: [B, 1, D] replicated; cache: shift [B, D]."""
    xt = x[:, 0]
    mix = params["mix"].astype(jnp.float32)
    mixed = (xt.astype(jnp.float32) * mix
             + cache["shift"].astype(jnp.float32) * (1 - mix)).astype(x.dtype)
    h = jnp.square(jax.nn.relu((mixed @ params["w_in"]).astype(jnp.float32)))
    y = h.astype(x.dtype) @ params["w_out"]
    y = dist.psum(y, plan.tp_axis)
    return y[:, None], {"shift": xt}
