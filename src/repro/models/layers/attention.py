"""Attention layers.

Three execution paths (see DESIGN.md section 4):

train / prefill (tokens seq-sharded over `model`):
  head_tp     AG(x over seq) -> q on local head shard, K/V on the single KV
              head this rank's q-group maps to -> local chunked flash
              attention over the full sequence -> row-sharded W_o ->
              reduce-scatter(seq).  (Megatron-SP schedule.)
  replicated  weights replicated (small archs): q stays seq-local, K/V
              all-gathered over seq (cheap: kv_heads * hd << D), no other
              collectives.

decode (tokens replicated over `model`, KV cache sequence-sharded):
  every rank computes attention of the full-head query against its local KV
  chunk, partial results merged with the log-sum-exp trick
  (pmax m, psum lsum*e^{m-M}, psum o*e^{m-M}).

Prefill writes the cache in exactly the decode layout:
  global layers  k,v: [B, KV, S_loc, hd]  (seq-sharded over `model`)
  local  layers  k,v: [B, KV, W, hd]      (ring buffer, replicated)
"""
from __future__ import annotations

import math
import os
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers.common import apply_rope, dtype_of
from repro.sharding.dist import Dist
from repro.sharding.plans import ShardingPlan

NEG_INF = -1e30

# REPRO_ATTN_F32=1 restores the pre-optimization attention numerics
# (materialized f32 K/V copies + full-cache select on decode update) —
# the §Perf iteration-1 BASELINE (EXPERIMENTS.md).
ATTN_F32_BASELINE = os.environ.get("REPRO_ATTN_F32", "") == "1"


# ---------------------------------------------------------------------------
# chunked flash attention core (pure jnp; the Pallas kernel in
# repro.kernels.flash_decode covers the TPU hot path, validated vs this)
# ---------------------------------------------------------------------------

def flash_attn(q, k, v, *, causal: bool, window: int = 0,
               q_offset=0, kv_offset=0, kv_len=None, chunk: int = 1024):
    """Online-softmax attention, chunked over KV.

    q: [B, Sq, H, hd]; k, v: [B, Sk, KH, hd]  (H % KH == 0)
    q_offset / kv_offset: absolute position of element 0 (int or traced).
    kv_len: number of valid kv positions (defaults to Sk).
    Returns [B, Sq, H, hd].
    """
    B, Sq, H, hd = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    g = H // KH
    scale = 1.0 / math.sqrt(hd)
    kv_len = Sk if kv_len is None else kv_len

    ck = min(chunk, Sk)
    pad = (-Sk) % ck
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = (Sk + pad) // ck

    qr = jnp.transpose(q.reshape(B, Sq, KH, g, hd), (0, 2, 3, 1, 4))  # [B,KH,g,Sq,hd]
    kc = jnp.transpose(k.reshape(B, n_chunks, ck, KH, hd), (1, 0, 3, 2, 4))
    vc = jnp.transpose(v.reshape(B, n_chunks, ck, KH, hd), (1, 0, 3, 2, 4))
    pos_q = q_offset + jnp.arange(Sq)

    def body(carry, inp):
        m, lsum, acc = carry
        ci, kci, vci = inp
        pos_k = kv_offset + ci * ck + jnp.arange(ck)
        # bf16-native matmuls with f32 accumulation (MXU-style): never
        # materialize an f32 copy of K/V — that doubled HBM traffic and
        # dominated the dry-run memory roofline (EXPERIMENTS.md §Perf)
        qq = qr
        if ATTN_F32_BASELINE:
            qq, kci, vci = (t.astype(jnp.float32) for t in (qq, kci, vci))
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qq, kci,
                       preferred_element_type=jnp.float32) * scale
        mask = (pos_k[None, :] < kv_len)
        if causal:
            mask &= pos_k[None, :] <= pos_q[:, None]
        if window:
            mask &= pos_q[:, None] - pos_k[None, :] < window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = lsum * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p.astype(vci.dtype), vci,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KH, g, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KH, g, Sq), jnp.float32)
    a0 = jnp.zeros((B, KH, g, Sq, hd), jnp.float32)
    (m, lsum, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(lsum, 1e-30)[..., None]
    out = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


def attn_chunk_lse(q, k, v, *, pos_k, max_pos):
    """Single-chunk decode attention returning unnormalized (o, m, lsum) for the
    cross-rank log-sum-exp combine.

    q: [B, H, hd]; k, v: [B, KH, S_loc, hd]; pos_k: [S_loc] absolute
    positions; max_pos: highest attendable position (inclusive).
    Returns o: [B, H, hd] f32 (sum of e^{s-m} v), m: [B, H], lsum: [B, H].
    """
    B, H, hd = q.shape
    KH = k.shape[1]
    g = H // KH
    scale = 1.0 / math.sqrt(hd)
    # bf16-native score/value matmuls with f32 accumulation: reading the KV
    # cache at bf16 width (instead of materializing an f32 copy) is the
    # decode memory-roofline fix of EXPERIMENTS.md §Perf iteration 1
    qr = q.reshape(B, KH, g, hd).astype(k.dtype)
    if ATTN_F32_BASELINE:
        qr, k, v = (t.astype(jnp.float32) for t in (qr, k, v))
    s = jnp.einsum("bhgd,bhsd->bhgs", qr, k,
                   preferred_element_type=jnp.float32) * scale
    mask = pos_k[None, None, None, :] <= max_pos
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(mask, p, 0.0)
    o = jnp.einsum("bhgs,bhsd->bhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    lsum = jnp.sum(p, axis=-1)
    return o.reshape(B, H, hd), m.reshape(B, H), lsum.reshape(B, H)


def lse_combine(o, m, lsum, axis, dist: Dist):
    """Merge per-rank partial attention (o, m, lsum) over a sharded KV axis."""
    if axis is None or dist.size(axis) == 1:
        return o / jnp.maximum(lsum, 1e-30)[..., None]
    m_g = dist.pmax(jax.lax.stop_gradient(m), axis)
    corr = jnp.exp(m - m_g)
    l_g = dist.psum(lsum * corr, axis)
    o_g = dist.psum(o * corr[..., None], axis)
    return o_g / jnp.maximum(l_g, 1e-30)[..., None]


def ring_attention(q, k, v, *, seq_ax, dist: Dist, causal: bool = True):
    """Ring attention over a sequence-sharded KV (§Perf iteration 3).

    q: [B, Sq_loc, H_loc, hd] (local seq chunk, local head shard)
    k, v: [B, Sk_loc, KH_loc, hd] (local seq chunk of the matching KV heads)

    Instead of all-gathering the full activations/KV (Megatron-SP), the KV
    chunk rotates around the `seq_ax` ring via collective_permute while an
    online-softmax state accumulates — per-device collective traffic drops
    from O(S*D) to O(S*KH_loc*hd), and each hop is data-independent of the
    current chunk's attention compute, so XLA's latency-hiding scheduler
    overlaps them. Fully-future chunks are masked (not skipped): simple
    ring, ~2x compute for exact causal semantics (zigzag ordering is the
    known fix; documented as future work).
    """
    B, sq, H_loc, hd = q.shape
    sk, KH_loc = k.shape[1], k.shape[2]
    n = dist.size(seq_ax)
    if n == 1:
        return flash_attn(q, k, v, causal=causal)
    r = dist.index(seq_ax)
    g = H_loc // KH_loc
    scale = 1.0 / math.sqrt(hd)
    pos_q = r * sq + jnp.arange(sq)
    qr = jnp.transpose(q.reshape(B, sq, KH_loc, g, hd),
                       (0, 2, 3, 1, 4))                     # [B,KH,g,Sq,hd]

    def body(carry, step):
        m, lsum, acc, kc, vc = carry
        src = jnp.mod(r - step, n)
        pos_k = src * sk + jnp.arange(sk)
        kt = jnp.transpose(kc, (0, 2, 1, 3))                # [B,KH,Sk,hd]
        vt = jnp.transpose(vc, (0, 2, 1, 3))
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qr, kt,
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((sq, sk), bool)
        if causal:
            mask = pos_k[None, :] <= pos_q[:, None]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        corr = jnp.exp(m - m_new)
        l_new = lsum * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p.astype(vt.dtype), vt,
            preferred_element_type=jnp.float32)
        kc = dist.roll(kc, seq_ax, shift=1)
        vc = dist.roll(vc, seq_ax, shift=1)
        return (m_new, l_new, acc_new, kc, vc), None

    m0 = jnp.full((B, KH_loc, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KH_loc, g, sq), jnp.float32)
    a0 = jnp.zeros((B, KH_loc, g, sq, hd), jnp.float32)
    (m, lsum, acc, _, _), _ = jax.lax.scan(
        body, (m0, l0, a0, k, v), jnp.arange(n))
    out = acc / jnp.maximum(lsum, 1e-30)[..., None]
    out = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(B, sq, H_loc * hd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# parameter init (global shapes; sliced by shard_map in_specs)
# ---------------------------------------------------------------------------

def init_attention(cfg, plan: ShardingPlan, key, *, cross: bool = False):
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = dtype_of(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    sc = d ** -0.5
    params = {
        "w_q": jax.random.normal(k1, (d, H * hd), dt) * sc,
        "w_k": jax.random.normal(k2, (d, KV, hd), dt) * sc,
        "w_v": jax.random.normal(k3, (d, KV, hd), dt) * sc,
        "w_o": jax.random.normal(k4, (H * hd, d), dt) * ((H * hd) ** -0.5),
    }
    if plan.attn_mode == "head_tp":
        specs = {
            "w_q": P(None, plan.tp_axis),
            "w_k": P(None, None, None),
            "w_v": P(None, None, None),
            "w_o": P(plan.tp_axis, None),
        }
    else:
        specs = {k: P(*([None] * v.ndim)) for k, v in params.items()}
    return params, specs


def _local_kv_slice(cfg, plan: ShardingPlan, dist: Dist):
    """KV head range this rank's q shard maps to under head_tp."""
    tp = dist.size(plan.tp_axis)
    H, KV = cfg.num_heads, cfg.num_kv_heads
    h_loc = H // tp
    kv_loc = max(1, (KV * h_loc) // H)  # == max(1, KV // tp)
    r = dist.index(plan.tp_axis)
    start = (r * h_loc * KV) // H
    return start, kv_loc


# ---------------------------------------------------------------------------
# train / prefill self-attention
# ---------------------------------------------------------------------------

def attention_fwd(params, x, cfg, plan: ShardingPlan, dist: Dist, *,
                  causal: bool = True, window: int = 0,
                  make_cache: bool = False):
    """x: [B, S_loc, D] seq-sharded (or full under NullDist).
    Returns (y [B, S_loc, D], cache | None)."""
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    seq_ax = plan.seq_axis
    B, s_loc, _ = x.shape
    r_seq = dist.index(seq_ax)
    q_offset = r_seq * s_loc

    cache = None
    if make_cache:
        # cache K/V: all KV heads for the LOCAL seq chunk (decode layout)
        k_c = jnp.einsum("bsd,dkh->bksh", x, params["w_k"])
        v_c = jnp.einsum("bsd,dkh->bksh", x, params["w_v"])
        pos_local = q_offset + jnp.arange(s_loc)
        k_c = jnp.transpose(
            apply_rope(jnp.transpose(k_c, (0, 2, 1, 3)), pos_local,
                       cfg.rope_theta), (0, 2, 1, 3))
        if window:
            cache = _window_cache_from_prefill(k_c, v_c, window, s_loc, plan, dist)
        else:
            cache = {"k": k_c, "v": v_c}

    if plan.attn_mode == "head_tp":
        if plan.ring_attn and window == 0 and dist.size(seq_ax) > 1:
            # ring path (§Perf iteration 3): q/k/v from the LOCAL seq
            # chunk only; KV rotates around the seq ring — no full-seq
            # all-gather, no full-seq reduce-scatter.
            q = (x @ params["w_q"]).reshape(B, s_loc, -1, hd)
            start, kv_loc = _local_kv_slice(cfg, plan, dist)
            w_k = jax.lax.dynamic_slice_in_dim(params["w_k"], start, kv_loc,
                                               axis=1)
            w_v = jax.lax.dynamic_slice_in_dim(params["w_v"], start, kv_loc,
                                               axis=1)
            k = jnp.einsum("bsd,dkh->bskh", x, w_k)
            v = jnp.einsum("bsd,dkh->bskh", x, w_v)
            pos_local = q_offset + jnp.arange(s_loc)
            q = apply_rope(q, pos_local, cfg.rope_theta)
            k = apply_rope(k, pos_local, cfg.rope_theta)
            o = ring_attention(q, k, v, seq_ax=seq_ax, dist=dist,
                               causal=causal)
            y = o @ params["w_o"]                 # head-partial [B,S_loc,D]
            y = dist.psum(y, plan.tp_axis)
            return y, cache
        xg = dist.all_gather(x, seq_ax, dim=1)                 # [B, S, D]
        S = xg.shape[1]
        q = (xg @ params["w_q"]).reshape(B, S, -1, hd)         # local heads
        start, kv_loc = _local_kv_slice(cfg, plan, dist)
        w_k = jax.lax.dynamic_slice_in_dim(params["w_k"], start, kv_loc, axis=1)
        w_v = jax.lax.dynamic_slice_in_dim(params["w_v"], start, kv_loc, axis=1)
        k = jnp.einsum("bsd,dkh->bskh", xg, w_k)
        v = jnp.einsum("bsd,dkh->bskh", xg, w_v)
        pos = jnp.arange(S)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        o = flash_attn(q, k, v, causal=causal, window=window)
        # w_o is row-sharded over heads: the tiled psum_scatter sums the
        # partial head contributions AND scatters the sequence in one
        # collective (Megatron-SP).
        y = o.reshape(B, S, -1) @ params["w_o"]
        y = dist.reduce_scatter(y, seq_ax, dim=1)
        return y, cache

    # replicated-weight path
    q = (x @ params["w_q"]).reshape(B, s_loc, H, hd)
    k = jnp.einsum("bsd,dkh->bskh", x, params["w_k"])
    v = jnp.einsum("bsd,dkh->bskh", x, params["w_v"])
    pos_local = q_offset + jnp.arange(s_loc)
    q = apply_rope(q, pos_local, cfg.rope_theta)
    k = apply_rope(k, pos_local, cfg.rope_theta)
    k = dist.all_gather(k, seq_ax, dim=1)                      # [B, S, KV, hd]
    v = dist.all_gather(v, seq_ax, dim=1)
    o = flash_attn(q, k, v, causal=causal, window=window, q_offset=q_offset)
    y = o.reshape(B, s_loc, -1) @ params["w_o"]
    return y, cache


def _window_cache_from_prefill(k_c, v_c, window, s_loc, plan, dist):
    """Build the replicated ring-buffer cache for a sliding-window layer from
    the seq-sharded prefill K/V. Only the final `window` positions matter;
    they live on the last rank(s). We all-gather the last `window` positions
    worth (cheap: window << S) via psum of masked contributions."""
    B, KV, _, hd = k_c.shape
    seq_ax = plan.seq_axis
    n = dist.size(seq_ax)
    S = s_loc * n
    r = dist.index(seq_ax)
    pos_local = r * s_loc + jnp.arange(s_loc)
    # ring slot for each local position; valid if within the last `window`
    slot = pos_local % window
    valid = pos_local >= S - window
    k_ring = jnp.zeros((B, KV, window, hd), k_c.dtype)
    v_ring = jnp.zeros((B, KV, window, hd), v_c.dtype)
    k_ring = k_ring.at[:, :, slot, :].add(jnp.where(valid[None, None, :, None], k_c, 0))
    v_ring = v_ring.at[:, :, slot, :].add(jnp.where(valid[None, None, :, None], v_c, 0))
    k_ring = dist.psum(k_ring, seq_ax)
    v_ring = dist.psum(v_ring, seq_ax)
    return {"k": k_ring, "v": v_ring}


# ---------------------------------------------------------------------------
# decode self-attention (KV cache)
# ---------------------------------------------------------------------------

def attention_decode(params, x, cache, pos, cfg, plan: ShardingPlan,
                     dist: Dist, *, window: int = 0):
    """x: [B, 1, D] (replicated over tp); cache k/v: [B, KV, S_loc, hd]
    (seq-sharded over plan.kv_axis; ring buffer [B, KV, W, hd] if window).
    pos: scalar int32, position of the incoming token. Returns (y, cache)."""
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    B = x.shape[0]
    xt = x[:, 0]                                              # [B, D]
    tp = dist.size(plan.tp_axis)

    q = (xt @ params["w_q"]).reshape(B, -1, hd)
    if plan.attn_mode == "head_tp" and tp > 1:
        q = dist.all_gather(q, plan.tp_axis, dim=1)           # [B, H, hd]
    q = apply_rope(q[:, None], jnp.full((1,), pos), cfg.rope_theta)[:, 0]

    k_new = jnp.einsum("bd,dkh->bkh", xt, params["w_k"])
    v_new = jnp.einsum("bd,dkh->bkh", xt, params["w_v"])
    k_new = apply_rope(k_new[:, None], jnp.full((1,), pos),
                       cfg.rope_theta)[:, 0]

    if window:
        slot = pos % window
        k_c = jax.lax.dynamic_update_slice(
            cache["k"], k_new[:, :, None, :], (0, 0, slot, 0))
        v_c = jax.lax.dynamic_update_slice(
            cache["v"], v_new[:, :, None, :], (0, 0, slot, 0))
        w = cache["k"].shape[2]
        slots = jnp.arange(w)
        slot_pos = pos - jnp.mod(pos - slots, w)              # abs pos per slot
        # unwritten slots (early decode, pos < window) -> mask out
        slot_pos = jnp.where(slot_pos < 0, jnp.int32(2 ** 30), slot_pos)
        o, m, lsum = attn_chunk_lse(q, k_c, v_c, pos_k=slot_pos, max_pos=pos)
        o = o / jnp.maximum(lsum, 1e-30)[..., None]
    else:
        s_loc = cache["k"].shape[2]
        kv_ax = plan.kv_axis
        r = dist.index(kv_ax)
        local = pos - r * s_loc
        in_range = (local >= 0) & (local < s_loc)
        lc = jnp.clip(local, 0, s_loc - 1)
        # non-owner ranks write the OLD value back at the clamped slot:
        # the select stays slice-sized (a full-cache where() forced XLA to
        # copy/convert the whole cache per layer — §Perf iteration 1)
        if ATTN_F32_BASELINE:
            k_up = jax.lax.dynamic_update_slice(
                cache["k"], k_new[:, :, None, :], (0, 0, lc, 0))
            v_up = jax.lax.dynamic_update_slice(
                cache["v"], v_new[:, :, None, :], (0, 0, lc, 0))
            k_c = jnp.where(in_range, k_up, cache["k"])
            v_c = jnp.where(in_range, v_up, cache["v"])
        else:
            B_, KV_ = k_new.shape[0], k_new.shape[1]
            old_k = jax.lax.dynamic_slice(cache["k"], (0, 0, lc, 0),
                                          (B_, KV_, 1, cache["k"].shape[3]))
            old_v = jax.lax.dynamic_slice(cache["v"], (0, 0, lc, 0),
                                          (B_, KV_, 1, cache["v"].shape[3]))
            u_k = jnp.where(in_range, k_new[:, :, None, :], old_k)
            u_v = jnp.where(in_range, v_new[:, :, None, :], old_v)
            k_c = jax.lax.dynamic_update_slice(cache["k"], u_k,
                                               (0, 0, lc, 0))
            v_c = jax.lax.dynamic_update_slice(cache["v"], u_v,
                                               (0, 0, lc, 0))
        pos_k = r * s_loc + jnp.arange(s_loc)
        o, m, lsum = attn_chunk_lse(q, k_c, v_c, pos_k=pos_k, max_pos=pos)
        o = lse_combine(o, m, lsum, kv_ax, dist)
        cache = {"k": k_c, "v": v_c}
        y = _decode_out_proj(o, params, plan, dist, B)
        return y, cache

    cache = {"k": k_c, "v": v_c}
    y = _decode_out_proj(o, params, plan, dist, B)
    return y, cache


def _decode_out_proj(o, params, plan: ShardingPlan, dist: Dist, B):
    """o: [B, H, hd] f32 full heads on every rank; W_o may be row-sharded."""
    tp = dist.size(plan.tp_axis)
    w_o = params["w_o"]
    if plan.attn_mode == "head_tp" and tp > 1:
        hh_loc = w_o.shape[0]
        r = dist.index(plan.tp_axis)
        o_loc = jax.lax.dynamic_slice_in_dim(
            o.reshape(B, -1), r * hh_loc, hh_loc, axis=1)
        y = o_loc.astype(w_o.dtype) @ w_o
        y = dist.psum(y, plan.tp_axis)
    else:
        y = o.reshape(B, -1).astype(w_o.dtype) @ w_o
    return y[:, None, :]


# ---------------------------------------------------------------------------
# cross-attention (encoder-decoder)
# ---------------------------------------------------------------------------

def cross_attention_fwd(params, x, enc_kv, cfg, plan: ShardingPlan,
                        dist: Dist):
    """Training/prefill cross-attention. x: [B, S_loc, D] decoder tokens;
    enc_kv: {"k","v"} [B, KV, Se_loc, hd] seq-sharded encoder cache."""
    hd = cfg.head_dim
    B, s_loc, _ = x.shape
    tp = dist.size(plan.tp_axis)
    k = jnp.transpose(enc_kv["k"], (0, 2, 1, 3))             # [B, Se_loc, KV, hd]
    v = jnp.transpose(enc_kv["v"], (0, 2, 1, 3))
    if plan.attn_mode == "head_tp" and tp > 1:
        # Megatron-SP: full-seq q on the local head shard, matching KV head.
        xg = dist.all_gather(x, plan.seq_axis, dim=1)
        q = (xg @ params["w_q"]).reshape(B, xg.shape[1], -1, hd)
        start, kv_loc = _local_kv_slice(cfg, plan, dist)
        k = jax.lax.dynamic_slice_in_dim(k, start, kv_loc, axis=2)
        v = jax.lax.dynamic_slice_in_dim(v, start, kv_loc, axis=2)
        k = dist.all_gather(k, plan.seq_axis, dim=1)
        v = dist.all_gather(v, plan.seq_axis, dim=1)
        o = flash_attn(q, k, v, causal=False)
        y = o.reshape(B, o.shape[1], -1) @ params["w_o"]     # head-partial
        return dist.reduce_scatter(y, plan.seq_axis, dim=1)
    q = (x @ params["w_q"]).reshape(B, s_loc, -1, hd)
    k = dist.all_gather(k, plan.seq_axis, dim=1)
    v = dist.all_gather(v, plan.seq_axis, dim=1)
    o = flash_attn(q, k, v, causal=False)
    return o.reshape(B, s_loc, -1) @ params["w_o"]


def cross_attention_decode(params, x, enc_kv, enc_len, cfg,
                           plan: ShardingPlan, dist: Dist):
    """Decode-time cross-attention: x [B, 1, D]; enc_kv seq-sharded."""
    B = x.shape[0]
    hd = cfg.head_dim
    xt = x[:, 0]
    tp = dist.size(plan.tp_axis)
    q = (xt @ params["w_q"]).reshape(B, -1, hd)
    if plan.attn_mode == "head_tp" and tp > 1:
        q = dist.all_gather(q, plan.tp_axis, dim=1)
    s_loc = enc_kv["k"].shape[2]
    r = dist.index(plan.kv_axis)
    pos_k = r * s_loc + jnp.arange(s_loc)
    o, m, lsum = attn_chunk_lse(q, enc_kv["k"], enc_kv["v"], pos_k=pos_k,
                             max_pos=enc_len - 1)
    o = lse_combine(o, m, lsum, plan.kv_axis, dist)
    return _decode_out_proj(o, params, plan, dist, B)


def make_enc_cache(params, enc_out, cfg, plan: ShardingPlan, dist: Dist):
    """Precompute the (read-only) encoder K/V for decoder cross-attention.
    enc_out: [B, Se_loc, D] seq-sharded -> k/v [B, KV, Se_loc, hd]."""
    k = jnp.einsum("bsd,dkh->bksh", enc_out, params["w_k"])
    v = jnp.einsum("bsd,dkh->bksh", enc_out, params["w_v"])
    return {"k": k, "v": v}
