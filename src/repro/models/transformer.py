"""Decoder stack: scan-over-periods with an unrolled remainder.

A model is `n_periods` repetitions of `cfg.period` (a tuple of LayerSpecs)
plus `n_remainder` leading pattern positions. Parameters and caches are
stored as a tuple (one tree per position-in-period) of leaves stacked over
periods, so the whole stack lowers as one `lax.scan` — keeping the HLO small
enough to GSPMD-compile 95-layer models for 512 devices.

Layer = pre-norm mixer (+ cross-attention for enc-dec) + pre-norm FFN,
residual around each.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import LayerSpec, ModelConfig
from repro.models.layers import attention as attn
from repro.models.layers import common, mamba as mamba_mod, mla as mla_mod
from repro.models.layers import moe as moe_mod, rwkv as rwkv_mod
from repro.sharding.dist import Dist
from repro.sharding.plans import ShardingPlan


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------

def init_layer(spec: LayerSpec, cfg: ModelConfig, plan: ShardingPlan, key,
               *, cross: bool = False):
    ks = jax.random.split(key, 6)
    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}

    params["norm1"], specs["norm1"] = common.init_rms_norm(cfg.d_model, jnp.float32)
    if spec.mixer in ("attn", "attn_local"):
        if cfg.attn_kind == "mla":
            params["mixer"], specs["mixer"] = mla_mod.init_mla(cfg, plan, ks[0])
        else:
            params["mixer"], specs["mixer"] = attn.init_attention(cfg, plan, ks[0])
    elif spec.mixer == "mamba":
        params["mixer"], specs["mixer"] = mamba_mod.init_mamba(cfg, plan, ks[0])
    elif spec.mixer == "rwkv":
        params["mixer"], specs["mixer"] = rwkv_mod.init_rwkv_tm(cfg, plan, ks[0])

    if cross:
        params["norm_x"], specs["norm_x"] = common.init_rms_norm(cfg.d_model, jnp.float32)
        params["cross"], specs["cross"] = attn.init_attention(cfg, plan, ks[1])

    params["norm2"], specs["norm2"] = common.init_rms_norm(cfg.d_model, jnp.float32)
    if spec.mixer == "rwkv":
        params["ffn"], specs["ffn"] = rwkv_mod.init_rwkv_cm(cfg, plan, ks[2])
    elif spec.ffn == "dense":
        params["ffn"], specs["ffn"] = common.init_dense_ffn(cfg, plan, ks[2])
    elif spec.ffn == "moe":
        params["ffn"], specs["ffn"] = moe_mod.init_moe(cfg, plan, ks[2])
    # FSDP (training): extend specs BEFORE period-stacking so the scan dim is
    # never sharded; forward all-gathers per period (common.fsdp_gather).
    specs = jax.tree.map(lambda p, s: common.fsdp_spec(p.shape, s, plan),
                         params, specs)
    return params, specs


# ---------------------------------------------------------------------------
# per-layer apply
# ---------------------------------------------------------------------------

def apply_layer(spec: LayerSpec, p, x, cfg, plan: ShardingPlan, dist: Dist, *,
                mode: str, cache=None, pos=None, enc_len=None, enc_out=None,
                collect_aux: bool = False):
    """mode: train | prefill | decode. Returns (x, new_cache, aux)."""
    new_cache: Dict[str, Any] = {}
    aux = jnp.float32(0)
    window = cfg.sliding_window if spec.mixer == "attn_local" else 0

    h = common.rms_norm(x, p["norm1"]["scale"], cfg.norm_eps)
    if spec.mixer in ("attn", "attn_local"):
        if cfg.attn_kind == "mla":
            if mode == "decode":
                h, c = mla_mod.mla_decode(p["mixer"], h, cache["mixer"], pos,
                                          cfg, plan, dist)
            else:
                h, c = mla_mod.mla_fwd(p["mixer"], h, cfg, plan, dist,
                                       make_cache=(mode == "prefill"))
        else:
            if mode == "decode":
                h, c = attn.attention_decode(p["mixer"], h, cache["mixer"],
                                             pos, cfg, plan, dist,
                                             window=window)
            else:
                h, c = attn.attention_fwd(p["mixer"], h, cfg, plan, dist,
                                          causal=True, window=window,
                                          make_cache=(mode == "prefill"))
        if c is not None:
            new_cache["mixer"] = c
    elif spec.mixer == "mamba":
        if mode == "decode":
            h, c = mamba_mod.mamba_decode(p["mixer"], h, cache["mixer"],
                                          cfg, plan, dist)
        else:
            h, c = mamba_mod.mamba_fwd(p["mixer"], h, cfg, plan, dist,
                                       make_cache=(mode == "prefill"))
        if c is not None:
            new_cache["mixer"] = c
    elif spec.mixer == "rwkv":
        if mode == "decode":
            h, c = rwkv_mod.rwkv_tm_decode(p["mixer"], h, cache["mixer"],
                                           cfg, plan, dist)
        else:
            h, c = rwkv_mod.rwkv_tm_fwd(p["mixer"], h, cfg, plan, dist,
                                        make_cache=(mode == "prefill"))
        if c is not None:
            new_cache["mixer"] = c
    else:
        h = jnp.zeros_like(x)
    x = x + h

    if "cross" in p:
        h = common.rms_norm(x, p["norm_x"]["scale"], cfg.norm_eps)
        if mode == "decode":
            h = attn.cross_attention_decode(p["cross"], h, cache["cross"],
                                            enc_len, cfg, plan, dist)
            new_cache["cross"] = cache["cross"]      # read-only pass-through
        else:
            enc_kv = attn.make_enc_cache(p["cross"], enc_out, cfg, plan, dist)
            h = attn.cross_attention_fwd(p["cross"], h, enc_kv, cfg,
                                         plan, dist)
            if mode == "prefill":
                new_cache["cross"] = enc_kv
        x = x + h

    h = common.rms_norm(x, p["norm2"]["scale"], cfg.norm_eps)
    if spec.mixer == "rwkv":
        if mode == "decode":
            h, c = rwkv_mod.rwkv_cm_decode(p["ffn"], h, cache["ffn"], plan, dist)
        else:
            h, c = rwkv_mod.rwkv_cm_fwd(p["ffn"], h, plan, dist,
                                        make_cache=(mode == "prefill"))
        if c is not None:
            new_cache["ffn"] = c
    elif spec.ffn == "dense":
        h = common.dense_ffn(p["ffn"], h, plan, dist)
    elif spec.ffn == "moe":
        h, aux = moe_mod.moe_ffn(p["ffn"], h, cfg, plan, dist,
                                 collect_aux=collect_aux)
    else:
        h = jnp.zeros_like(x)
    x = x + h
    return x, (new_cache if new_cache else None), aux


# ---------------------------------------------------------------------------
# stack init
# ---------------------------------------------------------------------------

def _stack_trees(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _prepend_none(spec_tree):
    return jax.tree.map(
        lambda s: P(*((None,) + tuple(s))), spec_tree,
        is_leaf=lambda s: isinstance(s, P))


def init_stack(cfg: ModelConfig, plan: ShardingPlan, key, *,
               cross: bool = False, n_layers: Optional[int] = None,
               period: Optional[Tuple[LayerSpec, ...]] = None):
    """Returns ({"periods": tuple_of_stacked, "rem": tuple}, same-shape specs)."""
    period = period or cfg.period
    n_layers = n_layers if n_layers is not None else cfg.num_layers
    n_per = n_layers // len(period)
    n_rem = n_layers % len(period)

    keys = jax.random.split(key, n_layers + 1)
    periods, rem = [], []
    spec_tree_pos = []
    for i, spec in enumerate(period):
        per_layer = [init_layer(spec, cfg, plan, keys[j * len(period) + i],
                                cross=cross)
                     for j in range(n_per)]
        ps = [p for p, _ in per_layer]
        spec_tree_pos.append(per_layer[0][1])
        periods.append(_stack_trees(ps) if n_per else None)
    rem_specs = []
    for i in range(n_rem):
        p, s = init_layer(period[i], cfg, plan, keys[n_per * len(period) + i],
                          cross=cross)
        rem.append(p)
        rem_specs.append(s)
    params = {"periods": tuple(periods), "rem": tuple(rem)}
    specs = {"periods": tuple(_prepend_none(s) for s in spec_tree_pos),
             "rem": tuple(rem_specs)}
    if n_per == 0:
        params["periods"], specs["periods"] = (), ()
    return params, specs


# ---------------------------------------------------------------------------
# stack apply
# ---------------------------------------------------------------------------

def apply_stack(params, x, cfg: ModelConfig, plan: ShardingPlan, dist: Dist,
                *, mode: str, caches=None, pos=None, enc_len=None,
                enc_out=None, collect_aux: bool = False, remat: bool = False,
                period: Optional[Tuple[LayerSpec, ...]] = None,
                n_layers: Optional[int] = None, param_specs=None,
                unroll: bool = False):
    """caches: {"periods": tuple_of_stacked, "rem": tuple} (decode) or None
    (train/prefill — prefill CREATES caches). Returns (x, new_caches|None, aux).

    unroll=True unrolls the period scan (XLA cost_analysis counts a scan
    body once, so exact roofline accounting needs the unrolled program;
    launch.dryrun --unroll)."""
    period = period or cfg.period
    n_layers = n_layers if n_layers is not None else cfg.num_layers
    n_per = n_layers // len(period)
    n_rem = n_layers % len(period)
    want_cache = mode in ("prefill", "decode")
    have_cache = caches is not None

    def one_period(x, aux, pparams, pcaches):
        new_caches = []
        for i, spec in enumerate(period):
            p_i = pparams[i]
            if param_specs is not None and plan.fsdp_axis is not None:
                # strip the leading period-dim None from the stacked spec
                sp_i = jax.tree.map(lambda s: P(*tuple(s)[1:]),
                                    param_specs["periods"][i],
                                    is_leaf=lambda s: isinstance(s, P))
                p_i = common.fsdp_gather(p_i, sp_i, plan, dist)
            c_in = pcaches[i] if pcaches is not None else None
            x, c, a = apply_layer(spec, p_i, x, cfg, plan, dist,
                                  mode=mode, cache=c_in, pos=pos,
                                  enc_len=enc_len, enc_out=enc_out,
                                  collect_aux=collect_aux)
            aux = aux + a
            new_caches.append(c)
        return x, aux, tuple(new_caches)

    aux = jnp.float32(0)
    new_period_caches = None
    if n_per > 0:
        def body(carry, xs):
            x, aux = carry
            if have_cache:
                pparams, pcaches = xs
            else:
                pparams, pcaches = xs, None
            x, aux, ncache = one_period(x, aux, pparams, pcaches)
            return (x, aux), (ncache if want_cache else None)

        scan_body = jax.checkpoint(body) if remat else body
        xs = (params["periods"], caches["periods"]) if have_cache \
            else params["periods"]
        (x, aux), ys = jax.lax.scan(scan_body, (x, aux), xs,
                                    unroll=n_per if unroll else 1)
        new_period_caches = ys if want_cache else None

    new_rem = []
    for i in range(n_rem):
        c_in = caches["rem"][i] if have_cache else None
        p_i = params["rem"][i]
        if param_specs is not None and plan.fsdp_axis is not None:
            p_i = common.fsdp_gather(p_i, param_specs["rem"][i], plan, dist)
        x, c, a = apply_layer(period[i], p_i, x, cfg, plan, dist,
                              mode=mode, cache=c_in, pos=pos, enc_len=enc_len,
                              enc_out=enc_out, collect_aux=collect_aux)
        aux = aux + a
        new_rem.append(c)

    new_caches = None
    if want_cache:
        new_caches = {"periods": new_period_caches, "rem": tuple(new_rem)}
    return x, new_caches, aux
