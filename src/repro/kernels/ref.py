"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def moe_gmm_ref(x, w_gate, w_up, w_down):
    """Grouped expert SwiGLU FFN.
    x: [E, T, D]; w_gate/w_up: [E, D, F]; w_down: [E, F, D] -> [E, T, D]."""
    g = jnp.einsum("etd,edf->etf", x, w_gate)
    u = jnp.einsum("etd,edf->etf", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("etf,efd->etd", h, w_down)


def flash_decode_ref(q, k, v, length):
    """Single-token decode attention.
    q: [B, H, hd]; k/v: [B, KH, S, hd]; length: int or scalar array —
    number of valid positions. Returns [B, H, hd]."""
    B, H, hd = q.shape
    KH, S = k.shape[1], k.shape[2]
    g = H // KH
    qr = q.reshape(B, KH, g, hd).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhsd->bhgs", qr, k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(hd))
    mask = jnp.arange(S) < length
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bhsd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)
