"""Pallas TPU kernel: grouped expert SwiGLU matmul (the MoE FFN hot spot).

The paper's expert computation (dense per-expert FFN over the A2A'd token
buffers) is the dominant MoE compute. TPU adaptation (DESIGN.md section 3):
instead of a CUTLASS grouped GEMM over ragged token groups, we use the
static-capacity layout [E, T, D] produced by the dispatch scatter, tiled so
each (expert, token-tile, f-tile) step keeps its working set in VMEM and
feeds the MXU with 128-aligned tiles:

  grid (E, T/bt, F/bf) — sequential minor axis f accumulates the down-proj
  into a VMEM f32 accumulator; both matmuls and the SwiGLU fuse in one pass
  over the expert's weights, so expert weights stream HBM->VMEM exactly once
  per token-tile.

VMEM per step (bt=128, bf=256, D=4096, bf16):
  x 1 MiB + w_gate/w_up/w_down 3*2 MiB + acc f32 2 MiB  ~= 9 MiB  (< 16 MiB)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, wg_ref, wu_ref, wd_ref, out_ref, acc_ref, *, n_f: int):
    f = pl.program_id(2)

    @pl.when(f == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                       # [bt, D]
    wg = wg_ref[0]                     # [D, bf]
    wu = wu_ref[0]
    wd = wd_ref[0]                     # [bf, D]
    g = jnp.dot(x, wg, preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu, preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    acc_ref[...] += jnp.dot(h, wd, preferred_element_type=jnp.float32)

    @pl.when(f == n_f - 1)
    def _flush():
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "block_f", "interpret"))
def moe_gmm_pallas(x, w_gate, w_up, w_down, *, block_t: int = 128,
                   block_f: int = 256, interpret: bool = False):
    """x: [E, T, D]; w_gate/w_up: [E, D, F]; w_down: [E, F, D] -> [E, T, D].

    T and F need not be tile multiples: the token and FFN axes zero-pad up
    to the block size (block_t itself shrinks to T when T is smaller), so
    arbitrary capacity factors run instead of tripping a divisibility
    assert. Zero token rows produce zero outputs (sliced off) and zero FFN
    columns contribute nothing to the down-projection, so padding is exact.
    """
    e, t, d = x.shape
    f = w_gate.shape[-1]
    # shrink tiles for small T/F, keeping them hardware-aligned (sublane x8
    # on the token axis, lane x128 on the FFN axis)
    bt = min(block_t, -(-t // 8) * 8)
    bf = min(block_f, -(-f // 128) * 128)
    t_pad = -(-t // bt) * bt
    f_pad = -(-f // bf) * bf
    if t_pad != t:
        x = jnp.pad(x, ((0, 0), (0, t_pad - t), (0, 0)))
    if f_pad != f:
        w_gate = jnp.pad(w_gate, ((0, 0), (0, 0), (0, f_pad - f)))
        w_up = jnp.pad(w_up, ((0, 0), (0, 0), (0, f_pad - f)))
        w_down = jnp.pad(w_down, ((0, 0), (0, f_pad - f), (0, 0)))
    n_t, n_f = t_pad // bt, f_pad // bf

    grid = (e, n_t, n_f)
    out = pl.pallas_call(
        functools.partial(_kernel, n_f=n_f),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, d), lambda e_, t_, f_: (e_, t_, 0)),
            pl.BlockSpec((1, d, bf), lambda e_, t_, f_: (e_, 0, f_)),
            pl.BlockSpec((1, d, bf), lambda e_, t_, f_: (e_, 0, f_)),
            pl.BlockSpec((1, bf, d), lambda e_, t_, f_: (e_, f_, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt, d), lambda e_, t_, f_: (e_, t_, 0)),
        out_shape=jax.ShapeDtypeStruct((e, t_pad, d), x.dtype),
        # f32 accumulator persisted across the sequential f grid steps
        scratch_shapes=[pltpu.VMEM((bt, d), jnp.float32)],
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
    return out[:, :t] if t_pad != t else out
