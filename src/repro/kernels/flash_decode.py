"""Pallas TPU kernel: online-softmax decode attention over a KV chunk.

The decode-attention hot spot: one query token against a long KV cache.
Memory-bandwidth-bound (every KV byte read once), so the kernel's job is to
stream K/V HBM->VMEM in S-tiles while the softmax state (m, l, acc) stays in
VMEM scratch across the sequential S grid axis.

Layout: q [B, KH, g, hd]; k/v [B, KH, S, hd]; grid (B, KH, S/bs).
`length` (valid KV positions) rides along as a scalar-prefetch operand.
The cross-device sequence-parallel combine (the LSE merge over the `model`
mesh axis) happens OUTSIDE the kernel in repro.models.layers.attention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, out_ref,
            m_ref, l_ref, acc_ref, *, bs: int, n_s: int):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # [g, hd]
    k = k_ref[0, 0].astype(jnp.float32)            # [bs, hd]
    v = v_ref[0, 0].astype(jnp.float32)
    hd = q.shape[-1]

    s = (q @ k.T) * (hd ** -0.5)                   # [g, bs]
    pos = s_idx * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    valid = pos < len_ref[0]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                            # [g, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(valid, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + p @ v
    m_ref[...] = m_new

    @pl.when(s_idx == n_s - 1)
    def _flush():
        out_ref[0, 0] = (acc_ref[...] /
                         jnp.maximum(l_ref[...], 1e-30)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def flash_decode_pallas(q, k, v, length, *, block_s: int = 512,
                        interpret: bool = False):
    """q: [B, H, hd]; k/v: [B, KH, S, hd]; length: [] or [1] int32.
    Returns [B, H, hd] (normalized — single-device path)."""
    b, h, hd = q.shape
    kh, s = k.shape[1], k.shape[2]
    g = h // kh
    bs = min(block_s, s)
    assert s % bs == 0, (s, bs)
    n_s = s // bs
    qr = q.reshape(b, kh, g, hd)
    length = jnp.asarray(length, jnp.int32).reshape(1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kh, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda b_, h_, s_, len_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b_, h_, s_, len_: (b_, h_, s_, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b_, h_, s_, len_: (b_, h_, s_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda b_, h_, s_, len_: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, bs=bs, n_s=n_s),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kh, g, hd), q.dtype),
        interpret=interpret,
    )(length, qr, k, v)
    return out.reshape(b, h, hd)
