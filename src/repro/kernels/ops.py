"""Jit'd dispatching wrappers around the Pallas kernels.

The model code calls these; they pick the Pallas TPU kernel on TPU backends
and the pure-jnp oracle elsewhere (CPU smoke tests, 512-device dry-run).
Set REPRO_FORCE_IMPL={pallas,pallas_interpret,ref} to override.
"""
from __future__ import annotations

import os

import jax

from repro.kernels import ref as kref


def _impl() -> str:
    forced = os.environ.get("REPRO_FORCE_IMPL", "")
    if forced:
        return forced
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def moe_gmm(x, w_gate, w_up, w_down):
    impl = _impl()
    if impl == "ref":
        return kref.moe_gmm_ref(x, w_gate, w_up, w_down)
    from repro.kernels.moe_gmm import moe_gmm_pallas
    t, f = x.shape[1], w_gate.shape[-1]
    if t % 8 or f % 8:        # shapes too small/ragged for the kernel tiling
        return kref.moe_gmm_ref(x, w_gate, w_up, w_down)
    return moe_gmm_pallas(
        x, w_gate, w_up, w_down,
        block_t=min(128, t), block_f=min(256, f),
        interpret=(impl == "pallas_interpret"))


def flash_decode(q, k, v, length):
    impl = _impl()
    if impl == "ref":
        return kref.flash_decode_ref(q, k, v, length)
    from repro.kernels.flash_decode import flash_decode_pallas
    s = k.shape[2]
    if s % 8:
        return kref.flash_decode_ref(q, k, v, length)
    return flash_decode_pallas(
        q, k, v, length, block_s=min(512, s),
        interpret=(impl == "pallas_interpret"))
