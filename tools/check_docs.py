"""Docs link-and-reference checker (CI lint step).

Scans README.md and docs/*.md and fails if any reference is stale:

  * markdown link targets ``[text](path)`` must exist (http/mailto and
    pure #anchors are skipped);
  * backtick tokens that look like file paths (contain "/" and end in a
    known extension, optionally followed by ``: symbol``) must resolve
    against the repo root or the conventional prefixes (``src/``,
    ``src/repro/``) — so ``core/sweep.py`` in a doc resolves to
    ``src/repro/core/sweep.py``;
  * backtick tokens that look like dotted python references
    (``repro.*`` / ``benchmarks.*`` / ``tests.*`` / ``tools.*``) must
    resolve to a module file, and any trailing attribute must appear in
    that module's source;
  * a ``path: symbol`` suffix (and ``module.symbol``) is checked by
    substring against the target file.

Run: ``python tools/check_docs.py`` from the repo root (exit 1 on any
unresolved reference, listing them). tests/test_docs.py runs it too.
"""
from __future__ import annotations

import glob
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DOC_GLOBS = ("README.md", "docs/*.md")
PATH_ROOTS = ("", "src/", "src/repro/", "src/repro/core/", "docs/",
              "benchmarks/", "bench_results/", "tests/", "tools/")
EXTS = r"(?:py|md|json|toml|yaml|yml|txt|sh)"
PATH_RE = re.compile(rf"[\w.*/-]+\.{EXTS}\b")
DOTTED_RE = re.compile(r"\b(?:repro|benchmarks|tests|tools)(?:\.\w+)+")
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
TICK_RE = re.compile(r"`([^`\n]+)`")


def _resolve_path(tok: str) -> Path | None:
    for root in PATH_ROOTS:
        cand = str(ROOT / (root + tok))
        hits = glob.glob(cand)
        if hits:
            return Path(sorted(hits)[0])
    return None


def _resolve_dotted(tok: str) -> tuple[Path | None, str | None]:
    """Longest module prefix -> file; returns (file, leftover attr)."""
    parts = tok.split(".")
    base = {"repro": ROOT / "src" / "repro", "benchmarks": ROOT / "benchmarks",
            "tests": ROOT / "tests", "tools": ROOT / "tools"}[parts[0]]
    for cut in range(len(parts), 0, -1):
        p = base.joinpath(*parts[1:cut])
        for cand in (p.with_suffix(".py"), p / "__init__.py"):
            if cand.is_file():
                attr = parts[cut] if cut < len(parts) else None
                return cand, attr
    return None, None


def check_file(doc: Path) -> list[str]:
    errs: list[str] = []
    text = doc.read_text()
    rel = doc.relative_to(ROOT)

    for m in LINK_RE.finditer(text):
        tgt = m.group(1).split("#")[0]
        if not tgt or "://" in tgt or tgt.startswith("mailto:"):
            continue
        if not ((doc.parent / tgt).exists() or (ROOT / tgt).exists()):
            errs.append(f"{rel}: broken link target '{m.group(1)}'")

    for m in TICK_RE.finditer(text):
        tok = m.group(1)
        for pm in PATH_RE.finditer(tok):
            target = _resolve_path(pm.group(0))
            if target is None:
                errs.append(f"{rel}: path '{pm.group(0)}' (in `{tok}`) "
                            "does not exist")
                continue
            # `path: symbol` — the named symbol must appear in the file
            rest = tok[pm.end():]
            sym = re.match(r":\s*(\w+)", rest)
            if sym and target.suffix == ".py" \
                    and sym.group(1) not in target.read_text():
                errs.append(f"{rel}: symbol '{sym.group(1)}' not found "
                            f"in {pm.group(0)}")
        if PATH_RE.search(tok):
            continue  # path tokens already checked; skip dotted scan
        for dm in DOTTED_RE.finditer(tok):
            mod, attr = _resolve_dotted(dm.group(0))
            if mod is None:
                errs.append(f"{rel}: module '{dm.group(0)}' (in `{tok}`) "
                            "does not resolve to a file")
            elif attr and attr not in mod.read_text():
                errs.append(f"{rel}: attribute '{attr}' of "
                            f"'{dm.group(0)}' not found in "
                            f"{mod.relative_to(ROOT)}")
    return errs


def main() -> int:
    docs = [p for pat in DOC_GLOBS for p in sorted(ROOT.glob(pat))]
    errs = [e for d in docs for e in check_file(d)]
    for e in errs:
        print(f"check_docs: {e}", file=sys.stderr)
    print(f"check_docs: {len(docs)} docs, "
          f"{'FAIL (%d stale refs)' % len(errs) if errs else 'all refs ok'}")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
