"""Fabric-name literal checker (CI lint step).

The fabric registry (`src/repro/core/fabric.py`) is the single source of
truth for topology names: core code must enumerate `FABRICS` /
`TOPOLOGIES` or take the name as data, never hard-code `"torus"` and
friends — a hard-coded literal is exactly the per-topology dispatch the
registry refactor removed, and it silently skips any fabric registered
later.

This checker walks every module under ``src/repro`` except the fabric
module itself and fails on any string constant exactly equal to a
registered fabric name (docstrings are exempt — prose may name
topologies). Tests and benchmarks are out of scope: naming a topology is
the point of a figure.

Run: ``python tools/check_fabric_strings.py`` from the repo root
(exit 1 listing ``file:line`` offenders).
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SRC = ROOT / "src" / "repro"
# the one module allowed to spell the names: it defines them
ALLOWED = {SRC / "core" / "fabric.py"}


def _fabric_names() -> set[str]:
    sys.path.insert(0, str(ROOT / "src"))
    from repro.core.fabric import FABRICS
    return set(FABRICS)


def _docstring_spans(tree: ast.AST) -> set[int]:
    """Line numbers owned by docstrings (exempt from the check)."""
    lines: set[int] = set()
    for node in ast.walk(tree):
        if not isinstance(node, (ast.Module, ast.ClassDef,
                                 ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        body = getattr(node, "body", [])
        if body and isinstance(body[0], ast.Expr) \
                and isinstance(body[0].value, ast.Constant) \
                and isinstance(body[0].value.value, str):
            doc = body[0].value
            lines.update(range(doc.lineno, doc.end_lineno + 1))
    return lines


def check_file(path: Path, names: set[str]) -> list[str]:
    tree = ast.parse(path.read_text(), filename=str(path))
    doc_lines = _docstring_spans(tree)
    rel = path.relative_to(ROOT)
    errs = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Constant) and node.value in names \
                and node.lineno not in doc_lines:
            errs.append(f"{rel}:{node.lineno}: fabric name "
                        f"{node.value!r} hard-coded outside the registry "
                        "(enumerate repro.core.fabric.FABRICS instead)")
    return errs


def main() -> int:
    names = _fabric_names()
    files = [p for p in sorted(SRC.rglob("*.py")) if p not in ALLOWED]
    errs = [e for p in files for e in check_file(p, names)]
    for e in errs:
        print(f"check_fabric_strings: {e}", file=sys.stderr)
    print(f"check_fabric_strings: {len(files)} modules, "
          f"{len(names)} registered names, "
          f"{'FAIL (%d literals)' % len(errs) if errs else 'clean'}")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
