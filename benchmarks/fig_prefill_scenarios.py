"""Prefill-aware scenario sweep: chunked vs disaggregated prefill on the
Table-3 clusters (new figure; extends the paper, which models decode only).

Grid: prompt length x TTFT SLO x topology, DeepSeek-V3, 64 XPUs, TPOT SLO
40 ms, three serving modes per cell:

  decode    the paper's search (prefill free) — upper-bound baseline
  chunked   prefill chunks interleaved into decode iterations (joint
            batch x chunk-size search; TPOT inflated by the chunk riding
            every iteration, TTFT = sum of the prompt's chunk iterations)
  disagg    cluster split into prefill/decode pools (split ratio swept;
            throughput capped by the balanced pipeline rate, TTFT = one
            whole-prompt pass + KV-cache handoff)

Expected trends: ignoring prefill overstates throughput most at long
prompts; disaggregation buys TTFT headroom (whole-prompt passes never wait
behind decode SLOs) at the cost of devices taken from the decode pool;
chunked keeps all devices decoding and wins when the TTFT budget is loose.
"""
from __future__ import annotations

from benchmarks.common import save, solve_points, table
from repro.configs import get_arch
from repro.core import H100, Scenario, make_cluster

TOPOS = ("scale-up", "scale-out", "torus", "fullmesh")
PROMPTS = (512, 2048, 8192)
TTFTS_MS = (500.0, 2000.0)
TPOT_MS = 40.0
GEN_LEN = 1024          # decode tokens per request; avg context = L + GEN/2
MODES = ("decode", "chunked", "disagg")


def run(verbose: bool = True):
    cfg = get_arch("deepseek-v3")
    clusters = [make_cluster(t, 64, H100) for t in TOPOS]
    scenarios = [Scenario(TPOT_MS, L + GEN_LEN // 2, prompt_len=L,
                          ttft_ms=T)
                 for L in PROMPTS for T in TTFTS_MS]
    grids = {mode: solve_points(cfg, clusters, scenarios, mode=mode,
                                prefill=True)
             for mode in MODES}

    results = {}
    rows = []
    for si, sc in enumerate(scenarios):
        for ti, topo in enumerate(TOPOS):
            n = clusters[ti].n_xpus
            entry = {}
            row = [sc.prompt_len, int(sc.ttft_ms), topo]
            for mode in MODES:
                op = grids[mode][ti][si]
                if op is None:
                    entry[mode] = None
                    row.append("miss")
                    continue
                entry[mode] = {
                    "thpt_per_xpu": op.throughput / n,
                    "tpot_ms": op.tpot * 1e3,
                    "ttft_ms": op.ttft * 1e3,
                    "batch": op.batch,
                    "chunk": op.chunk,
                    "n_prefill_xpus": op.n_prefill_xpus,
                    # fraction of the TPOT-side iteration that is exposed
                    # communication; under the no-overlap timing this is
                    # the comm share — i.e. the headroom DBO can attack
                    # (benchmarks/fig_prefill_overlap.py quantifies it)
                    "exposed_comm_frac": (op.exposed_comm / op.tpot
                                          if op.tpot else 0.0),
                }
                extra = (f" c{op.chunk}" if mode == "chunked" else
                         f" p{op.n_prefill_xpus}" if mode == "disagg" else "")
                row.append(f"{op.throughput / n:.0f}{extra}")
            results.setdefault(sc.name, {})[topo] = entry
            rows.append(row)
    out = table(["prompt", "TTFT ms", "topology",
                 "decode tok/s/XPU", "chunked", "disagg"], rows,
                title="Prefill-aware operating points (DeepSeek-V3, 64 XPU, "
                      "TPOT 40 ms)")

    def thpt(L, T, topo, mode):
        e = results[Scenario(TPOT_MS, L + GEN_LEN // 2, prompt_len=L,
                             ttft_ms=T).name][topo][mode]
        return e["thpt_per_xpu"] if e else 0.0

    long_p, short_p = PROMPTS[-1], PROMPTS[0]
    tight, loose = TTFTS_MS[0], TTFTS_MS[-1]
    results["claims"] = {
        # modeling prefill always costs throughput vs the prefill-free
        # baseline at the longest prompt, on every topology
        "prefill_not_free": all(
            max(thpt(long_p, loose, t, "chunked"),
                thpt(long_p, loose, t, "disagg"))
            < thpt(long_p, loose, t, "decode") for t in TOPOS),
        # at long prompts disaggregation beats chunking on every topology:
        # chunk iterations are taxed by the decode batch they ride, a
        # dedicated pool prefills at full efficiency
        "disagg_wins_long_prompt": all(
            thpt(long_p, loose, t, "disagg")
            >= thpt(long_p, loose, t, "chunked") for t in TOPOS),
        # neither mode dominates: chunking keeps all XPUs decoding and wins
        # somewhere (full-mesh at short prompts, where its cheap A2As make
        # the mixed iterations affordable)
        "no_universal_winner": any(
            thpt(short_p, loose, t, "chunked")
            > thpt(short_p, loose, t, "disagg") for t in TOPOS),
        # a 0.5 s TTFT budget at 8K prompts is infeasible on every topology
        # once prefill is modeled — the decode-only search still claims
        # capacity there, which is exactly the overstatement this figure
        # quantifies
        "tight_ttft_long_prompt_infeasible": all(
            thpt(long_p, tight, t, "chunked") == 0.0
            and thpt(long_p, tight, t, "disagg") == 0.0
            and thpt(long_p, tight, t, "decode") > 0.0 for t in TOPOS),
    }
    if verbose:
        print(out)
        print("\nclaims:", results["claims"])
    save("fig_prefill_scenarios", results)
    return results


if __name__ == "__main__":
    run()
