"""Roofline table over every dry-run cell (deliverable g).

Reads results_dryrun_unrolled.json (exact per-layer accounting: the layer
scan is unrolled because XLA cost_analysis counts a scan body once) and
prints the three-term roofline + bottleneck + MODEL/HLO flops ratio per
(arch x shape) on the single-pod 256-chip mesh."""
from __future__ import annotations

import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

if __package__ in (None, ""):
    # executed as `python benchmarks/roofline.py`: sys.path[0] is
    # benchmarks/, so neither `benchmarks.*` nor `repro.*` resolves from a
    # fresh checkout — put the repo root and src/ in front
    sys.path.insert(0, os.path.join(ROOT, "src"))
    sys.path.insert(0, ROOT)

from benchmarks.common import save, table
from repro.analysis import roofline as R

# preference order: exact (unroll+unstack) > unrolled > scanned
CANDIDATES = [os.path.join(ROOT, p) for p in (
    "results_dryrun_exact.json", "results_dryrun_unrolled.json",
    "results_dryrun_single.json")]


def run(verbose: bool = True, results_path: str = ""):
    path = results_path or next(
        (p for p in CANDIDATES if os.path.exists(p)), None)
    if path is None:
        # fresh checkouts have no dry-run artifacts; degrade to a recorded
        # skip instead of raising StopIteration out of the harness
        out = {"status": "skipped",
               "reason": "no dry-run results JSON found (run "
                         "repro.launch.dryrun on a TPU host to produce "
                         "results_dryrun_*.json); searched: "
                         + ", ".join(os.path.basename(p)
                                     for p in CANDIDATES)}
        if verbose:
            print(f"roofline: SKIPPED — {out['reason']}")
        save("roofline", out)
        return out
    with open(path) as f:
        cells = json.load(f)
    rows = []
    out = {"status": "ok", "source": path, "cells": {}}
    for res in cells:
        r = R.from_dryrun(res)
        if r is None:
            out["cells"][f"{res['arch']}/{res['shape']}"] = {
                "status": res["status"], "reason": res.get("reason", "")}
            continue
        key = f"{r.arch}/{r.shape}"
        out["cells"][key] = {
            "compute_ms": r.compute_s * 1e3,
            "memory_ms": r.memory_s * 1e3,
            "collective_ms": r.collective_s * 1e3,
            "bottleneck": r.bottleneck,
            "model_hlo_ratio": r.useful_flops_ratio,
            "roofline_fraction": r.roofline_fraction,
            "hint": R.what_would_help(r),
        }
        rows.append([r.arch, r.shape, f"{r.compute_s * 1e3:.2f}",
                     f"{r.memory_s * 1e3:.2f}",
                     f"{r.collective_s * 1e3:.2f}", r.bottleneck,
                     f"{r.useful_flops_ratio:.2f}",
                     f"{r.roofline_fraction * 100:.1f}%"])
    tbl = table(["arch", "shape", "compute ms", "memory ms", "collective ms",
                 "bottleneck", "model/HLO", "roofline frac"], rows,
                title=f"Roofline (TPU v5e, per chip) — {os.path.basename(path)}")
    if verbose:
        print(tbl)
    save("roofline", out)
    return out


if __name__ == "__main__":
    run()
