"""Five-fabric ranking: the registry's static four + the OCS fabric.

The registry proof-point figure (docs/fabrics.md): rank ALL registered
fabrics — the paper's four (Fig 14 grid) plus the reconfigurable optical
circuit-switched fabric — on throughput per cost over the fig14 scenario
grid, then re-rank them on a fig17-style bandwidth-sweep Pareto arm.
No core module is edited to admit the fifth topology: `TOPOS` is just
`tuple(FABRICS)`.

Headline: OCS serves every scenario, beats scale-out everywhere, beats
scale-up on throughput/cost in the majority of scenarios (the per-port
MEMS pricing undercuts the per-GB/s electrical switch tiers), and its
best bandwidth point lands within 15% of the Pareto frontier — without
ever winning outright: the switchless meshes keep the frontier."""
from __future__ import annotations

from benchmarks.common import save, solve_level_points, table
from repro.configs import get_arch
from repro.core import H100, Scenario, make_cluster, pareto
from repro.core.fabric import FABRICS
from repro.core.tco import cluster_tco

# every registered fabric, in registration order — the OCS fabric rides
# along purely by being in the registry
TOPOS = tuple(FABRICS)
SCENARIOS = [Scenario(t, c) for c in (512, 4096) for t in (15.0, 40.0, 100.0)]
PARETO_SCENARIO = Scenario(40.0, 512)


def run(verbose: bool = True, n: int = 64):
    cfg = get_arch("deepseek-v3")
    clusters = [make_cluster(topo, n, H100) for topo in TOPOS]
    # one shared engine pass spans all five fabrics x scenarios x opts
    grids = solve_level_points(cfg, clusters, SCENARIOS,
                               ("noopt", "dbo+sd"))
    costs = {topo: cluster_tco(cl).per_xpu(n)
             for topo, cl in zip(TOPOS, clusters)}

    results = {}
    rows = []
    ocs_vs_scaleup = []
    ocs_vs_scaleout = []
    for si, sc in enumerate(SCENARIOS):
        per_topo = {}
        for ti, topo in enumerate(TOPOS):
            entry = {"cost_per_xpu": costs[topo]}
            for opts in ("noopt", "dbo+sd"):
                op = grids[opts][ti][si]
                entry[opts] = {
                    "thpt_per_xpu": (op.throughput / n) if op else 0.0,
                    "thpt_per_cost":
                        (op.throughput / n / costs[topo]) if op else 0.0,
                    "batch": op.batch if op else 0}
            per_topo[topo] = entry
        results[sc.name] = per_topo
        ocs = per_topo["ocs"]["dbo+sd"]["thpt_per_cost"]
        su = per_topo["scale-up"]["dbo+sd"]["thpt_per_cost"]
        so = per_topo["scale-out"]["dbo+sd"]["thpt_per_cost"]
        ocs_vs_scaleup.append(ocs > su)
        ocs_vs_scaleout.append(ocs > so)
        rows.append([sc.name] + [
            f"{per_topo[t]['dbo+sd']['thpt_per_xpu']:.0f}/"
            f"{per_topo[t]['dbo+sd']['thpt_per_cost']:.2f}"
            for t in TOPOS])
    out = table(["scenario"] + [f"{t} thpt/tpc" for t in TOPOS], rows,
                title=f"fig_ocs — five-fabric ranking ({n} XPUs, DBO+SD)")

    # fig17-style arm: each fabric sweeps fractions of its own provision;
    # the frontier decides whether a reconfigurable fabric earns a place
    points = pareto.sweep_networks(cfg, PARETO_SCENARIO, H100, sizes=(n,),
                                   topologies=TOPOS)
    frontier = pareto.pareto_frontier(points)
    best_tpc = {}
    for p in points:
        best_tpc[p.topology] = max(best_tpc.get(p.topology, 0.0),
                                   p.throughput_per_cost)
    frontier_best = max(p.throughput_per_cost for p in frontier)
    ocs_ratio = best_tpc["ocs"] / frontier_best
    results["pareto"] = {
        "scenario": PARETO_SCENARIO.name,
        "points": [{"topology": p.topology, "link_bw_GBs": p.link_bw / 1e9,
                    "cost_per_xpu": p.cost_per_xpu,
                    "thpt_per_xpu": p.throughput_per_xpu,
                    "thpt_per_cost": p.throughput_per_cost}
                   for p in points],
        "frontier": [{"topology": p.topology,
                      "link_bw_GBs": p.link_bw / 1e9,
                      "thpt_per_cost": p.throughput_per_cost}
                     for p in frontier],
        "best_tpc_by_topology": best_tpc,
    }

    results["claims"] = {
        # the registry proof: the fifth fabric is served by the same
        # search surface as the four it was registered beside
        "all_five_fabrics_ranked": len(TOPOS) == 5 and "ocs" in TOPOS,
        "ocs_feasible_all_scenarios": all(
            grids["dbo+sd"][TOPOS.index("ocs")][si] is not None
            for si in range(len(SCENARIOS))),
        "ocs_beats_scaleout_everywhere": all(ocs_vs_scaleout),
        "ocs_beats_scaleup_majority":
            sum(ocs_vs_scaleup) * 2 > len(SCENARIOS),
        "ocs_wins_vs_scaleup": sum(ocs_vs_scaleup),
        "ocs_cost_between_mesh_and_scaleup":
            costs["torus"] < costs["ocs"] < costs["scale-up"],
        "ocs_within_15pct_of_frontier": ocs_ratio >= 0.85,
        "ocs_frontier_tpc_ratio": round(ocs_ratio, 3),
        "frontier_topologies": sorted({p.topology for p in frontier}),
    }
    if verbose:
        print(out)
        print("\nclaims:", results["claims"])
    save(f"fig_ocs_{n}", results)
    return results


if __name__ == "__main__":
    run()
