"""Paper Fig 10: effect of TPOT SLO and context length on max throughput
per XPU for two scale-up clusters (450 vs 150 GB/s).

Trends: throughput rises with relaxed TPOT; clusters converge at tight
TPOT (beta-term negligible at small batch); long context narrows the gap
(memory-capacity-capped batch)."""
from __future__ import annotations

from benchmarks.common import save, solve_points, table
from repro.configs import get_arch
from repro.core import H100, Scenario, make_cluster


def run(verbose: bool = True):
    cfg = get_arch("deepseek-v3")
    tpots = (10.0, 15.0, 20.0, 40.0, 60.0, 100.0)
    ctxs = (512, 4096, 8192)
    bws = (450e9, 150e9)
    clusters = [make_cluster("scale-up", 64, H100, link_bw=bw) for bw in bws]
    scenarios = [Scenario(t, c) for c in ctxs for t in tpots]
    # one batched grid evaluation for the whole 2-cluster x 18-scenario sweep
    ops = solve_points(cfg, clusters, scenarios)

    results = {}
    rows = []
    for si, sc in enumerate(scenarios):
        row = [sc.context, int(sc.tpot_ms)]
        for ci, bw in enumerate(bws):
            op = ops[ci][si]
            n_xpus = clusters[ci].n_xpus
            key = f"ctx{sc.context}/bw{int(bw / 1e9)}"
            if op is None:
                row += ["miss", "-"]
                results.setdefault(key, []).append(
                    {"tpot_ms": sc.tpot_ms, "thpt_per_xpu": 0.0, "batch": 0})
            else:
                row += [f"{op.throughput / n_xpus:.0f}", op.batch]
                results.setdefault(key, []).append(
                    {"tpot_ms": sc.tpot_ms,
                     "thpt_per_xpu": op.throughput / n_xpus,
                     "batch": op.batch})
        rows.append(row)
    out = table(["ctx", "TPOT ms", "450: tok/s/XPU", "B", "150: tok/s/XPU",
                 "B"], rows, title="Fig 10 — scenario sweep (no sw opts)")

    def ratio(ctx, i):
        a = results[f"ctx{ctx}/bw450"][i]["thpt_per_xpu"]
        b = results[f"ctx{ctx}/bw150"][i]["thpt_per_xpu"]
        return b / a if a else 1.0

    results["claims"] = {
        # gap small at tight TPOT, wide at relaxed (ctx 512)
        "converge_at_tight_tpot": ratio(512, 1) > ratio(512, 5),
        # long context narrows the relaxed-TPOT gap
        "long_ctx_narrows_gap": ratio(8192, 5) > ratio(512, 5),
    }
    if verbose:
        print(out)
        print("\nclaims:", results["claims"])
    save("fig10_scenarios", results)
    return results


if __name__ == "__main__":
    run()
