"""Skewed expert routing x topology x placement (beyond-paper experiment).

The paper prices MoE all-to-all under uniform expert routing. This figure
asks what realistic routing skew does to the Table-3 topology ranking: a
Zipf(s) expert popularity (per-layer draws, `Scenario(routing="zipf")`)
makes grouped GEMM and A2A payload scale with the HOTTEST rank's load, and
the replication/placement search (`placement="auto"`) spends HBM headroom
on replicas of hot experts to flatten it back.

Questions answered (asserted in `claims`):
  * skew never improves throughput/$ — load factors are >= 1 and every
    schedule map is monotone, so each s>0 cell is bounded by its s=0 cell;
  * placement never loses — the R=0 arm is always searched first and only
    strictly better replicated arms replace it;
  * info: does the switchless (torus/fullmesh) cost-effectiveness win over
    scale-up survive skew, with and without placement?

High-skew low-SLO cells can be infeasible (throughput 0) — that is a
finding, not an error: at ep=64 and s=1.0 the hottest rank carries ~11-16x
the uniform expert load, which placement buys back almost entirely."""
from __future__ import annotations

from benchmarks.common import save, solve_points, table
from repro.configs import get_arch
from repro.core import H100, Scenario, make_cluster
from repro.core.tco import cluster_tco

TOPOS = ("scale-up", "scale-out", "torus", "fullmesh")
ZIPF_S = (0.0, 0.6, 1.0, 1.4)
BASE = [(t, c) for c in (512, 4096) for t in (15.0, 40.0, 100.0)]


def _scenario(tpot, ctx, s):
    if s == 0.0:
        return Scenario(tpot, ctx)
    return Scenario(tpot, ctx, routing="zipf", zipf_s=s)


def run(verbose: bool = True, n: int = 64):
    cfg = get_arch("deepseek-v3")
    clusters = [make_cluster(topo, n, H100) for topo in TOPOS]
    costs = [cluster_tco(cl).per_xpu(n) for cl in clusters]

    results = {"zipf_s": list(ZIPF_S)}
    rows = []
    for s in ZIPF_S:
        scenarios = [_scenario(t, c, s) for (t, c) in BASE]
        plain = solve_points(cfg, clusters, scenarios, opts="dbo+sd")
        placed = solve_points(cfg, clusters, scenarios, opts="dbo+sd",
                              placement="auto")
        per_s = {}
        for si, (tpot, ctx) in enumerate(BASE):
            per_topo = {}
            for ti, topo in enumerate(TOPOS):
                cell = {}
                for key, grid in (("none", plain), ("auto", placed)):
                    op = grid[ti][si]
                    cell[key] = {
                        "thpt_per_xpu": (op.throughput / n) if op else 0.0,
                        "thpt_per_cost": (op.throughput / n / costs[ti])
                                         if op else 0.0,
                        "batch": op.batch if op else 0,
                        "extra_experts": op.extra_experts if op else 0}
                per_topo[topo] = cell
            key = f"tpot{tpot:g}_ctx{ctx}"
            per_s[key] = per_topo
            if ctx == 4096:
                rows.append([f"s={s:g} {key}"] + [
                    f"{per_topo[t]['none']['thpt_per_cost']:.2f}/"
                    f"{per_topo[t]['auto']['thpt_per_cost']:.2f}"
                    f"(R{per_topo[t]['auto']['extra_experts']})"
                    for t in TOPOS])
        results[f"s{s:g}"] = per_s

    def cells(s, key):
        return [results[f"s{s:g}"][b][t][key]
                for b in results["s0"] for t in TOPOS]

    skew_never_improves = all(
        sv["thpt_per_cost"] <= uv["thpt_per_cost"] + 1e-9
        for s in ZIPF_S[1:]
        for sv, uv in zip(cells(s, "none"), cells(0.0, "none")))
    placement_never_loses = all(
        c["auto"]["thpt_per_cost"] >= c["none"]["thpt_per_cost"] - 1e-9
        for s in ZIPF_S
        for b in results[f"s{s:g}"].values() for c in b.values())

    def switchless_wins(s, key):
        wins = []
        for b in results[f"s{s:g}"].values():
            su = b["scale-up"][key]["thpt_per_cost"]
            sl = max(b["torus"][key]["thpt_per_cost"],
                     b["fullmesh"][key]["thpt_per_cost"])
            if su or sl:
                wins.append(sl >= su)
        return all(wins)

    results["claims"] = {
        "skew_never_improves_thpt_per_cost": skew_never_improves,
        "placement_never_loses": placement_never_loses,
        "switchless_win_survives_skew_unplaced": {
            f"s{s:g}": switchless_wins(s, "none") for s in ZIPF_S},
        "switchless_win_survives_skew_placed": {
            f"s{s:g}": switchless_wins(s, "auto") for s in ZIPF_S},
    }
    assert skew_never_improves, "a skewed cell beat its uniform twin"
    assert placement_never_loses, "placement='auto' lost to placement=None"

    out = table(["cell"] + [f"{t} tpc none/auto(R)" for t in TOPOS], rows,
                title=f"fig_skew — Zipf expert skew x placement ({n} XPUs,"
                      " DBO+SD, ctx 4096)")
    if verbose:
        print(out)
        print("\nclaims:", results["claims"])
    save("fig_skew", results)
    return results


if __name__ == "__main__":
    run()
