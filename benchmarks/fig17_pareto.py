"""Paper Fig 17: performance-cost Pareto frontier over (topology, link BW,
cluster size).

Headline: full-mesh forms the Pareto frontier in all serving scenarios;
torus tracks it at lower throughput; scale-out misses entirely; scale-up
wins raw throughput/XPU but not throughput/cost."""
from __future__ import annotations

from benchmarks.common import save, table
from repro.configs import get_arch
from repro.core import H100, Scenario
from repro.core.pareto import pareto_frontier, sweep_networks

SCENARIOS = [Scenario(t, c) for c in (512, 4096) for t in (15.0, 40.0, 100.0)]


def run(verbose: bool = True):
    cfg = get_arch("deepseek-v3")
    results = {}
    fm_on_frontier, so_on_frontier = [], []
    for sc in SCENARIOS:
        points = sweep_networks(cfg, sc, H100)
        frontier = pareto_frontier(points)
        results[sc.name] = {
            "points": [vars(p) for p in points],
            "frontier": [vars(p) for p in frontier],
        }
        topos_on = {p.topology for p in frontier}
        fm_on_frontier.append("fullmesh" in topos_on)
        so_on_frontier.append("scale-out" in topos_on)
        if verbose:
            rows = [[p.topology, p.n_xpus, f"{p.link_bw / 1e9:.0f}",
                     f"{p.cost_per_xpu:.0f}", f"{p.throughput_per_xpu:.0f}",
                     f"{p.throughput_per_cost:.2f}"] for p in frontier]
            print(table(["topology", "N", "BW GB/s", "cost/XPU", "thpt/XPU",
                         "thpt/cost"], rows,
                        title=f"Fig 17 frontier — {sc.name}"))
            print()

    # best throughput-per-cost point per scenario
    best_rows = []
    fm_best = []
    for sc in SCENARIOS:
        pts = results[sc.name]["points"]
        best = max(pts, key=lambda p: p["throughput_per_cost"])
        fm_best.append(best["topology"] == "fullmesh")
        best_rows.append([sc.name, best["topology"], best["n_xpus"],
                          f"{best['link_bw'] / 1e9:.0f}GB/s",
                          f"{best['throughput_per_cost']:.2f}"])
    results["claims"] = {
        "fullmesh_on_frontier_everywhere": all(fm_on_frontier),
        "fullmesh_best_tpc_fraction": sum(fm_best) / len(fm_best),
        "scaleout_never_on_frontier": not any(so_on_frontier),
    }
    if verbose:
        print(table(["scenario", "best topo", "N", "BW", "thpt/cost"],
                    best_rows, title="Fig 17 — best thpt/cost point"))
        print("\nclaims:", results["claims"])
    save("fig17_pareto", results)
    return results


if __name__ == "__main__":
    run()
