"""Paper Fig 16: 64 vs 256 XPUs (EP64 vs EP256).

Trends: throughput/cost DROPS at 256 in the 40-100ms regimes for every
topology (bigger A2A domain, no compute-efficiency gain); the drop is worst
for scale-up (two-level fat-tree); some low-TPOT scenarios improve (1
expert/GPU cuts weight-load time at small batch)."""
from __future__ import annotations

from benchmarks.common import save, solve_points, table
from repro.configs import get_arch
from repro.core import H100, Scenario, make_cluster
from repro.core.tco import cluster_tco

TOPOS = ("scale-up", "torus", "fullmesh")


def run(verbose: bool = True):
    cfg = get_arch("deepseek-v3")
    scenarios = [Scenario(t, 512) for t in (15.0, 40.0, 100.0)]
    # one batched grid call per cluster size (grids must share n_xpus)
    clusters = {n: [make_cluster(topo, n, H100) for topo in TOPOS]
                for n in (64, 256)}
    grids = {n: solve_points(cfg, cls, scenarios, opts="dbo+sd")
             for n, cls in clusters.items()}
    results = {}
    rows = []
    for si, sc in enumerate(scenarios):
        for ti, topo in enumerate(TOPOS):
            row = [sc.name, topo]
            for n in (64, 256):
                cost = cluster_tco(clusters[n][ti]).per_xpu(n)
                op = grids[n][ti][si]
                tpx = (op.throughput / n) if op else 0.0
                results[f"{sc.name}/{topo}/{n}"] = {
                    "thpt_per_xpu": tpx, "thpt_per_cost": tpx / cost,
                    "cost_per_xpu": cost, "batch": op.batch if op else 0}
                row += [f"{tpx:.0f}", f"{tpx / cost:.2f}"]
            rows.append(row)
    out = table(["scenario", "topology", "64: thpt/XPU", "t/c",
                 "256: thpt/XPU", "t/c"], rows,
                title="Fig 16 — cluster-size scaling (DBO+SD)")

    def tc(sc, topo, n):
        return results[f"{sc}/{topo}/{n}"]["thpt_per_cost"]

    drop_4090 = all(tc(f"tpot{t}ms_ctx512", topo, 256)
                    < tc(f"tpot{t}ms_ctx512", topo, 64)
                    for t in (40, 100) for topo in TOPOS)
    su_drop = (tc("tpot40ms_ctx512", "scale-up", 256)
               / tc("tpot40ms_ctx512", "scale-up", 64))
    fm_drop = (tc("tpot40ms_ctx512", "fullmesh", 256)
               / tc("tpot40ms_ctx512", "fullmesh", 64))
    results["claims"] = {
        "tpc_drops_at_256_relaxed_slo": bool(drop_4090),
        "scaleup_drop_worse_than_fullmesh": bool(su_drop < fm_drop),
        "scaleup_tpc_ratio_256v64": su_drop,
        "fullmesh_tpc_ratio_256v64": fm_drop,
    }
    if verbose:
        print(out)
        print("\nclaims:", results["claims"])
    save("fig16_scale", results)
    return results


if __name__ == "__main__":
    run()
