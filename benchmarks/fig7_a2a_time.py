"""Paper Fig 7: A2A communication time per topology vs message size.

Orderings the paper reads off this figure: scale-up best on both alpha and
beta terms; full-mesh beats torus on both thanks to higher connectivity."""
from __future__ import annotations

from benchmarks.common import ascii_curve, save, table
from repro.core import H100, make_cluster


def run(verbose: bool = True):
    sizes = [2**k for k in range(10, 31, 2)]        # 1 KiB .. 1 GiB
    topos = ("scale-up", "fullmesh", "torus", "scale-out")
    results = {}
    rows = []
    for n in (64, 256):
        clusters = {t: make_cluster(t, n, H100) for t in topos}
        for m in sizes:
            row = [n, f"{m / 2**20:.3g} MiB"]
            for t in topos:
                dt = clusters[t].a2a_time(m)
                row.append(f"{dt * 1e6:.1f}")
                results.setdefault(f"{t}/{n}", []).append(
                    {"m_bytes": m, "t_us": dt * 1e6})
            rows.append(row)
    out = table(["N", "msg", *(f"{t} us" for t in topos)], rows,
                title="Fig 7 — A2A time by topology")
    ordering_ok = all(
        results[f"scale-up/{n}"][i]["t_us"]
        <= results[f"fullmesh/{n}"][i]["t_us"]
        <= results[f"torus/{n}"][i]["t_us"]
        for n in (64, 256) for i in range(len(sizes)))
    if verbose:
        print(out)
        print(f"\nordering scale-up <= fullmesh <= torus holds: {ordering_ok}")
        xs = [r["m_bytes"] for r in results["torus/64"]]
        ys = [r["t_us"] for r in results["torus/64"]]
        print(ascii_curve([float(i) for i in range(len(xs))], ys,
                          label="torus/64 A2A us vs log2 msg"))
    results["ordering_ok"] = ordering_ok
    save("fig7_a2a_time", results)
    return results


if __name__ == "__main__":
    run()
