"""Paper Table 1: alpha-beta model fitting methodology.

We have no DGX to measure NCCL on; instead we validate the FITTING CODE the
paper's Table 1 came from: generate synthetic collective timings from a
ground-truth extended-Hockney model (plus measurement noise), run the fit,
and report recovered parameters + mean relative error — the same two
quantities the paper reports (MRE 10.82% intra / 7.97% inter)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import save, table
from repro.core import alphabeta as ab


def run(verbose: bool = True):
    rng = np.random.default_rng(42)
    results = {}
    rows = []
    for name, truth, bw, noise in (
            ("intra-node", ab.INTRA_NODE, 450e9, 0.08),
            ("inter-node", ab.INTER_NODE, 50e9, 0.06)):
        # sweep like the paper: message sizes 128B..16GiB, 4..32 XPUs
        sizes = np.exp(np.linspace(np.log(128), np.log(16 * 2**30), 18))
        ns = [4, 8, 16, 32]
        rounds, dests, ms, times = [], [], [], []
        for n in ns:
            for m in sizes:
                # P2P-style collective: R=1, D=n-1, coeff~(n-1)/n
                r, d_, c = 1, n - 1, (n - 1) / n
                t = truth.time(rounds=r, dests=d_, m_coeff=c, m_bytes=m,
                               bandwidth=bw)
                rounds.append(r)
                dests.append(d_)
                ms.append(c * m)
                times.append(t * (1 + rng.normal(0, noise)))
        fit = ab.fit_alpha_beta(rounds, dests, ms, bw, times)
        model = [fit.time(rounds=r, dests=d_, m_coeff=1.0, m_bytes=m,
                          bandwidth=bw)
                 for r, d_, m in zip(rounds, dests, ms)]
        mre = ab.mean_relative_error(model, times)
        results[name] = {
            "fit": {"alpha0_us": fit.alpha0 * 1e6,
                    "alpha_r_us": fit.alpha_r * 1e6,
                    "alpha_d_us": fit.alpha_d * 1e6,
                    "link_utilization": fit.link_utilization},
            "truth": {"alpha0_us": truth.alpha0 * 1e6,
                      "alpha_r_us": truth.alpha_r * 1e6,
                      "alpha_d_us": truth.alpha_d * 1e6,
                      "link_utilization": truth.link_utilization},
            "mre": mre,
        }
        rows.append([name,
                     f"{fit.alpha0 * 1e6:.2f}/{truth.alpha0 * 1e6:.2f}",
                     f"{fit.alpha_r * 1e6:.2f}/{truth.alpha_r * 1e6:.2f}",
                     f"{fit.alpha_d * 1e6:.3f}/{truth.alpha_d * 1e6:.3f}",
                     f"{fit.link_utilization:.3f}/{truth.link_utilization:.3f}",
                     f"{mre * 100:.2f}%"])
    out = table(["regime", "a0 us (fit/true)", "ar us", "ad us",
                 "util", "MRE"], rows,
                title="Table 1 — alpha-beta fit recovery (paper MRE: "
                      "10.82% intra / 7.97% inter)")
    if verbose:
        print(out)
    results["paper_mre"] = {"intra": 0.1082, "inter": 0.0797}
    save("table1_alphabeta", results)
    return results


if __name__ == "__main__":
    run()
