"""Overlap-aware prefill serving: DBO vs no-overlap topology rankings
(new figure; extends fig_prefill_scenarios with the three-lane (max,+)
schedule threaded through the prefill modes).

Grid: prompt length x TTFT SLO x Table-3 topology, DeepSeek-V3, 64 XPUs,
TPOT SLO 40 ms. Both prefill serving modes (chunked, disaggregated) are
searched twice per cell — no-overlap (`dbo=False`, the committed
fig_prefill_scenarios timing) and DBO (`dbo=True`: decode iterations split
into B/2 microbatches, prefill chunks and the disagg whole-prompt pass
into causal half-chunks; A2A/AR hide under the other microbatch's GEMMs,
pp hops ride the dedicated send/recv lane).

Expected trends (MixServe arXiv 2601.08800, MixNet/MFABRIC 2501.03905:
overlap-aware scheduling is what makes lower-bandwidth fabrics
competitive): DBO can only help (each component is best-of(no-overlap,
monotone schedule)); the gains concentrate on the bandwidth-constrained
fabrics whose exposed A2A the no-overlap timing overstates, while the
fully-provisioned scale-up switch — already compute-bound — gains least,
narrowing (and sometimes re-ordering) the topology ranking.
"""
from __future__ import annotations

from benchmarks.common import save, solve_points, table
from repro.configs import get_arch
from repro.core import H100, Scenario, make_cluster

TOPOS = ("scale-up", "scale-out", "torus", "fullmesh")
PROMPTS = (512, 2048, 8192)
TTFTS_MS = (500.0, 2000.0)
TPOT_MS = 40.0
GEN_LEN = 1024          # decode tokens per request; avg context = L + GEN/2
MODES = ("chunked", "disagg")


def run(verbose: bool = True):
    cfg = get_arch("deepseek-v3")
    clusters = [make_cluster(t, 64, H100) for t in TOPOS]
    scenarios = [Scenario(TPOT_MS, L + GEN_LEN // 2, prompt_len=L,
                          ttft_ms=T)
                 for L in PROMPTS for T in TTFTS_MS]
    grids = {(mode, dbo): solve_points(cfg, clusters, scenarios, mode=mode,
                                       dbo=dbo)
             for mode in MODES for dbo in (False, True)}

    results = {}
    rows = []
    gains = {t: [] for t in TOPOS}       # relative best-mode gains per topo
    never_worse = True
    strict_cells = []
    ect_drops = []
    for si, sc in enumerate(scenarios):
        best_thpt = {False: {}, True: {}}
        for ti, topo in enumerate(TOPOS):
            n = clusters[ti].n_xpus
            entry = {}
            for mode in MODES:
                for dbo in (False, True):
                    op = grids[mode, dbo][ti][si]
                    key = f"{mode}_dbo" if dbo else mode
                    if op is None:
                        entry[key] = None
                        continue
                    entry[key] = {
                        "thpt_per_xpu": op.throughput / n,
                        "tpot_ms": op.tpot * 1e3,
                        "ttft_ms": op.ttft * 1e3,
                        "batch": op.batch,
                        "chunk": op.chunk,
                        "n_prefill_xpus": op.n_prefill_xpus,
                        "exposed_comm_frac": (op.exposed_comm / op.tpot
                                              if op.tpot else 0.0),
                    }
                t0 = (entry[mode] or {"thpt_per_xpu": 0.0})["thpt_per_xpu"]
                t1 = (entry[f"{mode}_dbo"]
                      or {"thpt_per_xpu": 0.0})["thpt_per_xpu"]
                never_worse &= t1 >= t0 * (1 - 1e-12)
                if t1 > t0 * (1 + 1e-9):
                    strict_cells.append([mode, topo, sc.name])
                if entry[mode] and entry[f"{mode}_dbo"]:
                    ect_drops.append(
                        entry[mode]["exposed_comm_frac"]
                        - entry[f"{mode}_dbo"]["exposed_comm_frac"])
            for dbo in (False, True):
                best_thpt[dbo][topo] = max(
                    (entry[k]["thpt_per_xpu"]
                     for k in (m + ("_dbo" if dbo else "") for m in MODES)
                     if entry[k]), default=0.0)
            if best_thpt[False][topo] > 0:
                gains[topo].append(best_thpt[True][topo]
                                   / best_thpt[False][topo] - 1.0)
            results.setdefault(sc.name, {})[topo] = entry
            rows.append([sc.prompt_len, int(sc.ttft_ms), topo]
                        + [f"{best_thpt[d][topo]:.0f}" for d in (False, True)]
                        + [(f"{(best_thpt[True][topo] / best_thpt[False][topo] - 1) * 100:+.1f}%"
                            if best_thpt[False][topo] else "-")])
        ranking = {("dbo" if d else "noopt"):
                   sorted(TOPOS, key=lambda t: -best_thpt[d][t])
                   for d in (False, True)}
        results[sc.name]["ranking"] = ranking
    out = table(["prompt", "TTFT ms", "topology", "best no-ovl tok/s/XPU",
                 "best DBO", "gain"], rows,
                title="Prefill overlap vs no-overlap (DeepSeek-V3, 64 XPU, "
                      "TPOT 40 ms, best of chunked/disagg)")

    mean_gain = {t: (sum(g) / len(g) if g else 0.0) for t, g in gains.items()}
    ranking_shifts = [[sc, r["noopt"], r["dbo"]]
                      for sc, r in ((s, results[s]["ranking"])
                                    for s in results if s != "claims")
                      if r["noopt"] != r["dbo"]]
    results["claims"] = {
        # the monotone (max,+) schedule can only help: every searched
        # operating point with DBO is at least the no-overlap one
        "overlap_never_worse": never_worse,
        # and it must MATTER somewhere, else the lanes are dead weight
        "overlap_strictly_helps_somewhere": bool(strict_cells),
        # the paper-motivating trend: the fully-provisioned scale-up
        # switch is already compute-bound, so every bandwidth-constrained
        # fabric gains at least as much from overlap as scale-up does
        "low_bw_fabrics_gain_most": all(
            mean_gain[t] >= mean_gain["scale-up"] - 1e-12
            for t in TOPOS),
        # overlap hides communication: the exposed-comm fraction of the
        # chosen operating points shrinks ON AVERAGE. (Not pointwise: at a
        # FIXED point DBO only hides comm, but the search may move to a
        # larger batch/chunk whose bigger collectives trade a higher
        # exposure fraction for more throughput — that is the search
        # working, not overlap failing.)
        "exposed_comm_shrinks_on_average": (
            bool(ect_drops) and sum(ect_drops) / len(ect_drops) > 0),
        "mean_gain_by_topology": mean_gain,
        "strict_cells": strict_cells,
        "ranking_shifts": ranking_shifts,
    }
    if verbose:
        print(out)
        print("\nclaims:", {k: v for k, v in results["claims"].items()
                            if isinstance(v, bool)})
        print("mean gain by topology:",
              {t: f"{g * 100:+.1f}%" for t, g in mean_gain.items()})
    save("fig_prefill_overlap", results)
    return results


if __name__ == "__main__":
    run()
