"""Paper Fig 9: latency and throughput as batch size grows (two scale-up
clusters, 450 vs 150 GB/s, context 512).

Trends: TPOT grows sublinearly at small batch (memory-bound compute +
alpha-dominated comm); throughput = B/TPOT keeps rising; the beta-term gap
between the clusters appears once messages are large.

Runs on the batched sweep engine: one op table, one vectorized evaluation
over the (cluster, batch) grid instead of per-point `iteration_time` calls.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save, table
from repro.configs import get_arch
from repro.core import H100, make_cluster
from repro.core import optable, sweep


def run(verbose: bool = True):
    cfg = get_arch("deepseek-v3")
    batches = [64, 256, 1024, 4096, 8192, 16384, 32768, 65536]
    clusters = [make_cluster("scale-up", 64, H100, link_bw=450e9),
                make_cluster("scale-up", 64, H100, link_bw=150e9)]
    op_table = optable.op_table(cfg, 1, 64, 64, "fp8")
    t, tc, tm = sweep.batched_iteration_components(
        op_table, clusters, np.array(batches), context=512)

    results = {"450": [], "150": []}
    rows = []
    for bi, b in enumerate(batches):
        row = [b]
        for ci, key in ((0, "450"), (1, "150")):
            ti = float(t[ci, bi])
            n_xpus = clusters[ci].n_xpus
            results[key].append({"batch": b, "tpot_ms": ti * 1e3,
                                 "t_comp_ms": float(tc[ci, bi]) * 1e3,
                                 "t_comm_ms": float(tm[ci, bi]) * 1e3,
                                 "thpt_per_xpu": b / ti / n_xpus})
            row += [f"{ti * 1e3:.2f}", f"{b / ti / n_xpus:.0f}"]
        rows.append(row)
    out = table(["batch", "TPOT@450 ms", "tok/s/XPU", "TPOT@150 ms",
                 "tok/s/XPU"], rows,
                title="Fig 9 — batch vs latency/throughput (scale-up 64)")

    # claims: sublinear TPOT growth at small batch; throughput monotone
    t0, t1 = results["450"][0]["tpot_ms"], results["450"][2]["tpot_ms"]
    sublinear = t1 / t0 < batches[2] / batches[0]
    thpt = [r["thpt_per_xpu"] for r in results["450"]]
    monotone = all(a <= b * 1.001 for a, b in zip(thpt, thpt[1:]))
    gap_small = results["450"][0]["tpot_ms"] / results["150"][0]["tpot_ms"]
    gap_big = results["450"][-1]["tpot_ms"] / results["150"][-1]["tpot_ms"]
    results["claims"] = {
        "tpot_sublinear_small_batch": bool(sublinear),
        "throughput_monotone": bool(monotone),
        "beta_gap_grows_with_batch": bool(gap_big < gap_small),
    }
    if verbose:
        print(out)
        print("\nclaims:", results["claims"])
    save("fig9_batch_sweep", results)
    return results


if __name__ == "__main__":
    run()
