"""Paper Fig 9: latency and throughput as batch size grows (two scale-up
clusters, 450 vs 150 GB/s, context 512).

Trends: TPOT grows sublinearly at small batch (memory-bound compute +
alpha-dominated comm); throughput = B/TPOT keeps rising; the beta-term gap
between the clusters appears once messages are large."""
from __future__ import annotations

from benchmarks.common import fmt_bw, save, table
from repro.configs import get_arch
from repro.core import H100, make_cluster
from repro.core.optimizer import iteration_time
from repro.core.workload import ServingPoint


def run(verbose: bool = True):
    cfg = get_arch("deepseek-v3")
    batches = [64, 256, 1024, 4096, 8192, 16384, 32768, 65536]
    results = {"450": [], "150": []}
    rows = []
    for b in batches:
        row = [b]
        for bw, key in ((450e9, "450"), (150e9, "150")):
            cl = make_cluster("scale-up", 64, H100, link_bw=bw)
            p = ServingPoint(batch_global=b, context=512, ep=64, n_devices=64)
            t, _, tc, tm = iteration_time(cfg, p, cl, dbo=False)
            results[key].append({"batch": b, "tpot_ms": t * 1e3,
                                 "t_comp_ms": tc * 1e3, "t_comm_ms": tm * 1e3,
                                 "thpt_per_xpu": b / t / 64})
            row += [f"{t * 1e3:.2f}", f"{b / t / 64:.0f}"]
        rows.append(row)
    out = table(["batch", "TPOT@450 ms", "tok/s/XPU", "TPOT@150 ms",
                 "tok/s/XPU"], rows,
                title="Fig 9 — batch vs latency/throughput (scale-up 64)")

    # claims: sublinear TPOT growth at small batch; throughput monotone
    t0, t1 = results["450"][0]["tpot_ms"], results["450"][2]["tpot_ms"]
    sublinear = t1 / t0 < batches[2] / batches[0]
    thpt = [r["thpt_per_xpu"] for r in results["450"]]
    monotone = all(a <= b * 1.001 for a, b in zip(thpt, thpt[1:]))
    gap_small = results["450"][0]["tpot_ms"] / results["150"][0]["tpot_ms"]
    gap_big = results["450"][-1]["tpot_ms"] / results["150"][-1]["tpot_ms"]
    results["claims"] = {
        "tpot_sublinear_small_batch": bool(sublinear),
        "throughput_monotone": bool(monotone),
        "beta_gap_grows_with_batch": bool(gap_big < gap_small),
    }
    if verbose:
        print(out)
        print("\nclaims:", results["claims"])
    save("fig9_batch_sweep", results)
    return results


if __name__ == "__main__":
    run()
