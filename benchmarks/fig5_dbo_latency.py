"""Paper Fig 5 + Fig 6: DBO mechanics.

Fig 5: per-iteration latency & throughput in two scale-up clusters
(450 vs 150 GB/s link BW), DeepSeek-V3, EP64, global batch 32768 tokens:
DBO lets the low-BW cluster match the high-BW one.

Fig 6: DBO is beneficial only at sufficiently large batch sizes — at small
batch the layers are memory-bandwidth-bound and splitting the batch nearly
doubles compute time."""
from __future__ import annotations

from benchmarks.common import fmt_bw, save, table
from repro.configs import get_arch
from repro.core import H100, make_cluster
from repro.core.optimizer import iteration_time
from repro.core.workload import ServingPoint


def run(verbose: bool = True):
    cfg = get_arch("deepseek-v3")
    results = {"fig5": [], "fig6": []}

    # ---- Fig 5: batch 32768 tokens over 64 XPUs, 2 link BWs ----
    rows = []
    for bw in (450e9, 150e9):
        cl = make_cluster("scale-up", 64, H100, link_bw=bw)
        p = ServingPoint(batch_global=32768, context=512, ep=64, n_devices=64)
        t_no, ect_no, tc, tm = iteration_time(cfg, p, cl, dbo=False)
        t_dbo, ect_dbo, _, _ = iteration_time(cfg, p, cl, dbo=True)
        rows.append([fmt_bw(bw), f"{t_no * 1e3:.1f}", f"{t_dbo * 1e3:.1f}",
                     f"{ect_no * 1e3:.2f}", f"{ect_dbo * 1e3:.2f}",
                     f"{32768 / t_dbo / cl.n_xpus:.0f}"])
        results["fig5"].append({
            "link_bw": bw, "t_noopt_ms": t_no * 1e3, "t_dbo_ms": t_dbo * 1e3,
            "ect_noopt_ms": ect_no * 1e3, "ect_dbo_ms": ect_dbo * 1e3,
            "thpt_dbo_per_xpu": 32768 / t_dbo / cl.n_xpus})
    t5 = table(["link BW", "t no-overlap ms", "t DBO ms", "ECT no ms",
                "ECT DBO ms", "tok/s/XPU (DBO)"], rows,
               title="Fig 5 — DBO closes the 450 vs 150 GB/s gap "
                     "(DeepSeek-V3, EP64, B=32768)")

    # ---- Fig 6: DBO benefit vs batch size ----
    rows6 = []
    cl = make_cluster("scale-up", 64, H100, link_bw=450e9)
    for b in (256, 512, 1024, 4096, 16384, 32768, 65536):
        p = ServingPoint(batch_global=b, context=512, ep=64, n_devices=64)
        t_no, *_ = iteration_time(cfg, p, cl, dbo=False)
        t_dbo, *_ = iteration_time(cfg, p, cl, dbo=True)
        gain = (t_no - t_dbo) / t_no * 100
        rows6.append([b, f"{t_no * 1e3:.2f}", f"{t_dbo * 1e3:.2f}",
                      f"{gain:+.1f}%"])
        results["fig6"].append({"batch": b, "t_noopt_ms": t_no * 1e3,
                                "t_dbo_ms": t_dbo * 1e3,
                                "dbo_gain_pct": gain})
    t6 = table(["batch", "t no-overlap ms", "t DBO ms", "DBO gain"], rows6,
               title="Fig 6 — DBO helps only at large batch (small batch: "
                     "memory-bound, splitting ~doubles compute)")

    if verbose:
        print(t5)
        print()
        print(t6)
    # claims
    small_gain = results["fig6"][0]["dbo_gain_pct"]
    big_gain = results["fig6"][-1]["dbo_gain_pct"]
    hi, lo = results["fig5"]
    # DBO must close most of the BW-induced latency gap (paper: 'a
    # lower-cost network can match the performance of expensive networks';
    # our anomaly-free schedule hides ~75% of the exposed comm — see
    # EXPERIMENTS.md for the delta discussion)
    gap_no = lo["t_noopt_ms"] - hi["t_noopt_ms"]
    gap_dbo = lo["t_dbo_ms"] - hi["t_dbo_ms"]
    results["claims"] = {
        "dbo_hurts_small_batch": small_gain <= 0.0,
        "dbo_helps_large_batch": big_gain > 0.0,
        "dbo_closes_most_of_bw_gap": gap_dbo < 0.5 * gap_no,
        "dbo_hides_most_ect": lo["ect_dbo_ms"] < 0.35 * lo["ect_noopt_ms"],
    }
    if verbose:
        print("\nclaims:", results["claims"])
    save("fig5_dbo_latency", results)
    return results


if __name__ == "__main__":
    run()
