"""Availability-adjusted topology ranking (fig14/fig17 under failures).

The paper's headline — switchless torus/full-mesh beat scale-up on
throughput/$ by 20.6-56.2% — is evaluated on a healthy 64-XPU cluster.
This figure re-scores the same ranking with the throughput numerator
replaced by the expected steady-state throughput under the stationary
component-failure distribution (`core/availability.py`): every fault
state up to two simultaneous failures is priced through the
failure-aware (tp, pp, ep) re-search and the remap-vs-degrade policy,
then weighted by its stationary probability at each failure-rate point.

The MTBF sweep scales every component class's MTBF by `mtbf_scale`
(1.0 = the documented defaults, <1 = sicker fleet); the crossover scan
reports the largest scale at which the best-switchless throughput/$ win
over scale-up is lost, if any, in the scanned range."""
from __future__ import annotations

from benchmarks.common import save, table
from repro.configs import get_arch
from repro.core import H100, Scenario, make_cluster
from repro.core.availability import (MTBF_MTTR_H, build_availability)
from repro.core.specdec import SpecDecConfig
from repro.core.tco import cluster_tco

TOPOS = ("scale-up", "scale-out", "torus", "fullmesh")
SCENARIOS = [Scenario(t, c) for c in (512, 4096) for t in (15.0, 40.0, 100.0)]
# mtbf_scale sweep points: 1.0 = documented per-class defaults
# (docs/failure_model.md); the decades either side cover optimistic
# fleets and the hostile tail where rankings could flip.
MTBF_SCALES = (10.0, 3.0, 1.0, 0.3, 0.1, 0.03, 0.01)
# finer log-spaced grid for the crossover scan (reweighting cached
# states is cheap; the degraded searches run once per topology)
_SCAN = [10.0 ** (1 - 4 * i / 120) for i in range(121)]


def _adjusted(models, costs, scale):
    """Availability-adjusted throughput/$ per topology at one scale."""
    return {t: models[t].report(scale).expected_throughput / costs[t]
            for t in TOPOS}


def _crossover(models, costs):
    """Largest scanned mtbf_scale where the best-switchless win over
    scale-up is lost (None if it survives the whole scanned range)."""
    for s in _SCAN:  # descending: healthy -> hostile
        adj = _adjusted(models, costs, s)
        best_sw = max(adj["torus"], adj["fullmesh"])
        if best_sw <= adj["scale-up"]:
            return s
    return None


def run(verbose: bool = True, n: int = 64):
    cfg = get_arch("deepseek-v3")
    clusters = {t: make_cluster(t, n, H100) for t in TOPOS}
    costs = {t: cluster_tco(clusters[t]).total() for t in TOPOS}
    results = {"mtbf_scales": list(MTBF_SCALES),
               "mtbf_mttr_h": {k: list(v) for k, v in MTBF_MTTR_H.items()}}
    rows = []
    crossovers = {}
    win_at_default = []
    for sc in SCENARIOS:
        # dbo+sd: the optimization level fig14's headline ranking uses
        models = {t: build_availability(clusters[t], cfg, sc, dbo=True,
                                        sd=SpecDecConfig())
                  for t in TOPOS}
        per_topo = {}
        for t in TOPOS:
            m = models[t]
            sweep = {}
            for s in MTBF_SCALES:
                r = m.report(s)
                sweep[f"{s:g}"] = {
                    "availability": r.availability,
                    "expected_thpt": r.expected_throughput,
                    "adjusted_thpt_per_cost":
                        r.expected_throughput / costs[t],
                    "tail_mass": r.tail_mass,
                    "transition_loss": r.transition_loss,
                }
            per_topo[t] = {
                "healthy_thpt": m.healthy_throughput,
                "healthy_thpt_per_cost": m.healthy_throughput / costs[t],
                "components": {c.name: c.count for c in m.classes},
                "actions": {a: sum(1 for st in m.states
                                   if st.action == a)
                            for a in ("keep", "remap", "down")},
                "sweep": sweep,
            }
        cross = _crossover(models, costs)
        crossovers[sc.name] = cross
        adj1 = _adjusted(models, costs, 1.0)
        win = max(adj1["torus"], adj1["fullmesh"]) > adj1["scale-up"]
        win_at_default.append(win)
        per_topo["crossover_mtbf_scale"] = cross
        per_topo["crossover_xpu_mtbf_h"] = (
            MTBF_MTTR_H["xpu"][0] * cross if cross is not None else None)
        results[sc.name] = per_topo
        rows.append([sc.name]
                    + [f"{adj1[t]:.2f}" for t in TOPOS]
                    + ["yes" if win else "no",
                       f"{cross:.3g}" if cross is not None else ">range"])
    out = table(["scenario"] + [f"{t} adj-tpc" for t in TOPOS]
                + ["switchless win @x1", "crossover scale"],
                rows, title=f"fig_failures — availability-adjusted "
                            f"throughput/$ ({n} XPUs)")
    finite = [c for c in crossovers.values() if c is not None]
    results["claims"] = {
        "switchless_win_survives_default_mtbf": all(win_at_default),
        "crossover_mtbf_scale_by_scenario": crossovers,
        "worst_crossover_mtbf_scale": max(finite) if finite else None,
        "scan_range_mtbf_scale": [min(_SCAN), max(_SCAN)],
        "sweep_points": len(MTBF_SCALES),
    }
    if verbose:
        print(out)
        print("\nclaims:", results["claims"])
    save(f"fig_failures_{n}", results)
    return results


if __name__ == "__main__":
    run()
