"""Paper Fig 18 + 19: forward projection to Blackwell and Rubin.

Fig 18: throughput vs link BW per topology on 256 XPUs, TPOT {10, 40} ms,
ctx {512, 4096}. Claim: Blackwell's 900 GB/s provision keeps switchless
competitive; Rubin's short-context low-TPOT corner needs ~2x provision for
full-mesh/torus to match scale-up (memory BW scales 6.57x vs link 4x).

Fig 19: driving alpha_r, alpha_d -> 0 restores full-mesh parity at
Rubin/TPOT=10ms."""
from __future__ import annotations

from benchmarks.common import save, table
from repro.configs import get_arch
from repro.core import Scenario
from repro.core.future import (generation_report,
                               saturating_bandwidth, throughput_vs_bandwidth)
from repro.core.hardware import RUBIN


def run(verbose: bool = True):
    cfg = get_arch("deepseek-v3")
    results = {}
    rows = []
    for gen in ("Blackwell", "Rubin"):
        for tpot in (10.0, 40.0):
            for ctx in (512, 4096):
                sc = Scenario(tpot, ctx)
                rep = generation_report(cfg, sc, gen, n=256)
                results[f"{gen}/{sc.name}"] = rep
                row = [gen, int(tpot), ctx]
                for topo in ("scale-up", "torus", "fullmesh"):
                    sat = rep["topologies"][topo]["saturating_bw"]
                    row.append("-" if sat is None else f"{sat / 1e9:.0f}")
                rows.append(row)
    out = table(["gen", "TPOT", "ctx", "scale-up sat GB/s", "torus",
                 "fullmesh"], rows,
                title="Fig 18 — saturating bandwidth vs provision "
                      "(Blackwell 900, Rubin 1800 GB/s)")

    # Fig 19: alpha scaling at Rubin, TPOT=10ms
    fig19 = {}
    for ctx in (512, 4096):
        sc = Scenario(10.0, ctx)
        grid = [1800e9 * f for f in (0.25, 0.5, 1.0, 2.0)]
        for a in (1.0, 0.0):
            for topo in ("scale-up", "fullmesh"):
                curve = throughput_vs_bandwidth(
                    cfg, sc, RUBIN, topo, 256, grid, alpha_scale=a)
                fig19[f"ctx{ctx}/alpha{a}/{topo}"] = [
                    (p.link_bw / 1e9, p.throughput_per_xpu) for p in curve]
    results["fig19"] = fig19

    def thpt_at(key, bw_gbs):
        pts = dict(fig19.get(key, []))
        return pts.get(bw_gbs, 0.0)

    def curve_at(gen, sc, topo, bw):
        pts = dict(results[f"{gen}/{sc}"]["topologies"][topo]["curve"])
        return pts.get(bw, 0.0)

    results["claims"] = {
        # Blackwell: in relaxed/long-context scenarios full-mesh reaches
        # (most of) scale-up's performance at the 900 GB/s provision.
        # (Our model places the SHORT-context 40ms boundary one generation
        # earlier than the paper — same mechanism, see EXPERIMENTS.md.)
        "blackwell_fullmesh_parity_long_ctx":
            curve_at("Blackwell", "tpot40ms_ctx4096", "fullmesh", 900e9)
            >= 0.85 * curve_at("Blackwell", "tpot40ms_ctx4096", "scale-up",
                               900e9),
        # Rubin caveat (paper section 4.5): short-context scenarios need
        # more than the 1800 provision for switchless parity
        "rubin_short_ctx_needs_more_bw":
            (results["Rubin/tpot10ms_ctx512"]["topologies"]["fullmesh"]
             ["saturating_bw"] or 1e18) > 1800e9,
        # Fig 19: driving alpha_r, alpha_d -> 0 substantially lifts
        # full-mesh at the Rubin provision (paper: removes the gap; our
        # model: >1.5x improvement, remaining gap is beta-term-bound)
        "alpha0_lifts_fullmesh":
            thpt_at("ctx512/alpha0.0/fullmesh", 1800.0)
            >= 1.5 * thpt_at("ctx512/alpha1.0/fullmesh", 1800.0),
        "alpha1_has_gap":
            thpt_at("ctx512/alpha1.0/fullmesh", 1800.0)
            < thpt_at("ctx512/alpha1.0/scale-up", 1800.0),
    }
    if verbose:
        print(out)
        print("\nclaims:", results["claims"])
    save("fig18_future", results)
    return results


if __name__ == "__main__":
    run()
