"""Paper Fig 11: effect of software optimizations on the throughput-TPOT
frontier (scale-up 64, ctx 512, 450 vs 150 vs 50 GB/s).

(a) DBO: falls back to baseline at small batch; 150 GB/s + DBO ~matches
    450 GB/s once TPOT > ~60 ms; 50 GB/s cannot catch up even with DBO.
(b) SD: extends DBO's effective regime into 40-60 ms and enables very low
    TPOT SLOs."""
from __future__ import annotations

from benchmarks.common import save, solve_level_points, table
from repro.configs import get_arch
from repro.core import H100, Scenario, make_cluster


def run(verbose: bool = True):
    cfg = get_arch("deepseek-v3")
    tpots = (10.0, 15.0, 25.0, 40.0, 60.0, 100.0)
    bws = (450e9, 150e9, 50e9)
    clusters = [make_cluster("scale-up", 64, H100, link_bw=bw) for bw in bws]
    scenarios = [Scenario(t, 512) for t in tpots]
    results = {}
    # one shared engine pass covers all three opts curves
    grids = solve_level_points(cfg, clusters, scenarios,
                               ("noopt", "dbo", "dbo+sd"))
    for opts in ("noopt", "dbo", "dbo+sd"):
        grid = grids[opts]
        for ci, bw in enumerate(bws):
            key = f"{opts}/bw{int(bw / 1e9)}"
            n_xpus = clusters[ci].n_xpus
            for si, tpot in enumerate(tpots):
                op = grid[ci][si]
                results.setdefault(key, []).append(
                    {"tpot_ms": tpot,
                     "thpt_per_xpu": (op.throughput / n_xpus) if op else 0.0,
                     "used_dbo": bool(op and op.used_dbo),
                     "used_sd": bool(op and op.used_sd)})

    rows = []
    for i, tpot in enumerate(tpots):
        row = [int(tpot)]
        for opts in ("noopt", "dbo", "dbo+sd"):
            for bw in bws:
                row.append(f"{results[f'{opts}/bw{int(bw/1e9)}'][i]['thpt_per_xpu']:.0f}")
        rows.append(row)
    hdr = ["TPOT"] + [f"{o}/{int(b/1e9)}" for o in ("noopt", "dbo", "dbo+sd")
                      for b in bws]
    out = table(hdr, rows, title="Fig 11 — software-optimization frontier "
                                 "(tok/s/XPU)")

    def at(opts, bw, i):
        return results[f"{opts}/bw{bw}"][i]["thpt_per_xpu"]

    i60 = tpots.index(60.0)
    i40 = tpots.index(40.0)
    i15 = tpots.index(15.0)
    results["claims"] = {
        # (a) 150+DBO approaches 450 at TPOT >= 60ms (paper: 'nearly
        # matches'; our anomaly-free DBO schedule reaches ~0.81-0.87 —
        # ratio reported below, delta discussed in EXPERIMENTS.md)
        "dbo_150_matches_450_at_60ms":
            at("dbo", 150, i60) > 0.80 * at("dbo", 450, i60),
        "dbo_150_over_450_ratio_60ms":
            at("dbo", 150, i60) / max(at("dbo", 450, i60), 1e-9),
        # (a) 50 GB/s cannot catch up even with DBO
        "dbo_50_cannot_catch_up":
            at("dbo", 50, i60) < 0.8 * at("dbo", 450, i60),
        # (b) SD narrows the 40ms gap vs DBO alone
        "sd_narrows_40ms_gap":
            (at("dbo+sd", 150, i40) / max(at("dbo+sd", 450, i40), 1e-9))
            >= (at("dbo", 150, i40) / max(at("dbo", 450, i40), 1e-9)) - 0.02,
        # (b) SD enables low-TPOT SLOs DBO alone misses ('SD is necessary
        # to meet TPOT=15ms')
        "sd_extends_low_tpot":
            at("dbo+sd", 450, i15) > at("dbo", 450, i15),
    }
    if verbose:
        print(out)
        print("\nclaims:", results["claims"])
    save("fig11_sw_opts", results)
    return results


if __name__ == "__main__":
    run()
