"""CI gate: fail when the sweep-heavy benchmark timings regress > MAX_RATIO
over the committed baseline.

  python benchmarks/check_timing.py --baseline <committed BENCH_sweep_timing.json> \
      --current bench_results/BENCH_sweep_timing.json [--max-ratio 2.0]

Only modules freshly timed in the current run are compared (the harness
merges prior timings for modules a filtered run skipped — those carry the
baseline values verbatim and would trivially pass). An absolute noise
floor keeps sub-second modules from tripping the ratio on a cold CI
runner: a module fails only if now > max(ratio * baseline, baseline + FLOOR_S).
"""
from __future__ import annotations

import argparse
import json
import sys

FLOOR_S = 5.0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--current", required=True)
    ap.add_argument("--max-ratio", type=float, default=2.0)
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        base = json.load(f)["modules"]
    with open(args.current) as f:
        cur = json.load(f)["modules"]

    failures = []
    for name, row in cur.items():
        now = row.get("now_s")
        was = base.get(name, {}).get("now_s")
        if now is None or was is None or now == was:
            continue        # not timed this run (merged from baseline)
        limit = max(args.max_ratio * was, was + FLOOR_S)
        status = "FAIL" if now > limit else "ok"
        print(f"[{status}] {name}: baseline {was:.2f}s -> now {now:.2f}s "
              f"(limit {limit:.2f}s)")
        if now > limit:
            failures.append(name)
    if failures:
        print(f"\nsweep timing regressed >{args.max_ratio}x (+{FLOOR_S}s "
              f"floor) in: {', '.join(failures)}", file=sys.stderr)
        return 1
    print("\nsweep timings within budget")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
