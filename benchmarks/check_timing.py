"""CI gate: fail when the sweep-heavy benchmark timings regress.

  python benchmarks/check_timing.py --baseline <committed BENCH_sweep_timing.json> \
      --current bench_results/BENCH_sweep_timing.json [--max-ratio 2.0]

Two gates per module, both enforced on every module freshly timed in the
current run (the harness merges prior timings for modules a filtered run
skipped — those carry the baseline values verbatim and would trivially
pass):

  ratio    now <= max(max_ratio * baseline, baseline + FLOOR_S) against the
           committed baseline timing. The absolute noise floor keeps
           sub-second modules from tripping the ratio on a cold CI runner.
  budget   now <= the module's own `budget_s` (written by benchmarks/run.py
           from BUDGETS_S) — an absolute per-benchmark ceiling, so modules
           that post-date the seed timings (fig_parallelism, fig_pipeline,
           fig_prefill_overlap, fig_failures) are gated too, and a
           legitimate baseline refresh cannot smuggle in an unbounded
           slowdown.

Both gates measure wall-clock on a shared CI runner, so a single noisy
neighbor can trip them without any code regression: a module that fails
is re-run once (fresh `benchmarks.run <module>` subprocess, which
rewrites the timing JSON) and only fails the gate if the re-run misses
too. `--no-retry` restores single-shot behavior for local bisection.

  --update-baseline rewrites the baseline file with the current run's
  timings (use after a change that legitimately grows the grid — e.g. the
  pp axis enlarging the candidate set — then commit the refreshed JSON).
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import subprocess
import sys

FLOOR_S = 5.0
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_modules(path: str) -> dict:
    with open(path) as f:
        return json.load(f)["modules"]


def _gate(name: str, row: dict, base: dict, max_ratio: float):
    """Evaluate one module's gates. Returns (now, limits, bad) where
    `limits` is [(kind, ceiling_s)] and `bad` the violated ones, or None
    when the module was not freshly timed this run."""
    now = row.get("now_s")
    was = base.get(name, {}).get("now_s")
    budget = row.get("budget_s")
    if now is None or now == was:
        return None         # not timed this run (merged from baseline)
    limits = []
    if was is not None:
        limits.append(("ratio", max(max_ratio * was, was + FLOOR_S)))
    if budget is not None:
        limits.append(("budget", float(budget)))
    if not limits:
        return None
    bad = [f"{what} {lim:.2f}s" for what, lim in limits if now > lim]
    return now, was, limits, bad


def _retry(name: str, current_path: str) -> bool:
    """Re-run one module through the harness (which rewrites the timing
    JSON at `current_path`). True if the subprocess completed."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(REPO_ROOT, "src"),
                    env.get("PYTHONPATH")) if p)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", name],
        cwd=REPO_ROOT, env=env,
        stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    return proc.returncode == 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--current", required=True)
    ap.add_argument("--max-ratio", type=float, default=2.0)
    ap.add_argument("--no-retry", action="store_true",
                    help="fail on the first miss instead of re-running "
                         "the module once (wall-clock gates are noisy)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite --baseline with the current timings "
                         "instead of gating (commit the result)")
    args = ap.parse_args(argv)

    if args.update_baseline:
        shutil.copyfile(args.current, args.baseline)
        print(f"baseline rewritten: {args.current} -> {args.baseline}")
        return 0

    base = _load_modules(args.baseline)
    cur = _load_modules(args.current)

    failures = []
    for name in cur:
        res = _gate(name, cur[name], base, args.max_ratio)
        if res is None:
            continue
        now, was, limits, bad = res
        if bad and not args.no_retry:
            print(f"[retry] {name}: now {now:.2f}s over "
                  f"{', '.join(bad)} — re-running once", flush=True)
            if _retry(name, args.current):
                row = _load_modules(args.current).get(name, cur[name])
                res2 = _gate(name, row, base, args.max_ratio)
                if res2 is not None:
                    now, was, limits, bad = res2
        status = "FAIL" if bad else "ok"
        base_str = f"baseline {was:.2f}s -> " if was is not None else ""
        print(f"[{status}] {name}: {base_str}now {now:.2f}s "
              f"(limits: {', '.join(f'{w} {v:.2f}s' for w, v in limits)})")
        if bad:
            failures.append(f"{name} ({'; '.join(bad)})")
    if failures:
        print(f"\nsweep timing regressed (>{args.max_ratio}x + {FLOOR_S}s "
              f"floor, or over budget; after one retry) in: "
              f"{', '.join(failures)}", file=sys.stderr)
        return 1
    print("\nsweep timings within budget")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
