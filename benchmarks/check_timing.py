"""CI gate: fail when the sweep-heavy benchmark timings regress.

  python benchmarks/check_timing.py --baseline <committed BENCH_sweep_timing.json> \
      --current bench_results/BENCH_sweep_timing.json [--max-ratio 2.0]

Two gates per module, both enforced on every module freshly timed in the
current run (the harness merges prior timings for modules a filtered run
skipped — those carry the baseline values verbatim and would trivially
pass):

  ratio    now <= max(max_ratio * baseline, baseline + FLOOR_S) against the
           committed baseline timing. The absolute noise floor keeps
           sub-second modules from tripping the ratio on a cold CI runner.
  budget   now <= the module's own `budget_s` (written by benchmarks/run.py
           from BUDGETS_S) — an absolute per-benchmark ceiling, so modules
           that post-date the seed timings (fig_parallelism, fig_pipeline,
           fig_prefill_overlap) are gated too, and a legitimate baseline
           refresh cannot smuggle in an unbounded slowdown.

  --update-baseline rewrites the baseline file with the current run's
  timings (use after a change that legitimately grows the grid — e.g. the
  pp axis enlarging the candidate set — then commit the refreshed JSON).
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys

FLOOR_S = 5.0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--current", required=True)
    ap.add_argument("--max-ratio", type=float, default=2.0)
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite --baseline with the current timings "
                         "instead of gating (commit the result)")
    args = ap.parse_args(argv)

    if args.update_baseline:
        shutil.copyfile(args.current, args.baseline)
        print(f"baseline rewritten: {args.current} -> {args.baseline}")
        return 0

    with open(args.baseline) as f:
        base = json.load(f)["modules"]
    with open(args.current) as f:
        cur = json.load(f)["modules"]

    failures = []
    for name, row in cur.items():
        now = row.get("now_s")
        was = base.get(name, {}).get("now_s")
        budget = row.get("budget_s")
        if now is None or now == was:
            continue        # not timed this run (merged from baseline)
        limits = []
        if was is not None:
            limits.append(("ratio", max(args.max_ratio * was,
                                        was + FLOOR_S)))
        if budget is not None:
            limits.append(("budget", float(budget)))
        if not limits:
            continue
        bad = [f"{what} {lim:.2f}s" for what, lim in limits if now > lim]
        status = "FAIL" if bad else "ok"
        base_str = f"baseline {was:.2f}s -> " if was is not None else ""
        print(f"[{status}] {name}: {base_str}now {now:.2f}s "
              f"(limits: {', '.join(f'{w} {v:.2f}s' for w, v in limits)})")
        if bad:
            failures.append(f"{name} ({'; '.join(bad)})")
    if failures:
        print(f"\nsweep timing regressed (>{args.max_ratio}x + {FLOOR_S}s "
              f"floor, or over budget) in: {', '.join(failures)}",
              file=sys.stderr)
        return 1
    print("\nsweep timings within budget")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
