"""Fig-18-style product grid on the jitted sweep engine.

The grid crosses link bandwidth x cluster size x XPU generation x topology
x scenario x batch for DeepSeek-V3 — the study shape ROADMAP's
"JAX-jitted sweep engine" item names, at >= 10^6 TPOT cells. The NumPy
engine cannot hold it whole: `GridEval._durations` materializes
(n_ops, n_clusters, n_scenarios, n_batches) tensors, ~4 TB here, so the
NumPy path runs in cluster-axis blocks (sized favorably for it) while the
jitted backend (`core/sweep_jax.py`) evaluates each cluster size as one
`lax.scan` device program whose working set stays in cache. Both engines
produce the same TPOT surface (parity asserted at <= 1e-6 relative,
~1e-12 observed); the >= 10x speedup claim is the engine's acceptance bar
and is recorded into BENCH_sweep_timing.json by the harness.

Timing protocol: jit trace+compile is one-time per grid shape and is
recorded separately (`jax_compile_s`); the speedup row compares
steady-state evaluation — the regime the product-grid figures and the
planned per-request re-optimization loop run in. The three-lane DBO
makespan is timed on a subgrid (both engines, identical blocks): its
(max,+) recurrence is memory-bound on the materialized duration tensor
for both backends, so its speedup is reported as info, not gated.

Sanity claim: TPOT is non-increasing in link bandwidth along every
(size, generation, topology, scenario, batch) fiber — alphas are
unchanged by provisioning, so more bandwidth can only shrink comm time.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import save, table
from repro.configs.deepseek_v3 import CONFIG as CFG
from repro.core import optable, sweep
from repro.core.hardware import BLACKWELL, H100, RUBIN
from repro.core.optimizer import Scenario
from repro.core.topology import TOPOLOGIES, make_cluster

SIZES = (64, 256)
GENERATIONS = (("h100", H100), ("blackwell", BLACKWELL), ("rubin", RUBIN))
BW_MULTS = tuple(float(2.0 ** e) for e in range(-2, 6))   # 0.25x .. 32x
TPOTS_MS = (5.0, 10.0, 15.0, 25.0, 40.0, 60.0, 100.0, 150.0)
CONTEXTS = (256, 512, 1024, 2048, 4096, 8192, 16384, 32768)
TP = 2
# NumPy block size along the cluster axis: small enough that the
# (n_ops, block, n_sc, n_b) tensors stay ~hundreds of MB (bigger blocks
# only slow the NumPy path down — materialization thrashes)
NP_BLOCK = 8
DBO_CLUSTERS = 8          # dbo subgrid: one block of the size-64 grid


def _clusters(n: int):
    return [make_cluster(topo, n, xpu, link_bw_mult=mult)
            for _, xpu in GENERATIONS
            for topo in TOPOLOGIES
            for mult in BW_MULTS]


def _batches():
    return np.unique(np.round(np.geomspace(1, 32768, 96)).astype(np.int64))


def _tpot_blocks(tab, clusters, scs, batches, backend, dbo, block):
    """TPOT over the grid, evaluated in cluster-axis blocks; returns the
    assembled (n_cl, n_sc, n_b) array. One GridEval per block — the NumPy
    path cannot hold the whole cluster axis, and identical blocking keeps
    the comparison apples-to-apples when both backends are blocked."""
    outs = []
    for lo in range(0, len(clusters), block):
        ev = sweep.GridEval(tab, clusters[lo:lo + block], scs, batches,
                            backend=backend)
        outs.append(ev.tpot(dbo=dbo))
    return np.concatenate(outs, axis=0)


def run(verbose: bool = False):
    scs = [Scenario(t, c) for t in TPOTS_MS for c in CONTEXTS]
    batches = _batches()
    grids = {}
    for n in SIZES:
        ep = max(n // TP, 1)
        grids[n] = (optable.op_table(CFG, TP, ep, n, "fp8", pp=1),
                    _clusters(n))
    n_cells = sum(len(cl) for _, cl in grids.values()) * len(scs) \
        * len(batches)
    assert n_cells >= 10 ** 6, n_cells

    # ---- no-overlap TPOT product grid: the headline timing ----
    # jit compile (one trace per grid shape), excluded from steady-state
    t0 = time.time()
    for n in SIZES:
        tab, cls = grids[n]
        _tpot_blocks(tab, cls, scs, batches, "jax", False, len(cls))
    jax_compile_s = time.time() - t0

    t0 = time.time()
    tpot_jax = {n: _tpot_blocks(*grids[n], scs, batches, "jax", False,
                                len(grids[n][1])) for n in SIZES}
    jax_s = time.time() - t0

    t0 = time.time()
    tpot_np = {n: _tpot_blocks(*grids[n], scs, batches, "numpy", False,
                               NP_BLOCK) for n in SIZES}
    np_s = time.time() - t0

    rel_seq = max(
        float(np.max(np.abs(tpot_np[n] - tpot_jax[n]) / tpot_np[n]))
        for n in SIZES)
    speedup = np_s / jax_s

    # ---- three-lane DBO makespan: one block, both engines ----
    tab64, cls64 = grids[64]
    sub = cls64[:DBO_CLUSTERS]
    t0 = time.time()
    _tpot_blocks(tab64, sub, scs, batches, "jax", True, DBO_CLUSTERS)
    dbo_compile_s = time.time() - t0
    t0 = time.time()
    dbo_jax = _tpot_blocks(tab64, sub, scs, batches, "jax", True,
                           DBO_CLUSTERS)
    dbo_jax_s = time.time() - t0
    t0 = time.time()
    dbo_np = _tpot_blocks(tab64, sub, scs, batches, "numpy", True,
                          DBO_CLUSTERS)
    dbo_np_s = time.time() - t0
    rel_dbo = float(np.max(np.abs(dbo_np - dbo_jax) / dbo_np))
    n_dbo_cells = DBO_CLUSTERS * len(scs) * len(batches)

    # ---- link-bw monotonicity along every fiber ----
    monotonic = True
    for n in SIZES:
        cube = tpot_jax[n].reshape(len(GENERATIONS), len(TOPOLOGIES),
                                   len(BW_MULTS), len(scs), len(batches))
        monotonic &= bool(np.all(np.diff(cube, axis=2) <= 1e-12))

    if verbose:
        print(table(
            ["grid", "cells", "numpy_s", "jax_s", "speedup", "max_rel"],
            [["tpot (seq)", n_cells, f"{np_s:.2f}", f"{jax_s:.2f}",
              f"{speedup:.1f}x", f"{rel_seq:.1e}"],
             ["tpot (dbo)", n_dbo_cells, f"{dbo_np_s:.2f}",
              f"{dbo_jax_s:.2f}", f"{dbo_np_s / dbo_jax_s:.1f}x",
              f"{rel_dbo:.1e}"]],
            title="product grid: numpy reference vs jitted engine"))
        print(f"jit compile: seq {jax_compile_s:.2f}s, "
              f"dbo {dbo_compile_s:.2f}s (one-time per grid shape)")

    payload = {
        "grid": {"sizes": list(SIZES), "tp": TP,
                 "generations": [g for g, _ in GENERATIONS],
                 "topologies": list(TOPOLOGIES),
                 "bw_mults": list(BW_MULTS), "tpot_ms": list(TPOTS_MS),
                 "contexts": list(CONTEXTS),
                 "n_batches": int(len(batches)), "n_cells": int(n_cells)},
        "seq": {"numpy_s": round(np_s, 2), "jax_s": round(jax_s, 2),
                "jax_compile_s": round(jax_compile_s, 2),
                "speedup": round(speedup, 1),
                "max_rel_diff": rel_seq},
        "dbo": {"n_cells": int(n_dbo_cells),
                "numpy_s": round(dbo_np_s, 2),
                "jax_s": round(dbo_jax_s, 2),
                "jax_compile_s": round(dbo_compile_s, 2),
                "speedup": round(dbo_np_s / dbo_jax_s, 1),
                "max_rel_diff": rel_dbo},
        "claims": {
            "grid_cells_ge_1e6": bool(n_cells >= 10 ** 6),
            "jit_speedup_ge_10x": bool(speedup >= 10.0),
            "parity_seq_le_1e-6": bool(rel_seq <= 1e-6),
            "parity_dbo_le_1e-6": bool(rel_dbo <= 1e-6),
            "tpot_monotonic_in_link_bw": monotonic,
            "seq_speedup": round(speedup, 1),
            "dbo_speedup": round(dbo_np_s / dbo_jax_s, 1),
        },
    }
    save("fig_product_grid", payload)
    return payload


if __name__ == "__main__":
    run(verbose=True)
