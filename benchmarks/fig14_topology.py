"""Paper Fig 14 + 15: topology comparison at fixed per-XPU bandwidth
(64 XPUs; Fig 15 = 4K-context scenarios).

Headline: switchless torus/full-mesh beat scale-up on throughput/cost in
ALL scenario combinations (paper band: +20.6-56.2%); scale-up keeps the
raw-throughput lead; scale-out misses everywhere."""
from __future__ import annotations

from benchmarks.common import save, solve_level_points, table
from repro.configs import get_arch
from repro.core import H100, Scenario, make_cluster
from repro.core.tco import cluster_tco

TOPOS = ("scale-up", "scale-out", "torus", "fullmesh")
SCENARIOS = [Scenario(t, c) for c in (512, 4096) for t in (15.0, 40.0, 100.0)]


def run(verbose: bool = True, n: int = 64):
    cfg = get_arch("deepseek-v3")
    clusters = [make_cluster(topo, n, H100) for topo in TOPOS]
    # batched: one shared engine pass spans topologies x scenarios x opts
    grids = solve_level_points(cfg, clusters, SCENARIOS,
                               ("noopt", "dbo+sd"))
    results = {}
    rows = []
    improvements = []
    for si, sc in enumerate(SCENARIOS):
        per_topo = {}
        for ti, topo in enumerate(TOPOS):
            cost = cluster_tco(clusters[ti]).per_xpu(n)
            entry = {"cost_per_xpu": cost}
            for opts in ("noopt", "dbo+sd"):
                op = grids[opts][ti][si]
                entry[opts] = {
                    "thpt_per_xpu": (op.throughput / n) if op else 0.0,
                    "thpt_per_cost": (op.throughput / n / cost) if op else 0.0,
                    "batch": op.batch if op else 0}
            per_topo[topo] = entry
        results[sc.name] = per_topo
        su = per_topo["scale-up"]["dbo+sd"]["thpt_per_cost"]
        best_sw = max(per_topo["torus"]["dbo+sd"]["thpt_per_cost"],
                      per_topo["fullmesh"]["dbo+sd"]["thpt_per_cost"])
        imp = (best_sw / su - 1) * 100 if su else float("inf")
        improvements.append(imp)
        rows.append([sc.name] + [
            f"{per_topo[t]['dbo+sd']['thpt_per_xpu']:.0f}/"
            f"{per_topo[t]['dbo+sd']['thpt_per_cost']:.2f}"
            for t in TOPOS] + [f"{imp:+.1f}%"])
    out = table(["scenario"] + [f"{t} thpt/tpc" for t in TOPOS]
                + ["best-switchless vs scale-up"],
                rows, title=f"Fig 14/15 — topology comparison ({n} XPUs, "
                            "DBO+SD)")
    results["claims"] = {
        "switchless_wins_everywhere": all(i > 0 for i in improvements),
        "improvement_range_pct": [min(improvements), max(improvements)],
        "paper_range_pct": [20.6, 56.2],
        "scaleup_best_raw_throughput": all(
            results[sc.name]["scale-up"]["dbo+sd"]["thpt_per_xpu"]
            >= max(results[sc.name][t]["dbo+sd"]["thpt_per_xpu"]
                   for t in ("torus", "fullmesh")) * 0.999
            for sc in SCENARIOS),
        "scaleout_never_best": all(
            results[sc.name]["scale-out"]["dbo+sd"]["thpt_per_cost"]
            <= max(results[sc.name][t]["dbo+sd"]["thpt_per_cost"]
                   for t in TOPOS if t != "scale-out")
            for sc in SCENARIOS),
    }
    if verbose:
        print(out)
        print("\nclaims:", results["claims"])
    save(f"fig14_topology_{n}", results)
    return results


if __name__ == "__main__":
    run()
