"""Shared benchmark infrastructure: result persistence, ASCII rendering,
and the `repro.core.api` unwrap helpers every searching figure uses (the
figures consume the search exclusively through the facade — `solve_grid`
returns `Solution`s, the figures index bare operating-point grids)."""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List, Optional, Sequence

from repro.core import api

OUT_DIR = os.environ.get("BENCH_OUT", os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "bench_results"))


def solve_points(cfg, clusters, scenarios, spec: Optional[api.SearchSpec]
                 = None, *, prefill: bool = False, **kw) -> List[List[Any]]:
    """`api.solve_grid` unwrapped to the [cluster][scenario] operating-
    point grid the figures index (None where the SLO is unreachable).
    Pass a `SearchSpec` or its fields as kwargs. `prefill=True` (implied
    by prefill-mode specs) unwraps via `Solution.prefill_point`, so a
    mode='decode' comparison arm keeps the `PrefillOperatingPoint`
    wrapper shape the prefill figures expect."""
    spec = api.SearchSpec(**kw) if spec is None else spec
    grid = api.solve_grid(cfg, clusters, scenarios, spec)
    if prefill or spec.mode != "decode":
        return [[s.prefill_point for s in row] for row in grid]
    return [[s.point for s in row] for row in grid]


def solve_level_points(cfg, clusters, scenarios,
                       levels: Sequence[str] = api.OPTS_LEVELS,
                       spec: Optional[api.SearchSpec] = None,
                       **kw) -> Dict[str, List[List[Any]]]:
    """`api.solve_levels` unwrapped to {level: point grid} — several
    software-optimization levels sharing one engine pass."""
    spec = api.SearchSpec(**kw) if spec is None else spec
    multi = api.solve_levels(cfg, clusters, scenarios, levels, spec)
    return {lvl: [[s.point for s in row] for row in multi[lvl]]
            for lvl in levels}


def save(name: str, payload: Dict[str, Any]) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    payload = dict(payload)
    payload["_bench"] = name
    payload["_time"] = time.strftime("%Y-%m-%d %H:%M:%S")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path


def table(headers: Sequence[str], rows: Sequence[Sequence[Any]],
          title: str = "") -> str:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows
              else len(str(h)) for i, h in enumerate(headers)]
    fmt = "  ".join(f"{{:>{w}}}" for w in widths)
    lines = []
    if title:
        lines.append(f"--- {title} ---")
    lines.append(fmt.format(*headers))
    lines.append("  ".join("-" * w for w in widths))
    for r in rows:
        lines.append(fmt.format(*[str(x) for x in r]))
    return "\n".join(lines)


def ascii_curve(xs: Sequence[float], ys: Sequence[float], *, width: int = 60,
                height: int = 12, label: str = "") -> str:
    """Minimal scatter/line rendering for terminal reports."""
    if not xs:
        return "(no data)"
    xmin, xmax = min(xs), max(xs)
    ymin, ymax = min(ys), max(ys)
    grid = [[" "] * width for _ in range(height)]
    for x, y in zip(xs, ys):
        i = int((x - xmin) / (xmax - xmin + 1e-12) * (width - 1))
        j = int((y - ymin) / (ymax - ymin + 1e-12) * (height - 1))
        grid[height - 1 - j][i] = "*"
    out = [f"[{label}] y:[{ymin:.3g}, {ymax:.3g}] x:[{xmin:.3g}, {xmax:.3g}]"]
    out += ["|" + "".join(row) for row in grid]
    return "\n".join(out)


def fmt_bw(bw: float) -> str:
    return f"{bw / 1e9:.0f}GB/s"
