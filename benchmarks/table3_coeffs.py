"""Paper Table 3: A2A algorithm coefficients per topology/size — our
formulas must reproduce the table EXACTLY (also asserted in
tests/test_collectives.py)."""
from __future__ import annotations

from benchmarks.common import save, table
from repro.core import collectives as coll

PAPER = {
    ("ScaleUp-P2P", 64): (1, 63, 63 / 64),
    ("ScaleUp-P2P", 256): (1, 255, 255 / 256),
    ("ScaleUp-Bruck", 64): (6, 6, 3.0),
    ("ScaleUp-Bruck", 256): (8, 8, 4.0),
    ("FullMesh-DoR", 64): (3, 27, 9 / 4),
    ("FullMesh-DoR", 256): (3, 51, 17 / 4),
    ("Torus-HalfRing", 64): (6, 36, 3.0),
    ("Torus-HalfRing", 256): (12, 72, 6.0),
}

DIMS = {64: (4, 4, 4), 256: (8, 8, 4)}


def _ours(name, n):
    if name == "ScaleUp-P2P":
        return coll.a2a_p2p(n)
    if name == "ScaleUp-Bruck":
        return coll.a2a_bruck(n)
    if name == "FullMesh-DoR":
        return coll.a2a_fullmesh_dor(DIMS[n])
    if name == "Torus-HalfRing":
        return coll.a2a_torus_halfring(DIMS[n])
    raise KeyError(name)


def run(verbose: bool = True):
    rows = []
    results = {}
    all_match = True
    for (name, n), (pr, pd, pm) in PAPER.items():
        c = _ours(name, n)
        match = (c.rounds == pr and c.dests == pd
                 and abs(c.m_coeff - pm) < 1e-12)
        all_match &= match
        rows.append([name, n, f"{c.rounds}ar+{c.dests}ad+{c.m_coeff:.4g}mb",
                     f"{pr}ar+{pd}ad+{pm:.4g}mb",
                     "OK" if match else "MISMATCH"])
        results[f"{name}/{n}"] = {"ours": [c.rounds, c.dests, c.m_coeff],
                                  "paper": [pr, pd, pm], "match": match}
    out = table(["algorithm", "N", "ours", "paper Table 3", "status"], rows,
                title="Table 3 — A2A coefficients (exact reproduction)")
    if verbose:
        print(out)
        print(f"ALL MATCH: {all_match}")
    results["all_match"] = all_match
    save("table3_coeffs", results)
    return results


if __name__ == "__main__":
    run()
