"""Pipeline-parallel mapping study (fig14-style): what does the pp axis
buy on top of the PR-3 (tp, ep) search?

Two regimes, DeepSeek-V3 on 64 XPUs across the Table-3 topologies:

  H100 (80 GB)    the dense shard fits at every tp, so pp competes on the
                  margin: dividing the dense shard by tp*pp frees KV
                  headroom (larger batches) at the price of pp-1 hidden-
                  state hops — the fixed-(tp, ep) search vs the full
                  (tp, pp, ep) triple search re-ranks throughput/cost.
  TPU v5e (16 GB) the memory-bound flagship regime MoE-CAP argues
                  benchmarks must cover: at pp=1 every tp < 8 mapping is
                  HBM-pruned and serving hides behind wide all-reduce-
                  heavy TP; pp flips the low-tp mappings to feasible
                  (dense/(tp*pp) shrinks, experts/n does not grow), so
                  the triple search finds cheaper-communication operating
                  points the pair search cannot reach.

Recorded per (platform, topology, scenario): fixed-(tp, ep) vs triple
operating points, throughput/cost, and where pp flips feasibility or the
cost-effectiveness winner.
"""
from __future__ import annotations

from benchmarks.common import save, solve_points, table
from repro.configs import get_arch
from repro.core import H100, TPU_V5E, Scenario, make_cluster
from repro.core.tco import cluster_tco

TOPOS = ("scale-up", "scale-out", "torus", "fullmesh")
SCENARIOS_H100 = [Scenario(t, c) for c in (512, 4096)
                  for t in (15.0, 40.0, 100.0)]
SCENARIOS_V5E = [Scenario(t, 512) for t in (40.0, 100.0)]


def _cell(op, n, cost):
    if op is None:
        return {"thpt_per_xpu": 0.0, "thpt_per_cost": 0.0, "batch": 0,
                "tp": 0, "pp": 0, "ep": 0, "exposed_comm_frac": 0.0}
    return {"thpt_per_xpu": op.throughput / n,
            "thpt_per_cost": op.throughput / n / cost,
            "batch": op.batch, "tp": op.tp, "pp": op.pp, "ep": op.ep,
            # share of the iteration that is exposed communication under
            # the no-overlap search — at pp > 1 this includes the pp-1
            # hops a DBO'd schedule would ride on the send/recv lane
            "exposed_comm_frac": (op.exposed_comm / op.tpot
                                  if op.tpot else 0.0)}


def _sweep_platform(cfg, xpu, scenarios, n):
    """(results, rows, claims-evidence) of fixed-(tp, ep) vs triple search
    on one XPU generation."""
    clusters = [make_cluster(topo, n, xpu) for topo in TOPOS]
    costs = {topo: cluster_tco(cl).per_xpu(n)
             for topo, cl in zip(TOPOS, clusters)}

    def _search(**kw):
        try:
            return solve_points(cfg, clusters, scenarios, **kw)
        except ValueError:      # no feasible mapping at all
            return [[None] * len(scenarios) for _ in clusters]

    pair = _search(tp="auto")
    trip = _search(tp="auto", pp="auto")

    results, rows = {}, []
    never_worse = True
    strict_cells, flip_feasible, flip_winner = [], [], []
    for si, sc in enumerate(scenarios):
        per_topo = {}
        for ti, topo in enumerate(TOPOS):
            f = _cell(pair[ti][si], n, costs[topo])
            a = _cell(trip[ti][si], n, costs[topo])
            never_worse &= a["thpt_per_xpu"] >= f["thpt_per_xpu"]
            if a["thpt_per_xpu"] > f["thpt_per_xpu"]:
                strict_cells.append([topo, sc.name])
            if f["thpt_per_xpu"] == 0.0 and a["thpt_per_xpu"] > 0.0:
                flip_feasible.append([topo, sc.name])
            per_topo[topo] = {"cost_per_xpu": costs[topo],
                              "pair": f, "triple": a}
            rows.append([sc.name, topo, f"{f['thpt_per_xpu']:.0f}",
                         f"{a['thpt_per_xpu']:.0f}",
                         (f"tp{a['tp']}xpp{a['pp']}xep{a['ep']}"
                          if a["tp"] else "-"),
                         (f"{(a['thpt_per_xpu'] / f['thpt_per_xpu'] - 1) * 100:+.1f}%"
                          if f["thpt_per_xpu"]
                          else ("feasible" if a["thpt_per_xpu"] else "-"))])
        results[sc.name] = per_topo
        ranked = {k: sorted(TOPOS,
                            key=lambda t: -per_topo[t][k]["thpt_per_cost"])
                  for k in ("pair", "triple")}
        results[sc.name]["ranking"] = ranked
        if (ranked["pair"] != ranked["triple"]
                and any(per_topo[t]["pair"]["thpt_per_cost"] > 0
                        for t in TOPOS)):
            flip_winner.append([sc.name, ranked["pair"][0],
                                ranked["triple"][0]])
    evidence = {"never_worse": never_worse, "strict_cells": strict_cells,
                "flip_feasible": flip_feasible, "flip_winner": flip_winner}
    return results, rows, evidence


def run(verbose: bool = True, n: int = 64):
    cfg = get_arch("deepseek-v3")
    res_h100, rows_h100, ev_h100 = _sweep_platform(cfg, H100,
                                                   SCENARIOS_H100, n)
    res_v5e, rows_v5e, ev_v5e = _sweep_platform(cfg, TPU_V5E,
                                                SCENARIOS_V5E, n)

    results = {"h100": res_h100, "v5e": res_v5e}
    v5e_served = [[topo, sc]
                  for sc, per_topo in res_v5e.items()
                  for topo in TOPOS
                  if per_topo[topo]["triple"]["thpt_per_xpu"] > 0]
    v5e_low_tp = [[topo, sc]
                  for sc, per_topo in res_v5e.items()
                  for topo in TOPOS
                  if per_topo[topo]["triple"]["tp"]
                  and per_topo[topo]["triple"]["tp"]
                  * per_topo[topo]["triple"]["pp"] < 64
                  and per_topo[topo]["triple"]["pp"] > 1]
    results["claims"] = {
        # the triple search can only add candidates on either platform
        "triple_never_worse": ev_h100["never_worse"] and ev_v5e["never_worse"],
        # and the axis must MATTER: somewhere pp strictly improves the
        # operating point (batch headroom vs hop cost goes pp's way)
        "pp_strictly_improves_somewhere": bool(ev_h100["strict_cells"]
                                               or ev_v5e["strict_cells"]),
        # the memory-bound headline: DeepSeek-V3 is served on 16 GB v5e
        # through the triple search on every Table-3 topology
        "v5e_dsv3_served_on_every_topology": all(
            any(c[0] == topo for c in v5e_served) for topo in TOPOS),
        # and on v5e the WINNING mapping uses the pipeline axis somewhere
        # (pp > 1 beating the pure wide-TP fallback)
        "v5e_winner_uses_pp_somewhere": bool(v5e_low_tp),
        "strict_cells_h100": ev_h100["strict_cells"],
        "strict_cells_v5e": ev_v5e["strict_cells"],
        "feasibility_flips": {"h100": ev_h100["flip_feasible"],
                              "v5e": ev_v5e["flip_feasible"]},
        "winner_flips": {"h100": ev_h100["flip_winner"],
                         "v5e": ev_v5e["flip_winner"]},
    }
    if verbose:
        print(table(["scenario", "topology", "pair tok/s/XPU",
                     "triple tok/s/XPU", "triple map", "delta"],
                    rows_h100,
                    title=f"fig_pipeline — H100, fixed (tp,ep) vs "
                          f"(tp,pp,ep) triples ({n} XPUs)"))
        print()
        print(table(["scenario", "topology", "pair tok/s/XPU",
                     "triple tok/s/XPU", "triple map", "delta"],
                    rows_v5e,
                    title=f"fig_pipeline — TPU v5e 16 GB, DeepSeek-V3 "
                          f"({n} XPUs)"))
        print("\nclaims:", {k: v for k, v in results["claims"].items()
                            if isinstance(v, bool)})
    save(f"fig_pipeline_{n}", results)
    return results


if __name__ == "__main__":
    run()
