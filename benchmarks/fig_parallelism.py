"""Hybrid-parallelism study (fig14-style): does the topology ranking hold
when every topology gets its BEST (tp, ep) mapping instead of the paper's
fixed one?

The paper compares topologies under one parallelism mapping (attention
TP=1 / experts EP=n). MixServe-style co-optimization of (tp, ep = n/tp)
per topology can move the operating points: TP shards the dense weight
streams and makes tight TPOT SLOs reachable without SD, and each topology
pays a DIFFERENT price for the TP all-reduce (scale-out hides it inside
the NVLink island, meshes run it over a sub-mesh neighborhood, scale-up
over the switched fabric). This benchmark re-ranks the Table-3 topologies
under fixed vs. auto mapping and records where the mapping search strictly
improves throughput (and therefore throughput/cost).
"""
from __future__ import annotations

from benchmarks.common import save, solve_points, table
from repro.configs import get_arch
from repro.core import H100, Scenario, make_cluster
from repro.core.tco import cluster_tco

TOPOS = ("scale-up", "scale-out", "torus", "fullmesh")
SCENARIOS = [Scenario(t, c) for c in (512, 4096) for t in (15.0, 40.0, 100.0)]


def run(verbose: bool = True, n: int = 64):
    cfg = get_arch("deepseek-v3")
    clusters = [make_cluster(topo, n, H100) for topo in TOPOS]
    fixed = solve_points(cfg, clusters, SCENARIOS)
    auto = solve_points(cfg, clusters, SCENARIOS, tp="auto")

    costs = {topo: cluster_tco(clusters[ti]).per_xpu(n)
             for ti, topo in enumerate(TOPOS)}
    results = {}
    rows = []
    never_worse = True
    strict_cells = []
    for si, sc in enumerate(SCENARIOS):
        per_topo = {}
        for ti, topo in enumerate(TOPOS):
            cost = costs[topo]
            f, a = fixed[ti][si], auto[ti][si]
            f_thr = f.throughput if f else 0.0
            a_thr = a.throughput if a else 0.0
            never_worse &= a_thr >= f_thr
            if a_thr > f_thr:
                strict_cells.append([topo, sc.name])
            per_topo[topo] = {
                "cost_per_xpu": cost,
                "fixed": {"thpt_per_xpu": f_thr / n,
                          "thpt_per_cost": f_thr / n / cost,
                          "batch": f.batch if f else 0},
                "auto": {"thpt_per_xpu": a_thr / n,
                         "thpt_per_cost": a_thr / n / cost,
                         "batch": a.batch if a else 0,
                         "tp": a.tp if a else 0, "ep": a.ep if a else 0},
            }
            rows.append([sc.name, topo, f"{f_thr / n:.0f}",
                         f"{a_thr / n:.0f}",
                         f"tp{a.tp}xep{a.ep}" if a else "-",
                         f"{(a_thr / f_thr - 1) * 100:+.1f}%" if f_thr
                         else ("feasible" if a_thr else "-")])
        results[sc.name] = per_topo

    # does the cost-effectiveness ranking of the topologies move?
    def ranking(key):
        out = {}
        for sc in SCENARIOS:
            tpc = {t: results[sc.name][t][key]["thpt_per_cost"]
                   for t in TOPOS}
            out[sc.name] = sorted(TOPOS, key=lambda t: -tpc[t])
        return out

    rank_fixed, rank_auto = ranking("fixed"), ranking("auto")
    fixed_feasible = [sc for sc in SCENARIOS
                      if results[sc.name]["scale-up"]["fixed"]
                      ["thpt_per_cost"] > 0]
    tight = [sc for sc in SCENARIOS if sc not in fixed_feasible]
    results["ranking"] = {"fixed": rank_fixed, "auto": rank_auto}
    results["claims"] = {
        # the mapping search can only add candidates, never lose tp=1
        "auto_never_worse": never_worse,
        # and the axis must MATTER: at least one cell strictly improves
        "auto_strictly_improves_somewhere": bool(strict_cells),
        "strict_cells": strict_cells,
        # the paper's headline SURVIVES co-optimization where its fixed
        # mapping could serve at all: best switchless still beats scale-up
        # on throughput/cost at every relaxed-SLO scenario
        "switchless_wins_relaxed_slo_under_auto": all(
            max(results[sc.name]["torus"]["auto"]["thpt_per_cost"],
                results[sc.name]["fullmesh"]["auto"]["thpt_per_cost"])
            > results[sc.name]["scale-up"]["auto"]["thpt_per_cost"]
            for sc in fixed_feasible),
        # ...but the tight-SLO scenarios ONLY the mapping search can serve
        # flip the winner to a switched fabric (scale-out's NVLink-island
        # TP or scale-up) — the ranking is mapping-dependent, the
        # MixServe argument this axis exists to test
        "tight_slo_feasible_only_under_auto": bool(tight) and all(
            results[sc.name][t]["fixed"]["thpt_per_cost"] == 0
            and any(results[sc.name][t2]["auto"]["thpt_per_cost"] > 0
                    for t2 in TOPOS)
            for sc in tight for t in TOPOS),
        "tight_slo_winner_is_switched": all(
            rank_auto[sc.name][0] in ("scale-up", "scale-out")
            for sc in tight) if tight else False,
    }
    out = table(["scenario", "topology", "fixed tok/s/XPU", "auto tok/s/XPU",
                 "auto map", "delta"], rows,
                title=f"fig_parallelism — fixed vs auto (tp, ep) mapping "
                      f"({n} XPUs, no sw opts)")
    if verbose:
        print(out)
        print("\nclaims:", {k: v for k, v in results["claims"].items()
                            if k != "strict_cells"})
    save(f"fig_parallelism_{n}", results)
    return results


if __name__ == "__main__":
    run()
