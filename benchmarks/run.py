"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run                  # all
  PYTHONPATH=src python -m benchmarks.run fig14            # substring filter
  PYTHONPATH=src python -m benchmarks.run --list           # print modules
  PYTHONPATH=src python -m benchmarks.run --only fig14_topology

A bare positional pattern is a SUBSTRING filter and runs every matching
module (e.g. `fig1` matches fig10/fig11/fig12/...). `--only NAME` runs
exactly one module — NAME must equal a module's short name (the part
after `benchmarks.`) or its full dotted path, and the harness errors on
no match instead of silently running nothing. `--list` prints every
registered module with its short name and exits.

Results land in bench_results/*.json; claim checks print per module.

Sweep engine
------------
The sweep-heavy modules (fig9-fig12, fig14, fig16-fig18) run on the batched
sweep engine (`repro.core.sweep`): the decode op list is lowered once per
(model, parallelism) into a coefficient table (`repro.core.optable`), and
the whole batch-grid x {dbo, sd} x scenario x topology search evaluates as
NumPy array programs — including an exact (max,+) vectorization of the DBO
two-lane schedule — instead of per-point Python loops. Only the argmax
winner of each sweep is re-derived through the scalar path, which keeps the
reported `OperatingPoint`s byte-identical to the seed implementation.

Each harness run records wall-clock per sweep-heavy module next to the
timings measured at the seed commit into
`bench_results/BENCH_sweep_timing.json`; the end-to-end speedup quoted
there is the evidence for the engine's >= 5x acceptance bar.

Prefill serving modes
---------------------
`fig_prefill_scenarios` extends the operating-point search beyond the
paper's decode-only model: `Scenario` carries an optional
(`prompt_len`, `ttft_ms`) prefill spec, `workload.prefill_iteration`
emits the chunk op list (attention quadratic in chunk, MoE rows linear),
`optable.prefill_op_table` lowers it to polynomial coefficient tables,
and `sweep.sweep_prefill` searches three modes per (cluster, scenario):

  decode    the paper's search, prefill free (baseline)
  chunked   prefill chunks interleaved into decode iterations — joint
            batch x chunk-size search; TPOT carries the load-weighted
            chunk tax, TTFT is the sum of the chunk iterations
  disagg    prefill/decode pools with the split ratio swept; throughput
            is the balanced pipeline rate, TTFT one whole-prompt pass
            plus the KV-cache handoff

All three modes accept `dbo=True`: the three-lane (max,+) DBO schedule
(compute / collectives / pp send-recv — `repro.core.overlap`) times
decode iterations as two B/2 microbatches and prefill work as two causal
half-chunks, hiding A2A/AR under the other microbatch's GEMMs and pp
hops under both. `fig_prefill_overlap` sweeps overlap vs no-overlap
across prompt x TTFT x topology: gains concentrate on the
bandwidth-constrained fabrics and re-order the topology ranking.

Decode-only scenarios (`prompt_len == 0`) evaluate byte-identically to
the seed search — the fig9-fig18 JSONs are regression-locked by
tests/test_prefill.py and by the CI `bench-regression` job, which
regenerates fig10/table3 on a fresh checkout and fails on any diff.

Hybrid-parallelism search
-------------------------
`max_throughput` / `best_of_opts` / `max_throughput_prefill` (and their
grid entry points in `repro.core.sweep`) accept tp="auto": the search
grows a joint (tp, ep = n/tp) mapping axis. `sweep.parallelism_candidates`
enumerates the valid mappings (attention-head and expert-count
divisibility plus weight-shard feasibility), each candidate evaluates
through its own op table with the collectives placed by the topology
(`Cluster.comm_spec`: the TP all-reduce runs over the scale-up / mesh
neighborhood — a torus/full-mesh sub-mesh, the NVLink island of a
scale-out cluster — and the expert A2A over the quotient fabric), and
every (cluster, scenario) cell keeps the highest-throughput mapping,
ties to the smallest tp so fixed-mapping results are byte-identical.
`fig_parallelism` re-ranks the Table-3 topologies under fixed vs. auto
mapping: switchless fabrics keep their cost-effectiveness win at
relaxed SLOs, while tight-TPOT scenarios only the mapping search can
serve flip the winner to the switched fabrics.

Pipeline-parallel axis
----------------------
pp="auto" (alone or with tp="auto") extends the mapping search to
(tp, pp, ep = n/(tp*pp)) triples: pp splits the layer stack into
balanced contiguous stages (`workload.stage_layer_counts`; uneven
splits carry the `stage_imbalance` bottleneck factor), divides the
per-stage dense weight shard by tp*pp while the expert shard stays
experts/n, and adds pp-1 per-token `pp_sendrecv` hidden-state hops
placed by the topology (one mesh link on torus/full-mesh, the NIC
across scale-out islands, the switch on scale-up). Candidates require
tp*pp | n, pp <= layer count, and the per-stage HBM fit; ties resolve
to the smallest (tp, pp), so every pp=1 result is byte-identical to
the PR-3 search. Disaggregated prefill resolves the mapping PER POOL
(tp_prefill/pp_prefill recorded on the operating point). `fig_pipeline`
compares the fixed-(tp, ep) search against the full triple search on
H100 (where pp trades KV headroom against hop latency) and on 16 GB
TPU v5e, where pp flips DeepSeek-V3's low-tp mappings from HBM-pruned
to feasible and wins the cost-per-throughput ranking.

Degraded-fabric serving
-----------------------
`fig_failures` re-scores the fig14 topology ranking with the throughput
numerator replaced by the expected steady-state throughput under the
stationary component-failure distribution: `Cluster.with_faults`
derates the fabric per topology (torus detours, full-mesh 2-hop relay,
scale-up plane loss, scale-out node loss), `sweep.degraded_max_throughput`
re-runs the (tp, pp, ep) search on the survivor subcluster,
`optimizer.degrade_policy` arbitrates keep-mapping vs pay-remap-downtime,
and `core.availability` enumerates multi-fault states with component
counts derived from the TCO link/switch inventory (MTBF/MTTR defaults
in docs/failure_model.md). The zero-fault path is byte-identical to
the healthy model, so every other figure JSON is unaffected.

Skewed expert routing + placement
---------------------------------
`fig_skew` drops the uniform-routing assumption: `Scenario(routing="zipf",
zipf_s=s)` draws a per-layer Zipf expert popularity (seeded, permutation
independent of s), `repro.core.placement` turns it into per-layer hot-rank
load factors, and the sweep charges grouped GEMM time and A2A payload at
the hottest rank's load (`sweep.op_load_factors`; both NumPy and JAX
backends, scalar parity at 1e-9). `placement="auto"` searches replica
counts for the hottest experts (HBM-feasibility-gated via
`workload.model_shard_bytes`) with greedy hot-expert replication + LPT
placement; the R=0 arm is searched first and only strictly better arms
replace it, so uniform scenarios stay byte-identical and placement never
loses. The figure sweeps Zipf s x Table-3 topologies x fig14 scenarios
with and without placement.
"""
from __future__ import annotations

import importlib
import json
import sys
import time
import traceback

MODULES = [
    "benchmarks.table1_alphabeta",
    "benchmarks.table3_coeffs",
    "benchmarks.validation",
    "benchmarks.fig5_dbo_latency",
    "benchmarks.fig7_a2a_time",
    "benchmarks.fig9_batch_sweep",
    "benchmarks.fig10_scenarios",
    "benchmarks.fig11_sw_opts",
    "benchmarks.fig12_linkbw",
    "benchmarks.fig14_topology",
    "benchmarks.fig16_scale",
    "benchmarks.fig17_pareto",
    "benchmarks.fig18_future",
    "benchmarks.fig_prefill_scenarios",
    "benchmarks.fig_prefill_overlap",
    "benchmarks.fig_parallelism",
    "benchmarks.fig_pipeline",
    "benchmarks.fig_failures",
    "benchmarks.fig_ocs",
    "benchmarks.fig_product_grid",
    "benchmarks.fig_skew",
    "benchmarks.fig_traffic",
    "benchmarks.roofline",
]

# Wall-clock seconds of the sweep-heavy modules measured at the seed commit
# (scalar optimizer, this container); the counterpart "now" timings are
# written next to these by `_save_sweep_timing` for the before/after record.
SEED_TIMINGS_S = {
    "benchmarks.fig9_batch_sweep": 0.32,
    "benchmarks.fig10_scenarios": 1.28,
    "benchmarks.fig11_sw_opts": 30.54,
    "benchmarks.fig12_linkbw": 69.24,
    "benchmarks.fig14_topology": 27.95,
    "benchmarks.fig16_scale": 23.05,
    "benchmarks.fig17_pareto": 283.79,
    "benchmarks.fig18_future": 185.44,
}

# Per-benchmark wall-clock budgets (seconds): absolute ceilings enforced by
# benchmarks/check_timing.py next to the 2x-vs-baseline ratio gate, sized
# ~20-40x the local runtimes so a cold CI runner passes but a quadratic
# candidate-grid blowup does not. Modules without a seed timing
# (fig_parallelism / fig_pipeline post-date the seed) are gated by their
# budget alone.
BUDGETS_S = {
    "benchmarks.fig9_batch_sweep": 10,
    "benchmarks.fig10_scenarios": 15,
    "benchmarks.fig11_sw_opts": 30,
    "benchmarks.fig12_linkbw": 60,
    "benchmarks.fig14_topology": 45,
    "benchmarks.fig16_scale": 45,
    "benchmarks.fig17_pareto": 180,
    "benchmarks.fig18_future": 120,
    "benchmarks.fig_parallelism": 60,
    "benchmarks.fig_pipeline": 120,
    "benchmarks.fig_prefill_overlap": 120,
    "benchmarks.fig_failures": 180,
    # five-fabric fig14 grid (one batched pass) + a single-size
    # fig17-style Pareto arm over 5 topologies x 5 bandwidth fractions
    "benchmarks.fig_ocs": 120,
    # 10^6-cell numpy-vs-jax product grid: ~35s local (numpy reference
    # pass dominates), plus jit compile and a cold CI runner's margin
    "benchmarks.fig_product_grid": 240,
    # 4 Zipf-s levels x 2 placement arms, each a full topology x scenario
    # grid; the placement arm re-sweeps per replica-count candidate
    "benchmarks.fig_skew": 240,
    # 4 topologies x (5-load bursty sweep + 40-min diurnal static/auto
    # pair + fault arm); the diurnal sims dominate (~10^5 iterations of
    # the traffic clock each)
    "benchmarks.fig_traffic": 360,
}


def _save_sweep_timing(timings: dict) -> None:
    """Record seed-vs-now wall-clock for the sweep-heavy modules. Timings
    from earlier (filtered) harness runs are kept, so partial runs
    accumulate into one before/after record."""
    import os

    from benchmarks.common import OUT_DIR, save

    prior = {}
    path = os.path.join(OUT_DIR, "BENCH_sweep_timing.json")
    if os.path.exists(path):
        with open(path) as f:
            prior = json.load(f).get("modules", {})

    rows = {}
    seed_total = now_total = 0.0
    complete = True
    tracked = dict.fromkeys(list(SEED_TIMINGS_S) + list(BUDGETS_S))
    for name in tracked:
        seed_s = SEED_TIMINGS_S.get(name)
        short = name.split(".")[-1]
        now_s = timings.get(name, prior.get(short, {}).get("now_s"))
        rows[short] = {"seed_s": seed_s, "now_s": now_s,
                       "budget_s": BUDGETS_S.get(name)}
        if now_s is None:
            complete = False
            continue
        if seed_s is None:
            continue                 # budget-only module (no seed record)
        seed_total += seed_s
        now_total += now_s
    payload = {
        "modules": rows,
        "seed_total_s": round(seed_total, 2),
        "now_total_s": round(now_total, 2),
        "speedup_end_to_end": (round(seed_total / now_total, 1)
                               if now_total else None),
        "all_modules_timed": complete,
    }

    # op-table LRU effectiveness over THIS harness run: mapping x model x
    # fault product grids thrash a small cache (the old maxsize=64 bound),
    # and a low hit rate here is the early warning
    from repro.core import optable
    payload["optable_cache"] = optable.cache_stats()

    # the jitted product-grid engine's speedup-vs-NumPy record (written by
    # fig_product_grid this run, or carried from its committed JSON)
    pg_path = os.path.join(OUT_DIR, "fig_product_grid.json")
    if os.path.exists(pg_path):
        with open(pg_path) as f:
            pg = json.load(f)
        payload["product_grid_jax"] = {
            "n_cells": pg.get("grid", {}).get("n_cells"),
            "numpy_s": pg.get("seq", {}).get("numpy_s"),
            "jax_s": pg.get("seq", {}).get("jax_s"),
            "speedup": pg.get("seq", {}).get("speedup"),
        }
    save("BENCH_sweep_timing", payload)


def main(argv):
    import argparse
    ap = argparse.ArgumentParser(
        prog="benchmarks.run",
        description="Run benchmark modules (see module docstring).")
    ap.add_argument("pattern", nargs="?", default="",
                    help="substring filter on dotted module names; empty "
                         "runs everything")
    ap.add_argument("--list", action="store_true", dest="list_modules",
                    help="print registered modules (short + dotted names) "
                         "and exit")
    ap.add_argument("--only", default=None, metavar="NAME",
                    help="run exactly one module; NAME must equal a short "
                         "name (e.g. fig14_topology) or dotted path — "
                         "errors on no match, unlike the substring filter")
    args = ap.parse_args(argv[1:])

    if args.list_modules:
        for name in MODULES:
            print(f"{name.split('.')[-1]:<24} {name}")
        return 0
    if args.only is not None:
        selected = [n for n in MODULES
                    if n == args.only or n.split(".")[-1] == args.only]
        if not selected:
            known = ", ".join(n.split(".")[-1] for n in MODULES)
            print(f"--only {args.only!r} matches no registered module; "
                  f"known benchmarks: {known}", file=sys.stderr)
            return 2
    else:
        selected = [n for n in MODULES if args.pattern in n]

    failures = []
    claims_summary = {}
    timings = {}
    for name in selected:
        print(f"\n{'=' * 72}\n== {name}\n{'=' * 72}", flush=True)
        t0 = time.time()
        try:
            mod = importlib.import_module(name)
            res = mod.run(verbose=True)
            claims = res.get("claims", {}) if isinstance(res, dict) else {}
            claims_summary[name] = claims
            timings[name] = round(time.time() - t0, 2)
        except Exception:
            traceback.print_exc()
            failures.append(name)
        print(f"[{name}: {time.time() - t0:.1f}s]", flush=True)

    if any(name in SEED_TIMINGS_S or name in BUDGETS_S
           for name in timings):
        _save_sweep_timing(timings)

    print(f"\n{'=' * 72}\n== CLAIM SUMMARY\n{'=' * 72}")
    n_true = n_false = 0
    for name, claims in claims_summary.items():
        for k, v in claims.items():
            if isinstance(v, bool):
                n_true += v
                n_false += (not v)
                mark = "PASS" if v else "FAIL"
                print(f"  [{mark}] {name.split('.')[-1]}: {k}")
            else:
                print(f"  [info] {name.split('.')[-1]}: {k} = {v}")
    print(f"\nclaims: {n_true} pass, {n_false} fail; "
          f"module failures: {failures or 'none'}")
    return 1 if failures or n_false else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
