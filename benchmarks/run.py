"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig14      # substring filter

Results land in bench_results/*.json; claim checks print per module."""
from __future__ import annotations

import importlib
import sys
import time
import traceback

MODULES = [
    "benchmarks.table1_alphabeta",
    "benchmarks.table3_coeffs",
    "benchmarks.validation",
    "benchmarks.fig5_dbo_latency",
    "benchmarks.fig7_a2a_time",
    "benchmarks.fig9_batch_sweep",
    "benchmarks.fig10_scenarios",
    "benchmarks.fig11_sw_opts",
    "benchmarks.fig12_linkbw",
    "benchmarks.fig14_topology",
    "benchmarks.fig16_scale",
    "benchmarks.fig17_pareto",
    "benchmarks.fig18_future",
    "benchmarks.roofline",
]


def main(argv):
    pattern = argv[1] if len(argv) > 1 else ""
    failures = []
    claims_summary = {}
    for name in MODULES:
        if pattern and pattern not in name:
            continue
        print(f"\n{'=' * 72}\n== {name}\n{'=' * 72}", flush=True)
        t0 = time.time()
        try:
            mod = importlib.import_module(name)
            res = mod.run(verbose=True)
            claims = res.get("claims", {}) if isinstance(res, dict) else {}
            claims_summary[name] = claims
        except Exception:
            traceback.print_exc()
            failures.append(name)
        print(f"[{name}: {time.time() - t0:.1f}s]", flush=True)

    print(f"\n{'=' * 72}\n== CLAIM SUMMARY\n{'=' * 72}")
    n_true = n_false = 0
    for name, claims in claims_summary.items():
        for k, v in claims.items():
            if isinstance(v, bool):
                n_true += v
                n_false += (not v)
                mark = "PASS" if v else "FAIL"
                print(f"  [{mark}] {name.split('.')[-1]}: {k}")
            else:
                print(f"  [info] {name.split('.')[-1]}: {k} = {v}")
    print(f"\nclaims: {n_true} pass, {n_false} fail; "
          f"module failures: {failures or 'none'}")
    return 1 if failures or n_false else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
