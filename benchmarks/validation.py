"""Paper Fig 8: end-to-end runtime-estimation validation.

The paper validates against (a) an 8xH100 HGX and (b) the public SGLang
DeepSeek-V3 96xH100 deployment report, with <9.6% / <7.5% relative error.
We have no H100, so this bench validates our roofline-with-efficiency
compute model against the SAME public reference points the paper used:

  SGLang large-scale-EP blog (12x8 H100, PD-disaggregated): decode phase
  ~22.3k output tok/s per node (2787 tok/s/GPU) at ~2000-token contexts,
  decode batch ~256 requests/GPU  -> implied TPOT ~ 92 ms.

We report our model's TPOT at that operating point and the relative error.
The paper's profiled model achieves <7.5%; our unprofiled roofline model is
expected to land within ~2x (documented in EXPERIMENTS.md; all topology
COMPARISONS are ratios, which cancel first-order efficiency error)."""
from __future__ import annotations

from benchmarks.common import save, table
from repro.configs import get_arch
from repro.core import H100, make_cluster
from repro.core.optimizer import iteration_time
from repro.core.workload import ServingPoint

# public reference (SGLang blog, May 2025)
SGLANG = {
    "n_gpus": 96,
    "decode_tok_s_per_gpu": 2787.0,
    "batch_per_gpu": 256,
    "context": 2000,
    "implied_tpot_ms": 256 / 2787.0 * 1e3,     # ~91.9 ms
}


def run(verbose: bool = True):
    cfg = get_arch("deepseek-v3")
    n = SGLANG["n_gpus"]
    cl = make_cluster("scale-out", n, H100)     # their fabric: IB Clos
    p = ServingPoint(batch_global=SGLANG["batch_per_gpu"] * n,
                     context=SGLANG["context"], ep=n, n_devices=n)
    t, ect, tc, tm = iteration_time(cfg, p, cl, dbo=False)
    ours_ms = t * 1e3
    ref_ms = SGLANG["implied_tpot_ms"]
    rel_err = (ours_ms - ref_ms) / ref_ms

    rows = [
        ["TPOT (ms)", f"{ours_ms:.1f}", f"{ref_ms:.1f}",
         f"{rel_err * +100:+.1f}%"],
        ["tok/s/GPU", f"{SGLANG['batch_per_gpu'] / t / 1:.0f}",
         f"{SGLANG['decode_tok_s_per_gpu']:.0f}", ""],
        ["  t_compute (ms)", f"{tc * 1e3:.1f}", "-", ""],
        ["  t_comm (ms)", f"{tm * 1e3:.1f}", "-", ""],
    ]
    out = table(["quantity", "our model", "SGLang 96xH100", "rel err"],
                rows, title="Fig 8 validation — DeepSeek-V3 decode vs "
                            "public trace (paper's profiled model: <7.5%)")
    results = {
        "ours_tpot_ms": ours_ms, "ref_tpot_ms": ref_ms,
        "rel_err": rel_err, "t_compute_ms": tc * 1e3,
        "t_comm_ms": tm * 1e3,
        "within_2x": bool(abs(rel_err) < 1.0),
    }
    if verbose:
        print(out)
        print(f"\nwithin 2x of public trace: {results['within_2x']}")
    save("validation", results)
    return results


if __name__ == "__main__":
    run()
