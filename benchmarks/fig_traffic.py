"""fig_traffic: goodput-under-SLO attainment per topology per $ from the
cluster-scale traffic simulator (`repro.core.traffic`).

The capacity figures answer "best steady-state operating point"; this one
replays seeded arrival traces — Gamma-burst and diurnal Poisson — against
the four Table-3 topologies running operating points obtained through
`repro.core.api.solve`, and prices what production actually sells:
goodput (decode tokens of requests meeting BOTH the TTFT and TPOT SLO)
per monthly fleet dollar.

Three arms per topology (olmoe-1b-7b on 8 XPUs — small enough that a
2-minute bursty trace is tens of thousands of requests):

  1. Bursty load sweep: a CV^2=4 Gamma arrival stream scaled to 0.6-1.1x
     the topology's OWN searched capacity. The TPOT SLO binds the
     searched batch cap, so offered load beyond 1.0x queues instead of
     batching up — SLO attainment holds a plateau and then falls off a
     cliff, and the cliff is where the topologies separate.
  2. Diurnal autoscaling: a day-shaped rate curve (compressed to a 40-min
     trace) served either by the static full pool or by a threshold
     autoscaler over {1/4, 1/2, 1} pools; `best_provisioning` keeps the
     best goodput/$ of {static, autoscale}, so autoscaling can never
     lose, and the recorded margin is its actual win.
  3. Compressed-timescale fault injection at 0.8x load: seeded injector
     firings become queueing events (drain + re-shard downtime + degraded
     serving), so faults show up as TTFT spikes and goodput loss, never
     as a goodput gain.

All traces, fault plans, and policies are seeded and the simulator is
deterministic, so the emitted JSON is byte-stable under regeneration
(the CI bench gate diffs it with `-I'"_time"'`).
"""
from __future__ import annotations

from benchmarks.common import save, table
from repro.configs import get_arch
from repro.core import H100, Scenario, SearchSpec, make_cluster, traffic

TOPOS = ("scale-up", "scale-out", "torus", "fullmesh")
N_XPUS = 8
ARCH = "olmoe-1b-7b"
# TPOT tight enough that the searched batch cap binds the SLO (the cliff
# precondition — see docs/traffic_sim.md) and an explicit TTFT SLO so
# queueing delay costs attainment.
SCENARIO = Scenario(15.0, 512, ttft_ms=500.0)
MIX = ((0.75, 0, 768), (0.25, 0, 1792))      # mean gen = 1024 tokens
POOL_FRACS = (0.25, 0.5, 1.0)
LOADS = (0.6, 0.8, 0.9, 1.0, 1.1)
FAULT_LOAD = 0.8
BURSTY = dict(horizon_s=120.0, cv2=4.0, seed=11)
DIURNAL = dict(horizon_s=2400.0, period_s=1200.0, amplitude=0.6,
               mean_load=0.45, seed=3)
POLICY = traffic.AutoscalePolicy(check_interval_s=60.0, target_util=0.7,
                                 min_dwell_s=300.0)
# fault timescales compressed to the 2-minute bursty horizon
FAULT_RATE_PER_ITER = 5e-5
FAULT_REPAIR_S = 45.0
FAULT_DOWNTIME_S = 10.0

_KEEP = ("attainment", "goodput_tok_s", "goodput_per_cost", "ttft_p99",
         "tpot_p99", "active_frac", "cost_month", "n_switches",
         "n_fault_events", "n_requests")


def _slim(res: traffic.TrafficResult) -> dict:
    d = res.as_dict()
    return {k: d[k] for k in _KEEP}


def run(verbose: bool = True):
    cfg = get_arch(ARCH)
    mean_gen = 0.0
    tot = sum(w for w, _, _ in MIX)
    for w, _, g in MIX:
        mean_gen += w / tot * g

    results = {"scenario": SCENARIO.name, "loads": list(LOADS)}
    rows_load, rows_diurnal, rows_fault = [], [], []
    per_topo = {}
    for topo in TOPOS:
        cl = make_cluster(topo, N_XPUS, H100)
        cat = traffic.build_catalog(cfg, cl, SCENARIO, SearchSpec(),
                                    pool_fracs=POOL_FRACS, mix=MIX)
        cap_rps = cat.capacity_rps(cat.full, mean_gen)
        entry = {"capacity_rps": float(f"{cap_rps:.9g}"),
                 "cap_batch": cat.full.cap,
                 "tpot_at_cap_ms": float(f"{cat.full.tpot[-1] * 1e3:.9g}")}

        # ---- arm 1: bursty load sweep (same unit stream per topology,
        # time-compressed by load -> monotone by construction) ----
        base = traffic.TraceSpec(
            horizon_s=BURSTY["horizon_s"], rate_rps=cap_rps,
            arrival="gamma", cv2=BURSTY["cv2"], length_mix=MIX,
            seed=BURSTY["seed"], name=f"bursty-{topo}")
        entry["bursty"] = {}
        fault_trace = None
        for load in LOADS:
            tr = traffic.generate_trace(base.scaled(load))
            res = traffic.simulate_trace(cat, tr)
            entry["bursty"][f"{load:g}"] = _slim(res)
            rows_load.append([topo, f"{load:g}", f"{res.attainment:.4f}",
                              f"{res.goodput_per_cost:.2f}",
                              f"{res.ttft_p99 * 1e3:.0f}ms"])
            if load == FAULT_LOAD:
                fault_trace = tr

        # ---- arm 2: diurnal autoscaling vs static ----
        dspec = traffic.TraceSpec(
            horizon_s=DIURNAL["horizon_s"],
            rate_rps=DIURNAL["mean_load"] * cap_rps, arrival="poisson",
            diurnal_amplitude=DIURNAL["amplitude"],
            diurnal_period_s=DIURNAL["period_s"], length_mix=MIX,
            seed=DIURNAL["seed"], name=f"diurnal-{topo}")
        dtr = traffic.generate_trace(dspec)
        static = traffic.simulate_trace(cat, dtr)
        best_name, best = traffic.best_provisioning(
            cat, dtr, policies=[None, POLICY])
        entry["diurnal"] = {"static": _slim(static),
                            "best": _slim(best),
                            "best_policy": best_name}
        rows_diurnal.append(
            [topo, f"{static.attainment:.4f}",
             f"{static.goodput_per_cost:.2f}", best_name,
             f"{best.attainment:.4f}", f"{best.goodput_per_cost:.2f}",
             f"{best.active_frac:.2f}", best.n_switches])

        # ---- arm 3: compressed-timescale fault injection ----
        plan = traffic.seeded_fault_plan(
            cl, n_iters=cat.est_iterations(fault_trace),
            rate_per_iter=FAULT_RATE_PER_ITER, seed=BURSTY["seed"],
            repair_s=FAULT_REPAIR_S, downtime_s=FAULT_DOWNTIME_S)
        healthy = traffic.simulate_trace(cat, fault_trace)
        faulted = traffic.simulate_trace(cat, fault_trace, faults=plan)
        entry["faults"] = {"healthy": _slim(healthy),
                           "faulted": _slim(faulted)}
        rows_fault.append(
            [topo, faulted.n_fault_events,
             f"{healthy.ttft_p99 * 1e3:.0f}ms",
             f"{faulted.ttft_p99 * 1e3:.0f}ms",
             f"{healthy.goodput_tok_s:.0f}",
             f"{faulted.goodput_tok_s:.0f}"])

        per_topo[topo] = entry
    results["topologies"] = per_topo

    # ---- rankings (most cost-effective first) ----
    def rank(metric):
        return sorted(
            TOPOS, key=lambda t: -metric(per_topo[t]))

    rank_bursty = rank(lambda e: e["bursty"][f"{FAULT_LOAD:g}"]
                       ["goodput_per_cost"])
    rank_diurnal = rank(lambda e: e["diurnal"]["best"]["goodput_per_cost"])
    ttft_bursty = sorted(TOPOS, key=lambda t: per_topo[t]["bursty"]
                         [f"{FAULT_LOAD:g}"]["ttft_p99"])
    results["rankings"] = {
        "bursty_goodput_per_cost": rank_bursty,
        "bursty_p99_ttft_best_first": ttft_bursty,
        "diurnal_goodput_per_cost": rank_diurnal,
    }

    def attains(topo):
        return [per_topo[topo]["bursty"][f"{ld:g}"]["attainment"]
                for ld in LOADS]

    results["claims"] = {
        # queueing theory sanity: compressing the SAME request stream can
        # only hurt — attainment is monotone non-increasing in load
        "attainment_monotone_in_load": all(
            a + 1e-6 >= b for topo in TOPOS
            for a, b in zip(attains(topo), attains(topo)[1:])),
        # the TPOT SLO binds the searched cap, so overload queues: every
        # topology falls off the attainment plateau past 1.0x capacity
        "attainment_cliff_past_capacity": all(
            attains(topo)[-1] < attains(topo)[0] - 0.05 for topo in TOPOS),
        # the paper's switchless headline survives bursty serving:
        # torus/full-mesh beat scale-up on goodput/$ at 0.8x load
        "switchless_wins_bursty_goodput_per_cost": max(
            per_topo["torus"]["bursty"][f"{FAULT_LOAD:g}"]
            ["goodput_per_cost"],
            per_topo["fullmesh"]["bursty"][f"{FAULT_LOAD:g}"]
            ["goodput_per_cost"]) > per_topo["scale-up"]["bursty"]
            [f"{FAULT_LOAD:g}"]["goodput_per_cost"],
        # best_provisioning includes the static arm, so autoscaling never
        # loses on ANY trace...
        "autoscale_never_loses": all(
            per_topo[t]["diurnal"]["best"]["goodput_per_cost"]
            >= per_topo[t]["diurnal"]["static"]["goodput_per_cost"]
            for t in TOPOS),
        # ...and the diurnal trough makes it strictly win on EVERY
        # topology (parked capacity bills elsewhere; the fabric does not)
        "autoscale_strictly_wins_diurnal": all(
            per_topo[t]["diurnal"]["best"]["goodput_per_cost"]
            > per_topo[t]["diurnal"]["static"]["goodput_per_cost"]
            for t in TOPOS),
        # faults are queueing events: the p99 TTFT spikes and goodput
        # never improves, on every topology
        "faults_spike_ttft_never_add_goodput": all(
            per_topo[t]["faults"]["faulted"]["ttft_p99"]
            >= per_topo[t]["faults"]["healthy"]["ttft_p99"]
            and per_topo[t]["faults"]["faulted"]["goodput_tok_s"]
            <= per_topo[t]["faults"]["healthy"]["goodput_tok_s"]
            and per_topo[t]["faults"]["faulted"]["n_fault_events"] >= 1
            for t in TOPOS),
    }

    if verbose:
        print(table(["topology", "load", "attainment", "goodput/$",
                     "p99 TTFT"], rows_load,
                    title=f"fig_traffic — bursty load sweep "
                          f"(CV^2={BURSTY['cv2']:g}, {ARCH}, "
                          f"{N_XPUS} XPUs)"))
        print()
        print(table(["topology", "static att", "static g/$", "best",
                     "best att", "best g/$", "active", "switches"],
                    rows_diurnal, title="fig_traffic — diurnal trace: "
                                        "static vs best provisioning"))
        print()
        print(table(["topology", "events", "p99 TTFT healthy",
                     "p99 TTFT faulted", "goodput healthy",
                     "goodput faulted"], rows_fault,
                    title="fig_traffic — fault injection at "
                          f"{FAULT_LOAD:g}x load"))
        print("\nrankings:", results["rankings"])
        print("claims:", results["claims"])
    save("fig_traffic", results)
    return results


if __name__ == "__main__":
    run()
