"""Paper Fig 12 + 13: throughput per cost vs scale-up link bandwidth.

Headline: the 1x provisioning (450 GB/s) is past the sweet spot; choosing
the sweet spot improves throughput/cost by 6-27% across scenarios (§4.2).
Fig 13: the sweet spot is robust to the cost adjustment factor c."""
from __future__ import annotations

from benchmarks.common import save, solve_level_points, table
from repro.configs import get_arch
from repro.core import H100, Scenario, make_cluster
from repro.core.tco import cluster_tco

# the sweep is "x of the scale-up provision" (450 GB/s on H100): the
# multipliers land on 50/150/300/450/900 GB/s exactly
BW_MULTS = (1 / 9, 1 / 3, 2 / 3, 1.0, 2.0)
BWS = tuple(H100.scale_up_bw * m for m in BW_MULTS)
SCENARIOS = [Scenario(t, c) for c in (512, 4096) for t in (15.0, 40.0, 100.0)]


def run(verbose: bool = True):
    cfg = get_arch("deepseek-v3")
    clusters = [make_cluster("scale-up", 64, H100, link_bw_mult=m)
                for m in BW_MULTS]
    costs = {c: {bw: cluster_tco(cl).per_xpu(cl.n_xpus, c)
                 for bw, cl in zip(BWS, clusters)}
             for c in (0.25, 0.5, 1.0, 2.0)}
    # one shared engine pass covers all bandwidths x scenarios x opts; the
    # fig13 c-sweep reuses the dbo+sd operating points (throughput does not
    # depend on the cost adjustment factor).
    grids = solve_level_points(cfg, clusters, SCENARIOS,
                               ("noopt", "dbo", "dbo+sd"))

    def tpc_at(opts, bi, si, c=1.0):
        op = grids[opts][bi][si]
        if op is None:
            return 0.0
        return op.throughput / clusters[bi].n_xpus / costs[c][BWS[bi]]

    results = {"fig12": {}, "fig13": {}}
    improvements = []
    rows = []
    for si, sc in enumerate(SCENARIOS):
        for opts in ("noopt", "dbo", "dbo+sd"):
            vals = {bw: tpc_at(opts, bi, si) for bi, bw in enumerate(BWS)}
            results["fig12"][f"{sc.name}/{opts}"] = {
                str(int(b / 1e9)): v for b, v in vals.items()}
            best_bw = max(vals, key=vals.get)
            imp = (vals[best_bw] / vals[450e9] - 1) * 100 if vals[450e9] else 0
            if opts == "dbo+sd":
                improvements.append(imp)
            rows.append([sc.name, opts, f"{int(best_bw / 1e9)}GB/s",
                         f"{imp:+.1f}%"])
    out = table(["scenario", "opts", "sweet spot", "gain vs 1x"], rows,
                title="Fig 12 — link-BW sweet spot (paper: sweet spot below "
                      "1x; +6-27% with sw opts)")

    # Fig 13: c sweep at one scenario
    si40 = SCENARIOS.index(Scenario(40.0, 512))
    for c in (0.25, 0.5, 1.0, 2.0):
        vals = {bw: tpc_at("dbo+sd", bi, si40, c)
                for bi, bw in enumerate(BWS)}
        best_bw = max(vals, key=vals.get)
        results["fig13"][f"c={c}"] = {"sweet_spot_GBs": best_bw / 1e9,
                                      "curve": {str(int(b / 1e9)): v
                                                for b, v in vals.items()}}
    results["claims"] = {
        "sweet_spot_below_1x_fraction":
            sum(1 for r in rows if r[1] == "dbo+sd"
                and int(r[2].rstrip("GB/s")) < 450) / len(SCENARIOS),
        "improvement_range_pct": [min(improvements), max(improvements)],
        "paper_range_pct": [6.0, 27.0],
        "fig13_sweet_spot_stable": len({v["sweet_spot_GBs"]
                                        for v in results["fig13"].values()
                                        }) <= 2,
    }
    if verbose:
        print(out)
        print("\nclaims:", results["claims"])
    save("fig12_linkbw", results)
    return results


if __name__ == "__main__":
    run()
